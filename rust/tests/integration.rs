//! Integration tests over the runtime: manifest loading, parameter init,
//! stage execution against the real `tiny` artifacts, and cross-layer
//! consistency (rust flops model vs python costmodel in the manifest).
//!
//! Requires `make artifacts` (skips gracefully if artifacts are missing so
//! `cargo test` before the AOT step still passes unit tests).

use std::collections::BTreeMap;

use sfprompt::data::{make_batch, synth::DatasetProfile, SynthDataset};
use sfprompt::flops;
use sfprompt::model::{init_params, SegmentParams};
use sfprompt::runtime::{ArtifactStore, Executor, HostTensor, TensorInputs};

fn open_tiny() -> Option<ArtifactStore> {
    match ArtifactStore::open(&sfprompt::artifacts_root(), "tiny") {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("SKIP (no artifacts): {e:#}");
            None
        }
    }
}

fn batch_for(store: &ArtifactStore) -> (HostTensor, HostTensor) {
    let cfg = &store.manifest.config;
    let profile = DatasetProfile {
        name: "t",
        num_classes: cfg.num_classes,
        noise: 0.4,
        class_overlap: 0.1,
    };
    let ds = SynthDataset::generate(profile, cfg.image_size, cfg.channels, cfg.batch, 3, 4);
    let idx: Vec<usize> = (0..cfg.batch).collect();
    let b = make_batch(&ds.examples, &idx, cfg.batch, cfg.image_size, cfg.channels);
    (b.images, b.labels)
}

#[test]
fn manifest_loads_and_validates() {
    let Some(store) = open_tiny() else { return };
    let man = &store.manifest;
    assert_eq!(man.config.name, "tiny");
    assert!(man.stages.contains_key("local_step"));
    assert!(man.stages.contains_key("head_forward"));
    for seg in ["head", "body", "tail", "prompt"] {
        assert!(!man.segment(seg).unwrap().is_empty(), "{seg}");
    }
    let params = init_params(man, 7);
    params.validate(man).unwrap();
}

#[test]
fn init_is_deterministic_and_respects_specs() {
    let Some(store) = open_tiny() else { return };
    let a = init_params(&store.manifest, 42);
    let b = init_params(&store.manifest, 42);
    let c = init_params(&store.manifest, 43);
    for seg in ["head", "tail", "prompt"] {
        assert!(a.get(seg).unwrap().max_abs_diff(b.get(seg).unwrap()) == 0.0);
        assert!(a.get(seg).unwrap().max_abs_diff(c.get(seg).unwrap()) > 0.0);
    }
    // LayerNorm scales init at exactly 1, biases at 0.
    let head = a.get("head").unwrap();
    let defs = store.manifest.segment("head").unwrap();
    for (t, d) in head.tensors.iter().zip(defs) {
        if d.name.ends_with("ln1.scale") {
            assert!(t.as_f32().iter().all(|&x| x == 1.0));
        }
        if d.name.ends_with("ln1.bias") {
            assert!(t.as_f32().iter().all(|&x| x == 0.0));
        }
    }
}

#[test]
fn local_step_executes_and_reduces_loss() {
    let Some(store) = open_tiny() else { return };
    let params = init_params(&store.manifest, 7);
    let (images, labels) = batch_for(&store);
    let lr = HostTensor::scalar_f32(0.1);

    let mut tail = params.get("tail").unwrap().clone();
    let mut prompt = params.get("prompt").unwrap().clone();
    let head = params.get("head").unwrap();

    let mut losses = Vec::new();
    for _ in 0..5 {
        let mut segs: BTreeMap<&str, &SegmentParams> = BTreeMap::new();
        segs.insert("head", head);
        segs.insert("tail", &tail);
        segs.insert("prompt", &prompt);
        let mut tensors: TensorInputs = BTreeMap::new();
        tensors.insert("images", &images);
        tensors.insert("labels", &labels);
        tensors.insert("lr", &lr);
        let mut out = Executor::run(&store, "local_step", &segs, &tensors).unwrap();
        losses.push(out.loss().unwrap());
        tail = out.take_segment("tail").unwrap();
        prompt = out.take_segment("prompt").unwrap();
    }
    assert!(losses[4] < losses[0], "{losses:?}");
    assert!(losses.iter().all(|l| l.is_finite()));
}

#[test]
fn split_chain_matches_shapes_and_runs() {
    let Some(store) = open_tiny() else { return };
    let cfg = store.manifest.config.clone();
    let params = init_params(&store.manifest, 7);
    let (images, labels) = batch_for(&store);
    let lr = HostTensor::scalar_f32(0.05);

    // head_forward
    let mut segs: BTreeMap<&str, &SegmentParams> = BTreeMap::new();
    segs.insert("head", params.get("head").unwrap());
    segs.insert("prompt", params.get("prompt").unwrap());
    let mut tensors: TensorInputs = BTreeMap::new();
    tensors.insert("images", &images);
    let out = Executor::run(&store, "head_forward", &segs, &tensors).unwrap();
    let smashed = out.tensor("smashed").unwrap().clone();
    assert_eq!(smashed.shape, vec![cfg.batch, cfg.seq_len, cfg.dim]);

    // body_forward
    let mut segs: BTreeMap<&str, &SegmentParams> = BTreeMap::new();
    segs.insert("body", params.get("body").unwrap());
    let mut tensors: TensorInputs = BTreeMap::new();
    tensors.insert("smashed", &smashed);
    let out = Executor::run(&store, "body_forward", &segs, &tensors).unwrap();
    let body_out = out.tensor("body_out").unwrap().clone();

    // tail_step
    let mut segs: BTreeMap<&str, &SegmentParams> = BTreeMap::new();
    segs.insert("tail", params.get("tail").unwrap());
    let mut tensors: TensorInputs = BTreeMap::new();
    tensors.insert("body_out", &body_out);
    tensors.insert("labels", &labels);
    tensors.insert("lr", &lr);
    let out = Executor::run(&store, "tail_step", &segs, &tensors).unwrap();
    let loss = out.loss().unwrap();
    let g_body_out = out.tensor("g_body_out").unwrap().clone();
    assert!(loss.is_finite() && loss > 0.0);
    assert_eq!(g_body_out.shape, smashed.shape);
    // Updated tail differs from the original.
    assert!(out.segment("tail").unwrap().max_abs_diff(params.get("tail").unwrap()) > 0.0);

    // body_backward
    let mut segs: BTreeMap<&str, &SegmentParams> = BTreeMap::new();
    segs.insert("body", params.get("body").unwrap());
    let mut tensors: TensorInputs = BTreeMap::new();
    tensors.insert("smashed", &smashed);
    tensors.insert("g_body_out", &g_body_out);
    let out = Executor::run(&store, "body_backward", &segs, &tensors).unwrap();
    let g_smashed = out.tensor("g_smashed").unwrap().clone();

    // prompt_grad
    let mut segs: BTreeMap<&str, &SegmentParams> = BTreeMap::new();
    segs.insert("head", params.get("head").unwrap());
    segs.insert("prompt", params.get("prompt").unwrap());
    let mut tensors: TensorInputs = BTreeMap::new();
    tensors.insert("images", &images);
    tensors.insert("g_smashed", &g_smashed);
    tensors.insert("lr", &lr);
    let out = Executor::run(&store, "prompt_grad", &segs, &tensors).unwrap();
    assert!(out.segment("prompt").unwrap().max_abs_diff(params.get("prompt").unwrap()) > 0.0);
}

#[test]
fn el2n_scores_separate_easy_and_hard() {
    let Some(store) = open_tiny() else { return };
    let params = init_params(&store.manifest, 7);
    let (images, labels) = batch_for(&store);
    let mut segs: BTreeMap<&str, &SegmentParams> = BTreeMap::new();
    segs.insert("head", params.get("head").unwrap());
    segs.insert("tail", params.get("tail").unwrap());
    segs.insert("prompt", params.get("prompt").unwrap());
    let mut tensors: TensorInputs = BTreeMap::new();
    tensors.insert("images", &images);
    tensors.insert("labels", &labels);
    let out = Executor::run(&store, "el2n_scores", &segs, &tensors).unwrap();
    let scores = out.tensor("scores").unwrap();
    assert_eq!(scores.shape, vec![store.manifest.config.batch]);
    // EL2N is in [0, sqrt(2)] for probability vectors.
    assert!(scores.as_f32().iter().all(|&s| (0.0..=1.5).contains(&s)));
}

#[test]
fn missing_inputs_fail_loudly() {
    let Some(store) = open_tiny() else { return };
    let params = init_params(&store.manifest, 7);
    let segs: BTreeMap<&str, &SegmentParams> = BTreeMap::new();
    let tensors: TensorInputs = BTreeMap::new();
    // No segments provided at all.
    assert!(Executor::run(&store, "local_step", &segs, &tensors).is_err());
    // Wrong tensor shape.
    let (images, _) = batch_for(&store);
    let mut segs: BTreeMap<&str, &SegmentParams> = BTreeMap::new();
    segs.insert("head", params.get("head").unwrap());
    segs.insert("prompt", params.get("prompt").unwrap());
    let bad = HostTensor::zeros(vec![1, 2, 3]);
    let mut t: TensorInputs = BTreeMap::new();
    t.insert("images", &bad);
    assert!(Executor::run(&store, "head_forward", &segs, &t).is_err());
    drop(images);
}

#[test]
fn unknown_stage_and_config_error() {
    let Some(store) = open_tiny() else { return };
    assert!(store.stage_def("nope").is_err());
    assert!(ArtifactStore::open(&sfprompt::artifacts_root(), "no_such_config").is_err());
}

#[test]
fn rust_flops_model_matches_python_costmodel() {
    // The manifest carries python/compile/costmodel.py's numbers; the rust
    // flops module must reproduce them for every non-analytic config.
    for config in ["tiny", "small", "small_c100", "vit_base_sim", "vit_large_sim"] {
        let man = match sfprompt::runtime::Manifest::load(
            &sfprompt::artifacts_root().join(config),
        ) {
            Ok(m) => m,
            Err(_) => {
                eprintln!("SKIP {config}");
                continue;
            }
        };
        let rust = flops::segment_flops(&man.config, true);
        let py = &man.cost.flops_fwd_per_sample;
        assert_eq!(rust.head, py["head"], "{config} head");
        assert_eq!(rust.body, py["body"], "{config} body");
        assert_eq!(rust.tail, py["tail"], "{config} tail");
        let rust_np = flops::segment_flops(&man.config, false);
        let py_np = &man.cost.flops_fwd_per_sample_noprompt;
        assert_eq!(rust_np.head, py_np["head"], "{config} head noprompt");
    }
}

#[test]
fn eval_forward_produces_logits() {
    let Some(store) = open_tiny() else { return };
    let cfg = store.manifest.config.clone();
    let params = init_params(&store.manifest, 7);
    let (images, _) = batch_for(&store);
    let mut segs: BTreeMap<&str, &SegmentParams> = BTreeMap::new();
    for s in ["head", "body", "tail", "prompt"] {
        segs.insert(s, params.get(s).unwrap());
    }
    let mut tensors: TensorInputs = BTreeMap::new();
    tensors.insert("images", &images);
    let out = Executor::run(&store, "eval_forward", &segs, &tensors).unwrap();
    let logits = out.tensor("logits").unwrap();
    assert_eq!(logits.shape, vec![cfg.batch, cfg.num_classes]);
    assert!(logits.as_f32().iter().all(|v| v.is_finite()));
}
