//! Integration tests over the substrate stack: synthesized manifests,
//! parameter init, stage execution on the native backend, and cross-layer
//! consistency (rust flops model vs the manifest's cost block). Nothing
//! here needs artifacts on disk — every test runs everywhere.

use std::collections::BTreeMap;

use sfprompt::backend::{run_stage_hosts, Backend, NativeBackend, TensorInputs};
use sfprompt::data::{make_batch, synth::DatasetProfile, SynthDataset};
use sfprompt::flops;
use sfprompt::model::{init_params, SegmentParams};
use sfprompt::runtime::HostTensor;

fn batch_for(backend: &NativeBackend) -> (HostTensor, HostTensor) {
    let cfg = &backend.manifest().config;
    let profile = DatasetProfile {
        name: "t",
        num_classes: cfg.num_classes,
        noise: 0.4,
        class_overlap: 0.1,
    };
    let ds = SynthDataset::generate(profile, cfg.image_size, cfg.channels, cfg.batch, 3, 4);
    let idx: Vec<usize> = (0..cfg.batch).collect();
    let b = make_batch(&ds.examples, &idx, cfg.batch, cfg.image_size, cfg.channels);
    (b.images, b.labels)
}

#[test]
fn synthesized_manifest_loads_and_validates() {
    let backend = NativeBackend::tiny();
    let man = backend.manifest();
    assert_eq!(man.config.name, "tiny");
    assert!(man.stages.contains_key("local_step"));
    assert!(man.stages.contains_key("head_forward"));
    for seg in ["head", "body", "tail", "prompt"] {
        assert!(!man.segment(seg).unwrap().is_empty(), "{seg}");
    }
    let params = init_params(man, 7);
    params.validate(man).unwrap();
}

#[test]
fn init_is_deterministic_and_respects_specs() {
    let backend = NativeBackend::tiny();
    let a = init_params(backend.manifest(), 42);
    let b = init_params(backend.manifest(), 42);
    let c = init_params(backend.manifest(), 43);
    for seg in ["head", "tail", "prompt"] {
        assert!(a.get(seg).unwrap().max_abs_diff(b.get(seg).unwrap()) == 0.0);
        assert!(a.get(seg).unwrap().max_abs_diff(c.get(seg).unwrap()) > 0.0);
    }
    // LayerNorm scales init at exactly 1, biases at 0.
    let head = a.get("head").unwrap();
    let defs = backend.manifest().segment("head").unwrap();
    for (t, d) in head.tensors.iter().zip(defs) {
        if d.name.ends_with("ln1.scale") {
            assert!(t.as_f32().iter().all(|&x| x == 1.0));
        }
        if d.name.ends_with("ln1.bias") {
            assert!(t.as_f32().iter().all(|&x| x == 0.0));
        }
    }
}

#[test]
fn local_step_executes_and_reduces_loss() {
    let backend = NativeBackend::tiny();
    let params = init_params(backend.manifest(), 7);
    let (images, labels) = batch_for(&backend);
    let lr = HostTensor::scalar_f32(0.1);

    let mut tail = params.get("tail").unwrap().clone();
    let mut prompt = params.get("prompt").unwrap().clone();
    let head = params.get("head").unwrap();

    let mut losses = Vec::new();
    for _ in 0..5 {
        let mut segs: BTreeMap<&str, &SegmentParams> = BTreeMap::new();
        segs.insert("head", head);
        segs.insert("tail", &tail);
        segs.insert("prompt", &prompt);
        let mut tensors: TensorInputs = BTreeMap::new();
        tensors.insert("images", &images);
        tensors.insert("labels", &labels);
        tensors.insert("lr", &lr);
        let mut out = run_stage_hosts(&backend, "local_step", &segs, &tensors).unwrap();
        losses.push(out.loss().unwrap());
        tail = out.take_segment("tail").unwrap();
        prompt = out.take_segment("prompt").unwrap();
    }
    assert!(losses[4] < losses[0], "{losses:?}");
    assert!(losses.iter().all(|l| l.is_finite()));
}

#[test]
fn split_chain_matches_shapes_and_runs() {
    let backend = NativeBackend::tiny();
    let cfg = backend.manifest().config.clone();
    let params = init_params(backend.manifest(), 7);
    let (images, labels) = batch_for(&backend);
    let lr = HostTensor::scalar_f32(0.05);

    // head_forward
    let mut segs: BTreeMap<&str, &SegmentParams> = BTreeMap::new();
    segs.insert("head", params.get("head").unwrap());
    segs.insert("prompt", params.get("prompt").unwrap());
    let mut tensors: TensorInputs = BTreeMap::new();
    tensors.insert("images", &images);
    let out = run_stage_hosts(&backend, "head_forward", &segs, &tensors).unwrap();
    let smashed = out.tensor("smashed").unwrap().clone();
    assert_eq!(smashed.shape, vec![cfg.batch, cfg.seq_len, cfg.dim]);

    // body_forward
    let mut segs: BTreeMap<&str, &SegmentParams> = BTreeMap::new();
    segs.insert("body", params.get("body").unwrap());
    let mut tensors: TensorInputs = BTreeMap::new();
    tensors.insert("smashed", &smashed);
    let out = run_stage_hosts(&backend, "body_forward", &segs, &tensors).unwrap();
    let body_out = out.tensor("body_out").unwrap().clone();

    // tail_step
    let mut segs: BTreeMap<&str, &SegmentParams> = BTreeMap::new();
    segs.insert("tail", params.get("tail").unwrap());
    let mut tensors: TensorInputs = BTreeMap::new();
    tensors.insert("body_out", &body_out);
    tensors.insert("labels", &labels);
    tensors.insert("lr", &lr);
    let out = run_stage_hosts(&backend, "tail_step", &segs, &tensors).unwrap();
    let loss = out.loss().unwrap();
    let g_body_out = out.tensor("g_body_out").unwrap().clone();
    assert!(loss.is_finite() && loss > 0.0);
    assert_eq!(g_body_out.shape, smashed.shape);
    // Updated tail differs from the original.
    assert!(out.segment("tail").unwrap().max_abs_diff(params.get("tail").unwrap()) > 0.0);

    // body_backward
    let mut segs: BTreeMap<&str, &SegmentParams> = BTreeMap::new();
    segs.insert("body", params.get("body").unwrap());
    let mut tensors: TensorInputs = BTreeMap::new();
    tensors.insert("smashed", &smashed);
    tensors.insert("g_body_out", &g_body_out);
    let out = run_stage_hosts(&backend, "body_backward", &segs, &tensors).unwrap();
    let g_smashed = out.tensor("g_smashed").unwrap().clone();

    // prompt_grad
    let mut segs: BTreeMap<&str, &SegmentParams> = BTreeMap::new();
    segs.insert("head", params.get("head").unwrap());
    segs.insert("prompt", params.get("prompt").unwrap());
    let mut tensors: TensorInputs = BTreeMap::new();
    tensors.insert("images", &images);
    tensors.insert("g_smashed", &g_smashed);
    tensors.insert("lr", &lr);
    let out = run_stage_hosts(&backend, "prompt_grad", &segs, &tensors).unwrap();
    assert!(out.segment("prompt").unwrap().max_abs_diff(params.get("prompt").unwrap()) > 0.0);
}

#[test]
fn el2n_scores_separate_easy_and_hard() {
    let backend = NativeBackend::tiny();
    let params = init_params(backend.manifest(), 7);
    let (images, labels) = batch_for(&backend);
    let mut segs: BTreeMap<&str, &SegmentParams> = BTreeMap::new();
    segs.insert("head", params.get("head").unwrap());
    segs.insert("tail", params.get("tail").unwrap());
    segs.insert("prompt", params.get("prompt").unwrap());
    let mut tensors: TensorInputs = BTreeMap::new();
    tensors.insert("images", &images);
    tensors.insert("labels", &labels);
    let out = run_stage_hosts(&backend, "el2n_scores", &segs, &tensors).unwrap();
    let scores = out.tensor("scores").unwrap();
    assert_eq!(scores.shape, vec![backend.manifest().config.batch]);
    // EL2N is in [0, sqrt(2)] for probability vectors.
    assert!(scores.as_f32().iter().all(|&s| (0.0..=1.5).contains(&s)));
}

#[test]
fn missing_inputs_fail_loudly() {
    let backend = NativeBackend::tiny();
    let params = init_params(backend.manifest(), 7);
    let segs: BTreeMap<&str, &SegmentParams> = BTreeMap::new();
    let tensors: TensorInputs = BTreeMap::new();
    // No segments provided at all.
    assert!(run_stage_hosts(&backend, "local_step", &segs, &tensors).is_err());
    // Wrong tensor shape.
    let (images, _) = batch_for(&backend);
    let mut segs: BTreeMap<&str, &SegmentParams> = BTreeMap::new();
    segs.insert("head", params.get("head").unwrap());
    segs.insert("prompt", params.get("prompt").unwrap());
    let bad = HostTensor::zeros(vec![1, 2, 3]);
    let mut t: TensorInputs = BTreeMap::new();
    t.insert("images", &bad);
    assert!(run_stage_hosts(&backend, "head_forward", &segs, &t).is_err());
    drop(images);
}

#[test]
fn unknown_stage_and_config_error() {
    let backend = NativeBackend::tiny();
    assert!(backend.manifest().stage("nope").is_err());
    assert!(NativeBackend::for_config("no_such_config").is_err());
    // Analytic-only profiles synthesize manifests but refuse to execute.
    assert!(NativeBackend::for_config("vit_base_sim").is_err());
}

#[test]
fn rust_flops_model_matches_python_costmodel_goldens() {
    // The synthesized manifests compute their cost block WITH
    // crate::flops, so comparing the two would be circular. These goldens
    // were produced by python/compile/costmodel.py itself
    // (`costmodel.segment_flops(get(name), with_prompt)`), making this a
    // genuine rust-vs-python cross-check with zero artifacts on disk.
    let goldens: [(&str, [u64; 3], [u64; 3]); 3] = [
        ("tiny", [610_077, 413_469, 419_485], [522_277, 325_669, 330_661]),
        ("small", [12_892_714, 18_749_247, 6_288_405], [11_251_466, 16_287_375, 5_463_685]),
        (
            "vit_base_sim",
            [231_211_008, 37_888_776_540, 1_462_272],
            [231_211_008, 34_926_286_812, 1_363_968],
        ),
    ];
    for (config, with_prompt, noprompt) in goldens {
        let man = sfprompt::backend::native::synth_manifest(config).unwrap();
        let rust = flops::segment_flops(&man.config, true);
        assert_eq!([rust.head, rust.body, rust.tail], with_prompt, "{config} with prompt");
        let rust_np = flops::segment_flops(&man.config, false);
        assert_eq!([rust_np.head, rust_np.body, rust_np.tail], noprompt, "{config} noprompt");
        // And the synthesized cost block carries exactly these numbers.
        assert_eq!(man.cost.flops_fwd_per_sample["head"], rust.head, "{config} manifest");
    }
    // Any python-emitted manifest present on disk must agree too (the
    // assertion the artifact path always ran; skips when absent).
    for config in ["tiny", "small", "small_c100", "vit_base_sim", "vit_large_sim"] {
        if let Ok(man) =
            sfprompt::runtime::Manifest::load(&sfprompt::artifacts_root().join(config))
        {
            let rust = flops::segment_flops(&man.config, true);
            assert_eq!(rust.head, man.cost.flops_fwd_per_sample["head"], "{config} disk");
            assert_eq!(rust.body, man.cost.flops_fwd_per_sample["body"], "{config} disk");
        }
    }
}

#[test]
fn eval_forward_produces_logits() {
    let backend = NativeBackend::tiny();
    let cfg = backend.manifest().config.clone();
    let params = init_params(backend.manifest(), 7);
    let (images, _) = batch_for(&backend);
    let mut segs: BTreeMap<&str, &SegmentParams> = BTreeMap::new();
    for s in ["head", "body", "tail", "prompt"] {
        segs.insert(s, params.get(s).unwrap());
    }
    let mut tensors: TensorInputs = BTreeMap::new();
    tensors.insert("images", &images);
    let out = run_stage_hosts(&backend, "eval_forward", &segs, &tensors).unwrap();
    let logits = out.tensor("logits").unwrap();
    assert_eq!(logits.shape, vec![cfg.batch, cfg.num_classes]);
    assert!(logits.as_f32().iter().all(|v| v.is_finite()));
}

#[test]
fn pjrt_backend_still_opens_manifests_from_disk() {
    // The artifact path stays alive behind the same trait: opening the
    // store succeeds whenever artifacts exist; stage execution needs the
    // `pjrt` feature. Without artifacts, open fails cleanly.
    match sfprompt::backend::PjrtBackend::open(&sfprompt::artifacts_root(), "tiny") {
        Ok(be) => assert_eq!(be.manifest().config.name, "tiny"),
        Err(e) => {
            let msg = format!("{e:#}");
            assert!(msg.contains("manifest"), "unexpected error: {msg}");
        }
    }
}
