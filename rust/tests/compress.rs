//! Compression-semantics integration tests on the native `tiny`
//! substrate: measured upload reduction, report accounting, determinism
//! with compression enabled, error-feedback accuracy parity (tolerance
//! documented in docs/COMPRESS.md), and baseline-engine coverage.

use sfprompt::backend::{Backend, NativeBackend};
use sfprompt::compress::Scheme;
use sfprompt::federation::{drive, Method, NullObserver, RunReport, RunSpec};
use sfprompt::util::json::Json;

fn tiny_spec(method: Method) -> RunSpec {
    let mut spec = RunSpec::new("tiny", "cifar10", method);
    spec.fed.rounds = 2;
    spec.fed.num_clients = 6;
    spec.fed.clients_per_round = 3;
    spec.fed.local_epochs = 1;
    spec.samples_per_client = 8;
    spec.eval_samples = 32;
    spec.fed.eval_limit = Some(32);
    spec
}

fn report_for(spec: &RunSpec) -> RunReport {
    let backend = NativeBackend::for_config(&spec.config).unwrap();
    let (train, eval) = spec.datasets(&backend.manifest().config).unwrap();
    let mut run = spec.builder().build(&backend, &train, Some(&eval)).unwrap();
    let hist = drive(run.as_mut(), &mut NullObserver).unwrap();
    RunReport::new(spec, run.setup_bytes(), hist)
}

/// Strip real-wall-time fields so reports compare exactly.
fn strip_wall(v: &Json) -> Json {
    match v {
        Json::Obj(o) => Json::Obj(
            o.iter()
                .filter(|(k, _)| k.as_str() != "wall_s")
                .map(|(k, x)| (k.clone(), strip_wall(x)))
                .collect(),
        ),
        Json::Arr(a) => Json::Arr(a.iter().map(strip_wall).collect()),
        other => other.clone(),
    }
}

#[test]
fn topk_cuts_measured_upload_bytes_by_10x() {
    // The acceptance bar: topk:0.01 must reduce per-round Upload bytes by
    // ≥ 10x versus dense f32 — as recorded by ByteMeter on real encoded
    // frames, not estimated.
    let mut spec = tiny_spec(Method::SfPrompt);
    spec.fed.compress = Scheme::TopK { ratio: 0.01 };
    let report = report_for(&spec);
    let comm = &report.history.total_comm;
    let wire = comm.by_kind["upload"];
    let raw = comm.raw_by_kind["upload"];
    assert!(
        raw as f64 >= 10.0 * wire as f64,
        "upload reduction only {:.1}x ({raw} raw vs {wire} wire)",
        raw as f64 / wire as f64
    );
    // Whole-run ratio is < 1 (downlink stays dense, so well above the
    // upload-only ratio, but compression must still show).
    let ratio = comm.compression_ratio();
    assert!(ratio < 1.0, "compression ratio {ratio}");

    // The report JSON carries the accounting.
    let v = report.to_json();
    let jcomm = v.get("comm").unwrap();
    assert_eq!(
        jcomm.get("by_kind_raw").unwrap().get("upload").unwrap().as_usize(),
        Some(raw as usize)
    );
    assert!(jcomm.get("compression_ratio").unwrap().as_f64().unwrap() < 1.0);
    assert_eq!(
        v.get("spec").unwrap().get("compress").unwrap().as_str(),
        Some("topk:0.01"),
        "the spec echoes the scheme"
    );
    // Dense-path sanity: every round's record carries raw >= wire.
    for r in v.get("rounds").unwrap().as_arr().unwrap() {
        let wire_b = r.get("bytes").unwrap().as_f64().unwrap();
        let raw_b = r.get("raw_bytes").unwrap().as_f64().unwrap();
        assert!(raw_b >= wire_b, "round raw {raw_b} < wire {wire_b}");
    }
}

#[test]
fn identical_compressed_specs_reproduce_identical_reports() {
    // Determinism regression with compression enabled: rand-k coordinate
    // draws and QSGD rounding run on the documented per-client seed
    // domain, so identical specs must serialize identically.
    for scheme in ["randk:0.1", "quant:4"] {
        let mut spec = tiny_spec(Method::SfPrompt);
        spec.fed.compress = Scheme::parse(scheme).unwrap();
        let a = strip_wall(&report_for(&spec).to_json()).to_string();
        let b = strip_wall(&report_for(&spec).to_json()).to_string();
        assert_eq!(a, b, "{scheme} run is not deterministic");
    }
}

#[test]
fn error_feedback_tracks_dense_accuracy() {
    // docs/COMPRESS.md documents the parity tolerance: at this smoke
    // scale (tiny config, 3 rounds) error-feedback top-k at ratio 0.1
    // must land within ±0.25 absolute accuracy of the dense run. (The
    // compress experiment sweeps the tighter, longer-horizon cells.)
    let mut dense = tiny_spec(Method::SfPrompt);
    dense.fed.rounds = 3;
    let dense_acc = report_for(&dense).history.final_accuracy();

    let mut sparse = dense.clone();
    sparse.fed.compress = Scheme::TopK { ratio: 0.1 };
    let sparse_report = report_for(&sparse);
    let sparse_acc = sparse_report.history.final_accuracy();
    assert!(
        (dense_acc - sparse_acc).abs() <= 0.25,
        "EF top-k accuracy {sparse_acc} drifted from dense {dense_acc}"
    );
    // And it genuinely compressed while doing so.
    let comm = &sparse_report.history.total_comm;
    assert!(comm.by_kind["upload"] < comm.raw_by_kind["upload"]);
}

#[test]
fn baselines_compress_their_uploads_too() {
    // FL compresses its uplink FullModel; SFL its Upload. Both must run
    // end-to-end and show an uplink reduction on the compressed kind.
    let mut fl = tiny_spec(Method::Fl);
    fl.fed.compress = Scheme::TopK { ratio: 0.05 };
    let comm = report_for(&fl).history.total_comm.clone();
    // FullModel is recorded in both directions; only the uplink half is
    // compressed, so raw must exceed wire without any 2x requirement.
    assert!(
        comm.raw_by_kind["full_model"] > comm.by_kind["full_model"],
        "FL uplink FullModel was not compressed ({:?})",
        comm.by_kind
    );

    let mut sfl = tiny_spec(Method::SflLinear);
    sfl.fed.compress = Scheme::RandK { ratio: 0.1 };
    let comm = report_for(&sfl).history.total_comm.clone();
    assert!(
        comm.raw_by_kind["upload"] > comm.by_kind["upload"],
        "SFL upload was not compressed ({:?})",
        comm.by_kind
    );
}

#[test]
fn quantized_uploads_run_and_shrink() {
    let mut spec = tiny_spec(Method::SfPrompt);
    spec.fed.compress = Scheme::Quant { bits: 4 };
    let report = report_for(&spec);
    let comm = &report.history.total_comm;
    let wire = comm.by_kind["upload"];
    let raw = comm.raw_by_kind["upload"];
    // 4-bit codes ≈ 1/8 of f32 payloads; framing keeps it from the full
    // 8x, but 4x is comfortably guaranteed.
    assert!(raw as f64 >= 4.0 * wire as f64, "quant:4 reduction {raw} vs {wire}");
    assert!(report.history.final_accuracy().is_finite());
}
