//! Telemetry integration tests: a real 2-round SFPrompt run recorded
//! end-to-end (span skeleton, JSONL, Chrome export, metrics), a baseline
//! run's span coverage, and span-tree invariants checked over both real
//! traces and randomized synthetic ones.
//!
//! The telemetry sink is process-global, so every test that installs one
//! holds `GATE` for its duration; assertions are presence-based (≥) where
//! concurrent instrumentation could add spans.

use std::sync::{Arc, Mutex, MutexGuard};

use sfprompt::backend::NativeBackend;
use sfprompt::data::{synth::DatasetProfile, SynthDataset};
use sfprompt::federation::{drive, FedConfig, Method, RunBuilder, Selection};
use sfprompt::partition::Partition;
use sfprompt::telemetry::{
    self, merge_traces, MergedTrace, ProcessTrace, SpanRecord, Telemetry, TelemetryObserver,
};
use sfprompt::transport::WireFormat;
use sfprompt::util::json::Json;

/// Serialises tests that install the global sink.
static GATE: Mutex<()> = Mutex::new(());

fn gate() -> MutexGuard<'static, ()> {
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

fn data(backend: &NativeBackend, n: usize, seed: u64) -> SynthDataset {
    let cfg = &backend.manifest().config;
    let profile = DatasetProfile {
        name: "t",
        num_classes: cfg.num_classes,
        noise: 0.35,
        class_overlap: 0.1,
    };
    SynthDataset::generate(profile, cfg.image_size, cfg.channels, n, 5, seed)
}

fn fed(rounds: usize) -> FedConfig {
    FedConfig {
        num_clients: 6,
        clients_per_round: 2,
        local_epochs: 1,
        rounds,
        lr: 0.1,
        retain_fraction: 0.5,
        local_loss_update: true,
        partition: Partition::Iid,
        seed: 9,
        eval_limit: Some(16),
        eval_every: 1,
        selection: Selection::Uniform,
        wire: WireFormat::F32,
        compress: sfprompt::compress::Scheme::None,
    }
}

/// Drive one run with a fresh installed sink; returns its sealed records
/// and the telemetry bundle (for metrics assertions).
fn record_run(method: Method, rounds: usize) -> (Vec<SpanRecord>, Arc<Telemetry>) {
    let backend = NativeBackend::tiny();
    let train = data(&backend, 96, 6);
    let eval = data(&backend, 32, 60);
    let sink = Arc::new(Telemetry::new());
    telemetry::install(sink.clone());
    let result = (|| {
        let mut run =
            RunBuilder::new(method).fed(fed(rounds)).build(&backend, &train, Some(&eval))?;
        let mut obs = TelemetryObserver::new(sink.clone());
        drive(run.as_mut(), &mut obs)
    })();
    telemetry::uninstall();
    result.unwrap();
    assert_eq!(sink.tracer.finish(), 0, "every span must close on a clean run");
    (sink.tracer.records(), sink)
}

/// Span-tree invariants every sealed trace must satisfy:
/// 1. no span is flagged open;
/// 2. every parent id resolves, and the child's interval nests inside it;
/// 3. spans on one thread are properly nested (no partial overlap);
/// 4. end >= start everywhere.
fn assert_tree_invariants(records: &[SpanRecord]) {
    use std::collections::BTreeMap;
    let by_id: BTreeMap<u64, &SpanRecord> = records.iter().map(|r| (r.id, r)).collect();
    for r in records {
        assert!(!r.open, "span {}/{} left open", r.cat, r.name);
        assert!(r.end_s >= r.start_s, "span {} ends before it starts", r.name);
        if let Some(pid) = r.parent {
            let p = by_id
                .get(&pid)
                .unwrap_or_else(|| panic!("span {} has dangling parent {pid}", r.name));
            assert!(
                p.start_s <= r.start_s && r.end_s <= p.end_s,
                "child {}/{} [{}, {}] escapes parent {}/{} [{}, {}]",
                r.cat, r.name, r.start_s, r.end_s, p.cat, p.name, p.start_s, p.end_s
            );
        }
    }
    // Same-thread spans: sorted by start, each pair either nests or is
    // disjoint — partial overlap would mean the implicit stack broke.
    let mut by_tid: BTreeMap<u64, Vec<&SpanRecord>> = BTreeMap::new();
    for r in records {
        by_tid.entry(r.tid).or_default().push(r);
    }
    for (tid, mut spans) in by_tid {
        spans.sort_by(|a, b| a.start_s.total_cmp(&b.start_s).then(a.id.cmp(&b.id)));
        for w in spans.windows(2) {
            let (a, b) = (w[0], w[1]);
            assert!(
                b.start_s >= a.end_s || b.end_s <= a.end_s,
                "tid {tid}: spans {} and {} partially overlap",
                a.name, b.name
            );
        }
    }
}

#[test]
fn sfprompt_e2e_trace_has_the_full_span_skeleton() {
    let _g = gate();
    let (records, sink) = record_run(Method::SfPrompt, 2);
    assert_tree_invariants(&records);

    // run → round skeleton: exactly one run span, one round span per round.
    let runs: Vec<_> = records.iter().filter(|r| r.cat == "run").collect();
    assert_eq!(runs.len(), 1);
    assert_eq!(runs[0].name, "run:sfprompt");
    assert!(runs[0].sim_s.is_some(), "run span carries the final sim clock");
    let rounds: Vec<_> = records.iter().filter(|r| r.cat == "round").collect();
    assert_eq!(rounds.len(), 2);
    for (i, r) in rounds.iter().enumerate() {
        assert_eq!(r.name, format!("round:{i}"));
        assert_eq!(r.parent, Some(runs[0].id), "round must nest in the run span");
        assert!(r.sim_s.is_some(), "round spans carry the cumulative sim clock");
    }

    // clients_per_round=2 over 2 rounds → 4 client spans, each under a
    // round span, each on a worker thread (the engine spawns per client).
    let clients: Vec<_> = records.iter().filter(|r| r.cat == "client").collect();
    assert_eq!(clients.len(), 4);
    let round_ids: Vec<u64> = rounds.iter().map(|r| r.id).collect();
    for c in &clients {
        assert!(round_ids.contains(&c.parent.expect("client span parented")));
    }

    // Phase spans: driver-side distribute/serve/aggregate/eval per round,
    // plus the per-client phase1/phase2/phase3 chain.
    let phase_names: Vec<&str> = records
        .iter()
        .filter(|r| r.cat == "phase")
        .map(|r| r.name.as_str())
        .collect();
    for want in [
        "distribute", "serve", "aggregate", "eval",
        "phase1_local", "phase1_prune", "phase2_split", "phase3_upload",
    ] {
        assert!(
            phase_names.iter().filter(|&&n| n == want).count() >= 2,
            "expected ≥2 {want:?} phase spans (one per round/client), got {phase_names:?}"
        );
    }

    // Backend stage spans exist and sit under client phases.
    let stages: Vec<_> = records.iter().filter(|r| r.cat == "stage").collect();
    assert!(!stages.is_empty());
    for s in &stages {
        assert!(s.parent.is_some(), "stage {} must not be a root", s.name);
    }
    assert!(stages.iter().any(|s| s.name == "local_step"));
    assert!(stages.iter().any(|s| s.name == "el2n_scores"));
    assert!(stages.iter().any(|s| s.name == "tail_step"));
    assert!(stages.iter().any(|s| s.name == "eval_forward"));

    // Metrics side: stage histograms with matching analytic-FLOP counters,
    // codec + fedavg + pruning timings, and wire bytes per message kind.
    let m = &sink.metrics;
    assert!(m.histogram_count("stage_s/local_step") > 0);
    assert!(m.counter("stage_flops/local_step") > 0);
    assert!(m.histogram_count("codec_encode_s") > 0);
    assert!(m.histogram_count("codec_decode_s") > 0);
    assert!(m.histogram_count("aggregate_s") >= 2, "one FedAvg per round");
    assert!(m.histogram_count("el2n_prune_s") >= 4, "one pruning pass per client-round");
    assert!(m.counter("wire_bytes/smashed_data") > 0);
    assert!(m.counter("frames/upload") >= 4);
    assert_eq!(m.counter("clients_done"), 4);

    // The metrics JSON block surfaces the hottest-stage summary with p50/p95.
    let j = m.to_json();
    let hottest = j.get("hottest_stages").and_then(Json::as_arr).unwrap();
    assert!(!hottest.is_empty());
    assert!(hottest[0].get("p95_ms").and_then(Json::as_f64).is_some());
    assert!(
        j.get("achieved_gflops").and_then(Json::as_obj).map_or(0, |o| o.len()) > 0,
        "achieved GFLOP/s derived from flops counters"
    );
}

#[test]
fn parallel_run_keeps_the_trace_skeleton_and_busy_counters() {
    let _g = gate();
    // Force the kernel pool wide, then record the same run the skeleton test
    // uses. Pool workers never emit spans of their own — each stage span
    // lives on the calling client thread and absorbs the workers' busy time
    // into the `stage_busy_us/*` counters — so the tree invariants must hold
    // unchanged at any thread count.
    sfprompt::backend::native::pool::set_threads(4);
    let outcome = std::panic::catch_unwind(|| record_run(Method::SfPrompt, 2));
    sfprompt::backend::native::pool::set_threads(0);
    let (records, sink) = outcome.unwrap();
    assert_tree_invariants(&records);

    let stages: Vec<_> = records.iter().filter(|r| r.cat == "stage").collect();
    assert!(!stages.is_empty());
    let ids: std::collections::BTreeSet<u64> = records.iter().map(|r| r.id).collect();
    for s in &stages {
        let pid = s.parent.expect("stage spans are parented even when the pool is wide");
        assert!(ids.contains(&pid), "stage {} has a dangling parent", s.name);
    }

    // Busy-time accounting: every stage histogram has a matching busy
    // counter, and busy time can only exceed wall time (it adds the spawned
    // workers' thread-seconds on top).
    let m = &sink.metrics;
    for stage in ["local_step", "el2n_scores", "tail_step", "eval_forward"] {
        assert!(m.histogram_count(&format!("stage_s/{stage}")) > 0, "missing stage_s/{stage}");
        assert!(m.counter(&format!("stage_busy_us/{stage}")) > 0, "missing stage_busy_us/{stage}");
    }
    let j = m.to_json();
    assert!(
        j.get("achieved_gflops").and_then(Json::as_obj).map_or(0, |o| o.len()) > 0,
        "GFLOP/s still derived (from busy time) under parallel kernels"
    );
}

#[test]
fn trace_serialises_to_valid_jsonl_and_chrome_json() {
    let _g = gate();
    let backend = NativeBackend::tiny();
    let train = data(&backend, 96, 7);
    let sink = Arc::new(Telemetry::new());
    telemetry::install(sink.clone());
    let result = (|| {
        let mut run = RunBuilder::new(Method::SfPrompt).fed(fed(2)).build(&backend, &train, None)?;
        let mut obs = TelemetryObserver::new(sink.clone());
        drive(run.as_mut(), &mut obs)
    })();
    telemetry::uninstall();
    result.unwrap();
    sink.tracer.finish();

    // JSONL: meta header first, then one strict-JSON span object per line.
    let text = sink.tracer.to_jsonl();
    let mut lines = text.lines();
    let meta = Json::parse(lines.next().unwrap()).unwrap();
    assert_eq!(meta.get("format").and_then(Json::as_str), Some("sfprompt-trace"));
    let mut span_lines = 0usize;
    for line in lines {
        let v = Json::parse(line).unwrap_or_else(|e| panic!("bad JSONL line {line:?}: {e}"));
        assert_eq!(v.get("ev").and_then(Json::as_str), Some("span"));
        assert!(v.get("t1_s").and_then(Json::as_f64) >= v.get("t0_s").and_then(Json::as_f64));
        assert_eq!(v.get("open"), None, "no span may be flagged open");
        span_lines += 1;
    }
    assert_eq!(span_lines, sink.tracer.records().len());

    // Chrome trace-event export: complete events, µs clocks.
    let doc = sink.tracer.to_chrome_trace();
    let events = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
    assert_eq!(events.len(), span_lines);
    for e in events {
        assert_eq!(e.get("ph").and_then(Json::as_str), Some("X"));
        assert!(e.get("dur").and_then(Json::as_f64).unwrap() >= 0.0);
        assert!(e.get("tid").and_then(Json::as_f64).is_some());
    }
}

#[test]
fn baseline_runs_are_traced_too() {
    let _g = gate();
    let (records, sink) = record_run(Method::SflLinear, 2);
    assert_tree_invariants(&records);
    let clients: Vec<_> = records.iter().filter(|r| r.cat == "client").collect();
    assert_eq!(clients.len(), 4, "2 rounds × 2 selected clients, inline on the driver");
    let round_ids: Vec<u64> = records.iter().filter(|r| r.cat == "round").map(|r| r.id).collect();
    for c in &clients {
        assert!(round_ids.contains(&c.parent.unwrap()), "baseline clients nest in rounds");
    }
    assert!(
        records.iter().any(|r| r.cat == "phase" && r.name == "aggregate"),
        "baseline FedAvg emits an aggregate span"
    );
    assert!(sink.metrics.histogram_count("aggregate_s") >= 2);
    assert!(sink.metrics.histogram_count("stage_s/head_forward_noprompt") > 0);
}

/// Invariants a merged (multi-process) trace must satisfy on top of the
/// single-process ones:
/// 1. canonical process order — coordinator (span_base 0) is process 0;
/// 2. every parent edge resolves inside the merged document;
/// 3. an edge is flagged `remote` iff it crosses a process boundary;
/// 4. every non-coordinator span reaches a coordinator ancestor;
/// 5. a child escapes its parent's interval only where `skew` is flagged.
fn assert_merged_invariants(merged: &MergedTrace) {
    use std::collections::BTreeMap;
    assert_eq!(merged.processes[0].span_base, 0, "coordinator must be process 0");
    assert_eq!(merged.processes[0].process, "coordinator");
    let by_id: BTreeMap<u64, &sfprompt::telemetry::MergedSpan> =
        merged.spans.iter().map(|s| (s.id, s)).collect();
    for s in &merged.spans {
        assert!(!s.open, "merged span {} left open", s.name);
        if let Some(pid) = s.parent {
            let p = by_id.get(&pid).unwrap_or_else(|| panic!("dangling parent {pid}"));
            assert_eq!(
                s.remote,
                p.proc != s.proc,
                "span {}: remote flag must mean a cross-process edge",
                s.name
            );
            let escapes = s.t0_s < p.t0_s - 0.5 || s.t1_s > p.t1_s + 0.5;
            assert!(
                !escapes || s.skew,
                "span {} escapes its parent without a skew flag",
                s.name
            );
        }
        // Walk to a root: every non-coordinator span must pass through
        // the coordinator process on the way.
        if s.proc != 0 {
            let mut cur: &sfprompt::telemetry::MergedSpan = s;
            let mut hops = 0;
            while let Some(pid) = cur.parent {
                cur = by_id.get(&pid).unwrap();
                hops += 1;
                assert!(hops < 10_000, "parent cycle at span {}", s.name);
                if cur.proc == 0 {
                    break;
                }
            }
            assert_eq!(
                cur.proc, 0,
                "span {} never reaches a coordinator ancestor",
                s.name
            );
        }
    }
    // Merged spans are sorted by re-based start time.
    for w in merged.spans.windows(2) {
        assert!(w[0].t0_s <= w[1].t0_s + 1e-12);
    }
}

#[test]
fn distributed_traces_merge_into_one_causal_tree() {
    // Three "processes" exactly as a networked run wires them: one
    // coordinator sink plus two client sinks with disjoint span-id blocks,
    // sharing a trace id, with honestly-measured clock offsets (each
    // client's epoch measured against the coordinator's, like the NTP
    // handshake does over the socket).
    let coord = Arc::new(Telemetry::new());
    coord.tracer.set_trace_context(0xfeed, "coordinator", 0);
    let run = coord.span("run", "run:sfprompt");
    let round = coord.span_under("round", "round:0", Some(run.id()));
    let round_id = round.id();

    let mut client_sinks = Vec::new();
    for p in 0..2u64 {
        let sink = Arc::new(Telemetry::new());
        sink.tracer.set_trace_context(0xfeed, &format!("client-{p}"), (p + 1) << 40);
        // coordinator_time = client_time + offset: measured at creation.
        sink.tracer.set_clock(coord.tracer.now_s(), 0.01);
        {
            let c = sink.span_remote("client", &format!("client:{p}"), round_id);
            let _phase = sink.span_under("phase", "phase2_split", Some(c.id()));
        }
        assert_eq!(sink.tracer.finish(), 0);
        client_sinks.push(sink);
    }
    drop(round);
    drop(run);
    assert_eq!(coord.tracer.finish(), 0);

    // Merge with the coordinator listed LAST: the merge must still put it
    // first (canonical span_base order), like `trace merge` on any argv.
    let traces: Vec<ProcessTrace> = [&client_sinks[1], &client_sinks[0], &coord]
        .iter()
        .map(|s| ProcessTrace::parse(&s.tracer.to_jsonl()).unwrap())
        .collect();
    let merged = merge_traces(&traces).unwrap();
    assert_eq!(merged.trace_id, 0xfeed);
    assert_eq!(merged.processes.len(), 3);
    assert_merged_invariants(&merged);

    // Both client spans resolved onto the coordinator's round span.
    let remotes: Vec<_> = merged.spans.iter().filter(|s| s.remote).collect();
    assert_eq!(remotes.len(), 2, "one cross-process edge per client");
    for r in &remotes {
        assert_eq!(r.parent, Some(round_id));
    }
    // Honest clocks on one machine: nothing should be flagged.
    assert!(merged.spans.iter().all(|s| !s.skew), "no skew with measured offsets");

    // The merged JSONL re-parses as a v2 trace and keeps every span.
    let reparsed = ProcessTrace::parse(&merged.to_jsonl()).unwrap();
    assert_eq!(reparsed.trace_id, 0xfeed);
}

#[test]
fn lying_clocks_surface_as_skew_flags_not_clamped_timestamps() {
    let coord = Arc::new(Telemetry::new());
    coord.tracer.set_trace_context(0xbad, "coordinator", 0);
    let round = coord.span("round", "round:0");
    let round_id = round.id();
    let client = Arc::new(Telemetry::new());
    client.tracer.set_trace_context(0xbad, "client-0", 1 << 40);
    // A wildly wrong offset with a tight claimed RTT bound.
    client.tracer.set_clock(120.0, 0.001);
    {
        let _c = client.span_remote("client", "client:0", round_id);
    }
    client.tracer.finish();
    drop(round);
    coord.tracer.finish();

    let merged = merge_traces(&[
        ProcessTrace::parse(&coord.tracer.to_jsonl()).unwrap(),
        ProcessTrace::parse(&client.tracer.to_jsonl()).unwrap(),
    ])
    .unwrap();
    let c = merged.spans.iter().find(|s| s.cat == "client").unwrap();
    assert!(c.skew, "the impossible overlap must be flagged");
    assert!(c.t0_s >= 120.0, "timestamps are re-based but never clamped");
    let r = merged.spans.iter().find(|s| s.cat == "round").unwrap();
    assert!(c.t0_s > r.t1_s, "the flagged child genuinely escapes its parent");
}

#[test]
fn randomized_span_trees_uphold_invariants() {
    // Property-style: random open/close interleavings across threads, with
    // explicit cross-thread parents, still yield a well-formed tree.
    use sfprompt::util::rng::Rng;
    let sink = Arc::new(Telemetry::new());
    for trial in 0..10u64 {
        let root = sink.span("run", &format!("trial:{trial}"));
        let root_id = root.id();
        let mut handles = Vec::new();
        for w in 0..3u64 {
            let s = sink.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(trial * 31 + w);
                let worker = s.span_under("client", &format!("worker:{w}"), Some(root_id));
                let mut open: Vec<sfprompt::telemetry::SpanGuard> = Vec::new();
                for i in 0..40 {
                    // Biased walk: open deeper or pop back out at random.
                    if open.len() < 5 && rng.next_u64() % 3 != 0 {
                        open.push(s.span("stage", &format!("op:{i}")));
                    } else {
                        open.pop();
                    }
                }
                // Innermost-first: Vec drops front-to-back, which would
                // close parents before their children.
                while let Some(g) = open.pop() {
                    drop(g);
                }
                drop(worker);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        drop(root);
    }
    assert_eq!(sink.tracer.finish(), 0);
    let records = sink.tracer.records();
    assert_tree_invariants(&records);
    assert_eq!(records.iter().filter(|r| r.cat == "run").count(), 10);
    assert_eq!(records.iter().filter(|r| r.cat == "client").count(), 30);
}
