//! Finite-difference validation of the native backend's hand-written
//! backward passes, exercised through the public stage API only:
//!
//! * `tail_step` — the cut-layer gradient `g_body_out` and the SGD-applied
//!   tail parameter gradients against central differences of the loss;
//! * `prompt_grad` — the prompt gradient against central differences of
//!   the scalar ⟨head_forward(p), g_smashed⟩ (the VJP definition).
//!
//! Entries are sampled where the analytic gradient is largest, so the
//! comparison is against signal, not float noise.

use std::collections::BTreeMap;

use sfprompt::backend::{run_stage_hosts, Backend, NativeBackend, TensorInputs};
use sfprompt::model::{init_params, ParamSet, SegmentParams};
use sfprompt::runtime::HostTensor;
use sfprompt::util::rng::Rng;

const EPS: f32 = 1e-2;

fn randn(shape: Vec<usize>, sigma: f32, rng: &mut Rng) -> HostTensor {
    let n = shape.iter().product();
    HostTensor::f32(shape, (0..n).map(|_| rng.normal_f32(0.0, sigma)).collect())
}

fn rand_labels(b: usize, classes: usize, rng: &mut Rng) -> HostTensor {
    HostTensor::i32(vec![b], (0..b).map(|_| rng.below(classes) as i32).collect())
}

/// Indices of the `k` largest-|v| entries.
fn top_entries(v: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..v.len()).collect();
    idx.sort_by(|&a, &b| v[b].abs().total_cmp(&v[a].abs()));
    idx.truncate(k);
    idx
}

fn assert_close(analytic: f32, fd: f32, what: &str) {
    let tol = 2e-3_f32.max(0.02 * fd.abs());
    assert!(
        (analytic - fd).abs() <= tol,
        "{what}: analytic {analytic} vs finite-difference {fd} (tol {tol})"
    );
}

fn tail_loss(
    backend: &NativeBackend,
    tail: &SegmentParams,
    body_out: &HostTensor,
    labels: &HostTensor,
) -> f32 {
    // lr = 0: tail_step becomes a pure loss evaluation.
    let lr = HostTensor::scalar_f32(0.0);
    let mut segs: BTreeMap<&str, &SegmentParams> = BTreeMap::new();
    segs.insert("tail", tail);
    let mut t: TensorInputs = BTreeMap::new();
    t.insert("body_out", body_out);
    t.insert("labels", labels);
    t.insert("lr", &lr);
    run_stage_hosts(backend, "tail_step", &segs, &t).unwrap().loss().unwrap()
}

#[test]
fn tail_step_cut_gradient_matches_finite_differences() {
    let backend = NativeBackend::tiny();
    let cfg = backend.manifest().config.clone();
    let params = init_params(backend.manifest(), 7);
    let tail = params.get("tail").unwrap();
    let mut rng = Rng::new(11);
    let body_out =
        randn(vec![cfg.batch, cfg.seq_len, cfg.dim], 1.0, &mut rng);
    let labels = rand_labels(cfg.batch, cfg.num_classes, &mut rng);

    // Analytic gradient from the stage itself.
    let lr = HostTensor::scalar_f32(0.0);
    let mut segs: BTreeMap<&str, &SegmentParams> = BTreeMap::new();
    segs.insert("tail", tail);
    let mut t: TensorInputs = BTreeMap::new();
    t.insert("body_out", &body_out);
    t.insert("labels", &labels);
    t.insert("lr", &lr);
    let out = run_stage_hosts(&backend, "tail_step", &segs, &t).unwrap();
    let g = out.tensor("g_body_out").unwrap().as_f32().to_vec();

    for &i in &top_entries(&g, 6) {
        let mut plus = body_out.clone();
        plus.as_f32_mut()[i] += EPS;
        let mut minus = body_out.clone();
        minus.as_f32_mut()[i] -= EPS;
        let fd = (tail_loss(&backend, tail, &plus, &labels)
            - tail_loss(&backend, tail, &minus, &labels))
            / (2.0 * EPS);
        assert_close(g[i], fd, &format!("g_body_out[{i}]"));
    }
}

#[test]
fn tail_step_parameter_gradients_match_finite_differences() {
    let backend = NativeBackend::tiny();
    let cfg = backend.manifest().config.clone();
    let params = init_params(backend.manifest(), 7);
    let tail = params.get("tail").unwrap().clone();
    let mut rng = Rng::new(13);
    let body_out = randn(vec![cfg.batch, cfg.seq_len, cfg.dim], 1.0, &mut rng);
    let labels = rand_labels(cfg.batch, cfg.num_classes, &mut rng);

    // lr = 1 makes the SGD update expose the raw gradient:
    // g = tail_old − tail_new.
    let lr = HostTensor::scalar_f32(1.0);
    let mut segs: BTreeMap<&str, &SegmentParams> = BTreeMap::new();
    segs.insert("tail", &tail);
    let mut t: TensorInputs = BTreeMap::new();
    t.insert("body_out", &body_out);
    t.insert("labels", &labels);
    t.insert("lr", &lr);
    let out = run_stage_hosts(&backend, "tail_step", &segs, &t).unwrap();
    let new_tail = out.segment("tail").unwrap();

    // Check a few entries of several tensors: a block weight (qkv.w, #2),
    // the final LayerNorm scale (len-4) and the classifier weight (len-2).
    let nt = tail.tensors.len();
    for &ti in &[2usize, nt - 4, nt - 2] {
        let old = tail.tensors[ti].as_f32();
        let new = new_tail.tensors[ti].as_f32();
        let g: Vec<f32> = old.iter().zip(new).map(|(o, n)| o - n).collect();
        for &i in &top_entries(&g, 3) {
            let perturb = |delta: f32| {
                let mut tp = tail.clone();
                tp.tensors[ti].as_f32_mut()[i] += delta;
                tail_loss(&backend, &tp, &body_out, &labels)
            };
            let fd = (perturb(EPS) - perturb(-EPS)) / (2.0 * EPS);
            assert_close(g[i], fd, &format!("tail tensor {ti} entry {i}"));
        }
    }
}

fn smashed_dot(
    backend: &NativeBackend,
    params: &ParamSet,
    prompt: &SegmentParams,
    images: &HostTensor,
    weights: &[f32],
) -> f32 {
    let mut segs: BTreeMap<&str, &SegmentParams> = BTreeMap::new();
    segs.insert("head", params.get("head").unwrap());
    segs.insert("prompt", prompt);
    let mut t: TensorInputs = BTreeMap::new();
    t.insert("images", images);
    let out = run_stage_hosts(backend, "head_forward", &segs, &t).unwrap();
    out.tensor("smashed")
        .unwrap()
        .as_f32()
        .iter()
        .zip(weights)
        .map(|(&a, &b)| a * b)
        .sum()
}

#[test]
fn prompt_grad_matches_finite_differences_of_the_vjp_objective() {
    let backend = NativeBackend::tiny();
    let cfg = backend.manifest().config.clone();
    let params = init_params(backend.manifest(), 7);
    let prompt = params.get("prompt").unwrap().clone();
    let mut rng = Rng::new(17);
    let images = randn(
        vec![cfg.batch, cfg.image_size, cfg.image_size, cfg.channels],
        1.0,
        &mut rng,
    );
    // Random cotangent: prompt_grad computes p − lr · (∂⟨smashed, w⟩/∂p).
    let g_smashed = randn(vec![cfg.batch, cfg.seq_len, cfg.dim], 0.5, &mut rng);

    let lr = HostTensor::scalar_f32(1.0);
    let mut segs: BTreeMap<&str, &SegmentParams> = BTreeMap::new();
    segs.insert("head", params.get("head").unwrap());
    segs.insert("prompt", &prompt);
    let mut t: TensorInputs = BTreeMap::new();
    t.insert("images", &images);
    t.insert("g_smashed", &g_smashed);
    t.insert("lr", &lr);
    let out = run_stage_hosts(&backend, "prompt_grad", &segs, &t).unwrap();
    let new_prompt = out.segment("prompt").unwrap();
    let g: Vec<f32> = prompt.tensors[0]
        .as_f32()
        .iter()
        .zip(new_prompt.tensors[0].as_f32())
        .map(|(o, n)| o - n)
        .collect();

    let w = g_smashed.as_f32();
    for &i in &top_entries(&g, 6) {
        let perturb = |delta: f32| {
            let mut p = prompt.clone();
            p.tensors[0].as_f32_mut()[i] += delta;
            smashed_dot(&backend, &params, &p, &images, w)
        };
        let fd = (perturb(EPS) - perturb(-EPS)) / (2.0 * EPS);
        assert_close(g[i], fd, &format!("g_prompt[{i}]"));
    }
}

#[test]
fn local_step_gradient_descends_the_local_loss() {
    // Composition check: one local_step at small lr must reduce the loss
    // the step was computed on (descent direction), and repeated steps
    // must keep it finite and monotically trending down.
    let backend = NativeBackend::tiny();
    let cfg = backend.manifest().config.clone();
    let params = init_params(backend.manifest(), 23);
    let mut rng = Rng::new(29);
    let images = randn(
        vec![cfg.batch, cfg.image_size, cfg.image_size, cfg.channels],
        1.0,
        &mut rng,
    );
    let labels = rand_labels(cfg.batch, cfg.num_classes, &mut rng);
    let lr = HostTensor::scalar_f32(0.05);

    let mut tail = params.get("tail").unwrap().clone();
    let mut prompt = params.get("prompt").unwrap().clone();
    let mut losses = Vec::new();
    for _ in 0..8 {
        let mut segs: BTreeMap<&str, &SegmentParams> = BTreeMap::new();
        segs.insert("head", params.get("head").unwrap());
        segs.insert("tail", &tail);
        segs.insert("prompt", &prompt);
        let mut t: TensorInputs = BTreeMap::new();
        t.insert("images", &images);
        t.insert("labels", &labels);
        t.insert("lr", &lr);
        let mut out = run_stage_hosts(&backend, "local_step", &segs, &t).unwrap();
        losses.push(out.loss().unwrap());
        tail = out.take_segment("tail").unwrap();
        prompt = out.take_segment("prompt").unwrap();
    }
    assert!(losses.iter().all(|l| l.is_finite()), "{losses:?}");
    assert!(
        losses.last().unwrap() < &losses[0],
        "full-batch SGD must descend: {losses:?}"
    );
}
