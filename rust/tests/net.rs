//! Networked-coordinator integration tests over real localhost TCP.
//!
//! The acceptance bar: a federated run served over sockets must produce a
//! `RunReport` **byte-identical** (modulo wall-clock fields) to the same
//! seeded spec driven in-process, with `ByteMeter` counting measured
//! socket bytes. Plus the failure surface: refused handshakes (wire
//! version, run id), garbage joiners, and the observer event stream.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::thread;
use std::time::Duration;

use sfprompt::backend::{Backend, NativeBackend};
use sfprompt::compress::Scheme;
use sfprompt::federation::{drive, Method, NullObserver, RunReport, RunSpec};
use sfprompt::net::{
    self, ClientOptions, ClientSummary, ConnectOptions, Control, ServeOptions, TcpLink,
    NET_PROTO_VERSION,
};
use sfprompt::transport::WireFormat;
use sfprompt::util::json::Json;

fn tiny_spec() -> RunSpec {
    let mut spec = RunSpec::new("tiny", "cifar10", Method::SfPrompt);
    spec.fed.rounds = 2;
    spec.fed.num_clients = 6;
    spec.fed.clients_per_round = 3;
    spec.fed.local_epochs = 1;
    spec.samples_per_client = 8;
    spec.eval_samples = 32;
    spec.fed.eval_limit = Some(32);
    spec
}

fn in_process_report(spec: &RunSpec) -> RunReport {
    let backend = NativeBackend::for_config(&spec.config).unwrap();
    let (train, eval) = spec.datasets(&backend.manifest().config).unwrap();
    let mut run = spec.builder().build(&backend, &train, Some(&eval)).unwrap();
    let hist = drive(run.as_mut(), &mut NullObserver).unwrap();
    RunReport::new(spec, run.setup_bytes(), hist)
}

/// Strip real-wall-time fields — the serve-only `health` block (wall-clock
/// ages by design), the serve-only `ledger` block (the in-process report
/// here is built without one), and any `telemetry` block — so reports
/// compare exactly. Mirrors the DROP list of `sfprompt diff`.
fn strip_wall(v: &Json) -> Json {
    const STRIP: [&str; 4] = ["wall_s", "health", "ledger", "telemetry"];
    match v {
        Json::Obj(o) => Json::Obj(
            o.iter()
                .filter(|(k, _)| !STRIP.contains(&k.as_str()))
                .map(|(k, x)| (k.clone(), strip_wall(x)))
                .collect(),
        ),
        Json::Arr(a) => Json::Arr(a.iter().map(strip_wall).collect()),
        other => other.clone(),
    }
}

fn test_connect() -> ConnectOptions {
    ConnectOptions {
        retries: 50,
        backoff: Duration::from_millis(20),
        io_timeout: Duration::from_secs(30),
    }
}

fn test_serve_opts(processes: usize) -> ServeOptions {
    ServeOptions {
        processes,
        run_id: "test-run".into(),
        io_timeout: Duration::from_secs(30),
        quiet: true,
        ..ServeOptions::default()
    }
}

/// Serve `spec` on an ephemeral localhost port with `processes` client
/// threads standing in for client processes; return the server's report
/// and every client's summary.
fn tcp_run(spec: &RunSpec, processes: usize) -> (RunReport, Vec<ClientSummary>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let artifacts = sfprompt::artifacts_root();
    thread::scope(|s| {
        let server = s.spawn(|| {
            net::serve(listener, spec, &artifacts, &test_serve_opts(processes), &mut NullObserver)
        });
        let clients: Vec<_> = (0..processes)
            .map(|p| {
                let addr = addr.clone();
                let artifacts = artifacts.clone();
                s.spawn(move || {
                    let opts = ClientOptions {
                        connect: test_connect(),
                        name: format!("test-client-{p}"),
                        run_id: "test-run".into(),
                        quiet: true,
                    };
                    net::run_client(&addr, &artifacts, &opts)
                })
            })
            .collect();
        let report = server.join().unwrap().expect("serve failed");
        let summaries = clients
            .into_iter()
            .map(|c| c.join().unwrap().expect("client failed"))
            .collect();
        (report, summaries)
    })
}

#[test]
fn tcp_loopback_report_is_byte_identical_to_in_process() {
    let spec = tiny_spec();
    let local = strip_wall(&in_process_report(&spec).to_json());
    let (report, summaries) = tcp_run(&spec, 2);
    let networked = strip_wall(&report.to_json());
    assert_eq!(
        networked.to_string(),
        local.to_string(),
        "networked RunReport must match the in-process run byte for byte"
    );

    // Cohort accounting: 2 processes split the 6 logical clients 3/3, and
    // every selected client-round was computed by exactly one of them.
    assert_eq!(summaries.len(), 2);
    let mut all_ids: Vec<usize> =
        summaries.iter().flat_map(|s| s.client_ids.iter().copied()).collect();
    all_ids.sort_unstable();
    assert_eq!(all_ids, (0..spec.fed.num_clients).collect::<Vec<_>>());
    let total_participations: usize = summaries.iter().map(|s| s.rounds_participated).sum();
    assert_eq!(total_participations, spec.fed.rounds * spec.fed.clients_per_round);

    // The socket carried real traffic and the meter measured it: encoded
    // frames for distribution + phase-2 + upload are far beyond 1 KB even
    // on the tiny config.
    assert!(report.history.total_comm.total() > 1024);

    // The serve report seals a cost ledger whose totals re-add to the
    // measured meter exactly (reconcile already gated the run on the
    // per-kind sums; spot-check the sealed JSON here).
    let json = report.to_json();
    let ledger = json.get("ledger").expect("serve report must carry a cost ledger");
    assert_eq!(ledger.get("format").and_then(Json::as_str), Some("sfprompt-ledger"));
    let totals = ledger.get("totals").expect("ledger totals");
    let comm = &report.history.total_comm;
    assert_eq!(totals.get("up_bytes").and_then(Json::as_f64), Some(comm.uplink as f64));
    assert_eq!(totals.get("down_bytes").and_then(Json::as_f64), Some(comm.downlink as f64));
    assert_eq!(totals.get("messages").and_then(Json::as_f64), Some(comm.messages as f64));
    for (&kind, &bytes) in &comm.by_kind {
        assert_eq!(
            totals.get("by_kind").and_then(|b| b.get(kind)).and_then(Json::as_f64),
            Some(bytes as f64),
            "ledger by_kind[{kind}] must equal the meter"
        );
    }
}

#[test]
fn tcp_loopback_matches_in_process_with_compression_and_f16() {
    // Error-feedback residuals live client-side; sparse wire frames cross
    // the socket. Both must survive the process split bit-for-bit.
    let mut spec = tiny_spec();
    spec.fed.compress = Scheme::TopK { ratio: 0.25 };
    spec.fed.wire = WireFormat::F16;
    let local = strip_wall(&in_process_report(&spec).to_json());
    let (report, _) = tcp_run(&spec, 3);
    assert_eq!(strip_wall(&report.to_json()).to_string(), local.to_string());
    let comm = &report.history.total_comm;
    assert!(
        comm.raw_total() > comm.total(),
        "compression must show in the measured socket bytes"
    );
}

#[test]
fn wire_version_mismatch_is_refused_and_the_run_survives() {
    let spec = tiny_spec();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let artifacts = sfprompt::artifacts_root();
    thread::scope(|s| {
        let server = s.spawn(|| {
            net::serve(listener, &spec, &artifacts, &test_serve_opts(1), &mut NullObserver)
        });

        // A peer speaking a future codec version gets a typed refusal.
        let mut bad = TcpLink::connect(&addr, &test_connect()).unwrap();
        bad.send_control(&Control::Hello {
            proto: NET_PROTO_VERSION,
            wire: 99,
            name: "time-traveller".into(),
            run_id: String::new(),
            t0: 0.0,
        })
        .unwrap();
        match bad.recv_msg(false).unwrap() {
            Some(net::NetMsg::Control(Control::Reject { reason }, _)) => {
                assert!(reason.contains("wire version"), "unexpected reason: {reason}");
            }
            other => panic!("expected Reject, got {other:?}"),
        }
        drop(bad);

        // The cohort slot stays open: a conforming client completes the run.
        let good = s.spawn(|| {
            let opts = ClientOptions {
                connect: test_connect(),
                name: "conformist".into(),
                run_id: String::new(), // empty = join whatever is served
                quiet: true,
            };
            net::run_client(&addr, &artifacts, &opts)
        });
        server.join().unwrap().expect("serve must survive a refused handshake");
        good.join().unwrap().expect("good client must complete");
    });
}

#[test]
fn run_id_mismatch_is_refused_with_the_reason() {
    let spec = tiny_spec();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let artifacts = sfprompt::artifacts_root();
    thread::scope(|s| {
        let server = s.spawn(|| {
            net::serve(listener, &spec, &artifacts, &test_serve_opts(1), &mut NullObserver)
        });

        let wrong = ClientOptions {
            connect: test_connect(),
            name: "lost".into(),
            run_id: "some-other-run".into(),
            quiet: true,
        };
        let err = format!("{:#}", net::run_client(&addr, &artifacts, &wrong).unwrap_err());
        assert!(err.contains("run id mismatch"), "unexpected error: {err}");

        let good = s.spawn(|| {
            let opts = ClientOptions {
                connect: test_connect(),
                name: "found".into(),
                run_id: "test-run".into(),
                quiet: true,
            };
            net::run_client(&addr, &artifacts, &opts)
        });
        server.join().unwrap().expect("serve must survive a refused client");
        good.join().unwrap().expect("good client must complete");
    });
}

#[test]
fn garbage_joiner_is_rejected_without_killing_the_run() {
    let spec = tiny_spec();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let artifacts = sfprompt::artifacts_root();
    thread::scope(|s| {
        let server = s.spawn(|| {
            net::serve(listener, &spec, &artifacts, &test_serve_opts(1), &mut NullObserver)
        });

        // A complete envelope whose magic is neither "SF" nor "NC".
        let mut garbage = TcpStream::connect(addr).unwrap();
        let mut msg = 8u32.to_le_bytes().to_vec();
        msg.extend_from_slice(b"XXjunk12");
        garbage.write_all(&msg).unwrap();
        // Server answers with a Reject and closes; we only need it to move on.
        drop(garbage);

        let good = s.spawn(|| {
            let opts = ClientOptions {
                connect: test_connect(),
                name: "real".into(),
                run_id: "test-run".into(),
                quiet: true,
            };
            net::run_client(&addr.to_string(), &artifacts, &opts)
        });
        server.join().unwrap().expect("serve must survive a garbage joiner");
        good.join().unwrap().expect("good client must complete");
    });
}

#[test]
fn observer_socket_streams_the_run_as_json_lines() {
    let spec = tiny_spec();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let artifacts = sfprompt::artifacts_root();
    thread::scope(|s| {
        let server = s.spawn(|| {
            net::serve(listener, &spec, &artifacts, &test_serve_opts(1), &mut NullObserver)
        });

        // Subscribe an observer BEFORE the client joins: its socket is
        // accepted (and subscribed) first, so it sees the stream from
        // run_start. After the Observe handshake the socket is read-only.
        let mut obs_link = TcpLink::connect(&addr, &test_connect()).unwrap();
        obs_link.send_control(&Control::Observe { proto: NET_PROTO_VERSION }).unwrap();
        let obs_stream = obs_link.into_stream();
        obs_stream.set_read_timeout(Some(Duration::from_secs(60))).unwrap();

        let client = s.spawn(|| {
            let opts = ClientOptions {
                connect: test_connect(),
                name: "worker".into(),
                run_id: "test-run".into(),
                quiet: true,
            };
            net::run_client(&addr, &artifacts, &opts)
        });

        // Server drops the sink when the run ends, closing the socket, so
        // reading to EOF collects the complete stream.
        let mut lines = Vec::new();
        for line in BufReader::new(obs_stream).lines() {
            let Ok(line) = line else { break };
            lines.push(Json::parse(&line).expect("every event line is strict JSON"));
        }
        server.join().unwrap().expect("serve failed");
        client.join().unwrap().expect("client failed");

        let events: Vec<&str> =
            lines.iter().map(|l| l.get("event").unwrap().as_str().unwrap()).collect();
        assert_eq!(events.first(), Some(&"run_start"), "stream: {events:?}");
        assert_eq!(events.last(), Some(&"run_end"), "stream: {events:?}");
        let count = |kind: &str| events.iter().filter(|e| **e == kind).count();
        assert_eq!(count("round_start"), spec.fed.rounds, "stream: {events:?}");
        assert_eq!(count("round_end"), spec.fed.rounds, "stream: {events:?}");
        assert_eq!(
            lines[0].get("format").unwrap().as_str(),
            Some("sfprompt-events"),
            "run_start announces the stream format"
        );
    });
}

#[test]
fn serve_rejects_baseline_methods_up_front() {
    let mut spec = tiny_spec();
    spec.method = Method::Fl;
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let err = format!(
        "{:#}",
        net::serve(
            listener,
            &spec,
            &sfprompt::artifacts_root(),
            &test_serve_opts(1),
            &mut NullObserver,
        )
        .unwrap_err()
    );
    assert!(err.contains("sfprompt method only"), "unexpected error: {err}");
}
