//! Determinism-under-parallelism integration tests.
//!
//! The native backend's contract is that the blocked, pooled kernels are
//! **bit-identical** to a single-threaded run at every thread count: the
//! pool partitions output rows, never a reduction axis, so each output
//! element sees the same f32 accumulation order no matter how many workers
//! share the loop. (Blocked-vs-scalar-reference bit-identity is covered by
//! the in-module tests in `backend::native::math`; this file checks the
//! same property end-to-end through the public stage API and whole
//! federated runs.)
//!
//! The pool's thread count is process-global, so every test here holds
//! `GATE` while it reconfigures the pool and restores auto (0) before
//! releasing it.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Mutex, MutexGuard};

use sfprompt::backend::native::pool;
use sfprompt::backend::{run_stage_hosts, Backend, NativeBackend, TensorInputs};
use sfprompt::federation::{drive, Method, NullObserver, RunReport, RunSpec};
use sfprompt::model::{init_params, ParamSet, SegmentParams};
use sfprompt::runtime::{Dtype, HostTensor};
use sfprompt::util::json::Json;
use sfprompt::util::rng::Rng;

static GATE: Mutex<()> = Mutex::new(());

fn gate() -> MutexGuard<'static, ()> {
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

/// Restores the pool to auto sizing when dropped, even on assert panic.
struct PoolReset;

impl Drop for PoolReset {
    fn drop(&mut self) {
        pool::set_threads(0);
    }
}

fn randn(shape: Vec<usize>, sigma: f32, rng: &mut Rng) -> HostTensor {
    let n = shape.iter().product();
    HostTensor::f32(shape, (0..n).map(|_| rng.normal_f32(0.0, sigma)).collect())
}

fn bits(t: &HostTensor) -> Vec<u64> {
    match t.dtype() {
        Dtype::F32 => t.as_f32().iter().map(|v| v.to_bits() as u64).collect(),
        Dtype::I32 => t.as_i32().iter().map(|&v| v as u64).collect(),
    }
}

fn segment_bits(s: &SegmentParams) -> Vec<Vec<u64>> {
    s.tensors.iter().map(bits).collect()
}

/// Run every SFPrompt-family stage (forward and VJP) once and flatten all
/// outputs — tensors, updated segments, losses — into one comparable blob.
fn all_stage_outputs(backend: &NativeBackend) -> Vec<(String, Vec<Vec<u64>>)> {
    let cfg = backend.manifest().config.clone();
    let params = init_params(backend.manifest(), 7);
    let mut rng = Rng::new(41);
    let images =
        randn(vec![cfg.batch, cfg.image_size, cfg.image_size, cfg.channels], 1.0, &mut rng);
    let smashed = randn(vec![cfg.batch, cfg.seq_len, cfg.dim], 1.0, &mut rng);
    let g_up = randn(vec![cfg.batch, cfg.seq_len, cfg.dim], 0.5, &mut rng);
    let labels = HostTensor::i32(
        vec![cfg.batch],
        (0..cfg.batch).map(|_| rng.below(cfg.num_classes) as i32).collect(),
    );
    let lr = HostTensor::scalar_f32(0.1);

    // A nested fn (not a closure): the returned map borrows from `params`,
    // which closure lifetime elision cannot express.
    fn seg<'a>(
        params: &'a ParamSet,
        names: &[&'static str],
    ) -> BTreeMap<&'static str, &'a SegmentParams> {
        names.iter().map(|&n| (n, params.get(n).unwrap())).collect()
    }
    // (stage, segments, tensor inputs, tensor outputs, segment outputs)
    let cases: Vec<(&str, Vec<&str>, Vec<(&str, &HostTensor)>, Vec<&str>, Vec<&str>)> = vec![
        ("head_forward", vec!["head", "prompt"], vec![("images", &images)], vec!["smashed"], vec![]),
        ("body_forward", vec!["body"], vec![("smashed", &smashed)], vec!["body_out"], vec![]),
        (
            "tail_step",
            vec!["tail"],
            vec![("body_out", &smashed), ("labels", &labels), ("lr", &lr)],
            vec!["loss", "g_body_out"],
            vec!["tail"],
        ),
        (
            "body_backward",
            vec!["body"],
            vec![("smashed", &smashed), ("g_body_out", &g_up)],
            vec!["g_smashed"],
            vec![],
        ),
        (
            "prompt_grad",
            vec!["head", "prompt"],
            vec![("images", &images), ("g_smashed", &g_up), ("lr", &lr)],
            vec![],
            vec!["prompt"],
        ),
        (
            "local_step",
            vec!["head", "tail", "prompt"],
            vec![("images", &images), ("labels", &labels), ("lr", &lr)],
            vec!["loss"],
            vec!["tail", "prompt"],
        ),
        (
            "el2n_scores",
            vec!["head", "tail", "prompt"],
            vec![("images", &images), ("labels", &labels)],
            vec!["scores"],
            vec![],
        ),
        (
            "eval_forward",
            vec!["head", "body", "tail", "prompt"],
            vec![("images", &images)],
            vec!["logits"],
            vec![],
        ),
    ];

    let mut flat = Vec::new();
    for (stage, seg_names, tensors, t_outs, s_outs) in cases {
        let segs = seg(&params, &seg_names);
        let t: TensorInputs = tensors.into_iter().collect();
        let out = run_stage_hosts(backend, stage, &segs, &t).unwrap();
        for name in t_outs {
            flat.push((format!("{stage}/{name}"), vec![bits(out.tensor(name).unwrap())]));
        }
        for name in s_outs {
            flat.push((format!("{stage}/seg:{name}"), segment_bits(out.segment(name).unwrap())));
        }
    }
    flat
}

#[test]
fn every_stage_is_bit_identical_at_any_thread_count() {
    let _g = gate();
    let _reset = PoolReset;
    let backend = NativeBackend::tiny();

    pool::set_threads(1);
    let baseline = all_stage_outputs(&backend);
    for threads in [2usize, 3, 4, 8] {
        pool::set_threads(threads);
        let got = all_stage_outputs(&backend);
        assert_eq!(baseline.len(), got.len());
        for ((name, want), (name2, have)) in baseline.iter().zip(&got) {
            assert_eq!(name, name2);
            assert_eq!(
                want, have,
                "{name}: output bytes changed between 1 and {threads} threads"
            );
        }
    }
}

fn tiny_spec(method: Method, seed: u64) -> RunSpec {
    let mut spec = RunSpec::new("tiny", "cifar10", method);
    spec.fed.rounds = 1;
    spec.fed.num_clients = 4;
    spec.fed.clients_per_round = 2;
    spec.fed.local_epochs = 1;
    spec.fed.seed = seed;
    spec.samples_per_client = 8;
    spec.eval_samples = 16;
    spec.fed.eval_limit = Some(16);
    spec
}

fn report_for(spec: &RunSpec) -> RunReport {
    let backend = NativeBackend::for_config(&spec.config).unwrap();
    let (train, eval) = spec.datasets(&backend.manifest().config).unwrap();
    let mut run = spec.builder().build(&backend, &train, Some(&eval)).unwrap();
    let hist = drive(run.as_mut(), &mut NullObserver).unwrap();
    RunReport::new(spec, run.setup_bytes(), hist)
}

/// Strip real-wall-time fields and the thread-count spec key (the knobs a
/// thread sweep legitimately varies) so the rest can be compared exactly.
fn strip_nondeterministic(v: &Json) -> Json {
    match v {
        Json::Obj(o) => Json::Obj(
            o.iter()
                .filter(|(k, _)| k.as_str() != "wall_s" && k.as_str() != "threads")
                .map(|(k, x)| (k.clone(), strip_nondeterministic(x)))
                .collect(),
        ),
        Json::Arr(a) => Json::Arr(a.iter().map(strip_nondeterministic).collect()),
        other => other.clone(),
    }
}

#[test]
fn random_runs_reproduce_byte_identical_reports_for_threads_1_through_8() {
    // Property-style: seeded random spec draws, each driven at every thread
    // count in 1..=8; the RunReport JSON (modulo wall time) must not move
    // by a single byte. Full runs are expensive, so the case count is small
    // — the per-kernel sweep above covers the fine-grained space.
    let _g = gate();
    let _reset = PoolReset;
    let mut rng = Rng::new(2024);
    for method in [Method::SfPrompt, Method::SflLinear] {
        let spec = tiny_spec(method, rng.next_u64() % 1_000);
        pool::set_threads(1);
        let baseline = strip_nondeterministic(&report_for(&spec).to_json()).to_string();
        for threads in 2..=8usize {
            pool::set_threads(threads);
            let got = strip_nondeterministic(&report_for(&spec).to_json()).to_string();
            assert_eq!(
                baseline, got,
                "{method:?} report differs between 1 and {threads} threads"
            );
        }
    }
}

#[test]
fn spec_threads_key_reaches_the_pool_and_keeps_reports_equal() {
    // The `"threads"` RunSpec key (and thus `--threads`) must configure the
    // pool via open_backend and leave every report byte untouched.
    let _g = gate();
    let _reset = PoolReset;
    let root = Path::new(".");

    let report_with = |threads: Option<usize>| -> String {
        let mut spec = tiny_spec(Method::SfPrompt, 5);
        spec.threads = threads;
        let backend = spec.open_backend(root).unwrap();
        if let Some(n) = threads {
            assert_eq!(pool::threads(), n, "open_backend must apply the spec's thread count");
        }
        let (train, eval) = spec.datasets(&backend.manifest().config).unwrap();
        let mut run = spec.builder().build(backend.as_ref(), &train, Some(&eval)).unwrap();
        let hist = drive(run.as_mut(), &mut NullObserver).unwrap();
        let report = RunReport::new(&spec, run.setup_bytes(), hist);
        strip_nondeterministic(&report.to_json()).to_string()
    };

    let one = report_with(Some(1));
    let four = report_with(Some(4));
    let auto = report_with(None);
    assert_eq!(one, four, "--threads 1 vs --threads 4 reports must match");
    assert_eq!(one, auto, "auto thread sizing must not change report bytes");
}
