//! Fleet-semantics integration tests on the native `tiny` substrate:
//! determinism (identical specs ⇒ identical reports), survivor-weight
//! renormalization, dropout and deadline round accounting, and the
//! no-fleet ⇒ legacy-latency contract.

use sfprompt::backend::{Backend, NativeBackend};
use sfprompt::federation::{drive, FederatedRun, Method, NullObserver, RunReport, RunSpec};
use sfprompt::model::SegmentParams;
use sfprompt::runtime::HostTensor;
use sfprompt::sim::{ClientOutcome, DropReason, FleetSpec};
use sfprompt::util::json::Json;

fn tiny_spec(method: Method) -> RunSpec {
    let mut spec = RunSpec::new("tiny", "cifar10", method);
    spec.fed.rounds = 2;
    spec.fed.num_clients = 6;
    spec.fed.clients_per_round = 3;
    spec.fed.local_epochs = 1;
    spec.samples_per_client = 8;
    spec.eval_samples = 32;
    spec.fed.eval_limit = Some(32);
    spec
}

fn report_for(spec: &RunSpec) -> RunReport {
    let backend = NativeBackend::for_config(&spec.config).unwrap();
    let (train, eval) = spec.datasets(&backend.manifest().config).unwrap();
    let mut run = spec.builder().build(&backend, &train, Some(&eval)).unwrap();
    let hist = drive(run.as_mut(), &mut NullObserver).unwrap();
    RunReport::new(spec, run.setup_bytes(), hist)
}

/// Strip the real-wall-time fields (the only nondeterministic part of a
/// report) so the rest can be compared exactly.
fn strip_wall(v: &Json) -> Json {
    match v {
        Json::Obj(o) => Json::Obj(
            o.iter()
                .filter(|(k, _)| k.as_str() != "wall_s")
                .map(|(k, x)| (k.clone(), strip_wall(x)))
                .collect(),
        ),
        Json::Arr(a) => Json::Arr(a.iter().map(strip_wall).collect()),
        other => other.clone(),
    }
}

#[test]
fn identical_specs_reproduce_identical_reports() {
    // The determinism regression behind the documented seed-domain map
    // (util::rng::seeds): two runs of the same spec must serialize to the
    // same RunReport JSON, modulo real wall time — including measured
    // bytes, latencies, losses, accuracies, and fleet events.
    let mut spec = tiny_spec(Method::SfPrompt);
    let mut fleet = FleetSpec::named("two-tier").unwrap();
    fleet.dropout_p = 0.2;
    fleet.deadline_s = Some(5.0);
    fleet.min_quorum = 1;
    spec.fleet = Some(fleet);

    let a = strip_wall(&report_for(&spec).to_json()).to_string();
    let b = strip_wall(&report_for(&spec).to_json()).to_string();
    assert_eq!(a, b, "fleet run is not deterministic");

    // And the legacy path too.
    let plain = tiny_spec(Method::SfPrompt);
    let a = strip_wall(&report_for(&plain).to_json()).to_string();
    let b = strip_wall(&report_for(&plain).to_json()).to_string();
    assert_eq!(a, b, "legacy run is not deterministic");

    // A different seed must actually change the run.
    let mut reseeded = tiny_spec(Method::SfPrompt);
    reseeded.fed.seed = 23;
    let c = strip_wall(&report_for(&reseeded).to_json()).to_string();
    assert_ne!(a, c, "seed is not threaded through the run");
}

#[test]
fn no_fleet_key_means_legacy_latencies() {
    // The back-compat contract: a spec without a fleet key and the same
    // spec round-tripped through JSON report identical sim latencies, and
    // every selected client appears as a Done event (nothing drops).
    let spec = tiny_spec(Method::SfPrompt);
    let report = report_for(&spec);
    for rec in &report.history.rounds {
        assert_eq!(rec.clients.len(), spec.fed.clients_per_round);
        assert_eq!(rec.dropped(), 0);
        assert!(rec.sim_latency_s > 0.0);
        // Round latency is the slowest client's elapsed time — so it must
        // equal the max Done event time extended by broadcast-only tail
        // charges; at minimum it is never below any event time.
        for ev in &rec.clients {
            assert!(matches!(ev.outcome, ClientOutcome::Done));
            assert!(rec.sim_latency_s >= ev.at_s - 1e-12);
        }
    }
    let text = report.to_json().to_string();
    let reparsed = RunSpec::parse(&Json::parse(&text).unwrap().get("spec").unwrap().to_string())
        .unwrap();
    let again = report_for(&reparsed);
    let lat = |r: &RunReport| -> Vec<u64> {
        r.history.rounds.iter().map(|x| x.sim_latency_s.to_bits()).collect()
    };
    assert_eq!(lat(&report), lat(&again), "latencies changed across a spec round-trip");
}

#[test]
fn aggregation_weights_renormalize_over_survivors() {
    // Dropping a client mid-round must renormalize FedAvg over the
    // survivors' sample counts: survivors (n=1, value 0) and (n=3, value
    // 4) average to 3, regardless of what the dropped client uploaded.
    use sfprompt::federation::server::Server;
    let seg = |name: &str, v: f32| SegmentParams {
        segment: name.into(),
        tensors: vec![HostTensor::f32(vec![2], vec![v, v])],
    };
    let survivors = [
        (seg("tail", 0.0), seg("prompt", 10.0), 1usize),
        (seg("tail", 4.0), seg("prompt", 2.0), 3usize),
    ];
    let (tail, prompt) = Server::aggregate(&survivors).unwrap();
    assert_eq!(tail.tensors[0].as_f32(), &[3.0, 3.0]);
    assert_eq!(prompt.tensors[0].as_f32(), &[4.0, 4.0]);
}

#[test]
fn dropout_fleet_drops_offline_clients_and_still_trains() {
    let mut spec = tiny_spec(Method::SfPrompt);
    spec.fed.rounds = 4;
    let mut fleet = FleetSpec::named("uniform").unwrap();
    fleet.dropout_p = 0.5;
    spec.fleet = Some(fleet);

    let report = report_for(&spec);
    let dropped = report.history.dropped_clients();
    assert!(dropped > 0, "p=0.5 over 12 client-round draws never dropped anyone");
    let offline = report
        .history
        .rounds
        .iter()
        .flat_map(|r| &r.clients)
        .filter(|e| e.outcome == ClientOutcome::Dropped(DropReason::Offline))
        .count();
    assert_eq!(offline, dropped, "dropout drops are offline drops");
    assert!(report.history.final_accuracy().is_finite());
    // Offline clients transmitted nothing: rounds with more survivors
    // carry more bytes.
    for rec in &report.history.rounds {
        if rec.survivors() == 0 {
            assert_eq!(rec.comm.total(), 0, "an empty round must move no bytes");
        }
    }
}

#[test]
fn deadline_cuts_stragglers_across_methods() {
    // A two-tier fleet under a tight deadline: slow-tier clients must be
    // dropped with DropReason::Deadline, rounds still aggregate (quorum
    // >= 1), and the round latency never exceeds the slowest survivor's
    // path by less than the deadline logic allows.
    for method in [Method::SfPrompt, Method::Fl, Method::SflLinear] {
        let mut spec = tiny_spec(method);
        spec.fed.rounds = 3;
        let mut fleet = FleetSpec::named("two-tier").unwrap();
        // Slow tier 1000x behind: any straggler blows through the deadline.
        fleet.devices = sfprompt::sim::RateDist::TwoTier {
            fast: 1e12,
            slow: 1e6,
            slow_fraction: 0.5,
        };
        fleet.deadline_s = Some(2.0);
        fleet.min_quorum = 1;
        spec.fleet = Some(fleet);

        let report = report_for(&spec);
        let deadline_drops = report
            .history
            .rounds
            .iter()
            .flat_map(|r| &r.clients)
            .filter(|e| e.outcome == ClientOutcome::Dropped(DropReason::Deadline))
            .count();
        assert!(
            deadline_drops > 0,
            "{method:?}: a 50% slow tier at 1e6 FLOP/s never missed a 2s deadline"
        );
        for rec in &report.history.rounds {
            assert!(
                rec.survivors() >= 1,
                "{method:?}: quorum 1 must guarantee a survivor in every round"
            );
        }
        assert!(report.history.final_accuracy().is_finite(), "{method:?}");
    }
}

#[test]
fn fleet_observer_receives_client_events() {
    use sfprompt::federation::RoundObserver;

    #[derive(Default)]
    struct Counter {
        done: usize,
        dropped: usize,
    }
    impl RoundObserver for Counter {
        fn on_client_done(&mut self, _r: usize, _c: usize, _t: f64) {
            self.done += 1;
        }
        fn on_client_dropped(&mut self, _r: usize, _c: usize, _t: f64, _why: DropReason) {
            self.dropped += 1;
        }
    }

    let mut spec = tiny_spec(Method::SflLinear);
    let mut fleet = FleetSpec::named("uniform").unwrap();
    fleet.dropout_p = 0.4;
    spec.fleet = Some(fleet);

    let backend = NativeBackend::for_config(&spec.config).unwrap();
    let (train, eval) = spec.datasets(&backend.manifest().config).unwrap();
    let mut run = spec.builder().build(&backend, &train, Some(&eval)).unwrap();
    let mut obs = Counter::default();
    let hist = drive(run.as_mut(), &mut obs).unwrap();

    let expected: usize = spec.fed.rounds * spec.fed.clients_per_round;
    assert_eq!(obs.done + obs.dropped, expected, "every selected client produces one event");
    assert_eq!(obs.dropped, hist.dropped_clients());
}
