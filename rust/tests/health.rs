//! Live-operations integration tests over real localhost TCP.
//!
//! Pins the PR-9 acceptance surface: a serving coordinator answers the
//! one-shot `status` control probe mid-admission, rejects a status request
//! carrying unknown keys (strict control plane), attaches the `health`
//! block to the final `RunReport`, and leaves a parseable post-mortem
//! flight dump behind when a client process aborts the run.

use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::thread;
use std::time::Duration;

use sfprompt::federation::{Method, NullObserver, RunSpec};
use sfprompt::net::{
    self, ClientOptions, ConnectOptions, Control, NetMsg, ServeOptions, TcpLink,
    NET_PROTO_VERSION,
};
use sfprompt::util::json::Json;

fn tiny_spec() -> RunSpec {
    let mut spec = RunSpec::new("tiny", "cifar10", Method::SfPrompt);
    spec.fed.rounds = 2;
    spec.fed.num_clients = 6;
    spec.fed.clients_per_round = 3;
    spec.fed.local_epochs = 1;
    spec.samples_per_client = 8;
    spec.eval_samples = 32;
    spec.fed.eval_limit = Some(32);
    spec
}

fn test_connect() -> ConnectOptions {
    ConnectOptions {
        retries: 50,
        backoff: Duration::from_millis(20),
        io_timeout: Duration::from_secs(30),
    }
}

fn test_serve_opts(processes: usize) -> ServeOptions {
    ServeOptions {
        processes,
        run_id: "test-run".into(),
        io_timeout: Duration::from_secs(30),
        quiet: true,
        ..ServeOptions::default()
    }
}

/// One typed `status` probe against `addr`; returns the reply body.
fn probe_status(addr: &str) -> Json {
    let mut link = TcpLink::connect(addr, &test_connect()).unwrap();
    link.send_control(&Control::Status { proto: NET_PROTO_VERSION }).unwrap();
    match link.recv_msg(false).unwrap() {
        Some(NetMsg::Control(Control::StatusReply { body }, _)) => body,
        other => panic!("expected a status reply, got {other:?}"),
    }
}

#[test]
fn status_probe_answers_during_admission_and_the_report_carries_health() {
    let spec = tiny_spec();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let artifacts = sfprompt::artifacts_root();
    thread::scope(|s| {
        let server = s.spawn(|| {
            net::serve(listener, &spec, &artifacts, &test_serve_opts(1), &mut NullObserver)
        });

        // 1. Probe before any client process joins: the registry is still
        //    in its pre-run state and the snapshot carries run identity.
        let body = probe_status(&addr);
        assert_eq!(body.get("state").unwrap().as_str(), Some("waiting"), "body: {body}");
        assert_eq!(body.get("run_id").unwrap().as_str(), Some("test-run"));
        assert_eq!(body.get("processes").unwrap().as_f64(), Some(1.0));
        assert_eq!(body.get("config").unwrap().as_str(), Some("tiny"));
        assert!(body.get("clients").unwrap().as_obj().is_some(), "body: {body}");

        // 2. A status envelope smuggling an unknown key is refused by the
        //    strict control plane — and the slot stays open.
        let mut sneaky = TcpStream::connect(&addr).unwrap();
        let json = br#"{"kind":"status","proto":1,"verbose":true}"#;
        let mut body_bytes = b"NC".to_vec();
        body_bytes.push(NET_PROTO_VERSION);
        body_bytes.extend_from_slice(json);
        let mut msg = (body_bytes.len() as u32).to_le_bytes().to_vec();
        msg.extend_from_slice(&body_bytes);
        sneaky.write_all(&msg).unwrap();
        let mut sneaky = TcpLink::from_stream(sneaky, Duration::from_secs(30)).unwrap();
        match sneaky.recv_msg(false).unwrap() {
            Some(NetMsg::Control(Control::Reject { reason }, _)) => {
                assert!(reason.contains("handshake failed"), "unexpected reason: {reason}");
            }
            other => panic!("expected Reject, got {other:?}"),
        }
        drop(sneaky);

        // 3. A conforming client completes the run; the returned report
        //    carries the sealed health block.
        let client = s.spawn(|| {
            let opts = ClientOptions {
                connect: test_connect(),
                name: "probed".into(),
                run_id: "test-run".into(),
                quiet: true,
            };
            net::run_client(&addr, &artifacts, &opts)
        });
        let report = server.join().unwrap().expect("serve failed");
        client.join().unwrap().expect("client failed");

        let health = report.to_json().get("health").cloned().expect("report has a health block");
        assert_eq!(health.get("state").unwrap().as_str(), Some("complete"), "health: {health}");
        assert_eq!(
            health.get("rounds_done").unwrap().as_f64(),
            Some(spec.fed.rounds as f64),
            "health: {health}"
        );
        let anomalies = health.get("anomalies").unwrap().as_arr().unwrap();
        assert!(anomalies.is_empty(), "tiny run must be anomaly-free: {health}");
    });
}

#[test]
fn aborted_client_leaves_a_parseable_postmortem_dump() {
    let spec = tiny_spec();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let artifacts = sfprompt::artifacts_root();
    let dir = std::env::temp_dir().join(format!("sfprompt-health-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let pm = dir.join("postmortem.jsonl");
    let opts = ServeOptions {
        io_timeout: Duration::from_secs(5),
        postmortem: Some(pm.clone()),
        ..test_serve_opts(1)
    };
    thread::scope(|s| {
        let server =
            s.spawn(|| net::serve(listener, &spec, &artifacts, &opts, &mut NullObserver));

        // Handshake like a real client process, then vanish without a FIN
        // ceremony: the run must fail and dump the flight ring.
        let mut deserter = TcpLink::connect(&addr, &test_connect()).unwrap();
        deserter
            .send_control(&Control::Hello {
                proto: NET_PROTO_VERSION,
                wire: sfprompt::transport::WIRE_VERSION,
                name: "deserter".into(),
                run_id: "test-run".into(),
                t0: 0.0,
            })
            .unwrap();
        match deserter.recv_msg(false).unwrap() {
            Some(NetMsg::Control(c, _)) => assert_eq!(c.kind(), "welcome"),
            other => panic!("expected welcome, got {other:?}"),
        }
        drop(deserter);

        let err = server.join().unwrap().expect_err("run must fail when its only process dies");
        let err = format!("{err:#}");
        assert!(!err.is_empty());
    });

    let text = std::fs::read_to_string(&pm).expect("post-mortem dump must exist");
    let lines: Vec<Json> = text
        .lines()
        .map(|l| Json::parse(l).expect("every post-mortem line is strict JSON"))
        .collect();
    assert!(!lines.is_empty());
    assert_eq!(lines[0].get("ev").unwrap().as_str(), Some("meta"));
    assert_eq!(lines[0].get("format").unwrap().as_str(), Some("sfprompt-flight"));
    // The failure itself is on the ring: serve records a run_failed entry
    // before sealing, so the dump is never just a header.
    assert!(
        lines[1..].iter().any(|l| l.get("ev").and_then(Json::as_str) == Some("flight")),
        "dump: {text}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
