//! End-to-end federation tests on the native backend's synthesized `tiny`
//! substrate, driven entirely through the unified run API (`RunBuilder` →
//! `FederatedRun` → `drive`): the SFPrompt engine and all three baselines
//! run full rounds, account measured bytes, and train (losses decrease
//! over rounds) — with **zero artifacts on disk and zero skipped tests**.

use sfprompt::backend::{Backend, NativeBackend};
use sfprompt::comm::MsgKind;
use sfprompt::data::{synth::DatasetProfile, SynthDataset};
use sfprompt::federation::{
    drive, FedConfig, FederatedRun, Method, NullObserver, RoundObserver, RunBuilder, Selection,
};
use sfprompt::metrics::{RoundRecord, RunHistory};
use sfprompt::partition::Partition;
use sfprompt::transport::WireFormat;

fn data(backend: &NativeBackend, n: usize, seed: u64) -> SynthDataset {
    let cfg = &backend.manifest().config;
    let profile = DatasetProfile {
        name: "t",
        num_classes: cfg.num_classes,
        noise: 0.35,
        class_overlap: 0.1,
    };
    SynthDataset::generate(profile, cfg.image_size, cfg.channels, n, 5, seed)
}

fn fed(rounds: usize) -> FedConfig {
    FedConfig {
        num_clients: 6,
        clients_per_round: 2,
        local_epochs: 2,
        rounds,
        lr: 0.1,
        retain_fraction: 0.5,
        local_loss_update: true,
        partition: Partition::Iid,
        seed: 9,
        eval_limit: Some(32),
        eval_every: 1,
        selection: Selection::Uniform,
        wire: WireFormat::F32,
        compress: sfprompt::compress::Scheme::None,
    }
}

fn build<'a>(
    backend: &'a NativeBackend,
    f: FedConfig,
    method: Method,
    train: &'a SynthDataset,
    eval: Option<&'a SynthDataset>,
) -> Box<dyn FederatedRun + 'a> {
    RunBuilder::new(method).fed(f).build(backend, train, eval).unwrap()
}

#[test]
fn builder_rejects_invalid_configs_without_a_backend() {
    let b = || RunBuilder::new(Method::SfPrompt);
    assert!(b().clients(4, 5).validate().is_err());
    assert!(b().rounds(0).validate().is_err());
    assert!(b().retain_fraction(0.0).validate().is_err());
    assert!(b().retain_fraction(1.5).validate().is_err());
    assert!(b().lr(-0.1).validate().is_err());
    assert!(b().net_rate(0.0).validate().is_err());
    assert!(b().validate().is_ok());
    assert!(b().fed(fed(3)).validate().is_ok());
}

#[test]
fn builder_rejects_methods_whose_stages_are_not_lowered() {
    // Prompt-sweep configs synthesize the sfprompt family only; baseline
    // methods must fail at build with the missing stages named, not
    // mid-round.
    let backend = NativeBackend::for_config("small_c100_p16").unwrap();
    let train = data(&backend, 96, 19);
    let err = RunBuilder::new(Method::Fl)
        .fed(fed(1))
        .build(&backend, &train, None)
        .unwrap_err()
        .to_string();
    assert!(err.contains("full_step"), "{err}");
    // The sfprompt family itself is present on the same config.
    assert!(RunBuilder::new(Method::SfPrompt).fed(fed(1)).build(&backend, &train, None).is_ok());
}

#[test]
fn builder_rejects_dataset_smaller_than_fleet() {
    let backend = NativeBackend::tiny();
    let train = data(&backend, 4, 6); // 4 samples, 6 clients
    let err = RunBuilder::new(Method::SfPrompt).fed(fed(1)).build(&backend, &train, None);
    assert!(err.is_err());
}

#[test]
fn sfprompt_trains_and_losses_decrease() {
    let backend = NativeBackend::tiny();
    let train = data(&backend, 96, 6);
    let eval = data(&backend, 32, 60);
    let mut run = build(&backend, fed(4), Method::SfPrompt, &train, Some(&eval));
    let hist = drive(run.as_mut(), &mut NullObserver).unwrap();
    assert_eq!(hist.rounds.len(), 4);
    let first = &hist.rounds[0];
    let last = &hist.rounds[3];
    // Phase-1 local loss: every round's mean over full local epochs.
    assert!(last.mean_local_loss < first.mean_local_loss,
            "local loss {} -> {}", first.mean_local_loss, last.mean_local_loss);
    // Phase-2 split loss decreases across rounds too (the acceptance
    // criterion: real training through the cut layer, not just Phase 1).
    assert!(last.mean_split_loss < first.mean_split_loss,
            "split loss {} -> {}", first.mean_split_loss, last.mean_split_loss);
    assert!(hist.rounds.iter().all(|r| r.mean_split_loss.is_finite()));
    assert!(hist.final_accuracy() >= 0.0 && hist.final_accuracy() <= 1.0);
    // The trait view matches what the driver returned.
    assert_eq!(run.method(), Method::SfPrompt);
    assert_eq!(run.history().rounds.len(), 4);
    assert_eq!(run.comm_totals().total(), hist.total_comm.total());
    assert!(run.setup_bytes() > 0, "SFPrompt distributes the frozen head once");
    let final_acc = run.final_eval().unwrap();
    assert!((0.0..=1.0).contains(&final_acc));
}

#[test]
fn driver_streams_ordered_events() {
    let backend = NativeBackend::tiny();
    let train = data(&backend, 96, 16);
    let eval = data(&backend, 32, 61);

    #[derive(Default)]
    struct Recorder {
        run_started: usize,
        run_ended: usize,
        starts: Vec<usize>,
        ends: Vec<usize>,
        evals: Vec<usize>,
    }
    impl RoundObserver for Recorder {
        fn on_run_start(&mut self, method: Method, f: &FedConfig) {
            assert_eq!(method, Method::SfPrompt);
            assert_eq!(f.rounds, 2);
            self.run_started += 1;
        }
        fn on_round_start(&mut self, round: usize) {
            self.starts.push(round);
        }
        fn on_eval(&mut self, round: usize, accuracy: f64) {
            assert!(accuracy.is_finite());
            self.evals.push(round);
        }
        fn on_round_end(&mut self, rec: &RoundRecord, clock_s: f64) {
            assert!(clock_s > 0.0, "frames crossed the simulated link");
            assert!(rec.comm.total() > 0);
            self.ends.push(rec.round);
        }
        fn on_run_end(&mut self, history: &RunHistory) {
            assert_eq!(history.rounds.len(), self.ends.len());
            self.run_ended += 1;
        }
    }

    let mut obs = Recorder::default();
    let mut run = build(&backend, fed(2), Method::SfPrompt, &train, Some(&eval));
    drive(run.as_mut(), &mut obs).unwrap();
    assert_eq!(obs.run_started, 1);
    assert_eq!(obs.run_ended, 1);
    assert_eq!(obs.starts, vec![0, 1]);
    assert_eq!(obs.ends, vec![0, 1]);
    assert_eq!(obs.evals, vec![0, 1], "eval_every=1 evaluates each round");
}

#[test]
fn sfprompt_comm_accounting_measures_frames() {
    let backend = NativeBackend::tiny();
    let train = data(&backend, 96, 7);
    let f = fed(2);
    let mut run = build(&backend, f, Method::SfPrompt, &train, None);
    let hist = drive(run.as_mut(), &mut NullObserver).unwrap();

    let mb = &backend.manifest().cost.message_bytes;
    let cfg = &backend.manifest().config;
    // Analytic per-round traffic: per selected client
    //   distribution (tail+prompt) + upload (tail+prompt) + broadcast
    //   + 4 cut-layer crossings per pruned batch.
    let per_client_samples = 96 / f.num_clients; // iid, divisible
    let retained = ((per_client_samples as f64 * f.retain_fraction).round()) as usize;
    let n_batches = (retained + cfg.batch - 1) / cfg.batch;
    let expected_per_round = f.clients_per_round
        * (3 * (mb["tail_params"] + mb["prompt_params"])
            + 4 * n_batches * mb["smashed_per_batch"]);
    let analytic = (expected_per_round * f.rounds) as u64;
    let measured = hist.total_comm.total();
    // Measured frames carry real framing overhead (length prefix, header,
    // shape tags, segment names, CRC) on top of the analytic payload size:
    // strictly more than analytic, but within 5%.
    assert!(measured > analytic, "measured {measured} <= analytic {analytic}");
    assert!(
        (measured as f64) < analytic as f64 * 1.05,
        "framing overhead above 5%: measured {measured}, analytic {analytic}"
    );
    // No full-model messages in SFPrompt, ever.
    assert!(!hist.total_comm.by_kind.contains_key(MsgKind::FullModel.label()));
}

#[test]
fn int8_wire_cuts_uplink_bytes() {
    let backend = NativeBackend::tiny();
    let train = data(&backend, 96, 7);
    let run_with = |wire: WireFormat| {
        let f = FedConfig { wire, ..fed(2) };
        let mut run = build(&backend, f, Method::SfPrompt, &train, None);
        drive(run.as_mut(), &mut NullObserver).unwrap()
    };
    let f32_hist = run_with(WireFormat::F32);
    let int8_hist = run_with(WireFormat::Int8);
    // ≥ 40% uplink reduction (int8 is ~4x smaller on the compressed kinds;
    // pruned batch counts can differ slightly since quantization perturbs
    // EL2N scores, hence the conservative bound).
    let (f32_up, int8_up) = (f32_hist.total_comm.uplink, int8_hist.total_comm.uplink);
    assert!(
        (int8_up as f64) < f32_up as f64 * 0.6,
        "int8 uplink {int8_up} not <60% of f32 uplink {f32_up}"
    );
    // Downlink stays f32: same message structure, near-identical bytes.
    let (f32_down, int8_down) = (f32_hist.total_comm.downlink, int8_hist.total_comm.downlink);
    assert!(
        (int8_down as f64 - f32_down as f64).abs() < f32_down as f64 * 0.1,
        "downlink drifted: {f32_down} vs {int8_down}"
    );
    // And the quantized run still trains.
    assert!(int8_hist.rounds.iter().all(|r| r.mean_split_loss.is_finite()));
}

#[test]
fn pruning_reduces_split_traffic() {
    let backend = NativeBackend::tiny();
    let train = data(&backend, 96, 8);
    let mut comm_at = Vec::new();
    for retain in [1.0, 0.25] {
        let f = FedConfig { retain_fraction: retain, ..fed(2) };
        let mut run = build(&backend, f, Method::SfPrompt, &train, None);
        let hist = drive(run.as_mut(), &mut NullObserver).unwrap();
        comm_at.push(hist.total_comm.by_kind["smashed_data"]);
    }
    assert!(comm_at[1] < comm_at[0], "pruning must cut smashed traffic: {comm_at:?}");
}

#[test]
fn pruning_keeps_the_hard_examples() {
    // EL2N pruning must retain high-score (hard/boundary) samples. Score a
    // fresh fleet's first client and check that what prune_dataset keeps
    // is exactly the top of its own score ranking — exercised through the
    // public stage API with a real synthesized corpus.
    use sfprompt::federation::client::{top_k_by_score, Client};
    use sfprompt::util::rng::Rng;

    let backend = NativeBackend::tiny();
    let train = data(&backend, 64, 17);
    let params = sfprompt::model::init_params(backend.manifest(), 3);
    let head_prep = backend.prepare_segment(params.get("head").unwrap()).unwrap();
    let mut client = Client::new(0, (0..64).collect(), Rng::new(4));
    let kept = client
        .prune_dataset(
            &backend,
            &train.examples,
            &head_prep,
            params.get("tail").unwrap(),
            params.get("prompt").unwrap(),
            0.25,
        )
        .unwrap();
    assert_eq!(kept.len(), 16);

    // Re-score every sample through the same stage and verify the kept
    // set is the argmax-16 of the scores.
    let cfg = &backend.manifest().config;
    let mut scored = Vec::new();
    for chunk in sfprompt::data::batch_indices(&(0..64).collect::<Vec<_>>(), cfg.batch) {
        let batch = sfprompt::data::make_batch(
            &train.examples, &chunk, cfg.batch, cfg.image_size, cfg.channels,
        );
        let mut segs: sfprompt::backend::SegmentInputs = Default::default();
        segs.insert("head", sfprompt::backend::SegInput::Prepared(&head_prep));
        segs.insert("tail", sfprompt::backend::SegInput::Host(params.get("tail").unwrap()));
        segs.insert(
            "prompt",
            sfprompt::backend::SegInput::Host(params.get("prompt").unwrap()),
        );
        let mut tensors: sfprompt::backend::TensorInputs = Default::default();
        tensors.insert("images", &batch.images);
        tensors.insert("labels", &batch.labels);
        let out = backend.run_stage("el2n_scores", &segs, &tensors).unwrap();
        let scores = out.tensor("scores").unwrap().as_f32().to_vec();
        for (i, &idx) in chunk.iter().enumerate() {
            if scored.iter().all(|&(j, _)| j != idx) {
                scored.push((idx, scores[i]));
            }
        }
    }
    let expect = top_k_by_score(scored, 16);
    let mut kept_sorted = kept.clone();
    let mut expect_sorted = expect.clone();
    kept_sorted.sort_unstable();
    expect_sorted.sort_unstable();
    assert_eq!(kept_sorted, expect_sorted, "pruning kept something other than the top scores");
}

#[test]
fn ablation_without_local_loss_still_runs() {
    let backend = NativeBackend::tiny();
    let train = data(&backend, 96, 9);
    let f = FedConfig { local_loss_update: false, ..fed(2) };
    let mut run = build(&backend, f, Method::SfPrompt, &train, None);
    let hist = drive(run.as_mut(), &mut NullObserver).unwrap();
    assert_eq!(hist.rounds.len(), 2);
    assert!(hist.rounds[0].mean_local_loss.is_nan() || hist.rounds[0].mean_local_loss == 0.0);
}

#[test]
fn fl_baseline_trains_and_costs_full_model_bytes() {
    let backend = NativeBackend::tiny();
    let train = data(&backend, 96, 10);
    let f = fed(2);
    let mut run = build(&backend, f, Method::Fl, &train, None);
    let hist = drive(run.as_mut(), &mut NullObserver).unwrap();
    assert_eq!(run.method(), Method::Fl);
    assert_eq!(run.setup_bytes(), 0, "FL has no one-time setup traffic");
    let full = backend.manifest().cost.message_bytes["full_model"];
    let analytic = (2 * full * f.clients_per_round * f.rounds) as u64;
    let measured = hist.total_comm.total();
    // Measured frames = analytic payload + framing overhead, within 5%.
    assert!(measured > analytic, "measured {measured} <= analytic {analytic}");
    assert!((measured as f64) < analytic as f64 * 1.05);
    let losses: Vec<f64> = hist.rounds.iter().map(|r| r.mean_split_loss).collect();
    assert!(losses.iter().all(|l| l.is_finite()));
}

#[test]
fn sfl_ff_trains_and_talks_every_epoch() {
    let backend = NativeBackend::tiny();
    let train = data(&backend, 96, 11);
    let mut run = build(&backend, fed(2), Method::SflFullFinetune, &train, None);
    let hist = drive(run.as_mut(), &mut NullObserver).unwrap();
    // 4 crossings per batch per epoch; sanity: smashed bytes scale with U.
    assert!(hist.total_comm.by_kind.contains_key("smashed_data"));
    assert!(hist.total_comm.by_kind.contains_key("grad_smashed"));
    let losses: Vec<f64> = hist.rounds.iter().map(|r| r.mean_split_loss).collect();
    assert!(losses.windows(2).any(|w| w[1] <= w[0]), "{losses:?}");
}

#[test]
fn sfl_linear_never_sends_gradients_downstream() {
    let backend = NativeBackend::tiny();
    let train = data(&backend, 96, 12);
    let mut run = build(&backend, fed(2), Method::SflLinear, &train, None);
    let hist = drive(run.as_mut(), &mut NullObserver).unwrap();
    // Frozen head/body: activations flow, gradients never cross the cut.
    assert!(hist.total_comm.by_kind.contains_key("smashed_data"));
    assert!(!hist.total_comm.by_kind.contains_key("grad_smashed"));
    assert!(!hist.total_comm.by_kind.contains_key("grad_body_out"));
}

#[test]
fn sfprompt_vs_sfl_comm_ordering_matches_paper() {
    // The paper's headline: SFPrompt ≪ SFL on communication for U > 1.
    let backend = NativeBackend::tiny();
    let train = data(&backend, 96, 13);
    let f = FedConfig { local_epochs: 4, ..fed(1) };

    let mut sfp = build(&backend, f, Method::SfPrompt, &train, None);
    let sfp_comm =
        drive(sfp.as_mut(), &mut NullObserver).unwrap().total_comm.total();

    let mut sfl = build(&backend, f, Method::SflFullFinetune, &train, None);
    let sfl_comm =
        drive(sfl.as_mut(), &mut NullObserver).unwrap().total_comm.total();

    assert!(
        sfp_comm * 2 < sfl_comm,
        "SFPrompt ({sfp_comm}) should be well under SFL ({sfl_comm})"
    );
}

#[test]
fn deterministic_runs_for_same_seed() {
    let backend = NativeBackend::tiny();
    let train = data(&backend, 96, 14);
    let run = || {
        let mut r = build(&backend, fed(2), Method::SfPrompt, &train, None);
        drive(r.as_mut(), &mut NullObserver).unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.total_comm.total(), b.total_comm.total());
    for (x, y) in a.rounds.iter().zip(&b.rounds) {
        assert_eq!(x.mean_split_loss.to_bits(), y.mean_split_loss.to_bits());
    }
}

#[test]
fn noniid_partition_runs_end_to_end() {
    let backend = NativeBackend::tiny();
    let train = data(&backend, 120, 15);
    let f = FedConfig {
        partition: Partition::Dirichlet { alpha: 0.1 },
        num_clients: 8,
        ..fed(2)
    };
    let mut run = build(&backend, f, Method::SfPrompt, &train, None);
    let hist = drive(run.as_mut(), &mut NullObserver).unwrap();
    assert_eq!(hist.rounds.len(), 2);
}
