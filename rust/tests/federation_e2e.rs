//! End-to-end federation tests on the real `tiny` artifacts: the SFPrompt
//! engine and all three baselines must run full rounds, account bytes
//! correctly, and train (loss decreases over rounds).

use sfprompt::comm::MsgKind;
use sfprompt::data::{synth::DatasetProfile, SynthDataset};
use sfprompt::federation::baselines::BaselineEngine;
use sfprompt::federation::{Selection, FedConfig, Method, SfPromptEngine};
use sfprompt::partition::Partition;
use sfprompt::runtime::ArtifactStore;
use sfprompt::transport::WireFormat;

fn open_tiny() -> Option<ArtifactStore> {
    match ArtifactStore::open(&sfprompt::artifacts_root(), "tiny") {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("SKIP (no artifacts): {e:#}");
            None
        }
    }
}

fn data(store: &ArtifactStore, n: usize, seed: u64) -> SynthDataset {
    let cfg = &store.manifest.config;
    let profile = DatasetProfile {
        name: "t",
        num_classes: cfg.num_classes,
        noise: 0.35,
        class_overlap: 0.1,
    };
    SynthDataset::generate(profile, cfg.image_size, cfg.channels, n, 5, seed)
}

fn fed(rounds: usize) -> FedConfig {
    FedConfig {
        num_clients: 6,
        clients_per_round: 2,
        local_epochs: 2,
        rounds,
        lr: 0.1,
        retain_fraction: 0.5,
        local_loss_update: true,
        partition: Partition::Iid,
        seed: 9,
        eval_limit: Some(32),
        eval_every: 1,
        selection: Selection::Uniform,
        wire: WireFormat::F32,
    }
}

#[test]
fn sfprompt_runs_and_loss_decreases() {
    let Some(store) = open_tiny() else { return };
    let train = data(&store, 96, 6);
    let eval = data(&store, 32, 60);
    let mut engine = SfPromptEngine::new(&store, fed(4), &train);
    let hist = engine.run(&train, Some(&eval), |_| {}).unwrap();
    assert_eq!(hist.rounds.len(), 4);
    let first = &hist.rounds[0];
    let last = &hist.rounds[3];
    assert!(last.mean_local_loss < first.mean_local_loss,
            "local loss {} -> {}", first.mean_local_loss, last.mean_local_loss);
    assert!(hist.final_accuracy() >= 0.0 && hist.final_accuracy() <= 1.0);
}

#[test]
fn sfprompt_comm_accounting_measures_frames() {
    let Some(store) = open_tiny() else { return };
    let train = data(&store, 96, 7);
    let f = fed(2);
    let mut engine = SfPromptEngine::new(&store, f, &train);
    let hist = engine.run(&train, None, |_| {}).unwrap();

    let mb = &store.manifest.cost.message_bytes;
    let cfg = &store.manifest.config;
    // Analytic per-round traffic: per selected client
    //   distribution (tail+prompt) + upload (tail+prompt) + broadcast
    //   + 4 cut-layer crossings per pruned batch.
    let per_client_samples = 96 / f.num_clients; // iid, divisible
    let retained = ((per_client_samples as f64 * f.retain_fraction).round()) as usize;
    let n_batches = (retained + cfg.batch - 1) / cfg.batch;
    let expected_per_round = f.clients_per_round
        * (3 * (mb["tail_params"] + mb["prompt_params"])
            + 4 * n_batches * mb["smashed_per_batch"]);
    let analytic = (expected_per_round * f.rounds) as u64;
    let measured = hist.total_comm.total();
    // Measured frames carry real framing overhead (length prefix, header,
    // shape tags, segment names, CRC) on top of the analytic payload size:
    // strictly more than analytic, but within 5%.
    assert!(measured > analytic, "measured {measured} <= analytic {analytic}");
    assert!(
        (measured as f64) < analytic as f64 * 1.05,
        "framing overhead above 5%: measured {measured}, analytic {analytic}"
    );
    // No full-model messages in SFPrompt, ever.
    assert!(!hist.total_comm.by_kind.contains_key(MsgKind::FullModel.label()));
}

#[test]
fn int8_wire_cuts_uplink_bytes() {
    let Some(store) = open_tiny() else { return };
    let train = data(&store, 96, 7);
    let run_with = |wire: WireFormat| {
        let f = FedConfig { wire, ..fed(2) };
        let mut engine = SfPromptEngine::new(&store, f, &train);
        engine.run(&train, None, |_| {}).unwrap()
    };
    let f32_hist = run_with(WireFormat::F32);
    let int8_hist = run_with(WireFormat::Int8);
    // ≥ 40% uplink reduction (int8 is ~4x smaller on the compressed kinds;
    // pruned batch counts can differ slightly since quantization perturbs
    // EL2N scores, hence the conservative bound).
    let (f32_up, int8_up) = (f32_hist.total_comm.uplink, int8_hist.total_comm.uplink);
    assert!(
        (int8_up as f64) < f32_up as f64 * 0.6,
        "int8 uplink {int8_up} not <60% of f32 uplink {f32_up}"
    );
    // Downlink stays f32: same message structure, near-identical bytes.
    let (f32_down, int8_down) = (f32_hist.total_comm.downlink, int8_hist.total_comm.downlink);
    assert!(
        (int8_down as f64 - f32_down as f64).abs() < f32_down as f64 * 0.1,
        "downlink drifted: {f32_down} vs {int8_down}"
    );
    // And the quantized run still trains.
    assert!(int8_hist.rounds.iter().all(|r| r.mean_split_loss.is_finite()));
}

#[test]
fn pruning_reduces_split_traffic() {
    let Some(store) = open_tiny() else { return };
    let train = data(&store, 96, 8);
    let mut comm_at = Vec::new();
    for retain in [1.0, 0.25] {
        let f = FedConfig { retain_fraction: retain, ..fed(2) };
        let mut engine = SfPromptEngine::new(&store, f, &train);
        let hist = engine.run(&train, None, |_| {}).unwrap();
        comm_at.push(hist.total_comm.by_kind["smashed_data"]);
    }
    assert!(comm_at[1] < comm_at[0], "pruning must cut smashed traffic: {comm_at:?}");
}

#[test]
fn ablation_without_local_loss_still_runs() {
    let Some(store) = open_tiny() else { return };
    let train = data(&store, 96, 9);
    let f = FedConfig { local_loss_update: false, ..fed(2) };
    let mut engine = SfPromptEngine::new(&store, f, &train);
    let hist = engine.run(&train, None, |_| {}).unwrap();
    assert_eq!(hist.rounds.len(), 2);
    assert!(hist.rounds[0].mean_local_loss.is_nan() || hist.rounds[0].mean_local_loss == 0.0);
}

#[test]
fn fl_baseline_trains_and_costs_full_model_bytes() {
    let Some(store) = open_tiny() else { return };
    let train = data(&store, 96, 10);
    let f = fed(2);
    let mut engine = BaselineEngine::new(&store, f, Method::Fl, &train);
    let hist = engine.run(&train, None, |_| {}).unwrap();
    let full = store.manifest.cost.message_bytes["full_model"];
    let analytic = (2 * full * f.clients_per_round * f.rounds) as u64;
    let measured = hist.total_comm.total();
    // Measured frames = analytic payload + framing overhead, within 5%.
    assert!(measured > analytic, "measured {measured} <= analytic {analytic}");
    assert!((measured as f64) < analytic as f64 * 1.05);
    let losses: Vec<f64> = hist.rounds.iter().map(|r| r.mean_split_loss).collect();
    assert!(losses.iter().all(|l| l.is_finite()));
}

#[test]
fn sfl_ff_trains_and_talks_every_epoch() {
    let Some(store) = open_tiny() else { return };
    let train = data(&store, 96, 11);
    let f = fed(2);
    let mut engine = BaselineEngine::new(&store, f, Method::SflFullFinetune, &train);
    let hist = engine.run(&train, None, |_| {}).unwrap();
    // 4 crossings per batch per epoch; sanity: smashed bytes scale with U.
    assert!(hist.total_comm.by_kind.contains_key("smashed_data"));
    assert!(hist.total_comm.by_kind.contains_key("grad_smashed"));
    let losses: Vec<f64> = hist.rounds.iter().map(|r| r.mean_split_loss).collect();
    assert!(losses.windows(2).any(|w| w[1] <= w[0]), "{losses:?}");
}

#[test]
fn sfl_linear_never_sends_gradients_downstream() {
    let Some(store) = open_tiny() else { return };
    let train = data(&store, 96, 12);
    let mut engine = BaselineEngine::new(&store, fed(2), Method::SflLinear, &train);
    let hist = engine.run(&train, None, |_| {}).unwrap();
    // Frozen head/body: activations flow, gradients never cross the cut.
    assert!(hist.total_comm.by_kind.contains_key("smashed_data"));
    assert!(!hist.total_comm.by_kind.contains_key("grad_smashed"));
    assert!(!hist.total_comm.by_kind.contains_key("grad_body_out"));
}

#[test]
fn sfprompt_vs_sfl_comm_ordering_matches_paper() {
    // The paper's headline: SFPrompt ≪ SFL on communication for U > 1.
    let Some(store) = open_tiny() else { return };
    let train = data(&store, 96, 13);
    let f = FedConfig { local_epochs: 4, ..fed(1) };

    let mut sfp = SfPromptEngine::new(&store, f, &train);
    let sfp_comm = sfp.run(&train, None, |_| {}).unwrap().total_comm.total();

    let mut sfl = BaselineEngine::new(&store, f, Method::SflFullFinetune, &train);
    let sfl_comm = sfl.run(&train, None, |_| {}).unwrap().total_comm.total();

    assert!(
        sfp_comm * 2 < sfl_comm,
        "SFPrompt ({sfp_comm}) should be well under SFL ({sfl_comm})"
    );
}

#[test]
fn deterministic_runs_for_same_seed() {
    let Some(store) = open_tiny() else { return };
    let train = data(&store, 96, 14);
    let run = || {
        let mut e = SfPromptEngine::new(&store, fed(2), &train);
        e.run(&train, None, |_| {}).unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.total_comm.total(), b.total_comm.total());
    for (x, y) in a.rounds.iter().zip(&b.rounds) {
        assert_eq!(x.mean_split_loss.to_bits(), y.mean_split_loss.to_bits());
    }
}

#[test]
fn noniid_partition_runs_end_to_end() {
    let Some(store) = open_tiny() else { return };
    let train = data(&store, 120, 15);
    let f = FedConfig {
        partition: Partition::Dirichlet { alpha: 0.1 },
        num_clients: 8,
        ..fed(2)
    };
    let mut engine = SfPromptEngine::new(&store, f, &train);
    let hist = engine.run(&train, None, |_| {}).unwrap();
    assert_eq!(hist.rounds.len(), 2);
}
