//! Property-based tests over coordinator invariants (substrate — the
//! offline registry has no `proptest`, so these use a seeded-random case
//! driver with explicit failure reporting; 200+ random cases per property).

use sfprompt::comm::{ByteMeter, Direction, MsgKind};
use sfprompt::compress::{
    CompressedRepr, CompressedSegment, CompressedTensor, Scheme, UpdateCompressor,
};
use sfprompt::data::batch_indices;
use sfprompt::model::{fedavg, Contribution, SegmentParams};
use sfprompt::partition::{label_skew, partition, Partition};
use sfprompt::runtime::HostTensor;
use sfprompt::transport::{decode_frame, encode_frame, Frame, Payload, WireFormat};
use sfprompt::util::json::Json;
use sfprompt::util::rng::Rng;

const CASES: usize = 200;

fn seg_from(rng: &mut Rng, n: usize) -> SegmentParams {
    SegmentParams {
        segment: "s".into(),
        tensors: vec![HostTensor::f32(
            vec![n],
            (0..n).map(|_| rng.normal_f32(0.0, 2.0)).collect(),
        )],
    }
}

// ---------------------------------------------------------------- fedavg

#[test]
fn prop_fedavg_within_convex_hull() {
    // Every aggregated coordinate must lie within [min, max] of the inputs.
    let mut rng = Rng::new(101);
    for case in 0..CASES {
        let k = 1 + rng.below(6);
        let n = 1 + rng.below(20);
        let segs: Vec<SegmentParams> = (0..k).map(|_| seg_from(&mut rng, n)).collect();
        let weights: Vec<usize> = (0..k).map(|_| 1 + rng.below(50)).collect();
        let contribs: Vec<Contribution> = segs
            .iter()
            .zip(&weights)
            .map(|(s, &w)| Contribution { params: s, num_samples: w })
            .collect();
        let out = fedavg(&contribs).unwrap();
        for i in 0..n {
            let vals: Vec<f32> = segs.iter().map(|s| s.tensors[0].as_f32()[i]).collect();
            let (lo, hi) = vals.iter().fold((f32::MAX, f32::MIN), |(l, h), &v| {
                (l.min(v), h.max(v))
            });
            let got = out.tensors[0].as_f32()[i];
            assert!(
                got >= lo - 1e-4 && got <= hi + 1e-4,
                "case {case}: coord {i} = {got} outside [{lo}, {hi}]"
            );
        }
    }
}

#[test]
fn prop_fedavg_permutation_invariant() {
    let mut rng = Rng::new(102);
    for case in 0..CASES {
        let k = 2 + rng.below(5);
        let n = 1 + rng.below(16);
        let segs: Vec<SegmentParams> = (0..k).map(|_| seg_from(&mut rng, n)).collect();
        let weights: Vec<usize> = (0..k).map(|_| 1 + rng.below(20)).collect();
        let fwd: Vec<Contribution> = segs
            .iter()
            .zip(&weights)
            .map(|(s, &w)| Contribution { params: s, num_samples: w })
            .collect();
        let rev: Vec<Contribution> = segs
            .iter()
            .zip(&weights)
            .rev()
            .map(|(s, &w)| Contribution { params: s, num_samples: w })
            .collect();
        let a = fedavg(&fwd).unwrap();
        let b = fedavg(&rev).unwrap();
        assert!(a.max_abs_diff(&b) < 1e-5, "case {case}: diff {}", a.max_abs_diff(&b));
    }
}

#[test]
fn prop_fedavg_scale_equivariant() {
    // fedavg(c * xs) == c * fedavg(xs)
    let mut rng = Rng::new(103);
    for case in 0..CASES / 2 {
        let k = 1 + rng.below(4);
        let n = 1 + rng.below(10);
        let segs: Vec<SegmentParams> = (0..k).map(|_| seg_from(&mut rng, n)).collect();
        let c = rng.normal_f32(0.0, 3.0);
        let contribs = |s: &[SegmentParams]| -> SegmentParams {
            let cs: Vec<Contribution> =
                s.iter().map(|p| Contribution { params: p, num_samples: 7 }).collect();
            fedavg(&cs).unwrap()
        };
        let base = contribs(&segs);
        let scaled_in: Vec<SegmentParams> = segs
            .iter()
            .map(|s| {
                let mut x = s.clone();
                x.scale(c);
                x
            })
            .collect();
        let scaled_out = contribs(&scaled_in);
        let mut expect = base.clone();
        expect.scale(c);
        assert!(scaled_out.max_abs_diff(&expect) < 2e-3, "case {case}");
    }
}

// ---------------------------------------------------------------- partition

#[test]
fn prop_partition_is_exact_cover() {
    let mut rng = Rng::new(104);
    for case in 0..CASES {
        let n = 1 + rng.below(600);
        let classes = 1 + rng.below(20) as i32;
        let clients = 1 + rng.below(20);
        let labels: Vec<i32> = (0..n).map(|_| rng.below(classes as usize) as i32).collect();
        let scheme = if rng.uniform() < 0.5 {
            Partition::Iid
        } else {
            Partition::Dirichlet { alpha: 0.05 + rng.uniform() * 2.0 }
        };
        let parts = partition(&labels, clients, scheme, &mut rng);
        assert_eq!(parts.len(), clients, "case {case}");
        let mut all: Vec<usize> = parts.iter().flatten().copied().collect();
        all.sort_unstable();
        let expect: Vec<usize> = (0..n).collect();
        assert_eq!(all, expect, "case {case}: not an exact cover ({scheme:?})");
    }
}

#[test]
fn prop_partition_nonempty_when_enough_samples() {
    let mut rng = Rng::new(105);
    for case in 0..CASES / 2 {
        let clients = 2 + rng.below(30);
        let n = clients * (1 + rng.below(20));
        let labels: Vec<i32> = (0..n).map(|_| rng.below(10) as i32).collect();
        let parts =
            partition(&labels, clients, Partition::Dirichlet { alpha: 0.1 }, &mut rng);
        let empties = parts.iter().filter(|p| p.is_empty()).count();
        assert_eq!(empties, 0, "case {case}: {empties} empty clients (n={n}, k={clients})");
    }
}

#[test]
fn prop_skew_bounded_zero_one() {
    let mut rng = Rng::new(106);
    for _ in 0..CASES / 4 {
        let n = 50 + rng.below(500);
        let labels: Vec<i32> = (0..n).map(|_| rng.below(7) as i32).collect();
        let parts = partition(&labels, 10, Partition::Dirichlet { alpha: 0.2 }, &mut rng);
        let s = label_skew(&labels, &parts);
        assert!((0.0..=1.0).contains(&s), "skew {s}");
    }
}

// ---------------------------------------------------------------- batching

#[test]
fn prop_batches_cover_all_indices_without_invention() {
    let mut rng = Rng::new(107);
    for case in 0..CASES {
        let n = 1 + rng.below(200);
        let batch = 1 + rng.below(32);
        let indices: Vec<usize> = (0..n).map(|_| rng.below(1000)).collect();
        let batches = batch_indices(&indices, batch);
        // Every batch has exactly `batch` entries.
        assert!(batches.iter().all(|b| b.len() == batch), "case {case}");
        // Concatenation starts with the original sequence…
        let flat: Vec<usize> = batches.iter().flatten().copied().collect();
        assert_eq!(&flat[..n], &indices[..], "case {case}");
        // …and any padding repeats the final element only.
        assert!(flat[n..].iter().all(|&x| x == *indices.last().unwrap()), "case {case}");
    }
}

// ---------------------------------------------------------------- comm

#[test]
fn prop_meter_total_equals_sum_of_kinds() {
    let mut rng = Rng::new(108);
    let kinds = [
        MsgKind::ModelDistribution,
        MsgKind::SmashedData,
        MsgKind::BodyOutput,
        MsgKind::GradBodyOut,
        MsgKind::GradSmashed,
        MsgKind::Upload,
        MsgKind::AggregateBroadcast,
        MsgKind::FullModel,
    ];
    for case in 0..CASES {
        let mut m = ByteMeter::default();
        let msgs = rng.below(200);
        let mut expect = 0u64;
        for _ in 0..msgs {
            let kind = kinds[rng.below(kinds.len())];
            let dir = if rng.uniform() < 0.5 { Direction::Uplink } else { Direction::Downlink };
            let bytes = rng.below(1 << 20);
            m.record(kind, dir, bytes);
            expect += bytes as u64;
        }
        assert_eq!(m.total(), expect, "case {case}");
        assert_eq!(m.by_kind.values().sum::<u64>(), expect, "case {case}");
        assert_eq!(m.messages, msgs as u64, "case {case}");
    }
}

// ---------------------------------------------------------------- json

#[test]
fn prop_json_roundtrip_random_trees() {
    let mut rng = Rng::new(109);
    fn gen(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.uniform() < 0.5),
            2 => Json::Num((rng.below(2_000_001) as i64 - 1_000_000) as f64),
            3 => Json::Str(format!("s{}-\"q\\{}", rng.below(100), rng.below(100))),
            4 => Json::Arr((0..rng.below(5)).map(|_| gen(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    for case in 0..CASES {
        let v = gen(&mut rng, 3);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("case {case}: {e}\n{text}"));
        assert_eq!(v, back, "case {case}");
    }
}

// ---------------------------------------------------------------- codec

const KINDS: [MsgKind; 9] = [
    MsgKind::ModelDistribution,
    MsgKind::SmashedData,
    MsgKind::BodyOutput,
    MsgKind::GradBodyOut,
    MsgKind::GradSmashed,
    MsgKind::Upload,
    MsgKind::AggregateBroadcast,
    MsgKind::FullModel,
    MsgKind::Abort,
];

fn random_tensor(rng: &mut Rng, sigma: f32) -> HostTensor {
    let rank = rng.below(4);
    let shape: Vec<usize> = (0..rank).map(|_| 1 + rng.below(6)).collect();
    let n: usize = shape.iter().product();
    if rng.uniform() < 0.25 {
        HostTensor::i32(shape, (0..n).map(|_| rng.below(2000) as i32 - 1000).collect())
    } else {
        HostTensor::f32(shape, (0..n).map(|_| rng.normal_f32(0.0, sigma)).collect())
    }
}

fn random_frame(rng: &mut Rng, sigma: f32) -> Frame {
    let kind = KINDS[rng.below(KINDS.len())];
    let payload = match rng.below(3) {
        0 => Payload::Empty,
        1 => Payload::Tensor(random_tensor(rng, sigma)),
        _ => {
            let n_segs = 1 + rng.below(3);
            Payload::Segments(
                (0..n_segs)
                    .map(|i| SegmentParams {
                        segment: format!("seg{i}"),
                        tensors: (0..1 + rng.below(3))
                            .map(|_| random_tensor(rng, sigma))
                            .collect(),
                    })
                    .collect(),
            )
        }
    };
    Frame::new(kind, rng.below(1 << 20) as u32, rng.below(1 << 10) as u32, payload)
}

/// Every f32 tensor in a payload, flattened (for error comparisons).
fn f32_values(p: &Payload) -> Vec<f32> {
    let from_tensor = |t: &HostTensor| match t.dtype() {
        sfprompt::runtime::Dtype::F32 => t.as_f32().to_vec(),
        _ => Vec::new(),
    };
    match p {
        Payload::Empty => Vec::new(),
        Payload::Tensor(t) => from_tensor(t),
        Payload::Segments(segs) => {
            segs.iter().flat_map(|s| s.tensors.iter().flat_map(|t| from_tensor(t))).collect()
        }
    }
}

#[test]
fn prop_codec_f32_roundtrip_is_identity() {
    let mut rng = Rng::new(210);
    for case in 0..CASES {
        let frame = random_frame(&mut rng, 2.0);
        let bytes = encode_frame(&frame, WireFormat::F32).unwrap();
        let back = decode_frame(&bytes).unwrap_or_else(|e| panic!("case {case}: {e:#}"));
        assert_eq!(back, frame, "case {case}");
    }
}

#[test]
fn prop_codec_f16_error_is_bounded() {
    let mut rng = Rng::new(211);
    for case in 0..CASES {
        let frame = random_frame(&mut rng, 10.0);
        let bytes = encode_frame(&frame, WireFormat::F16).unwrap();
        let back = decode_frame(&bytes).unwrap();
        // Structure and i32 data survive exactly; f32 within f16 precision
        // (relative 2^-11 for normals; absolute slack covers subnormals).
        assert_eq!(back.kind, frame.kind, "case {case}");
        for (a, b) in f32_values(&frame.payload).iter().zip(f32_values(&back.payload)) {
            assert!(
                (a - b).abs() <= a.abs() / 1024.0 + 1e-3,
                "case {case}: {a} -> {b}"
            );
        }
    }
}

#[test]
fn prop_codec_int8_error_is_bounded_per_tensor() {
    let mut rng = Rng::new(212);
    for case in 0..CASES {
        let n = 1 + rng.below(300);
        let vals: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 5.0)).collect();
        let frame = Frame::new(
            MsgKind::SmashedData,
            0,
            0,
            Payload::Tensor(HostTensor::f32(vec![n], vals.clone())),
        );
        let bytes = encode_frame(&frame, WireFormat::Int8).unwrap();
        let back = decode_frame(&bytes).unwrap().payload.into_tensor().unwrap();
        let (lo, hi) = vals.iter().fold((f32::MAX, f32::MIN), |(l, h), &v| {
            (l.min(v), h.max(v))
        });
        let scale = (hi - lo) / 255.0;
        for (a, b) in vals.iter().zip(back.as_f32()) {
            assert!(
                (a - b).abs() <= scale * 0.502 + 1e-5,
                "case {case}: {a} -> {b} (scale {scale})"
            );
        }
    }
}

#[test]
fn prop_codec_rejects_any_single_byte_corruption() {
    // Every byte of a frame is protected: the length prefix by the length
    // check, everything else by CRC32 — so ANY flip must fail decode.
    let mut rng = Rng::new(213);
    for case in 0..CASES {
        let wire = [WireFormat::F32, WireFormat::F16, WireFormat::Int8][rng.below(3)];
        let frame = random_frame(&mut rng, 2.0);
        let good = encode_frame(&frame, wire).unwrap();
        let mut bad = good.clone();
        let at = rng.below(bad.len());
        bad[at] ^= 1 << rng.below(8);
        assert!(decode_frame(&bad).is_err(), "case {case}: flip at {at} accepted");
        // Truncation at any point must also fail.
        let cut = rng.below(good.len());
        assert!(decode_frame(&good[..cut]).is_err(), "case {case}: truncation at {cut}");
    }
}

#[test]
fn prop_codec_rejects_wrong_version_even_with_valid_crc() {
    let mut rng = Rng::new(214);
    for case in 0..CASES / 4 {
        let frame = random_frame(&mut rng, 2.0);
        let mut bytes = encode_frame(&frame, WireFormat::F32).unwrap();
        bytes[6] = bytes[6].wrapping_add(1 + rng.below(250) as u8);
        // Recompute the CRC so only the version check can reject.
        let crc = sfprompt::transport::crc32::crc32(&bytes[4..bytes.len() - 4]);
        let n = bytes.len();
        bytes[n - 4..].copy_from_slice(&crc.to_le_bytes());
        let err = decode_frame(&bytes).unwrap_err().to_string();
        assert!(err.contains("version"), "case {case}: {err}");
    }
}

// ---------------------------------------------------------------- compress

/// Sparse wire frames round-trip exactly: whatever index layout the codec
/// picked (varint deltas or bitmap), the decoded tensor reconstructs the
/// identical dense vector, values bit-exact at f32, and any sparse repr
/// that comes back has sorted, duplicate-free indices.
#[test]
fn prop_sparse_frame_roundtrip_is_exact() {
    let mut rng = Rng::new(301);
    for case in 0..CASES {
        let n = 1 + rng.below(500);
        // Densities from ~empty to full exercise varint, bitmap, and the
        // dense fallback.
        let nnz = rng.below(n + 1);
        let mut coords: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut coords);
        let mut indices: Vec<u32> = coords[..nnz].iter().map(|&i| i as u32).collect();
        indices.sort_unstable();
        let values: Vec<f32> =
            (0..nnz).map(|_| rng.normal_f32(0.0, 3.0) * 1e-4_f32.powi(rng.below(3) as i32)).collect();
        let tensor = CompressedTensor {
            shape: vec![n],
            repr: CompressedRepr::Sparse { indices: indices.clone(), values: values.clone() },
        };
        let frame = Frame::new(
            MsgKind::Upload,
            case as u32,
            7,
            Payload::Compressed(vec![CompressedSegment {
                segment: "tail".into(),
                tensors: vec![tensor.clone()],
            }]),
        );
        let bytes = encode_frame(&frame, WireFormat::F32).unwrap();
        let back = decode_frame(&bytes)
            .unwrap_or_else(|e| panic!("case {case}: {e:#}"))
            .payload
            .into_compressed()
            .unwrap();
        let got = &back[0].tensors[0];
        let want_dense = tensor.decompress().unwrap();
        let got_dense = got.decompress().unwrap();
        assert_eq!(got_dense.len(), want_dense.len(), "case {case}");
        for (a, b) in want_dense.iter().zip(&got_dense) {
            assert_eq!(a.to_bits(), b.to_bits(), "case {case}: {a} != {b}");
        }
        if let CompressedRepr::Sparse { indices: gi, values: gv } = &got.repr {
            assert!(gi.windows(2).all(|w| w[0] < w[1]), "case {case}: unsorted/dup indices");
            assert_eq!(gi, &indices, "case {case}");
            for (a, b) in values.iter().zip(gv) {
                assert_eq!(a.to_bits(), b.to_bits(), "case {case}");
            }
        }
    }
}

/// The codec's layout choice guarantees a compressed frame never exceeds
/// the dense-f32 frame carrying the same tensors — for every scheme, at
/// every density.
#[test]
fn prop_compressed_wire_never_exceeds_dense() {
    let mut rng = Rng::new(302);
    for case in 0..CASES {
        let n = 1 + rng.below(800);
        let values: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let seg = SegmentParams {
            segment: "s".into(),
            tensors: vec![HostTensor::f32(vec![n], values.clone())],
        };
        let dense_frame =
            Frame::new(MsgKind::Upload, 0, 0, Payload::Segments(vec![seg.clone()]));
        let dense_len = encode_frame(&dense_frame, WireFormat::F32).unwrap().len();

        let schemes = [
            Scheme::TopK { ratio: 0.01 + rng.uniform() * 0.99 },
            Scheme::RandK { ratio: 0.01 + rng.uniform() * 0.99 },
            Scheme::Quant { bits: 2 + rng.below(7) as u8 },
        ];
        for scheme in schemes {
            let mut comp = scheme.compressor(case as u64).unwrap();
            let repr = comp.compress(&values);
            let frame = Frame::new(
                MsgKind::Upload,
                0,
                0,
                Payload::Compressed(vec![CompressedSegment {
                    segment: "s".into(),
                    tensors: vec![CompressedTensor { shape: vec![n], repr }],
                }]),
            );
            let len = encode_frame(&frame, WireFormat::F32).unwrap().len();
            assert!(
                len <= dense_len,
                "case {case}: {} frame is {len} B > dense {dense_len} B (n={n})",
                scheme.label()
            );
        }
    }
}

/// Error-feedback conservation: every round, `sent + residual` equals
/// `update + residual_prev` coordinate for coordinate, exactly in f32 —
/// sparsification moves mass between the wire and the residual, it never
/// creates or destroys any.
#[test]
fn prop_error_feedback_conserves_update_mass() {
    let mut rng = Rng::new(303);
    for case in 0..CASES / 2 {
        let n = 1 + rng.below(60);
        let scheme = if rng.uniform() < 0.5 {
            Scheme::TopK { ratio: 0.05 + rng.uniform() * 0.5 }
        } else {
            Scheme::RandK { ratio: 0.05 + rng.uniform() * 0.5 }
        };
        let mut comp = UpdateCompressor::new(scheme, case as u64);
        let reference = SegmentParams {
            segment: "p".into(),
            tensors: vec![HostTensor::f32(vec![n], vec![0.0; n])],
        };
        for round in 0..4 {
            let update: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 2.0)).collect();
            let updated = SegmentParams {
                segment: "p".into(),
                tensors: vec![HostTensor::f32(vec![n], update.clone())],
            };
            let prev: Vec<f32> =
                comp.residual("p", 0).map(<[f32]>::to_vec).unwrap_or_else(|| vec![0.0; n]);
            let compressed = comp.compress_update(&[&reference], &[&updated]).unwrap();
            let sent = compressed[0].tensors[0].decompress().unwrap();
            let res = comp.residual("p", 0).expect("sparsifiers keep a residual");
            for i in 0..n {
                // Exact f32 equality (== so that a ±0.0 split still
                // passes): kept values travel bit-exact, dropped values
                // move to the residual untouched.
                let lhs = sent[i] + res[i];
                let rhs = update[i] + prev[i];
                assert!(lhs == rhs, "case {case} round {round} coord {i}: {lhs} != {rhs}");
            }
        }
    }
}

// ---------------------------------------------------------------- rng

#[test]
fn prop_forked_streams_are_decorrelated() {
    let mut root = Rng::new(110);
    let mut a = root.fork(1);
    let mut b = root.fork(2);
    let n = 4000;
    let xs: Vec<f64> = (0..n).map(|_| a.uniform()).collect();
    let ys: Vec<f64> = (0..n).map(|_| b.uniform()).collect();
    let mx = xs.iter().sum::<f64>() / n as f64;
    let my = ys.iter().sum::<f64>() / n as f64;
    let cov = xs.iter().zip(&ys).map(|(x, y)| (x - mx) * (y - my)).sum::<f64>() / n as f64;
    assert!(cov.abs() < 0.01, "cov {cov}");
}

// ---------------------------------------------------------------- sim clock

/// Legacy parity: a homogeneous SimClock (all slots online, infinite
/// device rate, shared link rate, no deadline) must reproduce the old
/// LinkClock arithmetic bit-for-bit over arbitrary charge sequences —
/// per-charge dt, per-slot elapsed, and round latency. Compute charges on
/// an infinite device must add exactly +0.0.
#[test]
fn prop_homogeneous_simclock_matches_linkclock_bit_for_bit() {
    use sfprompt::comm::NetworkModel;
    use sfprompt::federation::LinkClock;
    use sfprompt::sim::{SimClock, SlotProfile};

    let mut rng = Rng::new(411);
    for case in 0..CASES {
        let k = 1 + rng.below(8);
        let net = NetworkModel {
            rate_bytes_per_s: 100.0 + rng.uniform() * 5e7,
            sharing_clients: 1 + rng.below(10),
        };
        let mut legacy = LinkClock::new(net, k);
        let profiles: Vec<SlotProfile> = (0..k)
            .map(|slot| SlotProfile {
                client: slot,
                link_bytes_per_s: net.effective_rate(),
                device_flops_per_s: f64::INFINITY,
                slowdown: 1.0,
                online: true,
            })
            .collect();
        let mut sim = SimClock::new(profiles, None);

        for _ in 0..1 + rng.below(40) {
            let slot = rng.below(k);
            let bytes = rng.below(1 << 22);
            let dt_legacy = legacy.charge(slot, bytes);
            let dt_sim = sim.charge_transfer(slot, bytes);
            assert_eq!(
                dt_legacy.to_bits(),
                dt_sim.to_bits(),
                "case {case}: dt diverged for {bytes} B on slot {slot}"
            );
            // Interleaved compute on an infinite device is exactly free.
            assert_eq!(sim.charge_compute(slot, rng.next_u64() >> 20), 0.0);
        }
        for slot in 0..k {
            sim.mark_done(slot);
            assert_eq!(
                legacy.slot_s(slot).to_bits(),
                sim.slot_s(slot).to_bits(),
                "case {case}: slot {slot} elapsed diverged"
            );
        }
        let out = sim.finish();
        assert_eq!(
            legacy.round_latency_s().to_bits(),
            out.latency_s.to_bits(),
            "case {case}: round latency diverged"
        );
        assert_eq!(out.survivors.len(), k, "case {case}: homogeneous fleet never drops");
        assert_eq!(out.dropped(), 0);
    }
}

/// Deadline resolution invariants over random fleets: survivors are
/// exactly the marks within the effective deadline, at least
/// min(quorum, online) clients always survive, events cover every slot
/// once, and the latency is never below any survivor's elapsed time
/// (and equals the legacy max when nothing dropped).
#[test]
fn prop_deadline_resolution_invariants() {
    use sfprompt::sim::{ClientOutcome, DeadlinePolicy, SimClock, SlotProfile};

    let mut rng = Rng::new(412);
    for case in 0..CASES {
        let k = 1 + rng.below(10);
        let profiles: Vec<SlotProfile> = (0..k)
            .map(|slot| SlotProfile {
                client: 100 + slot,
                link_bytes_per_s: 10.0 + rng.uniform() * 1e4,
                device_flops_per_s: 1e6 + rng.uniform() * 1e9,
                slowdown: if rng.uniform() < 0.3 { 4.0 } else { 1.0 },
                online: rng.uniform() < 0.8,
            })
            .collect();
        let policy = DeadlinePolicy {
            deadline_s: 0.01 + rng.uniform() * 10.0,
            min_quorum: 1 + rng.below(k),
        };
        let mut clock = SimClock::new(profiles, Some(policy));
        let online: Vec<usize> = (0..k).filter(|&s| clock.online(s)).collect();
        for &slot in &online {
            for _ in 0..rng.below(5) {
                clock.charge_transfer(slot, rng.below(1 << 20));
                clock.charge_compute(slot, rng.next_u64() >> 40);
            }
            clock.mark_done(slot);
        }
        let out = clock.finish();

        assert_eq!(out.events.len(), k, "case {case}: one event per slot");
        let quorum = policy.min_quorum.min(online.len());
        assert!(
            out.survivors.len() >= quorum,
            "case {case}: quorum {quorum} violated ({} survivors)",
            out.survivors.len()
        );
        assert_eq!(out.survivors.len() + out.dropped(), k, "case {case}");
        for &slot in &out.survivors {
            assert!(clock.online(slot), "case {case}: offline survivor");
            assert!(
                out.latency_s >= clock.slot_s(slot) - 1e-12,
                "case {case}: latency below a survivor's elapsed time"
            );
        }
        // Done events are chronological.
        let done_times: Vec<f64> = out
            .events
            .iter()
            .filter(|e| e.outcome == ClientOutcome::Done)
            .map(|e| e.at_s)
            .collect();
        assert!(
            done_times.windows(2).all(|w| w[0] <= w[1]),
            "case {case}: done events out of order"
        );
    }
}

// ---------------------------------------------------------------- net envelope

/// A reader that delivers a byte stream in arbitrary caller-chosen chunk
/// sizes — TCP segmentation without a socket.
struct Segmented {
    data: Vec<u8>,
    pos: usize,
    sizes: Vec<usize>,
    next: usize,
}

impl std::io::Read for Segmented {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        if self.pos >= self.data.len() {
            return Ok(0);
        }
        let want = *self.sizes.get(self.next).unwrap_or(&usize::MAX);
        self.next += 1;
        let n = want.min(buf.len()).min(self.data.len() - self.pos).max(1);
        buf[..n].copy_from_slice(&self.data[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

#[test]
fn prop_net_stream_reassembles_any_message_mix_under_any_segmentation() {
    // Any interleaving of codec data frames and control messages on one
    // byte stream, delivered in adversarial chunk sizes, must come back
    // exactly — same messages, same order, same wire byte counts.
    use sfprompt::net::wire::{control_bytes, read_message};
    use sfprompt::net::{Control, NetMsg, NET_PROTO_VERSION};
    use sfprompt::transport::WIRE_VERSION;

    enum Expect {
        Frame(Frame, usize),
        Control(String, usize),
    }

    let mut rng = Rng::new(109);
    for case in 0..CASES / 4 {
        let n_msgs = 1 + rng.below(6);
        let mut stream = Vec::new();
        let mut expect = Vec::new();
        for _ in 0..n_msgs {
            if rng.uniform() < 0.5 {
                let frame = random_frame(&mut rng, 2.0);
                let wire = [WireFormat::F32, WireFormat::F16][rng.below(2)];
                let bytes = encode_frame(&frame, wire).unwrap();
                // The codec may transform the payload (f16, int8), so the
                // expectation is the decode of the exact encoded bytes.
                let decoded = decode_frame(&bytes).unwrap();
                expect.push(Expect::Frame(decoded, bytes.len()));
                stream.extend_from_slice(&bytes);
            } else {
                let c = match rng.below(4) {
                    0 => Control::Hello {
                        proto: NET_PROTO_VERSION,
                        wire: WIRE_VERSION,
                        name: format!("peer-{}", rng.below(100)),
                        run_id: format!("run-{}", rng.below(10)),
                        t0: f64::from_bits(rng.next_u64()),
                    },
                    1 => Control::Reject { reason: "no".repeat(rng.below(40)) },
                    2 => Control::RoundReport {
                        round: rng.below(1 << 16) as u32,
                        client: rng.below(1 << 10) as u32,
                        local_losses: (0..rng.below(5))
                            .map(|_| f64::from_bits(rng.next_u64()))
                            .collect(),
                        split_losses: (0..rng.below(5))
                            .map(|_| f64::from_bits(rng.next_u64()))
                            .collect(),
                    },
                    _ => Control::Shutdown { reason: "bye".into() },
                };
                let bytes = control_bytes(&c);
                expect.push(Expect::Control(c.to_json().to_string(), bytes.len()));
                stream.extend_from_slice(&bytes);
            }
        }
        // Adversarial segmentation: many tiny chunks, then whatever is left.
        let sizes: Vec<usize> = (0..rng.below(200)).map(|_| 1 + rng.below(7)).collect();
        let mut r = Segmented { data: stream, pos: 0, sizes, next: 0 };
        for (i, want) in expect.iter().enumerate() {
            let got = read_message(&mut r, false)
                .unwrap_or_else(|e| panic!("case {case} msg {i}: {e}"))
                .expect("idle_ok=false never yields None");
            match (got, want) {
                (NetMsg::Frame(f, n), Expect::Frame(wf, wn)) => {
                    assert_eq!(&f, wf, "case {case} msg {i}: frame mismatch");
                    assert_eq!(n, *wn, "case {case} msg {i}: frame byte count");
                }
                (NetMsg::Control(c, n), Expect::Control(wj, wn)) => {
                    assert_eq!(c.to_json().to_string(), *wj, "case {case} msg {i}");
                    assert_eq!(n, *wn, "case {case} msg {i}: control byte count");
                }
                (got, _) => panic!("case {case} msg {i}: kind flipped ({got:?})"),
            }
        }
        // Stream fully consumed: one more read is a clean Closed.
        assert!(read_message(&mut r, false).is_err(), "case {case}: trailing bytes");
    }
}

#[test]
fn prop_round_report_losses_roundtrip_bit_exact_through_the_envelope() {
    // Loss vectors ride the control plane as hex bit patterns; every f64 —
    // NaNs with payloads, infinities, subnormals, -0.0 — must survive the
    // envelope bit-for-bit (the loopback report equality depends on it).
    use sfprompt::net::wire::{control_bytes, read_message};
    use sfprompt::net::{Control, NetMsg};

    let mut rng = Rng::new(110);
    for case in 0..CASES {
        let weird = [
            0.0f64.to_bits(),
            (-0.0f64).to_bits(),
            f64::NAN.to_bits(),
            f64::NAN.to_bits() | 0xdead,
            f64::INFINITY.to_bits(),
            f64::NEG_INFINITY.to_bits(),
            f64::MIN_POSITIVE.to_bits(),
            1u64, // smallest subnormal
        ];
        let gen_bits = |rng: &mut Rng| {
            if rng.uniform() < 0.3 {
                weird[rng.below(weird.len())]
            } else {
                rng.next_u64()
            }
        };
        let local: Vec<u64> = (0..1 + rng.below(8)).map(|_| gen_bits(&mut rng)).collect();
        let split: Vec<u64> = (0..1 + rng.below(8)).map(|_| gen_bits(&mut rng)).collect();
        let c = Control::RoundReport {
            round: case as u32,
            client: rng.below(1 << 20) as u32,
            local_losses: local.iter().map(|&b| f64::from_bits(b)).collect(),
            split_losses: split.iter().map(|&b| f64::from_bits(b)).collect(),
        };
        let bytes = control_bytes(&c);
        let mut r = Segmented { data: bytes, pos: 0, sizes: vec![3; 4096], next: 0 };
        match read_message(&mut r, false).unwrap().unwrap() {
            NetMsg::Control(
                Control::RoundReport { local_losses, split_losses, .. },
                _,
            ) => {
                let got_local: Vec<u64> = local_losses.iter().map(|v| v.to_bits()).collect();
                let got_split: Vec<u64> = split_losses.iter().map(|v| v.to_bits()).collect();
                assert_eq!(got_local, local, "case {case}: local loss bits drifted");
                assert_eq!(got_split, split, "case {case}: split loss bits drifted");
            }
            other => panic!("case {case}: expected a round report, got {other:?}"),
        }
    }
}

// ---------------------------------------------------------------- ledger

#[test]
fn prop_ledger_reattributes_the_meter_bit_exactly() {
    // The communication-cost ledger is a RE-ATTRIBUTION of the ByteMeter's
    // measurements: feed both at the same sites with the same byte counts
    // (as every engine tap site does) and the per-kind row sums must equal
    // the meter's by_kind / raw_by_kind totals exactly — no tolerance.
    // Then a single missed tap must be caught by reconcile().
    use sfprompt::telemetry::Ledger;

    const KINDS: [MsgKind; 8] = [
        MsgKind::ModelDistribution,
        MsgKind::SmashedData,
        MsgKind::BodyOutput,
        MsgKind::GradBodyOut,
        MsgKind::GradSmashed,
        MsgKind::Upload,
        MsgKind::AggregateBroadcast,
        MsgKind::FullModel,
    ];
    let mut rng = Rng::new(111);
    for case in 0..CASES {
        let mut meter = ByteMeter::default();
        let mut ledger = Ledger::new();
        for _ in 0..1 + rng.below(120) {
            let kind = KINDS[rng.below(KINDS.len())];
            let dir =
                if rng.below(2) == 0 { Direction::Uplink } else { Direction::Downlink };
            let wire = rng.below(1 << 20);
            let raw = wire + rng.below(1 << 20);
            let (round, client) = (rng.below(8) as u32, rng.below(16) as u32);
            meter.record_with_raw(kind, dir, wire, raw);
            ledger.tap(round, client, kind, dir, wire, raw, rng.below(1000) as f64 * 1e-3);
            if rng.below(4) == 0 {
                ledger.tap_compute(round, client, 0.25);
            }
        }
        let (wire_sums, raw_sums) = ledger.by_kind_totals();
        assert_eq!(wire_sums, meter.by_kind, "case {case}: wire sums diverge");
        assert_eq!(raw_sums, meter.raw_by_kind, "case {case}: raw sums diverge");
        ledger.reconcile(&meter).unwrap_or_else(|e| panic!("case {case}: {e}"));

        // One meter record without its ledger tap — reconcile must refuse.
        meter.record(KINDS[rng.below(KINDS.len())], Direction::Uplink, 1 + rng.below(64));
        assert!(ledger.reconcile(&meter).is_err(), "case {case}: missed tap undetected");
    }
}

#[test]
fn prop_clock_messages_round_trip_ntp_legs_bit_exactly() {
    // The NTP handshake and re-estimation messages carry raw monotonic
    // timestamps; any rounding would corrupt the derived offset/RTT. Every
    // leg must survive the wire with its exact f64 bit pattern, including
    // weird values (subnormals, infinities, negative zero).
    use sfprompt::net::wire::{control_bytes, read_message};
    use sfprompt::net::{Control, NetMsg};

    let mut rng = Rng::new(112);
    for case in 0..CASES {
        let weird = [
            0.0f64.to_bits(),
            (-0.0f64).to_bits(),
            f64::INFINITY.to_bits(),
            f64::NEG_INFINITY.to_bits(),
            f64::MIN_POSITIVE.to_bits(),
            1u64,
        ];
        let mut gen = |rng: &mut Rng| {
            if rng.uniform() < 0.3 {
                weird[rng.below(weird.len())]
            } else {
                rng.next_u64()
            }
        };
        let legs = [gen(&mut rng), gen(&mut rng), gen(&mut rng)];
        let msgs = [
            Control::ClockProbe { t0: f64::from_bits(legs[0]) },
            Control::ClockReply {
                t0: f64::from_bits(legs[0]),
                t1: f64::from_bits(legs[1]),
                t2: f64::from_bits(legs[2]),
            },
            Control::RoundCtx { round: case as u32, parent: rng.next_u64() >> 11 },
        ];
        for msg in msgs {
            let bytes = control_bytes(&msg);
            let mut r = Segmented { data: bytes, pos: 0, sizes: vec![5; 4096], next: 0 };
            let got = match read_message(&mut r, false).unwrap().unwrap() {
                NetMsg::Control(c, _) => c,
                other => panic!("case {case}: expected control, got {other:?}"),
            };
            match (&msg, &got) {
                (Control::ClockProbe { t0: a }, Control::ClockProbe { t0: b }) => {
                    assert_eq!(a.to_bits(), b.to_bits(), "case {case}: probe t0 drifted");
                }
                (
                    Control::ClockReply { t0: a0, t1: a1, t2: a2 },
                    Control::ClockReply { t0: b0, t1: b1, t2: b2 },
                ) => {
                    assert_eq!(a0.to_bits(), b0.to_bits(), "case {case}: reply t0 drifted");
                    assert_eq!(a1.to_bits(), b1.to_bits(), "case {case}: reply t1 drifted");
                    assert_eq!(a2.to_bits(), b2.to_bits(), "case {case}: reply t2 drifted");
                }
                (
                    Control::RoundCtx { round: ra, parent: pa },
                    Control::RoundCtx { round: rb, parent: pb },
                ) => {
                    assert_eq!((ra, pa), (rb, pb), "case {case}: round context drifted");
                }
                (sent, got) => panic!("case {case}: kind changed: {sent:?} -> {got:?}"),
            }
        }
    }
}
