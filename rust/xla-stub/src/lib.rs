//! Offline stand-in for the `xla` crate (PJRT bindings).
//!
//! The coordinator crate compiles against the `xla` 0.1.6 API surface
//! (`PjRtClient::cpu` → `HloModuleProto::from_text_file` → `compile` →
//! `execute`), but the offline crate registry does not carry `xla` and the
//! CI image carries no `xla_extension` shared library. This crate is wired
//! in under the dependency name `xla` (see `rust/Cargo.toml`) and provides:
//!
//! * a **functional** host-side [`Literal`]: `scalar` / `vec1` / `reshape` /
//!   `to_vec` really work, so everything that only moves tensors around
//!   (parameter init, the transport codec, `segment_literals`) runs for real;
//! * **erroring** execution entry points: `HloModuleProto::from_text_file`,
//!   `PjRtClient::compile`, and `PjRtLoadedExecutable::execute` return a
//!   clear "built without PJRT" error instead of linking native code.
//!
//! Enabling the `pjrt` cargo feature is reserved for environments where the
//! real bindings are available; today it only sharpens the error message.
//!
//! Unlike the real bindings (which hold `Rc` handles into the PJRT runtime),
//! every type here is plain data and therefore `Send + Sync` — which is what
//! lets the coordinator share an `ArtifactStore` across per-client threads.

use std::fmt;
use std::path::Path;

/// Crate-local result alias, mirroring the real bindings.
pub type Result<T> = std::result::Result<T, Error>;

/// Error type compatible with `anyhow::Context` (implements
/// `std::error::Error + Send + Sync + 'static`).
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn new(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

fn no_pjrt(what: &str) -> Error {
    if cfg!(feature = "pjrt") {
        Error::new(format!(
            "{what}: the `pjrt` feature is enabled but this build carries no \
             PJRT backend (the offline registry has no `xla` crate)"
        ))
    } else {
        Error::new(format!(
            "{what}: built without the `pjrt` feature — stage execution is \
             unavailable; manifest/codec/analysis paths work without it"
        ))
    }
}

/// Host-side element buffer. Public only so [`NativeType`] can name it;
/// treat as an implementation detail.
#[doc(hidden)]
#[derive(Debug, Clone, PartialEq)]
pub enum Buf {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Buf {
    fn len(&self) -> usize {
        match self {
            Buf::F32(v) => v.len(),
            Buf::I32(v) => v.len(),
        }
    }
}

/// Element types a [`Literal`] can hold (the coordinator only uses f32/i32).
pub trait NativeType: Copy + Sized {
    #[doc(hidden)]
    fn into_buf(v: Vec<Self>) -> Buf;
    #[doc(hidden)]
    fn from_buf(b: &Buf) -> Option<Vec<Self>>;
    #[doc(hidden)]
    fn type_name() -> &'static str;
}

impl NativeType for f32 {
    fn into_buf(v: Vec<Self>) -> Buf {
        Buf::F32(v)
    }
    fn from_buf(b: &Buf) -> Option<Vec<Self>> {
        match b {
            Buf::F32(v) => Some(v.clone()),
            Buf::I32(_) => None,
        }
    }
    fn type_name() -> &'static str {
        "f32"
    }
}

impl NativeType for i32 {
    fn into_buf(v: Vec<Self>) -> Buf {
        Buf::I32(v)
    }
    fn from_buf(b: &Buf) -> Option<Vec<Self>> {
        match b {
            Buf::I32(v) => Some(v.clone()),
            Buf::F32(_) => None,
        }
    }
    fn type_name() -> &'static str {
        "i32"
    }
}

/// A host-resident dense literal (shape + elements). Fully functional.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    buf: Buf,
}

impl Literal {
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal { dims: vec![], buf: T::into_buf(vec![v]) }
    }

    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { dims: vec![v.len() as i64], buf: T::into_buf(v.to_vec()) }
    }

    pub fn element_count(&self) -> usize {
        self.buf.len()
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Reinterpret with new dimensions; the element count must match.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n < 0 || n as usize != self.element_count() {
            return Err(Error::new(format!(
                "reshape to {:?} ({n} elements) from a literal of {} elements",
                dims,
                self.element_count()
            )));
        }
        Ok(Literal { dims: dims.to_vec(), buf: self.buf.clone() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::from_buf(&self.buf).ok_or_else(|| {
            Error::new(format!("literal does not hold {} elements", T::type_name()))
        })
    }

    /// Decompose a tuple literal. Only PJRT executions produce tuples, so
    /// this always errors in the offline build.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(no_pjrt("Literal::to_tuple"))
    }
}

/// Parsed HLO module (execution-side; unavailable offline).
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        Err(no_pjrt(&format!(
            "HloModuleProto::from_text_file({})",
            path.as_ref().display()
        )))
    }
}

/// An XLA computation handle (execution-side; unavailable offline).
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// PJRT client handle. Construction succeeds (it is just a handle) so that
/// manifest-level tooling works; compilation/execution error cleanly.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _priv: () })
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(no_pjrt("PjRtClient::compile"))
    }
}

/// Compiled executable handle (unavailable offline).
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(no_pjrt("PjRtLoadedExecutable::execute"))
    }
}

/// Device buffer handle (unavailable offline).
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(no_pjrt("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(r.to_vec::<i32>().is_err());
        assert!(l.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn scalar_literals() {
        let f = Literal::scalar(0.5f32);
        assert!(f.dims().is_empty());
        assert_eq!(f.to_vec::<f32>().unwrap(), vec![0.5]);
        let i = Literal::scalar(7i32);
        assert_eq!(i.to_vec::<i32>().unwrap(), vec![7]);
    }

    #[test]
    fn execution_paths_error_cleanly() {
        assert!(HloModuleProto::from_text_file("nope.hlo.txt").is_err());
        let client = PjRtClient::cpu().unwrap();
        let comp = XlaComputation { _priv: () };
        assert!(client.compile(&comp).is_err());
    }
}
