//! Telemetry overhead guard.
//!
//! Three claims back docs/TELEMETRY.md's "free when off" statement, and
//! this bench enforces the first two as hard assertions (it aborts the
//! bench run if violated, so CI-style bench invocations catch
//! regressions):
//!
//! 1. **Zero allocations on the disabled path.** A counting global
//!    allocator wraps `System`; a tight loop of `telemetry::active()`
//!    calls with no sink installed must not allocate at all.
//! 2. **Zero allocations on the flight-recorder record path.** The
//!    always-on post-mortem ring (docs/OPS.md) writes into pre-allocated
//!    fixed-size slots; a tight `record()` loop spanning many ring wraps
//!    must not allocate either.
//! 3. **Negligible stage-loop overhead.** The same native stage loop is
//!    timed with telemetry disabled and enabled, so the cost of spans +
//!    histogram observations on the hot path is a printed measurement,
//!    not folklore.

#[path = "harness.rs"]
mod harness;

use std::alloc::{GlobalAlloc, Layout, System};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use harness::Bench;
use sfprompt::backend::{run_stage_hosts, Backend, NativeBackend};
use sfprompt::data::{make_batch, synth, SynthDataset};
use sfprompt::model::init_params;
use sfprompt::runtime::HostTensor;
use sfprompt::telemetry::{self, FlightRecorder, Telemetry};

/// Counts allocation events (alloc + realloc) while `COUNTING` is set;
/// delegates everything to `System`.
struct CountingAlloc;

static COUNTING: AtomicBool = AtomicBool::new(false);
static ALLOC_EVENTS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn assert_disabled_path_is_allocation_free() {
    assert!(telemetry::active().is_none(), "bench must start with no sink installed");
    const CALLS: u64 = 1_000_000;
    COUNTING.store(true, Ordering::SeqCst);
    let before = ALLOC_EVENTS.load(Ordering::SeqCst);
    for _ in 0..CALLS {
        // The hook prologue every hot path runs when telemetry is off.
        if telemetry::active().is_some() {
            unreachable!("no sink installed");
        }
    }
    let delta = ALLOC_EVENTS.load(Ordering::SeqCst) - before;
    COUNTING.store(false, Ordering::SeqCst);
    assert_eq!(
        delta, 0,
        "disabled telemetry::active() allocated {delta} times in {CALLS} calls"
    );
    println!("disabled path: 0 allocations across {CALLS} active() calls");
}

fn assert_flight_record_is_allocation_free() {
    let ring = FlightRecorder::with_capacity(1024);
    const CALLS: u64 = 1_000_000;
    COUNTING.store(true, Ordering::SeqCst);
    let before = ALLOC_EVENTS.load(Ordering::SeqCst);
    for i in 0..CALLS {
        // ~977 full ring wraps: steady-state overwrite, not just fill.
        ring.record("bench", "flight-alloc-guard-entry", i as f64, 1.0, 2.0);
    }
    let delta = ALLOC_EVENTS.load(Ordering::SeqCst) - before;
    COUNTING.store(false, Ordering::SeqCst);
    assert_eq!(
        delta, 0,
        "FlightRecorder::record allocated {delta} times in {CALLS} calls"
    );
    assert_eq!(ring.recorded(), CALLS, "every record() call must land");
    println!("flight ring:   0 allocations across {CALLS} record() calls");
}

fn stage_loop(backend: &dyn Backend, iters: usize) {
    let cfg = backend.manifest().config.clone();
    let params = init_params(backend.manifest(), 7);
    let mut profile = synth::profile("cifar10").unwrap();
    profile.num_classes = cfg.num_classes;
    let ds = SynthDataset::generate(profile, cfg.image_size, cfg.channels, cfg.batch, 1, 2);
    let idx: Vec<usize> = (0..cfg.batch).collect();
    let batch = make_batch(&ds.examples, &idx, cfg.batch, cfg.image_size, cfg.channels);
    let mut segs: BTreeMap<&str, &sfprompt::model::SegmentParams> = BTreeMap::new();
    segs.insert("head", params.get("head").unwrap());
    segs.insert("prompt", params.get("prompt").unwrap());
    let mut tensors: BTreeMap<&str, &HostTensor> = BTreeMap::new();
    tensors.insert("images", &batch.images);
    for _ in 0..iters {
        run_stage_hosts(backend, "head_forward", &segs, &tensors).unwrap();
    }
}

fn main() {
    println!("telemetry overhead benches");
    assert_disabled_path_is_allocation_free();
    assert_flight_record_is_allocation_free();

    let backend = NativeBackend::for_config("tiny").unwrap();
    backend.warm(&["head_forward"]).unwrap();

    Bench::new("stage_loop/telemetry_off (10x head_forward)").run(|| {
        stage_loop(&backend, 10);
    });

    let sink = Arc::new(Telemetry::new());
    telemetry::install(sink.clone());
    Bench::new("stage_loop/telemetry_on  (10x head_forward)").run(|| {
        stage_loop(&backend, 10);
    });
    telemetry::uninstall();
    sink.tracer.finish();
    println!(
        "enabled run recorded {} stage observations",
        sink.metrics.histogram_count("stage_s/head_forward")
    );
}
