//! Minimal bench harness (substrate — no `criterion` in the offline
//! registry). Mirrors criterion's reporting shape: warm-up, N timed
//! iterations, mean / stddev / p50 / p95 per benchmark, plus a free-form
//! throughput annotation.
//!
//! Used by all `cargo bench` targets via `#[path = "harness.rs"] mod ...`.
//!
//! Set `SFPROMPT_BENCH_JSON=path` to additionally append one JSON line per
//! finished benchmark to `path` — the machine-readable feed
//! `scripts/bench_snapshot` normalizes into `BENCH_*.json` snapshots.

use std::time::Instant;

use sfprompt::util::json::Json;

pub struct Bench {
    pub name: String,
    samples: usize,
    warmup: usize,
}

pub struct BenchReport {
    pub name: String,
    pub mean_ms: f64,
    pub std_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub samples: usize,
}

impl Bench {
    pub fn new(name: &str) -> Bench {
        // SFPROMPT_BENCH_SAMPLES=n overrides for quick smoke runs.
        let samples = std::env::var("SFPROMPT_BENCH_SAMPLES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(12);
        Bench { name: name.to_string(), samples, warmup: 2 }
    }

    pub fn samples(mut self, n: usize) -> Bench {
        self.samples = n;
        self
    }

    pub fn run<F: FnMut()>(self, mut f: F) -> BenchReport {
        for _ in 0..self.warmup {
            f();
        }
        let mut times = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>()
            / (times.len() - 1).max(1) as f64;
        let mut sorted = times.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q = |p: f64| sorted[((p * (sorted.len() - 1) as f64).round() as usize).min(sorted.len() - 1)];
        let report = BenchReport {
            name: self.name,
            mean_ms: mean,
            std_ms: var.sqrt(),
            p50_ms: q(0.5),
            p95_ms: q(0.95),
            samples: self.samples,
        };
        println!(
            "{:<46} mean {:>9.3} ms  ±{:>7.3}  p50 {:>9.3}  p95 {:>9.3}  (n={})",
            report.name, report.mean_ms, report.std_ms, report.p50_ms, report.p95_ms,
            report.samples
        );
        if let Ok(path) = std::env::var("SFPROMPT_BENCH_JSON") {
            if let Err(e) = append_json_line(&path, &report) {
                eprintln!("warning: SFPROMPT_BENCH_JSON={path}: {e}");
            }
        }
        report
    }
}

/// One JSON line per report, appended (benches in one target share a file).
fn append_json_line(path: &str, r: &BenchReport) -> std::io::Result<()> {
    use std::io::Write;
    let mut o = std::collections::BTreeMap::new();
    o.insert("name".to_string(), Json::Str(r.name.clone()));
    o.insert("mean_ms".to_string(), Json::Num(r.mean_ms));
    o.insert("std_ms".to_string(), Json::Num(r.std_ms));
    o.insert("p50_ms".to_string(), Json::Num(r.p50_ms));
    o.insert("p95_ms".to_string(), Json::Num(r.p95_ms));
    o.insert("samples".to_string(), Json::Num(r.samples as f64));
    let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
    writeln!(f, "{}", Json::Obj(o))
}

/// Print a derived-throughput line under a report.
pub fn throughput(report: &BenchReport, unit: &str, per_iter: f64) {
    let per_s = per_iter / (report.mean_ms / 1e3);
    println!("{:<46}   -> {:.1} {unit}/s", "", per_s);
}
