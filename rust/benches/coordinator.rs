//! Coordinator benchmarks — one per paper table/figure family, all driven
//! through the unified run API (`RunBuilder` → `drive`):
//!
//! * table2/table3: full global round per method (FL / SFL+FF / SFPrompt)
//! * fig6: SFPrompt without Phase 1 (ablation cost structure)
//! * fig7: pruning throughput at several retain fractions

#[path = "harness.rs"]
mod harness;

use harness::Bench;
use sfprompt::backend::{Backend, NativeBackend};
use sfprompt::data::{synth, SynthDataset};
use sfprompt::federation::{drive, FedConfig, Method, NullObserver, RunBuilder, Selection};
use sfprompt::partition::Partition;

fn fed(rounds: usize) -> FedConfig {
    FedConfig {
        num_clients: 10,
        clients_per_round: 2,
        local_epochs: 2,
        rounds,
        lr: 0.08,
        retain_fraction: 0.4,
        local_loss_update: true,
        partition: Partition::Iid,
        seed: 5,
        eval_limit: None,
        eval_every: usize::MAX,
        selection: Selection::Uniform,
        wire: sfprompt::transport::WireFormat::F32,
        compress: sfprompt::compress::Scheme::None,
    }
}

fn main() {
    let backend = NativeBackend::tiny();
    let cfg = backend.manifest().config.clone();
    let mut profile = synth::profile("cifar10").unwrap();
    profile.num_classes = cfg.num_classes;
    let train = SynthDataset::generate(profile, cfg.image_size, cfg.channels, 10 * 16, 1, 2);

    println!("coordinator benches (tiny config, K=2, U=2, 16 samples/client)");

    let one_round = |f: FedConfig, method: Method| {
        let mut run = RunBuilder::new(method).fed(f).build(&backend, &train, None).unwrap();
        drive(run.as_mut(), &mut NullObserver).unwrap();
    };

    // --- global round per method (tables 2/3 shape) ---
    for method in [Method::SfPrompt, Method::Fl, Method::SflFullFinetune, Method::SflLinear] {
        let f = fed(1);
        let r = Bench::new(&format!("round/{}", method.label()))
            .samples(6)
            .run(|| one_round(f, method));
        harness::throughput(&r, "rounds", 1.0);
    }

    // --- SFPrompt without Phase 1 (fig6 ablation cost structure) ---
    {
        let f = FedConfig { local_loss_update: false, ..fed(1) };
        Bench::new("round/sfprompt_wo_phase1 (fig6 ablation)")
            .samples(6)
            .run(|| one_round(f, Method::SfPrompt));
    }

    // --- pruning fractions (fig7 cost structure) ---
    for retain in [1.0, 0.4, 0.2] {
        let f = FedConfig { retain_fraction: retain, ..fed(1) };
        Bench::new(&format!("round/sfprompt_retain_{retain}"))
            .samples(6)
            .run(|| one_round(f, Method::SfPrompt));
    }
}
