//! Stage-execution benchmarks (the hot path behind every experiment):
//! per-stage time on the `tiny` and `small` configs, for every substrate
//! that can execute on this machine — the native kernel engine always,
//! the PJRT artifact path when artifacts + the `pjrt` feature are
//! present (probed with one head_forward call; skipped cleanly offline).
//!
//! Backs Table 2's computational-burden column with measured per-stage
//! times, and is the L3 profile used in EXPERIMENTS.md §Perf.

#[path = "harness.rs"]
mod harness;

use std::collections::BTreeMap;

use harness::Bench;
use sfprompt::backend::{run_stage_hosts, Backend, NativeBackend, PjrtBackend};
use sfprompt::data::{make_batch, synth, SynthDataset};
use sfprompt::model::{init_params, ParamSet, SegmentParams};
use sfprompt::runtime::HostTensor;

fn bench_backend(backend: &dyn Backend, label: &str) {
    let cfg = backend.manifest().config.clone();
    let params = init_params(backend.manifest(), 7);
    let mut profile = synth::profile("cifar10").unwrap();
    profile.num_classes = cfg.num_classes;
    let ds = SynthDataset::generate(profile, cfg.image_size, cfg.channels, cfg.batch, 1, 2);
    let idx: Vec<usize> = (0..cfg.batch).collect();
    let batch = make_batch(&ds.examples, &idx, cfg.batch, cfg.image_size, cfg.channels);
    let lr = HostTensor::scalar_f32(0.05);

    // A nested fn (not a closure): the returned map borrows from `params`,
    // which closure lifetime elision cannot express.
    fn seg<'a>(
        params: &'a ParamSet,
        names: &[&'static str],
    ) -> BTreeMap<&'static str, &'a SegmentParams> {
        names.iter().map(|&n| (n, params.get(n).unwrap())).collect()
    }

    // Probe: one head_forward decides whether this substrate can execute
    // here at all (PJRT without artifacts/feature errors cleanly).
    let probe = {
        let segs = seg(&params, &["head", "prompt"]);
        let mut tensors: BTreeMap<&str, &HostTensor> = BTreeMap::new();
        tensors.insert("images", &batch.images);
        run_stage_hosts(backend, "head_forward", &segs, &tensors)
    };
    let smashed = match probe {
        Ok(mut out) => out.tensors.remove("smashed").unwrap(),
        Err(e) => {
            eprintln!("skipping {label}: {e:#}");
            return;
        }
    };
    println!(
        "\n== {label} (dim={} seq={} batch={}) ==",
        cfg.dim, cfg.seq_len, cfg.batch
    );

    {
        let segs = seg(&params, &["head", "prompt"]);
        let mut tensors: BTreeMap<&str, &HostTensor> = BTreeMap::new();
        tensors.insert("images", &batch.images);
        backend.warm(&["head_forward"]).unwrap();
        Bench::new(&format!("{label}/head_forward")).run(|| {
            run_stage_hosts(backend, "head_forward", &segs, &tensors).unwrap();
        });
    }
    let body_out = {
        let segs = seg(&params, &["body"]);
        let mut tensors: BTreeMap<&str, &HostTensor> = BTreeMap::new();
        tensors.insert("smashed", &smashed);
        backend.warm(&["body_forward"]).unwrap();
        let mut last = None;
        Bench::new(&format!("{label}/body_forward")).run(|| {
            last = Some(run_stage_hosts(backend, "body_forward", &segs, &tensors).unwrap());
        });
        last.unwrap().tensors.remove("body_out").unwrap()
    };
    {
        let segs = seg(&params, &["tail"]);
        let mut tensors: BTreeMap<&str, &HostTensor> = BTreeMap::new();
        tensors.insert("body_out", &body_out);
        tensors.insert("labels", &batch.labels);
        tensors.insert("lr", &lr);
        backend.warm(&["tail_step"]).unwrap();
        Bench::new(&format!("{label}/tail_step")).run(|| {
            run_stage_hosts(backend, "tail_step", &segs, &tensors).unwrap();
        });
    }
    {
        let segs = seg(&params, &["body"]);
        let mut tensors: BTreeMap<&str, &HostTensor> = BTreeMap::new();
        tensors.insert("smashed", &smashed);
        tensors.insert("g_body_out", &body_out); // same shape, fine for timing
        backend.warm(&["body_backward"]).unwrap();
        Bench::new(&format!("{label}/body_backward")).run(|| {
            run_stage_hosts(backend, "body_backward", &segs, &tensors).unwrap();
        });
    }
    {
        let segs = seg(&params, &["head", "tail", "prompt"]);
        let mut tensors: BTreeMap<&str, &HostTensor> = BTreeMap::new();
        tensors.insert("images", &batch.images);
        tensors.insert("labels", &batch.labels);
        tensors.insert("lr", &lr);
        backend.warm(&["local_step"]).unwrap();
        let r = Bench::new(&format!("{label}/local_step (phase-1 SGD)")).run(|| {
            run_stage_hosts(backend, "local_step", &segs, &tensors).unwrap();
        });
        harness::throughput(&r, "samples", cfg.batch as f64);
    }
    {
        let segs = seg(&params, &["head", "tail", "prompt"]);
        let mut tensors: BTreeMap<&str, &HostTensor> = BTreeMap::new();
        tensors.insert("images", &batch.images);
        tensors.insert("labels", &batch.labels);
        backend.warm(&["el2n_scores"]).unwrap();
        Bench::new(&format!("{label}/el2n_scores (pruning)")).run(|| {
            run_stage_hosts(backend, "el2n_scores", &segs, &tensors).unwrap();
        });
    }
    {
        let segs = seg(&params, &["head", "body", "tail"]);
        let mut tensors: BTreeMap<&str, &HostTensor> = BTreeMap::new();
        tensors.insert("images", &batch.images);
        tensors.insert("labels", &batch.labels);
        tensors.insert("lr", &lr);
        backend.warm(&["full_step"]).unwrap();
        let r = Bench::new(&format!("{label}/full_step (FL baseline)")).run(|| {
            run_stage_hosts(backend, "full_step", &segs, &tensors).unwrap();
        });
        harness::throughput(&r, "samples", cfg.batch as f64);
    }
}

fn main() {
    println!("stage-execution benches (native kernels; PJRT when available)");
    for config in ["tiny", "small"] {
        let native = NativeBackend::for_config(config).unwrap();
        bench_backend(&native, &format!("native/{config}"));
        match PjrtBackend::open(&sfprompt::artifacts_root(), config) {
            Ok(pjrt) => bench_backend(&pjrt, &format!("pjrt/{config}")),
            Err(e) => eprintln!("skipping pjrt/{config}: {e:#}"),
        }
    }
}
