//! Stage-execution benchmarks (the hot path behind every experiment):
//! per-stage PJRT execution time on the `tiny` and `small` configs.
//!
//! Backs Table 2's computational-burden column with measured per-stage
//! times, and is the L3 profile used in EXPERIMENTS.md §Perf.

#[path = "harness.rs"]
mod harness;

use std::collections::BTreeMap;

use harness::Bench;
use sfprompt::data::{make_batch, synth, SynthDataset};
use sfprompt::model::{init_params, SegmentParams};
use sfprompt::runtime::{ArtifactStore, Executor, HostTensor, TensorInputs};

fn bench_config(config: &str) {
    let store = match ArtifactStore::open(&sfprompt::artifacts_root(), config) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("skipping {config}: {e:#} (run `make artifacts` first)");
            return;
        }
    };
    let cfg = store.manifest.config.clone();
    let params = init_params(&store.manifest, 7);
    let mut profile = synth::profile("cifar10").unwrap();
    profile.num_classes = cfg.num_classes;
    let ds = SynthDataset::generate(profile, cfg.image_size, cfg.channels, cfg.batch, 1, 2);
    let idx: Vec<usize> = (0..cfg.batch).collect();
    let batch = make_batch(&ds.examples, &idx, cfg.batch, cfg.image_size, cfg.channels);
    let lr = HostTensor::scalar_f32(0.05);

    println!("\n== config {config} (dim={} seq={} batch={}) ==", cfg.dim, cfg.seq_len, cfg.batch);

    fn seg<'a>(
        params: &'a sfprompt::model::ParamSet,
        names: &[&'static str],
    ) -> BTreeMap<&'static str, &'a SegmentParams> {
        names.iter().map(|n| (*n, params.get(n).unwrap())).collect()
    }
    let seg = |names: &[&'static str]| seg(&params, names);

    // head_forward
    {
        let segs = seg(&["head", "prompt"]);
        let mut tensors: TensorInputs = BTreeMap::new();
        tensors.insert("images", &batch.images);
        store.warm(&["head_forward"]).unwrap();
        Bench::new(&format!("{config}/head_forward")).run(|| {
            Executor::run(&store, "head_forward", &segs, &tensors).unwrap();
        });
    }
    // body_forward + body_backward need a smashed tensor
    let smashed = {
        let segs = seg(&["head", "prompt"]);
        let mut tensors: TensorInputs = BTreeMap::new();
        tensors.insert("images", &batch.images);
        let out = Executor::run(&store, "head_forward", &segs, &tensors).unwrap();
        out.tensors.into_iter().find(|(k, _)| k == "smashed").unwrap().1
    };
    {
        let segs = seg(&["body"]);
        let mut tensors: TensorInputs = BTreeMap::new();
        tensors.insert("smashed", &smashed);
        store.warm(&["body_forward"]).unwrap();
        Bench::new(&format!("{config}/body_forward")).run(|| {
            Executor::run(&store, "body_forward", &segs, &tensors).unwrap();
        });
    }
    let body_out = {
        let segs = seg(&["body"]);
        let mut tensors: TensorInputs = BTreeMap::new();
        tensors.insert("smashed", &smashed);
        let mut out = Executor::run(&store, "body_forward", &segs, &tensors).unwrap();
        out.tensors.remove("body_out").unwrap()
    };
    {
        let segs = seg(&["tail"]);
        let mut tensors: TensorInputs = BTreeMap::new();
        tensors.insert("body_out", &body_out);
        tensors.insert("labels", &batch.labels);
        tensors.insert("lr", &lr);
        store.warm(&["tail_step"]).unwrap();
        Bench::new(&format!("{config}/tail_step")).run(|| {
            Executor::run(&store, "tail_step", &segs, &tensors).unwrap();
        });
    }
    {
        let segs = seg(&["body"]);
        let mut tensors: TensorInputs = BTreeMap::new();
        tensors.insert("smashed", &smashed);
        tensors.insert("g_body_out", &body_out); // same shape, fine for timing
        store.warm(&["body_backward"]).unwrap();
        Bench::new(&format!("{config}/body_backward")).run(|| {
            Executor::run(&store, "body_backward", &segs, &tensors).unwrap();
        });
    }
    {
        let segs = seg(&["head", "tail", "prompt"]);
        let mut tensors: TensorInputs = BTreeMap::new();
        tensors.insert("images", &batch.images);
        tensors.insert("labels", &batch.labels);
        tensors.insert("lr", &lr);
        store.warm(&["local_step"]).unwrap();
        let r = Bench::new(&format!("{config}/local_step (phase-1 SGD)")).run(|| {
            Executor::run(&store, "local_step", &segs, &tensors).unwrap();
        });
        harness::throughput(&r, "samples", cfg.batch as f64);
    }
    {
        let segs = seg(&["head", "tail", "prompt"]);
        let mut tensors: TensorInputs = BTreeMap::new();
        tensors.insert("images", &batch.images);
        tensors.insert("labels", &batch.labels);
        store.warm(&["el2n_scores"]).unwrap();
        Bench::new(&format!("{config}/el2n_scores (pruning)")).run(|| {
            Executor::run(&store, "el2n_scores", &segs, &tensors).unwrap();
        });
    }
    {
        let segs = seg(&["head", "body", "tail"]);
        let mut tensors: TensorInputs = BTreeMap::new();
        tensors.insert("images", &batch.images);
        tensors.insert("labels", &batch.labels);
        tensors.insert("lr", &lr);
        store.warm(&["full_step"]).unwrap();
        let r = Bench::new(&format!("{config}/full_step (FL baseline)")).run(|| {
            Executor::run(&store, "full_step", &segs, &tensors).unwrap();
        });
        harness::throughput(&r, "samples", cfg.batch as f64);
    }
}

fn main() {
    println!("stage-execution benches (PJRT CPU, interpret-lowered Pallas)");
    bench_config("tiny");
    bench_config("small");
}
