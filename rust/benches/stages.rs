//! Stage-execution benchmarks (the hot path behind every experiment):
//! per-stage time on the `tiny` and `small` configs, for every substrate
//! that can execute on this machine — the native kernel engine always,
//! the PJRT artifact path when artifacts + the `pjrt` feature are
//! present (probed with one head_forward call; skipped cleanly offline).
//!
//! Backs Table 2's computational-burden column with measured per-stage
//! times, and is the L3 profile used in EXPERIMENTS.md §Perf. Also emits
//! `kernel/*` rows comparing the blocked GEMM against the scalar
//! reference (`math::reference`) across pool thread counts — the speedup
//! story recorded in BENCH_stages.json (docs/PERF.md).

#[path = "harness.rs"]
mod harness;

use std::collections::BTreeMap;

use harness::Bench;
use sfprompt::backend::native::{math, pool};
use sfprompt::backend::{run_stage_hosts, Backend, NativeBackend, PjrtBackend};
use sfprompt::data::{make_batch, synth, SynthDataset};
use sfprompt::model::{init_params, ParamSet, SegmentParams};
use sfprompt::runtime::HostTensor;
use sfprompt::util::rng::Rng;

/// Blocked-vs-scalar GEMM comparison at ViT-typical shapes, plus a thread
/// sweep over the pooled blocked kernel. These are the microkernels behind
/// every stage time below; the `scalar` rows are the pre-blocking baseline
/// (`math::reference`), kept as the speedup denominator in BENCH_stages.
fn bench_kernels() {
    println!("\n== kernels: blocked vs scalar reference ==");
    // (label, m, k, n): token-rows × dim GEMMs as the attention/MLP
    // projections see them on the `small` config, plus the skinny
    // classifier head.
    let shapes: [(&str, usize, usize, usize); 3] = [
        ("qkv 256x128x384", 256, 128, 384),
        ("mlp 256x128x512", 256, 128, 512),
        ("logits 64x128x10", 64, 128, 10),
    ];
    let mut rng = Rng::new(3);
    let mut sink = 0.0f32;
    for (label, m, k, n) in shapes {
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        pool::set_threads(1);
        Bench::new(&format!("kernel/scalar/{label}")).run(|| {
            sink += math::reference::matmul(&a, &b, m, k, n)[0];
        });
        for threads in [1usize, 2, 4] {
            pool::set_threads(threads);
            Bench::new(&format!("kernel/blocked-{threads}t/{label}")).run(|| {
                sink += math::matmul(&a, &b, m, k, n)[0];
            });
        }
        pool::set_threads(0);
    }
    assert!(sink.is_finite());
}

fn bench_backend(backend: &dyn Backend, label: &str) {
    let cfg = backend.manifest().config.clone();
    let params = init_params(backend.manifest(), 7);
    let mut profile = synth::profile("cifar10").unwrap();
    profile.num_classes = cfg.num_classes;
    let ds = SynthDataset::generate(profile, cfg.image_size, cfg.channels, cfg.batch, 1, 2);
    let idx: Vec<usize> = (0..cfg.batch).collect();
    let batch = make_batch(&ds.examples, &idx, cfg.batch, cfg.image_size, cfg.channels);
    let lr = HostTensor::scalar_f32(0.05);

    // A nested fn (not a closure): the returned map borrows from `params`,
    // which closure lifetime elision cannot express.
    fn seg<'a>(
        params: &'a ParamSet,
        names: &[&'static str],
    ) -> BTreeMap<&'static str, &'a SegmentParams> {
        names.iter().map(|&n| (n, params.get(n).unwrap())).collect()
    }

    // Probe: one head_forward decides whether this substrate can execute
    // here at all (PJRT without artifacts/feature errors cleanly).
    let probe = {
        let segs = seg(&params, &["head", "prompt"]);
        let mut tensors: BTreeMap<&str, &HostTensor> = BTreeMap::new();
        tensors.insert("images", &batch.images);
        run_stage_hosts(backend, "head_forward", &segs, &tensors)
    };
    let smashed = match probe {
        Ok(mut out) => out.tensors.remove("smashed").unwrap(),
        Err(e) => {
            eprintln!("skipping {label}: {e:#}");
            return;
        }
    };
    println!(
        "\n== {label} (dim={} seq={} batch={}) ==",
        cfg.dim, cfg.seq_len, cfg.batch
    );

    {
        let segs = seg(&params, &["head", "prompt"]);
        let mut tensors: BTreeMap<&str, &HostTensor> = BTreeMap::new();
        tensors.insert("images", &batch.images);
        backend.warm(&["head_forward"]).unwrap();
        Bench::new(&format!("{label}/head_forward")).run(|| {
            run_stage_hosts(backend, "head_forward", &segs, &tensors).unwrap();
        });
    }
    let body_out = {
        let segs = seg(&params, &["body"]);
        let mut tensors: BTreeMap<&str, &HostTensor> = BTreeMap::new();
        tensors.insert("smashed", &smashed);
        backend.warm(&["body_forward"]).unwrap();
        let mut last = None;
        Bench::new(&format!("{label}/body_forward")).run(|| {
            last = Some(run_stage_hosts(backend, "body_forward", &segs, &tensors).unwrap());
        });
        last.unwrap().tensors.remove("body_out").unwrap()
    };
    {
        let segs = seg(&params, &["tail"]);
        let mut tensors: BTreeMap<&str, &HostTensor> = BTreeMap::new();
        tensors.insert("body_out", &body_out);
        tensors.insert("labels", &batch.labels);
        tensors.insert("lr", &lr);
        backend.warm(&["tail_step"]).unwrap();
        Bench::new(&format!("{label}/tail_step")).run(|| {
            run_stage_hosts(backend, "tail_step", &segs, &tensors).unwrap();
        });
    }
    {
        let segs = seg(&params, &["body"]);
        let mut tensors: BTreeMap<&str, &HostTensor> = BTreeMap::new();
        tensors.insert("smashed", &smashed);
        tensors.insert("g_body_out", &body_out); // same shape, fine for timing
        backend.warm(&["body_backward"]).unwrap();
        Bench::new(&format!("{label}/body_backward")).run(|| {
            run_stage_hosts(backend, "body_backward", &segs, &tensors).unwrap();
        });
    }
    {
        let segs = seg(&params, &["head", "tail", "prompt"]);
        let mut tensors: BTreeMap<&str, &HostTensor> = BTreeMap::new();
        tensors.insert("images", &batch.images);
        tensors.insert("labels", &batch.labels);
        tensors.insert("lr", &lr);
        backend.warm(&["local_step"]).unwrap();
        let r = Bench::new(&format!("{label}/local_step (phase-1 SGD)")).run(|| {
            run_stage_hosts(backend, "local_step", &segs, &tensors).unwrap();
        });
        harness::throughput(&r, "samples", cfg.batch as f64);
    }
    {
        let segs = seg(&params, &["head", "tail", "prompt"]);
        let mut tensors: BTreeMap<&str, &HostTensor> = BTreeMap::new();
        tensors.insert("images", &batch.images);
        tensors.insert("labels", &batch.labels);
        backend.warm(&["el2n_scores"]).unwrap();
        Bench::new(&format!("{label}/el2n_scores (pruning)")).run(|| {
            run_stage_hosts(backend, "el2n_scores", &segs, &tensors).unwrap();
        });
    }
    {
        let segs = seg(&params, &["head", "body", "tail"]);
        let mut tensors: BTreeMap<&str, &HostTensor> = BTreeMap::new();
        tensors.insert("images", &batch.images);
        tensors.insert("labels", &batch.labels);
        tensors.insert("lr", &lr);
        backend.warm(&["full_step"]).unwrap();
        let r = Bench::new(&format!("{label}/full_step (FL baseline)")).run(|| {
            run_stage_hosts(backend, "full_step", &segs, &tensors).unwrap();
        });
        harness::throughput(&r, "samples", cfg.batch as f64);
    }
}

fn main() {
    println!("stage-execution benches (native kernels; PJRT when available)");
    bench_kernels();
    for config in ["tiny", "small"] {
        let native = NativeBackend::for_config(config).unwrap();
        bench_backend(&native, &format!("native/{config}"));
        match PjrtBackend::open(&sfprompt::artifacts_root(), config) {
            Ok(pjrt) => bench_backend(&pjrt, &format!("pjrt/{config}")),
            Err(e) => eprintln!("skipping pjrt/{config}: {e:#}"),
        }
    }
}
