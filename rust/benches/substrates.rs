//! Substrate micro-benchmarks: aggregation, partitioning, synthetic data
//! generation, JSON parsing, RNG — the non-PJRT parts of the hot path.

#[path = "harness.rs"]
mod harness;

use harness::Bench;
use sfprompt::comm::{ByteMeter, Direction, MsgKind};
use sfprompt::data::synth::{DatasetProfile, SynthDataset};
use sfprompt::model::{fedavg, Contribution, SegmentParams};
use sfprompt::partition::{partition, Partition};
use sfprompt::runtime::HostTensor;
use sfprompt::util::json::Json;
use sfprompt::util::rng::Rng;

fn big_segment(n: usize, seed: u64) -> SegmentParams {
    let mut rng = Rng::new(seed);
    SegmentParams {
        segment: "tail".into(),
        tensors: vec![HostTensor::f32(
            vec![n],
            (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
        )],
    }
}

fn main() {
    println!("substrate benches");

    // FedAvg over 5 clients x 1M params (ViT-Base tail scale).
    {
        let segs: Vec<SegmentParams> = (0..5).map(|i| big_segment(1_000_000, i)).collect();
        let r = Bench::new("fedavg/5x1M params").run(|| {
            let contribs: Vec<Contribution> = segs
                .iter()
                .map(|s| Contribution { params: s, num_samples: 10 })
                .collect();
            fedavg(&contribs).unwrap();
        });
        harness::throughput(&r, "Mparam", 5.0);
    }

    // Dirichlet partition of 50k samples over 50 clients.
    {
        let labels: Vec<i32> = (0..50_000).map(|i| (i % 100) as i32).collect();
        Bench::new("partition/dirichlet(0.1) 50k x 50").run(|| {
            let mut rng = Rng::new(3);
            partition(&labels, 50, Partition::Dirichlet { alpha: 0.1 }, &mut rng);
        });
    }

    // Synthetic data generation (32x32x3).
    {
        let profile =
            DatasetProfile { name: "b", num_classes: 10, noise: 0.5, class_overlap: 0.2 };
        let r = Bench::new("synth/generate 256 imgs 32x32x3").run(|| {
            SynthDataset::generate(profile, 32, 3, 256, 1, 2);
        });
        harness::throughput(&r, "img", 256.0);
    }

    // Manifest-scale JSON parse.
    {
        let root = sfprompt::artifacts_root().join("small").join("manifest.json");
        if let Ok(text) = std::fs::read_to_string(&root) {
            let r = Bench::new("json/parse small manifest").run(|| {
                Json::parse(&text).unwrap();
            });
            harness::throughput(&r, "MB", text.len() as f64 / 1e6);
        }
    }

    // Byte meter overhead (called 4x per batch per client on the hot loop).
    {
        Bench::new("comm/meter 100k records").run(|| {
            let mut m = ByteMeter::default();
            for i in 0..100_000 {
                m.record(
                    if i % 2 == 0 { MsgKind::SmashedData } else { MsgKind::GradSmashed },
                    Direction::Uplink,
                    1024,
                );
            }
            assert_eq!(m.messages, 100_000);
        });
    }

    // Kernel-pool dispatch overhead: the same trivial row fill inline
    // (threads=1 short-circuits to the calling thread) vs spawned across
    // scoped workers — the fixed cost every parallel kernel call pays.
    {
        use sfprompt::backend::native::pool;
        let mut out = vec![0.0f32; 64 * 1024];
        for threads in [1usize, 4] {
            pool::set_threads(threads);
            Bench::new(&format!("pool/dispatch 64k rows {threads}t")).run(|| {
                pool::run_rows1(64 * 1024, 1, &mut out, |row0, nrows, chunk| {
                    for (i, v) in chunk.iter_mut().enumerate() {
                        *v = (row0 + i) as f32 * 0.5;
                    }
                    let _ = nrows;
                });
            });
        }
        pool::set_threads(0);
        std::hint::black_box(&out);
    }

    // RNG throughput.
    {
        let r = Bench::new("rng/normal 1M draws").run(|| {
            let mut rng = Rng::new(9);
            let mut acc = 0.0f32;
            for _ in 0..1_000_000 {
                acc += rng.normal_f32(0.0, 1.0);
            }
            std::hint::black_box(acc);
        });
        harness::throughput(&r, "Mdraw", 1.0);
    }
}
