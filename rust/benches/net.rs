//! Networked-transport benchmarks: frame echo throughput and control
//! round-trip latency over a real localhost TCP socket pair, at the two
//! payload shapes that dominate federation traffic. The echo peer is a
//! thread, so numbers include both directions of the socket stack.
//!
//!     cargo bench --bench net

#[path = "harness.rs"]
mod harness;

use std::net::TcpListener;
use std::thread;
use std::time::Duration;

use harness::{throughput, Bench};
use sfprompt::comm::MsgKind;
use sfprompt::model::SegmentParams;
use sfprompt::net::{ConnectOptions, Control, NetMsg, TcpLink};
use sfprompt::runtime::HostTensor;
use sfprompt::transport::{encode_frame, Frame, Payload, Transport, WireFormat};
use sfprompt::util::rng::Rng;

fn activation_frame(rng: &mut Rng) -> Frame {
    // ViT-Base-ish smashed batch: 8 x 197 x 768 f32.
    let n = 8 * 197 * 768;
    let t = HostTensor::f32(vec![8, 197, 768], (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect());
    Frame::new(MsgKind::SmashedData, 0, 0, Payload::Tensor(t))
}

fn upload_frame(rng: &mut Rng) -> Frame {
    // A tail+prompt-style upload: a dozen mixed-size tensors.
    let segs = ["tail", "prompt"]
        .iter()
        .map(|name| SegmentParams {
            segment: name.to_string(),
            tensors: (0..6)
                .map(|i| {
                    let n = 1 << (8 + i);
                    HostTensor::f32(vec![n], (0..n).map(|_| rng.normal_f32(0.0, 0.2)).collect())
                })
                .collect(),
        })
        .collect();
    Frame::new(MsgKind::Upload, 0, 0, Payload::Segments(segs))
}

fn main() {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind localhost");
    let addr = listener.local_addr().unwrap().to_string();
    let echo = thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        let mut link = TcpLink::from_stream(stream, Duration::from_secs(60)).unwrap();
        loop {
            match link.recv_msg(false) {
                // Echo data frames back as f32 regardless of the inbound
                // precision (decode already dequantized the payload).
                Ok(Some(NetMsg::Frame(frame, _))) => {
                    link.send(&frame, WireFormat::F32).unwrap();
                }
                Ok(Some(NetMsg::Control(Control::Shutdown { .. }, _))) | Ok(None) => break,
                Ok(Some(NetMsg::Control(c, _))) => link.send_control(&c).map(|_| ()).unwrap(),
                Err(_) => break,
            }
        }
    });

    let opts = ConnectOptions {
        retries: 50,
        backoff: Duration::from_millis(20),
        io_timeout: Duration::from_secs(60),
    };
    let mut link = TcpLink::connect(&addr, &opts).expect("connect to echo peer");

    let mut rng = Rng::new(99);
    let frames = [("activation", activation_frame(&mut rng)), ("upload", upload_frame(&mut rng))];
    for (label, frame) in &frames {
        for wire in [WireFormat::F32, WireFormat::F16] {
            let mb = encode_frame(frame, wire).unwrap().len() as f64 / 1e6;
            let rep = Bench::new(&format!("net/echo/{label}/{}", wire.label())).run(|| {
                link.send(frame, wire).unwrap();
                let (back, _) = link.recv().unwrap();
                assert_eq!(back.kind, frame.kind);
            });
            throughput(&rep, "MB one-way", mb);
        }
    }

    // Control-plane round trip: the per-round report latency floor.
    let report = Control::RoundReport {
        round: 1,
        client: 2,
        local_losses: vec![0.5; 8],
        split_losses: vec![0.25; 8],
    };
    Bench::new("net/echo/control/round_report").samples(50).run(|| {
        link.send_control(&report).unwrap();
        match link.recv_msg(false).unwrap() {
            Some(NetMsg::Control(Control::RoundReport { .. }, _)) => {}
            other => panic!("echo peer answered {other:?}"),
        }
    });

    link.send_control(&Control::Shutdown { reason: "bench done".into() }).unwrap();
    drop(link);
    echo.join().unwrap();
}
