//! Transport codec benchmarks: encode/decode throughput per wire format,
//! at the two payload shapes that dominate real traffic — a per-batch
//! activation tensor (SmashedData) and a multi-tensor model segment list
//! (Upload). Needs no artifacts: payloads are synthesised.
//!
//!     cargo bench --bench transport

#[path = "harness.rs"]
mod harness;

use harness::{throughput, Bench};
use sfprompt::comm::MsgKind;
use sfprompt::model::SegmentParams;
use sfprompt::runtime::HostTensor;
use sfprompt::transport::{
    decode_frame, encode_frame, Frame, LoopbackLink, Payload, Transport, WireFormat,
};
use sfprompt::util::rng::Rng;

fn activation_frame(rng: &mut Rng) -> Frame {
    // ViT-Base-ish smashed batch: 8 x 197 x 768 f32.
    let n = 8 * 197 * 768;
    let t = HostTensor::f32(vec![8, 197, 768], (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect());
    Frame::new(MsgKind::SmashedData, 0, 0, Payload::Tensor(t))
}

fn upload_frame(rng: &mut Rng) -> Frame {
    // A tail+prompt-style upload: a dozen mixed-size tensors.
    let segs = ["tail", "prompt"]
        .iter()
        .map(|name| SegmentParams {
            segment: name.to_string(),
            tensors: (0..6)
                .map(|i| {
                    let n = 1 << (8 + i);
                    HostTensor::f32(vec![n], (0..n).map(|_| rng.normal_f32(0.0, 0.2)).collect())
                })
                .collect(),
        })
        .collect();
    Frame::new(MsgKind::Upload, 0, 0, Payload::Segments(segs))
}

fn main() {
    let mut rng = Rng::new(99);
    let frames = [("activation", activation_frame(&mut rng)), ("upload", upload_frame(&mut rng))];

    for (label, frame) in &frames {
        for wire in [WireFormat::F32, WireFormat::F16, WireFormat::Int8] {
            let encoded = encode_frame(frame, wire).unwrap();
            let mb = encoded.len() as f64 / 1e6;

            let rep = Bench::new(&format!("transport/encode/{label}/{}", wire.label()))
                .run(|| {
                    let bytes = encode_frame(frame, wire).unwrap();
                    assert_eq!(bytes.len(), encoded.len());
                });
            throughput(&rep, "MB", mb);

            let rep = Bench::new(&format!("transport/decode/{label}/{}", wire.label()))
                .run(|| {
                    let back = decode_frame(&encoded).unwrap();
                    assert_eq!(back.kind, frame.kind);
                });
            throughput(&rep, "MB", mb);

            let rep = Bench::new(&format!("transport/loopback/{label}/{}", wire.label()))
                .run(|| {
                    let mut link = LoopbackLink::new();
                    let n = link.send(frame, wire).unwrap();
                    let (_, m) = link.recv().unwrap();
                    assert_eq!(n, m);
                });
            throughput(&rep, "MB", mb);
        }
    }
}
