//! Update-compression benchmarks: top-k selection over a realistic update
//! vector, sparse frame encode/decode, and QSGD quantize+pack throughput.
//! Needs no artifacts: inputs are synthesised.
//!
//!     cargo bench --bench compress

#[path = "harness.rs"]
mod harness;

use harness::{throughput, Bench};
use sfprompt::comm::MsgKind;
use sfprompt::compress::{CompressedSegment, CompressedTensor, Scheme};
use sfprompt::transport::{decode_frame, encode_frame, Frame, Payload, WireFormat};
use sfprompt::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(44);
    // A ViT-Base-ish tail+prompt update: ~1M coordinates in one tensor.
    let n = 1 << 20;
    let update: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 0.05)).collect();
    let mb = (n * 4) as f64 / 1e6;

    for ratio in [0.1, 0.01] {
        let scheme = Scheme::TopK { ratio };
        let rep = Bench::new(&format!("compress/topk_select/{ratio}")).run(|| {
            let mut comp = scheme.compressor(1).unwrap();
            let repr = comp.compress(&update);
            std::hint::black_box(&repr);
        });
        throughput(&rep, "MB", mb);
    }

    // Sparse encode/decode at 1% density, through the full frame codec.
    let repr = Scheme::TopK { ratio: 0.01 }.compressor(1).unwrap().compress(&update);
    let frame = Frame::new(
        MsgKind::Upload,
        0,
        0,
        Payload::Compressed(vec![CompressedSegment {
            segment: "tail".into(),
            tensors: vec![CompressedTensor { shape: vec![n], repr }],
        }]),
    );
    let encoded = encode_frame(&frame, WireFormat::F32).unwrap();
    println!(
        "sparse upload frame: {} B for {mb:.1} MB dense ({:.1}x reduction)",
        encoded.len(),
        (n * 4) as f64 / encoded.len() as f64
    );
    let rep = Bench::new("compress/sparse_encode/topk:0.01").run(|| {
        let bytes = encode_frame(&frame, WireFormat::F32).unwrap();
        assert_eq!(bytes.len(), encoded.len());
    });
    throughput(&rep, "MB", mb);
    let rep = Bench::new("compress/sparse_decode/topk:0.01").run(|| {
        let back = decode_frame(&encoded).unwrap();
        assert_eq!(back.kind, MsgKind::Upload);
    });
    throughput(&rep, "MB", mb);

    // QSGD quantize (stochastic rounding) + pack via the codec.
    for bits in [4u8, 8] {
        let scheme = Scheme::Quant { bits };
        let rep = Bench::new(&format!("compress/qsgd_quantize/{bits}bit")).run(|| {
            let mut comp = scheme.compressor(2).unwrap();
            let repr = comp.compress(&update);
            std::hint::black_box(&repr);
        });
        throughput(&rep, "MB", mb);
    }
}
