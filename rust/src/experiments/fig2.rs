//! Figure 2 — communication cost of FL vs SFL (a) per global round as a
//! function of local epochs, and (b) cumulative over communication rounds.
//!
//! The paper's motivating observation: SFL's per-round traffic grows
//! linearly with local epochs U (smashed data + gradients every epoch)
//! while FL's is flat (2|W|K); SFL wins only at very small U.

use anyhow::Result;

use crate::analysis::{fl, sfl, sfprompt, CostParams};
use crate::util::csv::CsvWriter;

use super::ExpOptions;

pub fn run(opts: &ExpOptions) -> Result<()> {
    // (a) per-round comm vs local epochs
    let mut wa = CsvWriter::create(
        opts.out_dir.join("fig2a.csv"),
        &["local_epochs", "fl_mb", "sfl_mb", "sfprompt_mb"],
    )?;
    println!("Fig 2(a): per-round comm (MB) vs local epochs U (ViT-Base profile)");
    println!("{:>3} {:>10} {:>10} {:>10}", "U", "FL", "SFL", "SFPrompt");
    let mut crossover = None;
    for u in 1..=30 {
        let p = CostParams { local_epochs: u as f64, ..Default::default() };
        let (f, s, sp) = (fl(&p), sfl(&p), sfprompt(&p));
        if crossover.is_none() && s.comm_bytes > f.comm_bytes {
            crossover = Some(u);
        }
        if u <= 10 || u % 5 == 0 {
            println!(
                "{:>3} {:>10.1} {:>10.1} {:>10.1}",
                u,
                f.comm_bytes / 1e6,
                s.comm_bytes / 1e6,
                sp.comm_bytes / 1e6
            );
        }
        wa.row(&[
            u.to_string(),
            format!("{:.3}", f.comm_bytes / 1e6),
            format!("{:.3}", s.comm_bytes / 1e6),
            format!("{:.3}", sp.comm_bytes / 1e6),
        ])?;
    }
    if let Some(u) = crossover {
        println!("SFL overtakes FL at U = {u} local epochs (paper: low single digits)");
    }

    // (b) cumulative comm vs global rounds at U = 10
    let p = CostParams::default();
    let mut wb = CsvWriter::create(
        opts.out_dir.join("fig2b.csv"),
        &["round", "fl_gb", "sfl_gb", "sfprompt_gb"],
    )?;
    println!("\nFig 2(b): cumulative comm (GB) over rounds at U = {}", p.local_epochs);
    for r in 1..=50usize {
        let f = fl(&p).comm_bytes * r as f64 / 1e9;
        let s = sfl(&p).comm_bytes * r as f64 / 1e9;
        let sp = sfprompt(&p).comm_bytes * r as f64 / 1e9;
        if r % 10 == 0 {
            println!("round {:>3}: FL {:>7.2}  SFL {:>7.2}  SFPrompt {:>7.2}", r, f, s, sp);
        }
        wb.row(&[
            r.to_string(),
            format!("{:.4}", f),
            format!("{:.4}", s),
            format!("{:.4}", sp),
        ])?;
    }
    Ok(())
}
