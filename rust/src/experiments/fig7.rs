//! Figure 7 — accuracy vs local-dataset pruning fraction, IID and non-IID.
//!
//! The paper prunes up to 80% of local data with a small accuracy drop
//! because Phase-1 local-loss updates still see the full dataset.

use std::path::Path;

use anyhow::Result;

use crate::federation::Method;
use crate::partition::Partition;
use crate::util::csv::CsvWriter;

use super::common::{run_spec, RunSpec};
use super::ExpOptions;

pub fn run(artifacts: &Path, opts: &ExpOptions) -> Result<()> {
    let retains = [1.0, 0.8, 0.6, 0.4, 0.2];
    let mut w = CsvWriter::create(
        opts.out_dir.join("fig7.csv"),
        &["retain_fraction", "partition", "final_acc", "best_acc", "comm_mb_per_round"],
    )?;
    println!("Fig 7: pruning-fraction sweep (cifar100-like)");
    for part in [Partition::Iid, Partition::Dirichlet { alpha: 0.1 }] {
        for retain in retains {
            let mut spec = RunSpec::new("small_c100", "cifar100", Method::SfPrompt);
            spec.fed.partition = part;
            spec.fed.retain_fraction = retain;
            opts.apply(&mut spec);
            spec.fed.eval_every = opts.rounds.max(1);
            let hist = run_spec(artifacts, &spec, true)?;
            println!(
                "  {} retain={:.1}: final acc {:.4}, comm/round {:.2} MB",
                part.label(),
                retain,
                hist.final_accuracy(),
                hist.comm_mb_per_round()
            );
            w.row(&[
                format!("{retain:.1}"),
                part.label(),
                format!("{:.4}", hist.final_accuracy()),
                format!("{:.4}", hist.best_accuracy()),
                format!("{:.3}", hist.comm_mb_per_round()),
            ])?;
        }
    }
    Ok(())
}
