//! Table 3 — final accuracy of SFL+FF / SFL+Linear / SFPrompt across the
//! four datasets, IID and non-IID, plus the tuned-parameter ratio.

use std::path::Path;

use anyhow::Result;

use crate::federation::Method;
use crate::partition::Partition;
use crate::runtime::Manifest;
use crate::util::csv::CsvWriter;

use super::common::{run_spec, RunSpec};
use super::ExpOptions;

/// Tuned-parameter ratio per method (paper's last column).
pub fn tuned_ratio(man: &Manifest, method: Method) -> f64 {
    let p = &man.cost.params;
    let total = man.cost.params_total_backbone as f64;
    let tuned = match method {
        Method::Fl | Method::SflFullFinetune => total,
        // classifier w + b only
        Method::SflLinear => {
            let defs = man.segment("tail").unwrap();
            defs[defs.len() - 2..].iter().map(|d| d.shape.iter().product::<usize>()).sum::<usize>()
                as f64
        }
        Method::SfPrompt => (p["tail"] + p["prompt"]) as f64,
    };
    tuned / total
}

pub fn run(artifacts: &Path, opts: &ExpOptions) -> Result<()> {
    let datasets: [(&str, &'static str); 4] = [
        ("small", "cifar10"),
        ("small_c100", "cifar100"),
        ("small", "svhn"),
        ("small_c100", "flower102"),
    ];
    let methods = [Method::SflFullFinetune, Method::SflLinear, Method::SfPrompt];
    let parts = [Partition::Iid, Partition::Dirichlet { alpha: 0.1 }];

    let mut w = CsvWriter::create(
        opts.out_dir.join("table3.csv"),
        &["method", "dataset", "partition", "final_acc", "best_acc", "tuned_ratio"],
    )?;

    let mut summary: Vec<String> = Vec::new();
    for method in methods {
        for (config, dataset) in datasets {
            for part in parts {
                let mut spec = RunSpec::new(config, dataset, method);
                spec.fed.partition = part;
                opts.apply(&mut spec);
                // Only evaluate at the end: table reports terminal accuracy.
                spec.fed.eval_every = opts.rounds.max(1);
                let man = super::common::manifest_for(artifacts, config)?;
                let ratio = tuned_ratio(&man, method);
                let hist = run_spec(artifacts, &spec, true)?;
                let line = format!(
                    "{:<10} {:<10} {:<12} acc={:.4} tuned={:.4}%",
                    method.label(),
                    dataset,
                    part.label(),
                    hist.final_accuracy(),
                    ratio * 100.0
                );
                println!("{line}");
                summary.push(line);
                w.row(&[
                    method.label().into(),
                    dataset.into(),
                    part.label(),
                    format!("{:.4}", hist.final_accuracy()),
                    format!("{:.4}", hist.best_accuracy()),
                    format!("{:.6}", ratio),
                ])?;
            }
        }
    }
    println!("\nTable 3 summary ({} cells)", summary.len());
    Ok(())
}
