//! Shared helpers for the training-based experiments (fig4-7, table3).
//!
//! Experiment cells are [`RunSpec`] values (the same serializable type
//! `train --spec` consumes); running one goes through the unified run API:
//! `spec.open_backend(...)` → `spec.builder().build(...)` → [`drive`] with
//! a [`ProgressPrinter`] (or [`NullObserver`] when quiet).

use std::path::Path;

use anyhow::Result;

use crate::federation::{drive, NullObserver, ProgressPrinter, RoundObserver};
use crate::metrics::RunHistory;
use crate::runtime::Manifest;

pub use crate::federation::RunSpec;

/// Run one spec end-to-end; prints per-round progress lines unless quiet.
pub fn run_spec(artifacts: &Path, spec: &RunSpec, quiet: bool) -> Result<RunHistory> {
    let backend = spec.open_backend(artifacts)?;
    let (train, eval) = spec.datasets(&backend.manifest().config)?;
    let mut run = spec.builder().build(backend.as_ref(), &train, Some(&eval))?;
    let mut obs: Box<dyn RoundObserver> = if quiet {
        Box::new(NullObserver)
    } else {
        Box::new(ProgressPrinter::labeled(spec.method.label()))
    };
    drive(run.as_mut(), obs.as_mut())
}

/// Resolve a config's manifest for cost/analytic lookups: synthesize it
/// in memory when the config is native-known, else read the artifact dir.
pub fn manifest_for(artifacts: &Path, config: &str) -> Result<Manifest> {
    crate::backend::native::synth_manifest(config)
        .or_else(|_| Manifest::load(&artifacts.join(config)))
}
