//! Shared helpers for the training-based experiments (fig4-7, table3).

use std::path::Path;

use anyhow::Result;

use crate::data::{synth, SynthDataset};
use crate::federation::baselines::BaselineEngine;
use crate::federation::{FedConfig, Method, Selection, SfPromptEngine};
use crate::metrics::RunHistory;
use crate::partition::Partition;
use crate::runtime::ArtifactStore;

/// A fully specified training run.
#[derive(Debug, Clone)]
pub struct TrainSpec {
    pub config_name: String,
    pub dataset: &'static str,
    pub partition: Partition,
    pub method: Method,
    pub fed: FedConfig,
    pub samples_per_client: usize,
    pub eval_samples: usize,
}

impl TrainSpec {
    pub fn new(config_name: &str, dataset: &'static str, method: Method) -> TrainSpec {
        TrainSpec {
            config_name: config_name.into(),
            dataset,
            partition: Partition::Iid,
            method,
            fed: FedConfig {
                num_clients: 50,
                clients_per_round: 5,
                local_epochs: 10,
                rounds: 10,
                lr: 0.08,
                retain_fraction: 0.4,
                local_loss_update: true,
                partition: Partition::Iid,
                seed: 17,
                eval_limit: Some(160),
                eval_every: 1,
                selection: Selection::Uniform,
                wire: crate::transport::WireFormat::F32,
            },
            samples_per_client: 32,
            eval_samples: 160,
        }
    }

    pub fn datasets(&self, cfg: &crate::runtime::ModelConfig) -> (SynthDataset, SynthDataset) {
        let mut profile = synth::profile(self.dataset).expect("known dataset profile");
        // The model config's class count wins (e.g. small=10, small_c100=100).
        profile.num_classes = cfg.num_classes;
        let n_train = self.fed.num_clients * self.samples_per_client;
        let train = SynthDataset::generate(
            profile, cfg.image_size, cfg.channels, n_train,
            /*seed_protos=*/ 1000 + self.fed.seed, /*seed_samples=*/ 2000 + self.fed.seed,
        );
        let eval = SynthDataset::generate(
            profile, cfg.image_size, cfg.channels, self.eval_samples,
            1000 + self.fed.seed, 9000 + self.fed.seed,
        );
        (train, eval)
    }
}

/// Run one spec end-to-end; prints per-round progress lines.
pub fn run_spec(artifacts: &Path, spec: &TrainSpec, quiet: bool) -> Result<RunHistory> {
    let store = ArtifactStore::open(artifacts, &spec.config_name)?;
    let mut fed = spec.fed;
    fed.partition = spec.partition;
    let (train, eval) = spec.datasets(&store.manifest.config);

    let progress = |rec: &crate::metrics::RoundRecord| {
        if !quiet {
            println!(
                "  [{:<10}] round {:>2}: split_loss={:.4} local_loss={:.4} acc={:.4} comm={:.2}MB",
                spec.method.label(),
                rec.round,
                rec.mean_split_loss,
                rec.mean_local_loss,
                rec.eval_accuracy,
                rec.comm.mb()
            );
        }
    };

    if spec.method == Method::SfPrompt {
        let mut engine = SfPromptEngine::new(&store, fed, &train);
        engine.run(&train, Some(&eval), progress)
    } else {
        let mut engine = BaselineEngine::new(&store, fed, spec.method, &train);
        engine.run(&train, Some(&eval), progress)
    }
}
