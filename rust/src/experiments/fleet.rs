//! Fleet sweep — accuracy, simulated wall-clock, and dropped-client
//! counts across device-skew distributions × dropout levels, under
//! deadline-based rounds.
//!
//! Every cell runs the same SFPrompt federation; only the fleet changes.
//! The deadline starts tight (1 s) with a quorum of half the cohort, so
//! the quorum retry rule self-calibrates the cut-off per fleet: rounds
//! wait just long enough for half the clients, and slower stragglers
//! drop. The table makes the paper's implicit claim measurable — how much
//! accuracy survives when heterogeneity and churn are real.

use std::path::Path;

use anyhow::Result;

use crate::federation::Method;
use crate::sim::{FleetSpec, RateDist};
use crate::util::csv::CsvWriter;

use super::common::{run_spec, RunSpec};
use super::ExpOptions;

pub fn run(artifacts: &Path, opts: &ExpOptions) -> Result<()> {
    let devices = ["uniform", "two-tier", "pareto"];
    let dropouts = [0.0, 0.2, 0.4];
    let mut w = CsvWriter::create(
        opts.out_dir.join("fleet.csv"),
        &[
            "devices", "dropout_p", "final_acc", "best_acc", "sim_wall_s", "dropped_clients",
            "comm_mb",
        ],
    )?;

    println!("Fleet sweep: device skew x dropout under deadline rounds (tiny config)");
    println!(
        "{:<10} {:>9} {:>10} {:>10} {:>12} {:>9} {:>9}",
        "devices", "dropout", "final acc", "best acc", "sim wall s", "dropped", "comm MB"
    );
    for dev in devices {
        for &dropout_p in &dropouts {
            let mut spec = RunSpec::new("tiny", "cifar10", Method::SfPrompt);
            opts.apply(&mut spec);
            // A small federation keeps the 9-cell sweep cheap; the fleet
            // dynamics, not the model, are the subject here.
            spec.fed.num_clients = 12;
            spec.fed.clients_per_round = 4;
            spec.fed.local_epochs = opts.local_epochs.min(2);
            spec.samples_per_client = 16;
            spec.eval_samples = 96;
            spec.fed.eval_limit = Some(96);
            spec.fed.eval_every = spec.fed.rounds.max(1);

            let mut fleet = FleetSpec::named(dev)?;
            // The preset device rates are sized for real ViTs; the tiny
            // model is ~60 MFLOP per client round, so rescale the same
            // distribution shapes to rates where a 1 s deadline actually
            // separates the tiers.
            fleet.devices = match dev {
                "uniform" => RateDist::Uniform { min: 1e8, max: 1e9 },
                "two-tier" => {
                    RateDist::TwoTier { fast: 1e9, slow: 4e7, slow_fraction: 0.25 }
                }
                _ => RateDist::Pareto { scale: 1e9, shape: 1.2 },
            };
            fleet.dropout_p = dropout_p;
            fleet.deadline_s = Some(1.0);
            fleet.min_quorum = spec.fed.clients_per_round / 2;
            spec.fleet = Some(fleet);

            let hist = run_spec(artifacts, &spec, true)?;
            println!(
                "{:<10} {:>9.1} {:>10.4} {:>10.4} {:>12.1} {:>9} {:>9.2}",
                dev,
                dropout_p,
                hist.final_accuracy(),
                hist.best_accuracy(),
                hist.sim_wall_s(),
                hist.dropped_clients(),
                hist.total_comm.mb()
            );
            w.row(&[
                dev.into(),
                format!("{dropout_p:.1}"),
                format!("{:.4}", hist.final_accuracy()),
                format!("{:.4}", hist.best_accuracy()),
                format!("{:.3}", hist.sim_wall_s()),
                hist.dropped_clients().to_string(),
                format!("{:.3}", hist.total_comm.mb()),
            ])?;
        }
    }
    println!(
        "\ndeadline=1s with quorum=half the cohort: the retry rule extends the deadline \
         until half finish, so the tail of each device distribution is what drops; wrote {}",
        opts.out_dir.join("fleet.csv").display()
    );
    Ok(())
}
