//! Wire table — analytic vs measured vs quantized bytes per message kind.
//!
//! Three numbers per protocol message, cross-checked:
//! 1. the manifest's **analytic** size (`cost.message_bytes`, what the seed
//!    used to meter), 2. the **measured** f32 frame length from the real
//!    codec (analytic + framing overhead: length prefix, header, per-tensor
//!    shape tags, segment names, CRC), and 3. the **quantized** f16/int8
//!    frame lengths, with the int8 reconstruction error alongside so the
//!    accuracy/bytes trade-off is visible in one table.
//!
//! The engines compress only uplink payloads (`SmashedData`,
//! `GradBodyOut`, `Upload`); the table still encodes every kind under all
//! three formats so downlink compression can be judged before it is wired.

use std::path::Path;

use anyhow::Result;

use crate::comm::MsgKind;
use crate::model::init_params;
use crate::runtime::HostTensor;
use crate::transport::{encode_frame, Frame, Payload, WireFormat};
use crate::util::csv::CsvWriter;
use crate::util::rng::Rng;

use super::ExpOptions;

/// Max |a−b| between a payload and its decoded reconstruction.
fn max_abs_err(a: &Payload, b: &Payload) -> f64 {
    let tensors = |p: &Payload| -> Vec<HostTensor> {
        match p {
            Payload::Tensor(t) => vec![t.clone()],
            Payload::Segments(segs) => {
                segs.iter().flat_map(|s| s.tensors.iter().cloned()).collect()
            }
            // This table compares scalar wire precisions on dense frames;
            // sparse/quantized payloads are the compress experiment's job.
            Payload::Empty | Payload::Compressed(_) => Vec::new(),
        }
    };
    let (ta, tb) = (tensors(a), tensors(b));
    ta.iter()
        .zip(&tb)
        .flat_map(|(x, y)| x.as_f32().iter().zip(y.as_f32()).map(|(u, v)| (u - v).abs() as f64))
        .fold(0.0, f64::max)
}

pub fn run(artifacts: &Path, opts: &ExpOptions) -> Result<()> {
    let man = super::common::manifest_for(artifacts, "small")?;
    let cfg = man.config.clone();
    let params = init_params(&man, opts.seed);
    let tail = params.get("tail")?.clone();
    let prompt = params.get("prompt")?.clone();
    let head = params.get("head")?.clone();
    let body = params.get("body")?.clone();

    let mut rng = Rng::new(opts.seed ^ 0x5157);
    let n = cfg.batch * cfg.seq_len * cfg.dim;
    let smashed = HostTensor::f32(
        vec![cfg.batch, cfg.seq_len, cfg.dim],
        (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
    );

    let mb = &man.cost.message_bytes;
    let act_b = mb["smashed_per_batch"];
    let model_b = mb["tail_params"] + mb["prompt_params"];
    let rows: Vec<(MsgKind, Payload, usize)> = vec![
        (
            MsgKind::ModelDistribution,
            Payload::Segments(vec![tail.clone(), prompt.clone()]),
            model_b,
        ),
        (MsgKind::SmashedData, Payload::Tensor(smashed.clone()), act_b),
        (MsgKind::BodyOutput, Payload::Tensor(smashed.clone()), act_b),
        (MsgKind::GradBodyOut, Payload::Tensor(smashed.clone()), act_b),
        (MsgKind::GradSmashed, Payload::Tensor(smashed), act_b),
        (MsgKind::Upload, Payload::Segments(vec![tail.clone(), prompt]), model_b),
        (
            MsgKind::FullModel,
            Payload::Segments(vec![head, body, tail]),
            mb["full_model"],
        ),
    ];

    let mut w = CsvWriter::create(
        opts.out_dir.join("wire.csv"),
        &[
            "kind", "analytic_bytes", "f32_bytes", "framing_overhead_pct", "f16_bytes",
            "int8_bytes", "int8_reduction_pct", "int8_max_abs_err",
        ],
    )?;

    println!("wire codec on config `{}` (batch={}, seq={}, dim={}):", cfg.name, cfg.batch,
             cfg.seq_len, cfg.dim);
    println!(
        "{:<20} {:>12} {:>12} {:>9} {:>12} {:>12} {:>9} {:>11}",
        "kind", "analytic B", "f32 B", "frame %", "f16 B", "int8 B", "int8 -%", "int8 err"
    );
    let mut uplink_f32 = 0usize;
    let mut uplink_int8 = 0usize;
    for (kind, payload, analytic) in rows {
        let frame = Frame::new(kind, 0, 0, payload);
        let f32_b = encode_frame(&frame, WireFormat::F32)?.len();
        let f16_b = encode_frame(&frame, WireFormat::F16)?.len();
        let int8_bytes = encode_frame(&frame, WireFormat::Int8)?;
        let int8_b = int8_bytes.len();
        let decoded = crate::transport::decode_frame(&int8_bytes)?;
        let err = max_abs_err(&frame.payload, &decoded.payload);
        let overhead = 100.0 * (f32_b as f64 - analytic as f64) / analytic.max(1) as f64;
        let reduction = 100.0 * (1.0 - int8_b as f64 / f32_b as f64);
        if matches!(kind, MsgKind::SmashedData | MsgKind::GradBodyOut | MsgKind::Upload) {
            uplink_f32 += f32_b;
            uplink_int8 += int8_b;
        }
        println!(
            "{:<20} {:>12} {:>12} {:>8.2}% {:>12} {:>12} {:>8.1}% {:>11.2e}",
            kind.label(), analytic, f32_b, overhead, f16_b, int8_b, reduction, err
        );
        w.row(&[
            kind.label().into(),
            analytic.to_string(),
            f32_b.to_string(),
            format!("{overhead:.3}"),
            f16_b.to_string(),
            int8_b.to_string(),
            format!("{reduction:.2}"),
            format!("{err:.3e}"),
        ])?;
    }
    let uplink_reduction = 100.0 * (1.0 - uplink_int8 as f64 / uplink_f32.max(1) as f64);
    println!(
        "\nuplink payloads (smashed + cut-grad + upload): f32 {uplink_f32} B -> int8 \
         {uplink_int8} B ({uplink_reduction:.1}% reduction)"
    );
    println!("engines compress uplink only; run `sfprompt train --wire int8` to measure \
              the accuracy side of the trade-off");
    Ok(())
}
