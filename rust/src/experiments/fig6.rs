//! Figure 6 — ablation: SFPrompt with vs without the Phase-1 local-loss
//! update (cifar100-like).

use std::path::Path;

use anyhow::Result;

use crate::federation::Method;
use crate::util::csv::CsvWriter;

use super::common::{run_spec, RunSpec};
use super::ExpOptions;

pub fn run(artifacts: &Path, opts: &ExpOptions) -> Result<()> {
    let mut w = CsvWriter::create(
        opts.out_dir.join("fig6.csv"),
        &["variant", "round", "accuracy", "split_loss"],
    )?;
    println!("Fig 6: local-loss-update ablation (cifar100-like, IID)");
    for (variant, local_loss) in [("sfprompt", true), ("sfprompt_wo_localloss", false)] {
        let mut spec = RunSpec::new("small_c100", "cifar100", Method::SfPrompt);
        spec.fed.local_loss_update = local_loss;
        opts.apply(&mut spec);
        let hist = run_spec(artifacts, &spec, true)?;
        for rec in &hist.rounds {
            w.row(&[
                variant.into(),
                rec.round.to_string(),
                format!("{:.4}", rec.eval_accuracy),
                format!("{:.4}", rec.mean_split_loss),
            ])?;
        }
        println!(
            "  {variant:<22} final acc {:.4} (best {:.4}, comm/round {:.2} MB)",
            hist.final_accuracy(),
            hist.best_accuracy(),
            hist.comm_mb_per_round()
        );
    }
    Ok(())
}
