//! Figure 5 — accuracy and tuned-parameter count vs prompt length on the
//! cifar100-like task (prompt-length sweep configs small_c100_p*).

use std::path::Path;

use anyhow::Result;

use crate::federation::Method;
use crate::util::csv::CsvWriter;

use super::common::{run_spec, RunSpec};
use super::ExpOptions;

pub fn run(artifacts: &Path, opts: &ExpOptions) -> Result<()> {
    // (config, prompt_len) — small_c100 itself is the p=8 point.
    let sweep = [
        ("small_c100_p1", 1usize),
        ("small_c100_p2", 2),
        ("small_c100", 8),
        ("small_c100_p16", 16),
        ("small_c100_p32", 32),
    ];
    let mut w = CsvWriter::create(
        opts.out_dir.join("fig5.csv"),
        &["prompt_len", "tuned_params", "final_acc", "best_acc"],
    )?;
    println!("Fig 5: prompt-length sweep (cifar100-like, IID)");
    for (config, p_len) in sweep {
        let man = super::common::manifest_for(artifacts, config)?;
        let tuned = man.cost.params["tail"] + man.cost.params["prompt"];
        let mut spec = RunSpec::new(config, "cifar100", Method::SfPrompt);
        opts.apply(&mut spec);
        spec.fed.eval_every = opts.rounds.max(1);
        let hist = run_spec(artifacts, &spec, true)?;
        println!(
            "  P={:<3} tuned={:<8} final_acc={:.4} best={:.4}",
            p_len,
            tuned,
            hist.final_accuracy(),
            hist.best_accuracy()
        );
        w.row(&[
            p_len.to_string(),
            tuned.to_string(),
            format!("{:.4}", hist.final_accuracy()),
            format!("{:.4}", hist.best_accuracy()),
        ])?;
    }
    Ok(())
}
