//! Compression sweep — accuracy vs **measured** uploaded bytes across
//! scheme × ratio, plus the fleet interaction.
//!
//! Every cell runs the same SFPrompt federation; only `fed.compress`
//! changes. Upload bytes come from `ByteMeter` (`by_kind["upload"]` wire
//! vs `raw_by_kind["upload"]` dense-f32), so the reduction column is what
//! actually crossed the codec, not an analytic estimate. The error-
//! feedback tolerance these cells are judged against is documented in
//! docs/COMPRESS.md.
//!
//! Because fleet round time is charged from measured transport bytes,
//! compression composes with the deadline simulator for free — fewer
//! upload bytes means clients finish earlier and fewer get dropped — so a
//! second mini-table runs dense vs `topk:0.01` on a two-tier deadline
//! fleet and reports simulated wall-clock and drops side by side.

use std::path::Path;

use anyhow::Result;

use crate::compress::Scheme;
use crate::federation::Method;
use crate::metrics::RunHistory;
use crate::sim::{FleetSpec, RateDist};
use crate::util::csv::CsvWriter;

use super::common::{run_spec, RunSpec};
use super::ExpOptions;

/// The sweep's federation: small enough that 8 cells stay cheap, big
/// enough that upload traffic dominates a visible share of the round.
fn base_spec(opts: &ExpOptions) -> RunSpec {
    let mut spec = RunSpec::new("tiny", "cifar10", Method::SfPrompt);
    opts.apply(&mut spec);
    spec.fed.num_clients = 12;
    spec.fed.clients_per_round = 4;
    spec.fed.local_epochs = opts.local_epochs.min(2);
    spec.samples_per_client = 16;
    spec.eval_samples = 96;
    spec.fed.eval_limit = Some(96);
    // Accuracy is only needed at the end of each cell.
    spec.fed.eval_every = spec.fed.rounds.max(1);
    spec
}

fn upload_bytes(hist: &RunHistory) -> (u64, u64) {
    let wire = hist.total_comm.by_kind.get("upload").copied().unwrap_or(0);
    let raw = hist.total_comm.raw_by_kind.get("upload").copied().unwrap_or(0);
    (wire, raw)
}

pub fn run(artifacts: &Path, opts: &ExpOptions) -> Result<()> {
    let schemes = [
        "none", "quant:8", "quant:4", "randk:0.1", "randk:0.05", "topk:0.1", "topk:0.05",
        "topk:0.01",
    ];
    let mut w = CsvWriter::create(
        opts.out_dir.join("compress.csv"),
        &[
            "scheme", "final_acc", "best_acc", "upload_wire_kb", "upload_raw_kb",
            "upload_reduction_x", "total_mb", "sim_wall_s",
        ],
    )?;

    println!("Compression sweep: accuracy vs measured uploaded bytes (tiny config)");
    println!(
        "{:<10} {:>10} {:>10} {:>12} {:>12} {:>8} {:>9} {:>10}",
        "scheme", "final acc", "best acc", "upload KB", "raw KB", "x", "total MB", "sim wall s"
    );
    let mut dense_acc = f64::NAN;
    for name in schemes {
        let mut spec = base_spec(opts);
        spec.fed.compress = Scheme::parse(name)?;
        let hist = run_spec(artifacts, &spec, true)?;
        let (wire, raw) = upload_bytes(&hist);
        let reduction = raw as f64 / wire.max(1) as f64;
        if name == "none" {
            dense_acc = hist.final_accuracy();
        }
        println!(
            "{:<10} {:>10.4} {:>10.4} {:>12.2} {:>12.2} {:>8.1} {:>9.2} {:>10.1}",
            name,
            hist.final_accuracy(),
            hist.best_accuracy(),
            wire as f64 / 1e3,
            raw as f64 / 1e3,
            reduction,
            hist.total_comm.mb(),
            hist.sim_wall_s()
        );
        w.row(&[
            name.into(),
            format!("{:.4}", hist.final_accuracy()),
            format!("{:.4}", hist.best_accuracy()),
            format!("{:.3}", wire as f64 / 1e3),
            format!("{:.3}", raw as f64 / 1e3),
            format!("{reduction:.2}"),
            format!("{:.3}", hist.total_comm.mb()),
            format!("{:.3}", hist.sim_wall_s()),
        ])?;
    }
    println!(
        "\nerror-feedback sparsification should track the dense final accuracy \
         ({dense_acc:.4}) within the docs/COMPRESS.md tolerance while cutting upload \
         bytes by the x column; wrote {}",
        opts.out_dir.join("compress.csv").display()
    );

    // --- Fleet interaction: fewer measured bytes -> faster simulated
    // clients -> fewer deadline drops, with zero extra wiring. ---
    println!("\nDeadline-fleet interaction (two-tier links, deadline 1s, quorum 2):");
    println!(
        "{:<10} {:>10} {:>12} {:>9} {:>9}",
        "scheme", "final acc", "sim wall s", "dropped", "comm MB"
    );
    for name in ["none", "topk:0.01"] {
        let mut spec = base_spec(opts);
        spec.fed.compress = Scheme::parse(name)?;
        let mut fleet = FleetSpec::named("two-tier")?;
        fleet.devices = RateDist::TwoTier { fast: 1e9, slow: 4e7, slow_fraction: 0.25 };
        fleet.deadline_s = Some(1.0);
        fleet.min_quorum = spec.fed.clients_per_round / 2;
        spec.fleet = Some(fleet);
        let hist = run_spec(artifacts, &spec, true)?;
        println!(
            "{:<10} {:>10.4} {:>12.1} {:>9} {:>9.2}",
            name,
            hist.final_accuracy(),
            hist.sim_wall_s(),
            hist.dropped_clients(),
            hist.total_comm.mb()
        );
    }
    println!(
        "compression shortens upload transfers, so straggling clients beat the same \
         deadline more often (drops should not increase under topk:0.01)"
    );
    Ok(())
}
