//! Table 2 — per-round communication cost and per-client computational
//! burden for FL / SFL / SFPrompt on the ViT-Base and ViT-Large profiles.
//!
//! Two sources, cross-checked:
//! 1. the analytic model over the paper-scale profiles
//!    (vit_base_sim / vit_large_sim manifests, analytic-only), and
//! 2. exact measured bytes from a real run of each engine on the `small`
//!    config, scaled by nothing — reported alongside to show the shape.

use std::path::Path;

use anyhow::Result;

use crate::analysis::{fl, sfl, sfprompt, CostParams};
use crate::flops::{segment_flops, train_step_flops};
use crate::runtime::Manifest;
use crate::util::csv::CsvWriter;

use super::ExpOptions;

fn profile_params(man: &Manifest, retain: f64) -> CostParams {
    let cfg = &man.config;
    let w_bytes = man.cost.message_bytes["full_model"] as f64;
    CostParams {
        w_bytes,
        alpha: man.cost.alpha,
        tau: man.cost.tau,
        gamma: retain,
        p_bytes: man.cost.message_bytes["prompt_params"] as f64,
        // Cut-layer size without prompt tokens (the paper's q ≈ 197·768·4
        // for ViT-Base — back-solved from SFPrompt = 1825.19 MB).
        q_bytes: (cfg.seq_len_noprompt * cfg.dim * 4) as f64,
        d_samples: 250.0,
        clients: 5.0,
        local_epochs: 10.0,
        ..Default::default()
    }
}

pub fn run(artifacts: &Path, opts: &ExpOptions) -> Result<()> {
    let mut w = CsvWriter::create(
        opts.out_dir.join("table2.csv"),
        &["model", "method", "comm_mb_per_round", "comm_x_fl", "client_gflops", "gflops_x_fl"],
    )?;

    for profile in ["vit_base_sim", "vit_large_sim"] {
        let man = super::common::manifest_for(artifacts, profile)?;
        // γ_retain = 0.6, back-solved from the paper's 78.9/131.5 ratio.
        let p = profile_params(&man, 0.6);
        let model_mb = p.w_bytes / 1e6;
        println!(
            "\n{profile} (|W| = {:.0} MB, α={:.3}, τ={:.3}):",
            model_mb, p.alpha, p.tau
        );

        // Per-client computational burden per the paper's Table 1 rows:
        // FL = |D||W|, SFL = (1−τ)|D||W|, SFPrompt = (1−τ)γ|D||W| — i.e.
        // one training pass over the locally-processed samples (the
        // paper's table does not multiply by U; Phase-1 local-loss compute
        // is accounted in `analysis::sfprompt`, see DESIGN.md).
        let f_full = segment_flops(&man.config, false);
        let f_prompt = segment_flops(&man.config, true);
        let d = p.d_samples;
        let fl_gflops = train_step_flops(f_full.total()) as f64 * d / 1e9;
        let sfl_gflops = train_step_flops(f_full.client()) as f64 * d / 1e9;
        let sfp_gflops = train_step_flops(f_prompt.client()) as f64 * p.gamma * d / 1e9;

        let rows = [
            ("FL", fl(&p).comm_bytes, fl_gflops),
            ("SFL", sfl(&p).comm_bytes, sfl_gflops),
            ("SFPrompt", sfprompt(&p).comm_bytes, sfp_gflops),
        ];
        let fl_comm = rows[0].1;
        let fl_fl = rows[0].2;
        println!(
            "{:<10} {:>16} {:>8} {:>16} {:>9}",
            "method", "comm MB/round", "(x FL)", "client GFLOPs", "(x FL)"
        );
        for (name, comm, gflops) in rows {
            println!(
                "{:<10} {:>16.2} {:>7.2}x {:>16.1} {:>8.4}x",
                name,
                comm / 1e6,
                comm / fl_comm,
                gflops,
                gflops / fl_fl
            );
            w.row(&[
                profile.into(),
                name.into(),
                format!("{:.2}", comm / 1e6),
                format!("{:.4}", comm / fl_comm),
                format!("{:.2}", gflops),
                format!("{:.6}", gflops / fl_fl),
            ])?;
        }
    }
    println!("\npaper Table 2: SFPrompt comm 0.47x FL (ViT-Base), 0.19x (ViT-Large); \
              compute 0.0046x / 0.0017x FL");
    Ok(())
}
