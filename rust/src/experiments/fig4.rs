//! Figure 4 — accuracy curves: SFPrompt vs SFL+FF vs SFL+Linear on the
//! cifar10- and cifar100-like tasks, IID and non-IID.

use std::path::Path;

use anyhow::Result;

use crate::federation::Method;
use crate::partition::Partition;
use crate::util::csv::CsvWriter;

use super::common::{run_spec, RunSpec};
use super::ExpOptions;

pub fn run(artifacts: &Path, opts: &ExpOptions) -> Result<()> {
    let methods = [Method::SflFullFinetune, Method::SflLinear, Method::SfPrompt];
    let cells: [(&str, &'static str, Partition); 4] = [
        ("small", "cifar10", Partition::Iid),
        ("small", "cifar10", Partition::Dirichlet { alpha: 0.1 }),
        ("small_c100", "cifar100", Partition::Iid),
        ("small_c100", "cifar100", Partition::Dirichlet { alpha: 0.1 }),
    ];

    let mut w = CsvWriter::create(
        opts.out_dir.join("fig4.csv"),
        &["dataset", "partition", "method", "round", "accuracy", "split_loss"],
    )?;

    for (config, dataset, part) in cells {
        println!("--- fig4 cell: {dataset} / {} ---", part.label());
        for method in methods {
            let mut spec = RunSpec::new(config, dataset, method);
            spec.fed.partition = part;
            opts.apply(&mut spec);
            let hist = run_spec(artifacts, &spec, false)?;
            for rec in &hist.rounds {
                w.row(&[
                    dataset.into(),
                    part.label(),
                    method.label().into(),
                    rec.round.to_string(),
                    format!("{:.4}", rec.eval_accuracy),
                    format!("{:.4}", rec.mean_split_loss),
                ])?;
            }
            println!(
                "  => {dataset}/{}/{}: final acc {:.4} (best {:.4})",
                part.label(),
                method.label(),
                hist.final_accuracy(),
                hist.best_accuracy()
            );
        }
    }
    Ok(())
}
