//! Experiment harness: one module per paper table/figure.

pub mod common;
pub mod compress;
pub mod fig2;
pub mod fleet;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod table1;
pub mod table2;
pub mod table3;
pub mod wire;

use std::path::Path;

use anyhow::Result;

/// Shared knobs for the experiment harness (budget control).
#[derive(Debug, Clone)]
pub struct ExpOptions {
    pub out_dir: std::path::PathBuf,
    pub rounds: usize,
    pub local_epochs: usize,
    pub samples_per_client_x: f64,
    pub seed: u64,
}

impl Default for ExpOptions {
    fn default() -> Self {
        ExpOptions {
            out_dir: "results".into(),
            rounds: 12,
            local_epochs: 10,
            samples_per_client_x: 1.0,
            seed: 17,
        }
    }
}

impl ExpOptions {
    /// Apply the budget knobs to a run spec.
    pub fn apply(&self, spec: &mut crate::federation::RunSpec) {
        spec.fed.rounds = self.rounds;
        spec.fed.local_epochs = self.local_epochs;
        spec.fed.seed = self.seed;
        spec.samples_per_client =
            ((spec.samples_per_client as f64) * self.samples_per_client_x).max(8.0) as usize;
    }
}

pub fn run(id: &str, artifacts: &Path, opts: &ExpOptions) -> Result<()> {
    match id {
        "fig2" => fig2::run(opts),
        "table1" => table1::run(opts),
        "fig4" => fig4::run(artifacts, opts),
        "table2" => table2::run(artifacts, opts),
        "table3" => table3::run(artifacts, opts),
        "fig5" => fig5::run(artifacts, opts),
        "fig6" => fig6::run(artifacts, opts),
        "fig7" => fig7::run(artifacts, opts),
        "wire" => wire::run(artifacts, opts),
        "fleet" => fleet::run(artifacts, opts),
        "compress" => compress::run(artifacts, opts),
        "all" => {
            for id in [
                "table1", "fig2", "wire", "fleet", "compress", "table2", "fig4", "fig5",
                "fig6", "fig7", "table3",
            ] {
                println!("==== experiment {id} ====");
                run(id, artifacts, opts)?;
            }
            Ok(())
        }
        other => anyhow::bail!("unknown experiment id {other:?} \
            (known: compress fig2 fig4 fig5 fig6 fig7 fleet table1 table2 table3 wire all)"),
    }
}
