//! Table 1 — closed-form per-round computational burden, communication
//! cost, and latency for FL / SFL / SFPrompt (paper §3.5).

use anyhow::Result;

use crate::analysis::{fl, fl_crossover_w_bytes, sfl, sfprompt, CostParams};
use crate::util::csv::CsvWriter;

use super::ExpOptions;

pub fn run(opts: &ExpOptions) -> Result<()> {
    let p = CostParams::default();
    let rows = [("FL", fl(&p)), ("SFL", sfl(&p)), ("SFPrompt", sfprompt(&p))];

    let mut w = CsvWriter::create(
        opts.out_dir.join("table1.csv"),
        &["method", "compute_client_parambytes", "comm_mb", "latency_s"],
    )?;
    println!("Table 1 (ViT-Base profile, |D|={} samples, U={}, K={}):",
             p.d_samples, p.local_epochs, p.clients);
    println!("{:<10} {:>22} {:>12} {:>12}", "method", "client compute (|D||W|)", "comm MB",
             "latency s");
    let fl_row = rows[0].1;
    for (name, c) in rows {
        println!(
            "{:<10} {:>18.3e} ({:>5.4}x) {:>9.1} ({:.2}x) {:>9.1}",
            name,
            c.compute_client,
            c.compute_client / fl_row.compute_client,
            c.comm_bytes / 1e6,
            c.comm_bytes / fl_row.comm_bytes,
            c.latency_s,
        );
        w.row(&[
            name.into(),
            format!("{:.6e}", c.compute_client),
            format!("{:.3}", c.comm_bytes / 1e6),
            format!("{:.3}", c.latency_s),
        ])?;
    }
    println!(
        "FL-advantage crossover: SFPrompt wins on comm when |W| > {:.1} MB (paper: 2qγ|D|/(α+τ))",
        fl_crossover_w_bytes(&p) / 1e6
    );
    Ok(())
}
