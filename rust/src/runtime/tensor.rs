//! Host-side tensors and conversion to/from PJRT `Literal`s.

use anyhow::{bail, Context, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => bail!("unknown dtype {other:?}"),
        }
    }

    pub fn size_bytes(self) -> usize {
        4
    }
}

/// A dense host tensor; the only two dtypes the protocol uses are f32
/// (activations, parameters, gradients) and i32 (labels).
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor { shape, data: TensorData::F32(data) }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor { shape, data: TensorData::I32(data) }
    }

    pub fn scalar_f32(v: f32) -> HostTensor {
        HostTensor::f32(vec![], vec![v])
    }

    pub fn zeros(shape: Vec<usize>) -> HostTensor {
        let n = shape.iter().product();
        HostTensor::f32(shape, vec![0.0; n])
    }

    pub fn dtype(&self) -> Dtype {
        match self.data {
            TensorData::F32(_) => Dtype::F32,
            TensorData::I32(_) => Dtype::I32,
        }
    }

    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn size_bytes(&self) -> usize {
        self.element_count() * self.dtype().size_bytes()
    }

    pub fn as_f32(&self) -> &[f32] {
        match &self.data {
            TensorData::F32(v) => v,
            TensorData::I32(_) => panic!("tensor is i32, expected f32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> &mut [f32] {
        match &mut self.data {
            TensorData::F32(v) => v,
            TensorData::I32(_) => panic!("tensor is i32, expected f32"),
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        match &self.data {
            TensorData::I32(v) => v,
            TensorData::F32(_) => panic!("tensor is f32, expected i32"),
        }
    }

    /// Convert to an XLA literal (reshaped to this tensor's dimensions).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = match &self.data {
            TensorData::F32(v) => {
                if self.shape.is_empty() {
                    return Ok(xla::Literal::scalar(v[0]));
                }
                xla::Literal::vec1(v)
            }
            TensorData::I32(v) => {
                if self.shape.is_empty() {
                    return Ok(xla::Literal::scalar(v[0]));
                }
                xla::Literal::vec1(v)
            }
        };
        lit.reshape(&dims).context("reshape literal")
    }

    /// Read a literal back into a host tensor with a known shape/dtype.
    pub fn from_literal(lit: &xla::Literal, shape: &[usize], dtype: Dtype) -> Result<HostTensor> {
        let expected: usize = shape.iter().product();
        let t = match dtype {
            Dtype::F32 => {
                let v = lit.to_vec::<f32>().context("literal to f32 vec")?;
                if v.len() != expected {
                    bail!("literal has {} elements, expected {expected}", v.len());
                }
                HostTensor::f32(shape.to_vec(), v)
            }
            Dtype::I32 => {
                let v = lit.to_vec::<i32>().context("literal to i32 vec")?;
                if v.len() != expected {
                    bail!("literal has {} elements, expected {expected}", v.len());
                }
                HostTensor::i32(shape.to_vec(), v)
            }
        };
        Ok(t)
    }

    /// L2 norm (f32 tensors), used in tests and metrics.
    pub fn l2(&self) -> f64 {
        self.as_f32().iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_data_checked() {
        let t = HostTensor::f32(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.element_count(), 6);
        assert_eq!(t.size_bytes(), 24);
        assert_eq!(t.dtype(), Dtype::F32);
    }

    #[test]
    #[should_panic]
    fn mismatched_shape_panics() {
        HostTensor::f32(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn scalar_roundtrip_shapes() {
        let t = HostTensor::scalar_f32(0.25);
        assert!(t.shape.is_empty());
        assert_eq!(t.element_count(), 1);
    }

    #[test]
    fn l2_norm() {
        let t = HostTensor::f32(vec![2], vec![3.0, 4.0]);
        assert!((t.l2() - 5.0).abs() < 1e-12);
    }
}
