//! Runtime layer: manifests (stage signatures / shapes / cost numbers),
//! host tensors, and the PJRT artifact store.
//!
//! Stage *execution* lives behind [`crate::backend::Backend`]: the
//! [`crate::backend::native`] kernel engine needs only [`Manifest`] and
//! [`HostTensor`] from here, while [`crate::backend::PjrtBackend`] drives
//! [`ArtifactStore`] (lazy `PjRtClient::cpu()` → `HloModuleProto` →
//! `compile` → `execute`; a functional host-side stub offline).

pub mod artifact;
pub mod manifest;
pub mod tensor;

pub use artifact::{ArtifactStore, StageStats};
pub use manifest::{InitSpec, IoSpec, Manifest, ModelConfig, StageDef, TensorDef};
pub use tensor::{Dtype, HostTensor, TensorData};
