//! Runtime layer: PJRT artifact loading + stage execution.
//!
//! `xla` crate (0.1.6) against xla_extension 0.5.1 CPU:
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute`. Python never runs here — artifacts are
//! produced once by `make artifacts`.

pub mod artifact;
pub mod executor;
pub mod manifest;
pub mod tensor;

pub use artifact::{ArtifactStore, StageStats};
pub use executor::{segment_literals, Executor, SegInput, SegmentInputs, StageOutputs, TensorInputs};
pub use manifest::{InitSpec, IoSpec, Manifest, ModelConfig, StageDef, TensorDef};
pub use tensor::{Dtype, HostTensor, TensorData};
