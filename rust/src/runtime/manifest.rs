//! Typed view of `artifacts/<config>/manifest.json` (emitted by aot.py).
//!
//! The manifest is the single source of truth for stage signatures: which
//! segments and tensors each HLO program takes, positionally, and what it
//! returns. The rust side never hard-codes parameter orders.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

use super::tensor::Dtype;

#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub name: String,
    pub image_size: usize,
    pub patch_size: usize,
    pub channels: usize,
    pub dim: usize,
    pub heads: usize,
    pub depth_head: usize,
    pub depth_body: usize,
    pub depth_tail: usize,
    pub mlp_ratio: usize,
    pub num_classes: usize,
    pub prompt_len: usize,
    pub batch: usize,
    pub num_patches: usize,
    pub seq_len: usize,
    pub seq_len_noprompt: usize,
    pub patch_dim: usize,
    pub analytic_only: bool,
}

#[derive(Debug, Clone)]
pub struct TensorDef {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
    pub init: InitSpec,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum InitSpec {
    Zeros,
    Ones,
    Normal(f32),
}

impl InitSpec {
    pub fn parse(s: &str) -> Result<InitSpec> {
        match s {
            "zeros" => Ok(InitSpec::Zeros),
            "ones" => Ok(InitSpec::Ones),
            other => {
                let sigma = other
                    .strip_prefix("normal:")
                    .and_then(|v| v.parse::<f32>().ok())
                    .ok_or_else(|| anyhow!("bad init spec {other:?}"))?;
                Ok(InitSpec::Normal(sigma))
            }
        }
    }
}

/// One positional input or output of a stage.
#[derive(Debug, Clone, PartialEq)]
pub enum IoSpec {
    /// All tensors of a named segment, in manifest order.
    Segment(String),
    /// A single data tensor.
    Tensor { name: String, shape: Vec<usize>, dtype: Dtype },
    /// A f32 scalar (learning rate).
    Scalar(String),
}

#[derive(Debug, Clone)]
pub struct StageDef {
    pub name: String,
    pub file: String,
    pub family: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

#[derive(Debug, Clone)]
pub struct CostInfo {
    pub params: BTreeMap<String, usize>,
    pub params_total_backbone: usize,
    pub alpha: f64,
    pub tau: f64,
    pub message_bytes: BTreeMap<String, usize>,
    pub flops_fwd_per_sample: BTreeMap<String, u64>,
    pub flops_fwd_per_sample_noprompt: BTreeMap<String, u64>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub config: ModelConfig,
    pub segments: BTreeMap<String, Vec<TensorDef>>,
    pub stages: BTreeMap<String, StageDef>,
    pub cost: CostInfo,
}

fn req<'a>(j: &'a Json, key: &str) -> Result<&'a Json> {
    j.get(key).ok_or_else(|| anyhow!("manifest missing key {key:?}"))
}

fn usize_of(j: &Json, key: &str) -> Result<usize> {
    req(j, key)?.as_usize().ok_or_else(|| anyhow!("{key:?} not a usize"))
}

fn str_of(j: &Json, key: &str) -> Result<String> {
    Ok(req(j, key)?.as_str().ok_or_else(|| anyhow!("{key:?} not a string"))?.to_string())
}

fn shape_of(j: &Json) -> Result<Vec<usize>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("shape not an array"))?
        .iter()
        .map(|d| d.as_usize().ok_or_else(|| anyhow!("shape dim not usize")))
        .collect()
}

fn io_spec(j: &Json) -> Result<IoSpec> {
    match req(j, "kind")?.as_str() {
        Some("segment") => Ok(IoSpec::Segment(str_of(j, "segment")?)),
        Some("scalar") => Ok(IoSpec::Scalar(str_of(j, "name")?)),
        Some("tensor") | None => Ok(IoSpec::Tensor {
            name: str_of(j, "name")?,
            shape: shape_of(req(j, "shape")?)?,
            dtype: Dtype::parse(j.get("dtype").and_then(|d| d.as_str()).unwrap_or("f32"))?,
        }),
        Some(other) => bail!("unknown io kind {other:?}"),
    }
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Manifest::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("{e}"))?;

        let c = req(&j, "config")?;
        let config = ModelConfig {
            name: str_of(c, "name")?,
            image_size: usize_of(c, "image_size")?,
            patch_size: usize_of(c, "patch_size")?,
            channels: usize_of(c, "channels")?,
            dim: usize_of(c, "dim")?,
            heads: usize_of(c, "heads")?,
            depth_head: usize_of(c, "depth_head")?,
            depth_body: usize_of(c, "depth_body")?,
            depth_tail: usize_of(c, "depth_tail")?,
            mlp_ratio: usize_of(c, "mlp_ratio")?,
            num_classes: usize_of(c, "num_classes")?,
            prompt_len: usize_of(c, "prompt_len")?,
            batch: usize_of(c, "batch")?,
            num_patches: usize_of(c, "num_patches")?,
            seq_len: usize_of(c, "seq_len")?,
            seq_len_noprompt: usize_of(c, "seq_len_noprompt")?,
            patch_dim: usize_of(c, "patch_dim")?,
            analytic_only: c.get("analytic_only").and_then(|v| v.as_bool()).unwrap_or(false),
        };

        let mut segments = BTreeMap::new();
        for (seg, arr) in req(&j, "segments")?.as_obj().ok_or_else(|| anyhow!("segments"))? {
            let defs = arr
                .as_arr()
                .ok_or_else(|| anyhow!("segment {seg} not an array"))?
                .iter()
                .map(|d| {
                    Ok(TensorDef {
                        name: str_of(d, "name")?,
                        shape: shape_of(req(d, "shape")?)?,
                        dtype: Dtype::parse(&str_of(d, "dtype")?)?,
                        init: InitSpec::parse(&str_of(d, "init")?)?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            segments.insert(seg.clone(), defs);
        }

        let mut stages = BTreeMap::new();
        for (name, s) in req(&j, "stages")?.as_obj().ok_or_else(|| anyhow!("stages"))? {
            let inputs = req(s, "inputs")?
                .as_arr()
                .ok_or_else(|| anyhow!("inputs"))?
                .iter()
                .map(io_spec)
                .collect::<Result<Vec<_>>>()?;
            let outputs = req(s, "outputs")?
                .as_arr()
                .ok_or_else(|| anyhow!("outputs"))?
                .iter()
                .map(io_spec)
                .collect::<Result<Vec<_>>>()?;
            stages.insert(
                name.clone(),
                StageDef {
                    name: name.clone(),
                    file: str_of(s, "file")?,
                    family: str_of(s, "family")?,
                    inputs,
                    outputs,
                },
            );
        }

        let cost_j = req(&j, "cost")?;
        let map_usize = |key: &str| -> Result<BTreeMap<String, usize>> {
            Ok(req(cost_j, key)?
                .as_obj()
                .ok_or_else(|| anyhow!("{key} not an object"))?
                .iter()
                .map(|(k, v)| (k.clone(), v.as_usize().unwrap_or(0)))
                .collect())
        };
        let map_u64 = |key: &str| -> Result<BTreeMap<String, u64>> {
            Ok(req(cost_j, key)?
                .as_obj()
                .ok_or_else(|| anyhow!("{key} not an object"))?
                .iter()
                .map(|(k, v)| (k.clone(), v.as_i64().unwrap_or(0) as u64))
                .collect())
        };
        let cost = CostInfo {
            params: map_usize("params")?,
            params_total_backbone: usize_of(cost_j, "params_total_backbone")?,
            alpha: req(cost_j, "alpha")?.as_f64().unwrap_or(0.0),
            tau: req(cost_j, "tau")?.as_f64().unwrap_or(0.0),
            message_bytes: map_usize("message_bytes")?,
            flops_fwd_per_sample: map_u64("flops_fwd_per_sample")?,
            flops_fwd_per_sample_noprompt: map_u64("flops_fwd_per_sample_noprompt")?,
        };

        Ok(Manifest { config, segments, stages, cost })
    }

    pub fn stage(&self, name: &str) -> Result<&StageDef> {
        self.stages
            .get(name)
            .ok_or_else(|| anyhow!("stage {name:?} not in manifest (have: {:?})",
                                   self.stages.keys().collect::<Vec<_>>()))
    }

    pub fn segment(&self, name: &str) -> Result<&[TensorDef]> {
        self.segments
            .get(name)
            .map(|v| v.as_slice())
            .ok_or_else(|| anyhow!("segment {name:?} not in manifest"))
    }

    /// Total number of positional literals a stage consumes.
    pub fn stage_input_arity(&self, stage: &StageDef) -> usize {
        stage
            .inputs
            .iter()
            .map(|io| match io {
                IoSpec::Segment(seg) => self.segments[seg].len(),
                _ => 1,
            })
            .sum()
    }
}
