//! Artifact store: loads `artifacts/<config>/` and lazily compiles each
//! HLO-text stage into a cached PJRT executable.
//!
//! HLO **text** is the interchange format (see aot.py / DESIGN.md): the
//! xla_extension 0.5.1 proto parser rejects jax>=0.5's 64-bit instruction
//! ids, while the text parser reassigns ids and round-trips cleanly.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{Context, Result};

use super::manifest::{Manifest, StageDef};

pub struct ArtifactStore {
    pub dir: PathBuf,
    pub manifest: Manifest,
    client: xla::PjRtClient,
    executables: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    /// compile-time per stage, for metrics/EXPERIMENTS.md
    compile_ms: RefCell<HashMap<String, f64>>,
    /// per-stage execution stats: (calls, convert_s, exec_s)
    exec_stats: RefCell<HashMap<String, (u64, f64, f64)>>,
}

/// Aggregated execution statistics for one stage.
#[derive(Debug, Clone, Copy)]
pub struct StageStats {
    pub calls: u64,
    pub convert_s: f64,
    pub exec_s: f64,
}

impl ArtifactStore {
    /// Open `artifacts_root/<config_name>`.
    pub fn open(artifacts_root: &Path, config_name: &str) -> Result<ArtifactStore> {
        let dir = artifacts_root.join(config_name);
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(ArtifactStore {
            dir,
            manifest,
            client,
            executables: RefCell::new(HashMap::new()),
            compile_ms: RefCell::new(HashMap::new()),
            exec_stats: RefCell::new(HashMap::new()),
        })
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    pub fn stage_def(&self, name: &str) -> Result<&StageDef> {
        self.manifest.stage(name)
    }

    /// Compile (or fetch cached) the executable for a stage.
    pub fn executable(&self, stage: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.executables.borrow().get(stage) {
            return Ok(exe.clone());
        }
        let def = self.manifest.stage(stage)?;
        let path = self.dir.join(&def.file);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling stage {stage}"))?;
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        self.compile_ms.borrow_mut().insert(stage.to_string(), ms);
        let exe = Rc::new(exe);
        self.executables.borrow_mut().insert(stage.to_string(), exe.clone());
        Ok(exe)
    }

    /// Pre-compile a set of stages (warm-up before timed runs).
    pub fn warm(&self, stages: &[&str]) -> Result<()> {
        for s in stages {
            self.executable(s)?;
        }
        Ok(())
    }

    /// Record one execution (called by the Executor).
    pub(crate) fn note_execution(&self, stage: &str, convert_s: f64, exec_s: f64) {
        let mut stats = self.exec_stats.borrow_mut();
        let e = stats.entry(stage.to_string()).or_insert((0, 0.0, 0.0));
        e.0 += 1;
        e.1 += convert_s;
        e.2 += exec_s;
    }

    /// Per-stage cumulative stats (sorted by total execution time, desc).
    pub fn execution_stats(&self) -> Vec<(String, StageStats)> {
        let mut v: Vec<(String, StageStats)> = self
            .exec_stats
            .borrow()
            .iter()
            .map(|(k, &(calls, convert_s, exec_s))| {
                (k.clone(), StageStats { calls, convert_s, exec_s })
            })
            .collect();
        v.sort_by(|a, b| b.1.exec_s.partial_cmp(&a.1.exec_s).unwrap());
        v
    }

    pub fn reset_execution_stats(&self) {
        self.exec_stats.borrow_mut().clear();
    }

    pub fn compile_times_ms(&self) -> Vec<(String, f64)> {
        let mut v: Vec<(String, f64)> =
            self.compile_ms.borrow().iter().map(|(k, t)| (k.clone(), *t)).collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }
}
