//! Artifact store: loads `artifacts/<config>/` and lazily compiles each
//! HLO-text stage into a cached PJRT executable.
//!
//! HLO **text** is the interchange format (see aot.py / DESIGN.md): the
//! xla_extension 0.5.1 proto parser rejects jax>=0.5's 64-bit instruction
//! ids, while the text parser reassigns ids and round-trips cleanly.
//!
//! The store is `Sync` (interior state behind `Mutex`, executables shared
//! as `Arc`): the transport layer runs Phase-2 clients on one thread each,
//! all sharing one store. Locks guard only cache lookups and stat updates;
//! stage execution itself runs outside any lock.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use super::manifest::{Manifest, StageDef};

pub struct ArtifactStore {
    pub dir: PathBuf,
    pub manifest: Manifest,
    client: xla::PjRtClient,
    executables: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
    /// compile-time per stage, for metrics/EXPERIMENTS.md
    compile_ms: Mutex<HashMap<String, f64>>,
    /// per-stage execution stats: (calls, convert_s, exec_s)
    exec_stats: Mutex<HashMap<String, (u64, f64, f64)>>,
}

/// Aggregated execution statistics for one stage. (Lives in the runtime
/// leaf so the backend layer depends on runtime, never the reverse;
/// re-exported as `backend::StageStats`.)
#[derive(Debug, Clone, Copy)]
pub struct StageStats {
    pub calls: u64,
    /// input conversion / assembly time
    pub convert_s: f64,
    /// kernel / executable time
    pub exec_s: f64,
}

impl ArtifactStore {
    /// Open `artifacts_root/<config_name>`.
    pub fn open(artifacts_root: &Path, config_name: &str) -> Result<ArtifactStore> {
        let dir = artifacts_root.join(config_name);
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(ArtifactStore {
            dir,
            manifest,
            client,
            executables: Mutex::new(HashMap::new()),
            compile_ms: Mutex::new(HashMap::new()),
            exec_stats: Mutex::new(HashMap::new()),
        })
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    pub fn stage_def(&self, name: &str) -> Result<&StageDef> {
        self.manifest.stage(name)
    }

    /// Compile (or fetch cached) the executable for a stage.
    pub fn executable(&self, stage: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.executables.lock().unwrap().get(stage) {
            return Ok(exe.clone());
        }
        let def = self.manifest.stage(stage)?;
        let path = self.dir.join(&def.file);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling stage {stage}"))?;
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        self.compile_ms.lock().unwrap().insert(stage.to_string(), ms);
        let exe = Arc::new(exe);
        // Two threads may race to compile the same stage; first insert wins
        // so later callers share one executable.
        let mut cache = self.executables.lock().unwrap();
        let entry = cache.entry(stage.to_string()).or_insert(exe);
        Ok(entry.clone())
    }

    /// Pre-compile a set of stages (warm-up before timed runs).
    pub fn warm(&self, stages: &[&str]) -> Result<()> {
        for s in stages {
            self.executable(s)?;
        }
        Ok(())
    }

    /// Record one execution (called by the PJRT backend).
    pub(crate) fn note_execution(&self, stage: &str, convert_s: f64, exec_s: f64) {
        let mut stats = self.exec_stats.lock().unwrap();
        let e = stats.entry(stage.to_string()).or_insert((0, 0.0, 0.0));
        e.0 += 1;
        e.1 += convert_s;
        e.2 += exec_s;
    }

    /// Per-stage cumulative stats (sorted by total execution time, desc).
    pub fn execution_stats(&self) -> Vec<(String, StageStats)> {
        let mut v: Vec<(String, StageStats)> = self
            .exec_stats
            .lock()
            .unwrap()
            .iter()
            .map(|(k, &(calls, convert_s, exec_s))| {
                (k.clone(), StageStats { calls, convert_s, exec_s })
            })
            .collect();
        v.sort_by(|a, b| b.1.exec_s.total_cmp(&a.1.exec_s));
        v
    }

    pub fn reset_execution_stats(&self) {
        self.exec_stats.lock().unwrap().clear();
    }

    pub fn compile_times_ms(&self) -> Vec<(String, f64)> {
        let mut v: Vec<(String, f64)> =
            self.compile_ms.lock().unwrap().iter().map(|(k, t)| (k.clone(), *t)).collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }
}
