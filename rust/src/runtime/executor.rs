//! Stage executor: assembles positional inputs per the manifest signature,
//! runs the PJRT executable, and maps the output tuple back to named
//! segments / tensors.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

use crate::model::params::SegmentParams;

use super::artifact::ArtifactStore;
use super::manifest::{IoSpec, StageDef};
use super::tensor::HostTensor;

/// Named non-segment inputs to a stage (images, labels, gradients, lr).
pub type TensorInputs<'a> = BTreeMap<&'a str, &'a HostTensor>;

/// A segment input: host tensors (converted per call) or pre-converted
/// literals (the frozen-segment fast path — head/body never change within
/// an SFPrompt run, so the engine converts them once; see EXPERIMENTS.md
/// §Perf for the measured effect).
pub enum SegInput<'a> {
    Host(&'a SegmentParams),
    Literals(&'a [xla::Literal]),
}

pub type SegmentInputs<'a> = BTreeMap<&'a str, SegInput<'a>>;

/// Convert a segment's tensors to literals once (for `SegInput::Literals`).
pub fn segment_literals(params: &SegmentParams) -> Result<Vec<xla::Literal>> {
    params.tensors.iter().map(|t| t.to_literal()).collect()
}

/// Structured outputs of a stage execution.
#[derive(Debug, Default)]
pub struct StageOutputs {
    pub segments: BTreeMap<String, SegmentParams>,
    pub tensors: BTreeMap<String, HostTensor>,
}

impl StageOutputs {
    pub fn tensor(&self, name: &str) -> Result<&HostTensor> {
        self.tensors.get(name).ok_or_else(|| anyhow!("stage output missing tensor {name:?}"))
    }

    pub fn segment(&self, name: &str) -> Result<&SegmentParams> {
        self.segments.get(name).ok_or_else(|| anyhow!("stage output missing segment {name:?}"))
    }

    pub fn take_segment(&mut self, name: &str) -> Result<SegmentParams> {
        self.segments.remove(name).ok_or_else(|| anyhow!("stage output missing segment {name:?}"))
    }

    pub fn loss(&self) -> Result<f32> {
        Ok(self.tensor("loss")?.as_f32()[0])
    }
}

enum InputRef<'a> {
    Owned(usize),
    Cached(&'a xla::Literal),
}

pub struct Executor;

impl Executor {
    /// Run `stage` with host-resident segment params and named tensors.
    ///
    /// Inputs are matched positionally against the manifest: a
    /// `IoSpec::Segment` consumes all tensors of that segment from
    /// `segments`, a `IoSpec::Tensor`/`Scalar` consumes the named entry
    /// from `tensors`.
    pub fn run(
        store: &ArtifactStore,
        stage_name: &str,
        segments: &BTreeMap<&str, &SegmentParams>,
        tensors: &TensorInputs,
    ) -> Result<StageOutputs> {
        let mixed: SegmentInputs =
            segments.iter().map(|(k, v)| (*k, SegInput::Host(v))).collect();
        Self::run_mixed(store, stage_name, &mixed, tensors)
    }

    /// Like [`Executor::run`] but segments may be pre-converted literals
    /// (the frozen-segment fast path).
    pub fn run_mixed(
        store: &ArtifactStore,
        stage_name: &str,
        segments: &SegmentInputs,
        tensors: &TensorInputs,
    ) -> Result<StageOutputs> {
        let t0 = std::time::Instant::now();
        let def = store.stage_def(stage_name)?.clone();
        let (owned, order) = Self::assemble_inputs(store, &def, segments, tensors)?;
        // `order` indexes into owned (>=0) or borrows cached literals (<0
        // encoded as (seg, idx)); build the final &Literal list.
        let mut refs: Vec<&xla::Literal> = Vec::with_capacity(order.len());
        for item in &order {
            match item {
                InputRef::Owned(i) => refs.push(&owned[*i]),
                InputRef::Cached(lit) => refs.push(lit),
            }
        }
        let convert_s = t0.elapsed().as_secs_f64();
        let exe = store.executable(stage_name)?;
        let t1 = std::time::Instant::now();
        let result = exe
            .execute::<&xla::Literal>(&refs)
            .with_context(|| format!("executing stage {stage_name}"))?;
        let tuple = result
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| anyhow!("stage {stage_name} returned no buffers"))?
            .to_literal_sync()
            .context("fetch result literal")?;
        let exec_s = t1.elapsed().as_secs_f64();
        // aot.py lowers with return_tuple=True: always a (possibly 1-) tuple.
        let outs = tuple.to_tuple().context("decompose output tuple")?;
        let out = Self::map_outputs(store, &def, outs);
        store.note_execution(stage_name, convert_s, exec_s);
        out
    }

    fn assemble_inputs<'a>(
        store: &ArtifactStore,
        def: &StageDef,
        segments: &'a SegmentInputs,
        tensors: &TensorInputs,
    ) -> Result<(Vec<xla::Literal>, Vec<InputRef<'a>>)> {
        let arity = store.manifest.stage_input_arity(def);
        let mut owned = Vec::with_capacity(arity);
        let mut order = Vec::with_capacity(arity);
        for io in &def.inputs {
            match io {
                IoSpec::Segment(seg) => {
                    let input = segments
                        .get(seg.as_str())
                        .ok_or_else(|| anyhow!("stage {} needs segment {seg:?}", def.name))?;
                    let expected = store.manifest.segment(seg)?.len();
                    match input {
                        SegInput::Host(params) => {
                            if params.tensors.len() != expected {
                                bail!(
                                    "segment {seg:?} has {} tensors, manifest expects {expected}",
                                    params.tensors.len()
                                );
                            }
                            for t in &params.tensors {
                                owned.push(t.to_literal()?);
                                order.push(InputRef::Owned(owned.len() - 1));
                            }
                        }
                        SegInput::Literals(lits) => {
                            if lits.len() != expected {
                                bail!(
                                    "segment {seg:?} has {} literals, manifest expects {expected}",
                                    lits.len()
                                );
                            }
                            for l in *lits {
                                order.push(InputRef::Cached(l));
                            }
                        }
                    }
                }
                IoSpec::Tensor { name, shape, .. } => {
                    let t = tensors
                        .get(name.as_str())
                        .ok_or_else(|| anyhow!("stage {} needs tensor {name:?}", def.name))?;
                    if &t.shape != shape {
                        bail!(
                            "tensor {name:?}: shape {:?} != manifest {:?}",
                            t.shape,
                            shape
                        );
                    }
                    owned.push(t.to_literal()?);
                    order.push(InputRef::Owned(owned.len() - 1));
                }
                IoSpec::Scalar(name) => {
                    let t = tensors
                        .get(name.as_str())
                        .ok_or_else(|| anyhow!("stage {} needs scalar {name:?}", def.name))?;
                    owned.push(t.to_literal()?);
                    order.push(InputRef::Owned(owned.len() - 1));
                }
            }
        }
        Ok((owned, order))
    }

    fn map_outputs(
        store: &ArtifactStore,
        def: &StageDef,
        outs: Vec<xla::Literal>,
    ) -> Result<StageOutputs> {
        let mut result = StageOutputs::default();
        let mut it = outs.into_iter();
        for io in &def.outputs {
            match io {
                IoSpec::Segment(seg) => {
                    let defs = store.manifest.segment(seg)?;
                    let mut tensors = Vec::with_capacity(defs.len());
                    for d in defs {
                        let lit = it
                            .next()
                            .ok_or_else(|| anyhow!("stage {}: output tuple too short", def.name))?;
                        tensors.push(HostTensor::from_literal(&lit, &d.shape, d.dtype)?);
                    }
                    result
                        .segments
                        .insert(seg.clone(), SegmentParams { segment: seg.clone(), tensors });
                }
                IoSpec::Tensor { name, shape, dtype } => {
                    let lit = it
                        .next()
                        .ok_or_else(|| anyhow!("stage {}: output tuple too short", def.name))?;
                    result
                        .tensors
                        .insert(name.clone(), HostTensor::from_literal(&lit, shape, *dtype)?);
                }
                IoSpec::Scalar(name) => {
                    let lit = it
                        .next()
                        .ok_or_else(|| anyhow!("stage {}: output tuple too short", def.name))?;
                    result.tensors.insert(
                        name.clone(),
                        HostTensor::from_literal(&lit, &[], super::tensor::Dtype::F32)?,
                    );
                }
            }
        }
        if it.next().is_some() {
            bail!("stage {}: output tuple longer than manifest", def.name);
        }
        Ok(result)
    }
}
