//! Simulated network: message types, exact byte accounting, and the
//! paper's bandwidth/latency model.
//!
//! Communication **cost** — the paper's headline metric — is measured here
//! in exact bytes per message and aggregated per round, per client, per
//! direction, and per message kind. Latency is derived from configurable
//! up/downlink rates following the paper's analytic model (§3.5): with K
//! clients sharing rate R, each effective link runs at R/K.

use std::collections::BTreeMap;

/// What a message carries (drives Table 2 style breakdowns).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum MsgKind {
    /// Server -> client: client-side model (head+tail) at round start.
    ModelDistribution,
    /// Client -> server: smashed data (cut-layer activations).
    SmashedData,
    /// Server -> client: body output activations.
    BodyOutput,
    /// Client -> server: gradient w.r.t. body output.
    GradBodyOut,
    /// Server -> client: gradient w.r.t. smashed data.
    GradSmashed,
    /// Client -> server: updated tail + prompt for aggregation.
    Upload,
    /// Server -> client: aggregated tail + prompt.
    AggregateBroadcast,
    /// Full model in either direction (FL baseline).
    FullModel,
    /// Client -> server: the client failed mid-round (control frame, no
    /// payload); the server tears the round down instead of deadlocking.
    Abort,
}

impl MsgKind {
    pub fn label(&self) -> &'static str {
        match self {
            MsgKind::ModelDistribution => "model_distribution",
            MsgKind::SmashedData => "smashed_data",
            MsgKind::BodyOutput => "body_output",
            MsgKind::GradBodyOut => "grad_body_out",
            MsgKind::GradSmashed => "grad_smashed",
            MsgKind::Upload => "upload",
            MsgKind::AggregateBroadcast => "aggregate_broadcast",
            MsgKind::FullModel => "full_model",
            MsgKind::Abort => "abort",
        }
    }

    /// Wire code stamped into transport frame headers (docs/WIRE.md).
    pub fn code(&self) -> u8 {
        match self {
            MsgKind::ModelDistribution => 0,
            MsgKind::SmashedData => 1,
            MsgKind::BodyOutput => 2,
            MsgKind::GradBodyOut => 3,
            MsgKind::GradSmashed => 4,
            MsgKind::Upload => 5,
            MsgKind::AggregateBroadcast => 6,
            MsgKind::FullModel => 7,
            MsgKind::Abort => 8,
        }
    }

    pub fn from_code(code: u8) -> anyhow::Result<MsgKind> {
        Ok(match code {
            0 => MsgKind::ModelDistribution,
            1 => MsgKind::SmashedData,
            2 => MsgKind::BodyOutput,
            3 => MsgKind::GradBodyOut,
            4 => MsgKind::GradSmashed,
            5 => MsgKind::Upload,
            6 => MsgKind::AggregateBroadcast,
            7 => MsgKind::FullModel,
            8 => MsgKind::Abort,
            other => anyhow::bail!("unknown message kind code {other}"),
        })
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    Uplink,   // client -> server
    Downlink, // server -> client
}

/// Link-rate model. The paper normalises up/downlink to a single rate R
/// shared by K concurrent clients.
///
/// This is the **homogeneous** link model: `crate::sim::Fleet` subsumes it
/// (per-client link rates + an optional shared bottleneck pool) and the
/// engines charge time through the fleet's `SimClock`; a run without a
/// fleet spec wraps this model in `Fleet::homogeneous`, reproducing the
/// same transfer arithmetic bit-for-bit.
#[derive(Debug, Clone, Copy)]
pub struct NetworkModel {
    /// Link rate in bytes/second (both directions, per the paper).
    pub rate_bytes_per_s: f64,
    /// Number of clients sharing the link concurrently.
    pub sharing_clients: usize,
}

impl NetworkModel {
    pub fn effective_rate(&self) -> f64 {
        self.rate_bytes_per_s / self.sharing_clients.max(1) as f64
    }

    /// Transfer time for `bytes` under the shared-rate model. A zero or
    /// negative configured rate is a caller bug (it would silently yield
    /// `inf`/negative latency): debug builds assert, release builds clamp
    /// the rate to a tiny positive floor so latency stays finite and
    /// non-negative.
    pub fn transfer_time_s(&self, bytes: usize) -> f64 {
        let rate = self.effective_rate();
        debug_assert!(
            rate > 0.0 && rate.is_finite(),
            "NetworkModel rate must be positive and finite, got {rate} \
             (rate_bytes_per_s={}, sharing_clients={})",
            self.rate_bytes_per_s,
            self.sharing_clients
        );
        // Floor well above the subnormal range: dividing by
        // f64::MIN_POSITIVE would overflow straight back to `inf`.
        bytes as f64 / rate.max(1e-300)
    }
}

impl Default for NetworkModel {
    fn default() -> Self {
        // 100 Mbit/s shared by the 5 selected clients — a reasonable edge
        // uplink; only *ratios* between methods matter for the tables.
        NetworkModel { rate_bytes_per_s: 12.5e6, sharing_clients: 5 }
    }
}

/// Byte meter: every simulated transmission is recorded here.
///
/// Two parallel tallies per message kind: **wire** bytes (the encoded
/// frame length that actually crossed the transport — what latency is
/// charged on) and **raw** bytes (the dense-f32 frame the same payload
/// would have occupied). They differ only where a precision or
/// compression scheme shrank the payload, so `wire / raw` is the measured
/// compression ratio (1.0 for an uncompressed run).
#[derive(Debug, Default, Clone)]
pub struct ByteMeter {
    pub uplink: u64,
    pub downlink: u64,
    pub by_kind: BTreeMap<&'static str, u64>,
    /// Dense-f32 equivalent of every recorded frame, per kind.
    pub raw_by_kind: BTreeMap<&'static str, u64>,
    pub messages: u64,
}

impl ByteMeter {
    /// Record an uncompressed transmission (raw == wire).
    pub fn record(&mut self, kind: MsgKind, dir: Direction, bytes: usize) {
        self.record_with_raw(kind, dir, bytes, bytes);
    }

    /// Record a transmission whose dense-f32 equivalent (`raw_bytes`)
    /// differs from its on-the-wire length.
    pub fn record_with_raw(
        &mut self,
        kind: MsgKind,
        dir: Direction,
        wire_bytes: usize,
        raw_bytes: usize,
    ) {
        match dir {
            Direction::Uplink => self.uplink += wire_bytes as u64,
            Direction::Downlink => self.downlink += wire_bytes as u64,
        }
        *self.by_kind.entry(kind.label()).or_insert(0) += wire_bytes as u64;
        *self.raw_by_kind.entry(kind.label()).or_insert(0) += raw_bytes as u64;
        self.messages += 1;
    }

    pub fn total(&self) -> u64 {
        self.uplink + self.downlink
    }

    /// Dense-f32 equivalent of all recorded traffic.
    pub fn raw_total(&self) -> u64 {
        self.raw_by_kind.values().sum()
    }

    /// Measured wire bytes over their dense-f32 equivalent: < 1 when
    /// precision/compression saved traffic, 1.0 for dense runs (and for
    /// an empty meter).
    pub fn compression_ratio(&self) -> f64 {
        let raw = self.raw_total();
        if raw == 0 {
            1.0
        } else {
            self.total() as f64 / raw as f64
        }
    }

    pub fn merge(&mut self, other: &ByteMeter) {
        self.uplink += other.uplink;
        self.downlink += other.downlink;
        self.messages += other.messages;
        for (k, v) in &other.by_kind {
            *self.by_kind.entry(k).or_insert(0) += v;
        }
        for (k, v) in &other.raw_by_kind {
            *self.raw_by_kind.entry(k).or_insert(0) += v;
        }
    }

    pub fn mb(&self) -> f64 {
        self.total() as f64 / 1e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meter_accumulates_by_kind_and_direction() {
        let mut m = ByteMeter::default();
        m.record(MsgKind::SmashedData, Direction::Uplink, 100);
        m.record(MsgKind::BodyOutput, Direction::Downlink, 50);
        m.record(MsgKind::SmashedData, Direction::Uplink, 100);
        assert_eq!(m.uplink, 200);
        assert_eq!(m.downlink, 50);
        assert_eq!(m.total(), 250);
        assert_eq!(m.by_kind["smashed_data"], 200);
        assert_eq!(m.messages, 3);
    }

    #[test]
    fn merge_sums() {
        let mut a = ByteMeter::default();
        a.record(MsgKind::Upload, Direction::Uplink, 10);
        let mut b = ByteMeter::default();
        b.record(MsgKind::Upload, Direction::Uplink, 5);
        b.record(MsgKind::FullModel, Direction::Downlink, 7);
        a.merge(&b);
        assert_eq!(a.total(), 22);
        assert_eq!(a.by_kind["upload"], 15);
        assert_eq!(a.raw_total(), 22, "plain records carry raw == wire");
    }

    #[test]
    fn raw_bytes_drive_the_compression_ratio() {
        let mut m = ByteMeter::default();
        assert_eq!(m.compression_ratio(), 1.0, "empty meter is ratio 1");
        m.record(MsgKind::ModelDistribution, Direction::Downlink, 100);
        assert_eq!(m.compression_ratio(), 1.0);
        m.record_with_raw(MsgKind::Upload, Direction::Uplink, 25, 400);
        assert_eq!(m.total(), 125);
        assert_eq!(m.raw_total(), 500);
        assert_eq!(m.by_kind["upload"], 25);
        assert_eq!(m.raw_by_kind["upload"], 400);
        assert!((m.compression_ratio() - 0.25).abs() < 1e-12);

        let mut other = ByteMeter::default();
        other.record_with_raw(MsgKind::Upload, Direction::Uplink, 25, 400);
        m.merge(&other);
        assert_eq!(m.raw_by_kind["upload"], 800);
        assert_eq!(m.by_kind["upload"], 50);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "rate must be positive")]
    fn zero_rate_asserts_in_debug() {
        let net = NetworkModel { rate_bytes_per_s: 0.0, sharing_clients: 1 };
        let _ = net.transfer_time_s(100);
    }

    #[test]
    #[cfg(not(debug_assertions))]
    fn zero_rate_clamps_in_release() {
        for rate in [0.0, -5.0] {
            let net = NetworkModel { rate_bytes_per_s: rate, sharing_clients: 1 };
            let t = net.transfer_time_s(100);
            assert!(t.is_finite() && t >= 0.0, "rate {rate} -> {t}");
        }
    }

    #[test]
    fn msg_kind_codes_roundtrip() {
        for kind in [
            MsgKind::ModelDistribution,
            MsgKind::SmashedData,
            MsgKind::BodyOutput,
            MsgKind::GradBodyOut,
            MsgKind::GradSmashed,
            MsgKind::Upload,
            MsgKind::AggregateBroadcast,
            MsgKind::FullModel,
            MsgKind::Abort,
        ] {
            assert_eq!(MsgKind::from_code(kind.code()).unwrap(), kind);
        }
        assert!(MsgKind::from_code(200).is_err());
    }

    #[test]
    fn transfer_time_respects_rate_sharing() {
        let net = NetworkModel { rate_bytes_per_s: 1000.0, sharing_clients: 4 };
        assert!((net.transfer_time_s(500) - 2.0).abs() < 1e-9); // 500 / (1000/4)
    }
}
