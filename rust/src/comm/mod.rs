//! Simulated network: message types, exact byte accounting, and the
//! paper's bandwidth/latency model.
//!
//! Communication **cost** — the paper's headline metric — is measured here
//! in exact bytes per message and aggregated per round, per client, per
//! direction, and per message kind. Latency is derived from configurable
//! up/downlink rates following the paper's analytic model (§3.5): with K
//! clients sharing rate R, each effective link runs at R/K.

use std::collections::BTreeMap;

/// What a message carries (drives Table 2 style breakdowns).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum MsgKind {
    /// Server -> client: client-side model (head+tail) at round start.
    ModelDistribution,
    /// Client -> server: smashed data (cut-layer activations).
    SmashedData,
    /// Server -> client: body output activations.
    BodyOutput,
    /// Client -> server: gradient w.r.t. body output.
    GradBodyOut,
    /// Server -> client: gradient w.r.t. smashed data.
    GradSmashed,
    /// Client -> server: updated tail + prompt for aggregation.
    Upload,
    /// Server -> client: aggregated tail + prompt.
    AggregateBroadcast,
    /// Full model in either direction (FL baseline).
    FullModel,
}

impl MsgKind {
    pub fn label(&self) -> &'static str {
        match self {
            MsgKind::ModelDistribution => "model_distribution",
            MsgKind::SmashedData => "smashed_data",
            MsgKind::BodyOutput => "body_output",
            MsgKind::GradBodyOut => "grad_body_out",
            MsgKind::GradSmashed => "grad_smashed",
            MsgKind::Upload => "upload",
            MsgKind::AggregateBroadcast => "aggregate_broadcast",
            MsgKind::FullModel => "full_model",
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    Uplink,   // client -> server
    Downlink, // server -> client
}

/// Link-rate model. The paper normalises up/downlink to a single rate R
/// shared by K concurrent clients.
#[derive(Debug, Clone, Copy)]
pub struct NetworkModel {
    /// Link rate in bytes/second (both directions, per the paper).
    pub rate_bytes_per_s: f64,
    /// Number of clients sharing the link concurrently.
    pub sharing_clients: usize,
}

impl NetworkModel {
    pub fn effective_rate(&self) -> f64 {
        self.rate_bytes_per_s / self.sharing_clients.max(1) as f64
    }

    pub fn transfer_time_s(&self, bytes: usize) -> f64 {
        bytes as f64 / self.effective_rate()
    }
}

impl Default for NetworkModel {
    fn default() -> Self {
        // 100 Mbit/s shared by the 5 selected clients — a reasonable edge
        // uplink; only *ratios* between methods matter for the tables.
        NetworkModel { rate_bytes_per_s: 12.5e6, sharing_clients: 5 }
    }
}

/// Byte meter: every simulated transmission is recorded here.
#[derive(Debug, Default, Clone)]
pub struct ByteMeter {
    pub uplink: u64,
    pub downlink: u64,
    pub by_kind: BTreeMap<&'static str, u64>,
    pub messages: u64,
}

impl ByteMeter {
    pub fn record(&mut self, kind: MsgKind, dir: Direction, bytes: usize) {
        match dir {
            Direction::Uplink => self.uplink += bytes as u64,
            Direction::Downlink => self.downlink += bytes as u64,
        }
        *self.by_kind.entry(kind.label()).or_insert(0) += bytes as u64;
        self.messages += 1;
    }

    pub fn total(&self) -> u64 {
        self.uplink + self.downlink
    }

    pub fn merge(&mut self, other: &ByteMeter) {
        self.uplink += other.uplink;
        self.downlink += other.downlink;
        self.messages += other.messages;
        for (k, v) in &other.by_kind {
            *self.by_kind.entry(k).or_insert(0) += v;
        }
    }

    pub fn mb(&self) -> f64 {
        self.total() as f64 / 1e6
    }
}

/// A simulated duplex link between the server and one client. Owns a meter
/// and a logical clock so per-client latency can be reported.
#[derive(Debug, Default)]
pub struct SimLink {
    pub meter: ByteMeter,
    pub elapsed_s: f64,
}

impl SimLink {
    /// Transmit `bytes`; returns the transfer time under `net`.
    pub fn send(
        &mut self,
        net: &NetworkModel,
        kind: MsgKind,
        dir: Direction,
        bytes: usize,
    ) -> f64 {
        self.meter.record(kind, dir, bytes);
        let t = net.transfer_time_s(bytes);
        self.elapsed_s += t;
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meter_accumulates_by_kind_and_direction() {
        let mut m = ByteMeter::default();
        m.record(MsgKind::SmashedData, Direction::Uplink, 100);
        m.record(MsgKind::BodyOutput, Direction::Downlink, 50);
        m.record(MsgKind::SmashedData, Direction::Uplink, 100);
        assert_eq!(m.uplink, 200);
        assert_eq!(m.downlink, 50);
        assert_eq!(m.total(), 250);
        assert_eq!(m.by_kind["smashed_data"], 200);
        assert_eq!(m.messages, 3);
    }

    #[test]
    fn merge_sums() {
        let mut a = ByteMeter::default();
        a.record(MsgKind::Upload, Direction::Uplink, 10);
        let mut b = ByteMeter::default();
        b.record(MsgKind::Upload, Direction::Uplink, 5);
        b.record(MsgKind::FullModel, Direction::Downlink, 7);
        a.merge(&b);
        assert_eq!(a.total(), 22);
        assert_eq!(a.by_kind["upload"], 15);
    }

    #[test]
    fn link_clock_advances_with_rate_sharing() {
        let net = NetworkModel { rate_bytes_per_s: 1000.0, sharing_clients: 4 };
        let mut link = SimLink::default();
        let t = link.send(&net, MsgKind::SmashedData, Direction::Uplink, 500);
        assert!((t - 2.0).abs() < 1e-9); // 500 / (1000/4)
        assert!((link.elapsed_s - 2.0).abs() < 1e-9);
    }
}
