//! Prometheus text exposition over a minimal HTTP/1.0 responder.
//!
//! `sfprompt serve --prom ADDR` spawns [`spawn_metrics_server`], which
//! answers `GET /metrics` with the live [`MetricsRegistry`] rendered by
//! `MetricsRegistry::to_prometheus_text` (text format 0.0.4: one `# TYPE`
//! per family, histograms as cumulative `_bucket`/`_sum`/`_count`).
//!
//! Zero dependencies and deliberately tiny: this is not a web server. One
//! request per connection, `Connection: close`, a bounded header read with
//! timeouts, and only two routes (`/` banner, `/metrics`). That is exactly
//! the subset a Prometheus scraper (or `curl`) exercises, and nothing a
//! hostile peer can wedge: a slow-loris connection times out, an oversized
//! header is cut off at 8 KiB, and every connection is handled inline on
//! the responder thread — a stalled scrape delays the next scrape, never
//! the federation.
//!
//! [`MetricsRegistry`]: crate::telemetry::MetricsRegistry

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::telemetry::Telemetry;

/// Per-connection socket timeout: a scraper that stalls longer gets cut.
const HTTP_IO_TIMEOUT: Duration = Duration::from_secs(5);
/// Request headers larger than this are truncated (the request line is all
/// we parse anyway).
const MAX_HEADER_BYTES: usize = 8 * 1024;

/// Running exporter; stops (and joins its thread) on drop.
pub struct PromHandle {
    stop: Arc<AtomicBool>,
    join: Option<JoinHandle<()>>,
    addr: SocketAddr,
}

impl PromHandle {
    /// The bound address (useful with port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Drop for PromHandle {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(join) = self.join.take() {
            let _ = join.join();
        }
    }
}

/// Bind `addr` and serve `GET /metrics` from `telemetry` on a background
/// thread until the handle is dropped.
pub fn spawn_metrics_server(addr: &str, telemetry: Arc<Telemetry>) -> Result<PromHandle> {
    let listener = TcpListener::bind(addr)
        .with_context(|| format!("binding Prometheus exporter on {addr}"))?;
    let local = listener.local_addr().context("exporter local_addr")?;
    listener.set_nonblocking(true).context("exporter set_nonblocking")?;
    let stop = Arc::new(AtomicBool::new(false));
    let thread_stop = stop.clone();
    let join = std::thread::spawn(move || responder_loop(listener, &telemetry, &thread_stop));
    Ok(PromHandle { stop, join: Some(join), addr: local })
}

fn responder_loop(listener: TcpListener, telemetry: &Telemetry, stop: &AtomicBool) {
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _)) => {
                let _ = stream.set_nonblocking(false);
                let _ = answer(stream, telemetry); // a bad scrape is the scraper's problem
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(50));
            }
            Err(_) => return,
        }
    }
}

fn answer(mut stream: TcpStream, telemetry: &Telemetry) -> std::io::Result<()> {
    stream.set_read_timeout(Some(HTTP_IO_TIMEOUT))?;
    stream.set_write_timeout(Some(HTTP_IO_TIMEOUT))?;

    let mut head = Vec::new();
    let mut chunk = [0u8; 1024];
    while !head.windows(4).any(|w| w == b"\r\n\r\n") && head.len() < MAX_HEADER_BYTES {
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            break;
        }
        head.extend_from_slice(&chunk[..n]);
    }
    let head = String::from_utf8_lossy(&head);
    let mut request_line = head.lines().next().unwrap_or("").split_whitespace();
    let method = request_line.next().unwrap_or("");
    let path = request_line.next().unwrap_or("");

    let (status, content_type, body) = match (method, path) {
        ("GET", "/metrics") | ("GET", "/metrics/") => (
            "200 OK",
            "text/plain; version=0.0.4; charset=utf-8",
            telemetry.metrics.to_prometheus_text(),
        ),
        ("GET", "/") => (
            "200 OK",
            "text/plain; charset=utf-8",
            "sfprompt metrics exporter; scrape /metrics\n".to_string(),
        ),
        _ => ("404 Not Found", "text/plain; charset=utf-8", "not found\n".to_string()),
    };
    let response = format!(
        "HTTP/1.0 {status}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(response.as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn http_get(addr: SocketAddr, path: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(format!("GET {path} HTTP/1.0\r\nHost: test\r\n\r\n").as_bytes()).unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn serves_metrics_with_type_headers_and_404s_elsewhere() {
        let telemetry = Arc::new(Telemetry::new());
        telemetry.metrics.counter_add("net/tx_frames", 7);
        telemetry.metrics.observe("stage/head_forward", 0.25);
        let handle = spawn_metrics_server("127.0.0.1:0", telemetry.clone()).unwrap();

        let resp = http_get(handle.addr(), "/metrics");
        assert!(resp.starts_with("HTTP/1.0 200 OK\r\n"), "got: {resp}");
        assert!(resp.contains("Content-Type: text/plain; version=0.0.4"));
        let body = resp.split("\r\n\r\n").nth(1).unwrap();
        assert!(body.contains("# TYPE sfprompt_net counter"), "body: {body}");
        assert!(body.contains("sfprompt_net{item=\"tx_frames\"} 7"), "body: {body}");
        assert!(body.contains("# TYPE sfprompt_stage histogram"), "body: {body}");
        assert!(body.contains("le=\"+Inf\""), "body: {body}");

        let missing = http_get(handle.addr(), "/nope");
        assert!(missing.starts_with("HTTP/1.0 404"), "got: {missing}");

        let banner = http_get(handle.addr(), "/");
        assert!(banner.contains("scrape /metrics"));
        drop(handle); // joins the responder thread
    }
}
