//! The networked coordinator: one long-lived server process drives the
//! SFPrompt federation over real TCP sockets.
//!
//! [`serve`] listens, admits `processes` client processes (each owning the
//! logical clients `cid % processes == p`), then runs the standard
//! [`drive`] round loop against a [`RemoteEngine`] — the same
//! `distribute_model` / `serve_round` code the in-process engine uses,
//! pointed at a socket-backed [`FrameHub`] instead of the mpsc `Hub`.
//! Because every data frame on the wire is byte-for-byte the in-process
//! `encode_frame` output and every RNG stream is derived from the spec's
//! seed in the canonical order ([`build_clients`]), the resulting
//! [`RunReport`] is **byte-identical** to the same spec run in one process
//! (modulo wall-clock timings) — `tests/net.rs` pins this.
//!
//! Threading model (all `std`, no async):
//!
//! * admission happens inline on the accept loop;
//! * one **reader thread** per client process funnels inbound messages
//!   into a shared mpsc channel (frames and round reports alike);
//! * writes go through per-process `Mutex<TcpLink>` write halves;
//! * a background **acceptor** admits event-stream observers mid-run,
//!   answers one-shot `status` probes (see [`HealthRegistry`] and
//!   `docs/OPS.md`), drives the event sink's heartbeat, and politely
//!   rejects latecomer clients;
//! * the driver thread runs the round loop exactly like the in-process
//!   path, with a [`HealthObserver`] teeing every callback into the
//!   health registry and the always-on [`FlightRecorder`].
//!
//! Failure surface: a client that disconnects or aborts mid-run fails the
//! round with a typed, attributed error; on any exit (success or error)
//! the server sends a `Shutdown` control to every client and tears the
//! sockets down so nothing hangs.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::backend::{Backend, PreparedSegment};
use crate::comm::ByteMeter;
use crate::data::SynthDataset;
use crate::federation::client::build_clients;
use crate::federation::engine::{distribute_model, serve_round};
use crate::federation::{
    drive, FedConfig, FederatedRun, Method, RoundObserver, RunReport, RunSpec, Tee,
};
use crate::metrics::{evaluate, RoundRecord, RunHistory};
use crate::model::{init_params, ParamSet};
use crate::sim::Fleet;
use crate::telemetry::{FlightRecorder, HealthRegistry, Ledger};
use crate::transport::{Frame, FrameHub, Transport, WireFormat, WIRE_VERSION};
use crate::util::json::Json;
use crate::util::rng::{seeds, Rng};

use super::control::{Control, SHUTDOWN_COMPLETE};
use super::events::{EventSink, EventStreamObserver, HealthObserver};
use super::tcp::TcpLink;
use super::wire::{NetError, NetMsg, NET_PROTO_VERSION};

/// Server-side configuration for one served run.
pub struct ServeOptions {
    /// Client processes to admit before the round loop starts
    /// (1..=num_clients; logical clients are dealt round-robin).
    pub processes: usize,
    /// Identifier clients must echo in their Hello (empty client-side
    /// run_id matches anything).
    pub run_id: String,
    /// Per-connection read/write timeout.
    pub io_timeout: Duration,
    /// Event-line fan-out (file and/or subscribed observer sockets).
    pub events: EventSink,
    /// Live health book-keeping; `status` requests snapshot it at any
    /// point in the run (shared so the caller can inspect it afterwards).
    pub health: Arc<HealthRegistry>,
    /// Always-on bounded ring of recent health/span entries; dumped as a
    /// post-mortem when the run fails or an anomaly fires.
    pub flight: Arc<FlightRecorder>,
    /// Where to dump the flight ring on failure/anomaly (None = never).
    pub postmortem: Option<PathBuf>,
    /// Suppress per-connection stderr chatter.
    pub quiet: bool,
}

impl Default for ServeOptions {
    fn default() -> ServeOptions {
        ServeOptions {
            processes: 1,
            run_id: String::new(),
            io_timeout: Duration::from_secs(60),
            events: EventSink::default(),
            health: Arc::new(HealthRegistry::new()),
            flight: Arc::new(FlightRecorder::new()),
            postmortem: None,
            quiet: false,
        }
    }
}

/// Point-in-time `status` reply body: the health registry snapshot plus
/// run identity and the hottest telemetry stages (when tracing is on).
/// Schema documented in `docs/OPS.md`; consumed by `sfprompt top`.
fn status_snapshot(spec: &RunSpec, opts: &ServeOptions) -> Json {
    let mut o = match opts.health.status_json() {
        Json::Obj(o) => o,
        _ => unreachable!("status_json always returns an object"),
    };
    o.insert("run_id".into(), Json::Str(opts.run_id.clone()));
    o.insert("processes".into(), Json::Num(opts.processes as f64));
    o.insert("config".into(), Json::Str(spec.config.clone()));
    o.insert("flight_recorded".into(), Json::Num(opts.flight.recorded() as f64));
    let mut hottest = Vec::new();
    if let Some(t) = crate::telemetry::active() {
        // Aggregate closed spans by cat/name, keep the five hottest.
        let mut totals: BTreeMap<(String, String), (f64, u64)> = BTreeMap::new();
        for r in t.tracer.records() {
            let e = totals.entry((r.cat.to_string(), r.name)).or_insert((0.0, 0));
            e.0 += r.end_s - r.start_s;
            e.1 += 1;
        }
        let mut rows: Vec<_> = totals.into_iter().collect();
        rows.sort_by(|a, b| b.1 .0.total_cmp(&a.1 .0));
        for ((cat, name), (total_s, count)) in rows.into_iter().take(5) {
            let mut row = BTreeMap::new();
            row.insert("cat".into(), Json::Str(cat));
            row.insert("name".into(), Json::Str(name));
            row.insert("total_s".into(), Json::Num(total_s));
            row.insert("count".into(), Json::Num(count as f64));
            hottest.push(Json::Obj(row));
        }
    }
    o.insert("hottest".into(), Json::Arr(hottest));
    Json::Obj(o)
}

/// The logical clients process `p` of `n` owns.
pub fn owned_clients(num_clients: usize, processes: usize, p: usize) -> Vec<usize> {
    (0..num_clients).filter(|cid| cid % processes == p).collect()
}

/// "Now" on the coordinator's trace timebase (the tracer epoch every
/// coordinator span is stamped against); 0.0 when the server is untraced —
/// the NTP legs are then meaningless and clients ignore them.
fn server_now_s() -> f64 {
    crate::telemetry::active().map_or(0.0, |t| t.tracer.now_s())
}

/// Deterministic 128-bit trace id for a served run: FNV-1a over the run id
/// and seed. Deterministic so re-serving the same spec yields joinable
/// artifacts; forced non-zero because zero means "untraced".
fn derive_trace_id(run_id: &str, seed: u64) -> u128 {
    let mut h: u128 = 0x6c62272e07bb014262b821756295c58d;
    let prime: u128 = 0x0000000001000000000000000000013b;
    for b in run_id.bytes().chain(seed.to_le_bytes()) {
        h ^= b as u128;
        h = h.wrapping_mul(prime);
    }
    h | 1
}

/// Start of client process `p`'s span-id block: `(p + 1) << 40` keeps every
/// id below 2^53 (exact in JSON's f64) while leaving each process a
/// trillion ids. The coordinator allocates from base 0.
pub(crate) fn span_base_for(process: usize) -> u64 {
    ((process as u64) + 1) << 40
}

/// Inbound traffic from the reader threads: data frames for the round
/// router, round reports for the loss bookkeeping.
enum HubMsg {
    Frame(Frame, usize),
    Report { round: u32, client: u32, local_losses: Vec<f64>, split_losses: Vec<f64> },
}

/// Run-lifetime socket state shared by every round.
struct NetRuntime {
    /// Write halves, indexed by process.
    writers: Vec<Mutex<TcpLink>>,
    /// Shared inbound queue fed by the reader threads.
    rx: Receiver<Result<HubMsg>>,
    processes: usize,
    /// Reports that arrived while the router was waiting for frames
    /// (defensive; the lock-step protocol makes this rare).
    stash: RefCell<Vec<HubMsg>>,
}

impl NetRuntime {
    fn next_msg(&self) -> Result<HubMsg> {
        match self.rx.recv() {
            Ok(Ok(msg)) => Ok(msg),
            Ok(Err(e)) => Err(e),
            Err(_) => Err(anyhow!("all client connections closed")),
        }
    }
}

/// One round's [`FrameHub`] view of the socket fabric: slot-addressed
/// sends resolve through `selected` to the owning process's write half.
struct RoundHub<'a> {
    net: &'a NetRuntime,
    selected: &'a [usize],
}

impl FrameHub for RoundHub<'_> {
    fn send_to(&self, slot: usize, frame: &Frame, wire: WireFormat) -> Result<usize> {
        let cid =
            *self.selected.get(slot).ok_or_else(|| anyhow!("no selected slot {slot}"))?;
        let process = cid % self.net.processes;
        let mut link = self.net.writers[process].lock().expect("writer lock poisoned");
        link.send(frame, wire)
    }

    fn recv_any(&self) -> Result<(Frame, usize)> {
        loop {
            match self.net.next_msg()? {
                HubMsg::Frame(frame, n) => return Ok((frame, n)),
                report => self.net.stash.borrow_mut().push(report),
            }
        }
    }
}

/// [`FederatedRun`] over remote clients: the server half of every round
/// (selection, distribution, Phase-2 routing, FedAvg, broadcast, eval)
/// with client compute happening in the connected processes.
struct RemoteEngine<'a> {
    backend: &'a dyn Backend,
    fed: FedConfig,
    fleet: Fleet,
    global: ParamSet,
    /// Per-client sample counts (drives selection and FedAvg weights).
    counts: Vec<usize>,
    rng: Rng,
    setup_bytes: u64,
    body_prep: PreparedSegment,
    eval: Option<&'a SynthDataset>,
    history: RunHistory,
    /// Per-(round, client, message-kind) re-attribution of the ByteMeter's
    /// measurements; reconciled against `history.total_comm` after the run.
    ledger: Ledger,
    net: &'a NetRuntime,
}

impl RemoteEngine<'_> {
    fn run_round(&mut self, round: usize) -> Result<RoundRecord> {
        let wall0 = Instant::now();
        let telemetry = crate::telemetry::active();

        let selected = crate::federation::selection::select(
            self.fed.selection, self.fed.num_clients, self.fed.clients_per_round,
            &self.counts, round, &mut self.rng,
        );
        let k = selected.len();
        let n_ks: Vec<usize> = selected.iter().map(|&cid| self.counts[cid]).collect();

        let mut comm = ByteMeter::default();
        let mut clock = self.fleet.begin_round(&selected);
        let online: Vec<bool> = (0..k).map(|slot| clock.online(slot)).collect();
        let hub = RoundHub { net: self.net, selected: &selected };

        let dist_ref =
            [self.global.get("tail")?.clone(), self.global.get("prompt")?.clone()];

        // Hand every client process this round's trace context before any
        // frame flies: the coordinator-side round span (currently on this
        // thread's span stack) becomes the remote parent that client-side
        // `client:N` spans attach to when the traces are merged.
        if let Some(t) = &telemetry {
            if t.tracer.trace_id() != 0 {
                if let Some(parent) = t.current_span_id() {
                    let ctx = Control::RoundCtx { round: round as u32, parent };
                    for writer in &self.net.writers {
                        writer
                            .lock()
                            .expect("writer lock poisoned")
                            .send_control(&ctx)?;
                    }
                }
            }
        }

        distribute_model(
            &hub, &selected, round as u32, &dist_ref, &mut comm, &mut clock,
            &mut self.ledger,
        )?;

        let serve_span = telemetry.as_ref().map(|t| t.span("phase", "serve"));
        let (agg, outcome) = serve_round(
            self.backend, &self.body_prep, &hub, &selected, round as u32,
            &n_ks, &self.fed, &dist_ref, &mut comm, &mut clock, &mut self.ledger,
        )?;
        drop(serve_span);

        // Zero survivors with online clients means no broadcast was sent:
        // those clients are blocked waiting for one and will never report.
        // The in-process engine fails this round too (its hub closes under
        // the waiting clients); fail it here before deadlocking on reports.
        if agg.is_none() && online.iter().any(|&o| o) {
            bail!(
                "round {round} resolved with zero survivors; \
                 online clients cannot be released"
            );
        }

        // Every online client (dropped-but-online included — it completed
        // the protocol, its update was merely discarded) reports its loss
        // vectors after the broadcast. Collect them all, then keep the
        // survivors' in ascending slot order — the exact order the
        // in-process engine's thread joins produce.
        let mut reports: Vec<Option<(Vec<f64>, Vec<f64>)>> = (0..k).map(|_| None).collect();
        let mut missing = online.iter().filter(|&&o| o).count();
        let place = |msg: HubMsg, reports: &mut Vec<Option<(Vec<f64>, Vec<f64>)>>| {
            match msg {
                HubMsg::Report { round: r, client, local_losses, split_losses } => {
                    if r != round as u32 {
                        bail!("round report for round {r} during round {round}");
                    }
                    let slot = selected
                        .iter()
                        .position(|&c| c as u32 == client)
                        .ok_or_else(|| anyhow!("round report from unselected client {client}"))?;
                    if reports[slot].replace((local_losses, split_losses)).is_some() {
                        bail!("duplicate round report from client {client}");
                    }
                    Ok(true)
                }
                HubMsg::Frame(frame, _) => {
                    Err(anyhow!("unexpected {:?} frame between rounds", frame.kind))
                }
            }
        };
        for msg in self.net.stash.take() {
            if place(msg, &mut reports)? {
                missing -= 1;
            }
        }
        while missing > 0 {
            let msg = self.net.next_msg()?;
            if place(msg, &mut reports)? {
                missing -= 1;
            }
        }
        let mut local_losses = Vec::new();
        let mut split_losses = Vec::new();
        for (slot, report) in reports.into_iter().enumerate() {
            if !outcome.is_survivor(slot) {
                continue;
            }
            let (local, split) =
                report.ok_or_else(|| anyhow!("survivor slot {slot} never reported"))?;
            local_losses.extend(local);
            split_losses.extend(split);
        }

        if let Some((tail, prompt)) = agg {
            self.global.set(tail);
            self.global.set(prompt);
        }
        self.fleet.advance(outcome.latency_s);

        let eval_accuracy = match self.eval {
            Some(ds) if self.fed.should_eval(round) => {
                let _eval_span = telemetry.as_ref().map(|t| t.span("phase", "eval"));
                evaluate(self.backend, "eval_forward", &self.global, ds, self.fed.eval_limit)?
            }
            _ => f64::NAN,
        };

        Ok(RoundRecord {
            round,
            mean_local_loss: crate::util::stats::mean(&local_losses),
            mean_split_loss: crate::util::stats::mean(&split_losses),
            eval_accuracy,
            comm,
            wall_s: wall0.elapsed().as_secs_f64(),
            sim_latency_s: outcome.latency_s,
            clients: outcome.events,
        })
    }
}

impl FederatedRun for RemoteEngine<'_> {
    fn method(&self) -> Method {
        Method::SfPrompt
    }

    fn fed(&self) -> &FedConfig {
        &self.fed
    }

    fn round(&mut self, r: usize) -> Result<RoundRecord> {
        if r != self.history.rounds.len() {
            return Err(anyhow!(
                "rounds must run in order: expected round {}, got {r}",
                self.history.rounds.len()
            ));
        }
        let rec = self.run_round(r)?;
        self.history.push(rec.clone());
        Ok(rec)
    }

    fn history(&self) -> &RunHistory {
        &self.history
    }

    fn comm_totals(&self) -> &ByteMeter {
        &self.history.total_comm
    }

    fn ledger(&self) -> Option<&Ledger> {
        Some(&self.ledger)
    }

    fn setup_bytes(&self) -> u64 {
        self.setup_bytes
    }

    fn final_eval(&mut self) -> Result<f64> {
        match self.eval {
            Some(ds) => {
                evaluate(self.backend, "eval_forward", &self.global, ds, self.fed.eval_limit)
            }
            None => Ok(f64::NAN),
        }
    }
}

/// Answer one fresh connection's first message during admission. Returns
/// the admitted client link, if this connection became one. `trace_id` is
/// the run's distributed-trace id (0 when untraced); the welcome carries it
/// plus the NTP-style timestamp legs the client uses to estimate its clock
/// offset from the coordinator (docs/TRACING.md).
fn admit_connection(
    stream: TcpStream,
    spec: &RunSpec,
    opts: &ServeOptions,
    trace_id: u128,
    admitted: usize,
    accepting_clients: bool,
) -> Option<TcpLink> {
    let mut link = match TcpLink::from_stream(stream, opts.io_timeout) {
        Ok(l) => l,
        Err(e) => {
            eprintln!("serve: rejected connection (socket setup: {e})");
            return None;
        }
    };
    let peer = link.peer();
    let reject = |link: &mut TcpLink, reason: String| {
        if !opts.quiet {
            eprintln!("serve: rejected {peer}: {reason}");
        }
        let _ = link.send_control(&Control::Reject { reason });
        link.shutdown();
    };
    match link.recv_msg(false) {
        Ok(Some(NetMsg::Control(Control::Hello { proto, wire, name, run_id, t0 }, _))) => {
            // Receive timestamp of the hello on the coordinator timebase:
            // the t1 leg of the client's offset estimate.
            let t1 = server_now_s();
            if !accepting_clients {
                reject(&mut link, "run already in progress (connect as an observer)".into());
                return None;
            }
            if proto != NET_PROTO_VERSION {
                reject(
                    &mut link,
                    format!(
                        "net protocol version mismatch: you speak v{proto}, this server v{}",
                        NET_PROTO_VERSION
                    ),
                );
                return None;
            }
            if wire != WIRE_VERSION {
                reject(
                    &mut link,
                    format!(
                        "codec wire version mismatch: you speak v{wire}, this server v{}",
                        WIRE_VERSION
                    ),
                );
                return None;
            }
            if !run_id.is_empty() && run_id != opts.run_id {
                reject(
                    &mut link,
                    format!("run id mismatch: you asked for {run_id:?}, serving {:?}", opts.run_id),
                );
                return None;
            }
            let client_ids = owned_clients(spec.fed.num_clients, opts.processes, admitted);
            let welcome = Control::Welcome {
                proto: NET_PROTO_VERSION,
                wire: WIRE_VERSION,
                run_id: opts.run_id.clone(),
                process: admitted,
                processes: opts.processes,
                client_ids,
                spec: spec.clone(),
                trace_id,
                span_base: span_base_for(admitted),
                t0,
                t1,
                t2: server_now_s(),
            };
            match link.send_control(&welcome) {
                Ok(_) => {
                    if !opts.quiet {
                        eprintln!(
                            "serve: admitted {peer} ({name:?}) as process {}/{}",
                            admitted + 1,
                            opts.processes
                        );
                    }
                    Some(link)
                }
                Err(e) => {
                    eprintln!("serve: welcome to {peer} failed ({e}); slot stays open");
                    None
                }
            }
        }
        Ok(Some(NetMsg::Control(Control::Observe { proto }, _))) => {
            if proto != NET_PROTO_VERSION {
                reject(&mut link, format!("observer protocol v{proto} != v{NET_PROTO_VERSION}"));
                return None;
            }
            if !opts.quiet {
                eprintln!("serve: observer {peer} subscribed to the event stream");
            }
            opts.events.subscribe(link.into_stream());
            None
        }
        Ok(Some(NetMsg::Control(Control::Status { proto }, _))) => {
            if proto != NET_PROTO_VERSION {
                reject(&mut link, format!("status protocol v{proto} != v{NET_PROTO_VERSION}"));
                return None;
            }
            // One snapshot per connection: reply and hang up (`sfprompt
            // top` reconnects per poll).
            let reply = Control::StatusReply { body: status_snapshot(spec, opts) };
            if let Err(e) = link.send_control(&reply) {
                if !opts.quiet {
                    eprintln!("serve: status reply to {peer} failed ({e})");
                }
            }
            link.shutdown();
            None
        }
        Ok(Some(NetMsg::Control(other, _))) => {
            reject(&mut link, format!("expected hello or observe, got {:?}", other.kind()));
            None
        }
        Ok(Some(NetMsg::Frame(frame, _))) => {
            reject(&mut link, format!("expected a handshake, got a {:?} frame", frame.kind));
            None
        }
        Ok(None) => None,
        Err(e) => {
            // Garbage, truncation, or a version-mismatched envelope: say
            // why, try to tell the peer, move on. The run never dies to a
            // bad joiner.
            reject(&mut link, format!("handshake failed: {e}"));
            None
        }
    }
}

/// Reader-thread body: funnel one client process's inbound messages into
/// the shared hub channel until the socket closes or the run stops. Every
/// received frame feeds the health registry's per-client byte/liveness
/// accounting — the real socket traffic, not the simulated meter. Clock
/// probes are answered inline (stamp receive/send, echo) so the client can
/// refresh its offset estimate without a round trip through the driver.
fn reader_loop(
    mut link: TcpLink,
    tx: Sender<Result<HubMsg>>,
    process: usize,
    stop: &AtomicBool,
    health: &HealthRegistry,
    writer: &Mutex<TcpLink>,
    events: &EventSink,
) {
    loop {
        if stop.load(Ordering::Relaxed) {
            return;
        }
        match link.recv_msg(true) {
            Ok(None) => continue, // idle poll; re-check the stop flag
            Ok(Some(NetMsg::Control(Control::ClockProbe { t0 }, _))) => {
                let t1 = server_now_s();
                // One-way estimate only (the precise two-sided offset is
                // computed client-side from the full reply); enough for the
                // heartbeat's coarse "who re-synced" view.
                events.record_clock(process, t1 - t0);
                let reply = Control::ClockReply { t0, t1, t2: server_now_s() };
                if writer.lock().expect("writer lock poisoned").send_control(&reply).is_err() {
                    return;
                }
            }
            Ok(Some(NetMsg::Frame(frame, n))) => {
                health.client_bytes(frame.client as usize, n as u64);
                if tx.send(Ok(HubMsg::Frame(frame, n))).is_err() {
                    return;
                }
            }
            Ok(Some(NetMsg::Control(Control::RoundReport {
                round,
                client,
                local_losses,
                split_losses,
            }, _))) => {
                if tx
                    .send(Ok(HubMsg::Report { round, client, local_losses, split_losses }))
                    .is_err()
                {
                    return;
                }
            }
            Ok(Some(NetMsg::Control(other, _))) => {
                let _ = tx.send(Err(anyhow!(
                    "client process {process} sent unexpected control {:?}",
                    other.kind()
                )));
                return;
            }
            Err(e) => {
                if stop.load(Ordering::Relaxed) {
                    return; // shutdown tore the socket down under us
                }
                let closed =
                    matches!(e.downcast_ref::<NetError>(), Some(NetError::Closed));
                let _ = tx.send(Err(if closed {
                    anyhow!("client process {process} disconnected mid-run")
                } else {
                    e.context(format!("client process {process}"))
                }));
                return;
            }
        }
    }
}

/// Background acceptor after admission: observers may subscribe and
/// `status` probes get answered mid-run; latecomer clients get a polite
/// reject. The idle branch doubles as the liveness clock — it drives the
/// event sink's heartbeat, which culls observer sockets whose peer
/// vanished without a FIN.
fn acceptor_loop(listener: TcpListener, spec: &RunSpec, opts: &ServeOptions, stop: &AtomicBool) {
    if listener.set_nonblocking(true).is_err() {
        return;
    }
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _)) => {
                let _ = stream.set_nonblocking(false);
                // `accepting_clients: false`: the cohort is sealed (so no
                // welcome is ever sent and the trace id is moot).
                let _ = admit_connection(stream, spec, opts, 0, usize::MAX, false);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                opts.events.tick();
                std::thread::sleep(Duration::from_millis(50));
            }
            Err(_) => return,
        }
    }
}

/// Serve one federated run over TCP: admit `opts.processes` client
/// processes, drive every round through the shared engine code paths, and
/// return the completed [`RunReport`] — byte-identical (modulo wall-clock
/// fields) to `spec` run in-process.
pub fn serve(
    listener: TcpListener,
    spec: &RunSpec,
    artifacts_root: &Path,
    opts: &ServeOptions,
    obs: &mut dyn RoundObserver,
) -> Result<RunReport> {
    if spec.method != Method::SfPrompt {
        bail!(
            "serve supports the sfprompt method only (got {:?}); the baselines' wire \
             protocols are in-process for now",
            spec.method.label()
        );
    }
    spec.builder().validate()?;
    if opts.processes == 0 || opts.processes > spec.fed.num_clients {
        bail!(
            "processes must be in 1..={} (one process owns at least one logical client), got {}",
            spec.fed.num_clients,
            opts.processes
        );
    }

    let backend = spec.open_backend(artifacts_root)?;
    let backend: &dyn Backend = backend.as_ref();
    let manifest = backend.manifest();
    for stage in ["body_forward", "body_backward", "eval_forward"] {
        if !manifest.stages.contains_key(stage) {
            bail!("config {:?} was lowered without stage {stage:?}", manifest.config.name);
        }
    }
    let (train, eval) = spec.datasets(&manifest.config)?;
    if train.len() < spec.fed.num_clients {
        bail!(
            "training set has {} samples for {} clients (every client needs at least one)",
            train.len(),
            spec.fed.num_clients
        );
    }
    let labels = train.labels();
    let (clients, rng) = build_clients(&spec.fed, &labels);
    let counts: Vec<usize> = clients.iter().map(|c| c.num_samples()).collect();
    drop(clients); // the server only routes; client compute lives remotely

    let global = init_params(manifest, seeds::param_init(spec.fed.seed));
    let head_bytes = manifest.cost.message_bytes["head_params"] as u64;
    let body_prep = backend.prepare_segment(global.get("body")?)?;
    let fleet = spec.builder().resolved_fleet();

    // --- Distributed-trace identity: when this process is traced, mint
    // the run's trace id and claim the coordinator's span-id block before
    // any span opens, so every coordinator span lands in the right tree.
    let trace_id = match crate::telemetry::active() {
        Some(t) => {
            let id = derive_trace_id(&opts.run_id, spec.fed.seed);
            t.tracer.set_trace_context(id, "coordinator", 0);
            id
        }
        None => 0,
    };

    // --- Admission: blocking accepts until the cohort is full. ---
    if !opts.quiet {
        eprintln!(
            "serve: listening on {}, waiting for {} client process(es)",
            listener.local_addr().map(|a| a.to_string()).unwrap_or_else(|_| "?".into()),
            opts.processes
        );
    }
    let mut admitted_links = Vec::with_capacity(opts.processes);
    while admitted_links.len() < opts.processes {
        let (stream, _) = listener.accept()?;
        if let Some(link) =
            admit_connection(stream, spec, opts, trace_id, admitted_links.len(), true)
        {
            admitted_links.push(link);
        }
    }

    // --- Reader/writer split per process, shared inbound channel. ---
    let (tx, rx) = channel();
    let mut readers = Vec::with_capacity(opts.processes);
    let mut writers = Vec::with_capacity(opts.processes);
    for link in admitted_links {
        readers.push(link.try_clone()?);
        writers.push(Mutex::new(link));
    }
    let net = NetRuntime { writers, rx, processes: opts.processes, stash: RefCell::new(Vec::new()) };
    let stop = AtomicBool::new(false);

    let (history, ledger_json) = std::thread::scope(|scope| {
        for (process, reader) in readers.into_iter().enumerate() {
            let tx = tx.clone();
            let stop = &stop;
            let health = &*opts.health;
            let writer = &net.writers[process];
            let events = &opts.events;
            scope.spawn(move || reader_loop(reader, tx, process, stop, health, writer, events));
        }
        drop(tx); // readers hold the only senders now
        scope.spawn(|| acceptor_loop(listener, spec, opts, &stop));

        let mut engine = RemoteEngine {
            backend,
            fed: spec.fed,
            fleet,
            global,
            counts,
            rng,
            setup_bytes: head_bytes * spec.fed.num_clients as u64,
            body_prep,
            eval: Some(&eval),
            history: RunHistory::default(),
            ledger: Ledger::new(),
            net: &net,
        };
        let mut health_obs =
            HealthObserver::new(opts.health.clone(), opts.flight.clone(), opts.events.clone())
                .with_postmortem(opts.postmortem.clone())
                .quiet(opts.quiet);
        let mut event_obs = EventStreamObserver::new(opts.events.clone());
        let mut inner = Tee(&mut health_obs, &mut event_obs);
        let mut tee = Tee(obs, &mut inner);
        let result = drive(&mut engine, &mut tee).and_then(|history| {
            // The ledger is a re-attribution of the ByteMeter's numbers;
            // any divergence is a coordinator bug, not a client's.
            engine
                .ledger
                .reconcile(&history.total_comm)
                .map_err(|e| anyhow!("ledger/meter divergence: {e}"))?;
            Ok(history)
        });
        let ledger_json =
            if engine.ledger.is_empty() { None } else { Some(engine.ledger.to_json()) };

        // --- Teardown, success or not: tell every client, drop the
        // sockets (wakes blocked readers with EOF), stop the acceptor. ---
        let reason = match &result {
            Ok(_) => SHUTDOWN_COMPLETE.to_string(),
            Err(e) => format!("run failed: {e}"),
        };
        if let Err(e) = &result {
            // The run died: seal the health state and flush the flight
            // ring so the evidence outlives the process.
            opts.flight.record("health", &format!("run_failed: {e}"), 0.0, 0.0, 0.0);
            opts.health.end_run(true);
            health_obs.dump_postmortem("run failed");
        }
        stop.store(true, Ordering::Relaxed);
        for writer in &net.writers {
            let mut link = writer.lock().expect("writer lock poisoned");
            let _ = link.send_control(&Control::Shutdown { reason: reason.clone() });
            link.shutdown();
        }
        result.map(|history| (history, ledger_json))
    })?;

    let mut report = RunReport::new(spec, head_bytes * spec.fed.num_clients as u64, history)
        .with_health(opts.health.to_json());
    if let Some(ledger) = ledger_json {
        report = report.with_ledger(ledger);
    }
    Ok(report)
}
