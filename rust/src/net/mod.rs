//! Networked federation: the in-process run served over real TCP sockets.
//!
//! Everything below reuses the existing machinery — codec-v2 frames
//! ([`crate::transport`]), the shared round engine
//! (`federation::engine::{distribute_model, serve_round}` over
//! [`crate::transport::FrameHub`]), canonical client construction
//! ([`crate::federation::client`]), and the [`crate::federation::drive`]
//! loop — so a networked run is the same run, byte for byte, with sockets
//! where the mpsc channels were. Zero external dependencies: threaded
//! blocking `std::net`, no async runtime.
//!
//! * [`wire`] — length-prefixed message envelope shared by data frames
//!   and control messages; typed [`wire::NetError`]s for every way a
//!   socket can lie (truncation, oversize, garbage, stall, version skew).
//! * [`control`] — strict unknown-rejecting JSON control plane: Hello /
//!   Welcome (carrying the full `RunSpec`, the distributed-trace identity,
//!   and the NTP handshake legs) / RoundCtx (per-round cross-process span
//!   parent) / ClockProbe / ClockReply (periodic clock re-estimation) /
//!   Reject / Observe / Status / StatusReply / RoundReport (bit-exact hex
//!   floats) / Shutdown. Tracing semantics in `docs/TRACING.md`.
//! * [`tcp`] — [`tcp::TcpLink`], the socket-backed
//!   [`crate::transport::Transport`] with timeouts, connect retry with
//!   backoff, and telemetry byte counters.
//! * [`serve`] — the coordinator: admit N client processes, drive rounds
//!   through the shared engine code, tear down cleanly on any exit.
//! * [`client`] — the client process: handshake, deterministic state
//!   rebuild, per-owned-client workers over one demultiplexed socket.
//! * [`events`] — line-delimited JSON round events to a file and to
//!   `Observe`-subscribed sockets (`docs/NET.md` has the schema), with
//!   heartbeat-based dead-peer culling and the health observer that feeds
//!   the live-operations layer (`docs/OPS.md`).
//! * [`prom`] — `GET /metrics` Prometheus text exposition over a minimal
//!   HTTP/1.0 responder (`serve --prom ADDR`).
//!
//! CLI: `sfprompt serve --listen ADDR --processes N …`,
//! `sfprompt client --connect HOST:PORT …`, and the live-ops consoles
//! `sfprompt top --connect HOST:PORT`; see `docs/NET.md` and
//! `docs/OPS.md`.

pub mod client;
pub mod control;
pub mod events;
pub mod prom;
pub mod serve;
pub mod tcp;
pub mod wire;

pub use client::{run_client, ClientOptions, ClientSummary};
pub use control::{Control, SHUTDOWN_COMPLETE};
pub use events::{EventSink, EventStreamObserver, HealthObserver, DEFAULT_HEARTBEAT};
pub use prom::{spawn_metrics_server, PromHandle};
pub use serve::{owned_clients, serve, ServeOptions};
pub use tcp::{ConnectOptions, TcpLink};
pub use wire::{NetError, NetMsg, MAX_MSG_LEN, NET_PROTO_VERSION};
