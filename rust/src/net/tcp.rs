//! `TcpLink`: the socket-backed [`Transport`].
//!
//! One `TcpLink` wraps one `TcpStream` with the framing from
//! [`super::wire`]: `send` writes the exact `encode_frame` byte string
//! (whose leading length prefix doubles as the socket framing, so the
//! metered byte count **is** the socket byte count), `recv` reassembles
//! and CRC-checks the next inbound frame. Control messages share the same
//! stream via [`TcpLink::send_control`] / [`TcpLink::recv_msg`].
//!
//! Policy lives here too:
//! * **Timeouts** — every link gets `SO_RCVTIMEO`/`SO_SNDTIMEO`
//!   ([`ConnectOptions::io_timeout`]); a stalled peer surfaces
//!   [`NetError::TimedOut`] instead of hanging the round forever.
//! * **Connect retry** — [`TcpLink::connect`] retries with doubling
//!   backoff (capped) so `client` processes can start before (or race)
//!   the server without a shell-script sleep dance.
//! * **Nagle off** — the protocol is lock-step request/response per
//!   Phase-2 batch; coalescing 17-byte gradient headers costs RTTs.
//!
//! When telemetry is installed, real socket byte counts accumulate under
//! `net_tx_bytes` / `net_rx_bytes` (data frames) and `net_control_bytes`
//! (handshake/report overhead — deliberately *not* in `ByteMeter`, which
//! meters the paper's federated payload traffic only).

use std::io::Write;
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::time::Duration;

use anyhow::{anyhow, Context, Result};

use crate::transport::{encode_frame, Frame, Transport, WireFormat};

use super::control::Control;
use super::wire::{control_bytes, read_message, write_error, NetError, NetMsg};

/// Client-side connection policy.
#[derive(Debug, Clone)]
pub struct ConnectOptions {
    /// Connection attempts before giving up (≥ 1).
    pub retries: u32,
    /// Backoff before the second attempt; doubles each retry, capped at 2 s.
    pub backoff: Duration,
    /// Read/write timeout applied to the established stream.
    pub io_timeout: Duration,
}

impl Default for ConnectOptions {
    fn default() -> ConnectOptions {
        // ~30 attempts over ~1 min: enough for a CI script that backgrounds
        // the server and launches clients immediately.
        ConnectOptions {
            retries: 30,
            backoff: Duration::from_millis(100),
            io_timeout: Duration::from_secs(60),
        }
    }
}

const BACKOFF_CAP: Duration = Duration::from_secs(2);

/// One framed, timeout-guarded TCP connection.
pub struct TcpLink {
    stream: TcpStream,
    peer: SocketAddr,
}

impl TcpLink {
    /// Wrap an accepted/connected stream: disable Nagle, arm timeouts.
    pub fn from_stream(stream: TcpStream, io_timeout: Duration) -> Result<TcpLink> {
        let peer = stream.peer_addr().context("peer address")?;
        stream.set_nodelay(true).context("TCP_NODELAY")?;
        let t = (io_timeout > Duration::ZERO).then_some(io_timeout);
        stream.set_read_timeout(t).context("SO_RCVTIMEO")?;
        stream.set_write_timeout(t).context("SO_SNDTIMEO")?;
        Ok(TcpLink { stream, peer })
    }

    /// Dial `addr`, retrying with doubling backoff per
    /// [`ConnectOptions`]. Fails with the last connect error once the
    /// attempt budget is spent.
    pub fn connect(addr: &str, opts: &ConnectOptions) -> Result<TcpLink> {
        let targets: Vec<SocketAddr> =
            addr.to_socket_addrs().with_context(|| format!("resolving {addr:?}"))?.collect();
        if targets.is_empty() {
            return Err(anyhow!("{addr:?} resolved to no addresses"));
        }
        let mut delay = opts.backoff;
        let mut last_err = None;
        for attempt in 0..opts.retries.max(1) {
            if attempt > 0 {
                std::thread::sleep(delay);
                delay = (delay * 2).min(BACKOFF_CAP);
            }
            match TcpStream::connect(&targets[..]) {
                Ok(stream) => return TcpLink::from_stream(stream, opts.io_timeout),
                Err(e) => last_err = Some(e),
            }
        }
        Err(anyhow!(
            "could not connect to {addr} after {} attempts: {}",
            opts.retries.max(1),
            last_err.expect("at least one attempt ran")
        ))
    }

    pub fn peer(&self) -> SocketAddr {
        self.peer
    }

    /// A second handle on the same socket (reader/writer split).
    pub fn try_clone(&self) -> Result<TcpLink> {
        Ok(TcpLink { stream: self.stream.try_clone().context("cloning socket")?, peer: self.peer })
    }

    /// Tear the connection down (both directions, best effort). Queued
    /// outbound data still drains to the peer before the FIN; a reader
    /// blocked on this socket wakes with a clean EOF.
    pub fn shutdown(&self) {
        let _ = self.stream.shutdown(std::net::Shutdown::Both);
    }

    /// Unwrap back to the raw stream (observer sockets hand their write
    /// half to the event sink).
    pub fn into_stream(self) -> TcpStream {
        self.stream
    }

    /// Write one control message; returns its wire byte count.
    pub fn send_control(&mut self, c: &Control) -> Result<usize> {
        let bytes = control_bytes(c);
        self.stream.write_all(&bytes).map_err(write_error)?;
        if let Some(t) = crate::telemetry::active() {
            t.metrics.counter_add("net_control_bytes", bytes.len() as u64);
        }
        Ok(bytes.len())
    }

    /// Read the next message (frame or control). With `idle_ok`, a read
    /// timeout **between** messages returns `Ok(None)` so callers can poll
    /// a stop flag; a timeout mid-message is still an error.
    pub fn recv_msg(&mut self, idle_ok: bool) -> Result<Option<NetMsg>> {
        let msg = read_message(&mut self.stream, idle_ok)?;
        if let Some(t) = crate::telemetry::active() {
            match &msg {
                Some(NetMsg::Frame(_, n)) => t.metrics.counter_add("net_rx_bytes", *n as u64),
                Some(NetMsg::Control(_, n)) => {
                    t.metrics.counter_add("net_control_bytes", *n as u64)
                }
                None => {}
            }
        }
        Ok(msg)
    }
}

impl Transport for TcpLink {
    fn send(&mut self, frame: &Frame, wire: WireFormat) -> Result<usize> {
        let bytes = encode_frame(frame, wire)?;
        self.stream.write_all(&bytes).map_err(write_error)?;
        if let Some(t) = crate::telemetry::active() {
            t.metrics.counter_add("net_tx_bytes", bytes.len() as u64);
        }
        Ok(bytes.len())
    }

    fn recv(&mut self) -> Result<(Frame, usize)> {
        match self.recv_msg(false)? {
            Some(NetMsg::Frame(frame, n)) => Ok((frame, n)),
            Some(NetMsg::Control(c, _)) => match c {
                Control::Shutdown { reason } => {
                    Err(anyhow!("server shut the run down mid-round: {reason}"))
                }
                other => Err(anyhow!(
                    "expected a data frame, got control message {:?}",
                    other.kind()
                )),
            },
            None => Err(anyhow::Error::new(NetError::TimedOut)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::MsgKind;
    use crate::runtime::HostTensor;
    use crate::transport::Payload;
    use std::net::TcpListener;

    fn frame(vals: &[f32]) -> Frame {
        Frame::new(
            MsgKind::Upload,
            1,
            2,
            Payload::Tensor(HostTensor::f32(vec![vals.len()], vals.to_vec())),
        )
    }

    #[test]
    fn localhost_roundtrip_counts_socket_bytes() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut link = TcpLink::from_stream(stream, Duration::from_secs(5)).unwrap();
            let (f, n) = link.recv().unwrap();
            link.send(&f, WireFormat::F32).unwrap();
            n
        });
        let mut client = TcpLink::connect(&addr, &ConnectOptions::default()).unwrap();
        let f = frame(&[1.0, 2.0, 3.0]);
        let sent = client.send(&f, WireFormat::F32).unwrap();
        let (echoed, got) = client.recv().unwrap();
        assert_eq!(echoed, f);
        assert_eq!(sent, got, "send and recv must meter the same byte count");
        assert_eq!(sent, encode_frame(&f, WireFormat::F32).unwrap().len());
        assert_eq!(server.join().unwrap(), sent);
    }

    #[test]
    fn control_and_frames_share_the_stream() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut link = TcpLink::from_stream(stream, Duration::from_secs(5)).unwrap();
            let mut kinds = Vec::new();
            for _ in 0..2 {
                match link.recv_msg(false).unwrap().unwrap() {
                    NetMsg::Control(c, _) => kinds.push(c.kind().to_string()),
                    NetMsg::Frame(f, _) => kinds.push(f.kind.label().to_string()),
                }
            }
            kinds
        });
        let mut client = TcpLink::connect(&addr, &ConnectOptions::default()).unwrap();
        client
            .send_control(&Control::Hello {
                proto: super::super::wire::NET_PROTO_VERSION,
                wire: crate::transport::WIRE_VERSION,
                name: "t".into(),
                run_id: String::new(),
                t0: 0.0,
            })
            .unwrap();
        client.send(&frame(&[4.0]), WireFormat::F32).unwrap();
        assert_eq!(server.join().unwrap(), vec!["hello", "upload"]);
    }

    #[test]
    fn connect_retries_until_listener_appears() {
        // Reserve a port, drop the listener, rebind it after a delay: the
        // client's backoff loop must ride out the gap.
        let probe = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = probe.local_addr().unwrap();
        drop(probe);
        let late = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(250));
            let listener = TcpListener::bind(addr).unwrap();
            let _ = listener.accept();
        });
        let opts = ConnectOptions {
            retries: 40,
            backoff: Duration::from_millis(50),
            io_timeout: Duration::from_secs(5),
        };
        // NOTE: another process could steal the port between drop and
        // rebind; vanishingly unlikely for an ephemeral port in CI.
        TcpLink::connect(&addr.to_string(), &opts).unwrap();
        late.join().unwrap();
    }

    #[test]
    fn connect_failure_reports_attempts() {
        // A port from the reserved range nothing listens on, one attempt.
        let opts = ConnectOptions {
            retries: 1,
            backoff: Duration::from_millis(1),
            io_timeout: Duration::from_secs(1),
        };
        let err = TcpLink::connect("127.0.0.1:1", &opts).unwrap_err().to_string();
        assert!(err.contains("after 1 attempts"), "{err}");
    }

    #[test]
    fn idle_timeout_is_none_mid_message_is_error() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let hold = std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            // Quiet period, then half a length prefix, then stall.
            std::thread::sleep(Duration::from_millis(300));
            stream.write_all(&[9, 0]).unwrap();
            std::thread::sleep(Duration::from_millis(600));
        });
        let opts = ConnectOptions { io_timeout: Duration::from_millis(150), ..Default::default() };
        let mut link = TcpLink::connect(&addr, &opts).unwrap();
        assert!(link.recv_msg(true).unwrap().is_none(), "idle timeout must be quiet-ok");
        // Eventually the peer sends 2 of 4 prefix bytes and stalls: that
        // mid-message timeout is a hard error even with idle_ok.
        let err = loop {
            match link.recv_msg(true) {
                Ok(None) => continue,
                Ok(Some(m)) => panic!("unexpected message {m:?}"),
                Err(e) => break e,
            }
        };
        assert_eq!(err.downcast_ref::<NetError>(), Some(&NetError::TimedOut));
        hold.join().unwrap();
    }
}
