//! The networked client process: connect, handshake, compute rounds.
//!
//! [`run_client`] dials a [`super::serve`] coordinator, performs the typed
//! handshake (protocol + codec versions, optional run id), and receives a
//! `Welcome` carrying the full [`RunSpec`] plus the logical client ids
//! this process owns. It then rebuilds **exactly** the state the
//! in-process engine would give those clients — same dataset generation,
//! same partition, same per-client RNG forks via [`build_clients`] — and
//! runs [`client_split_round`] for each owned client whenever the server
//! distributes a model to it. Process boundaries change *where* a client
//! computes, never *what* it draws, which is what makes the networked run
//! bit-identical to the local one.
//!
//! Threading: the process main thread demultiplexes the single socket
//! (frames are routed to per-client worker threads by `frame.client`;
//! control messages end the run), workers share the write half behind a
//! mutex — sends are whole frames, so interleaving is frame-atomic. After
//! each completed round a worker reports its loss vectors back with a
//! `RoundReport` control message (bit-exact hex floats).
//!
//! When this process is traced (`client --trace`), the welcome handshake
//! also delivers the run's trace id, this process's span-id block, and the
//! NTP timestamp legs for the clock-offset estimate; `RoundCtx` messages
//! then parent each round's `client:N` span under the coordinator's round
//! span, and idle time on the socket is used for `ClockProbe` re-estimates.
//! See docs/TRACING.md for the full model.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::backend::{Backend, PreparedSegment};
use crate::comm::MsgKind;
use crate::data::Example;
use crate::federation::client::{build_clients, client_split_round, Client};
use crate::federation::{FedConfig, Method};
use crate::model::init_params;
use crate::runtime::ModelConfig;
use crate::transport::{Frame, Payload, Transport, WireFormat, WIRE_VERSION};
use crate::util::rng::seeds;

use super::control::{Control, SHUTDOWN_COMPLETE};
use super::tcp::{ConnectOptions, TcpLink};
use super::wire::{NetMsg, NET_PROTO_VERSION};

/// Client-process configuration.
pub struct ClientOptions {
    pub connect: ConnectOptions,
    /// Display name sent in the Hello (shows up in server logs).
    pub name: String,
    /// Run id to insist on (empty = join whatever the server is serving).
    pub run_id: String,
    pub quiet: bool,
}

impl Default for ClientOptions {
    fn default() -> ClientOptions {
        ClientOptions {
            connect: ConnectOptions::default(),
            name: "client".to_string(),
            run_id: String::new(),
            quiet: false,
        }
    }
}

/// What one client process did, for reporting after a clean run.
#[derive(Debug)]
pub struct ClientSummary {
    /// This process's slot in the cohort (0-based).
    pub process: usize,
    pub processes: usize,
    /// Logical clients this process computed for.
    pub client_ids: Vec<usize>,
    /// Total round participations completed across owned clients.
    pub rounds_participated: usize,
}

/// How long the demultiplexer lets the socket stay idle before using the
/// silence to refresh this process's clock-offset estimate (traced runs
/// only; see docs/TRACING.md).
const CLOCK_PROBE_INTERVAL: Duration = Duration::from_secs(5);

/// "Now" on this process's trace timebase; 0.0 when untraced, in which
/// case the NTP legs are ignored by both sides.
fn client_now_s() -> f64 {
    crate::telemetry::active().map_or(0.0, |t| t.tracer.now_s())
}

/// Frames routed to one worker, or the end-of-run signal.
enum WorkerMsg {
    Frame(Frame, usize),
    Shutdown,
}

/// The [`Transport`] a worker's [`client_split_round`] drives: receives
/// come from the demultiplexer's per-client queue (seeded with the round's
/// opening `ModelDistribution`), sends go to the shared socket write half
/// (whole frames under the lock, so concurrent workers interleave at frame
/// granularity only).
struct WorkerLink<'a> {
    pending: Option<(Frame, usize)>,
    rx: &'a Receiver<WorkerMsg>,
    writer: &'a Mutex<TcpLink>,
}

impl Transport for WorkerLink<'_> {
    fn send(&mut self, frame: &Frame, wire: WireFormat) -> Result<usize> {
        self.writer.lock().expect("writer lock poisoned").send(frame, wire)
    }

    fn recv(&mut self) -> Result<(Frame, usize)> {
        if let Some(pending) = self.pending.take() {
            return Ok(pending);
        }
        match self.rx.recv() {
            Ok(WorkerMsg::Frame(f, n)) => Ok((f, n)),
            Ok(WorkerMsg::Shutdown) => Err(anyhow!("server shut the run down mid-round")),
            Err(_) => Err(anyhow!("connection demultiplexer exited mid-round")),
        }
    }
}

/// Worker-thread body: run every round the server assigns to this client.
/// Returns the number of rounds completed. In a traced run, each round's
/// work runs under a `client:N` span whose remote parent is the
/// coordinator's round span (delivered out-of-band via `RoundCtx`), so the
/// phase spans [`client_split_round`] opens nest correctly after a trace
/// merge.
#[allow(clippy::too_many_arguments)]
fn worker_loop(
    mut client: Client,
    rx: Receiver<WorkerMsg>,
    writer: &Mutex<TcpLink>,
    backend: &dyn Backend,
    examples: &[Example],
    head: &PreparedSegment,
    fed: &FedConfig,
    cfg: &ModelConfig,
    round_ctx: &Mutex<BTreeMap<u32, u64>>,
    quiet: bool,
) -> Result<usize> {
    let cid = client.id as u32;
    let telemetry = crate::telemetry::active();
    let mut rounds = 0usize;
    loop {
        let (frame, n) = match rx.recv() {
            Ok(WorkerMsg::Frame(f, n)) => (f, n),
            Ok(WorkerMsg::Shutdown) | Err(_) => return Ok(rounds),
        };
        if frame.kind != MsgKind::ModelDistribution {
            bail!(
                "client {cid}: a round must open with a model distribution, got {:?}",
                frame.kind
            );
        }
        let round = frame.round;
        let round_span = telemetry.as_ref().map(|t| {
            let name = format!("client:{}", client.id);
            let parent = round_ctx.lock().expect("round context poisoned").get(&round).copied();
            match parent {
                Some(p) if t.tracer.trace_id() != 0 => t.span_remote("client", &name, p),
                _ => t.span("client", &name),
            }
        });
        let mut link = WorkerLink { pending: Some((frame, n)), rx: &rx, writer };
        let result = client_split_round(
            &mut client, backend, examples, head, fed, cfg, round, &mut link,
        );
        drop(round_span);
        match result {
            Ok(out) => {
                let report = Control::RoundReport {
                    round,
                    client: cid,
                    local_losses: out.local_losses,
                    split_losses: out.split_losses,
                };
                writer.lock().expect("writer lock poisoned").send_control(&report)?;
                rounds += 1;
                if !quiet {
                    eprintln!("client {cid}: completed round {round}");
                }
            }
            Err(e) => {
                // Tell the server before dying, or serve_round would wait
                // for an upload that never comes (mirrors the in-process
                // engine's abort-on-error client threads).
                let abort = Frame::new(MsgKind::Abort, round, cid, Payload::Empty);
                let _ = writer.lock().expect("writer lock poisoned").send(&abort, WireFormat::F32);
                return Err(e.context(format!("client {cid} in round {round}")));
            }
        }
    }
}

/// Dial the coordinator at `addr`, handshake, and compute every round the
/// server assigns to this process's clients until the server shuts the run
/// down. `artifacts_root` is consulted only by the PJRT backend.
pub fn run_client(
    addr: &str,
    artifacts_root: &Path,
    opts: &ClientOptions,
) -> Result<ClientSummary> {
    let mut link = TcpLink::connect(addr, &opts.connect)?;
    link.send_control(&Control::Hello {
        proto: NET_PROTO_VERSION,
        wire: WIRE_VERSION,
        name: opts.name.clone(),
        run_id: opts.run_id.clone(),
        t0: client_now_s(),
    })?;
    let (process, processes, client_ids, spec, sync) = match link.recv_msg(false)? {
        Some(NetMsg::Control(Control::Welcome {
            proto,
            wire,
            run_id: _,
            process,
            processes,
            client_ids,
            spec,
            trace_id,
            span_base,
            t0,
            t1,
            t2,
        }, _)) => {
            // The t3 leg: welcome receive time on this process's timebase.
            let t3 = client_now_s();
            if proto != NET_PROTO_VERSION {
                bail!("server speaks net protocol v{proto}, this client v{NET_PROTO_VERSION}");
            }
            if wire != WIRE_VERSION {
                bail!("server speaks codec wire v{wire}, this client v{WIRE_VERSION}");
            }
            (process, processes, client_ids, spec, (trace_id, span_base, t0, t1, t2, t3))
        }
        Some(NetMsg::Control(Control::Reject { reason }, _)) => {
            bail!("server rejected the handshake: {reason}")
        }
        Some(NetMsg::Control(other, _)) => {
            bail!("expected welcome, got control message {:?}", other.kind())
        }
        Some(NetMsg::Frame(frame, _)) => {
            bail!("expected welcome, got a {:?} frame", frame.kind)
        }
        None => bail!("server went quiet during the handshake"),
    };
    if spec.method != Method::SfPrompt {
        bail!("server is running method {:?}, which has no networked client", spec.method.label());
    }
    if client_ids.is_empty() {
        bail!("server assigned no clients to this process");
    }
    if let Some(&bad) = client_ids.iter().find(|&&cid| cid >= spec.fed.num_clients) {
        bail!("server assigned client {bad} outside the fleet of {}", spec.fed.num_clients);
    }
    if !opts.quiet {
        eprintln!(
            "client: admitted as process {}/{processes}, computing for clients {client_ids:?}",
            process + 1
        );
    }

    // Adopt the run's distributed-trace identity before any span opens:
    // the welcome's NTP legs (t0 send, t1 server-receive, t2 server-send,
    // t3 receive) give offset = ((t1-t0)+(t2-t3))/2 — coordinator time
    // minus this process's time — and rtt = (t3-t0)-(t2-t1), both recorded
    // in the trace header so `sfprompt trace merge` can re-base this
    // process's spans onto the coordinator timeline (docs/TRACING.md).
    let telemetry = crate::telemetry::active();
    if let Some(t) = &telemetry {
        let (trace_id, span_base, t0, t1, t2, t3) = sync;
        if trace_id != 0 {
            t.tracer.set_trace_context(trace_id, &format!("client-{process}"), span_base);
            let offset = ((t1 - t0) + (t2 - t3)) / 2.0;
            let rtt = (t3 - t0) - (t2 - t1);
            t.tracer.set_clock(offset, rtt);
        }
    }

    let backend = spec.open_backend(artifacts_root)?;
    let backend: &dyn Backend = backend.as_ref();
    let manifest = backend.manifest();
    for stage in ["local_step", "el2n_scores", "head_forward", "tail_step", "prompt_grad"] {
        if !manifest.stages.contains_key(stage) {
            bail!("config {:?} was lowered without stage {stage:?}", manifest.config.name);
        }
    }
    let cfg = manifest.config.clone();
    let (train, _eval) = spec.datasets(&cfg)?;
    let labels = train.labels();
    // Rebuild the WHOLE fleet in canonical order (partition + RNG forks
    // must match the server and every sibling process), keep our share.
    let (clients, _selection_rng) = build_clients(&spec.fed, &labels);
    let owned: Vec<Client> =
        clients.into_iter().filter(|c| client_ids.contains(&c.id)).collect();
    let global = init_params(manifest, seeds::param_init(spec.fed.seed));
    let head_prep = backend.prepare_segment(global.get("head")?)?;
    let fed = spec.fed;
    let examples = &train.examples;

    let writer = Mutex::new(link.try_clone().context("splitting the socket")?);
    // Round → coordinator-side parent span id, fed by `RoundCtx` control
    // messages (always sent before the round's first frame) and read by the
    // workers when they open their `client:N` spans.
    let round_ctx: Mutex<BTreeMap<u32, u64>> = Mutex::new(BTreeMap::new());

    let (reason, rounds) = std::thread::scope(|scope| {
        let mut senders: BTreeMap<u32, Sender<WorkerMsg>> = BTreeMap::new();
        let mut handles = Vec::with_capacity(owned.len());
        for client in owned {
            let (tx, rx) = channel();
            senders.insert(client.id as u32, tx);
            let writer = &writer;
            let head = &head_prep;
            let fed = &fed;
            let cfg = &cfg;
            let round_ctx = &round_ctx;
            let quiet = opts.quiet;
            handles.push(scope.spawn(move || {
                worker_loop(
                    client, rx, writer, backend, examples, head, fed, cfg, round_ctx, quiet,
                )
            }));
        }

        // --- Demultiplexer: the socket's read half, on this thread. ---
        let mut last_probe = Instant::now();
        let demux: Result<String> = loop {
            match link.recv_msg(true) {
                Ok(None) => {
                    // Idle between rounds: traced clients use the silence
                    // to refresh their clock-offset estimate.
                    if let Some(t) = &telemetry {
                        if t.tracer.trace_id() != 0
                            && last_probe.elapsed() >= CLOCK_PROBE_INTERVAL
                        {
                            last_probe = Instant::now();
                            let probe = Control::ClockProbe { t0: t.tracer.now_s() };
                            let sent = writer
                                .lock()
                                .expect("writer lock poisoned")
                                .send_control(&probe);
                            if let Err(e) = sent {
                                break Err(e.context("connection to server lost"));
                            }
                        }
                    }
                    continue;
                }
                Ok(Some(NetMsg::Control(Control::RoundCtx { round, parent }, _))) => {
                    round_ctx.lock().expect("round context poisoned").insert(round, parent);
                }
                Ok(Some(NetMsg::Control(Control::ClockReply { t0, t1, t2 }, _))) => {
                    if let Some(t) = &telemetry {
                        let t3 = t.tracer.now_s();
                        t.tracer.set_clock(
                            ((t1 - t0) + (t2 - t3)) / 2.0,
                            (t3 - t0) - (t2 - t1),
                        );
                    }
                }
                Ok(Some(NetMsg::Frame(frame, n))) => match senders.get(&frame.client) {
                    Some(tx) => {
                        if tx.send(WorkerMsg::Frame(frame, n)).is_err() {
                            break Err(anyhow!("a worker exited with its round unfinished"));
                        }
                    }
                    None => {
                        break Err(anyhow!(
                            "server sent a frame for client {}, which this process does not own",
                            frame.client
                        ))
                    }
                },
                Ok(Some(NetMsg::Control(Control::Shutdown { reason }, _))) => break Ok(reason),
                Ok(Some(NetMsg::Control(Control::Reject { reason }, _))) => {
                    break Err(anyhow!("server rejected this process mid-run: {reason}"))
                }
                Ok(Some(NetMsg::Control(other, _))) => {
                    break Err(anyhow!("unexpected control message {:?}", other.kind()))
                }
                Err(e) => break Err(e.context("connection to server lost")),
            }
        };
        for tx in senders.values() {
            let _ = tx.send(WorkerMsg::Shutdown);
        }
        drop(senders);

        let mut rounds = 0usize;
        let mut worker_err: Option<anyhow::Error> = None;
        for h in handles {
            match h.join().expect("worker thread panicked") {
                Ok(n) => rounds += n,
                Err(e) if worker_err.is_none() => worker_err = Some(e),
                Err(_) => {}
            }
        }
        match (demux, worker_err) {
            // A local compute failure is the root cause — the connection
            // noise that follows it (server tearing the run down) is not.
            (_, Some(e)) => Err(e),
            (Err(e), None) => Err(e),
            (Ok(reason), None) => Ok((reason, rounds)),
        }
    })?;

    if reason != SHUTDOWN_COMPLETE {
        bail!("server ended the run: {reason}");
    }
    if !opts.quiet {
        eprintln!("client: run complete ({rounds} round participations)");
    }
    Ok(ClientSummary { process, processes, client_ids, rounds_participated: rounds })
}
