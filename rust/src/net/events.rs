//! Line-delimited JSON event stream for observers.
//!
//! The serve loop turns its [`RoundObserver`] callbacks into one strict
//! JSON object per line (format `"sfprompt-events"` v1) and fans each
//! line out to an optional file (`serve --events FILE`) and to every
//! connected observer socket (a peer whose first message was
//! `Control::Observe`). A dashboard can therefore `nc HOST PORT`, send
//! one observe handshake, and tail the run live. Dead observer sockets
//! never fail the run: a socket is culled when a write errors **or times
//! out** — [`EventSink::subscribe`] arms a bounded write timeout, and
//! between rounds the serve acceptor calls [`EventSink::tick`], which
//! sends a socket-only `heartbeat` line after
//! [`DEFAULT_HEARTBEAT`] of silence. A half-open peer (gone without a
//! FIN, send buffer slowly filling) therefore gets culled within one
//! heartbeat + timeout instead of holding a stale entry all run.
//!
//! Line schema (every line has `"event"`):
//!
//! | event            | extra keys                                           |
//! |------------------|------------------------------------------------------|
//! | `run_start`      | `format`, `version`, `method`, `rounds`, `clients`, `per_round` |
//! | `round_start`    | `round`                                              |
//! | `client_done`    | `round`, `client`, `finish_s`                        |
//! | `client_dropped` | `round`, `client`, `at_s`, `reason`                  |
//! | `eval`           | `round`, `accuracy`                                  |
//! | `round_end`      | `round`, `local_loss`, `split_loss`, `accuracy` (null off eval rounds), `bytes`, `survivors`, `dropped`, `sim_latency_s`, `clock_s` |
//! | `run_end`        | `rounds`, `final_accuracy`, `total_bytes`            |
//! | `health_anomaly` | `round`, `kind`, `value`, `threshold` ([`HealthObserver`]) |
//! | `health_straggler` | `round`, `client`, `ewma_s`, `median_s`            |
//! | `heartbeat`      | `seq`, `clocks` (socket-only; never written to the file) |
//!
//! The heartbeat's optional `clocks` object piggybacks the latest
//! clock-offset re-estimates from client [`Control::ClockProbe`] exchanges:
//! `{"<process>": {"offset_s": ..., "probes": N}}`, keyed by client process
//! index. Consumers that only know v1 heartbeats still parse the line —
//! `seq` is unchanged and extra keys are additive (check_trace.py --events
//! stays green). `offset_s` here is the coordinator's one-way estimate
//! (receive-stamp minus client send-stamp, so it includes the uplink
//! delay); the precise two-sided offset lives in the client's trace header.
//!
//! [`Control::ClockProbe`]: crate::net::control::Control::ClockProbe

use std::collections::BTreeMap;
use std::fs::File;
use std::io::Write;
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::federation::{FedConfig, Method, RoundObserver};
use crate::metrics::{RoundRecord, RunHistory};
use crate::sim::DropReason;
use crate::telemetry::{FlightRecorder, HealthRegistry};
use crate::util::json::Json;

/// Socket-silence threshold before [`EventSink::tick`] sends a heartbeat.
pub const DEFAULT_HEARTBEAT: Duration = Duration::from_secs(10);

/// Write timeout armed on every subscribed observer socket, so a stalled
/// peer times out instead of blocking the emitting thread.
const OBSERVER_WRITE_TIMEOUT: Duration = Duration::from_secs(5);

fn num_or_null(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Null
    }
}

#[derive(Default)]
struct HbState {
    /// Last time anything was written to the sockets; `None` until the
    /// first [`EventSink::tick`] arms the clock, so short runs and unit
    /// tests never see a spurious heartbeat.
    last: Option<Instant>,
    seq: u64,
}

/// Latest clock-offset re-estimate for one client process, as seen by the
/// coordinator when servicing a `ClockProbe`.
#[derive(Clone, Copy)]
struct ClockEstimate {
    offset_s: f64,
    probes: u64,
}

/// Where event lines go: an optional file plus any number of observer
/// sockets (shared with the acceptor thread, which appends mid-run).
#[derive(Clone)]
pub struct EventSink {
    file: Arc<Mutex<Option<File>>>,
    observers: Arc<Mutex<Vec<TcpStream>>>,
    hb: Arc<Mutex<HbState>>,
    /// Per-process clock re-estimates, written by the reader threads when a
    /// probe is serviced, drained into heartbeat lines.
    clocks: Arc<Mutex<BTreeMap<usize, ClockEstimate>>>,
    heartbeat: Duration,
}

impl Default for EventSink {
    fn default() -> EventSink {
        EventSink::new(None)
    }
}

impl EventSink {
    pub fn new(file: Option<File>) -> EventSink {
        EventSink {
            file: Arc::new(Mutex::new(file)),
            observers: Arc::default(),
            hb: Arc::default(),
            clocks: Arc::default(),
            heartbeat: DEFAULT_HEARTBEAT,
        }
    }

    /// Override the heartbeat interval (tests use a few milliseconds).
    pub fn with_heartbeat(mut self, interval: Duration) -> EventSink {
        self.heartbeat = interval;
        self
    }

    /// Register a subscribed observer socket. A write timeout is armed so
    /// a half-open peer whose send buffer fills causes a timed-out write
    /// (and gets culled) instead of blocking the serve loop forever.
    pub fn subscribe(&self, stream: TcpStream) {
        stream.set_write_timeout(Some(OBSERVER_WRITE_TIMEOUT)).ok();
        self.observers.lock().expect("observer list poisoned").push(stream);
    }

    /// Note a serviced clock probe: the next heartbeat line carries the
    /// latest estimate per process under its `clocks` key. Called from the
    /// reader threads, so this only touches its own lock.
    pub fn record_clock(&self, process: usize, offset_s: f64) {
        let mut clocks = self.clocks.lock().expect("clock estimates poisoned");
        let entry = clocks.entry(process).or_insert(ClockEstimate { offset_s, probes: 0 });
        entry.offset_s = offset_s;
        entry.probes += 1;
    }

    pub fn has_outputs(&self) -> bool {
        self.file.lock().expect("event file poisoned").is_some()
            || !self.observers.lock().expect("observer list poisoned").is_empty()
    }

    /// Write one event line everywhere. Observer sockets that error are
    /// dropped, and a failing file is disabled after one stderr report —
    /// an observer must never bring the federation down.
    pub fn emit(&self, line: &Json) {
        let text = format!("{line}\n");
        let mut file = self.file.lock().expect("event file poisoned");
        if let Some(f) = file.as_mut() {
            if let Err(e) = f.write_all(text.as_bytes()).and_then(|()| f.flush()) {
                eprintln!("serve: event file write failed ({e}); disabling file events");
                *file = None;
            }
        }
        drop(file);
        self.write_sockets(&text);
    }

    /// Periodic liveness check, called by the serve acceptor between
    /// admissions. When the sockets have been silent longer than the
    /// heartbeat interval, a `{"event":"heartbeat","seq":N}` line is sent
    /// to the sockets only (the file keeps its `run_start`..`run_end`
    /// bracket), which both lets observers detect a wedged server and —
    /// via the write timeout — culls peers that vanished without a FIN.
    pub fn tick(&self) {
        let due = {
            let mut hb = self.hb.lock().expect("heartbeat state poisoned");
            match hb.last {
                None => {
                    hb.last = Some(Instant::now());
                    return; // first tick only arms the clock
                }
                Some(last) if last.elapsed() < self.heartbeat => return,
                Some(_) => {
                    hb.seq += 1;
                    hb.seq
                }
            }
        };
        if self.observers.lock().expect("observer list poisoned").is_empty() {
            // Still refresh the clock so a later subscriber is not greeted
            // by an instant heartbeat burst.
            self.hb.lock().expect("heartbeat state poisoned").last = Some(Instant::now());
            return;
        }
        let mut o = BTreeMap::new();
        o.insert("event".to_string(), Json::Str("heartbeat".to_string()));
        o.insert("seq".to_string(), Json::Num(due as f64));
        let clocks = self.clocks.lock().expect("clock estimates poisoned");
        if !clocks.is_empty() {
            let mut c = BTreeMap::new();
            for (process, est) in clocks.iter() {
                let mut e = BTreeMap::new();
                e.insert("offset_s".to_string(), num_or_null(est.offset_s));
                e.insert("probes".to_string(), Json::Num(est.probes as f64));
                c.insert(process.to_string(), Json::Obj(e));
            }
            o.insert("clocks".to_string(), Json::Obj(c));
        }
        drop(clocks);
        self.write_sockets(&format!("{}\n", Json::Obj(o)));
    }

    fn write_sockets(&self, text: &str) {
        let mut socks = self.observers.lock().expect("observer list poisoned");
        socks.retain_mut(|s| s.write_all(text.as_bytes()).is_ok());
        drop(socks);
        self.hb.lock().expect("heartbeat state poisoned").last = Some(Instant::now());
    }
}

/// [`RoundObserver`] that serialises every callback into the sink.
pub struct EventStreamObserver {
    sink: EventSink,
    clock_s: f64,
}

impl EventStreamObserver {
    pub fn new(sink: EventSink) -> EventStreamObserver {
        EventStreamObserver { sink, clock_s: 0.0 }
    }

    fn line(&self, event: &str, fields: Vec<(&str, Json)>) {
        let mut o = BTreeMap::new();
        o.insert("event".to_string(), Json::Str(event.to_string()));
        for (k, v) in fields {
            o.insert(k.to_string(), v);
        }
        self.sink.emit(&Json::Obj(o));
    }
}

impl RoundObserver for EventStreamObserver {
    fn on_run_start(&mut self, method: Method, fed: &FedConfig) {
        self.line(
            "run_start",
            vec![
                ("format", Json::Str("sfprompt-events".to_string())),
                ("version", Json::Num(1.0)),
                ("method", Json::Str(method.label().to_string())),
                ("rounds", Json::Num(fed.rounds as f64)),
                ("clients", Json::Num(fed.num_clients as f64)),
                ("per_round", Json::Num(fed.clients_per_round as f64)),
            ],
        );
    }

    fn on_round_start(&mut self, round: usize) {
        self.line("round_start", vec![("round", Json::Num(round as f64))]);
    }

    fn on_client_done(&mut self, round: usize, client: usize, finish_s: f64) {
        self.line(
            "client_done",
            vec![
                ("round", Json::Num(round as f64)),
                ("client", Json::Num(client as f64)),
                ("finish_s", num_or_null(finish_s)),
            ],
        );
    }

    fn on_client_dropped(&mut self, round: usize, client: usize, at_s: f64, reason: DropReason) {
        self.line(
            "client_dropped",
            vec![
                ("round", Json::Num(round as f64)),
                ("client", Json::Num(client as f64)),
                ("at_s", num_or_null(at_s)),
                ("reason", Json::Str(reason.label().to_string())),
            ],
        );
    }

    fn on_eval(&mut self, round: usize, accuracy: f64) {
        self.line(
            "eval",
            vec![("round", Json::Num(round as f64)), ("accuracy", num_or_null(accuracy))],
        );
    }

    fn on_round_end(&mut self, rec: &RoundRecord, clock_s: f64) {
        self.clock_s = clock_s;
        self.line(
            "round_end",
            vec![
                ("round", Json::Num(rec.round as f64)),
                ("local_loss", num_or_null(rec.mean_local_loss)),
                ("split_loss", num_or_null(rec.mean_split_loss)),
                ("accuracy", num_or_null(rec.eval_accuracy)),
                ("bytes", Json::Num(rec.comm.total() as f64)),
                ("survivors", Json::Num(rec.survivors() as f64)),
                ("dropped", Json::Num(rec.dropped() as f64)),
                ("sim_latency_s", num_or_null(rec.sim_latency_s)),
                ("clock_s", num_or_null(clock_s)),
            ],
        );
    }

    fn on_run_end(&mut self, history: &RunHistory) {
        self.line(
            "run_end",
            vec![
                ("rounds", Json::Num(history.rounds.len() as f64)),
                ("final_accuracy", num_or_null(history.final_accuracy())),
                ("total_bytes", Json::Num(history.total_comm.total() as f64)),
            ],
        );
    }
}

/// [`RoundObserver`] that drives the serve-side [`HealthRegistry`], mirrors
/// the round stream into the [`FlightRecorder`], and emits typed
/// `health_anomaly` / `health_straggler` event lines. When a post-mortem
/// path is set, the flight ring is dumped the moment an anomaly fires, so
/// the evidence survives even if the process dies right after.
pub struct HealthObserver {
    registry: Arc<HealthRegistry>,
    flight: Arc<FlightRecorder>,
    sink: EventSink,
    postmortem: Option<PathBuf>,
    quiet: bool,
}

impl HealthObserver {
    pub fn new(
        registry: Arc<HealthRegistry>,
        flight: Arc<FlightRecorder>,
        sink: EventSink,
    ) -> HealthObserver {
        HealthObserver { registry, flight, sink, postmortem: None, quiet: false }
    }

    pub fn with_postmortem(mut self, path: Option<PathBuf>) -> HealthObserver {
        self.postmortem = path;
        self
    }

    pub fn quiet(mut self, quiet: bool) -> HealthObserver {
        self.quiet = quiet;
        self
    }

    fn line(&self, event: &str, fields: Vec<(&str, Json)>) {
        let mut o = BTreeMap::new();
        o.insert("event".to_string(), Json::Str(event.to_string()));
        for (k, v) in fields {
            o.insert(k.to_string(), v);
        }
        self.sink.emit(&Json::Obj(o));
    }

    fn anomaly_fired(&self, a: &crate::telemetry::Anomaly) {
        self.flight
            .record("anomaly", a.kind.label(), a.round as f64, a.value, a.threshold);
        self.line(
            "health_anomaly",
            vec![
                ("round", Json::Num(a.round as f64)),
                ("kind", Json::Str(a.kind.label().to_string())),
                ("value", num_or_null(a.value)),
                ("threshold", num_or_null(a.threshold)),
            ],
        );
        if !self.quiet {
            eprintln!(
                "serve: health anomaly at round {}: {} (value {}, threshold {})",
                a.round,
                a.kind.label(),
                a.value,
                a.threshold
            );
        }
        self.dump_postmortem("anomaly");
    }

    /// Dump the flight ring to the configured post-mortem path (best
    /// effort; a failing dump is reported, never fatal).
    pub fn dump_postmortem(&self, why: &str) {
        if let Some(path) = &self.postmortem {
            match self.flight.dump_to(path) {
                Ok(()) if !self.quiet => {
                    eprintln!("serve: post-mortem ({why}) written to {}", path.display());
                }
                Ok(()) => {}
                Err(e) => eprintln!("serve: post-mortem dump failed: {e}"),
            }
        }
    }
}

impl RoundObserver for HealthObserver {
    fn on_run_start(&mut self, method: Method, fed: &FedConfig) {
        self.registry.begin_run(method.label(), fed.rounds, fed.num_clients);
        self.flight.record(
            "health",
            "run_start",
            fed.rounds as f64,
            fed.num_clients as f64,
            fed.clients_per_round as f64,
        );
    }

    fn on_round_start(&mut self, round: usize) {
        self.flight.record("health", "round_start", round as f64, 0.0, 0.0);
    }

    fn on_client_done(&mut self, round: usize, client: usize, finish_s: f64) {
        self.registry.client_done(round, client, finish_s);
        self.flight
            .record("health", "client_done", round as f64, client as f64, finish_s);
    }

    fn on_client_dropped(&mut self, round: usize, client: usize, at_s: f64, reason: DropReason) {
        self.registry.client_dropped(round, client);
        self.flight
            .record("health", reason.label(), round as f64, client as f64, at_s);
    }

    fn on_eval(&mut self, round: usize, accuracy: f64) {
        self.flight.record("health", "eval", round as f64, accuracy, 0.0);
        if let Some(a) = self.registry.eval(round, accuracy) {
            self.anomaly_fired(&a);
        }
    }

    fn on_round_end(&mut self, rec: &RoundRecord, clock_s: f64) {
        let rh = self.registry.round_end(
            rec.round,
            rec.mean_local_loss,
            rec.mean_split_loss,
            rec.survivors(),
            rec.comm.total(),
            rec.comm.raw_total(),
            clock_s,
        );
        self.flight.record(
            "health",
            "round_end",
            rec.round as f64,
            rec.comm.total() as f64,
            clock_s,
        );
        for a in &rh.anomalies {
            self.anomaly_fired(a);
        }
        for s in &rh.new_stragglers {
            self.flight
                .record("anomaly", "straggler", s.round as f64, s.client as f64, s.ewma_s);
            self.line(
                "health_straggler",
                vec![
                    ("round", Json::Num(s.round as f64)),
                    ("client", Json::Num(s.client as f64)),
                    ("ewma_s", num_or_null(s.ewma_s)),
                    ("median_s", num_or_null(s.median_s)),
                ],
            );
            if !self.quiet {
                eprintln!(
                    "serve: client {} flagged straggler at round {} (ewma {:.3}s vs median {:.3}s)",
                    s.client, s.round, s.ewma_s, s.median_s
                );
            }
        }
    }

    fn on_run_end(&mut self, history: &RunHistory) {
        self.registry.end_run(false);
        self.flight.record(
            "health",
            "run_end",
            history.rounds.len() as f64,
            history.final_accuracy(),
            history.total_comm.total() as f64,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Read;
    use std::net::TcpListener;

    #[test]
    fn events_reach_file_and_socket_as_json_lines() {
        let dir = std::env::temp_dir().join("sfprompt_events_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("events.jsonl");
        let sink = EventSink::new(Some(File::create(&path).unwrap()));

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let reader = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let mut buf = String::new();
            s.read_to_string(&mut buf).unwrap();
            buf
        });
        sink.subscribe(TcpStream::connect(addr).unwrap());

        let mut obs = EventStreamObserver::new(sink.clone());
        obs.on_run_start(Method::SfPrompt, &FedConfig::default());
        obs.on_round_start(0);
        obs.on_eval(0, 0.5);
        drop(obs);
        // Close the observer socket so read_to_string terminates.
        sink.observers.lock().unwrap().clear();

        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        let first = Json::parse(lines[0]).unwrap();
        assert_eq!(first.get("event").unwrap().as_str(), Some("run_start"));
        assert_eq!(first.get("format").unwrap().as_str(), Some("sfprompt-events"));
        for line in &lines {
            Json::parse(line).unwrap();
        }
        assert_eq!(reader.join().unwrap(), text, "socket observers see the same stream");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn dead_observer_socket_is_dropped_not_fatal() {
        let sink = EventSink::new(None);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server_side, _) = listener.accept().unwrap();
        drop(client); // peer goes away immediately
        sink.subscribe(server_side);
        let mut obs = EventStreamObserver::new(sink.clone());
        // First write may land in the send buffer; keep emitting until the
        // broken pipe surfaces and the socket is culled.
        for round in 0..100 {
            obs.on_round_start(round);
            if !sink.has_outputs() {
                break;
            }
        }
        assert!(!sink.has_outputs(), "dead observer must eventually be culled");
    }

    #[test]
    fn heartbeat_reaches_sockets_only_after_the_interval() {
        let sink = EventSink::new(None).with_heartbeat(Duration::from_millis(5));
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        sink.subscribe(TcpStream::connect(addr).unwrap());
        let (mut server_side, _) = listener.accept().unwrap();

        sink.tick(); // arms the clock only — no heartbeat yet
        std::thread::sleep(Duration::from_millis(10));
        sink.tick(); // past the interval: emits heartbeat 1
        sink.tick(); // clock was just refreshed: silent
        sink.observers.lock().unwrap().clear(); // close so read terminates

        let mut buf = String::new();
        server_side.read_to_string(&mut buf).unwrap();
        let lines: Vec<&str> = buf.lines().collect();
        assert_eq!(lines.len(), 1, "exactly one heartbeat: {buf:?}");
        let hb = Json::parse(lines[0]).unwrap();
        assert_eq!(hb.get("event").unwrap().as_str(), Some("heartbeat"));
        assert_eq!(hb.get("seq").and_then(Json::as_f64), Some(1.0));
    }

    #[test]
    fn heartbeat_piggybacks_clock_estimates_without_breaking_the_schema() {
        let sink = EventSink::new(None).with_heartbeat(Duration::from_millis(5));
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        sink.subscribe(TcpStream::connect(addr).unwrap());
        let (mut server_side, _) = listener.accept().unwrap();

        sink.record_clock(1, 0.25);
        sink.record_clock(1, 0.125); // latest estimate wins, probes accumulate
        sink.tick(); // arm
        std::thread::sleep(Duration::from_millis(10));
        sink.tick(); // heartbeat with clocks
        sink.observers.lock().unwrap().clear();

        let mut buf = String::new();
        server_side.read_to_string(&mut buf).unwrap();
        let hb = Json::parse(buf.lines().next().unwrap()).unwrap();
        assert_eq!(hb.get("event").unwrap().as_str(), Some("heartbeat"));
        assert_eq!(hb.get("seq").and_then(Json::as_f64), Some(1.0), "v1 key unchanged");
        let clock = hb.get("clocks").and_then(|c| c.get("1")).expect("clocks.1 present");
        assert_eq!(clock.get("offset_s").and_then(Json::as_f64), Some(0.125));
        assert_eq!(clock.get("probes").and_then(Json::as_f64), Some(2.0));
    }

    #[test]
    fn tick_culls_a_peer_that_vanished_without_a_fin() {
        let sink = EventSink::new(None).with_heartbeat(Duration::from_millis(1));
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server_side, _) = listener.accept().unwrap();
        drop(client); // peer gone; no more emits will happen
        sink.subscribe(server_side);
        sink.tick(); // arm
        // Heartbeats alone must discover the dead peer (the PR-8 behaviour
        // only culled on the next *event* write, which may never come).
        for _ in 0..200 {
            std::thread::sleep(Duration::from_millis(2));
            sink.tick();
            if !sink.has_outputs() {
                break;
            }
        }
        assert!(!sink.has_outputs(), "heartbeat ticks must cull the dead peer");
    }

    #[test]
    fn health_observer_fires_anomaly_events_and_postmortem_dump() {
        use crate::comm::ByteMeter;
        use crate::sim::{ClientEvent, ClientOutcome};

        let dir = std::env::temp_dir().join("sfprompt_health_obs_test");
        std::fs::create_dir_all(&dir).unwrap();
        let events_path = dir.join("events.jsonl");
        let pm_path = dir.join("postmortem.jsonl");
        std::fs::remove_file(&pm_path).ok();

        let sink = EventSink::new(Some(File::create(&events_path).unwrap()));
        let registry = Arc::new(HealthRegistry::new());
        let flight = Arc::new(FlightRecorder::with_capacity(64));
        let mut obs = HealthObserver::new(registry.clone(), flight.clone(), sink)
            .with_postmortem(Some(pm_path.clone()))
            .quiet(true);

        let rec = |round: usize, loss: f64| RoundRecord {
            round,
            mean_local_loss: loss,
            mean_split_loss: loss,
            eval_accuracy: f64::NAN,
            comm: ByteMeter::default(),
            wall_s: 0.0,
            sim_latency_s: 1.0,
            clients: (0..3)
                .map(|c| ClientEvent { client: c, at_s: 1.0, outcome: ClientOutcome::Done })
                .collect(),
        };
        obs.on_run_start(Method::SfPrompt, &FedConfig::default());
        obs.on_round_end(&rec(0, 1.0), 1.0); // baseline
        obs.on_round_end(&rec(1, 100.0), 2.0); // 100x baseline: explodes

        let anomalies = registry.anomalies();
        assert_eq!(anomalies.len(), 1);
        assert_eq!(anomalies[0].kind, crate::telemetry::AnomalyKind::ExplodingLoss);

        let text = std::fs::read_to_string(&events_path).unwrap();
        let anomaly_line = text
            .lines()
            .map(|l| Json::parse(l).unwrap())
            .find(|j| j.get("event").and_then(Json::as_str) == Some("health_anomaly"))
            .expect("health_anomaly event line emitted");
        assert_eq!(
            anomaly_line.get("kind").and_then(Json::as_str),
            Some("loss_exploding")
        );

        let pm = std::fs::read_to_string(&pm_path).expect("post-mortem dumped on anomaly");
        let meta = Json::parse(pm.lines().next().unwrap()).unwrap();
        assert_eq!(meta.get("ev").and_then(Json::as_str), Some("meta"));
        assert!(
            pm.lines()
                .skip(1)
                .map(|l| Json::parse(l).unwrap())
                .any(|j| j.get("kind").and_then(Json::as_str) == Some("anomaly")),
            "flight dump carries the anomaly entry"
        );
        std::fs::remove_file(&events_path).ok();
        std::fs::remove_file(&pm_path).ok();
    }
}
