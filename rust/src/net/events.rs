//! Line-delimited JSON event stream for observers.
//!
//! The serve loop turns its [`RoundObserver`] callbacks into one strict
//! JSON object per line (format `"sfprompt-events"` v1) and fans each
//! line out to an optional file (`serve --events FILE`) and to every
//! connected observer socket (a peer whose first message was
//! `Control::Observe`). A dashboard can therefore `nc HOST PORT`, send
//! one observe handshake, and tail the run live; dead observer sockets
//! are dropped on the first failed write, never failing the run.
//!
//! Line schema (every line has `"event"`):
//!
//! | event            | extra keys                                           |
//! |------------------|------------------------------------------------------|
//! | `run_start`      | `format`, `version`, `method`, `rounds`, `clients`, `per_round` |
//! | `round_start`    | `round`                                              |
//! | `client_done`    | `round`, `client`, `finish_s`                        |
//! | `client_dropped` | `round`, `client`, `at_s`, `reason`                  |
//! | `eval`           | `round`, `accuracy`                                  |
//! | `round_end`      | `round`, `local_loss`, `split_loss`, `accuracy` (null off eval rounds), `bytes`, `survivors`, `dropped`, `sim_latency_s`, `clock_s` |
//! | `run_end`        | `rounds`, `final_accuracy`, `total_bytes`            |

use std::collections::BTreeMap;
use std::fs::File;
use std::io::Write;
use std::net::TcpStream;
use std::sync::{Arc, Mutex};

use crate::federation::{FedConfig, Method, RoundObserver};
use crate::metrics::{RoundRecord, RunHistory};
use crate::sim::DropReason;
use crate::util::json::Json;

fn num_or_null(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Null
    }
}

/// Where event lines go: an optional file plus any number of observer
/// sockets (shared with the acceptor thread, which appends mid-run).
#[derive(Clone, Default)]
pub struct EventSink {
    file: Arc<Mutex<Option<File>>>,
    observers: Arc<Mutex<Vec<TcpStream>>>,
}

impl EventSink {
    pub fn new(file: Option<File>) -> EventSink {
        EventSink { file: Arc::new(Mutex::new(file)), observers: Arc::default() }
    }

    /// Register a subscribed observer socket.
    pub fn subscribe(&self, stream: TcpStream) {
        self.observers.lock().expect("observer list poisoned").push(stream);
    }

    pub fn has_outputs(&self) -> bool {
        self.file.lock().expect("event file poisoned").is_some()
            || !self.observers.lock().expect("observer list poisoned").is_empty()
    }

    /// Write one event line everywhere. Observer sockets that error are
    /// dropped, and a failing file is disabled after one stderr report —
    /// an observer must never bring the federation down.
    pub fn emit(&self, line: &Json) {
        let text = format!("{line}\n");
        let mut file = self.file.lock().expect("event file poisoned");
        if let Some(f) = file.as_mut() {
            if let Err(e) = f.write_all(text.as_bytes()).and_then(|()| f.flush()) {
                eprintln!("serve: event file write failed ({e}); disabling file events");
                *file = None;
            }
        }
        drop(file);
        let mut socks = self.observers.lock().expect("observer list poisoned");
        socks.retain_mut(|s| s.write_all(text.as_bytes()).is_ok());
    }
}

/// [`RoundObserver`] that serialises every callback into the sink.
pub struct EventStreamObserver {
    sink: EventSink,
    clock_s: f64,
}

impl EventStreamObserver {
    pub fn new(sink: EventSink) -> EventStreamObserver {
        EventStreamObserver { sink, clock_s: 0.0 }
    }

    fn line(&self, event: &str, fields: Vec<(&str, Json)>) {
        let mut o = BTreeMap::new();
        o.insert("event".to_string(), Json::Str(event.to_string()));
        for (k, v) in fields {
            o.insert(k.to_string(), v);
        }
        self.sink.emit(&Json::Obj(o));
    }
}

impl RoundObserver for EventStreamObserver {
    fn on_run_start(&mut self, method: Method, fed: &FedConfig) {
        self.line(
            "run_start",
            vec![
                ("format", Json::Str("sfprompt-events".to_string())),
                ("version", Json::Num(1.0)),
                ("method", Json::Str(method.label().to_string())),
                ("rounds", Json::Num(fed.rounds as f64)),
                ("clients", Json::Num(fed.num_clients as f64)),
                ("per_round", Json::Num(fed.clients_per_round as f64)),
            ],
        );
    }

    fn on_round_start(&mut self, round: usize) {
        self.line("round_start", vec![("round", Json::Num(round as f64))]);
    }

    fn on_client_done(&mut self, round: usize, client: usize, finish_s: f64) {
        self.line(
            "client_done",
            vec![
                ("round", Json::Num(round as f64)),
                ("client", Json::Num(client as f64)),
                ("finish_s", num_or_null(finish_s)),
            ],
        );
    }

    fn on_client_dropped(&mut self, round: usize, client: usize, at_s: f64, reason: DropReason) {
        self.line(
            "client_dropped",
            vec![
                ("round", Json::Num(round as f64)),
                ("client", Json::Num(client as f64)),
                ("at_s", num_or_null(at_s)),
                ("reason", Json::Str(reason.label().to_string())),
            ],
        );
    }

    fn on_eval(&mut self, round: usize, accuracy: f64) {
        self.line(
            "eval",
            vec![("round", Json::Num(round as f64)), ("accuracy", num_or_null(accuracy))],
        );
    }

    fn on_round_end(&mut self, rec: &RoundRecord, clock_s: f64) {
        self.clock_s = clock_s;
        self.line(
            "round_end",
            vec![
                ("round", Json::Num(rec.round as f64)),
                ("local_loss", num_or_null(rec.mean_local_loss)),
                ("split_loss", num_or_null(rec.mean_split_loss)),
                ("accuracy", num_or_null(rec.eval_accuracy)),
                ("bytes", Json::Num(rec.comm.total() as f64)),
                ("survivors", Json::Num(rec.survivors() as f64)),
                ("dropped", Json::Num(rec.dropped() as f64)),
                ("sim_latency_s", num_or_null(rec.sim_latency_s)),
                ("clock_s", num_or_null(clock_s)),
            ],
        );
    }

    fn on_run_end(&mut self, history: &RunHistory) {
        self.line(
            "run_end",
            vec![
                ("rounds", Json::Num(history.rounds.len() as f64)),
                ("final_accuracy", num_or_null(history.final_accuracy())),
                ("total_bytes", Json::Num(history.total_comm.total() as f64)),
            ],
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Read;
    use std::net::TcpListener;

    #[test]
    fn events_reach_file_and_socket_as_json_lines() {
        let dir = std::env::temp_dir().join("sfprompt_events_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("events.jsonl");
        let sink = EventSink::new(Some(File::create(&path).unwrap()));

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let reader = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let mut buf = String::new();
            s.read_to_string(&mut buf).unwrap();
            buf
        });
        sink.subscribe(TcpStream::connect(addr).unwrap());

        let mut obs = EventStreamObserver::new(sink.clone());
        obs.on_run_start(Method::SfPrompt, &FedConfig::default());
        obs.on_round_start(0);
        obs.on_eval(0, 0.5);
        drop(obs);
        // Close the observer socket so read_to_string terminates.
        sink.observers.lock().unwrap().clear();

        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        let first = Json::parse(lines[0]).unwrap();
        assert_eq!(first.get("event").unwrap().as_str(), Some("run_start"));
        assert_eq!(first.get("format").unwrap().as_str(), Some("sfprompt-events"));
        for line in &lines {
            Json::parse(line).unwrap();
        }
        assert_eq!(reader.join().unwrap(), text, "socket observers see the same stream");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn dead_observer_socket_is_dropped_not_fatal() {
        let sink = EventSink::new(None);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server_side, _) = listener.accept().unwrap();
        drop(client); // peer goes away immediately
        sink.subscribe(server_side);
        let mut obs = EventStreamObserver::new(sink.clone());
        // First write may land in the send buffer; keep emitting until the
        // broken pipe surfaces and the socket is culled.
        for round in 0..100 {
            obs.on_round_start(round);
            if !sink.has_outputs() {
                break;
            }
        }
        assert!(!sink.has_outputs(), "dead observer must eventually be culled");
    }
}
