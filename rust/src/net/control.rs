//! Control-plane messages: the typed handshake and round bookkeeping that
//! ride the socket alongside codec data frames.
//!
//! Strict, unknown-rejecting JSON in the same style as
//! [`crate::federation::RunSpec`] — a typo'd or stale peer fails loudly at
//! the first message, not three rounds in. Every message is an object with
//! a `"kind"` discriminator; keys outside each kind's documented set are
//! errors.
//!
//! Floats that must survive the trip **bit-exactly** (the per-round loss
//! vectors feeding the report's means, NaN included) travel as 16-hex-digit
//! bit-pattern strings (`f64::to_bits`), not JSON numbers — JSON has no
//! NaN and no bit-pattern guarantee; the hex form has both.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::federation::RunSpec;
use crate::util::json::Json;

/// The shutdown reason a clean run ends with; anything else means the
/// server tore the run down on an error and clients should exit nonzero.
pub const SHUTDOWN_COMPLETE: &str = "run complete";

/// A control-plane message (`"NC"` envelope — see [`super::wire`]).
#[derive(Debug, Clone)]
pub enum Control {
    /// Client → server, first message on a connection: identify and pin
    /// both protocol layers. An empty `run_id` means "whatever run you are
    /// serving". `t0` is the client's send timestamp (its local monotonic
    /// clock) — the first leg of the NTP-style handshake clock estimate
    /// (docs/TRACING.md); the server echoes it in [`Control::Welcome`].
    Hello { proto: u8, wire: u8, name: String, run_id: String, t0: f64 },
    /// Server → client, handshake accept: the process's slice of the
    /// federation plus the full [`RunSpec`], from which the client
    /// regenerates its datasets and RNG streams deterministically. Also
    /// carries the distributed-trace identity (run-wide `trace_id`, this
    /// process's disjoint span-id block) and the server-side NTP
    /// timestamps: `t0` echoes the Hello's stamp, `t1`/`t2` are the
    /// server's receive/send times on its own clock.
    Welcome {
        proto: u8,
        wire: u8,
        run_id: String,
        /// This connection's process index in `0..processes`.
        process: usize,
        /// Total client processes the server admits for the run.
        processes: usize,
        /// Logical client ids this process owns (`cid % processes == process`).
        client_ids: Vec<usize>,
        spec: RunSpec,
        /// Run-wide 128-bit trace id (0 when the server runs untraced).
        trace_id: u128,
        /// Start of this process's span-id block; the client allocates
        /// span ids from `span_base + 1`.
        span_base: u64,
        /// NTP handshake legs: client send (echoed), server recv, server send.
        t0: f64,
        t1: f64,
        t2: f64,
    },
    /// Server → client, immediately before a round's first data frame:
    /// the coordinator-side span id this process's `client:N` spans
    /// should parent under. TCP ordering guarantees it lands before the
    /// round's `ModelDistribution` frame.
    RoundCtx { round: u32, parent: u64 },
    /// Client → server: a periodic NTP-style clock probe (`t0` = client
    /// send time). The server answers with [`Control::ClockReply`].
    ClockProbe { t0: f64 },
    /// Server → client: `t0` echoed, `t1`/`t2` server recv/send times —
    /// the client computes offset/RTT and re-stamps its trace header.
    ClockReply { t0: f64, t1: f64, t2: f64 },
    /// Server → peer, handshake refuse (version mismatch, wrong run id,
    /// run already full); the server closes the connection after sending.
    Reject { reason: String },
    /// Peer → server, first message: subscribe to the line-delimited JSON
    /// round-event stream instead of joining as a client.
    Observe { proto: u8 },
    /// Peer → server, first message: ask for a point-in-time server
    /// snapshot instead of joining. Version-gated like `observe`; the
    /// server answers with one [`Control::StatusReply`] and closes, so a
    /// poller (`sfprompt top`) reconnects per sample.
    Status { proto: u8 },
    /// Server → peer: the snapshot. `body` is a JSON object (run/round
    /// progress, per-client health table, byte totals, hottest stages —
    /// schema in `docs/OPS.md`); it is carried opaquely so the snapshot
    /// can grow without a control-protocol bump.
    StatusReply { body: Json },
    /// Client → server after finishing a logical client's round: the
    /// per-epoch loss vectors the in-process engine would have returned
    /// from its client thread. Bit-exact via hex bit patterns.
    RoundReport { round: u32, client: u32, local_losses: Vec<f64>, split_losses: Vec<f64> },
    /// Server → client: the run is over (or aborting); drain and exit.
    Shutdown { reason: String },
}

fn hex_losses(vals: &[f64]) -> Json {
    Json::Arr(vals.iter().map(|v| Json::Str(format!("{:016x}", v.to_bits()))).collect())
}

/// One f64 as a 16-hex-digit bit pattern — same bit-exact transport as
/// the loss vectors, used for the NTP timestamp legs.
fn hex_f64(v: f64) -> Json {
    Json::Str(format!("{:016x}", v.to_bits()))
}

fn f64_from_hex(obj: &BTreeMap<String, Json>, kind: &str, key: &str) -> Result<f64> {
    let s = obj
        .get(key)
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("control {kind:?} needs hex bit-pattern string key {key:?}"))?;
    let bits = u64::from_str_radix(s, 16)
        .map_err(|_| anyhow!("control {kind:?} key {key:?} is not a 64-bit hex pattern"))?;
    Ok(f64::from_bits(bits))
}

fn losses_from(v: &Json, key: &str) -> Result<Vec<f64>> {
    v.get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("control {key:?} must be an array of hex bit-pattern strings"))?
        .iter()
        .map(|j| {
            let s = j.as_str().ok_or_else(|| anyhow!("control {key:?} entries must be strings"))?;
            let bits = u64::from_str_radix(s, 16)
                .map_err(|_| anyhow!("control {key:?} entry {s:?} is not a 64-bit hex pattern"))?;
            Ok(f64::from_bits(bits))
        })
        .collect()
}

fn check_keys(obj: &BTreeMap<String, Json>, kind: &str, known: &[&str]) -> Result<()> {
    for key in obj.keys() {
        if key != "kind" && !known.contains(&key.as_str()) {
            bail!(
                "unknown key {key:?} in control message {kind:?} (known: kind {})",
                known.join(" ")
            );
        }
    }
    Ok(())
}

fn u8_field(obj: &BTreeMap<String, Json>, kind: &str, key: &str) -> Result<u8> {
    obj.get(key)
        .and_then(Json::as_usize)
        .and_then(|n| u8::try_from(n).ok())
        .ok_or_else(|| anyhow!("control {kind:?} needs integer key {key:?} in 0..=255"))
}

fn u32_field(obj: &BTreeMap<String, Json>, kind: &str, key: &str) -> Result<u32> {
    obj.get(key)
        .and_then(Json::as_i64)
        .and_then(|n| u32::try_from(n).ok())
        .ok_or_else(|| anyhow!("control {kind:?} needs non-negative integer key {key:?}"))
}

/// Span ids / span bases: non-negative integers. They stay below 2^53
/// by construction (per-process blocks start at `(process + 1) << 40`),
/// so a JSON number carries them exactly.
fn u64_field(obj: &BTreeMap<String, Json>, kind: &str, key: &str) -> Result<u64> {
    obj.get(key)
        .and_then(Json::as_i64)
        .and_then(|n| u64::try_from(n).ok())
        .ok_or_else(|| anyhow!("control {kind:?} needs non-negative integer key {key:?}"))
}

fn str_field(obj: &BTreeMap<String, Json>, kind: &str, key: &str) -> Result<String> {
    obj.get(key)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| anyhow!("control {kind:?} needs string key {key:?}"))
}

impl Control {
    pub fn kind(&self) -> &'static str {
        match self {
            Control::Hello { .. } => "hello",
            Control::Welcome { .. } => "welcome",
            Control::RoundCtx { .. } => "round_ctx",
            Control::ClockProbe { .. } => "clock",
            Control::ClockReply { .. } => "clock_reply",
            Control::Reject { .. } => "reject",
            Control::Observe { .. } => "observe",
            Control::Status { .. } => "status",
            Control::StatusReply { .. } => "status_reply",
            Control::RoundReport { .. } => "round_report",
            Control::Shutdown { .. } => "shutdown",
        }
    }

    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("kind".to_string(), Json::Str(self.kind().to_string()));
        match self {
            Control::Hello { proto, wire, name, run_id, t0 } => {
                o.insert("proto".to_string(), Json::Num(*proto as f64));
                o.insert("wire".to_string(), Json::Num(*wire as f64));
                o.insert("name".to_string(), Json::Str(name.clone()));
                o.insert("run_id".to_string(), Json::Str(run_id.clone()));
                o.insert("t0".to_string(), hex_f64(*t0));
            }
            Control::Welcome {
                proto,
                wire,
                run_id,
                process,
                processes,
                client_ids,
                spec,
                trace_id,
                span_base,
                t0,
                t1,
                t2,
            } => {
                o.insert("proto".to_string(), Json::Num(*proto as f64));
                o.insert("wire".to_string(), Json::Num(*wire as f64));
                o.insert("run_id".to_string(), Json::Str(run_id.clone()));
                o.insert("process".to_string(), Json::Num(*process as f64));
                o.insert("processes".to_string(), Json::Num(*processes as f64));
                o.insert(
                    "client_ids".to_string(),
                    Json::Arr(client_ids.iter().map(|&c| Json::Num(c as f64)).collect()),
                );
                o.insert("spec".to_string(), spec.to_json());
                o.insert("trace_id".to_string(), Json::Str(format!("{trace_id:032x}")));
                o.insert("span_base".to_string(), Json::Num(*span_base as f64));
                o.insert("t0".to_string(), hex_f64(*t0));
                o.insert("t1".to_string(), hex_f64(*t1));
                o.insert("t2".to_string(), hex_f64(*t2));
            }
            Control::RoundCtx { round, parent } => {
                o.insert("round".to_string(), Json::Num(*round as f64));
                o.insert("parent".to_string(), Json::Num(*parent as f64));
            }
            Control::ClockProbe { t0 } => {
                o.insert("t0".to_string(), hex_f64(*t0));
            }
            Control::ClockReply { t0, t1, t2 } => {
                o.insert("t0".to_string(), hex_f64(*t0));
                o.insert("t1".to_string(), hex_f64(*t1));
                o.insert("t2".to_string(), hex_f64(*t2));
            }
            Control::Reject { reason } => {
                o.insert("reason".to_string(), Json::Str(reason.clone()));
            }
            Control::Observe { proto } => {
                o.insert("proto".to_string(), Json::Num(*proto as f64));
            }
            Control::Status { proto } => {
                o.insert("proto".to_string(), Json::Num(*proto as f64));
            }
            Control::StatusReply { body } => {
                o.insert("body".to_string(), body.clone());
            }
            Control::RoundReport { round, client, local_losses, split_losses } => {
                o.insert("round".to_string(), Json::Num(*round as f64));
                o.insert("client".to_string(), Json::Num(*client as f64));
                o.insert("local_losses".to_string(), hex_losses(local_losses));
                o.insert("split_losses".to_string(), hex_losses(split_losses));
            }
            Control::Shutdown { reason } => {
                o.insert("reason".to_string(), Json::Str(reason.clone()));
            }
        }
        Json::Obj(o)
    }

    pub fn from_json(v: &Json) -> Result<Control> {
        let obj = v.as_obj().ok_or_else(|| anyhow!("control message must be a JSON object"))?;
        let kind = obj
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("control message needs a string \"kind\""))?;
        match kind {
            "hello" => {
                check_keys(obj, kind, &["proto", "wire", "name", "run_id", "t0"])?;
                Ok(Control::Hello {
                    proto: u8_field(obj, kind, "proto")?,
                    wire: u8_field(obj, kind, "wire")?,
                    name: str_field(obj, kind, "name")?,
                    run_id: str_field(obj, kind, "run_id")?,
                    t0: f64_from_hex(obj, kind, "t0")?,
                })
            }
            "welcome" => {
                check_keys(
                    obj,
                    kind,
                    &[
                        "proto",
                        "wire",
                        "run_id",
                        "process",
                        "processes",
                        "client_ids",
                        "spec",
                        "trace_id",
                        "span_base",
                        "t0",
                        "t1",
                        "t2",
                    ],
                )?;
                let client_ids = obj
                    .get("client_ids")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("control \"welcome\" needs array \"client_ids\""))?
                    .iter()
                    .map(|j| {
                        j.as_usize()
                            .ok_or_else(|| anyhow!("\"client_ids\" entries must be integers"))
                    })
                    .collect::<Result<Vec<usize>>>()?;
                let spec = RunSpec::from_json(
                    obj.get("spec").ok_or_else(|| anyhow!("control \"welcome\" needs \"spec\""))?,
                )?;
                let trace_hex = str_field(obj, kind, "trace_id")?;
                let trace_id = u128::from_str_radix(&trace_hex, 16).map_err(|_| {
                    anyhow!("control \"welcome\" key \"trace_id\" is not a 128-bit hex pattern")
                })?;
                Ok(Control::Welcome {
                    proto: u8_field(obj, kind, "proto")?,
                    wire: u8_field(obj, kind, "wire")?,
                    run_id: str_field(obj, kind, "run_id")?,
                    process: obj
                        .get("process")
                        .and_then(Json::as_usize)
                        .ok_or_else(|| anyhow!("control \"welcome\" needs integer \"process\""))?,
                    processes: obj.get("processes").and_then(Json::as_usize).ok_or_else(|| {
                        anyhow!("control \"welcome\" needs integer \"processes\"")
                    })?,
                    client_ids,
                    spec,
                    trace_id,
                    span_base: u64_field(obj, kind, "span_base")?,
                    t0: f64_from_hex(obj, kind, "t0")?,
                    t1: f64_from_hex(obj, kind, "t1")?,
                    t2: f64_from_hex(obj, kind, "t2")?,
                })
            }
            "round_ctx" => {
                check_keys(obj, kind, &["round", "parent"])?;
                Ok(Control::RoundCtx {
                    round: u32_field(obj, kind, "round")?,
                    parent: u64_field(obj, kind, "parent")?,
                })
            }
            "clock" => {
                check_keys(obj, kind, &["t0"])?;
                Ok(Control::ClockProbe { t0: f64_from_hex(obj, kind, "t0")? })
            }
            "clock_reply" => {
                check_keys(obj, kind, &["t0", "t1", "t2"])?;
                Ok(Control::ClockReply {
                    t0: f64_from_hex(obj, kind, "t0")?,
                    t1: f64_from_hex(obj, kind, "t1")?,
                    t2: f64_from_hex(obj, kind, "t2")?,
                })
            }
            "reject" => {
                check_keys(obj, kind, &["reason"])?;
                Ok(Control::Reject { reason: str_field(obj, kind, "reason")? })
            }
            "observe" => {
                check_keys(obj, kind, &["proto"])?;
                Ok(Control::Observe { proto: u8_field(obj, kind, "proto")? })
            }
            "status" => {
                check_keys(obj, kind, &["proto"])?;
                Ok(Control::Status { proto: u8_field(obj, kind, "proto")? })
            }
            "status_reply" => {
                check_keys(obj, kind, &["body"])?;
                let body = obj
                    .get("body")
                    .filter(|b| b.as_obj().is_some())
                    .cloned()
                    .ok_or_else(|| anyhow!("control \"status_reply\" needs object \"body\""))?;
                Ok(Control::StatusReply { body })
            }
            "round_report" => {
                check_keys(obj, kind, &["round", "client", "local_losses", "split_losses"])?;
                Ok(Control::RoundReport {
                    round: u32_field(obj, kind, "round")?,
                    client: u32_field(obj, kind, "client")?,
                    local_losses: losses_from(v, "local_losses")?,
                    split_losses: losses_from(v, "split_losses")?,
                })
            }
            "shutdown" => {
                check_keys(obj, kind, &["reason"])?;
                Ok(Control::Shutdown { reason: str_field(obj, kind, "reason")? })
            }
            other => bail!(
                "unknown control kind {other:?} (known: hello welcome round_ctx clock \
                 clock_reply reject observe status status_reply round_report shutdown)"
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::federation::Method;

    fn roundtrip(c: &Control) -> Control {
        Control::from_json(&Json::parse(&c.to_json().to_string()).unwrap()).unwrap()
    }

    #[test]
    fn losses_roundtrip_bit_exactly_including_nan() {
        let c = Control::RoundReport {
            round: 4,
            client: 9,
            local_losses: vec![1.5, f64::NAN, f64::INFINITY, -0.0, 3.141592653589793],
            split_losses: vec![f64::MIN_POSITIVE, -f64::NAN],
        };
        match roundtrip(&c) {
            Control::RoundReport { round, client, local_losses, split_losses } => {
                assert_eq!((round, client), (4, 9));
                let (orig_l, orig_s) = match &c {
                    Control::RoundReport { local_losses, split_losses, .. } => {
                        (local_losses, split_losses)
                    }
                    _ => unreachable!(),
                };
                let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<u64>>();
                assert_eq!(bits(&local_losses), bits(orig_l));
                assert_eq!(bits(&split_losses), bits(orig_s));
            }
            other => panic!("wrong kind: {other:?}"),
        }
    }

    #[test]
    fn welcome_carries_a_full_spec() {
        let spec = RunSpec::new("tiny", "cifar10", Method::SfPrompt);
        let c = Control::Welcome {
            proto: 1,
            wire: 2,
            run_id: "run-17".into(),
            process: 1,
            processes: 2,
            client_ids: vec![1, 3, 5],
            spec: spec.clone(),
            trace_id: 0xdead_beef_dead_beef_dead_beef_dead_beef,
            span_base: 2 << 40,
            t0: 0.5,
            t1: 1.25,
            t2: 1.5,
        };
        match roundtrip(&c) {
            Control::Welcome {
                client_ids,
                spec: got,
                process,
                processes,
                trace_id,
                span_base,
                t0,
                t1,
                t2,
                ..
            } => {
                assert_eq!(client_ids, vec![1, 3, 5]);
                assert_eq!((process, processes), (1, 2));
                assert_eq!(got.to_json(), spec.to_json());
                assert_eq!(trace_id, 0xdead_beef_dead_beef_dead_beef_dead_beef);
                assert_eq!(span_base, 2 << 40);
                assert_eq!((t0, t1, t2), (0.5, 1.25, 1.5));
            }
            other => panic!("wrong kind: {other:?}"),
        }
    }

    #[test]
    fn clock_and_round_ctx_roundtrip_bit_exactly() {
        // NTP legs must survive bit-exactly — including values a JSON
        // number would mangle.
        let t0 = f64::from_bits(0x3ff0_0000_0000_0001);
        match roundtrip(&Control::ClockProbe { t0 }) {
            Control::ClockProbe { t0: got } => assert_eq!(got.to_bits(), t0.to_bits()),
            other => panic!("wrong kind: {other:?}"),
        }
        match roundtrip(&Control::ClockReply { t0, t1: 2.0, t2: f64::NAN }) {
            Control::ClockReply { t0: a, t1: b, t2: c } => {
                assert_eq!(a.to_bits(), t0.to_bits());
                assert_eq!(b, 2.0);
                assert!(c.is_nan());
            }
            other => panic!("wrong kind: {other:?}"),
        }
        match roundtrip(&Control::RoundCtx { round: 7, parent: (3 << 40) + 9 }) {
            Control::RoundCtx { round, parent } => {
                assert_eq!((round, parent), (7, (3 << 40) + 9));
            }
            other => panic!("wrong kind: {other:?}"),
        }
        // Strict keys apply to the new kinds too.
        let err = Control::from_json(
            &Json::parse(r#"{"kind":"clock","t0":"0000000000000000","drift":1}"#).unwrap(),
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("drift"), "{err}");
    }

    #[test]
    fn status_and_reply_roundtrip_with_strict_keys() {
        match roundtrip(&Control::Status { proto: 1 }) {
            Control::Status { proto } => assert_eq!(proto, 1),
            other => panic!("wrong kind: {other:?}"),
        }
        let body = Json::parse(r#"{"round": 3, "state": "running"}"#).unwrap();
        match roundtrip(&Control::StatusReply { body: body.clone() }) {
            Control::StatusReply { body: got } => assert_eq!(got, body),
            other => panic!("wrong kind: {other:?}"),
        }
        // Unknown keys on a status request are rejected, like every kind.
        let err = Control::from_json(&Json::parse(r#"{"kind":"status","proto":1,"verbose":true}"#).unwrap())
            .unwrap_err()
            .to_string();
        assert!(err.contains("verbose"), "{err}");
        // A reply body must be an object.
        assert!(Control::from_json(&Json::parse(r#"{"kind":"status_reply","body":7}"#).unwrap())
            .is_err());
    }

    #[test]
    fn unknown_keys_and_kinds_are_rejected() {
        let good =
            Control::Hello { proto: 1, wire: 2, name: "x".into(), run_id: String::new(), t0: 0.0 };
        let mut o = match good.to_json() {
            Json::Obj(o) => o,
            _ => unreachable!(),
        };
        o.insert("client_name".to_string(), Json::Str("typo".into()));
        let err = Control::from_json(&Json::Obj(o)).unwrap_err().to_string();
        assert!(err.contains("client_name"), "{err}");

        let err = Control::from_json(&Json::parse(r#"{"kind": "bye"}"#).unwrap())
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown control kind"), "{err}");

        assert!(Control::from_json(&Json::parse("[]").unwrap()).is_err());
        assert!(Control::from_json(&Json::parse(r#"{"proto": 1}"#).unwrap()).is_err());
        // Bad hex in a loss vector fails loudly.
        let bad = r#"{"kind":"round_report","round":0,"client":0,
                      "local_losses":["zzzz"],"split_losses":[]}"#;
        assert!(Control::from_json(&Json::parse(bad).unwrap()).is_err());
    }
}
