//! Socket framing: how codec frames and control messages share one TCP
//! byte stream.
//!
//! Every message on the wire is `[u32 len_le][body …]` where `len` counts
//! the body bytes and the body's first two bytes are a magic tag:
//!
//! * `"SF"` — a **codec-v2 federated frame**, byte-for-byte the output of
//!   [`crate::transport::encode_frame`] (whose own leading `u32 frame_len`
//!   *is* this length prefix — zero added framing overhead, so the socket
//!   byte count of a data frame equals its in-process encoded length and
//!   `ByteMeter` totals are identical across media).
//! * `"NC"` — a **net control message**: one version byte
//!   ([`NET_PROTO_VERSION`]) then a strict JSON body (handshake, round
//!   reports, shutdown — see [`super::control`]).
//!
//! Reads are robust against the realities of a stream socket: partial
//! reads are reassembled, a length prefix beyond [`MAX_MSG_LEN`] is
//! rejected *before* any allocation, EOF mid-message surfaces
//! [`NetError::Truncated`] (never a panic), and a read stalled past the
//! socket's `SO_RCVTIMEO` surfaces [`NetError::TimedOut`]. All of these
//! arrive as typed [`NetError`]s inside `anyhow::Error`, so callers can
//! `downcast_ref::<NetError>()` to branch on the failure mode.

use std::io::Read;

use anyhow::{bail, Result};

use crate::transport::{decode_frame, Frame};
use crate::util::json::Json;

use super::control::Control;

/// Version of the *net* layer protocol (envelope + control-message
/// schema). Independent of the codec's `WIRE_VERSION`, which every data
/// frame still carries and which the handshake pins separately.
///
/// v2: distributed tracing — `hello` carries an NTP `t0`, `welcome`
/// carries the trace identity + timestamp legs, and the `round_ctx` /
/// `clock` / `clock_reply` kinds exist (docs/TRACING.md).
pub const NET_PROTO_VERSION: u8 = 2;

/// Magic tag opening every control-message body.
pub(crate) const CONTROL_MAGIC: [u8; 2] = *b"NC";

/// Largest message body this endpoint will buffer. Matches the codec's
/// decode-side sanity cap (`MAX_ELEMENTS` = 1 GiB of f32 per tensor) plus
/// header slack: anything larger is a corrupted or hostile length prefix,
/// refused before a single byte of it is allocated.
pub const MAX_MSG_LEN: usize = (1 << 30) + (1 << 16);

/// Read-side chunk size: bodies are buffered incrementally in chunks of
/// this, so even an accepted length prefix only ever allocates as fast as
/// bytes actually arrive.
const READ_CHUNK: usize = 64 * 1024;

/// Typed failure modes of the socket edge. Wrapped in `anyhow::Error`;
/// callers branch with `err.downcast_ref::<NetError>()`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum NetError {
    /// Clean EOF on a message boundary (peer closed the connection).
    Closed,
    /// EOF in the middle of a message: `got` of `want` body bytes arrived.
    Truncated { got: usize, want: usize },
    /// Length prefix beyond [`MAX_MSG_LEN`]; rejected without allocating.
    Oversized { len: u64, cap: usize },
    /// A read or write stalled past the connection's configured timeout.
    TimedOut,
    /// Envelope net-protocol version mismatch on a control message.
    Version { got: u8, want: u8 },
}

impl std::fmt::Display for NetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            NetError::Closed => write!(f, "connection closed by peer"),
            NetError::Truncated { got, want } => {
                write!(f, "connection closed mid-message ({got} of {want} body bytes)")
            }
            NetError::Oversized { len, cap } => {
                write!(f, "message length prefix {len} exceeds the {cap}-byte cap")
            }
            NetError::TimedOut => write!(f, "socket read/write timed out"),
            NetError::Version { got, want } => {
                write!(f, "net-protocol version mismatch: peer speaks v{got}, this end v{want}")
            }
        }
    }
}

impl std::error::Error for NetError {}

/// One parsed inbound message, with its total on-the-wire byte count
/// (length prefix included).
#[derive(Debug)]
pub enum NetMsg {
    /// A federated data frame (already CRC-checked and decoded).
    Frame(Frame, usize),
    /// A control message (handshake / report / shutdown).
    Control(Control, usize),
}

/// How a `fill` attempt can resolve when `idle_ok` permits returning
/// without data.
enum Fill {
    Done,
    /// Timeout fired before the first byte — the peer is merely quiet.
    Idle,
}

/// Read exactly `buf.len()` bytes. `idle_ok` + `started` control how
/// timeouts and EOF map onto [`NetError`]: before the first byte of a
/// message (`!started`), a timeout can be reported as `Idle` and EOF is a
/// clean [`NetError::Closed`]; once any byte of the message has been
/// consumed, both become hard errors (`TimedOut` / `Truncated`).
fn fill(
    r: &mut impl Read,
    buf: &mut [u8],
    idle_ok: bool,
    started: bool,
    msg_want: usize,
    msg_got: usize,
) -> Result<Fill> {
    let mut filled = 0;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                if !started && filled == 0 {
                    bail!(NetError::Closed);
                }
                bail!(NetError::Truncated { got: msg_got + filled, want: msg_want });
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if !started && filled == 0 && idle_ok {
                    return Ok(Fill::Idle);
                }
                bail!(NetError::TimedOut);
            }
            Err(e) => return Err(e.into()),
        }
    }
    Ok(Fill::Done)
}

/// Read one length-prefixed message and dispatch on its magic. Returns
/// `None` only when `idle_ok` is set and the socket timed out before the
/// first byte of a message (the peer is alive but quiet — callers poll a
/// stop flag and retry). All other shortfalls are typed [`NetError`]s.
pub fn read_message<R: Read>(r: &mut R, idle_ok: bool) -> Result<Option<NetMsg>> {
    let mut prefix = [0u8; 4];
    match fill(r, &mut prefix, idle_ok, false, 4, 0)? {
        Fill::Idle => return Ok(None),
        Fill::Done => {}
    }
    let len = u32::from_le_bytes(prefix) as usize;
    if len > MAX_MSG_LEN {
        bail!(NetError::Oversized { len: len as u64, cap: MAX_MSG_LEN });
    }
    if len < 3 {
        bail!("runt message ({len} body bytes; minimum is magic + one byte)");
    }
    // Body arrives in bounded chunks: allocation tracks received bytes,
    // never the claimed length.
    let mut body = Vec::with_capacity(len.min(READ_CHUNK));
    let mut chunk = [0u8; READ_CHUNK];
    while body.len() < len {
        let take = (len - body.len()).min(READ_CHUNK);
        fill(r, &mut chunk[..take], false, true, len, body.len())?;
        body.extend_from_slice(&chunk[..take]);
    }
    let total = 4 + len;
    match [body[0], body[1]] {
        m if m == *b"SF" => {
            // A codec frame's encoded form starts with its own length
            // prefix; reassemble the exact encode_frame output and let the
            // codec do all validation (version, CRC, payload caps).
            let mut full = Vec::with_capacity(total);
            full.extend_from_slice(&prefix);
            full.extend_from_slice(&body);
            let frame = decode_frame(&full)?;
            Ok(Some(NetMsg::Frame(frame, total)))
        }
        m if m == CONTROL_MAGIC => {
            if body[2] != NET_PROTO_VERSION {
                bail!(NetError::Version { got: body[2], want: NET_PROTO_VERSION });
            }
            let text = std::str::from_utf8(&body[3..])
                .map_err(|_| anyhow::anyhow!("control message body is not UTF-8"))?;
            let v = Json::parse(text).map_err(|e| anyhow::anyhow!("control message: {e}"))?;
            Ok(Some(NetMsg::Control(Control::from_json(&v)?, total)))
        }
        m => bail!(
            "unrecognized message magic {:?} (expected \"SF\" data frame or \"NC\" control)",
            String::from_utf8_lossy(&m)
        ),
    }
}

/// Serialize a control message into its on-the-wire form:
/// `[u32 len]["NC"][NET_PROTO_VERSION][strict JSON]`.
pub fn control_bytes(c: &Control) -> Vec<u8> {
    let json = c.to_json().to_string();
    let body_len = 3 + json.len();
    let mut out = Vec::with_capacity(4 + body_len);
    out.extend_from_slice(&(body_len as u32).to_le_bytes());
    out.extend_from_slice(&CONTROL_MAGIC);
    out.push(NET_PROTO_VERSION);
    out.extend_from_slice(json.as_bytes());
    out
}

/// Map write-side io errors onto the same typed vocabulary as reads.
pub(crate) fn write_error(e: std::io::Error) -> anyhow::Error {
    match e.kind() {
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => {
            anyhow::Error::new(NetError::TimedOut)
        }
        std::io::ErrorKind::BrokenPipe | std::io::ErrorKind::ConnectionReset => {
            anyhow::Error::new(NetError::Closed)
        }
        _ => e.into(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::MsgKind;
    use crate::runtime::HostTensor;
    use crate::transport::{encode_frame, Payload, WireFormat};

    /// A reader that yields the stream in caller-chosen chunk sizes, to
    /// model TCP segmentation without a socket.
    pub(crate) struct ChunkedReader {
        data: Vec<u8>,
        pos: usize,
        chunks: Vec<usize>,
        next: usize,
    }

    impl ChunkedReader {
        pub(crate) fn new(data: Vec<u8>, chunks: Vec<usize>) -> ChunkedReader {
            ChunkedReader { data, pos: 0, chunks, next: 0 }
        }
    }

    impl Read for ChunkedReader {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            if self.pos >= self.data.len() {
                return Ok(0);
            }
            let want = *self.chunks.get(self.next).unwrap_or(&usize::MAX);
            self.next += 1;
            let n = want.min(buf.len()).min(self.data.len() - self.pos).max(1);
            buf[..n].copy_from_slice(&self.data[self.pos..self.pos + n]);
            self.pos += n;
            Ok(n)
        }
    }

    fn sample_frame() -> Frame {
        Frame::new(
            MsgKind::SmashedData,
            3,
            7,
            Payload::Tensor(HostTensor::f32(vec![4], vec![1.0, -2.0, 3.5, 0.25])),
        )
    }

    #[test]
    fn frame_reassembles_from_single_byte_chunks() {
        let bytes = encode_frame(&sample_frame(), WireFormat::F32).unwrap();
        let n = bytes.len();
        let mut r = ChunkedReader::new(bytes, vec![1; n]);
        match read_message(&mut r, false).unwrap().unwrap() {
            NetMsg::Frame(f, got_n) => {
                assert_eq!(f, sample_frame());
                assert_eq!(got_n, n, "wire count must equal the encoded frame length");
            }
            other => panic!("expected frame, got {other:?}"),
        }
    }

    #[test]
    fn oversized_prefix_is_rejected_before_allocation() {
        let mut data = u32::MAX.to_le_bytes().to_vec();
        data.extend_from_slice(b"SF");
        let mut r = ChunkedReader::new(data, vec![]);
        let err = read_message(&mut r, false).unwrap_err();
        match err.downcast_ref::<NetError>() {
            Some(NetError::Oversized { len, cap }) => {
                assert_eq!(*len, u32::MAX as u64);
                assert_eq!(*cap, MAX_MSG_LEN);
            }
            other => panic!("expected Oversized, got {other:?} ({err})"),
        }
    }

    #[test]
    fn midstream_eof_is_truncated_not_panic() {
        let mut bytes = encode_frame(&sample_frame(), WireFormat::F32).unwrap();
        bytes.truncate(bytes.len() / 2);
        let mut r = ChunkedReader::new(bytes, vec![]);
        let err = read_message(&mut r, false).unwrap_err();
        assert!(
            matches!(err.downcast_ref::<NetError>(), Some(NetError::Truncated { .. })),
            "{err}"
        );
    }

    #[test]
    fn clean_eof_is_closed() {
        let mut r = ChunkedReader::new(Vec::new(), vec![]);
        let err = read_message(&mut r, false).unwrap_err();
        assert_eq!(err.downcast_ref::<NetError>(), Some(&NetError::Closed));
    }

    #[test]
    fn control_version_mismatch_is_typed() {
        let c = Control::Shutdown { reason: "done".into() };
        let mut bytes = control_bytes(&c);
        bytes[6] = 42; // envelope version byte (after 4-byte len + "NC")
        let mut r = ChunkedReader::new(bytes, vec![]);
        let err = read_message(&mut r, false).unwrap_err();
        assert_eq!(
            err.downcast_ref::<NetError>(),
            Some(&NetError::Version { got: 42, want: NET_PROTO_VERSION })
        );
    }

    #[test]
    fn garbage_magic_is_refused() {
        let mut data = 8u32.to_le_bytes().to_vec();
        data.extend_from_slice(b"XXjunk12");
        let mut r = ChunkedReader::new(data, vec![]);
        let err = read_message(&mut r, false).unwrap_err().to_string();
        assert!(err.contains("magic"), "{err}");
    }

    #[test]
    fn control_roundtrips_through_the_envelope() {
        let c = Control::Hello {
            proto: NET_PROTO_VERSION,
            wire: crate::transport::WIRE_VERSION,
            name: "dev-board-4".into(),
            run_id: "run-17".into(),
            t0: 0.25,
        };
        let bytes = control_bytes(&c);
        let n = bytes.len();
        let mut r = ChunkedReader::new(bytes, vec![3; n]);
        match read_message(&mut r, false).unwrap().unwrap() {
            NetMsg::Control(got, got_n) => {
                assert_eq!(got.to_json(), c.to_json());
                assert_eq!(got_n, n);
            }
            other => panic!("expected control, got {other:?}"),
        }
    }
}
