//! FedAvg aggregation (paper Phase 3, Eq. 3 / Algorithm 2).
//!
//! Sample-count weighted average of client updates:
//! `(W_{t,r+1}, p_{r+1}) = Σ_k (n_k / N) (W_{t,k,r}, p_{k,r})`.

use anyhow::{bail, Result};

use super::params::SegmentParams;

/// One client's contribution to aggregation.
pub struct Contribution<'a> {
    pub params: &'a SegmentParams,
    pub num_samples: usize,
}

/// Weighted FedAvg over client segment params.
///
/// Invariants (property-tested): weights sum to 1; aggregation of identical
/// inputs is the identity; aggregation is permutation-invariant; a client
/// with zero samples contributes nothing.
pub fn fedavg(contributions: &[Contribution]) -> Result<SegmentParams> {
    if contributions.is_empty() {
        bail!("fedavg over zero contributions");
    }
    let total: usize = contributions.iter().map(|c| c.num_samples).sum();
    if total == 0 {
        bail!("fedavg with zero total samples");
    }
    let mut acc = contributions[0].params.zeros_like();
    for c in contributions {
        let w = c.num_samples as f32 / total as f32;
        acc.axpy(w, c.params)?;
    }
    Ok(acc)
}

/// Aggregate several segments at once (tail + prompt in SFPrompt).
pub fn fedavg_multi(
    per_client: &[(Vec<&SegmentParams>, usize)],
) -> Result<Vec<SegmentParams>> {
    if per_client.is_empty() {
        bail!("fedavg over zero clients");
    }
    let num_segments = per_client[0].0.len();
    let mut out = Vec::with_capacity(num_segments);
    for s in 0..num_segments {
        let contribs: Vec<Contribution> = per_client
            .iter()
            .map(|(segs, n)| Contribution { params: segs[s], num_samples: *n })
            .collect();
        out.push(fedavg(&contribs)?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use crate::runtime::tensor::HostTensor;

    use super::*;

    fn seg(vals: &[f32]) -> SegmentParams {
        SegmentParams {
            segment: "t".into(),
            tensors: vec![HostTensor::f32(vec![vals.len()], vals.to_vec())],
        }
    }

    #[test]
    fn weighted_average() {
        let a = seg(&[0.0, 0.0]);
        let b = seg(&[4.0, 8.0]);
        let out = fedavg(&[
            Contribution { params: &a, num_samples: 3 },
            Contribution { params: &b, num_samples: 1 },
        ])
        .unwrap();
        assert_eq!(out.tensors[0].as_f32(), &[1.0, 2.0]);
    }

    #[test]
    fn identity_on_identical_inputs() {
        let a = seg(&[1.5, -2.5, 3.0]);
        let out = fedavg(&[
            Contribution { params: &a, num_samples: 10 },
            Contribution { params: &a, num_samples: 90 },
        ])
        .unwrap();
        assert!(out.max_abs_diff(&a) < 1e-6);
    }

    #[test]
    fn zero_sample_client_ignored() {
        let a = seg(&[2.0]);
        let b = seg(&[100.0]);
        let out = fedavg(&[
            Contribution { params: &a, num_samples: 5 },
            Contribution { params: &b, num_samples: 0 },
        ])
        .unwrap();
        assert!((out.tensors[0].as_f32()[0] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn empty_or_all_zero_errors() {
        assert!(fedavg(&[]).is_err());
        let a = seg(&[1.0]);
        assert!(fedavg(&[Contribution { params: &a, num_samples: 0 }]).is_err());
    }

    #[test]
    fn multi_aggregates_each_segment() {
        let t1 = seg(&[0.0]);
        let p1 = seg(&[2.0]);
        let t2 = seg(&[2.0]);
        let p2 = seg(&[4.0]);
        let out = fedavg_multi(&[(vec![&t1, &p1], 1), (vec![&t2, &p2], 1)]).unwrap();
        assert_eq!(out[0].tensors[0].as_f32(), &[1.0]);
        assert_eq!(out[1].tensors[0].as_f32(), &[3.0]);
    }
}
