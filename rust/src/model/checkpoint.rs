//! Checkpointing: save/restore a `ParamSet` (resume federated runs, ship
//! fine-tuned tails/prompts to clients out of band).
//!
//! Format: a JSON header line (segment -> [tensor shapes]) followed by the
//! raw little-endian f32 payload, tensors in manifest order. Self-contained
//! (no serde); integrity-checked with a FNV-1a digest trailer.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::runtime::tensor::HostTensor;
use crate::util::json::Json;

use super::params::{ParamSet, SegmentParams};

const MAGIC: &str = "SFPROMPT-CKPT-v1";

fn fnv1a(bytes: &[u8], mut state: u64) -> u64 {
    for &b in bytes {
        state ^= b as u64;
        state = state.wrapping_mul(0x100_0000_01b3);
    }
    state
}

/// Save every segment of `params` to `path`.
pub fn save(params: &ParamSet, path: &Path) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut header = BTreeMap::new();
    for (seg, sp) in &params.segments {
        let shapes: Vec<Json> = sp
            .tensors
            .iter()
            .map(|t| Json::Arr(t.shape.iter().map(|&d| Json::Num(d as f64)).collect()))
            .collect();
        header.insert(seg.clone(), Json::Arr(shapes));
    }
    let header = Json::Obj(header).to_string();

    let mut f = std::fs::File::create(path).context("create checkpoint")?;
    writeln!(f, "{MAGIC}")?;
    writeln!(f, "{header}")?;
    let mut digest = 0xcbf2_9ce4_8422_2325u64;
    for sp in params.segments.values() {
        for t in &sp.tensors {
            let mut buf = Vec::with_capacity(t.element_count() * 4);
            for v in t.as_f32() {
                buf.extend_from_slice(&v.to_le_bytes());
            }
            digest = fnv1a(&buf, digest);
            f.write_all(&buf)?;
        }
    }
    f.write_all(&digest.to_le_bytes())?;
    Ok(())
}

/// Load a checkpoint. Shapes come from the header; the caller may validate
/// against a manifest with `ParamSet::validate`.
pub fn load(path: &Path) -> Result<ParamSet> {
    let mut data = Vec::new();
    std::fs::File::open(path)
        .with_context(|| format!("open checkpoint {}", path.display()))?
        .read_to_end(&mut data)?;

    let nl1 = data.iter().position(|&b| b == b'\n').ok_or_else(|| anyhow!("truncated"))?;
    if &data[..nl1] != MAGIC.as_bytes() {
        bail!("not a {MAGIC} file");
    }
    let nl2 = nl1 + 1
        + data[nl1 + 1..]
            .iter()
            .position(|&b| b == b'\n')
            .ok_or_else(|| anyhow!("truncated header"))?;
    let header = Json::parse(std::str::from_utf8(&data[nl1 + 1..nl2])?)
        .map_err(|e| anyhow!("header: {e}"))?;

    let mut offset = nl2 + 1;
    let mut segments = BTreeMap::new();
    let mut digest = 0xcbf2_9ce4_8422_2325u64;
    for (seg, shapes) in header.as_obj().ok_or_else(|| anyhow!("header not an object"))? {
        let mut tensors = Vec::new();
        for shape_j in shapes.as_arr().ok_or_else(|| anyhow!("bad shapes"))? {
            let shape: Vec<usize> = shape_j
                .as_arr()
                .ok_or_else(|| anyhow!("bad shape"))?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                .collect::<Result<_>>()?;
            let n: usize = shape.iter().product();
            let end = offset + 4 * n;
            if end > data.len() {
                bail!("checkpoint truncated in segment {seg}");
            }
            digest = fnv1a(&data[offset..end], digest);
            let vals: Vec<f32> = data[offset..end]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            tensors.push(HostTensor::f32(shape, vals));
            offset = end;
        }
        segments.insert(seg.clone(), SegmentParams { segment: seg.clone(), tensors });
    }
    if offset + 8 != data.len() {
        bail!("trailing bytes in checkpoint");
    }
    let stored = u64::from_le_bytes(data[offset..offset + 8].try_into().unwrap());
    if stored != digest {
        bail!("checkpoint digest mismatch (corrupted file)");
    }
    Ok(ParamSet { segments })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ParamSet {
        let mut segments = BTreeMap::new();
        for (name, n) in [("tail", 6usize), ("prompt", 4)] {
            segments.insert(
                name.to_string(),
                SegmentParams {
                    segment: name.to_string(),
                    tensors: vec![
                        HostTensor::f32(vec![n], (0..n).map(|i| i as f32 * 0.5).collect()),
                        HostTensor::f32(vec![2, 2], vec![1.0, -2.0, 3.5, 0.25]),
                    ],
                },
            );
        }
        ParamSet { segments }
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("sfp_ckpt_{}_{name}", std::process::id()))
    }

    #[test]
    fn roundtrip_exact() {
        let p = sample();
        let path = tmp("rt.ckpt");
        save(&p, &path).unwrap();
        let q = load(&path).unwrap();
        assert_eq!(p.segments.keys().collect::<Vec<_>>(), q.segments.keys().collect::<Vec<_>>());
        for (seg, sp) in &p.segments {
            assert_eq!(sp.max_abs_diff(&q.segments[seg]), 0.0);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_corruption() {
        let p = sample();
        let path = tmp("bad.ckpt");
        save(&p, &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_wrong_magic() {
        let path = tmp("magic.ckpt");
        std::fs::write(&path, b"NOPE\n{}\n").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_truncation() {
        let p = sample();
        let path = tmp("trunc.ckpt");
        save(&p, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 12]).unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
