//! Parameter initialisation from manifest init specs.
//!
//! The "pre-trained" backbone is simulated (DESIGN.md §Substitutions): the
//! frozen head/body are drawn once from the manifest's init distribution
//! with a fixed seed, standing in for downloaded pre-trained weights. What
//! the *system* exercises — which tensors are frozen, their sizes, the
//! message shapes — is identical to real ViT checkpoints.

use std::collections::BTreeMap;

use crate::runtime::manifest::{InitSpec, Manifest, TensorDef};
use crate::runtime::tensor::HostTensor;
use crate::util::rng::Rng;

use super::params::{ParamSet, SegmentParams};

pub fn init_tensor(def: &TensorDef, rng: &mut Rng) -> HostTensor {
    let n: usize = def.shape.iter().product();
    let data = match def.init {
        InitSpec::Zeros => vec![0.0; n],
        InitSpec::Ones => vec![1.0; n],
        InitSpec::Normal(sigma) => (0..n).map(|_| rng.normal_f32(0.0, sigma)).collect(),
    };
    HostTensor::f32(def.shape.clone(), data)
}

pub fn init_segment(manifest: &Manifest, segment: &str, rng: &mut Rng) -> SegmentParams {
    let defs = manifest.segment(segment).expect("segment exists");
    SegmentParams {
        segment: segment.to_string(),
        tensors: defs.iter().map(|d| init_tensor(d, rng)).collect(),
    }
}

/// Initialise the full model deterministically from `seed`.
pub fn init_params(manifest: &Manifest, seed: u64) -> ParamSet {
    let mut root = Rng::new(seed);
    let mut segments = BTreeMap::new();
    for (i, seg) in manifest.segments.keys().enumerate() {
        let mut rng = root.fork(i as u64 + 1);
        segments.insert(seg.clone(), init_segment(manifest, seg, &mut rng));
    }
    ParamSet { segments }
}
