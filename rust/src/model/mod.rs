//! Model state: parameter containers, deterministic init, FedAvg.

pub mod aggregate;
pub mod checkpoint;
pub mod init;
pub mod params;

pub use aggregate::{fedavg, fedavg_multi, Contribution};
pub use init::{init_params, init_segment};
pub use params::{ParamSet, SegmentParams};
