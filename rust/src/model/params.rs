//! Parameter containers: per-segment tensor lists + the full model set.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::runtime::manifest::Manifest;
use crate::runtime::tensor::HostTensor;

/// All tensors of one segment (head / body / tail / prompt), manifest order.
#[derive(Debug, Clone, PartialEq)]
pub struct SegmentParams {
    pub segment: String,
    pub tensors: Vec<HostTensor>,
}

impl SegmentParams {
    pub fn num_params(&self) -> usize {
        self.tensors.iter().map(|t| t.element_count()).sum()
    }

    pub fn size_bytes(&self) -> usize {
        self.tensors.iter().map(|t| t.size_bytes()).sum()
    }

    /// Elementwise in-place AXPY: self += alpha * other (FedAvg building block).
    pub fn axpy(&mut self, alpha: f32, other: &SegmentParams) -> Result<()> {
        if self.tensors.len() != other.tensors.len() {
            return Err(anyhow!(
                "segment arity mismatch: {} vs {}",
                self.tensors.len(),
                other.tensors.len()
            ));
        }
        for (a, b) in self.tensors.iter_mut().zip(&other.tensors) {
            if a.shape != b.shape {
                return Err(anyhow!("tensor shape mismatch {:?} vs {:?}", a.shape, b.shape));
            }
            for (x, y) in a.as_f32_mut().iter_mut().zip(b.as_f32()) {
                *x += alpha * y;
            }
        }
        Ok(())
    }

    pub fn scale(&mut self, alpha: f32) {
        for t in &mut self.tensors {
            for x in t.as_f32_mut() {
                *x *= alpha;
            }
        }
    }

    pub fn zeros_like(&self) -> SegmentParams {
        SegmentParams {
            segment: self.segment.clone(),
            tensors: self.tensors.iter().map(|t| HostTensor::zeros(t.shape.clone())).collect(),
        }
    }

    /// Max |a - b| across all tensors (test/metric helper).
    pub fn max_abs_diff(&self, other: &SegmentParams) -> f32 {
        self.tensors
            .iter()
            .zip(&other.tensors)
            .flat_map(|(a, b)| a.as_f32().iter().zip(b.as_f32()).map(|(x, y)| (x - y).abs()))
            .fold(0.0, f32::max)
    }
}

/// The global model: every segment, keyed by name.
#[derive(Debug, Clone)]
pub struct ParamSet {
    pub segments: BTreeMap<String, SegmentParams>,
}

impl ParamSet {
    pub fn get(&self, seg: &str) -> Result<&SegmentParams> {
        self.segments.get(seg).ok_or_else(|| anyhow!("missing segment {seg:?}"))
    }

    pub fn get_mut(&mut self, seg: &str) -> Result<&mut SegmentParams> {
        self.segments.get_mut(seg).ok_or_else(|| anyhow!("missing segment {seg:?}"))
    }

    pub fn set(&mut self, params: SegmentParams) {
        self.segments.insert(params.segment.clone(), params);
    }

    /// Verify tensor counts/shapes against the manifest (fail fast on drift).
    pub fn validate(&self, manifest: &Manifest) -> Result<()> {
        for (seg, defs) in &manifest.segments {
            let sp = self.get(seg)?;
            if sp.tensors.len() != defs.len() {
                return Err(anyhow!(
                    "segment {seg}: {} tensors, manifest wants {}",
                    sp.tensors.len(),
                    defs.len()
                ));
            }
            for (t, d) in sp.tensors.iter().zip(defs) {
                if t.shape != d.shape {
                    return Err(anyhow!(
                        "segment {seg} tensor {}: shape {:?} != {:?}",
                        d.name,
                        t.shape,
                        d.shape
                    ));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seg(vals: &[f32]) -> SegmentParams {
        SegmentParams {
            segment: "s".into(),
            tensors: vec![HostTensor::f32(vec![vals.len()], vals.to_vec())],
        }
    }

    #[test]
    fn axpy_and_scale() {
        let mut a = seg(&[1.0, 2.0]);
        let b = seg(&[10.0, 20.0]);
        a.axpy(0.5, &b).unwrap();
        assert_eq!(a.tensors[0].as_f32(), &[6.0, 12.0]);
        a.scale(2.0);
        assert_eq!(a.tensors[0].as_f32(), &[12.0, 24.0]);
    }

    #[test]
    fn axpy_shape_mismatch_errors() {
        let mut a = seg(&[1.0, 2.0]);
        let b = seg(&[1.0, 2.0, 3.0]);
        assert!(a.axpy(1.0, &b).is_err());
    }

    #[test]
    fn diff_metric() {
        let a = seg(&[1.0, 5.0]);
        let b = seg(&[2.0, 3.0]);
        assert_eq!(a.max_abs_diff(&b), 2.0);
    }
}
