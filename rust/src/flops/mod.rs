//! Analytic FLOPs model for split-ViT segments (Table 2).
//!
//! Mirrors python/compile/costmodel.py exactly — an integration test
//! asserts both implementations agree for every manifest. Convention:
//! 1 MAC = 2 FLOPs; forward only (backward counted as 2x forward where
//! needed, the standard approximation).

use crate::runtime::manifest::ModelConfig;

/// Forward FLOPs of one pre-LN transformer block at sequence length `seq`.
pub fn block_flops(dim: u64, seq: u64, mlp_ratio: u64) -> u64 {
    let (d, t, m) = (dim, seq, mlp_ratio * dim);
    let qkv = 2 * t * d * 3 * d;
    let attn_mm = 2 * 2 * t * t * d; // QK^T and PV
    let proj = 2 * t * d * d;
    let mlp = 2 * 2 * t * d * m;
    let ln = 2 * (8 * t * d);
    let softmax = 5 * t * t;
    qkv + attn_mm + proj + mlp + ln + softmax
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SegmentFlops {
    pub head: u64,
    pub body: u64,
    pub tail: u64,
}

impl SegmentFlops {
    pub fn client(&self) -> u64 {
        self.head + self.tail
    }

    pub fn total(&self) -> u64 {
        self.head + self.body + self.tail
    }
}

/// Per-sample forward FLOPs per segment.
pub fn segment_flops(cfg: &ModelConfig, with_prompt: bool) -> SegmentFlops {
    let t = if with_prompt { cfg.seq_len } else { cfg.seq_len_noprompt } as u64;
    let blk = block_flops(cfg.dim as u64, t, cfg.mlp_ratio as u64);
    let embed = 2 * cfg.num_patches as u64 * cfg.patch_dim as u64 * cfg.dim as u64;
    SegmentFlops {
        head: embed + cfg.depth_head as u64 * blk,
        body: cfg.depth_body as u64 * blk,
        tail: cfg.depth_tail as u64 * blk
            + 2 * cfg.dim as u64 * cfg.num_classes as u64
            + 8 * t * cfg.dim as u64,
    }
}

/// Per-sample FLOPs of one full train step (fwd + ~2x bwd) over a set of
/// segments — used for the per-client computational-burden column.
pub fn train_step_flops(fwd: u64) -> u64 {
    3 * fwd
}

/// Padded batches covering `n` samples (the kernels always execute full
/// batches; the tail batch is padded, so its cost is a whole batch).
pub fn padded_batches(n: usize, batch: usize) -> u64 {
    (n.div_ceil(batch.max(1))) as u64
}

/// Analytic FLOPs of **one call** of a backend stage (full padded batch),
/// the denominator behind telemetry's achieved-GFLOP/s metric. Same
/// conventions as the rest of this module: backward ≈ 2x forward, a train
/// step = 3x forward. `None` for unknown stage names.
///
/// Stage-level approximations (each maps a manifest stage onto segment
/// forwards): a `*_step` stage trains the segments it updates (3x their
/// forward); `body_backward*` is the backward half only (2x forward);
/// `prompt_grad` re-runs the head forward and backpropagates to the
/// prompt (≈ 2x head forward); `tail_step_linear` trains only the
/// classifier, so it is dominated by the tail forward; `el2n_scores` is a
/// head+tail forward pass.
pub fn stage_flops(cfg: &ModelConfig, stage: &str) -> Option<u64> {
    let b = cfg.batch as u64;
    let p = segment_flops(cfg, true);
    let np = segment_flops(cfg, false);
    Some(match stage {
        "head_forward" => p.head * b,
        "body_forward" => p.body * b,
        "tail_step" => train_step_flops(p.tail) * b,
        "body_backward" => 2 * p.body * b,
        "prompt_grad" => 2 * p.head * b,
        "local_step" => train_step_flops(p.client()) * b,
        "el2n_scores" => p.client() * b,
        "eval_forward" => p.total() * b,
        "head_forward_noprompt" => np.head * b,
        "body_forward_noprompt" => np.body * b,
        "tail_step_noprompt" => train_step_flops(np.tail) * b,
        "tail_step_linear" => np.tail * b,
        "body_backward_train" => train_step_flops(np.body) * b,
        "head_step" => 2 * np.head * b,
        "full_step" => train_step_flops(np.total()) * b,
        "eval_forward_noprompt" => np.total() * b,
        _ => return None,
    })
}

/// Per-client FLOPs of one SFPrompt round, for the fleet simulator's
/// compute charge. Documented approximation (fwd + ~2x bwd = 3x fwd, full
/// padded batches):
///
/// * Phase 1a (if `local_loss_update`): `local_epochs` train epochs over
///   the full local set through the W_h→W_t shortcut — head+tail steps;
/// * Phase 1b: one EL2N scoring pass — head+tail forward only;
/// * Phase 2: one split-training pass over the pruned set
///   (`phase2_batches` measured batches) — head fwd, tail step, prompt
///   backward, together ≈ one head+tail train step.
pub fn sfprompt_client_round_flops(
    cfg: &ModelConfig,
    n_local: usize,
    phase2_batches: usize,
    local_epochs: usize,
    local_loss_update: bool,
) -> u64 {
    let per_batch_fwd = segment_flops(cfg, true).client() * cfg.batch as u64;
    let local_batches = padded_batches(n_local, cfg.batch);
    let phase1a = if local_loss_update {
        local_epochs as u64 * local_batches * train_step_flops(per_batch_fwd)
    } else {
        0
    };
    let phase1b = local_batches * per_batch_fwd;
    let phase2 = phase2_batches as u64 * train_step_flops(per_batch_fwd);
    phase1a + phase1b + phase2
}

/// Per-client FLOPs of one FL (full fine-tune) round: the entire model
/// trains locally for every epoch.
pub fn fl_client_round_flops(cfg: &ModelConfig, n_local: usize, local_epochs: usize) -> u64 {
    let per_batch_fwd = segment_flops(cfg, false).total() * cfg.batch as u64;
    local_epochs as u64 * padded_batches(n_local, cfg.batch) * train_step_flops(per_batch_fwd)
}

/// Per-client FLOPs of one SFL round. `full_finetune` trains head + tail
/// on-device (SFL+FF); otherwise only the classifier tail trains
/// (SFL+Linear) and the head runs forward-only.
pub fn sfl_client_round_flops(
    cfg: &ModelConfig,
    n_local: usize,
    local_epochs: usize,
    full_finetune: bool,
) -> u64 {
    let f = segment_flops(cfg, false);
    let b = cfg.batch as u64;
    let per_batch = if full_finetune {
        train_step_flops((f.head + f.tail) * b)
    } else {
        f.head * b + train_step_flops(f.tail * b)
    };
    local_epochs as u64 * padded_batches(n_local, cfg.batch) * per_batch
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            image_size: 32,
            patch_size: 4,
            channels: 3,
            dim: 64,
            heads: 4,
            depth_head: 2,
            depth_body: 3,
            depth_tail: 1,
            mlp_ratio: 2,
            num_classes: 10,
            prompt_len: 8,
            batch: 16,
            num_patches: 64,
            seq_len: 73,
            seq_len_noprompt: 65,
            patch_dim: 48,
            analytic_only: false,
        }
    }

    #[test]
    fn flops_scale_with_depth() {
        let c = cfg();
        let f = segment_flops(&c, true);
        // body has 3 blocks, tail has 1 (+classifier): body ~ 3x tail block part.
        assert!(f.body > 2 * (f.tail - 2 * 64 * 10 - 8 * 73 * 64));
        assert!(f.total() > f.client());
    }

    #[test]
    fn prompt_increases_flops() {
        let c = cfg();
        assert!(segment_flops(&c, true).total() > segment_flops(&c, false).total());
    }

    #[test]
    fn client_round_flops_track_phases_and_methods() {
        let c = cfg();
        assert_eq!(padded_batches(0, 16), 0);
        assert_eq!(padded_batches(1, 16), 1);
        assert_eq!(padded_batches(17, 16), 2);

        // Pruning (fewer phase-2 batches) and skipping Phase 1a both cut cost.
        let full = sfprompt_client_round_flops(&c, 64, 4, 2, true);
        let pruned = sfprompt_client_round_flops(&c, 64, 2, 2, true);
        let no_local = sfprompt_client_round_flops(&c, 64, 4, 2, false);
        assert!(pruned < full);
        assert!(no_local < full);

        // FL trains the whole model: strictly more client compute than
        // SFPrompt's head+tail work at the same budget.
        assert!(fl_client_round_flops(&c, 64, 2) > full);
        // SFL+FF trains head+tail; SFL+Linear only the tail.
        assert!(
            sfl_client_round_flops(&c, 64, 2, true) > sfl_client_round_flops(&c, 64, 2, false)
        );
    }

    #[test]
    fn stage_flops_covers_every_manifest_stage() {
        let c = cfg();
        let stages = [
            "head_forward",
            "body_forward",
            "tail_step",
            "body_backward",
            "prompt_grad",
            "local_step",
            "el2n_scores",
            "eval_forward",
            "head_forward_noprompt",
            "body_forward_noprompt",
            "tail_step_noprompt",
            "tail_step_linear",
            "body_backward_train",
            "head_step",
            "full_step",
            "eval_forward_noprompt",
        ];
        for s in stages {
            let f = stage_flops(&c, s).unwrap_or_else(|| panic!("no flops for stage {s}"));
            assert!(f > 0, "stage {s} has zero flops");
        }
        assert_eq!(stage_flops(&c, "not_a_stage"), None);
        // Consistency with the segment model: prompted head forward costs
        // more than the promptless one; a train step is 3x its forward.
        assert!(
            stage_flops(&c, "head_forward").unwrap()
                > stage_flops(&c, "head_forward_noprompt").unwrap()
        );
        assert_eq!(
            stage_flops(&c, "tail_step_noprompt").unwrap(),
            3 * segment_flops(&c, false).tail * c.batch as u64
        );
    }

    #[test]
    fn block_flops_quadratic_in_seq_for_attention() {
        // Doubling seq should grow cost by >2x (attention term is quadratic).
        let f1 = block_flops(64, 50, 2);
        let f2 = block_flops(64, 100, 2);
        assert!(f2 > 2 * f1);
        assert!(f2 < 4 * f1);
    }
}
