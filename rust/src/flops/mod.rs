//! Analytic FLOPs model for split-ViT segments (Table 2).
//!
//! Mirrors python/compile/costmodel.py exactly — an integration test
//! asserts both implementations agree for every manifest. Convention:
//! 1 MAC = 2 FLOPs; forward only (backward counted as 2x forward where
//! needed, the standard approximation).

use crate::runtime::manifest::ModelConfig;

/// Forward FLOPs of one pre-LN transformer block at sequence length `seq`.
pub fn block_flops(dim: u64, seq: u64, mlp_ratio: u64) -> u64 {
    let (d, t, m) = (dim, seq, mlp_ratio * dim);
    let qkv = 2 * t * d * 3 * d;
    let attn_mm = 2 * 2 * t * t * d; // QK^T and PV
    let proj = 2 * t * d * d;
    let mlp = 2 * 2 * t * d * m;
    let ln = 2 * (8 * t * d);
    let softmax = 5 * t * t;
    qkv + attn_mm + proj + mlp + ln + softmax
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SegmentFlops {
    pub head: u64,
    pub body: u64,
    pub tail: u64,
}

impl SegmentFlops {
    pub fn client(&self) -> u64 {
        self.head + self.tail
    }

    pub fn total(&self) -> u64 {
        self.head + self.body + self.tail
    }
}

/// Per-sample forward FLOPs per segment.
pub fn segment_flops(cfg: &ModelConfig, with_prompt: bool) -> SegmentFlops {
    let t = if with_prompt { cfg.seq_len } else { cfg.seq_len_noprompt } as u64;
    let blk = block_flops(cfg.dim as u64, t, cfg.mlp_ratio as u64);
    let embed = 2 * cfg.num_patches as u64 * cfg.patch_dim as u64 * cfg.dim as u64;
    SegmentFlops {
        head: embed + cfg.depth_head as u64 * blk,
        body: cfg.depth_body as u64 * blk,
        tail: cfg.depth_tail as u64 * blk
            + 2 * cfg.dim as u64 * cfg.num_classes as u64
            + 8 * t * cfg.dim as u64,
    }
}

/// Per-sample FLOPs of one full train step (fwd + ~2x bwd) over a set of
/// segments — used for the per-client computational-burden column.
pub fn train_step_flops(fwd: u64) -> u64 {
    3 * fwd
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            image_size: 32,
            patch_size: 4,
            channels: 3,
            dim: 64,
            heads: 4,
            depth_head: 2,
            depth_body: 3,
            depth_tail: 1,
            mlp_ratio: 2,
            num_classes: 10,
            prompt_len: 8,
            batch: 16,
            num_patches: 64,
            seq_len: 73,
            seq_len_noprompt: 65,
            patch_dim: 48,
            analytic_only: false,
        }
    }

    #[test]
    fn flops_scale_with_depth() {
        let c = cfg();
        let f = segment_flops(&c, true);
        // body has 3 blocks, tail has 1 (+classifier): body ~ 3x tail block part.
        assert!(f.body > 2 * (f.tail - 2 * 64 * 10 - 8 * 73 * 64));
        assert!(f.total() > f.client());
    }

    #[test]
    fn prompt_increases_flops() {
        let c = cfg();
        assert!(segment_flops(&c, true).total() > segment_flops(&c, false).total());
    }

    #[test]
    fn block_flops_quadratic_in_seq_for_attention() {
        // Doubling seq should grow cost by >2x (attention term is quadratic).
        let f1 = block_flops(64, 50, 2);
        let f2 = block_flops(64, 100, 2);
        assert!(f2 > 2 * f1);
        assert!(f2 < 4 * f1);
    }
}
