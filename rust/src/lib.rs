//! # sfprompt
//!
//! Reproduction of *SFPrompt: Communication-Efficient Split Federated
//! Fine-Tuning for Large Pre-Trained Models over Resource-Limited Devices*
//! (Cao, Zhu, Gong — 2024) as a three-layer rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the federated/split coordinator: round scheduling,
//!   client selection, the split-training message protocol, local-loss
//!   self-update, EL2N dataset pruning, FedAvg aggregation, a simulated
//!   network with exact byte accounting, analytic cost models, baselines
//!   (FL, SFL+FF, SFL+Linear), and the experiment harness that regenerates
//!   every table and figure of the paper.
//! * **L2 (python/compile, build-time, optional)** — the split ViT + soft
//!   prompts in JAX, AOT-lowered per protocol message to
//!   `artifacts/<cfg>/*.hlo.txt` for the PJRT backend.
//! * **L1 (python/compile/kernels, build-time, optional)** — Pallas kernels
//!   (fused attention, LayerNorm, EL2N) called from L2.
//!
//! ## The compute substrate ([`backend`])
//!
//! Every stage execution goes through the [`backend::Backend`] trait, with
//! two interchangeable substrates:
//!
//! * **native** ([`backend::NativeBackend`], the default) — the
//!   prompt-augmented split ViT implemented as hand-written pure-Rust
//!   forward + backward kernels (patch embed, prompt concat, pre-LN
//!   multi-head attention, tanh-GELU MLP, cross-entropy, EL2N, exact SGD),
//!   driven by a **synthesized in-memory manifest**. Training runs
//!   end-to-end with zero artifacts on disk and zero Python — this is
//!   what `cargo test` and `train --backend native` exercise. Gradients
//!   are validated against `jax.grad` of the L2 model and by
//!   finite-difference tests.
//! * **pjrt** ([`backend::PjrtBackend`]) — the original artifact path:
//!   HLO text compiled and executed via the `xla` bindings (a functional
//!   host-side stub offline; real PJRT under the `pjrt` cargo feature).
//!
//! Frozen segments (head/body) cross the substrate boundary as opaque
//! [`backend::PreparedSegment`] handles, so no `xla` type appears in any
//! federation API. `--backend native_f16` stores those frozen segments
//! as f16 bits (half the resident bytes, decode-on-use; trainables stay
//! f32).
//!
//! ## Performance ([`backend::native::pool`], docs/PERF.md)
//!
//! The native kernels are cache-blocked (packed-B GEMM microkernel) and
//! parallel on a hand-rolled scoped thread pool (`--threads N`, the
//! `"threads"` RunSpec key, auto by default) — with results
//! **bit-identical to the scalar kernels at every thread count**:
//! blocking tiles outputs and threads partition rows, never a reduction,
//! so no f32 accumulation order changes. The pre-blocking kernels
//! survive as `backend::native::math::reference`, the bit-exact oracle.
//! Backends can fuse one stage across many clients'
//! inputs ([`backend::Backend::run_stage_batch`]); the serve loop drains
//! queued same-kind frames into such batches, and telemetry derives
//! GFLOP/s from per-thread **busy** time so parallelism never inflates
//! it. Speedups are recorded, not asserted: `scripts/bench_snapshot
//! stages` writes blocked-vs-scalar and thread-sweep rows to
//! `BENCH_stages.json`.
//!
//! ## The unified run API
//!
//! Every run — the paper's method and all three baselines — goes through
//! one typed pipeline (see `docs/API.md` for the full walkthrough):
//!
//! ```text
//! RunSpec (JSON, optional)                 federation::spec
//!   └─> spec.open_backend(root)?           backend (native | native_f16 | pjrt)
//!   └─> RunBuilder::new(method)...         federation::run   (validated;
//!         .build(&backend, &train, eval)?   the ONLY engine constructor)
//!         └─> Box<dyn FederatedRun>        method-agnostic engine handle
//!               └─> drive(run, observer)   federation::driver (the ONE
//!                     └─> RunHistory        round loop + event stream)
//!                           └─> RunReport  (JSON out, per-kind bytes)
//! ```
//!
//! [`federation::FederatedRun`] exposes `round` / `history` /
//! `comm_totals` / `final_eval`, so drivers (CLI `train`, the experiment
//! harness, examples, tests, benches) never name an engine type; method
//! variants are a [`federation::Method`] value plus a
//! [`federation::FedConfig`] delta. Progress, eval points, per-`MsgKind`
//! bytes, and the simulated §3.5 clock stream through
//! [`federation::RoundObserver`]; `sfprompt train --spec run.json --json`
//! runs the whole pipeline headlessly.
//!
//! ## Wire protocol & communication accounting
//!
//! Communication cost — the paper's headline metric — is **measured**, not
//! estimated: every federated message is serialised by [`transport`] into
//! a versioned binary frame (length prefix; `{version, kind, wire, round,
//! client}` header; typed payload; CRC32 trailer — see `docs/WIRE.md`) and
//! moved through a [`transport::Transport`] link. [`comm::ByteMeter`]
//! records the encoded frame lengths, so the totals behind Table 2 include
//! real framing overhead, and the shared-rate latency model of §3.5 runs
//! on measured bytes.
//!
//! Uplink payloads (`SmashedData`, `GradBodyOut`, `Upload`) support
//! pluggable precision ([`transport::WireFormat`]): f32 passthrough, IEEE
//! f16, or int8 affine quantization with per-tensor scales. Quantization
//! loss feeds back into training — the server computes on the dequantized
//! tensors — so `train --wire int8` measures both sides of the
//! accuracy/bytes trade-off, and `experiment --id wire` tabulates analytic
//! vs measured vs quantized bytes per message kind.
//!
//! ## Update compression ([`compress`])
//!
//! On top of scalar precision, Phase-3 uploads can be **compressed as
//! updates** ([`compress::Scheme`]; `train --compress
//! topk:0.01|randk:0.05|quant:4`, `RunBuilder::compress`, the
//! `"compress"` RunSpec key): top-k / rand-k sparsification with
//! per-client **error-feedback residuals** (dropped coordinates
//! accumulate and ship later, preserving convergence) or QSGD-style
//! stochastic quantization. Clients compress the delta against the
//! round's distributed reference before `Transport::send`; the wire
//! carries sparse frames (varint or bitmap coordinates, packed codes,
//! dense fallback — never larger than dense, property-tested); the server
//! decompresses before FedAvg. [`comm::ByteMeter`] meters both the wire
//! frames and their dense-f32 equivalent, so reports carry per-kind
//! raw-vs-wire bytes and a measured compression ratio, and the fleet
//! simulator's round time shrinks with the real byte savings.
//! `experiment --id compress` sweeps scheme × ratio into an
//! accuracy-vs-uploaded-bytes table (docs/COMPRESS.md).
//!
//! In the SFPrompt engine each selected client runs its round on its own
//! thread against the server's [`transport::Hub`], so Phase-2 split
//! training is genuinely concurrent (every [`backend::Backend`] is `Sync`).
//!
//! ## Fleet simulation ([`sim`])
//!
//! The paper's setting — resource-limited, heterogeneous edge devices —
//! is simulable end to end: a [`sim::FleetSpec`] (the `"fleet"` key of a
//! `RunSpec`, or `train --fleet <preset|file>`) gives every client a
//! device rate (FLOP/s) and link rate drawn from named distributions
//! (`uniform`, `pareto`, `two_tier`), seeded dropout/straggler/diurnal
//! availability, and optional **deadline-based rounds** (`--deadline-s`,
//! `--quorum`): the server aggregates whichever clients finish in time,
//! renormalizing FedAvg over the survivors, and the driver streams
//! per-client `on_client_done` / `on_client_dropped` events. Each round's
//! per-client time = analytic compute FLOPs over the device rate +
//! measured transport bytes over the link, resolved on a discrete-event
//! [`sim::SimClock`]. Without a fleet, time accounting reduces to the
//! §3.5 shared-rate model **bit-for-bit** (property-tested). See
//! docs/FLEET.md; `experiment --id fleet` sweeps device skew × dropout.
//!
//! ## Telemetry ([`telemetry`])
//!
//! Where does the time actually go? A zero-dependency tracing + metrics
//! layer answers with data instead of assertions: hierarchical spans
//! (run → round → client → phase → backend stage) stamped with wall *and*
//! sim-clock time, exported as JSON Lines or Chrome trace-event JSON
//! (opens in Perfetto), plus a registry of counters/gauges/histograms —
//! per-stage latency and achieved GFLOP/s (against the [`flops`] analytic
//! counts), frame encode/decode time, bytes per message kind,
//! compress/decompress time, FedAvg and EL2N timing. Off by default and
//! free when off (one atomic load per hook, zero allocations —
//! bench-guarded); `train --trace run.jsonl --metrics run.json` turns it
//! on, and `report --trace run.jsonl` pretty-prints a saved trace. See
//! docs/TELEMETRY.md.
//!
//! ## Networked coordinator ([`net`])
//!
//! The same federation runs over **real TCP sockets**: `sfprompt serve
//! --listen ADDR --processes N` runs the coordinator as a long-lived
//! server process and `sfprompt client --connect HOST:PORT` runs client
//! processes that compute their share of the fleet. The socket carries the
//! exact codec-v2 frame bytes (the frame's length prefix doubles as the
//! socket framing, so [`comm::ByteMeter`] and the `net_tx_bytes` /
//! `net_rx_bytes` telemetry counters meter **measured socket bytes**),
//! plus a strict-JSON control plane for the versioned handshake, loss
//! reporting (bit-exact hex floats), and shutdown. Client state is
//! rebuilt deterministically from the `Welcome`-delivered [`RunSpec`]
//! (same partition, same RNG fork order), so the networked `RunReport` is
//! **byte-identical** to the in-process one (modulo wall-clock) —
//! integration-tested over localhost. Observers can subscribe to a
//! line-delimited JSON round-event stream (`serve --events FILE`, or a
//! socket that sends one `observe` handshake). Zero new dependencies:
//! threaded blocking `std::net`. See docs/NET.md.
//!
//! ## Distributed tracing & the cost ledger (docs/TRACING.md)
//!
//! A networked run traced on every process stays one story: the
//! handshake propagates a run-wide 128-bit trace id and gives each
//! process a disjoint span-id block, per-round `RoundCtx` control
//! messages let client processes parent their spans under the
//! coordinator's round spans across process boundaries (serialised as
//! remote-parent `rp` edges), and NTP-style clock estimation (handshake
//! timestamps plus periodic probes, all bit-exact hex floats) measures
//! each client's clock offset/RTT against the coordinator. `sfprompt
//! trace merge A.jsonl B.jsonl ...` stitches the per-process files into
//! one causally-consistent tree on the coordinator timeline — remote
//! parents resolved, impossible overlaps flagged `skew` rather than
//! clamped. Alongside, [`telemetry::Ledger`] re-attributes the byte
//! meter's measurements onto (round, client, paper-phase, message-kind)
//! cells — reconciled **bit-exactly** against [`comm::ByteMeter`] at the
//! end of every run, sealed into the `RunReport` as `"ledger"`, and
//! rendered by `report --waterfall` as a per-round
//! communication-vs-compute waterfall.
//!
//! ## Live operations (docs/OPS.md)
//!
//! A serving coordinator is observable while it runs and debuggable when
//! it dies: a [`telemetry::HealthRegistry`] tracks per-client liveness,
//! straggler EWMAs, and run-level anomalies (non-finite/exploding loss,
//! stalled accuracy, zero-survivor streaks); any peer can ask for a
//! point-in-time snapshot with one `status` control message (`sfprompt
//! top --connect HOST:PORT` is the polling console); `serve --prom ADDR`
//! exposes the live metrics registry as Prometheus text over a minimal
//! HTTP/1.0 responder; an always-on, alloc-free
//! [`telemetry::FlightRecorder`] ring keeps the last ~1k events/span
//! closures and dumps post-mortem JSONL (`--postmortem FILE`, rendered
//! by `report --health`) when a run aborts or an anomaly fires; and
//! `sfprompt diff A B` canonically compares two reports or bench
//! snapshots with non-zero exit on regression — the CI gate.

pub mod analysis;
pub mod backend;
pub mod comm;
pub mod compress;
pub mod data;
pub mod experiments;
pub mod federation;
pub mod flops;
pub mod metrics;
pub mod model;
pub mod net;
pub mod partition;
pub mod runtime;
pub mod sim;
pub mod telemetry;
pub mod transport;
pub mod util;

/// Default artifacts directory (relative to the repo root / cwd).
pub const ARTIFACTS_DIR: &str = "artifacts";

/// Resolve the artifacts root: `$SFPROMPT_ARTIFACTS` or ./artifacts,
/// walking up from the current dir so tests/examples work from target/.
pub fn artifacts_root() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("SFPROMPT_ARTIFACTS") {
        return p.into();
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = dir.join(ARTIFACTS_DIR);
        if cand.is_dir() {
            return cand;
        }
        if !dir.pop() {
            return ARTIFACTS_DIR.into();
        }
    }
}
