//! Fleet simulation: heterogeneous devices, stragglers, availability
//! traces, and deadline-based rounds on a discrete-event clock.
//!
//! The paper's setting is resource-limited, heterogeneous edge devices;
//! this module is what makes that simulable. It supplies the driver's
//! **time authority**:
//!
//! * [`FleetSpec`] — serializable fleet description: device FLOP/s and
//!   link rates drawn from named [`RateDist`]s (`uniform`, `pareto`,
//!   `two_tier`), an optional shared bottleneck pool (subsuming the
//!   legacy shared-rate `NetworkModel`), seeded dropout / straggler /
//!   diurnal availability, and deadline + quorum round policy.
//! * [`Fleet`] — the runtime object an engine owns: samples per-client
//!   rates once per run, draws the per-round availability trace, and
//!   advances the cumulative simulated clock.
//! * [`SimClock`] — the per-round discrete-event clock: each selected
//!   client's slot accumulates transfer time (measured transport bytes
//!   over its link) and compute time (analytic FLOPs over its device),
//!   then [`SimClock::finish`] resolves the event queue chronologically,
//!   applies the [`DeadlinePolicy`], and reports survivors, drops, and
//!   the round latency as a [`RoundOutcome`].
//!
//! With no `fleet` key in a run spec the engines run on
//! [`Fleet::homogeneous`], which reproduces the pre-fleet `LinkClock`
//! accounting bit-for-bit. See docs/FLEET.md for the model, the JSON
//! format, and the scenario catalog.

pub mod clock;
pub mod fleet;

pub use clock::{
    ClientEvent, ClientOutcome, DeadlinePolicy, RoundOutcome, SimClock, SlotProfile,
};
pub use fleet::{Diurnal, DropReason, Fleet, FleetSpec, RateDist};
