//! The per-round discrete-event clock: every selected client owns a slot
//! whose simulated time accumulates transfer charges (measured transport
//! bytes over its link rate) and compute charges (analytic FLOPs over its
//! device rate), and the round resolves through an event queue ordered by
//! finish time, with optional deadline/quorum semantics.
//!
//! **Legacy parity** is load-bearing: with every slot online, an infinite
//! device rate, a shared link rate, and no deadline policy, `SimClock`
//! reproduces the old `LinkClock` arithmetic bit-for-bit — transfer time is
//! the identical `bytes / rate.max(1e-300)` expression, compute charges add
//! exactly `+0.0`, and round latency is the same `fold(0.0, f64::max)` over
//! per-slot elapsed time. A property test in `tests/proptests.rs` pins
//! this.

use super::fleet::DropReason;

/// Deadline-based round semantics: the server aggregates whichever clients
/// have finished (uploaded) by `deadline_s`. If fewer than `min_quorum`
/// made it, the deadline is extended (doubled) until the quorum is met —
/// the retry rule — so a round can be late but never empty while any
/// client is online.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeadlinePolicy {
    pub deadline_s: f64,
    pub min_quorum: usize,
}

/// What happened to one selected client this round, on the simulated
/// clock. `at_s` is the client's finish time for `Done`, the moment the
/// fleet gave up on it for `Dropped` (0.0 when it was offline at round
/// start).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClientEvent {
    /// Global client id (not the round slot).
    pub client: usize,
    pub at_s: f64,
    pub outcome: ClientOutcome,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ClientOutcome {
    Done,
    Dropped(DropReason),
}

impl ClientEvent {
    pub fn is_dropped(&self) -> bool {
        matches!(self.outcome, ClientOutcome::Dropped(_))
    }
}

/// The resolved round: chronological per-client events, which slots
/// survive into aggregation, and the round's simulated latency.
#[derive(Debug, Clone)]
pub struct RoundOutcome {
    /// Per-client events in event-queue (chronological) order.
    pub events: Vec<ClientEvent>,
    /// Slot indices whose uploads the server aggregates, ascending.
    pub survivors: Vec<usize>,
    /// Simulated round latency (the driver's §3.5 clock advances by this).
    pub latency_s: f64,
    /// How many times the quorum retry rule doubled the deadline.
    pub deadline_extensions: usize,
}

impl RoundOutcome {
    pub fn is_survivor(&self, slot: usize) -> bool {
        self.survivors.binary_search(&slot).is_ok()
    }

    pub fn dropped(&self) -> usize {
        self.events.iter().filter(|e| e.is_dropped()).count()
    }
}

/// One selected client's simulation parameters for the round, sampled by
/// [`super::Fleet::begin_round`].
#[derive(Debug, Clone, Copy)]
pub struct SlotProfile {
    /// Global client id.
    pub client: usize,
    /// Effective link rate, bytes/second (sharing already applied).
    pub link_bytes_per_s: f64,
    /// Device compute throughput, FLOP/s. `f64::INFINITY` models the
    /// legacy compute-free client.
    pub device_flops_per_s: f64,
    /// Straggler multiplier on compute time (1.0 = nominal).
    pub slowdown: f64,
    /// Whether the client is reachable this round at all.
    pub online: bool,
}

struct SlotState {
    prof: SlotProfile,
    elapsed_s: f64,
    /// Elapsed time snapshot at upload completion (deadline decisions are
    /// made on upload times; post-upload broadcast traffic only stretches
    /// the round tail).
    done_mark_s: Option<f64>,
}

/// Per-round simulated clock over the selected cohort. Engines charge
/// every transmitted frame and every unit of client compute here; the
/// round resolves with [`SimClock::finish`].
pub struct SimClock {
    slots: Vec<SlotState>,
    policy: Option<DeadlinePolicy>,
}

impl SimClock {
    pub fn new(profiles: Vec<SlotProfile>, policy: Option<DeadlinePolicy>) -> SimClock {
        let slots = profiles
            .into_iter()
            .map(|prof| SlotState { prof, elapsed_s: 0.0, done_mark_s: None })
            .collect();
        SimClock { slots, policy }
    }

    pub fn slots(&self) -> usize {
        self.slots.len()
    }

    pub fn online(&self, slot: usize) -> bool {
        self.slots[slot].prof.online
    }

    /// Global client id occupying `slot`.
    pub fn client(&self, slot: usize) -> usize {
        self.slots[slot].prof.client
    }

    /// Accumulated simulated time for one slot.
    pub fn slot_s(&self, slot: usize) -> f64 {
        self.slots[slot].elapsed_s
    }

    /// Charge `bytes` of transfer time to `slot`'s link; returns the time
    /// added. Offline slots never transmit, so the charge is zero.
    pub fn charge_transfer(&mut self, slot: usize, bytes: usize) -> f64 {
        let s = &mut self.slots[slot];
        if !s.prof.online {
            return 0.0;
        }
        // Identical expression to NetworkModel::transfer_time_s — the
        // legacy-parity contract depends on it.
        let dt = bytes as f64 / s.prof.link_bytes_per_s.max(1e-300);
        s.elapsed_s += dt;
        dt
    }

    /// Charge `flops` of compute to `slot`'s device (straggler slowdown
    /// applied); returns the time added. An infinite device rate yields
    /// exactly `+0.0` (the legacy compute-free client).
    pub fn charge_compute(&mut self, slot: usize, flops: u64) -> f64 {
        let s = &mut self.slots[slot];
        if !s.prof.online {
            return 0.0;
        }
        let dt = (flops as f64 / s.prof.device_flops_per_s.max(1e-300)) * s.prof.slowdown;
        s.elapsed_s += dt;
        dt
    }

    /// Snapshot `slot`'s elapsed time as its upload-completion mark — the
    /// time the deadline policy judges it by.
    pub fn mark_done(&mut self, slot: usize) {
        let s = &mut self.slots[slot];
        s.done_mark_s = Some(s.elapsed_s);
        if let Some(t) = crate::telemetry::active() {
            t.metrics.observe("sim_done_mark_s", s.elapsed_s);
        }
    }

    /// Resolve the round: order finishes chronologically, apply the
    /// deadline/quorum policy to upload marks, and compute the round
    /// latency. Pure — charging after `finish` is a caller bug.
    pub fn finish(&self) -> RoundOutcome {
        let mut events = Vec::with_capacity(self.slots.len());
        // Offline clients dropped at round start, before any online event.
        for s in &self.slots {
            if !s.prof.online {
                events.push(ClientEvent {
                    client: s.prof.client,
                    at_s: 0.0,
                    outcome: ClientOutcome::Dropped(DropReason::Offline),
                });
            }
        }

        // Event queue: online finishes ascending by upload mark (ties
        // break by slot index so resolution is deterministic).
        let mut finishes: Vec<(f64, usize)> = self
            .slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.prof.online)
            .map(|(i, s)| (s.done_mark_s.unwrap_or(s.elapsed_s), i))
            .collect();
        finishes.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));

        let (effective_deadline, extensions) = match self.policy {
            None => (f64::INFINITY, 0),
            Some(p) => {
                let quorum = p.min_quorum.min(finishes.len());
                let mut eff = p.deadline_s;
                let mut ext = 0usize;
                while finishes.iter().filter(|(t, _)| *t <= eff).count() < quorum {
                    eff *= 2.0;
                    ext += 1;
                    if ext >= 64 {
                        eff = f64::INFINITY; // pathological spec; admit all
                        break;
                    }
                }
                (eff, ext)
            }
        };

        let mut survivors = Vec::with_capacity(finishes.len());
        let mut late = Vec::new();
        for &(t, slot) in &finishes {
            if t <= effective_deadline {
                survivors.push(slot);
                events.push(ClientEvent {
                    client: self.slots[slot].prof.client,
                    at_s: t,
                    outcome: ClientOutcome::Done,
                });
            } else {
                late.push(slot);
            }
        }
        // Deadline drops all fire at the moment the server gives up.
        for &slot in &late {
            events.push(ClientEvent {
                client: self.slots[slot].prof.client,
                at_s: effective_deadline,
                outcome: ClientOutcome::Dropped(DropReason::Deadline),
            });
        }
        survivors.sort_unstable();

        // Round latency. No deadline drops: the slowest online slot's full
        // elapsed time (exactly the legacy max-over-clocks). With drops:
        // the server waited out the deadline, plus any survivor whose
        // post-upload traffic stretched past it.
        let survivor_max = survivors
            .iter()
            .map(|&i| self.slots[i].elapsed_s)
            .fold(0.0, f64::max);
        let latency_s = if late.is_empty() {
            finishes
                .iter()
                .map(|&(_, i)| self.slots[i].elapsed_s)
                .fold(0.0, f64::max)
        } else {
            effective_deadline.max(survivor_max)
        };

        if let Some(t) = crate::telemetry::active() {
            // Gauges (last write wins): `finish` may run twice per round
            // (mid-round survivor resolution + final), so monotone
            // counters here would double-count.
            t.metrics.gauge_set("sim_round_latency_s", latency_s);
            t.metrics.gauge_set("sim_deadline_extensions", extensions as f64);
        }
        RoundOutcome { events, survivors, latency_s, deadline_extensions: extensions }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn online(client: usize, link: f64, dev: f64) -> SlotProfile {
        SlotProfile {
            client,
            link_bytes_per_s: link,
            device_flops_per_s: dev,
            slowdown: 1.0,
            online: true,
        }
    }

    #[test]
    fn legacy_shape_max_over_slots() {
        let mut c = SimClock::new(
            vec![online(0, 250.0, f64::INFINITY), online(1, 250.0, f64::INFINITY)],
            None,
        );
        assert!((c.charge_transfer(0, 500) - 2.0).abs() < 1e-12);
        assert_eq!(c.charge_compute(0, u64::MAX), 0.0, "infinite device is free");
        c.charge_transfer(1, 1000); // 4 s
        c.mark_done(0);
        c.mark_done(1);
        let out = c.finish();
        assert_eq!(out.survivors, vec![0, 1]);
        assert_eq!(out.dropped(), 0);
        assert!((out.latency_s - 4.0).abs() < 1e-12);
        // Chronological: slot 0 (2 s) before slot 1 (4 s).
        assert_eq!(out.events[0].client, 0);
        assert_eq!(out.events[1].client, 1);
    }

    #[test]
    fn compute_scales_with_device_and_slowdown() {
        let mut slow = online(0, 1e6, 1e9);
        slow.slowdown = 4.0;
        let mut c = SimClock::new(vec![slow, online(1, 1e6, 2e9)], None);
        let d0 = c.charge_compute(0, 2_000_000_000); // 2 s * 4
        let d1 = c.charge_compute(1, 2_000_000_000); // 1 s
        assert!((d0 - 8.0).abs() < 1e-9);
        assert!((d1 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn deadline_drops_late_clients_and_latency_is_deadline() {
        let mut c = SimClock::new(
            vec![
                online(7, 100.0, f64::INFINITY),
                online(8, 10.0, f64::INFINITY),
            ],
            Some(DeadlinePolicy { deadline_s: 5.0, min_quorum: 1 }),
        );
        c.charge_transfer(0, 100); // 1 s
        c.charge_transfer(1, 100); // 10 s
        c.mark_done(0);
        c.mark_done(1);
        let out = c.finish();
        assert_eq!(out.survivors, vec![0]);
        assert_eq!(out.dropped(), 1);
        assert!((out.latency_s - 5.0).abs() < 1e-12);
        let drop = out.events.iter().find(|e| e.is_dropped()).unwrap();
        assert_eq!(drop.client, 8);
        assert_eq!(drop.outcome, ClientOutcome::Dropped(DropReason::Deadline));
        assert!((drop.at_s - 5.0).abs() < 1e-12);
    }

    #[test]
    fn quorum_retry_extends_deadline() {
        let mut c = SimClock::new(
            vec![
                online(0, 100.0, f64::INFINITY), // 1 s
                online(1, 25.0, f64::INFINITY),  // 4 s
                online(2, 10.0, f64::INFINITY),  // 10 s
            ],
            Some(DeadlinePolicy { deadline_s: 0.5, min_quorum: 2 }),
        );
        for slot in 0..3 {
            c.charge_transfer(slot, 100);
            c.mark_done(slot);
        }
        let out = c.finish();
        // 0.5 -> 1 -> 2 -> 4: first deadline admitting two finishers.
        assert_eq!(out.deadline_extensions, 3);
        assert_eq!(out.survivors, vec![0, 1]);
        assert_eq!(out.dropped(), 1);
        assert!((out.latency_s - 4.0).abs() < 1e-12);
    }

    #[test]
    fn offline_slots_charge_nothing_and_drop_at_zero() {
        let mut off = online(3, 100.0, 1e9);
        off.online = false;
        let mut c = SimClock::new(vec![off, online(4, 100.0, f64::INFINITY)], None);
        assert_eq!(c.charge_transfer(0, 1000), 0.0);
        assert_eq!(c.charge_compute(0, 1 << 40), 0.0);
        c.charge_transfer(1, 200);
        c.mark_done(1);
        let out = c.finish();
        assert_eq!(out.survivors, vec![1]);
        assert_eq!(out.dropped(), 1);
        let ev = &out.events[0];
        assert_eq!(ev.outcome, ClientOutcome::Dropped(DropReason::Offline));
        assert_eq!(ev.at_s, 0.0);
        assert_eq!(ev.client, 3);
    }

    #[test]
    fn quorum_caps_at_online_count() {
        // Quorum larger than the online cohort must not loop forever.
        let mut c = SimClock::new(
            vec![online(0, 100.0, f64::INFINITY)],
            Some(DeadlinePolicy { deadline_s: 1.0, min_quorum: 5 }),
        );
        c.charge_transfer(0, 50);
        c.mark_done(0);
        let out = c.finish();
        assert_eq!(out.survivors, vec![0]);
        assert_eq!(out.deadline_extensions, 0);
    }
}
