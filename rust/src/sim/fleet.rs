//! Fleet models: who the clients *are* — device compute rates, link
//! rates, and availability traces — and how a round's [`SimClock`] is
//! sampled from them.
//!
//! A [`FleetSpec`] is serializable (the `"fleet"` key of a `RunSpec`, see
//! docs/FLEET.md) and names three axes:
//!
//! * **devices** — per-client compute throughput in FLOP/s, drawn once per
//!   run from a named [`RateDist`] (`uniform`, `pareto`, `two_tier`);
//! * **links** — per-client link rate in bytes/s from the same
//!   distribution machinery, plus an optional shared bottleneck pool that
//!   caps the cohort (subsuming the legacy shared-rate `NetworkModel`);
//! * **availability** — seeded per-round dropout, straggler slowdown, and
//!   a diurnal on-fraction curve over the cumulative simulated clock.
//!
//! A [`Fleet`] is the runtime object the engines own: `begin_round`
//! samples the selected cohort's [`SimClock`]; `advance` moves the
//! fleet's simulated wall-clock forward by the round latency (the diurnal
//! model reads it). `Fleet::homogeneous` is the legacy mode — always-on,
//! compute-free clients on the paper's §3.5 shared-rate link — and
//! reproduces the old `LinkClock` numbers bit-for-bit.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::comm::NetworkModel;
use crate::util::json::Json;
use crate::util::rng::{seeds, Rng};

use super::clock::{DeadlinePolicy, SimClock, SlotProfile};

/// Why a client's round contribution was discarded.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DropReason {
    /// Unreachable at round start (dropout / diurnal trough).
    Offline,
    /// Missed the (possibly quorum-extended) round deadline.
    Deadline,
}

impl DropReason {
    pub fn label(&self) -> &'static str {
        match self {
            DropReason::Offline => "offline",
            DropReason::Deadline => "deadline",
        }
    }
}

/// Named distribution a per-client rate (FLOP/s or bytes/s) is drawn from.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RateDist {
    /// Uniform in [min, max].
    Uniform { min: f64, max: f64 },
    /// Heavy-tailed slowness: rate = `scale / s` with `s ~ Pareto(shape)`,
    /// `s >= 1` — most devices run near `scale`, a long tail runs far
    /// slower (the straggler regime the paper's setting implies).
    Pareto { scale: f64, shape: f64 },
    /// A `slow_fraction` of clients at `slow`, the rest at `fast`.
    TwoTier { fast: f64, slow: f64, slow_fraction: f64 },
}

impl RateDist {
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        match *self {
            RateDist::Uniform { min, max } => min + (max - min) * rng.uniform(),
            RateDist::Pareto { scale, shape } => {
                let s = (1.0 - rng.uniform()).max(f64::MIN_POSITIVE).powf(-1.0 / shape);
                scale / s
            }
            RateDist::TwoTier { fast, slow, slow_fraction } => {
                if rng.uniform() < slow_fraction {
                    slow
                } else {
                    fast
                }
            }
        }
    }

    pub fn validate(&self, what: &str) -> Result<()> {
        let pos = |v: f64, name: &str| -> Result<()> {
            if !v.is_finite() || v <= 0.0 {
                bail!("{what} {name} must be positive and finite, got {v}");
            }
            Ok(())
        };
        match *self {
            RateDist::Uniform { min, max } => {
                pos(min, "uniform.min")?;
                pos(max, "uniform.max")?;
                if min > max {
                    bail!("{what} uniform.min {min} exceeds uniform.max {max}");
                }
            }
            RateDist::Pareto { scale, shape } => {
                pos(scale, "pareto.scale")?;
                pos(shape, "pareto.shape")?;
            }
            RateDist::TwoTier { fast, slow, slow_fraction } => {
                pos(fast, "two_tier.fast")?;
                pos(slow, "two_tier.slow")?;
                if !(0.0..=1.0).contains(&slow_fraction) {
                    bail!("{what} two_tier.slow_fraction must be in [0, 1], got {slow_fraction}");
                }
            }
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut inner = BTreeMap::new();
        let name = match *self {
            RateDist::Uniform { min, max } => {
                inner.insert("min".to_string(), Json::Num(min));
                inner.insert("max".to_string(), Json::Num(max));
                "uniform"
            }
            RateDist::Pareto { scale, shape } => {
                inner.insert("scale".to_string(), Json::Num(scale));
                inner.insert("shape".to_string(), Json::Num(shape));
                "pareto"
            }
            RateDist::TwoTier { fast, slow, slow_fraction } => {
                inner.insert("fast".to_string(), Json::Num(fast));
                inner.insert("slow".to_string(), Json::Num(slow));
                inner.insert("slow_fraction".to_string(), Json::Num(slow_fraction));
                "two_tier"
            }
        };
        let mut o = BTreeMap::new();
        o.insert(name.to_string(), Json::Obj(inner));
        Json::Obj(o)
    }

    pub fn from_json(v: &Json) -> Result<RateDist> {
        let obj = v
            .as_obj()
            .filter(|o| o.len() == 1)
            .ok_or_else(|| anyhow!("rate distribution must be a one-key object like \
                 {{\"uniform\": {{\"min\": ..., \"max\": ...}}}}"))?;
        let (name, body) = obj.iter().next().expect("one key");
        let params = body
            .as_obj()
            .ok_or_else(|| anyhow!("rate distribution {name:?} parameters must be an object"))?;
        let num = |key: &str| -> Result<f64> {
            params
                .get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("rate distribution {name:?} needs numeric key {key:?}"))
        };
        let known = |keys: &[&str]| -> Result<()> {
            for k in params.keys() {
                if !keys.contains(&k.as_str()) {
                    bail!("unknown {name:?} key {k:?} (known: {})", keys.join(" "));
                }
            }
            Ok(())
        };
        Ok(match name.as_str() {
            "uniform" => {
                known(&["min", "max"])?;
                RateDist::Uniform { min: num("min")?, max: num("max")? }
            }
            "pareto" => {
                known(&["scale", "shape"])?;
                RateDist::Pareto { scale: num("scale")?, shape: num("shape")? }
            }
            "two_tier" => {
                known(&["fast", "slow", "slow_fraction"])?;
                RateDist::TwoTier {
                    fast: num("fast")?,
                    slow: num("slow")?,
                    slow_fraction: num("slow_fraction")?,
                }
            }
            other => bail!("unknown rate distribution {other:?} (known: uniform pareto two_tier)"),
        })
    }
}

/// Diurnal availability: the on-fraction follows a raised cosine over the
/// cumulative simulated clock, from 1.0 at `t = 0` down to
/// `min_availability` half a period later.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Diurnal {
    pub period_s: f64,
    pub min_availability: f64,
}

impl Diurnal {
    pub fn availability(&self, t_s: f64) -> f64 {
        let phase = 0.5 * (1.0 + (2.0 * std::f64::consts::PI * t_s / self.period_s).cos());
        self.min_availability + (1.0 - self.min_availability) * phase
    }
}

/// Serializable description of a heterogeneous fleet (the `"fleet"` key of
/// a `RunSpec`; see docs/FLEET.md for the JSON format and the preset
/// catalog).
#[derive(Debug, Clone, PartialEq)]
pub struct FleetSpec {
    /// Per-client device throughput, FLOP/s.
    pub devices: RateDist,
    /// Per-client link rate, bytes/s.
    pub links: RateDist,
    /// Optional shared bottleneck: the cohort splits this pool evenly and
    /// each client runs at `min(own_rate, pool / cohort_size)`.
    pub shared_pool_bytes_per_s: Option<f64>,
    /// Per-round, per-client probability of being offline at round start.
    pub dropout_p: f64,
    /// Per-round, per-client probability of running `straggler_slowdown`x
    /// slower than its nominal device rate.
    pub straggler_p: f64,
    pub straggler_slowdown: f64,
    /// Availability modulation over simulated time (multiplies 1−dropout).
    pub diurnal: Option<Diurnal>,
    /// Round deadline (simulated seconds). None = the server waits for
    /// every online client (legacy semantics).
    pub deadline_s: Option<f64>,
    /// Quorum for the deadline retry rule: fewer finishers than this and
    /// the deadline doubles until the quorum is met.
    pub min_quorum: usize,
}

impl FleetSpec {
    /// The preset catalog (docs/FLEET.md). `ideal` is compute-free on the
    /// legacy shared pool — the fleet to use when only deadline semantics
    /// are wanted.
    pub const NAMES: [&'static str; 6] =
        ["uniform", "two-tier", "pareto", "dropout", "diurnal", "ideal"];

    fn base(devices: RateDist) -> FleetSpec {
        FleetSpec {
            devices,
            links: RateDist::Uniform { min: 5e6, max: 25e6 },
            shared_pool_bytes_per_s: None,
            dropout_p: 0.0,
            straggler_p: 0.0,
            straggler_slowdown: 4.0,
            diurnal: None,
            deadline_s: None,
            min_quorum: 1,
        }
    }

    pub fn named(name: &str) -> Result<FleetSpec> {
        Ok(match name {
            // Mid-range edge devices, an order of magnitude of spread.
            "uniform" => FleetSpec::base(RateDist::Uniform { min: 5e9, max: 5e10 }),
            // Capable majority + a slow tier 25x behind it.
            "two-tier" => FleetSpec::base(RateDist::TwoTier {
                fast: 5e10,
                slow: 2e9,
                slow_fraction: 0.25,
            }),
            // Heavy-tailed slowness: the straggler regime.
            "pareto" => FleetSpec::base(RateDist::Pareto { scale: 5e10, shape: 1.2 }),
            "dropout" => FleetSpec {
                dropout_p: 0.2,
                ..FleetSpec::base(RateDist::Uniform { min: 5e9, max: 5e10 })
            },
            "diurnal" => FleetSpec {
                diurnal: Some(Diurnal { period_s: 3600.0, min_availability: 0.3 }),
                ..FleetSpec::base(RateDist::Uniform { min: 5e9, max: 5e10 })
            },
            // Compute-free clients on the legacy 100 Mbit/s shared pool:
            // deadline semantics without device heterogeneity.
            "ideal" => FleetSpec {
                links: RateDist::Uniform { min: 1e18, max: 1e18 },
                shared_pool_bytes_per_s: Some(12.5e6),
                ..FleetSpec::base(RateDist::Uniform { min: 1e18, max: 1e18 })
            },
            other => bail!(
                "unknown fleet preset {other:?} (known: {})",
                FleetSpec::NAMES.join(" ")
            ),
        })
    }

    /// Resolve a CLI `--fleet` argument: a preset name, else a JSON file.
    pub fn resolve(name_or_path: &str) -> Result<FleetSpec> {
        if FleetSpec::NAMES.contains(&name_or_path) {
            return FleetSpec::named(name_or_path);
        }
        let text = std::fs::read_to_string(name_or_path).map_err(|e| {
            anyhow!(
                "--fleet {name_or_path:?} is neither a preset (known: {}) nor a readable \
                 file: {e}",
                FleetSpec::NAMES.join(" ")
            )
        })?;
        let v = Json::parse(&text).map_err(|e| anyhow!("parsing fleet file: {e}"))?;
        FleetSpec::from_json(&v)
    }

    pub fn validate(&self) -> Result<()> {
        self.devices.validate("fleet devices")?;
        self.links.validate("fleet links")?;
        if let Some(pool) = self.shared_pool_bytes_per_s {
            if !pool.is_finite() || pool <= 0.0 {
                bail!("fleet shared_pool_bytes_per_s must be positive and finite, got {pool}");
            }
        }
        for (p, name) in [(self.dropout_p, "dropout_p"), (self.straggler_p, "straggler_p")] {
            if !(0.0..=1.0).contains(&p) {
                bail!("fleet {name} must be in [0, 1], got {p}");
            }
        }
        if self.dropout_p >= 1.0 {
            bail!("fleet dropout_p 1.0 leaves no client ever online");
        }
        if !self.straggler_slowdown.is_finite() || self.straggler_slowdown < 1.0 {
            bail!(
                "fleet straggler_slowdown must be >= 1, got {}",
                self.straggler_slowdown
            );
        }
        if let Some(d) = self.diurnal {
            if !d.period_s.is_finite() || d.period_s <= 0.0 {
                bail!("fleet diurnal.period_s must be positive and finite, got {}", d.period_s);
            }
            if !(0.0..=1.0).contains(&d.min_availability) {
                bail!(
                    "fleet diurnal.min_availability must be in [0, 1], got {}",
                    d.min_availability
                );
            }
        }
        if let Some(d) = self.deadline_s {
            if !d.is_finite() || d <= 0.0 {
                bail!("fleet deadline_s must be positive and finite, got {d}");
            }
        }
        if self.min_quorum == 0 {
            bail!("fleet min_quorum must be at least 1");
        }
        if self.min_quorum > 1 && self.deadline_s.is_none() {
            bail!(
                "fleet min_quorum {} has no effect without deadline_s (the quorum only \
                 governs the deadline retry rule)",
                self.min_quorum
            );
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("devices".to_string(), self.devices.to_json());
        o.insert("links".to_string(), self.links.to_json());
        if let Some(pool) = self.shared_pool_bytes_per_s {
            o.insert("shared_pool_bytes_per_s".to_string(), Json::Num(pool));
        }
        o.insert("dropout_p".to_string(), Json::Num(self.dropout_p));
        o.insert("straggler_p".to_string(), Json::Num(self.straggler_p));
        o.insert(
            "straggler_slowdown".to_string(),
            Json::Num(self.straggler_slowdown),
        );
        if let Some(d) = self.diurnal {
            let mut di = BTreeMap::new();
            di.insert("period_s".to_string(), Json::Num(d.period_s));
            di.insert("min_availability".to_string(), Json::Num(d.min_availability));
            o.insert("diurnal".to_string(), Json::Obj(di));
        }
        if let Some(d) = self.deadline_s {
            o.insert("deadline_s".to_string(), Json::Num(d));
        }
        o.insert("min_quorum".to_string(), Json::Num(self.min_quorum as f64));
        Json::Obj(o)
    }

    /// Parse from JSON: either a preset name string or a full object
    /// (unknown keys rejected; every key optional, defaulting to the
    /// `uniform` preset's values).
    pub fn from_json(v: &Json) -> Result<FleetSpec> {
        if let Some(name) = v.as_str() {
            return FleetSpec::named(name);
        }
        let obj = v
            .as_obj()
            .ok_or_else(|| anyhow!("fleet must be a preset name or an object"))?;
        const KNOWN: [&str; 9] = [
            "devices", "links", "shared_pool_bytes_per_s", "dropout_p", "straggler_p",
            "straggler_slowdown", "diurnal", "deadline_s", "min_quorum",
        ];
        for key in obj.keys() {
            if !KNOWN.contains(&key.as_str()) {
                bail!("unknown fleet key {key:?} (known: {})", KNOWN.join(" "));
            }
        }
        let mut spec = FleetSpec::named("uniform").expect("preset");
        if let Some(d) = obj.get("devices") {
            spec.devices = RateDist::from_json(d)?;
        }
        if let Some(l) = obj.get("links") {
            spec.links = RateDist::from_json(l)?;
        }
        let num = |key: &str, default: f64| -> Result<f64> {
            match obj.get(key) {
                None => Ok(default),
                Some(j) => j
                    .as_f64()
                    .ok_or_else(|| anyhow!("fleet key {key:?} must be a number")),
            }
        };
        spec.shared_pool_bytes_per_s = match obj.get("shared_pool_bytes_per_s") {
            None | Some(Json::Null) => None,
            Some(j) => Some(j.as_f64().ok_or_else(|| {
                anyhow!("fleet key \"shared_pool_bytes_per_s\" must be a number or null")
            })?),
        };
        spec.dropout_p = num("dropout_p", spec.dropout_p)?;
        spec.straggler_p = num("straggler_p", spec.straggler_p)?;
        spec.straggler_slowdown = num("straggler_slowdown", spec.straggler_slowdown)?;
        spec.diurnal = match obj.get("diurnal") {
            None | Some(Json::Null) => None,
            Some(j) => {
                let d = j
                    .as_obj()
                    .ok_or_else(|| anyhow!("fleet key \"diurnal\" must be an object or null"))?;
                for key in d.keys() {
                    if !["period_s", "min_availability"].contains(&key.as_str()) {
                        bail!("unknown diurnal key {key:?} (known: period_s min_availability)");
                    }
                }
                let get = |key: &str| -> Result<f64> {
                    d.get(key)
                        .and_then(Json::as_f64)
                        .ok_or_else(|| anyhow!("diurnal needs numeric key {key:?}"))
                };
                Some(Diurnal {
                    period_s: get("period_s")?,
                    min_availability: get("min_availability")?,
                })
            }
        };
        spec.deadline_s = match obj.get("deadline_s") {
            None | Some(Json::Null) => None,
            Some(j) => Some(
                j.as_f64()
                    .ok_or_else(|| anyhow!("fleet key \"deadline_s\" must be a number or null"))?,
            ),
        };
        spec.min_quorum = match obj.get("min_quorum") {
            None => spec.min_quorum,
            Some(j) => j
                .as_usize()
                .ok_or_else(|| anyhow!("fleet key \"min_quorum\" must be a positive integer"))?,
        };
        spec.validate()?;
        Ok(spec)
    }
}

enum FleetInner {
    /// Legacy: always-on, compute-free clients on the §3.5 shared-rate
    /// link — bit-for-bit the old `LinkClock` time accounting.
    Homogeneous { net: NetworkModel },
    Hetero(Box<HeteroFleet>),
}

struct HeteroFleet {
    spec: FleetSpec,
    /// Per-client-id sampled rates (fixed for the run).
    device_flops_per_s: Vec<f64>,
    link_bytes_per_s: Vec<f64>,
    /// Trace stream: availability + straggler draws, per round.
    rng: Rng,
    /// Cumulative simulated clock (drives the diurnal curve).
    now_s: f64,
}

/// The runtime fleet an engine owns: per-client profiles plus the seeded
/// trace stream, advancing on the simulated clock round by round.
pub struct Fleet {
    inner: FleetInner,
}

impl Fleet {
    /// The legacy homogeneous fleet (no `fleet` key in the spec).
    pub fn homogeneous(net: NetworkModel) -> Fleet {
        Fleet { inner: FleetInner::Homogeneous { net } }
    }

    /// Sample a heterogeneous fleet: per-client device and link rates are
    /// drawn once from the spec's distributions on the run's documented
    /// fleet seed domain ([`seeds::fleet`]), so identical (spec, seed)
    /// pairs reproduce identical fleets and traces.
    pub fn from_spec(spec: FleetSpec, num_clients: usize, seed: u64) -> Fleet {
        let mut rng = Rng::new(seeds::fleet(seed));
        let device_flops_per_s = (0..num_clients).map(|_| spec.devices.sample(&mut rng)).collect();
        let link_bytes_per_s = (0..num_clients).map(|_| spec.links.sample(&mut rng)).collect();
        Fleet {
            inner: FleetInner::Hetero(Box::new(HeteroFleet {
                spec,
                device_flops_per_s,
                link_bytes_per_s,
                rng,
                now_s: 0.0,
            })),
        }
    }

    pub fn is_heterogeneous(&self) -> bool {
        matches!(self.inner, FleetInner::Hetero { .. })
    }

    /// Cumulative simulated time (0.0 for the legacy fleet).
    pub fn now_s(&self) -> f64 {
        match &self.inner {
            FleetInner::Homogeneous { .. } => 0.0,
            FleetInner::Hetero(h) => h.now_s,
        }
    }

    /// Sampled device rate for one client (infinite in legacy mode).
    pub fn device_flops_per_s(&self, client: usize) -> f64 {
        match &self.inner {
            FleetInner::Homogeneous { .. } => f64::INFINITY,
            FleetInner::Hetero(h) => h.device_flops_per_s[client],
        }
    }

    /// Build the round's clock over the selected cohort: draw availability
    /// and straggler state per slot, fix effective link rates, and attach
    /// the deadline policy.
    pub fn begin_round(&mut self, selected: &[usize]) -> SimClock {
        match &mut self.inner {
            FleetInner::Homogeneous { net } => {
                let profiles = selected
                    .iter()
                    .map(|&cid| SlotProfile {
                        client: cid,
                        link_bytes_per_s: net.effective_rate(),
                        device_flops_per_s: f64::INFINITY,
                        slowdown: 1.0,
                        online: true,
                    })
                    .collect();
                SimClock::new(profiles, None)
            }
            FleetInner::Hetero(h) => {
                let h = &mut **h;
                let k = selected.len().max(1);
                let diurnal = h.spec.diurnal.map_or(1.0, |d| d.availability(h.now_s));
                let p_online = (1.0 - h.spec.dropout_p) * diurnal;
                let spec = &h.spec;
                let rng = &mut h.rng;
                let (links, devices) = (&h.link_bytes_per_s, &h.device_flops_per_s);
                let profiles = selected
                    .iter()
                    .map(|&cid| {
                        // Two draws per slot, always, so the trace stream
                        // is independent of which knobs are enabled.
                        let online = rng.uniform() < p_online;
                        let straggles = rng.uniform() < spec.straggler_p;
                        let mut link = links[cid];
                        if let Some(pool) = spec.shared_pool_bytes_per_s {
                            link = link.min(pool / k as f64);
                        }
                        SlotProfile {
                            client: cid,
                            link_bytes_per_s: link,
                            device_flops_per_s: devices[cid],
                            slowdown: if straggles { spec.straggler_slowdown } else { 1.0 },
                            online,
                        }
                    })
                    .collect();
                let policy = spec
                    .deadline_s
                    .map(|deadline_s| DeadlinePolicy { deadline_s, min_quorum: spec.min_quorum });
                SimClock::new(profiles, policy)
            }
        }
    }

    /// Advance the fleet's simulated clock by one round's latency.
    pub fn advance(&mut self, latency_s: f64) {
        if let FleetInner::Hetero(h) = &mut self.inner {
            h.now_s += latency_s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate_and_roundtrip_json() {
        for name in FleetSpec::NAMES {
            let spec = FleetSpec::named(name).unwrap();
            spec.validate().unwrap();
            let back = FleetSpec::from_json(&spec.to_json()).unwrap();
            assert_eq!(back, spec, "{name}");
        }
        assert!(FleetSpec::named("warp").is_err());
    }

    #[test]
    fn fleet_json_accepts_name_and_rejects_unknown_keys() {
        let by_name = FleetSpec::from_json(&Json::Str("two-tier".into())).unwrap();
        assert_eq!(by_name, FleetSpec::named("two-tier").unwrap());
        assert!(FleetSpec::from_json(&Json::parse(r#"{"dropout": 0.5}"#).unwrap()).is_err());
        assert!(FleetSpec::from_json(
            &Json::parse(r#"{"devices": {"zipf": {"s": 1.0}}}"#).unwrap()
        )
        .is_err());
        let partial =
            FleetSpec::from_json(&Json::parse(r#"{"dropout_p": 0.3, "deadline_s": 9.5}"#).unwrap())
                .unwrap();
        assert!((partial.dropout_p - 0.3).abs() < 1e-12);
        assert_eq!(partial.deadline_s, Some(9.5));
        assert_eq!(partial.devices, FleetSpec::named("uniform").unwrap().devices);
    }

    #[test]
    fn validate_rejects_bad_knobs() {
        let mut s = FleetSpec::named("uniform").unwrap();
        s.dropout_p = 1.0;
        assert!(s.validate().is_err());
        let mut s = FleetSpec::named("uniform").unwrap();
        s.straggler_slowdown = 0.5;
        assert!(s.validate().is_err());
        let mut s = FleetSpec::named("uniform").unwrap();
        s.min_quorum = 0;
        assert!(s.validate().is_err());
        // A quorum only means something under a deadline.
        let mut s = FleetSpec::named("uniform").unwrap();
        s.min_quorum = 2;
        assert!(s.validate().is_err());
        s.deadline_s = Some(10.0);
        assert!(s.validate().is_ok());
        let mut s = FleetSpec::named("uniform").unwrap();
        s.deadline_s = Some(-1.0);
        assert!(s.validate().is_err());
        let mut s = FleetSpec::named("uniform").unwrap();
        s.devices = RateDist::Uniform { min: 10.0, max: 1.0 };
        assert!(s.validate().is_err());
    }

    #[test]
    fn sampling_is_deterministic_per_seed_and_in_range() {
        let spec = FleetSpec::named("uniform").unwrap();
        let a = Fleet::from_spec(spec.clone(), 20, 17);
        let b = Fleet::from_spec(spec.clone(), 20, 17);
        let c = Fleet::from_spec(spec, 20, 18);
        let rates = |f: &Fleet| (0..20).map(|i| f.device_flops_per_s(i)).collect::<Vec<_>>();
        assert_eq!(rates(&a), rates(&b));
        assert_ne!(rates(&a), rates(&c));
        assert!(rates(&a).iter().all(|&r| (5e9..=5e10).contains(&r)));
    }

    #[test]
    fn two_tier_sampling_hits_both_tiers() {
        let spec = FleetSpec::named("two-tier").unwrap();
        let fleet = Fleet::from_spec(spec, 100, 3);
        let slow = (0..100).filter(|&i| fleet.device_flops_per_s(i) < 1e10).count();
        assert!(slow > 5 && slow < 60, "slow tier count {slow}");
    }

    #[test]
    fn pareto_rates_never_exceed_scale() {
        let spec = FleetSpec::named("pareto").unwrap();
        let fleet = Fleet::from_spec(spec, 200, 5);
        for i in 0..200 {
            let r = fleet.device_flops_per_s(i);
            assert!(r > 0.0 && r <= 5e10 + 1e-6, "client {i} rate {r}");
        }
    }

    #[test]
    fn homogeneous_round_is_always_on_and_compute_free() {
        let net = NetworkModel { rate_bytes_per_s: 1000.0, sharing_clients: 4 };
        let mut fleet = Fleet::homogeneous(net);
        let mut clock = fleet.begin_round(&[3, 9]);
        assert!(clock.online(0) && clock.online(1));
        assert_eq!(clock.client(1), 9);
        assert!((clock.charge_transfer(0, 500) - 2.0).abs() < 1e-12);
        assert_eq!(clock.charge_compute(0, u64::MAX), 0.0);
        assert_eq!(fleet.now_s(), 0.0);
    }

    #[test]
    fn dropout_trace_is_seeded_and_diurnal_modulates() {
        let mut spec = FleetSpec::named("dropout").unwrap();
        spec.dropout_p = 0.5;
        let selected: Vec<usize> = (0..30).collect();
        let offline = |fleet: &mut Fleet| {
            let clock = fleet.begin_round(&selected);
            (0..30).filter(|&s| !clock.online(s)).count()
        };
        let mut a = Fleet::from_spec(spec.clone(), 30, 7);
        let mut b = Fleet::from_spec(spec.clone(), 30, 7);
        let (na, nb) = (offline(&mut a), offline(&mut b));
        assert_eq!(na, nb, "same seed, same trace");
        assert!(na > 4 && na < 26, "roughly half offline, got {na}");

        // Diurnal trough at half period: availability collapses to min.
        let d = Diurnal { period_s: 100.0, min_availability: 0.2 };
        assert!((d.availability(0.0) - 1.0).abs() < 1e-9);
        assert!((d.availability(50.0) - 0.2).abs() < 1e-9);
    }
}
