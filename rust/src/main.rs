//! `sfprompt` CLI — the L3 coordinator entry point.
//!
//! Subcommands:
//!   inspect    --config <name>             show a manifest's inventory
//!   train      --config <name> [...]       run SFPrompt (or a baseline)
//!              --spec run.json --json      headless: RunSpec in, RunReport out
//!   experiment --id <fig2|fig4|...|all>    regenerate a paper table/figure
//!   analyze                                closed-form cost model sweep

use anyhow::{Context, Result};

use sfprompt::analysis::{fl_crossover_w_bytes, sweep, CostParams};
use sfprompt::backend::BackendChoice;
use sfprompt::compress::Scheme;
use sfprompt::experiments::{self, ExpOptions};
use sfprompt::federation::{
    drive, Method, NullObserver, ProgressPrinter, RunReport, RunSpec,
};
use sfprompt::partition::Partition;
use sfprompt::sim::FleetSpec;
use sfprompt::transport::WireFormat;
use sfprompt::util::cli::Args;
use sfprompt::util::csv::CsvWriter;

const USAGE: &str = "\
sfprompt — split federated prompt fine-tuning coordinator

USAGE:
  sfprompt inspect    --config <name> [--backend native|pjrt]
  sfprompt train      [--spec FILE.json] [--json]
                      [--config <name>] [--backend native|pjrt]
                      [--method sfprompt|fl|sfl_ff|sfl_linear]
                      [--rounds N] [--clients N] [--per-round K] [--epochs U]
                      [--lr F] [--retain F] [--dataset cifar10|cifar100|svhn|flower102]
                      [--noniid] [--alpha F] [--seed N] [--samples-per-client N]
                      [--no-local-loss] [--wire f32|f16|int8]
                      [--compress none|topk:R|randk:R|quant:B] [--net-rate BYTES_PER_S]
                      [--fleet <name|FILE.json>] [--deadline-s F] [--quorum N]
  sfprompt experiment --id <table1|table2|table3|fig2|fig4|fig5|fig6|fig7|wire|fleet|compress|all>
                      [--out DIR] [--rounds N] [--scale F] [--seed N]
  sfprompt analyze    [--out DIR]

`--backend native` (the default) runs every stage on the pure-Rust ViT
kernel engine with an in-memory manifest — no artifacts, no Python.
`--backend pjrt` executes the AOT-lowered artifacts under `artifacts/`
(requires the `pjrt` feature; see docs/BACKENDS.md).

`train --spec FILE.json` reads a RunSpec (CLI flags are ignored); `--json`
suppresses progress output and prints a RunReport JSON document with
per-message-kind measured bytes. See docs/API.md.

`--fleet` simulates a heterogeneous fleet — a preset (uniform | two-tier |
pareto | dropout | diurnal | ideal) or a FleetSpec JSON file — and
`--deadline-s`/`--quorum` enable deadline-based rounds (the server
aggregates whoever finishes in time, doubling the deadline until the
quorum is met). See docs/FLEET.md.

`--compress` sparsifies or quantizes Phase-3 uploads (top-k / rand-k keep
a fraction R of coordinates with per-client error feedback; quant:B is
B-bit stochastic quantization); measured raw-vs-wire bytes and the
compression ratio land in the report. See docs/COMPRESS.md.
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        eprintln!("{USAGE}");
        std::process::exit(2);
    }
    if let Err(e) = dispatch(Args::parse(argv)) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: Args) -> Result<()> {
    match args.positional.first().map(|s| s.as_str()) {
        Some("inspect") => inspect(&args),
        Some("train") => train(&args),
        Some("experiment") => experiment(&args),
        Some("analyze") => analyze(&args),
        _ => {
            eprintln!("{USAGE}");
            std::process::exit(2);
        }
    }
}

fn inspect(args: &Args) -> Result<()> {
    let config = args.get_or("config", "tiny");
    let choice = BackendChoice::parse(args.get_or("backend", "native"))?;
    // inspect is read-only: resolve the manifest without constructing an
    // executing backend, so analytic-only profiles (vit_base_sim, …)
    // inspect fine on the native path.
    let man = match choice {
        BackendChoice::Native => sfprompt::backend::native::synth_manifest(config)?,
        BackendChoice::Pjrt => sfprompt::runtime::Manifest::load(
            &sfprompt::artifacts_root().join(config),
        )?,
    };
    println!("config {} [{} backend]:", man.config.name, choice.label());
    println!(
        "  image {}x{}x{}  patch {}  dim {}  heads {}  depth {}+{}+{}  classes {}  prompt {}  batch {}",
        man.config.image_size, man.config.image_size, man.config.channels,
        man.config.patch_size, man.config.dim, man.config.heads,
        man.config.depth_head, man.config.depth_body, man.config.depth_tail,
        man.config.num_classes, man.config.prompt_len, man.config.batch
    );
    println!("  params: {:?} (backbone total {}, α={:.3}, τ={:.3})",
             man.cost.params, man.cost.params_total_backbone, man.cost.alpha, man.cost.tau);
    println!("  stages ({}):", man.stages.len());
    for (name, st) in &man.stages {
        println!("    {:<24} [{}] in={} out={}", name, st.family,
                 st.inputs.len(), st.outputs.len());
    }
    Ok(())
}

/// Build a RunSpec from CLI flags (the non-`--spec` path). Flags override
/// the [`RunSpec::new`] defaults field by field — the defaults themselves
/// live in one place.
fn spec_from_args(args: &Args) -> Result<RunSpec> {
    let method = Method::parse(args.get_or("method", "sfprompt"))?;
    let mut spec = RunSpec::new(
        args.get_or("config", "small"),
        args.get_or("dataset", "cifar10"),
        method,
    );
    spec.backend = BackendChoice::parse(args.get_or("backend", "native"))?;
    let f = &mut spec.fed;
    f.num_clients = args.get_parse("clients", f.num_clients);
    f.clients_per_round = args.get_parse("per-round", f.clients_per_round);
    f.local_epochs = args.get_parse("epochs", f.local_epochs);
    f.rounds = args.get_parse("rounds", f.rounds);
    f.lr = args.get_parse("lr", f.lr);
    f.retain_fraction = args.get_parse("retain", f.retain_fraction);
    f.local_loss_update = !args.has_flag("no-local-loss");
    if args.has_flag("noniid") {
        f.partition = Partition::Dirichlet { alpha: args.get_parse("alpha", 0.1f64) };
    }
    f.seed = args.get_parse("seed", f.seed);
    f.eval_limit = Some(args.get_parse("eval-limit", 160usize));
    f.eval_every = args.get_parse("eval-every", f.eval_every);
    f.wire = WireFormat::parse(args.get_or("wire", "f32"))?;
    f.compress = Scheme::parse(args.get_or("compress", "none"))?;
    spec.samples_per_client = args.get_parse("samples-per-client", spec.samples_per_client);
    if let Some(rate) = args.get("net-rate") {
        spec.net_rate_bytes_per_s = Some(
            rate.parse()
                .map_err(|_| anyhow::anyhow!("--net-rate must be a number, got {rate:?}"))?,
        );
    }
    if let Some(fleet) = args.get("fleet") {
        spec.fleet = Some(FleetSpec::resolve(fleet)?);
    }
    if let Some(deadline) = args.get("deadline-s") {
        let deadline_s: f64 = deadline
            .parse()
            .map_err(|_| anyhow::anyhow!("--deadline-s must be a number, got {deadline:?}"))?;
        // A deadline without a fleet runs the compute-free `ideal` preset,
        // honouring a `--net-rate` override as its shared pool.
        let fleet = match spec.fleet.take() {
            Some(f) => f,
            None => {
                let mut f = FleetSpec::named("ideal")?;
                if let Some(rate) = spec.net_rate_bytes_per_s {
                    f.shared_pool_bytes_per_s = Some(rate);
                }
                f
            }
        };
        spec.fleet = Some(FleetSpec { deadline_s: Some(deadline_s), ..fleet });
    }
    if let Some(quorum) = args.get("quorum") {
        let quorum: usize = quorum
            .parse()
            .map_err(|_| anyhow::anyhow!("--quorum must be a positive integer, got {quorum:?}"))?;
        let fleet = spec
            .fleet
            .take()
            .ok_or_else(|| anyhow::anyhow!("--quorum needs --fleet or --deadline-s"))?;
        spec.fleet = Some(FleetSpec { min_quorum: quorum, ..fleet });
    }
    Ok(spec)
}

/// Closed-form cost-model sweep (analysis::sweep) over model scale and
/// local epochs; prints the grid and writes results/analyze_sweep.csv.
fn analyze(args: &Args) -> Result<()> {
    let out_dir = std::path::PathBuf::from(args.get_or("out", "results"));
    std::fs::create_dir_all(&out_dir)?;
    let base = CostParams::default();
    let rows = sweep(&base);

    let mut w = CsvWriter::create(
        out_dir.join("analyze_sweep.csv"),
        &[
            "w_mb", "local_epochs", "fl_comm_mb", "sfl_comm_mb", "sfprompt_comm_mb",
            "fl_latency_s", "sfl_latency_s", "sfprompt_latency_s",
        ],
    )?;
    println!("closed-form sweep (K={}, |D|={}, γ={}, R={:.1} MB/s):",
             base.clients, base.d_samples, base.gamma, base.rate / 1e6);
    println!(
        "{:>10} {:>4} {:>12} {:>12} {:>12} {:>10} {:>10} {:>10}",
        "|W| MB", "U", "FL MB", "SFL MB", "SFP MB", "FL s", "SFL s", "SFP s"
    );
    for r in &rows {
        println!(
            "{:>10.1} {:>4} {:>12.1} {:>12.1} {:>12.1} {:>10.1} {:>10.1} {:>10.1}",
            r.w_mb, r.local_epochs, r.fl.comm_bytes / 1e6, r.sfl.comm_bytes / 1e6,
            r.sfprompt.comm_bytes / 1e6, r.fl.latency_s, r.sfl.latency_s,
            r.sfprompt.latency_s
        );
        w.row(&[
            format!("{:.2}", r.w_mb),
            format!("{}", r.local_epochs),
            format!("{:.3}", r.fl.comm_bytes / 1e6),
            format!("{:.3}", r.sfl.comm_bytes / 1e6),
            format!("{:.3}", r.sfprompt.comm_bytes / 1e6),
            format!("{:.3}", r.fl.latency_s),
            format!("{:.3}", r.sfl.latency_s),
            format!("{:.3}", r.sfprompt.latency_s),
        ])?;
    }
    println!(
        "\nFL-advantage crossover: SFPrompt wins on comm when |W| > {:.1} MB \
         (2qγ|D|/(α+τ)); wrote {}",
        fl_crossover_w_bytes(&base) / 1e6,
        out_dir.join("analyze_sweep.csv").display()
    );
    Ok(())
}

fn train(args: &Args) -> Result<()> {
    let spec = match args.get("spec") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading run spec {path}"))?;
            RunSpec::parse(&text).with_context(|| format!("parsing run spec {path}"))?
        }
        None => spec_from_args(args)?,
    };
    let json_out = args.has_flag("json");

    let backend = spec.open_backend(&sfprompt::artifacts_root())?;
    let (train_ds, eval_ds) = spec.datasets(&backend.manifest().config)?;
    let mut run = spec.builder().build(backend.as_ref(), &train_ds, Some(&eval_ds))?;

    if !json_out {
        let fed = run.fed();
        println!(
            "train: config={} backend={} dataset={} method={} rounds={} clients={}x{} U={} \
             γ_retain={} wire={} compress={}",
            spec.config, backend.name(), spec.dataset, spec.method.label(), fed.rounds,
            fed.clients_per_round, fed.num_clients, fed.local_epochs,
            fed.retain_fraction, fed.wire.label(), fed.compress.label()
        );
    }
    let hist = if json_out {
        drive(run.as_mut(), &mut NullObserver)?
    } else {
        drive(run.as_mut(), &mut ProgressPrinter::new())?
    };

    if json_out {
        let report = RunReport::new(&spec, run.setup_bytes(), hist);
        println!("{}", report.to_json());
        return Ok(());
    }
    println!(
        "done: final acc {:.4}, total comm {:.2} MB ({:.2} MB/round), messages {}, \
         sim wall {:.1}s",
        hist.final_accuracy(),
        hist.total_comm.mb(),
        hist.comm_mb_per_round(),
        hist.total_comm.messages,
        hist.sim_wall_s()
    );
    if hist.dropped_clients() > 0 {
        println!("  fleet: {} client-round contributions dropped", hist.dropped_clients());
    }
    for (kind, bytes) in &hist.total_comm.by_kind {
        println!("  {kind:<22} {:.3} MB", *bytes as f64 / 1e6);
    }
    if hist.total_comm.raw_total() > hist.total_comm.total() {
        println!(
            "  compression: {:.3} MB dense-f32 -> {:.3} MB wire (ratio {:.4})",
            hist.total_comm.raw_total() as f64 / 1e6,
            hist.total_comm.mb(),
            hist.total_comm.compression_ratio()
        );
    }
    if args.has_flag("stats") {
        println!("\nper-stage execution stats (desc by total exec time):");
        println!("{:<26} {:>8} {:>12} {:>12} {:>10}", "stage", "calls", "exec total s",
                 "mean ms", "convert s");
        for (name, s) in backend.execution_stats() {
            println!(
                "{:<26} {:>8} {:>12.2} {:>12.2} {:>10.3}",
                name, s.calls, s.exec_s, s.exec_s * 1e3 / s.calls as f64, s.convert_s
            );
        }
    }
    Ok(())
}

fn experiment(args: &Args) -> Result<()> {
    let id = args.get_or("id", "all").to_string();
    let opts = ExpOptions {
        out_dir: args.get_or("out", "results").into(),
        rounds: args.get_parse("rounds", 10usize),
        local_epochs: args.get_parse("epochs", 10usize),
        samples_per_client_x: args.get_parse("scale", 1.0f64),
        seed: args.get_parse("seed", 17u64),
    };
    std::fs::create_dir_all(&opts.out_dir)?;
    experiments::run(&id, &sfprompt::artifacts_root(), &opts)
}
