//! `sfprompt` CLI — the L3 coordinator entry point.
//!
//! Subcommands:
//!   inspect    --config <name>             show a manifest's inventory
//!   train      --config <name> [...]       run SFPrompt (or a baseline)
//!              --spec run.json --json      headless: RunSpec in, RunReport out
//!              --trace t.jsonl --metrics m.json   record telemetry
//!   serve      --listen ADDR --processes N run the coordinator over TCP
//!   client     --connect HOST:PORT         run a networked client process
//!   report     --trace t.jsonl             pretty-print a saved trace
//!   experiment --id <fig2|fig4|...|all>    regenerate a paper table/figure
//!   analyze                                closed-form cost model sweep

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use sfprompt::analysis::{fl_crossover_w_bytes, sweep, CostParams};
use sfprompt::backend::BackendChoice;
use sfprompt::compress::Scheme;
use sfprompt::experiments::{self, ExpOptions};
use sfprompt::federation::{
    drive, Method, NullObserver, ProgressPrinter, RunReport, RunSpec, Tee,
};
use sfprompt::net;
use sfprompt::partition::Partition;
use sfprompt::sim::FleetSpec;
use sfprompt::telemetry::{self, SpanRecord, Telemetry, TelemetryObserver};
use sfprompt::transport::WireFormat;
use sfprompt::util::cli::Args;
use sfprompt::util::csv::CsvWriter;
use sfprompt::util::json::Json;

const USAGE: &str = "\
sfprompt — split federated prompt fine-tuning coordinator

USAGE:
  sfprompt inspect    --config <name> [--backend native|pjrt]
  sfprompt train      [--spec FILE.json] [--json]
                      [--config <name>] [--backend native|native_f16|pjrt]
                      [--method sfprompt|fl|sfl_ff|sfl_linear]
                      [--rounds N] [--clients N] [--per-round K] [--epochs U]
                      [--lr F] [--retain F] [--dataset cifar10|cifar100|svhn|flower102]
                      [--noniid] [--alpha F] [--seed N] [--samples-per-client N]
                      [--no-local-loss] [--wire f32|f16|int8]
                      [--compress none|topk:R|randk:R|quant:B] [--net-rate BYTES_PER_S]
                      [--fleet <name|FILE.json>] [--deadline-s F] [--quorum N]
                      [--threads N] [--trace FILE.jsonl] [--metrics FILE.json]
  sfprompt serve      --listen HOST:PORT --processes N
                      [--spec FILE.json | train flags] [--run-id ID]
                      [--events FILE.jsonl] [--io-timeout-s F] [--quiet] [--json]
                      [--trace FILE.jsonl] [--metrics FILE.json]
  sfprompt client     --connect HOST:PORT [--name STR] [--run-id ID]
                      [--retries N] [--backoff-ms N] [--io-timeout-s F] [--quiet]
  sfprompt report     --trace FILE.jsonl [--chrome OUT.json] [--top N]
  sfprompt experiment --id <table1|table2|table3|fig2|fig4|fig5|fig6|fig7|wire|fleet|compress|all>
                      [--out DIR] [--rounds N] [--scale F] [--seed N]
  sfprompt analyze    [--out DIR]

`--backend native` (the default) runs every stage on the pure-Rust ViT
kernel engine with an in-memory manifest — no artifacts, no Python.
`--backend native_f16` additionally stores frozen head/body weights as
f16 (half the resident bytes, decode-on-use). `--backend pjrt` executes
the AOT-lowered artifacts under `artifacts/` (requires the `pjrt` cargo
feature; see docs/BACKENDS.md).

`--threads N` sets the native kernel worker count (default: all cores).
Any value produces a byte-identical RunReport — the kernels partition
rows deterministically and never split a reduction (docs/PERF.md).

`train --spec FILE.json` reads a RunSpec (CLI flags are ignored); `--json`
suppresses progress output and prints a RunReport JSON document with
per-message-kind measured bytes. See docs/API.md.

`--fleet` simulates a heterogeneous fleet — a preset (uniform | two-tier |
pareto | dropout | diurnal | ideal) or a FleetSpec JSON file — and
`--deadline-s`/`--quorum` enable deadline-based rounds (the server
aggregates whoever finishes in time, doubling the deadline until the
quorum is met). See docs/FLEET.md.

`--compress` sparsifies or quantizes Phase-3 uploads (top-k / rand-k keep
a fraction R of coordinates with per-client error feedback; quant:B is
B-bit stochastic quantization); measured raw-vs-wire bytes and the
compression ratio land in the report. See docs/COMPRESS.md.

`--trace` records hierarchical spans (run -> round -> phase -> client ->
stage) to JSON Lines; `--metrics` writes counters/gauges/latency
histograms (stage times, achieved GFLOP/s, bytes per message kind) as
JSON. `report` pretty-prints a saved trace and `--chrome` re-exports it
as Chrome trace-event JSON for Perfetto. See docs/TELEMETRY.md.

`serve` runs the same federation over real TCP: it listens, admits
--processes client processes (`sfprompt client --connect ...`), and drives
the rounds with client compute happening remotely — the RunReport is
byte-identical to the in-process `train` run of the same spec (modulo
wall-clock). `--events` streams round events as JSON lines (observers can
also subscribe over a socket). See docs/NET.md.
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        eprintln!("{USAGE}");
        std::process::exit(2);
    }
    if let Err(e) = dispatch(Args::parse(argv)) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: Args) -> Result<()> {
    match args.positional.first().map(|s| s.as_str()) {
        Some("inspect") => inspect(&args),
        Some("train") => train(&args),
        Some("serve") => serve_cmd(&args),
        Some("client") => client_cmd(&args),
        Some("report") => report(&args),
        Some("experiment") => experiment(&args),
        Some("analyze") => analyze(&args),
        _ => {
            eprintln!("{USAGE}");
            std::process::exit(2);
        }
    }
}

fn inspect(args: &Args) -> Result<()> {
    let config = args.get_or("config", "tiny");
    let choice = BackendChoice::parse(args.get_or("backend", "native"))?;
    // inspect is read-only: resolve the manifest without constructing an
    // executing backend, so analytic-only profiles (vit_base_sim, …)
    // inspect fine on the native path.
    let man = match choice {
        BackendChoice::Native => sfprompt::backend::native::synth_manifest(config)?,
        BackendChoice::Pjrt => sfprompt::runtime::Manifest::load(
            &sfprompt::artifacts_root().join(config),
        )?,
    };
    println!("config {} [{} backend]:", man.config.name, choice.label());
    println!(
        "  image {}x{}x{}  patch {}  dim {}  heads {}  depth {}+{}+{}  classes {}  prompt {}  batch {}",
        man.config.image_size, man.config.image_size, man.config.channels,
        man.config.patch_size, man.config.dim, man.config.heads,
        man.config.depth_head, man.config.depth_body, man.config.depth_tail,
        man.config.num_classes, man.config.prompt_len, man.config.batch
    );
    println!("  params: {:?} (backbone total {}, α={:.3}, τ={:.3})",
             man.cost.params, man.cost.params_total_backbone, man.cost.alpha, man.cost.tau);
    println!("  stages ({}):", man.stages.len());
    for (name, st) in &man.stages {
        println!("    {:<24} [{}] in={} out={}", name, st.family,
                 st.inputs.len(), st.outputs.len());
    }
    Ok(())
}

/// Build a RunSpec from CLI flags (the non-`--spec` path). Flags override
/// the [`RunSpec::new`] defaults field by field — the defaults themselves
/// live in one place.
fn spec_from_args(args: &Args) -> Result<RunSpec> {
    let method = Method::parse(args.get_or("method", "sfprompt"))?;
    let mut spec = RunSpec::new(
        args.get_or("config", "small"),
        args.get_or("dataset", "cifar10"),
        method,
    );
    spec.backend = BackendChoice::parse(args.get_or("backend", "native"))?;
    let f = &mut spec.fed;
    f.num_clients = args.get_parse("clients", f.num_clients);
    f.clients_per_round = args.get_parse("per-round", f.clients_per_round);
    f.local_epochs = args.get_parse("epochs", f.local_epochs);
    f.rounds = args.get_parse("rounds", f.rounds);
    f.lr = args.get_parse("lr", f.lr);
    f.retain_fraction = args.get_parse("retain", f.retain_fraction);
    f.local_loss_update = !args.has_flag("no-local-loss");
    if args.has_flag("noniid") {
        f.partition = Partition::Dirichlet { alpha: args.get_parse("alpha", 0.1f64) };
    }
    f.seed = args.get_parse("seed", f.seed);
    f.eval_limit = Some(args.get_parse("eval-limit", 160usize));
    f.eval_every = args.get_parse("eval-every", f.eval_every);
    f.wire = WireFormat::parse(args.get_or("wire", "f32"))?;
    f.compress = Scheme::parse(args.get_or("compress", "none"))?;
    spec.samples_per_client = args.get_parse("samples-per-client", spec.samples_per_client);
    if let Some(rate) = args.get("net-rate") {
        spec.net_rate_bytes_per_s = Some(
            rate.parse()
                .map_err(|_| anyhow::anyhow!("--net-rate must be a number, got {rate:?}"))?,
        );
    }
    if let Some(threads) = args.get("threads") {
        let n: usize = threads
            .parse()
            .map_err(|_| anyhow::anyhow!("--threads must be a positive integer, got {threads:?}"))?;
        if n == 0 {
            bail!("--threads must be at least 1 (omit the flag for auto)");
        }
        spec.threads = Some(n);
    }
    if let Some(fleet) = args.get("fleet") {
        spec.fleet = Some(FleetSpec::resolve(fleet)?);
    }
    if let Some(deadline) = args.get("deadline-s") {
        let deadline_s: f64 = deadline
            .parse()
            .map_err(|_| anyhow::anyhow!("--deadline-s must be a number, got {deadline:?}"))?;
        // A deadline without a fleet runs the compute-free `ideal` preset,
        // honouring a `--net-rate` override as its shared pool.
        let fleet = match spec.fleet.take() {
            Some(f) => f,
            None => {
                let mut f = FleetSpec::named("ideal")?;
                if let Some(rate) = spec.net_rate_bytes_per_s {
                    f.shared_pool_bytes_per_s = Some(rate);
                }
                f
            }
        };
        spec.fleet = Some(FleetSpec { deadline_s: Some(deadline_s), ..fleet });
    }
    if let Some(quorum) = args.get("quorum") {
        let quorum: usize = quorum
            .parse()
            .map_err(|_| anyhow::anyhow!("--quorum must be a positive integer, got {quorum:?}"))?;
        let fleet = spec
            .fleet
            .take()
            .ok_or_else(|| anyhow::anyhow!("--quorum needs --fleet or --deadline-s"))?;
        spec.fleet = Some(FleetSpec { min_quorum: quorum, ..fleet });
    }
    Ok(spec)
}

/// Closed-form cost-model sweep (analysis::sweep) over model scale and
/// local epochs; prints the grid and writes results/analyze_sweep.csv.
fn analyze(args: &Args) -> Result<()> {
    let out_dir = std::path::PathBuf::from(args.get_or("out", "results"));
    std::fs::create_dir_all(&out_dir)?;
    let base = CostParams::default();
    let rows = sweep(&base);

    let mut w = CsvWriter::create(
        out_dir.join("analyze_sweep.csv"),
        &[
            "w_mb", "local_epochs", "fl_comm_mb", "sfl_comm_mb", "sfprompt_comm_mb",
            "fl_latency_s", "sfl_latency_s", "sfprompt_latency_s",
        ],
    )?;
    println!("closed-form sweep (K={}, |D|={}, γ={}, R={:.1} MB/s):",
             base.clients, base.d_samples, base.gamma, base.rate / 1e6);
    println!(
        "{:>10} {:>4} {:>12} {:>12} {:>12} {:>10} {:>10} {:>10}",
        "|W| MB", "U", "FL MB", "SFL MB", "SFP MB", "FL s", "SFL s", "SFP s"
    );
    for r in &rows {
        println!(
            "{:>10.1} {:>4} {:>12.1} {:>12.1} {:>12.1} {:>10.1} {:>10.1} {:>10.1}",
            r.w_mb, r.local_epochs, r.fl.comm_bytes / 1e6, r.sfl.comm_bytes / 1e6,
            r.sfprompt.comm_bytes / 1e6, r.fl.latency_s, r.sfl.latency_s,
            r.sfprompt.latency_s
        );
        w.row(&[
            format!("{:.2}", r.w_mb),
            format!("{}", r.local_epochs),
            format!("{:.3}", r.fl.comm_bytes / 1e6),
            format!("{:.3}", r.sfl.comm_bytes / 1e6),
            format!("{:.3}", r.sfprompt.comm_bytes / 1e6),
            format!("{:.3}", r.fl.latency_s),
            format!("{:.3}", r.sfl.latency_s),
            format!("{:.3}", r.sfprompt.latency_s),
        ])?;
    }
    println!(
        "\nFL-advantage crossover: SFPrompt wins on comm when |W| > {:.1} MB \
         (2qγ|D|/(α+τ)); wrote {}",
        fl_crossover_w_bytes(&base) / 1e6,
        out_dir.join("analyze_sweep.csv").display()
    );
    Ok(())
}

/// The run spec a `train`/`serve` invocation describes: `--spec FILE.json`
/// wins; otherwise the CLI flags are assembled into one.
fn resolve_spec(args: &Args) -> Result<RunSpec> {
    match args.get("spec") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading run spec {path}"))?;
            RunSpec::parse(&text).with_context(|| format!("parsing run spec {path}"))
        }
        None => spec_from_args(args),
    }
}

fn train(args: &Args) -> Result<()> {
    let spec = resolve_spec(args)?;
    let json_out = args.has_flag("json");

    let backend = spec.open_backend(&sfprompt::artifacts_root())?;
    let (train_ds, eval_ds) = spec.datasets(&backend.manifest().config)?;
    let mut run = spec.builder().build(backend.as_ref(), &train_ds, Some(&eval_ds))?;

    if !json_out {
        let fed = run.fed();
        println!(
            "train: config={} backend={} dataset={} method={} rounds={} clients={}x{} U={} \
             γ_retain={} wire={} compress={}",
            spec.config, backend.name(), spec.dataset, spec.method.label(), fed.rounds,
            fed.clients_per_round, fed.num_clients, fed.local_epochs,
            fed.retain_fraction, fed.wire.label(), fed.compress.label()
        );
    }
    // --trace / --metrics install a process-global telemetry sink for the
    // duration of the drive; a TelemetryObserver maps driver events onto
    // run/round spans while the pipeline hooks fill in the rest.
    let trace_path = args.get("trace");
    let metrics_path = args.get("metrics");
    let telemetry = (trace_path.is_some() || metrics_path.is_some()).then(|| {
        let t = Arc::new(Telemetry::new());
        telemetry::install(t.clone());
        t
    });

    let driven = match &telemetry {
        Some(t) => {
            let mut tobs = TelemetryObserver::new(t.clone());
            if json_out {
                drive(run.as_mut(), &mut tobs)
            } else {
                let mut printer = ProgressPrinter::new();
                drive(run.as_mut(), &mut Tee(&mut printer, &mut tobs))
            }
        }
        None if json_out => drive(run.as_mut(), &mut NullObserver),
        None => drive(run.as_mut(), &mut ProgressPrinter::new()),
    };
    if telemetry.is_some() {
        telemetry::uninstall();
    }
    let hist = driven?;

    if let Some(t) = &telemetry {
        let dangling = t.tracer.finish();
        if dangling > 0 {
            eprintln!("warning: {dangling} telemetry spans never closed (flagged open:true)");
        }
        if let Some(path) = trace_path {
            std::fs::write(path, t.tracer.to_jsonl())
                .with_context(|| format!("writing trace {path}"))?;
        }
        if let Some(path) = metrics_path {
            std::fs::write(path, format!("{}\n", t.metrics.to_json()))
                .with_context(|| format!("writing metrics {path}"))?;
        }
    }

    if json_out {
        let mut report = RunReport::new(&spec, run.setup_bytes(), hist);
        if let Some(t) = &telemetry {
            report = report.with_telemetry(t.metrics.to_json());
        }
        println!("{}", report.to_json());
        return Ok(());
    }
    println!(
        "done: final acc {:.4}, total comm {:.2} MB ({:.2} MB/round), messages {}, \
         sim wall {:.1}s",
        hist.final_accuracy(),
        hist.total_comm.mb(),
        hist.comm_mb_per_round(),
        hist.total_comm.messages,
        hist.sim_wall_s()
    );
    if hist.dropped_clients() > 0 {
        println!("  fleet: {} client-round contributions dropped", hist.dropped_clients());
    }
    for (kind, bytes) in &hist.total_comm.by_kind {
        println!("  {kind:<22} {:.3} MB", *bytes as f64 / 1e6);
    }
    if hist.total_comm.raw_total() > hist.total_comm.total() {
        println!(
            "  compression: {:.3} MB dense-f32 -> {:.3} MB wire (ratio {:.4})",
            hist.total_comm.raw_total() as f64 / 1e6,
            hist.total_comm.mb(),
            hist.total_comm.compression_ratio()
        );
    }
    if let Some(t) = &telemetry {
        print_hottest_stages(&t.metrics.hottest_stages(5));
        if let Some(path) = trace_path {
            println!("  trace   -> {path}");
        }
        if let Some(path) = metrics_path {
            println!("  metrics -> {path}");
        }
    }
    if args.has_flag("stats") {
        println!("\nper-stage execution stats (desc by total exec time):");
        println!("{:<26} {:>8} {:>12} {:>12} {:>10}", "stage", "calls", "exec total s",
                 "mean ms", "convert s");
        for (name, s) in backend.execution_stats() {
            println!(
                "{:<26} {:>8} {:>12.2} {:>12.2} {:>10.3}",
                name, s.calls, s.exec_s, s.exec_s * 1e3 / s.calls as f64, s.convert_s
            );
        }
    }
    Ok(())
}

/// `serve --listen HOST:PORT --processes N`: run the coordinator as a TCP
/// server. Same spec resolution and telemetry plumbing as `train`; the
/// client compute happens in remote `sfprompt client` processes.
fn serve_cmd(args: &Args) -> Result<()> {
    let spec = resolve_spec(args)?;
    let json_out = args.has_flag("json");

    let listen = args.get_or("listen", "127.0.0.1:7070");
    let listener = std::net::TcpListener::bind(listen)
        .with_context(|| format!("binding {listen}"))?;

    let events = match args.get("events") {
        Some(path) => net::EventSink::new(Some(
            std::fs::File::create(path)
                .with_context(|| format!("creating event stream file {path}"))?,
        )),
        None => net::EventSink::new(None),
    };
    // Default run id is derived from the seed so server and clients agree
    // without coordination (clients can also skip the check with "").
    let default_run_id = format!("run-{}", spec.fed.seed);
    let opts = net::ServeOptions {
        processes: args.get_parse("processes", 1usize),
        run_id: args.get_or("run-id", &default_run_id).to_string(),
        io_timeout: std::time::Duration::from_secs_f64(
            args.get_parse("io-timeout-s", 60.0f64),
        ),
        events,
        quiet: args.has_flag("quiet") || json_out,
    };
    if !json_out && !opts.quiet {
        let f = &spec.fed;
        println!(
            "serve: listening on {} for {} client process(es); config={} dataset={} \
             method={} rounds={} clients={}x{} run-id={}",
            listener.local_addr().map_or_else(|_| listen.to_string(), |a| a.to_string()),
            opts.processes, spec.config, spec.dataset, spec.method.label(), f.rounds,
            f.clients_per_round, f.num_clients, opts.run_id
        );
    }

    let trace_path = args.get("trace");
    let metrics_path = args.get("metrics");
    let telemetry = (trace_path.is_some() || metrics_path.is_some()).then(|| {
        let t = Arc::new(Telemetry::new());
        telemetry::install(t.clone());
        t
    });

    let root = sfprompt::artifacts_root();
    let served = match &telemetry {
        Some(t) => {
            let mut tobs = TelemetryObserver::new(t.clone());
            if json_out {
                net::serve(listener, &spec, &root, &opts, &mut tobs)
            } else {
                let mut printer = ProgressPrinter::new();
                net::serve(listener, &spec, &root, &opts, &mut Tee(&mut printer, &mut tobs))
            }
        }
        None if json_out => net::serve(listener, &spec, &root, &opts, &mut NullObserver),
        None => net::serve(listener, &spec, &root, &opts, &mut ProgressPrinter::new()),
    };
    if telemetry.is_some() {
        telemetry::uninstall();
    }
    let report = served?;

    if let Some(t) = &telemetry {
        let dangling = t.tracer.finish();
        if dangling > 0 {
            eprintln!("warning: {dangling} telemetry spans never closed (flagged open:true)");
        }
        if let Some(path) = trace_path {
            std::fs::write(path, t.tracer.to_jsonl())
                .with_context(|| format!("writing trace {path}"))?;
        }
        if let Some(path) = metrics_path {
            std::fs::write(path, format!("{}\n", t.metrics.to_json()))
                .with_context(|| format!("writing metrics {path}"))?;
        }
    }

    if json_out {
        let report = match &telemetry {
            Some(t) => report.with_telemetry(t.metrics.to_json()),
            None => report,
        };
        println!("{}", report.to_json());
        return Ok(());
    }
    let hist = &report.history;
    println!(
        "done: final acc {:.4}, total comm {:.2} MB ({:.2} MB/round), messages {}, \
         sim wall {:.1}s",
        hist.final_accuracy(),
        hist.total_comm.mb(),
        hist.comm_mb_per_round(),
        hist.total_comm.messages,
        hist.sim_wall_s()
    );
    for (kind, bytes) in &hist.total_comm.by_kind {
        println!("  {kind:<22} {:.3} MB", *bytes as f64 / 1e6);
    }
    Ok(())
}

/// `client --connect HOST:PORT`: run one networked client process. The
/// server's `Welcome` carries the full RunSpec, so no other run flags are
/// needed — everything else here tunes the connection itself.
fn client_cmd(args: &Args) -> Result<()> {
    let addr = args
        .get("connect")
        .ok_or_else(|| anyhow!("client needs --connect HOST:PORT"))?;
    let opts = net::ClientOptions {
        connect: net::ConnectOptions {
            retries: args.get_parse("retries", 30u32),
            backoff: std::time::Duration::from_millis(args.get_parse("backoff-ms", 100u64)),
            io_timeout: std::time::Duration::from_secs_f64(
                args.get_parse("io-timeout-s", 60.0f64),
            ),
        },
        name: args.get_or("name", "client").to_string(),
        run_id: args.get_or("run-id", "").to_string(),
        quiet: args.has_flag("quiet"),
    };
    let summary = net::run_client(addr, &sfprompt::artifacts_root(), &opts)?;
    println!(
        "client: process {}/{} served clients {:?} for {} client-round(s); run complete",
        summary.process + 1,
        summary.processes,
        summary.client_ids,
        summary.rounds_participated
    );
    Ok(())
}

/// Console rendering of `MetricsRegistry::hottest_stages` (a JSON array).
fn print_hottest_stages(rows: &Json) {
    let Some(rows) = rows.as_arr() else { return };
    if rows.is_empty() {
        return;
    }
    println!("\nhottest backend stages (by total time):");
    println!(
        "{:<26} {:>8} {:>10} {:>9} {:>9} {:>9} {:>10}",
        "stage", "calls", "total s", "mean ms", "p50 ms", "p95 ms", "GFLOP/s"
    );
    for r in rows {
        let f = |k: &str| r.get(k).and_then(Json::as_f64).unwrap_or(0.0);
        let gflops = r
            .get("achieved_gflops")
            .and_then(Json::as_f64)
            .map_or("-".to_string(), |g| format!("{g:.2}"));
        println!(
            "{:<26} {:>8} {:>10.3} {:>9.3} {:>9.3} {:>9.3} {:>10}",
            r.get("stage").and_then(Json::as_str).unwrap_or("?"),
            f("calls") as u64,
            f("total_s"),
            f("mean_ms"),
            f("p50_ms"),
            f("p95_ms"),
            gflops
        );
    }
}

/// Rebuild `SpanRecord`s from a trace JSONL file (the inverse of
/// `Tracer::to_jsonl`). Returns the records in file order.
fn parse_trace(text: &str) -> Result<Vec<SpanRecord>> {
    // Span categories are &'static str on the in-memory record; a one-shot
    // CLI parse interns each distinct cat once.
    let mut interned: BTreeMap<String, &'static str> = BTreeMap::new();
    let mut intern = |s: &str| -> &'static str {
        *interned
            .entry(s.to_string())
            .or_insert_with(|| Box::leak(s.to_string().into_boxed_str()))
    };
    let mut out = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let v = Json::parse(line)
            .map_err(|e| anyhow!("trace line {}: {e}", lineno + 1))?;
        match v.get("ev").and_then(Json::as_str) {
            Some("meta") => {
                let fmt = v.get("format").and_then(Json::as_str);
                if fmt != Some("sfprompt-trace") {
                    bail!("not an sfprompt trace (format {fmt:?})");
                }
            }
            Some("span") => {
                let num = |k: &str| -> Result<f64> {
                    v.get(k)
                        .and_then(Json::as_f64)
                        .ok_or_else(|| anyhow!("trace line {}: missing {k:?}", lineno + 1))
                };
                let attrs = match v.get("attrs").and_then(Json::as_obj) {
                    Some(obj) => obj
                        .iter()
                        .filter_map(|(k, j)| j.as_f64().map(|n| (k.clone(), n)))
                        .collect(),
                    None => Vec::new(),
                };
                out.push(SpanRecord {
                    id: num("id")? as u64,
                    parent: v.get("parent").and_then(Json::as_f64).map(|p| p as u64),
                    cat: intern(v.get("cat").and_then(Json::as_str).unwrap_or("?")),
                    name: v
                        .get("name")
                        .and_then(Json::as_str)
                        .unwrap_or("?")
                        .to_string(),
                    tid: num("tid")? as u64,
                    start_s: num("t0_s")?,
                    end_s: num("t1_s")?,
                    sim_s: v.get("sim_s").and_then(Json::as_f64),
                    attrs,
                    open: v.get("open").and_then(Json::as_bool) == Some(true),
                });
            }
            other => bail!("trace line {}: unknown event {other:?}", lineno + 1),
        }
    }
    Ok(out)
}

/// `report --trace FILE.jsonl [--chrome OUT.json] [--top N]`: pretty-print
/// a saved trace — span census, round timeline, hottest stage spans — and
/// optionally re-export it as Chrome trace-event JSON.
fn report(args: &Args) -> Result<()> {
    let path = args
        .get("trace")
        .ok_or_else(|| anyhow!("report needs --trace FILE.jsonl"))?;
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading trace {path}"))?;
    let records = parse_trace(&text)?;
    if records.is_empty() {
        bail!("trace {path} contains no spans");
    }
    let top_n: usize = args.get_parse("top", 10usize);

    // Census per category.
    let mut by_cat: BTreeMap<&str, (usize, f64)> = BTreeMap::new();
    for r in &records {
        let e = by_cat.entry(r.cat).or_insert((0, 0.0));
        e.0 += 1;
        e.1 += r.end_s - r.start_s;
    }
    println!("trace {path}: {} spans", records.len());
    for (cat, (n, total)) in &by_cat {
        println!("  {cat:<8} {n:>6} spans  {total:>9.3}s total");
    }

    // Round timeline (run/round spans in start order).
    let rounds: Vec<&SpanRecord> = records.iter().filter(|r| r.cat == "round").collect();
    if !rounds.is_empty() {
        println!("\nround timeline:");
        for r in &rounds {
            let children = records.iter().filter(|c| c.parent == Some(r.id)).count();
            let sim = r.sim_s.map_or(String::new(), |s| format!("  sim_clock={s:.1}s"));
            println!(
                "  {:<10} wall {:>8.3}s..{:>8.3}s ({:>7.3}s)  {} child spans{}",
                r.name,
                r.start_s,
                r.end_s,
                r.end_s - r.start_s,
                children,
                sim
            );
        }
    }

    // Hottest stage spans, aggregated by name.
    let mut stages: BTreeMap<&str, (usize, f64)> = BTreeMap::new();
    for r in records.iter().filter(|r| r.cat == "stage") {
        let e = stages.entry(r.name.as_str()).or_insert((0, 0.0));
        e.0 += 1;
        e.1 += r.end_s - r.start_s;
    }
    if !stages.is_empty() {
        let mut rows: Vec<(&str, usize, f64)> =
            stages.into_iter().map(|(k, (n, s))| (k, n, s)).collect();
        rows.sort_by(|a, b| b.2.total_cmp(&a.2));
        println!("\nhottest stages (top {top_n} by total time):");
        println!("{:<26} {:>8} {:>10} {:>9}", "stage", "calls", "total s", "mean ms");
        for (name, calls, total) in rows.iter().take(top_n) {
            println!(
                "{:<26} {:>8} {:>10.3} {:>9.3}",
                name,
                calls,
                total,
                total * 1e3 / *calls as f64
            );
        }
    }

    let open: Vec<&SpanRecord> = records.iter().filter(|r| r.open).collect();
    if !open.is_empty() {
        println!("\nWARNING: {} spans never closed (instrumentation bug):", open.len());
        for r in &open {
            println!("  #{} {}/{} on tid {}", r.id, r.cat, r.name, r.tid);
        }
    }

    if let Some(out) = args.get("chrome") {
        let doc = sfprompt::telemetry::chrome_trace_from_records(&records);
        std::fs::write(out, format!("{doc}\n"))
            .with_context(|| format!("writing chrome trace {out}"))?;
        println!("\nchrome trace -> {out} (open in Perfetto or chrome://tracing)");
    }
    Ok(())
}

fn experiment(args: &Args) -> Result<()> {
    let id = args.get_or("id", "all").to_string();
    let opts = ExpOptions {
        out_dir: args.get_or("out", "results").into(),
        rounds: args.get_parse("rounds", 10usize),
        local_epochs: args.get_parse("epochs", 10usize),
        samples_per_client_x: args.get_parse("scale", 1.0f64),
        seed: args.get_parse("seed", 17u64),
    };
    std::fs::create_dir_all(&opts.out_dir)?;
    experiments::run(&id, &sfprompt::artifacts_root(), &opts)
}
