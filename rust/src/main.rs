//! `sfprompt` CLI — the L3 coordinator entry point.
//!
//! Subcommands:
//!   inspect    --config <name>             show a manifest's inventory
//!   train      --config <name> [...]       run SFPrompt (or a baseline)
//!              --spec run.json --json      headless: RunSpec in, RunReport out
//!              --trace t.jsonl --metrics m.json   record telemetry
//!   serve      --listen ADDR --processes N run the coordinator over TCP
//!   client     --connect HOST:PORT         run a networked client process
//!   top        --connect HOST:PORT         live status console for a server
//!   diff       A.json B.json               compare reports/bench snapshots
//!   trace      merge A.jsonl B.jsonl ...   stitch per-process traces into one tree
//!   report     --trace t.jsonl             pretty-print a saved trace
//!              --health e.jsonl            anomaly timeline from event/flight logs
//!              --waterfall report.json     per-round communication-cost waterfall
//!   experiment --id <fig2|fig4|...|all>    regenerate a paper table/figure
//!   analyze                                closed-form cost model sweep

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use sfprompt::analysis::{fl_crossover_w_bytes, sweep, CostParams};
use sfprompt::backend::BackendChoice;
use sfprompt::compress::Scheme;
use sfprompt::experiments::{self, ExpOptions};
use sfprompt::federation::{
    drive, Method, NullObserver, ProgressPrinter, RunReport, RunSpec, Tee,
};
use sfprompt::net;
use sfprompt::partition::Partition;
use sfprompt::sim::FleetSpec;
use sfprompt::telemetry::{
    self, merge_traces, ProcessTrace, SpanRecord, Telemetry, TelemetryObserver,
};
use sfprompt::transport::WireFormat;
use sfprompt::util::cli::Args;
use sfprompt::util::csv::CsvWriter;
use sfprompt::util::json::Json;

const USAGE: &str = "\
sfprompt — split federated prompt fine-tuning coordinator

USAGE:
  sfprompt inspect    --config <name> [--backend native|pjrt]
  sfprompt train      [--spec FILE.json] [--json]
                      [--config <name>] [--backend native|native_f16|pjrt]
                      [--method sfprompt|fl|sfl_ff|sfl_linear]
                      [--rounds N] [--clients N] [--per-round K] [--epochs U]
                      [--lr F] [--retain F] [--dataset cifar10|cifar100|svhn|flower102]
                      [--noniid] [--alpha F] [--seed N] [--samples-per-client N]
                      [--no-local-loss] [--wire f32|f16|int8]
                      [--compress none|topk:R|randk:R|quant:B] [--net-rate BYTES_PER_S]
                      [--fleet <name|FILE.json>] [--deadline-s F] [--quorum N]
                      [--threads N] [--trace FILE.jsonl] [--metrics FILE.json]
  sfprompt serve      --listen HOST:PORT --processes N
                      [--spec FILE.json | train flags] [--run-id ID]
                      [--events FILE.jsonl] [--io-timeout-s F] [--quiet] [--json]
                      [--trace FILE.jsonl] [--metrics FILE.json]
                      [--prom HOST:PORT] [--postmortem FILE.jsonl]
  sfprompt client     --connect HOST:PORT [--name STR] [--run-id ID]
                      [--retries N] [--backoff-ms N] [--io-timeout-s F] [--quiet]
                      [--trace FILE.jsonl]
  sfprompt top        --connect HOST:PORT [--interval-s F] [--once] [--json]
  sfprompt diff       A.json B.json [--tolerance F] [--print-canon]
  sfprompt trace      merge A.jsonl B.jsonl [...] [--out MERGED.jsonl]
                      [--chrome OUT.json]
  sfprompt report     --trace FILE.jsonl [--chrome OUT.json] [--top N]
  sfprompt report     --health FILE.jsonl
  sfprompt report     --waterfall REPORT.json [--round N]
  sfprompt experiment --id <table1|table2|table3|fig2|fig4|fig5|fig6|fig7|wire|fleet|compress|all>
                      [--out DIR] [--rounds N] [--scale F] [--seed N]
  sfprompt analyze    [--out DIR]

`--backend native` (the default) runs every stage on the pure-Rust ViT
kernel engine with an in-memory manifest — no artifacts, no Python.
`--backend native_f16` additionally stores frozen head/body weights as
f16 (half the resident bytes, decode-on-use). `--backend pjrt` executes
the AOT-lowered artifacts under `artifacts/` (requires the `pjrt` cargo
feature; see docs/BACKENDS.md).

`--threads N` sets the native kernel worker count (default: all cores).
Any value produces a byte-identical RunReport — the kernels partition
rows deterministically and never split a reduction (docs/PERF.md).

`train --spec FILE.json` reads a RunSpec (CLI flags are ignored); `--json`
suppresses progress output and prints a RunReport JSON document with
per-message-kind measured bytes. See docs/API.md.

`--fleet` simulates a heterogeneous fleet — a preset (uniform | two-tier |
pareto | dropout | diurnal | ideal) or a FleetSpec JSON file — and
`--deadline-s`/`--quorum` enable deadline-based rounds (the server
aggregates whoever finishes in time, doubling the deadline until the
quorum is met). See docs/FLEET.md.

`--compress` sparsifies or quantizes Phase-3 uploads (top-k / rand-k keep
a fraction R of coordinates with per-client error feedback; quant:B is
B-bit stochastic quantization); measured raw-vs-wire bytes and the
compression ratio land in the report. See docs/COMPRESS.md.

`--trace` records hierarchical spans (run -> round -> phase -> client ->
stage) to JSON Lines; `--metrics` writes counters/gauges/latency
histograms (stage times, achieved GFLOP/s, bytes per message kind) as
JSON. `report` pretty-prints a saved trace and `--chrome` re-exports it
as Chrome trace-event JSON for Perfetto. See docs/TELEMETRY.md.

`serve` runs the same federation over real TCP: it listens, admits
--processes client processes (`sfprompt client --connect ...`), and drives
the rounds with client compute happening remotely — the RunReport is
byte-identical to the in-process `train` run of the same spec (modulo
wall-clock). `--events` streams round events as JSON lines (observers can
also subscribe over a socket). See docs/NET.md.

Live operations (docs/OPS.md): a serving coordinator answers one-shot
`status` probes at any point in the run — `top --connect HOST:PORT` polls
them into a console table (`--once` prints a single snapshot, `--json`
the raw body). `serve --prom ADDR` exposes the live metrics registry as
Prometheus text at GET /metrics; `serve --postmortem FILE` dumps the
always-on flight recorder (a bounded ring of recent health/span entries)
the moment the run fails or an anomaly fires, and `report --health FILE`
renders the anomaly timeline from an event stream or flight dump.

Distributed tracing (docs/TRACING.md): when `serve --trace` and
`client --trace` both record, the handshake propagates one trace id,
per-process span-id blocks, and an NTP-style clock-offset estimate, so
client-side spans carry their coordinator-side parents. `trace merge`
stitches the per-process JSONL files into one causally-consistent tree
(re-based onto the coordinator timeline; impossible nestings are flagged
`skew`, never fabricated). Traced runs seal a per-(round, client, phase)
communication-cost ledger into the report's `"ledger"` block — a pure
re-attribution of the measured ByteMeter bytes — which
`report --waterfall` renders as a per-round cost waterfall.

`diff A B` compares two RunReports or BENCH_*.json snapshots field by
field after canonicalizing wall-clock-dependent blocks away (wall_s,
health, telemetry, ledger, machine, note); perf-pattern fields (mean_ms,
p95_ms, ...) compare within --tolerance (default 0.10 relative). Exit
codes: 0 match, 1 regression/divergence, 2 usage or unreadable input.
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        eprintln!("{USAGE}");
        std::process::exit(2);
    }
    if let Err(e) = dispatch(Args::parse(argv)) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: Args) -> Result<()> {
    match args.positional.first().map(|s| s.as_str()) {
        Some("inspect") => inspect(&args),
        Some("train") => train(&args),
        Some("serve") => serve_cmd(&args),
        Some("client") => client_cmd(&args),
        Some("top") => top_cmd(&args),
        Some("diff") => diff_cmd(&args),
        Some("trace") => trace_cmd(&args),
        Some("report") => report(&args),
        Some("experiment") => experiment(&args),
        Some("analyze") => analyze(&args),
        _ => {
            eprintln!("{USAGE}");
            std::process::exit(2);
        }
    }
}

fn inspect(args: &Args) -> Result<()> {
    let config = args.get_or("config", "tiny");
    let choice = BackendChoice::parse(args.get_or("backend", "native"))?;
    // inspect is read-only: resolve the manifest without constructing an
    // executing backend, so analytic-only profiles (vit_base_sim, …)
    // inspect fine on the native path.
    let man = match choice {
        BackendChoice::Native => sfprompt::backend::native::synth_manifest(config)?,
        BackendChoice::Pjrt => sfprompt::runtime::Manifest::load(
            &sfprompt::artifacts_root().join(config),
        )?,
    };
    println!("config {} [{} backend]:", man.config.name, choice.label());
    println!(
        "  image {}x{}x{}  patch {}  dim {}  heads {}  depth {}+{}+{}  classes {}  prompt {}  batch {}",
        man.config.image_size, man.config.image_size, man.config.channels,
        man.config.patch_size, man.config.dim, man.config.heads,
        man.config.depth_head, man.config.depth_body, man.config.depth_tail,
        man.config.num_classes, man.config.prompt_len, man.config.batch
    );
    println!("  params: {:?} (backbone total {}, α={:.3}, τ={:.3})",
             man.cost.params, man.cost.params_total_backbone, man.cost.alpha, man.cost.tau);
    println!("  stages ({}):", man.stages.len());
    for (name, st) in &man.stages {
        println!("    {:<24} [{}] in={} out={}", name, st.family,
                 st.inputs.len(), st.outputs.len());
    }
    Ok(())
}

/// Build a RunSpec from CLI flags (the non-`--spec` path). Flags override
/// the [`RunSpec::new`] defaults field by field — the defaults themselves
/// live in one place.
fn spec_from_args(args: &Args) -> Result<RunSpec> {
    let method = Method::parse(args.get_or("method", "sfprompt"))?;
    let mut spec = RunSpec::new(
        args.get_or("config", "small"),
        args.get_or("dataset", "cifar10"),
        method,
    );
    spec.backend = BackendChoice::parse(args.get_or("backend", "native"))?;
    let f = &mut spec.fed;
    f.num_clients = args.get_parse("clients", f.num_clients);
    f.clients_per_round = args.get_parse("per-round", f.clients_per_round);
    f.local_epochs = args.get_parse("epochs", f.local_epochs);
    f.rounds = args.get_parse("rounds", f.rounds);
    f.lr = args.get_parse("lr", f.lr);
    f.retain_fraction = args.get_parse("retain", f.retain_fraction);
    f.local_loss_update = !args.has_flag("no-local-loss");
    if args.has_flag("noniid") {
        f.partition = Partition::Dirichlet { alpha: args.get_parse("alpha", 0.1f64) };
    }
    f.seed = args.get_parse("seed", f.seed);
    f.eval_limit = Some(args.get_parse("eval-limit", 160usize));
    f.eval_every = args.get_parse("eval-every", f.eval_every);
    f.wire = WireFormat::parse(args.get_or("wire", "f32"))?;
    f.compress = Scheme::parse(args.get_or("compress", "none"))?;
    spec.samples_per_client = args.get_parse("samples-per-client", spec.samples_per_client);
    if let Some(rate) = args.get("net-rate") {
        spec.net_rate_bytes_per_s = Some(
            rate.parse()
                .map_err(|_| anyhow::anyhow!("--net-rate must be a number, got {rate:?}"))?,
        );
    }
    if let Some(threads) = args.get("threads") {
        let n: usize = threads
            .parse()
            .map_err(|_| anyhow::anyhow!("--threads must be a positive integer, got {threads:?}"))?;
        if n == 0 {
            bail!("--threads must be at least 1 (omit the flag for auto)");
        }
        spec.threads = Some(n);
    }
    if let Some(fleet) = args.get("fleet") {
        spec.fleet = Some(FleetSpec::resolve(fleet)?);
    }
    if let Some(deadline) = args.get("deadline-s") {
        let deadline_s: f64 = deadline
            .parse()
            .map_err(|_| anyhow::anyhow!("--deadline-s must be a number, got {deadline:?}"))?;
        // A deadline without a fleet runs the compute-free `ideal` preset,
        // honouring a `--net-rate` override as its shared pool.
        let fleet = match spec.fleet.take() {
            Some(f) => f,
            None => {
                let mut f = FleetSpec::named("ideal")?;
                if let Some(rate) = spec.net_rate_bytes_per_s {
                    f.shared_pool_bytes_per_s = Some(rate);
                }
                f
            }
        };
        spec.fleet = Some(FleetSpec { deadline_s: Some(deadline_s), ..fleet });
    }
    if let Some(quorum) = args.get("quorum") {
        let quorum: usize = quorum
            .parse()
            .map_err(|_| anyhow::anyhow!("--quorum must be a positive integer, got {quorum:?}"))?;
        let fleet = spec
            .fleet
            .take()
            .ok_or_else(|| anyhow::anyhow!("--quorum needs --fleet or --deadline-s"))?;
        spec.fleet = Some(FleetSpec { min_quorum: quorum, ..fleet });
    }
    Ok(spec)
}

/// Closed-form cost-model sweep (analysis::sweep) over model scale and
/// local epochs; prints the grid and writes results/analyze_sweep.csv.
fn analyze(args: &Args) -> Result<()> {
    let out_dir = std::path::PathBuf::from(args.get_or("out", "results"));
    std::fs::create_dir_all(&out_dir)?;
    let base = CostParams::default();
    let rows = sweep(&base);

    let mut w = CsvWriter::create(
        out_dir.join("analyze_sweep.csv"),
        &[
            "w_mb", "local_epochs", "fl_comm_mb", "sfl_comm_mb", "sfprompt_comm_mb",
            "fl_latency_s", "sfl_latency_s", "sfprompt_latency_s",
        ],
    )?;
    println!("closed-form sweep (K={}, |D|={}, γ={}, R={:.1} MB/s):",
             base.clients, base.d_samples, base.gamma, base.rate / 1e6);
    println!(
        "{:>10} {:>4} {:>12} {:>12} {:>12} {:>10} {:>10} {:>10}",
        "|W| MB", "U", "FL MB", "SFL MB", "SFP MB", "FL s", "SFL s", "SFP s"
    );
    for r in &rows {
        println!(
            "{:>10.1} {:>4} {:>12.1} {:>12.1} {:>12.1} {:>10.1} {:>10.1} {:>10.1}",
            r.w_mb, r.local_epochs, r.fl.comm_bytes / 1e6, r.sfl.comm_bytes / 1e6,
            r.sfprompt.comm_bytes / 1e6, r.fl.latency_s, r.sfl.latency_s,
            r.sfprompt.latency_s
        );
        w.row(&[
            format!("{:.2}", r.w_mb),
            format!("{}", r.local_epochs),
            format!("{:.3}", r.fl.comm_bytes / 1e6),
            format!("{:.3}", r.sfl.comm_bytes / 1e6),
            format!("{:.3}", r.sfprompt.comm_bytes / 1e6),
            format!("{:.3}", r.fl.latency_s),
            format!("{:.3}", r.sfl.latency_s),
            format!("{:.3}", r.sfprompt.latency_s),
        ])?;
    }
    println!(
        "\nFL-advantage crossover: SFPrompt wins on comm when |W| > {:.1} MB \
         (2qγ|D|/(α+τ)); wrote {}",
        fl_crossover_w_bytes(&base) / 1e6,
        out_dir.join("analyze_sweep.csv").display()
    );
    Ok(())
}

/// The run spec a `train`/`serve` invocation describes: `--spec FILE.json`
/// wins; otherwise the CLI flags are assembled into one.
fn resolve_spec(args: &Args) -> Result<RunSpec> {
    match args.get("spec") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading run spec {path}"))?;
            RunSpec::parse(&text).with_context(|| format!("parsing run spec {path}"))
        }
        None => spec_from_args(args),
    }
}

fn train(args: &Args) -> Result<()> {
    let spec = resolve_spec(args)?;
    let json_out = args.has_flag("json");

    let backend = spec.open_backend(&sfprompt::artifacts_root())?;
    let (train_ds, eval_ds) = spec.datasets(&backend.manifest().config)?;
    let mut run = spec.builder().build(backend.as_ref(), &train_ds, Some(&eval_ds))?;

    if !json_out {
        let fed = run.fed();
        println!(
            "train: config={} backend={} dataset={} method={} rounds={} clients={}x{} U={} \
             γ_retain={} wire={} compress={}",
            spec.config, backend.name(), spec.dataset, spec.method.label(), fed.rounds,
            fed.clients_per_round, fed.num_clients, fed.local_epochs,
            fed.retain_fraction, fed.wire.label(), fed.compress.label()
        );
    }
    // --trace / --metrics install a process-global telemetry sink for the
    // duration of the drive; a TelemetryObserver maps driver events onto
    // run/round spans while the pipeline hooks fill in the rest.
    let trace_path = args.get("trace");
    let metrics_path = args.get("metrics");
    let telemetry = (trace_path.is_some() || metrics_path.is_some()).then(|| {
        let t = Arc::new(Telemetry::new());
        telemetry::install(t.clone());
        t
    });

    let driven = match &telemetry {
        Some(t) => {
            let mut tobs = TelemetryObserver::new(t.clone());
            if json_out {
                drive(run.as_mut(), &mut tobs)
            } else {
                let mut printer = ProgressPrinter::new();
                drive(run.as_mut(), &mut Tee(&mut printer, &mut tobs))
            }
        }
        None if json_out => drive(run.as_mut(), &mut NullObserver),
        None => drive(run.as_mut(), &mut ProgressPrinter::new()),
    };
    if telemetry.is_some() {
        telemetry::uninstall();
    }
    let hist = driven?;

    if let Some(t) = &telemetry {
        let dangling = t.tracer.finish();
        if dangling > 0 {
            eprintln!("warning: {dangling} telemetry spans never closed (flagged open:true)");
        }
        if let Some(path) = trace_path {
            std::fs::write(path, t.tracer.to_jsonl())
                .with_context(|| format!("writing trace {path}"))?;
        }
        if let Some(path) = metrics_path {
            std::fs::write(path, format!("{}\n", t.metrics.to_json()))
                .with_context(|| format!("writing metrics {path}"))?;
        }
    }

    if json_out {
        let mut report = RunReport::new(&spec, run.setup_bytes(), hist);
        if let Some(t) = &telemetry {
            report = report.with_telemetry(t.metrics.to_json());
        }
        // The engines keep a per-(round, client, phase) ledger in lock-step
        // with the ByteMeter; reconcile (any divergence is an engine bug)
        // and seal it into the report for `report --waterfall`.
        if let Some(ledger) = run.ledger().filter(|l| !l.is_empty()) {
            ledger
                .reconcile(&report.history.total_comm)
                .map_err(|e| anyhow!("ledger/meter divergence: {e}"))?;
            report = report.with_ledger(ledger.to_json());
        }
        println!("{}", report.to_json());
        return Ok(());
    }
    println!(
        "done: final acc {:.4}, total comm {:.2} MB ({:.2} MB/round), messages {}, \
         sim wall {:.1}s",
        hist.final_accuracy(),
        hist.total_comm.mb(),
        hist.comm_mb_per_round(),
        hist.total_comm.messages,
        hist.sim_wall_s()
    );
    if hist.dropped_clients() > 0 {
        println!("  fleet: {} client-round contributions dropped", hist.dropped_clients());
    }
    for (kind, bytes) in &hist.total_comm.by_kind {
        println!("  {kind:<22} {:.3} MB", *bytes as f64 / 1e6);
    }
    if hist.total_comm.raw_total() > hist.total_comm.total() {
        println!(
            "  compression: {:.3} MB dense-f32 -> {:.3} MB wire (ratio {:.4})",
            hist.total_comm.raw_total() as f64 / 1e6,
            hist.total_comm.mb(),
            hist.total_comm.compression_ratio()
        );
    }
    if let Some(t) = &telemetry {
        print_hottest_stages(&t.metrics.hottest_stages(5));
        if let Some(path) = trace_path {
            println!("  trace   -> {path}");
        }
        if let Some(path) = metrics_path {
            println!("  metrics -> {path}");
        }
    }
    if args.has_flag("stats") {
        println!("\nper-stage execution stats (desc by total exec time):");
        println!("{:<26} {:>8} {:>12} {:>12} {:>10}", "stage", "calls", "exec total s",
                 "mean ms", "convert s");
        for (name, s) in backend.execution_stats() {
            println!(
                "{:<26} {:>8} {:>12.2} {:>12.2} {:>10.3}",
                name, s.calls, s.exec_s, s.exec_s * 1e3 / s.calls as f64, s.convert_s
            );
        }
    }
    Ok(())
}

/// `serve --listen HOST:PORT --processes N`: run the coordinator as a TCP
/// server. Same spec resolution and telemetry plumbing as `train`; the
/// client compute happens in remote `sfprompt client` processes.
fn serve_cmd(args: &Args) -> Result<()> {
    let spec = resolve_spec(args)?;
    let json_out = args.has_flag("json");

    let listen = args.get_or("listen", "127.0.0.1:7070");
    let listener = std::net::TcpListener::bind(listen)
        .with_context(|| format!("binding {listen}"))?;

    let events = match args.get("events") {
        Some(path) => net::EventSink::new(Some(
            std::fs::File::create(path)
                .with_context(|| format!("creating event stream file {path}"))?,
        )),
        None => net::EventSink::new(None),
    };
    // Default run id is derived from the seed so server and clients agree
    // without coordination (clients can also skip the check with "").
    let default_run_id = format!("run-{}", spec.fed.seed);
    let opts = net::ServeOptions {
        processes: args.get_parse("processes", 1usize),
        run_id: args.get_or("run-id", &default_run_id).to_string(),
        io_timeout: std::time::Duration::from_secs_f64(
            args.get_parse("io-timeout-s", 60.0f64),
        ),
        events,
        postmortem: args.get("postmortem").map(std::path::PathBuf::from),
        quiet: args.has_flag("quiet") || json_out,
        ..net::ServeOptions::default()
    };
    if !json_out && !opts.quiet {
        let f = &spec.fed;
        println!(
            "serve: listening on {} for {} client process(es); config={} dataset={} \
             method={} rounds={} clients={}x{} run-id={}",
            listener.local_addr().map_or_else(|_| listen.to_string(), |a| a.to_string()),
            opts.processes, spec.config, spec.dataset, spec.method.label(), f.rounds,
            f.clients_per_round, f.num_clients, opts.run_id
        );
    }

    // --prom forces telemetry on: a scraper needs a live registry even
    // when no trace/metrics file was requested.
    let trace_path = args.get("trace");
    let metrics_path = args.get("metrics");
    let prom_addr = args.get("prom");
    let telemetry = (trace_path.is_some() || metrics_path.is_some() || prom_addr.is_some())
        .then(|| {
            let t = Arc::new(Telemetry::new());
            t.attach_flight(opts.flight.clone());
            telemetry::install(t.clone());
            t
        });
    let _prom = match (prom_addr, &telemetry) {
        (Some(addr), Some(t)) => {
            let handle = net::spawn_metrics_server(addr, t.clone())?;
            if !opts.quiet {
                eprintln!("serve: Prometheus exporter on http://{}/metrics", handle.addr());
            }
            Some(handle)
        }
        _ => None,
    };

    let root = sfprompt::artifacts_root();
    let served = match &telemetry {
        Some(t) => {
            let mut tobs = TelemetryObserver::new(t.clone());
            if json_out {
                net::serve(listener, &spec, &root, &opts, &mut tobs)
            } else {
                let mut printer = ProgressPrinter::new();
                net::serve(listener, &spec, &root, &opts, &mut Tee(&mut printer, &mut tobs))
            }
        }
        None if json_out => net::serve(listener, &spec, &root, &opts, &mut NullObserver),
        None => net::serve(listener, &spec, &root, &opts, &mut ProgressPrinter::new()),
    };
    if telemetry.is_some() {
        telemetry::uninstall();
    }
    let report = served?;

    if let Some(t) = &telemetry {
        let dangling = t.tracer.finish();
        if dangling > 0 {
            eprintln!("warning: {dangling} telemetry spans never closed (flagged open:true)");
        }
        if let Some(path) = trace_path {
            std::fs::write(path, t.tracer.to_jsonl())
                .with_context(|| format!("writing trace {path}"))?;
        }
        if let Some(path) = metrics_path {
            std::fs::write(path, format!("{}\n", t.metrics.to_json()))
                .with_context(|| format!("writing metrics {path}"))?;
        }
    }

    if json_out {
        let report = match &telemetry {
            Some(t) => report.with_telemetry(t.metrics.to_json()),
            None => report,
        };
        println!("{}", report.to_json());
        return Ok(());
    }
    let hist = &report.history;
    println!(
        "done: final acc {:.4}, total comm {:.2} MB ({:.2} MB/round), messages {}, \
         sim wall {:.1}s",
        hist.final_accuracy(),
        hist.total_comm.mb(),
        hist.comm_mb_per_round(),
        hist.total_comm.messages,
        hist.sim_wall_s()
    );
    for (kind, bytes) in &hist.total_comm.by_kind {
        println!("  {kind:<22} {:.3} MB", *bytes as f64 / 1e6);
    }
    let anomalies = opts.health.anomalies();
    if !anomalies.is_empty() {
        println!(
            "  health: {} anomaly(ies) fired during the run — see the report's \
             \"health\" block or `report --health`",
            anomalies.len()
        );
    }
    Ok(())
}

/// `client --connect HOST:PORT`: run one networked client process. The
/// server's `Welcome` carries the full RunSpec, so no other run flags are
/// needed — everything else here tunes the connection itself. `--trace`
/// records this process's spans; joined with a traced server's welcome
/// context they parent under the coordinator's rounds (`trace merge`).
fn client_cmd(args: &Args) -> Result<()> {
    let addr = args
        .get("connect")
        .ok_or_else(|| anyhow!("client needs --connect HOST:PORT"))?;
    let opts = net::ClientOptions {
        connect: net::ConnectOptions {
            retries: args.get_parse("retries", 30u32),
            backoff: std::time::Duration::from_millis(args.get_parse("backoff-ms", 100u64)),
            io_timeout: std::time::Duration::from_secs_f64(
                args.get_parse("io-timeout-s", 60.0f64),
            ),
        },
        name: args.get_or("name", "client").to_string(),
        run_id: args.get_or("run-id", "").to_string(),
        quiet: args.has_flag("quiet"),
    };
    let trace_path = args.get("trace");
    let telemetry = trace_path.is_some().then(|| {
        let t = Arc::new(Telemetry::new());
        telemetry::install(t.clone());
        t
    });
    let run = net::run_client(addr, &sfprompt::artifacts_root(), &opts);
    if telemetry.is_some() {
        telemetry::uninstall();
    }
    if let (Some(t), Some(path)) = (&telemetry, trace_path) {
        let dangling = t.tracer.finish();
        if dangling > 0 {
            eprintln!("warning: {dangling} telemetry spans never closed (flagged open:true)");
        }
        // Written even when the run failed — a partial client trace is
        // exactly what a post-mortem merge wants.
        std::fs::write(path, t.tracer.to_jsonl())
            .with_context(|| format!("writing trace {path}"))?;
    }
    let summary = run?;
    println!(
        "client: process {}/{} served clients {:?} for {} client-round(s); run complete",
        summary.process + 1,
        summary.processes,
        summary.client_ids,
        summary.rounds_participated
    );
    if let Some(path) = trace_path {
        println!("client: trace -> {path}");
    }
    Ok(())
}

/// One `status` request/reply against a serving coordinator. The control
/// plane answers one snapshot per connection, so every poll reconnects.
fn fetch_status(addr: &str) -> Result<Json> {
    let connect = net::ConnectOptions {
        retries: 3,
        backoff: std::time::Duration::from_millis(100),
        io_timeout: std::time::Duration::from_secs(10),
    };
    let mut link = net::TcpLink::connect(addr, &connect)?;
    link.send_control(&net::Control::Status { proto: net::NET_PROTO_VERSION })?;
    match link.recv_msg(false)? {
        Some(net::NetMsg::Control(net::Control::StatusReply { body }, _)) => Ok(body),
        Some(net::NetMsg::Control(net::Control::Reject { reason }, _)) => {
            bail!("server rejected the status probe: {reason}")
        }
        Some(net::NetMsg::Control(other, _)) => {
            bail!("unexpected control {:?} in reply to status", other.kind())
        }
        Some(net::NetMsg::Frame(frame, _)) => {
            bail!("unexpected {:?} frame in reply to status", frame.kind)
        }
        None => bail!("server closed the connection without a status reply"),
    }
}

/// Render one status snapshot as a console block (`docs/OPS.md` schema).
fn render_status(body: &Json) {
    let s = |k: &str| body.get(k).and_then(Json::as_str).unwrap_or("?").to_string();
    let f = |k: &str| body.get(k).and_then(Json::as_f64).unwrap_or(0.0);
    println!(
        "run {} [{}]  method={} config={}  round {}/{}  clients={} procs={}  \
         uptime {:.1}s  sim clock {:.1}s",
        s("run_id"), s("state"), s("method"), s("config"),
        f("round") as u64, f("rounds_total") as u64,
        f("num_clients") as u64, f("processes") as u64,
        f("uptime_s"), f("sim_s")
    );
    if let Some(bytes) = body.get("bytes") {
        let bf = |k: &str| bytes.get(k).and_then(Json::as_f64).unwrap_or(0.0);
        println!(
            "bytes: {:.3} MB wire / {:.3} MB raw (ratio {:.4})   flight entries {}",
            bf("total") / 1e6, bf("raw") / 1e6, bf("compression_ratio"),
            f("flight_recorded") as u64
        );
    }
    if let Some(last) = body.get("last") {
        let lf = |k: &str| {
            last.get(k)
                .and_then(Json::as_f64)
                .map_or("-".to_string(), |v| format!("{v:.4}"))
        };
        println!(
            "last round: local_loss={} split_loss={} accuracy={}",
            lf("local_loss"), lf("split_loss"), lf("accuracy")
        );
    }
    if let Some(clients) = body.get("clients").and_then(Json::as_obj) {
        if !clients.is_empty() {
            println!(
                "{:>6} {:>6} {:>7} {:>10} {:>12} {:>10} {:>9} {:>9}",
                "client", "done", "dropped", "ewma_s", "bytes_rx", "in_flight",
                "seen_s", "straggler"
            );
            for (id, c) in clients {
                let cf = |k: &str| c.get(k).and_then(Json::as_f64).unwrap_or(0.0);
                let age = cf("last_seen_age_s");
                println!(
                    "{id:>6} {:>6} {:>7} {:>10.3} {:>12} {:>10} {:>9} {:>9}",
                    cf("rounds_done") as u64,
                    cf("rounds_dropped") as u64,
                    cf("latency_ewma_s"),
                    cf("bytes_rx") as u64,
                    cf("in_flight_bytes") as u64,
                    if age < 0.0 { "never".to_string() } else { format!("{age:.1}") },
                    if c.get("straggler").and_then(Json::as_bool) == Some(true) {
                        "YES"
                    } else {
                        "-"
                    }
                );
            }
        }
    }
    if let Some(anomalies) = body.get("anomalies").and_then(Json::as_arr) {
        for a in anomalies {
            println!(
                "ANOMALY round {}: {} (value {:?}, threshold {:?})",
                a.get("round").and_then(Json::as_f64).unwrap_or(-1.0) as i64,
                a.get("kind").and_then(Json::as_str).unwrap_or("?"),
                a.get("value").and_then(Json::as_f64),
                a.get("threshold").and_then(Json::as_f64)
            );
        }
    }
    if let Some(hottest) = body.get("hottest").and_then(Json::as_arr) {
        if !hottest.is_empty() {
            println!("hottest spans:");
            for h in hottest {
                println!(
                    "  {:<8} {:<24} {:>9.3}s x{}",
                    h.get("cat").and_then(Json::as_str).unwrap_or("?"),
                    h.get("name").and_then(Json::as_str).unwrap_or("?"),
                    h.get("total_s").and_then(Json::as_f64).unwrap_or(0.0),
                    h.get("count").and_then(Json::as_f64).unwrap_or(0.0) as u64
                );
            }
        }
    }
}

/// `top --connect HOST:PORT`: poll the coordinator's `status` endpoint and
/// render a live console table (one-shot with `--once`, raw with `--json`).
fn top_cmd(args: &Args) -> Result<()> {
    let addr = args
        .get("connect")
        .ok_or_else(|| anyhow!("top needs --connect HOST:PORT"))?;
    let interval_s: f64 = args.get_parse("interval-s", 1.0f64);
    let once = args.has_flag("once");
    let raw = args.has_flag("json");
    loop {
        let body = fetch_status(addr)?;
        if raw {
            println!("{body}");
        } else {
            if !once {
                // ANSI clear + home: repaint in place like `top`.
                print!("\x1b[2J\x1b[H");
            }
            render_status(&body);
        }
        if once {
            return Ok(());
        }
        std::thread::sleep(std::time::Duration::from_secs_f64(interval_s.max(0.1)));
    }
}

/// Recursively drop the fields two honest runs are allowed to disagree on:
/// wall-clock blocks (`wall_s`, `health`, `telemetry`, `ledger` — the
/// ledger's byte columns are deterministic but its transfer/compute
/// seconds follow the fleet clock, and untraced runs omit the block
/// entirely), machine context, and prose notes. Everything that remains is
/// part of the deterministic contract.
fn diff_canon(v: &Json) -> Json {
    const DROP: [&str; 6] = ["wall_s", "health", "telemetry", "ledger", "machine", "note"];
    match v {
        Json::Obj(o) => Json::Obj(
            o.iter()
                .filter(|(k, _)| !DROP.contains(&k.as_str()))
                .map(|(k, x)| (k.clone(), diff_canon(x)))
                .collect(),
        ),
        Json::Arr(a) => Json::Arr(a.iter().map(diff_canon).collect()),
        other => other.clone(),
    }
}

/// Fields that measure real time/throughput: compared within a relative
/// tolerance instead of exactly (bench timings wobble run to run).
fn is_perf_key(key: &str) -> bool {
    key.ends_with("_ms")
        || key.ends_with("_us")
        || key.ends_with("_ns")
        || key.ends_with("ns_per_op")
        || key.contains("elapsed")
        || key.contains("wall")
        || key.contains("gflops")
        || key.ends_with("bytes_per_s")
        || key.ends_with("mb_per_s")
}

/// Structural comparison of two canonicalized documents. Appends one line
/// per divergence (path, both values) to `out`.
fn diff_walk(a: &Json, b: &Json, path: &str, tolerance: f64, out: &mut Vec<String>) {
    match (a, b) {
        (Json::Obj(ao), Json::Obj(bo)) => {
            let keys: std::collections::BTreeSet<&String> =
                ao.keys().chain(bo.keys()).collect();
            for k in keys {
                let p = if path.is_empty() { k.clone() } else { format!("{path}.{k}") };
                match (ao.get(k), bo.get(k)) {
                    (Some(x), Some(y)) => diff_walk(x, y, &p, tolerance, out),
                    (Some(_), None) => out.push(format!("{p}: only in A")),
                    (None, Some(_)) => out.push(format!("{p}: only in B")),
                    (None, None) => unreachable!(),
                }
            }
        }
        (Json::Arr(aa), Json::Arr(ba)) => {
            if aa.len() != ba.len() {
                out.push(format!("{path}: array length {} vs {}", aa.len(), ba.len()));
                return;
            }
            for (i, (x, y)) in aa.iter().zip(ba).enumerate() {
                diff_walk(x, y, &format!("{path}[{i}]"), tolerance, out);
            }
        }
        (Json::Num(x), Json::Num(y)) => {
            let key = path.rsplit('.').next().unwrap_or(path);
            if is_perf_key(key) {
                let scale = x.abs().max(y.abs());
                if scale > 0.0 && (x - y).abs() / scale > tolerance {
                    out.push(format!(
                        "{path}: {x} vs {y} (relative {:.4} > tolerance {tolerance})",
                        (x - y).abs() / scale
                    ));
                }
            } else if x != y && !(x.is_nan() && y.is_nan()) {
                out.push(format!("{path}: {x} vs {y}"));
            }
        }
        _ => {
            if a != b {
                out.push(format!("{path}: {a} vs {b}"));
            }
        }
    }
}

/// `diff A.json B.json`: regression gate over two RunReports or bench
/// snapshots. Exit 0 = canonically identical, 1 = divergence past the
/// gates, 2 = usage/IO trouble.
fn diff_cmd(args: &Args) -> Result<()> {
    let (a_path, b_path) = match (args.positional.get(1), args.positional.get(2)) {
        (Some(a), Some(b)) => (a.clone(), b.clone()),
        _ => {
            eprintln!("usage: sfprompt diff A.json B.json [--tolerance F] [--print-canon]");
            std::process::exit(2);
        }
    };
    let load = |path: &str| -> Json {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("diff: cannot read {path}: {e}");
                std::process::exit(2);
            }
        };
        match Json::parse(&text) {
            Ok(v) => diff_canon(&v),
            Err(e) => {
                eprintln!("diff: {path} is not valid JSON: {e}");
                std::process::exit(2);
            }
        }
    };
    let a = load(&a_path);
    if args.has_flag("print-canon") {
        // Emit A's canonical form (for committing golden references).
        println!("{a}");
        return Ok(());
    }
    let b = load(&b_path);
    let tolerance: f64 = args.get_parse("tolerance", 0.10f64);
    let mut diffs = Vec::new();
    diff_walk(&a, &b, "", tolerance, &mut diffs);
    if diffs.is_empty() {
        println!("diff: {a_path} == {b_path} (canonicalized, tolerance {tolerance})");
        return Ok(());
    }
    eprintln!("diff: {} divergence(s) between {a_path} and {b_path}:", diffs.len());
    for d in &diffs {
        eprintln!("  {d}");
    }
    std::process::exit(1);
}

/// `trace merge A.jsonl B.jsonl [...]`: stitch per-process traces from one
/// traced networked run into a single causally-consistent tree. Remote
/// parent references resolve across files, client spans are re-based onto
/// the coordinator timeline using each trace's recorded clock offset, and
/// nestings that escape their parent beyond the clock estimate's RTT bound
/// are flagged `skew` (never silently clamped). See docs/TRACING.md.
fn trace_cmd(args: &Args) -> Result<()> {
    if args.positional.get(1).map(|s| s.as_str()) != Some("merge") {
        eprintln!(
            "usage: sfprompt trace merge A.jsonl B.jsonl [...] [--out MERGED.jsonl] \
             [--chrome OUT.json]"
        );
        std::process::exit(2);
    }
    let inputs = &args.positional[2..];
    if inputs.len() < 2 {
        bail!("trace merge needs at least two per-process trace files");
    }
    let mut traces = Vec::with_capacity(inputs.len());
    for path in inputs {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading trace {path}"))?;
        traces.push(ProcessTrace::parse(&text).map_err(|e| anyhow!("{path}: {e}"))?);
    }
    let merged = merge_traces(&traces).map_err(|e| anyhow!("trace merge: {e}"))?;

    let remote = merged.spans.iter().filter(|s| s.remote).count();
    let skewed = merged.spans.iter().filter(|s| s.skew).count();
    eprintln!(
        "merged trace {:032x}: {} spans from {} process(es), {} cross-process edge(s){}",
        merged.trace_id,
        merged.spans.len(),
        merged.processes.len(),
        remote,
        if skewed > 0 { format!(", {skewed} flagged skew") } else { String::new() }
    );
    for p in &merged.processes {
        eprintln!(
            "  {:<14} span_base={:#x}  clock offset {:+.6}s (rtt {:.6}s)",
            p.process, p.span_base, p.offset_s, p.rtt_s
        );
    }

    match args.get("out") {
        Some(out) => {
            std::fs::write(out, merged.to_jsonl())
                .with_context(|| format!("writing merged trace {out}"))?;
            eprintln!("merged trace -> {out}");
        }
        None => print!("{}", merged.to_jsonl()),
    }
    if let Some(out) = args.get("chrome") {
        std::fs::write(out, format!("{}\n", merged.to_chrome_trace()))
            .with_context(|| format!("writing chrome trace {out}"))?;
        eprintln!("chrome trace -> {out} (open in Perfetto or chrome://tracing)");
    }
    Ok(())
}

/// `report --waterfall REPORT.json [--round N]`: render the report's
/// `"ledger"` block — measured bytes re-attributed per (round, client,
/// phase) — as a per-round communication-cost waterfall.
fn report_waterfall(path: &str, args: &Args) -> Result<()> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading report {path}"))?;
    let v = Json::parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;
    let ledger = v.get("ledger").ok_or_else(|| {
        anyhow!(
            "{path} has no \"ledger\" block — produce the report from a traced run \
             (train/serve with --trace or --metrics)"
        )
    })?;
    if ledger.get("format").and_then(Json::as_str) != Some("sfprompt-ledger") {
        bail!("{path}: \"ledger\" block is not an sfprompt-ledger document");
    }
    let rows = ledger
        .get("rows")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("{path}: ledger has no rows array"))?;
    let only_round = args.get("round").map(|r| r.parse::<u64>()).transpose()
        .map_err(|_| anyhow!("--round must be an integer"))?;

    // (round -> phase -> (bytes, transfer_s)), plus per-round compute.
    let mut per_round: BTreeMap<u64, BTreeMap<String, (u64, f64)>> = BTreeMap::new();
    for row in rows {
        let round = row.get("round").and_then(Json::as_f64).unwrap_or(-1.0) as u64;
        if only_round.is_some_and(|r| r != round) {
            continue;
        }
        let phase = row
            .get("phase")
            .and_then(Json::as_str)
            .unwrap_or("?")
            .to_string();
        let bytes = (row.get("up_bytes").and_then(Json::as_f64).unwrap_or(0.0)
            + row.get("down_bytes").and_then(Json::as_f64).unwrap_or(0.0)) as u64;
        let transfer = row.get("transfer_s").and_then(Json::as_f64).unwrap_or(0.0);
        let e = per_round.entry(round).or_default().entry(phase).or_insert((0, 0.0));
        e.0 += bytes;
        e.1 += transfer;
    }
    let mut compute: BTreeMap<u64, f64> = BTreeMap::new();
    if let Some(cs) = ledger.get("compute").and_then(Json::as_arr) {
        for c in cs {
            let round = c.get("round").and_then(Json::as_f64).unwrap_or(-1.0) as u64;
            if only_round.is_some_and(|r| r != round) {
                continue;
            }
            *compute.entry(round).or_insert(0.0) +=
                c.get("compute_s").and_then(Json::as_f64).unwrap_or(0.0);
        }
    }
    if per_round.is_empty() {
        bail!("no ledger rows{}", only_round.map_or(String::new(), |r| format!(" for round {r}")));
    }

    let max_cost = per_round
        .values()
        .flat_map(|phases| phases.values().map(|(_, s)| *s))
        .chain(compute.values().copied())
        .fold(0.0f64, f64::max);
    let bar = |cost: f64| -> String {
        const WIDTH: usize = 40;
        let n = if max_cost > 0.0 {
            ((cost / max_cost) * WIDTH as f64).round() as usize
        } else {
            0
        };
        "#".repeat(n.min(WIDTH))
    };
    println!("communication-cost waterfall ({path}):");
    for (round, phases) in &per_round {
        println!("round {round}:");
        for (phase, (bytes, transfer)) in phases {
            println!(
                "  {:<14} {:>12.3} MB {:>10.3}s |{}",
                phase,
                *bytes as f64 / 1e6,
                transfer,
                bar(*transfer)
            );
        }
        if let Some(c) = compute.get(round) {
            println!("  {:<14} {:>15} {:>10.3}s |{}", "compute", "-", c, bar(*c));
        }
    }
    if let Some(totals) = ledger.get("totals") {
        let tf = |k: &str| totals.get(k).and_then(Json::as_f64).unwrap_or(0.0);
        println!(
            "totals: {:.3} MB up / {:.3} MB down, {} messages, transfer {:.3}s, compute {:.3}s",
            tf("up_bytes") / 1e6,
            tf("down_bytes") / 1e6,
            tf("messages") as u64,
            tf("transfer_s"),
            tf("compute_s")
        );
    }
    Ok(())
}

/// Console rendering of `MetricsRegistry::hottest_stages` (a JSON array).
fn print_hottest_stages(rows: &Json) {
    let Some(rows) = rows.as_arr() else { return };
    if rows.is_empty() {
        return;
    }
    println!("\nhottest backend stages (by total time):");
    println!(
        "{:<26} {:>8} {:>10} {:>9} {:>9} {:>9} {:>10}",
        "stage", "calls", "total s", "mean ms", "p50 ms", "p95 ms", "GFLOP/s"
    );
    for r in rows {
        let f = |k: &str| r.get(k).and_then(Json::as_f64).unwrap_or(0.0);
        let gflops = r
            .get("achieved_gflops")
            .and_then(Json::as_f64)
            .map_or("-".to_string(), |g| format!("{g:.2}"));
        println!(
            "{:<26} {:>8} {:>10.3} {:>9.3} {:>9.3} {:>9.3} {:>10}",
            r.get("stage").and_then(Json::as_str).unwrap_or("?"),
            f("calls") as u64,
            f("total_s"),
            f("mean_ms"),
            f("p50_ms"),
            f("p95_ms"),
            gflops
        );
    }
}

/// Rebuild `SpanRecord`s from a trace JSONL file (the inverse of
/// `Tracer::to_jsonl`). Returns the records in file order.
fn parse_trace(text: &str) -> Result<Vec<SpanRecord>> {
    // Span categories are &'static str on the in-memory record; a one-shot
    // CLI parse interns each distinct cat once.
    let mut interned: BTreeMap<String, &'static str> = BTreeMap::new();
    let mut intern = |s: &str| -> &'static str {
        *interned
            .entry(s.to_string())
            .or_insert_with(|| Box::leak(s.to_string().into_boxed_str()))
    };
    let mut out = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let v = Json::parse(line)
            .map_err(|e| anyhow!("trace line {}: {e}", lineno + 1))?;
        match v.get("ev").and_then(Json::as_str) {
            Some("meta") => {
                let fmt = v.get("format").and_then(Json::as_str);
                if fmt != Some("sfprompt-trace") {
                    bail!("not an sfprompt trace (format {fmt:?})");
                }
            }
            Some("span") => {
                let num = |k: &str| -> Result<f64> {
                    v.get(k)
                        .and_then(Json::as_f64)
                        .ok_or_else(|| anyhow!("trace line {}: missing {k:?}", lineno + 1))
                };
                let attrs = match v.get("attrs").and_then(Json::as_obj) {
                    Some(obj) => obj
                        .iter()
                        .filter_map(|(k, j)| j.as_f64().map(|n| (k.clone(), n)))
                        .collect(),
                    None => Vec::new(),
                };
                out.push(SpanRecord {
                    id: num("id")? as u64,
                    parent: v.get("parent").and_then(Json::as_f64).map(|p| p as u64),
                    cat: intern(v.get("cat").and_then(Json::as_str).unwrap_or("?")),
                    name: v
                        .get("name")
                        .and_then(Json::as_str)
                        .unwrap_or("?")
                        .to_string(),
                    tid: num("tid")? as u64,
                    start_s: num("t0_s")?,
                    end_s: num("t1_s")?,
                    sim_s: v.get("sim_s").and_then(Json::as_f64),
                    attrs,
                    open: v.get("open").and_then(Json::as_bool) == Some(true),
                });
            }
            other => bail!("trace line {}: unknown event {other:?}", lineno + 1),
        }
    }
    Ok(out)
}

/// `report --health FILE.jsonl`: anomaly timeline from a live-ops log —
/// either a serve `--events` stream (lines keyed `"event"`) or a flight
/// recorder post-mortem dump (lines keyed `"ev"`); auto-detected.
fn report_health(path: &str) -> Result<()> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading health log {path}"))?;
    let mut rows: Vec<(f64, String)> = Vec::new();
    let mut kind = "unknown";
    let mut total_lines = 0usize;
    for (lineno, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let v = Json::parse(line).map_err(|e| anyhow!("{path} line {}: {e}", lineno + 1))?;
        total_lines += 1;
        if let Some(event) = v.get("event").and_then(Json::as_str) {
            // serve --events stream.
            kind = "event stream";
            let round = v.get("round").and_then(Json::as_f64).unwrap_or(-1.0);
            match event {
                "health_anomaly" => rows.push((round, format!(
                    "round {:>4}  ANOMALY {}  value={:?} threshold={:?}",
                    round as i64,
                    v.get("kind").and_then(Json::as_str).unwrap_or("?"),
                    v.get("value").and_then(Json::as_f64),
                    v.get("threshold").and_then(Json::as_f64)
                ))),
                "health_straggler" => rows.push((round, format!(
                    "round {:>4}  straggler client {}  ewma={:.3}s median={:.3}s",
                    round as i64,
                    v.get("client").and_then(Json::as_f64).unwrap_or(-1.0) as i64,
                    v.get("ewma_s").and_then(Json::as_f64).unwrap_or(0.0),
                    v.get("median_s").and_then(Json::as_f64).unwrap_or(0.0)
                ))),
                "client_dropped" => rows.push((round, format!(
                    "round {:>4}  client {} dropped ({})",
                    round as i64,
                    v.get("client").and_then(Json::as_f64).unwrap_or(-1.0) as i64,
                    v.get("reason").and_then(Json::as_str).unwrap_or("?")
                ))),
                _ => {}
            }
        } else if let Some(ev) = v.get("ev").and_then(Json::as_str) {
            // Flight recorder dump.
            match ev {
                "meta" => {
                    kind = "flight dump";
                    let fmt = v.get("format").and_then(Json::as_str);
                    if fmt != Some("sfprompt-flight") {
                        bail!("{path}: not a flight dump (format {fmt:?})");
                    }
                }
                "flight" => {
                    if v.get("kind").and_then(Json::as_str) == Some("anomaly") {
                        let t = v.get("t_s").and_then(Json::as_f64).unwrap_or(0.0);
                        rows.push((t, format!(
                            "t={t:>8.3}s  ANOMALY {}  round={} value={:?} threshold={:?}",
                            v.get("name").and_then(Json::as_str).unwrap_or("?"),
                            v.get("v0").and_then(Json::as_f64).unwrap_or(-1.0) as i64,
                            v.get("v1").and_then(Json::as_f64),
                            v.get("v2").and_then(Json::as_f64)
                        )));
                    }
                }
                other => bail!("{path} line {}: unknown ev {other:?}", lineno + 1),
            }
        } else {
            bail!("{path} line {}: neither an event line nor a flight entry", lineno + 1);
        }
    }
    println!("health log {path}: {kind}, {total_lines} lines");
    if rows.is_empty() {
        println!("  no anomalies, stragglers, or drops recorded — healthy run");
        return Ok(());
    }
    rows.sort_by(|a, b| a.0.total_cmp(&b.0));
    for (_, row) in &rows {
        println!("  {row}");
    }
    Ok(())
}

/// `report --trace FILE.jsonl [--chrome OUT.json] [--top N]`: pretty-print
/// a saved trace — span census, round timeline, hottest stage spans — and
/// optionally re-export it as Chrome trace-event JSON.
fn report(args: &Args) -> Result<()> {
    if let Some(path) = args.get("health") {
        return report_health(path);
    }
    if let Some(path) = args.get("waterfall") {
        return report_waterfall(path, args);
    }
    let path = args.get("trace").ok_or_else(|| {
        anyhow!("report needs --trace FILE.jsonl, --health FILE.jsonl, or --waterfall REPORT.json")
    })?;
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading trace {path}"))?;
    let records = parse_trace(&text)?;
    if records.is_empty() {
        bail!("trace {path} contains no spans");
    }
    let top_n: usize = args.get_parse("top", 10usize);

    // Census per category.
    let mut by_cat: BTreeMap<&str, (usize, f64)> = BTreeMap::new();
    for r in &records {
        let e = by_cat.entry(r.cat).or_insert((0, 0.0));
        e.0 += 1;
        e.1 += r.end_s - r.start_s;
    }
    println!("trace {path}: {} spans", records.len());
    for (cat, (n, total)) in &by_cat {
        println!("  {cat:<8} {n:>6} spans  {total:>9.3}s total");
    }

    // Round timeline (run/round spans in start order).
    let rounds: Vec<&SpanRecord> = records.iter().filter(|r| r.cat == "round").collect();
    if !rounds.is_empty() {
        println!("\nround timeline:");
        for r in &rounds {
            let children = records.iter().filter(|c| c.parent == Some(r.id)).count();
            let sim = r.sim_s.map_or(String::new(), |s| format!("  sim_clock={s:.1}s"));
            println!(
                "  {:<10} wall {:>8.3}s..{:>8.3}s ({:>7.3}s)  {} child spans{}",
                r.name,
                r.start_s,
                r.end_s,
                r.end_s - r.start_s,
                children,
                sim
            );
        }
    }

    // Hottest stage spans, aggregated by name.
    let mut stages: BTreeMap<&str, (usize, f64)> = BTreeMap::new();
    for r in records.iter().filter(|r| r.cat == "stage") {
        let e = stages.entry(r.name.as_str()).or_insert((0, 0.0));
        e.0 += 1;
        e.1 += r.end_s - r.start_s;
    }
    if !stages.is_empty() {
        let mut rows: Vec<(&str, usize, f64)> =
            stages.into_iter().map(|(k, (n, s))| (k, n, s)).collect();
        rows.sort_by(|a, b| b.2.total_cmp(&a.2));
        println!("\nhottest stages (top {top_n} by total time):");
        println!("{:<26} {:>8} {:>10} {:>9}", "stage", "calls", "total s", "mean ms");
        for (name, calls, total) in rows.iter().take(top_n) {
            println!(
                "{:<26} {:>8} {:>10.3} {:>9.3}",
                name,
                calls,
                total,
                total * 1e3 / *calls as f64
            );
        }
    }

    let open: Vec<&SpanRecord> = records.iter().filter(|r| r.open).collect();
    if !open.is_empty() {
        println!("\nWARNING: {} spans never closed (instrumentation bug):", open.len());
        for r in &open {
            println!("  #{} {}/{} on tid {}", r.id, r.cat, r.name, r.tid);
        }
    }

    if let Some(out) = args.get("chrome") {
        let doc = sfprompt::telemetry::chrome_trace_from_records(&records);
        std::fs::write(out, format!("{doc}\n"))
            .with_context(|| format!("writing chrome trace {out}"))?;
        println!("\nchrome trace -> {out} (open in Perfetto or chrome://tracing)");
    }
    Ok(())
}

fn experiment(args: &Args) -> Result<()> {
    let id = args.get_or("id", "all").to_string();
    let opts = ExpOptions {
        out_dir: args.get_or("out", "results").into(),
        rounds: args.get_parse("rounds", 10usize),
        local_epochs: args.get_parse("epochs", 10usize),
        samples_per_client_x: args.get_parse("scale", 1.0f64),
        seed: args.get_parse("seed", 17u64),
    };
    std::fs::create_dir_all(&opts.out_dir)?;
    experiments::run(&id, &sfprompt::artifacts_root(), &opts)
}
