//! Links: how encoded frames move between endpoints.
//!
//! * [`Transport`] — the one interface every federated message crosses.
//!   `send` returns the **encoded frame length**, which is what the engines
//!   feed into `comm::ByteMeter` — communication accounting is measurement,
//!   not estimation.
//! * [`ChannelLink`] — mpsc-backed duplex endpoint. [`channel_pair`] builds
//!   a symmetric in-process link (baselines, tests); [`Hub::new`] builds a
//!   star topology (one server, N client threads) for concurrent Phase-2
//!   split training.
//! * [`LoopbackLink`] — send-to-self queue: every frame still round-trips
//!   through the full encode → bytes → decode path (codec tests, benches).

use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, Sender};

use anyhow::{anyhow, Result};

use super::codec::{decode_frame, encode_frame, Frame};
use super::encode::WireFormat;

/// A duplex frame pipe. Implementations serialise on `send` and parse +
/// integrity-check on `recv`; both report the on-the-wire byte count.
pub trait Transport {
    /// Encode `frame` under `wire` and transmit it; returns encoded bytes.
    fn send(&mut self, frame: &Frame, wire: WireFormat) -> Result<usize>;
    /// Block for the next frame; returns it with its encoded byte count.
    fn recv(&mut self) -> Result<(Frame, usize)>;
}

/// Server side of a star topology, abstracted over the medium: a
/// slot-addressed outbound channel per selected client plus one shared
/// inbound queue. The in-process [`Hub`] (mpsc) and the networked
/// `net::serve` round hub (TCP sockets) both implement it, so the
/// SFPrompt serve loop is written once and neither knows nor cares
/// whether its clients are threads or processes.
pub trait FrameHub {
    /// Encode `frame` under `wire` and deliver it to `slot`; returns the
    /// encoded byte count (what `ByteMeter` records).
    fn send_to(&self, slot: usize, frame: &Frame, wire: WireFormat) -> Result<usize>;
    /// Block for the next inbound frame from any client.
    fn recv_any(&self) -> Result<(Frame, usize)>;
    /// Non-blocking drain: the next inbound frame if one is already
    /// queued, `None` otherwise. The serve loop uses this to coalesce
    /// same-kind frames into fused batched stage calls; the default says
    /// "nothing queued", which keeps hubs that can't peek (e.g. the TCP
    /// round hub) on the one-frame-at-a-time path.
    fn try_recv_any(&self) -> Result<Option<(Frame, usize)>> {
        Ok(None)
    }
}

/// One endpoint of an in-process link (the wire is `Vec<u8>` messages over
/// `std::sync::mpsc` — unbounded, so single-threaded send→recv sequences
/// never deadlock, and threaded endpoints block only on `recv`).
pub struct ChannelLink {
    tx: Sender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
}

impl ChannelLink {
    fn new(tx: Sender<Vec<u8>>, rx: Receiver<Vec<u8>>) -> ChannelLink {
        ChannelLink { tx, rx }
    }
}

impl Transport for ChannelLink {
    fn send(&mut self, frame: &Frame, wire: WireFormat) -> Result<usize> {
        let bytes = encode_frame(frame, wire)?;
        let n = bytes.len();
        self.tx
            .send(bytes)
            .map_err(|_| anyhow!("link closed (peer endpoint dropped)"))?;
        Ok(n)
    }

    fn recv(&mut self) -> Result<(Frame, usize)> {
        let bytes = self
            .rx
            .recv()
            .map_err(|_| anyhow!("link closed (peer endpoint dropped)"))?;
        let frame = decode_frame(&bytes)?;
        Ok((frame, bytes.len()))
    }
}

/// A symmetric duplex link: two connected endpoints.
pub fn channel_pair() -> (ChannelLink, ChannelLink) {
    let (a_tx, b_rx) = channel();
    let (b_tx, a_rx) = channel();
    (ChannelLink::new(a_tx, a_rx), ChannelLink::new(b_tx, b_rx))
}

/// Server side of a star topology: one shared inbound queue (frames carry
/// the sender's client id) plus a private outbound channel per slot.
pub struct Hub {
    rx: Receiver<Vec<u8>>,
    to_client: Vec<Sender<Vec<u8>>>,
}

impl Hub {
    /// Build a hub with `n` client endpoints. Endpoint `i` talks to the
    /// hub; the hub addresses it as slot `i`.
    pub fn new(n: usize) -> (Hub, Vec<ChannelLink>) {
        let (to_server, rx) = channel();
        let mut to_client = Vec::with_capacity(n);
        let mut links = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, client_rx) = channel();
            to_client.push(tx);
            links.push(ChannelLink::new(to_server.clone(), client_rx));
        }
        // `to_server` drops here: once every client endpoint is gone,
        // `recv_any` reports disconnection instead of blocking forever.
        (Hub { rx, to_client }, links)
    }

    pub fn send_to(&self, slot: usize, frame: &Frame, wire: WireFormat) -> Result<usize> {
        let bytes = encode_frame(frame, wire)?;
        let n = bytes.len();
        self.to_client
            .get(slot)
            .ok_or_else(|| anyhow!("no client slot {slot}"))?
            .send(bytes)
            .map_err(|_| anyhow!("client slot {slot} hung up"))?;
        Ok(n)
    }

    /// Block for the next inbound frame from any client.
    pub fn recv_any(&self) -> Result<(Frame, usize)> {
        let bytes = self
            .rx
            .recv()
            .map_err(|_| anyhow!("all client endpoints hung up"))?;
        let frame = decode_frame(&bytes)?;
        Ok((frame, bytes.len()))
    }

    /// Non-blocking variant of [`Hub::recv_any`].
    pub fn try_recv_any(&self) -> Result<Option<(Frame, usize)>> {
        use std::sync::mpsc::TryRecvError;
        match self.rx.try_recv() {
            Ok(bytes) => {
                let frame = decode_frame(&bytes)?;
                Ok(Some((frame, bytes.len())))
            }
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => Err(anyhow!("all client endpoints hung up")),
        }
    }
}

impl FrameHub for Hub {
    fn send_to(&self, slot: usize, frame: &Frame, wire: WireFormat) -> Result<usize> {
        Hub::send_to(self, slot, frame, wire)
    }

    fn recv_any(&self) -> Result<(Frame, usize)> {
        Hub::recv_any(self)
    }

    fn try_recv_any(&self) -> Result<Option<(Frame, usize)>> {
        Hub::try_recv_any(self)
    }
}

/// Send-to-self link: frames queue up and come back on `recv`, having been
/// fully serialised and reparsed. The test/bench stand-in for a network.
#[derive(Default)]
pub struct LoopbackLink {
    queue: VecDeque<Vec<u8>>,
}

impl LoopbackLink {
    pub fn new() -> LoopbackLink {
        LoopbackLink::default()
    }

    /// Frames currently in flight.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }
}

impl Transport for LoopbackLink {
    fn send(&mut self, frame: &Frame, wire: WireFormat) -> Result<usize> {
        let bytes = encode_frame(frame, wire)?;
        let n = bytes.len();
        self.queue.push_back(bytes);
        Ok(n)
    }

    fn recv(&mut self) -> Result<(Frame, usize)> {
        let bytes = self
            .queue
            .pop_front()
            .ok_or_else(|| anyhow!("loopback link is empty"))?;
        let frame = decode_frame(&bytes)?;
        Ok((frame, bytes.len()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::MsgKind;
    use crate::runtime::HostTensor;
    use crate::transport::codec::Payload;

    fn frame(kind: MsgKind, client: u32, vals: &[f32]) -> Frame {
        Frame::new(kind, 0, client, Payload::Tensor(HostTensor::f32(vec![vals.len()], vals.to_vec())))
    }

    #[test]
    fn loopback_roundtrips_in_order() {
        let mut link = LoopbackLink::new();
        let a = frame(MsgKind::SmashedData, 1, &[1.0, 2.0]);
        let b = frame(MsgKind::GradBodyOut, 1, &[3.0]);
        let na = link.send(&a, WireFormat::F32).unwrap();
        link.send(&b, WireFormat::F32).unwrap();
        assert_eq!(link.pending(), 2);
        let (got_a, n) = link.recv().unwrap();
        assert_eq!((got_a, n), (a, na));
        let (got_b, _) = link.recv().unwrap();
        assert_eq!(got_b, b);
        assert!(link.recv().is_err());
    }

    #[test]
    fn channel_pair_is_duplex() {
        let (mut server, mut client) = channel_pair();
        server.send(&frame(MsgKind::BodyOutput, 7, &[0.5]), WireFormat::F32).unwrap();
        let (got, _) = client.recv().unwrap();
        assert_eq!(got.kind, MsgKind::BodyOutput);
        client.send(&frame(MsgKind::SmashedData, 7, &[1.5]), WireFormat::F16).unwrap();
        let (got, _) = server.recv().unwrap();
        assert_eq!(got.kind, MsgKind::SmashedData);
    }

    #[test]
    fn hub_routes_by_slot_and_detects_hangup() {
        let (hub, mut links) = Hub::new(2);
        hub.send_to(0, &frame(MsgKind::ModelDistribution, 0, &[1.0]), WireFormat::F32).unwrap();
        hub.send_to(1, &frame(MsgKind::ModelDistribution, 1, &[2.0]), WireFormat::F32).unwrap();
        let (f0, _) = links[0].recv().unwrap();
        let (f1, _) = links[1].recv().unwrap();
        assert_eq!(f0.client, 0);
        assert_eq!(f1.client, 1);
        links[0].send(&frame(MsgKind::Upload, 0, &[9.0]), WireFormat::F32).unwrap();
        let (up, _) = hub.recv_any().unwrap();
        assert_eq!(up.kind, MsgKind::Upload);
        assert!(hub.send_to(5, &f0, WireFormat::F32).is_err());
        drop(links);
        assert!(hub.recv_any().is_err());
    }

    #[test]
    fn hub_try_recv_drains_without_blocking() {
        let (hub, mut links) = Hub::new(1);
        assert!(hub.try_recv_any().unwrap().is_none());
        links[0].send(&frame(MsgKind::Upload, 0, &[1.0]), WireFormat::F32).unwrap();
        let (f, _) = hub.try_recv_any().unwrap().unwrap();
        assert_eq!(f.kind, MsgKind::Upload);
        assert!(hub.try_recv_any().unwrap().is_none());
        drop(links);
        assert!(hub.try_recv_any().is_err());
    }

    #[test]
    fn frame_hub_default_try_recv_says_nothing_queued() {
        struct NoPeek;
        impl FrameHub for NoPeek {
            fn send_to(&self, _: usize, _: &Frame, _: WireFormat) -> Result<usize> {
                Ok(0)
            }
            fn recv_any(&self) -> Result<(Frame, usize)> {
                Err(anyhow!("empty"))
            }
        }
        assert!(NoPeek.try_recv_any().unwrap().is_none());
    }

    #[test]
    fn hub_works_across_threads() {
        let (hub, links) = Hub::new(3);
        std::thread::scope(|s| {
            for (i, mut link) in links.into_iter().enumerate() {
                s.spawn(move || {
                    let (f, _) = link.recv().unwrap();
                    assert_eq!(f.client, i as u32);
                    link.send(&frame(MsgKind::Upload, i as u32, &[i as f32]), WireFormat::F32)
                        .unwrap();
                });
            }
            for slot in 0..3 {
                hub.send_to(slot, &frame(MsgKind::ModelDistribution, slot as u32, &[0.0]), WireFormat::F32)
                    .unwrap();
            }
            let mut seen = Vec::new();
            for _ in 0..3 {
                let (f, _) = hub.recv_any().unwrap();
                seen.push(f.client);
            }
            seen.sort_unstable();
            assert_eq!(seen, vec![0, 1, 2]);
        });
    }
}
