//! Payload precision layer: pluggable encodings for f32 tensor data.
//!
//! Three wire formats, selectable per message (the engines compress the
//! uplink payloads — `SmashedData`, `GradBodyOut`, `Upload` — and keep
//! everything else at f32):
//!
//! * **f32** — passthrough, 4 bytes/element, bit-exact.
//! * **f16** — IEEE 754 binary16, 2 bytes/element, round-to-nearest-even;
//!   relative error ≤ 2⁻¹¹ for values in the normal range.
//! * **int8** — per-tensor affine quantization, 1 byte/element + an 8-byte
//!   `{min, scale}` header: `x ≈ min + scale·q`, `q ∈ [0, 255]`,
//!   `scale = (max − min)/255`; absolute error ≤ scale/2.
//!
//! i32 tensors (labels) always pass through raw — they never tolerate loss.

use anyhow::{anyhow, bail, Result};

/// Precision applied to f32 payload data on the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireFormat {
    F32,
    F16,
    Int8,
}

impl WireFormat {
    pub fn label(self) -> &'static str {
        match self {
            WireFormat::F32 => "f32",
            WireFormat::F16 => "f16",
            WireFormat::Int8 => "int8",
        }
    }

    pub fn parse(s: &str) -> Result<WireFormat> {
        match s {
            "f32" => Ok(WireFormat::F32),
            "f16" => Ok(WireFormat::F16),
            "int8" => Ok(WireFormat::Int8),
            other => bail!("unknown wire format {other:?} (known: f32 f16 int8)"),
        }
    }

    pub fn code(self) -> u8 {
        match self {
            WireFormat::F32 => 0,
            WireFormat::F16 => 1,
            WireFormat::Int8 => 2,
        }
    }

    pub fn from_code(code: u8) -> Result<WireFormat> {
        match code {
            0 => Ok(WireFormat::F32),
            1 => Ok(WireFormat::F16),
            2 => Ok(WireFormat::Int8),
            other => bail!("unknown wire format code {other}"),
        }
    }
}

// ------------------------------------------------------------------- f16

/// Convert f32 to IEEE binary16 bits, round-to-nearest-even. Overflow goes
/// to ±inf, underflow below the smallest subnormal flushes to ±0.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let man = bits & 0x007F_FFFF;

    if exp == 0xFF {
        // Inf / NaN (keep NaN payload non-zero).
        return sign | 0x7C00 | if man != 0 { 0x0200 } else { 0 };
    }
    let e = exp - 127 + 15; // re-biased exponent
    if e >= 0x1F {
        return sign | 0x7C00; // overflow -> inf
    }
    if e <= 0 {
        // Subnormal half (or zero). Value = M · 2^(exp-150) with the
        // implicit bit; the half subnormal unit is 2^-24, so q = M >> (14-e).
        if e < -10 {
            return sign; // underflows even the smallest subnormal
        }
        let m = man | 0x0080_0000;
        let shift = (14 - e) as u32;
        let mut h = (m >> shift) as u16;
        let rem = m & ((1u32 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        if rem > halfway || (rem == halfway && (h & 1) == 1) {
            h += 1; // may carry into the exponent field: that is correct
        }
        return sign | h;
    }
    // Normal half: 10 mantissa bits, round the 13 dropped bits.
    let mut h = ((e as u16) << 10) | (man >> 13) as u16;
    let rem = man & 0x1FFF;
    if rem > 0x1000 || (rem == 0x1000 && (h & 1) == 1) {
        h = h.wrapping_add(1); // carry may bump exponent / reach inf: correct
    }
    sign | h
}

/// Convert IEEE binary16 bits back to f32 (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let man = (h & 0x03FF) as u32;
    let bits = if exp == 0 {
        if man == 0 {
            sign // ±0
        } else {
            // Subnormal: value = man · 2^-24; normalize into f32.
            let mut m = man;
            let mut e32 = 113u32; // exponent once bit 10 is the implicit bit
            while m & 0x0400 == 0 {
                m <<= 1;
                e32 -= 1;
            }
            sign | (e32 << 23) | ((m & 0x03FF) << 13)
        }
    } else if exp == 0x1F {
        sign | 0x7F80_0000 | (man << 13) // inf / NaN
    } else {
        sign | ((exp + 112) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

// ------------------------------------------------------------------ int8

/// Per-tensor affine quantization: returns `(min, scale, codes)` with
/// `x ≈ min + scale·code`. Degenerate tensors (constant, empty, all-NaN)
/// get `scale = 0` and all-zero codes.
pub fn int8_quantize(xs: &[f32]) -> (f32, f32, Vec<u8>) {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &x in xs {
        // f32::min/max skip NaN operands, so NaNs never poison the range.
        lo = lo.min(x);
        hi = hi.max(x);
    }
    // Constant tensors have hi == lo: the affine scale denominator would
    // be zero, so guard the divide and emit `{min: lo, scale: 0}` with
    // all-zero codes — dequantization then returns `lo + 0·q`, i.e. the
    // constant BIT-exactly (regression: `int8_degenerate_tensors` here,
    // `int8_constant_tensor_frame_roundtrips_exactly` at the frame level).
    if !lo.is_finite() || !hi.is_finite() || hi <= lo {
        let base = if lo.is_finite() { lo } else { 0.0 };
        return (base, 0.0, vec![0u8; xs.len()]);
    }
    // Range math in f64: hi - lo can overflow f32 to inf for diverged
    // tensors (e.g. endpoints near ±f32::MAX), which would make every
    // decoded element NaN. scale itself always fits f32 (≤ 2·MAX/255).
    let scale = ((hi as f64 - lo as f64) / 255.0) as f32;
    if scale <= 0.0 || !scale.is_finite() {
        return (lo, 0.0, vec![0u8; xs.len()]);
    }
    let codes = xs
        .iter()
        .map(|&x| {
            let q = (x as f64 - lo as f64) / scale as f64;
            if q.is_nan() {
                0
            } else {
                q.round().clamp(0.0, 255.0) as u8
            }
        })
        .collect();
    (lo, scale, codes)
}

/// Reconstruct f32 values from affine int8 codes (f64 accumulation, so
/// extreme ranges cannot overflow intermediates; result clamped to f32).
pub fn int8_dequantize(min: f32, scale: f32, codes: &[u8]) -> Vec<f32> {
    codes
        .iter()
        .map(|&q| {
            let v = min as f64 + scale as f64 * q as f64;
            v.clamp(-(f32::MAX as f64), f32::MAX as f64) as f32
        })
        .collect()
}

// ------------------------------------------------- f32 slab encode/decode

/// Append `xs` to `out` under `wire`; returns the per-element tag the codec
/// stores so the receiver knows how to decode.
pub fn encode_f32s(wire: WireFormat, xs: &[f32], out: &mut Vec<u8>) {
    match wire {
        WireFormat::F32 => {
            out.reserve(xs.len() * 4);
            for &x in xs {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        WireFormat::F16 => {
            out.reserve(xs.len() * 2);
            for &x in xs {
                out.extend_from_slice(&f32_to_f16_bits(x).to_le_bytes());
            }
        }
        WireFormat::Int8 => {
            let (min, scale, codes) = int8_quantize(xs);
            out.reserve(8 + codes.len());
            out.extend_from_slice(&min.to_le_bytes());
            out.extend_from_slice(&scale.to_le_bytes());
            out.extend_from_slice(&codes);
        }
    }
}

/// Number of payload bytes `n` f32 elements occupy under `wire`.
pub fn encoded_f32_len(wire: WireFormat, n: usize) -> usize {
    match wire {
        WireFormat::F32 => 4 * n,
        WireFormat::F16 => 2 * n,
        WireFormat::Int8 => 8 + n,
    }
}

/// Decode `n` f32 elements from the front of `buf`; returns the values and
/// the number of bytes consumed.
pub fn decode_f32s(wire: WireFormat, n: usize, buf: &[u8]) -> Result<(Vec<f32>, usize)> {
    let need = encoded_f32_len(wire, n);
    if buf.len() < need {
        bail!("tensor data truncated: need {need} bytes, have {}", buf.len());
    }
    let xs = match wire {
        WireFormat::F32 => buf[..need]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect(),
        WireFormat::F16 => buf[..need]
            .chunks_exact(2)
            .map(|c| f16_bits_to_f32(u16::from_le_bytes([c[0], c[1]])))
            .collect(),
        WireFormat::Int8 => {
            let min = f32::from_le_bytes(
                buf[0..4].try_into().map_err(|_| anyhow!("int8 header"))?,
            );
            let scale = f32::from_le_bytes(
                buf[4..8].try_into().map_err(|_| anyhow!("int8 header"))?,
            );
            int8_dequantize(min, scale, &buf[8..need])
        }
    };
    Ok((xs, need))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f16_exact_on_representable_values() {
        for x in [0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 65504.0, 6.103515625e-5] {
            let rt = f16_bits_to_f32(f32_to_f16_bits(x));
            assert_eq!(rt.to_bits(), x.to_bits(), "{x}");
        }
    }

    #[test]
    fn f16_handles_extremes() {
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1e9)), f32::INFINITY);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(-1e9)), f32::NEG_INFINITY);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1e-10)), 0.0);
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
        // Smallest half subnormal survives.
        let tiny = 5.960_464_5e-8f32;
        let rt = f16_bits_to_f32(f32_to_f16_bits(tiny));
        assert!((rt - tiny).abs() < 1e-9, "{rt}");
    }

    #[test]
    fn int8_bounded_error_and_endpoints() {
        let xs: Vec<f32> = (0..100).map(|i| (i as f32 - 50.0) * 0.37).collect();
        let (min, scale, codes) = int8_quantize(&xs);
        let back = int8_dequantize(min, scale, &codes);
        for (a, b) in xs.iter().zip(&back) {
            assert!((a - b).abs() <= scale * 0.5001 + 1e-6, "{a} vs {b}");
        }
        // Range endpoints are exactly representable.
        assert_eq!(codes[0], 0);
        assert_eq!(*codes.last().unwrap(), 255);
    }

    #[test]
    fn int8_survives_extreme_ranges() {
        // hi - lo overflows f32 here; the f64 range math must keep scale
        // finite and the reconstruction NaN-free.
        let xs = [-3.0e38f32, 0.0, 3.0e38];
        let (min, scale, codes) = int8_quantize(&xs);
        assert!(scale.is_finite() && scale > 0.0, "scale {scale}");
        assert_eq!((codes[0], codes[2]), (0, 255));
        let back = int8_dequantize(min, scale, &codes);
        assert!(back.iter().all(|v| v.is_finite()), "{back:?}");
        assert!((back[0] - xs[0]).abs() <= scale * 0.502);
    }

    #[test]
    fn int8_degenerate_tensors() {
        let (min, scale, codes) = int8_quantize(&[3.25; 7]);
        assert_eq!((min, scale), (3.25, 0.0));
        assert!(codes.iter().all(|&c| c == 0));
        assert_eq!(int8_dequantize(min, scale, &codes), vec![3.25; 7]);
        let (_, scale, codes) = int8_quantize(&[]);
        assert_eq!(scale, 0.0);
        assert!(codes.is_empty());
    }

    #[test]
    fn slab_roundtrip_all_formats() {
        let xs: Vec<f32> = (0..33).map(|i| (i as f32) * 0.711 - 11.0).collect();
        for wire in [WireFormat::F32, WireFormat::F16, WireFormat::Int8] {
            let mut buf = Vec::new();
            encode_f32s(wire, &xs, &mut buf);
            assert_eq!(buf.len(), encoded_f32_len(wire, xs.len()));
            let (back, used) = decode_f32s(wire, xs.len(), &buf).unwrap();
            assert_eq!(used, buf.len());
            assert_eq!(back.len(), xs.len());
            if wire == WireFormat::F32 {
                assert_eq!(back, xs);
            }
        }
    }

    #[test]
    fn wire_format_codes_roundtrip() {
        for w in [WireFormat::F32, WireFormat::F16, WireFormat::Int8] {
            assert_eq!(WireFormat::from_code(w.code()).unwrap(), w);
            assert_eq!(WireFormat::parse(w.label()).unwrap(), w);
        }
        assert!(WireFormat::from_code(9).is_err());
        assert!(WireFormat::parse("bf16").is_err());
    }
}
