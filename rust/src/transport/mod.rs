//! Wire-level transport: binary codec, framed messages, channel links.
//!
//! The paper's headline metric is communication cost, so this layer makes
//! it a **measurement**: every federated message is serialised into a
//! versioned, CRC-checked binary frame and moved through a [`Transport`];
//! the engines meter the encoded frame lengths instead of trusting the
//! manifest's analytic estimates. The precision layer ([`WireFormat`])
//! additionally compresses uplink payloads (f16 / int8-affine), which is
//! how the accuracy-vs-bytes trade-off of FedPrompt/SplitLoRA-style upload
//! compression is measured (`sfprompt experiment --id wire`,
//! `sfprompt train --wire int8`).
//!
//! * [`codec`] — frame layout: length prefix, `{version, kind, wire,
//!   round, client}` header, typed payload, CRC32 trailer (docs/WIRE.md).
//!   Since wire v2 a frame can also carry a **compressed** payload —
//!   sparse (varint-delta or bitmap coordinates) or packed-QSGD update
//!   segments from the `compress` subsystem, with a dense fallback so a
//!   compressed frame never exceeds its dense equivalent (docs/COMPRESS.md).
//! * [`encode`] — pluggable element precision: f32 passthrough, IEEE f16,
//!   int8 affine quantization with per-tensor `{min, scale}`.
//! * [`link`] — [`ChannelLink`] (mpsc; also the star-topology [`Hub`]
//!   that lets Phase-2 clients run on real threads) and [`LoopbackLink`].
//! * [`crc32`] — the checksum substrate.

pub mod codec;
pub mod crc32;
pub mod encode;
pub mod link;

pub use codec::{
    decode_frame, dense_segments_wire_len, encode_frame, encoded_frame_len, Frame, Payload,
    FRAME_OVERHEAD, WIRE_VERSION,
};
pub use encode::WireFormat;
pub use link::{channel_pair, ChannelLink, FrameHub, Hub, LoopbackLink, Transport};
