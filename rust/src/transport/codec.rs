//! Versioned binary frame codec for the federated message protocol.
//!
//! Frame layout (all integers little-endian; see docs/WIRE.md):
//!
//! ```text
//! [ u32 frame_len ]                     length prefix: bytes that follow
//! [ "SF" u8 version u8 kind u8 wire ]   magic + protocol version + tags
//! [ u32 round ] [ u32 client ]          routing / bookkeeping
//! [ u32 payload_len ]
//! [ payload … ]
//! [ u32 crc32 ]                         over header + payload
//! ```
//!
//! Payload encoding: a tag byte (`0` segment list, `1` tensor, `2` empty,
//! `3` compressed segment list), then length-prefixed names and tensors.
//! Each tensor carries its own element-encoding tag (f32 raw / i32 raw /
//! f16 / int8-affine), so a decoder never needs out-of-band context.
//! Compressed tensors (docs/COMPRESS.md) carry a per-tensor layout tag:
//! sparse coordinates as varint index deltas or a dense bitmap (whichever
//! is smaller), packed QSGD codes, or a dense fallback when no sparse
//! layout would save bytes — so a compressed frame is never larger than
//! its dense equivalent. No serde: the offline registry carries none, so
//! this follows the `util/json.rs` hand-rolled precedent.

use anyhow::{anyhow, bail, Result};

use crate::comm::MsgKind;
use crate::compress::{qsgd_levels, CompressedRepr, CompressedSegment, CompressedTensor};
use crate::model::SegmentParams;
use crate::runtime::{HostTensor, TensorData};

use super::crc32::crc32;
use super::encode::{decode_f32s, encode_f32s, encoded_f32_len, WireFormat};

/// Protocol version stamped into every frame header. v2 added the
/// compressed payload (tag 3) for sparse/quantized uploads.
pub const WIRE_VERSION: u8 = 2;

const MAGIC: [u8; 2] = *b"SF";

/// Header bytes after the length prefix: magic(2) + version(1) + kind(1) +
/// wire(1) + round(4) + client(4) + payload_len(4).
pub const HEADER_LEN: usize = 17;

/// Fixed per-frame overhead: length prefix + header + CRC32 trailer.
pub const FRAME_OVERHEAD: usize = 4 + HEADER_LEN + 4;

/// Per-tensor element encodings (tagged in the payload, one per tensor).
const ENC_F32: u8 = 0;
const ENC_I32: u8 = 1;
const ENC_F16: u8 = 2;
const ENC_INT8: u8 = 3;

const PAYLOAD_SEGMENTS: u8 = 0;
const PAYLOAD_TENSOR: u8 = 1;
const PAYLOAD_EMPTY: u8 = 2;
const PAYLOAD_COMPRESSED: u8 = 3;

/// Per-compressed-tensor layouts (docs/COMPRESS.md). The encoder picks
/// whichever is smallest for the tensor at hand, so compressed frames
/// never exceed their dense-f32 equivalent.
const LAYOUT_DENSE: u8 = 0;
const LAYOUT_SPARSE_VARINT: u8 = 1;
const LAYOUT_SPARSE_BITMAP: u8 = 2;
const LAYOUT_QSGD: u8 = 3;

/// Decode-side sanity cap: refuse frames claiming more elements than this
/// in a single tensor (256 Mi elements = 1 GiB of f32), so a corrupted
/// header cannot trigger a huge allocation before the CRC is even checked.
const MAX_ELEMENTS: usize = 1 << 28;
const MAX_RANK: usize = 8;

/// What a frame carries.
#[derive(Debug, Clone, PartialEq)]
pub enum Payload {
    /// Parameter segments, in protocol order (e.g. `[tail, prompt]`).
    Segments(Vec<SegmentParams>),
    /// A single activation/gradient tensor.
    Tensor(HostTensor),
    /// Control frames (e.g. `Abort`) carry no data.
    Empty,
    /// Compressed update segments (sparse / quantized Phase-3 uploads;
    /// the server decompresses against its reference before FedAvg).
    Compressed(Vec<CompressedSegment>),
}

impl Payload {
    pub fn into_tensor(self) -> Result<HostTensor> {
        match self {
            Payload::Tensor(t) => Ok(t),
            other => bail!("expected tensor payload, got {}", other.label()),
        }
    }

    pub fn into_segments(self) -> Result<Vec<SegmentParams>> {
        match self {
            Payload::Segments(s) => Ok(s),
            other => bail!("expected segments payload, got {}", other.label()),
        }
    }

    pub fn into_compressed(self) -> Result<Vec<CompressedSegment>> {
        match self {
            Payload::Compressed(s) => Ok(s),
            other => bail!("expected compressed payload, got {}", other.label()),
        }
    }

    fn label(&self) -> &'static str {
        match self {
            Payload::Segments(_) => "segments",
            Payload::Tensor(_) => "tensor",
            Payload::Empty => "empty",
            Payload::Compressed(_) => "compressed",
        }
    }
}

/// One protocol message: header fields + payload.
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    pub kind: MsgKind,
    pub round: u32,
    pub client: u32,
    pub payload: Payload,
}

impl Frame {
    pub fn new(kind: MsgKind, round: u32, client: u32, payload: Payload) -> Frame {
        Frame { kind, round, client, payload }
    }
}

// ----------------------------------------------------------------- encode

fn tensor_payload_len(t: &HostTensor, wire: WireFormat) -> usize {
    let data = match &t.data {
        TensorData::F32(v) => encoded_f32_len(wire, v.len()),
        TensorData::I32(v) => 4 * v.len(),
    };
    // enc tag + rank + dims + data
    2 + 4 * t.shape.len() + data
}

fn encode_tensor(t: &HostTensor, wire: WireFormat, out: &mut Vec<u8>) -> Result<()> {
    if t.shape.len() > MAX_RANK {
        bail!("tensor rank {} exceeds wire maximum {MAX_RANK}", t.shape.len());
    }
    for &d in &t.shape {
        if d > u32::MAX as usize {
            bail!("tensor dim {d} exceeds u32");
        }
    }
    match &t.data {
        TensorData::F32(v) => {
            out.push(match wire {
                WireFormat::F32 => ENC_F32,
                WireFormat::F16 => ENC_F16,
                WireFormat::Int8 => ENC_INT8,
            });
            out.push(t.shape.len() as u8);
            for &d in &t.shape {
                out.extend_from_slice(&(d as u32).to_le_bytes());
            }
            encode_f32s(wire, v, out);
        }
        TensorData::I32(v) => {
            out.push(ENC_I32);
            out.push(t.shape.len() as u8);
            for &d in &t.shape {
                out.extend_from_slice(&(d as u32).to_le_bytes());
            }
            out.reserve(v.len() * 4);
            for &x in v {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
    }
    Ok(())
}

// ------------------------------------------------- compressed tensors

/// LEB128 length of one u32.
fn varint_len(mut v: u32) -> usize {
    let mut n = 1;
    while v >= 0x80 {
        v >>= 7;
        n += 1;
    }
    n
}

fn push_varint(out: &mut Vec<u8>, mut v: u32) {
    while v >= 0x80 {
        out.push((v & 0x7F) as u8 | 0x80);
        v >>= 7;
    }
    out.push(v as u8);
}

/// Byte cost of the sparse index stream as varint deltas: the first index
/// raw, then successive gaps (always ≥ 1 for sorted unique indices).
fn varint_indices_len(indices: &[u32]) -> usize {
    let mut len = 0;
    let mut prev = 0u32;
    for (i, &idx) in indices.iter().enumerate() {
        len += varint_len(if i == 0 { idx } else { idx - prev });
        prev = idx;
    }
    len
}

/// The layout the encoder picks for a compressed tensor, with its exact
/// data length (everything after the `layout, rank, dims` header). Shared
/// by [`encoded_frame_len`] and the encoder so lengths never drift.
fn compressed_layout(t: &CompressedTensor) -> Result<(u8, usize)> {
    let n = t.element_count();
    let dense = 4 * n;
    match &t.repr {
        CompressedRepr::Dense(values) => {
            if values.len() != n {
                bail!("dense repr carries {} values for {n} elements", values.len());
            }
            Ok((LAYOUT_DENSE, dense))
        }
        CompressedRepr::Sparse { indices, values } => {
            if indices.len() != values.len() {
                bail!("sparse repr: {} indices vs {} values", indices.len(), values.len());
            }
            let mut prev: Option<u32> = None;
            for &i in indices {
                if (i as usize) >= n {
                    bail!("sparse index {i} out of range for {n} elements");
                }
                if prev.is_some_and(|p| i <= p) {
                    bail!("sparse indices must be strictly increasing");
                }
                prev = Some(i);
            }
            let nnz = indices.len();
            let varint = 4 + varint_indices_len(indices) + 4 * nnz;
            let bitmap = n.div_ceil(8) + 4 * nnz;
            // Smallest wins; ties prefer the index list (cheaper to scan).
            if varint <= bitmap && varint <= dense {
                Ok((LAYOUT_SPARSE_VARINT, varint))
            } else if bitmap <= dense {
                Ok((LAYOUT_SPARSE_BITMAP, bitmap))
            } else {
                Ok((LAYOUT_DENSE, dense))
            }
        }
        CompressedRepr::Qsgd { bits, scale, codes } => {
            if !(2..=8).contains(bits) {
                bail!("qsgd bits must be in 2..=8, got {bits}");
            }
            if !scale.is_finite() || *scale < 0.0 {
                bail!("qsgd scale must be finite and non-negative, got {scale}");
            }
            if codes.len() != n {
                bail!("qsgd repr carries {} codes for {n} elements", codes.len());
            }
            let packed = 5 + (n * *bits as usize).div_ceil(8);
            // Tiny tensors where the scale header dominates fall back to
            // dense *dequantized* values — identical reconstruction,
            // never more bytes than dense.
            if packed <= dense {
                Ok((LAYOUT_QSGD, packed))
            } else {
                Ok((LAYOUT_DENSE, dense))
            }
        }
    }
}

/// Exact encoded size of one compressed tensor (header + data).
fn compressed_tensor_len(t: &CompressedTensor) -> Result<usize> {
    Ok(2 + 4 * t.shape.len() + compressed_layout(t)?.1)
}

fn encode_compressed_tensor(t: &CompressedTensor, out: &mut Vec<u8>) -> Result<()> {
    if t.shape.len() > MAX_RANK {
        bail!("tensor rank {} exceeds wire maximum {MAX_RANK}", t.shape.len());
    }
    for &d in &t.shape {
        if d > u32::MAX as usize {
            bail!("tensor dim {d} exceeds u32");
        }
    }
    let (layout, _) = compressed_layout(t)?;
    out.push(layout);
    out.push(t.shape.len() as u8);
    for &d in &t.shape {
        out.extend_from_slice(&(d as u32).to_le_bytes());
    }
    let n = t.element_count();
    match (layout, &t.repr) {
        (LAYOUT_DENSE, _) => {
            // Dense fallback: materialise the reconstruction (for Dense
            // reprs this is the values themselves).
            for x in t.decompress()? {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        (LAYOUT_SPARSE_VARINT, CompressedRepr::Sparse { indices, values }) => {
            out.extend_from_slice(&(indices.len() as u32).to_le_bytes());
            let mut prev = 0u32;
            for (i, &idx) in indices.iter().enumerate() {
                push_varint(out, if i == 0 { idx } else { idx - prev });
                prev = idx;
            }
            for &v in values {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        (LAYOUT_SPARSE_BITMAP, CompressedRepr::Sparse { indices, values }) => {
            let mut bitmap = vec![0u8; n.div_ceil(8)];
            for &i in indices {
                bitmap[i as usize / 8] |= 1 << (i % 8);
            }
            out.extend_from_slice(&bitmap);
            for &v in values {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        (LAYOUT_QSGD, CompressedRepr::Qsgd { bits, scale, codes }) => {
            out.push(*bits);
            out.extend_from_slice(&scale.to_le_bytes());
            let mut packed = vec![0u8; (n * *bits as usize).div_ceil(8)];
            for (i, &c) in codes.iter().enumerate() {
                let bit = i * *bits as usize;
                let word = (c as u16) << (bit % 8);
                packed[bit / 8] |= word as u8;
                if bit % 8 + *bits as usize > 8 {
                    packed[bit / 8 + 1] |= (word >> 8) as u8;
                }
            }
            out.extend_from_slice(&packed);
        }
        _ => unreachable!("compressed_layout pairs layouts with reprs"),
    }
    Ok(())
}

fn decode_compressed_tensor(r: &mut Reader) -> Result<CompressedTensor> {
    let layout = r.u8()?;
    let (shape, n) = read_shape(r)?;
    let repr = match layout {
        LAYOUT_DENSE => CompressedRepr::Dense(read_f32s(r, n)?),
        LAYOUT_SPARSE_VARINT => {
            let nnz = r.u32()? as usize;
            if nnz > n {
                bail!("sparse tensor claims {nnz} nonzeros in {n} elements");
            }
            let mut indices = Vec::with_capacity(nnz);
            let mut prev = 0u32;
            for i in 0..nnz {
                let v = r.varint()?;
                let idx = if i == 0 {
                    v
                } else {
                    if v == 0 {
                        bail!("sparse index gap of zero (duplicate coordinate)");
                    }
                    prev.checked_add(v)
                        .ok_or_else(|| anyhow!("sparse index overflows u32"))?
                };
                if (idx as usize) >= n {
                    bail!("sparse index {idx} out of range for {n} elements");
                }
                indices.push(idx);
                prev = idx;
            }
            let values = read_f32s(r, nnz)?;
            CompressedRepr::Sparse { indices, values }
        }
        LAYOUT_SPARSE_BITMAP => {
            let bitmap = r.take(n.div_ceil(8))?;
            let mut indices = Vec::new();
            for (byte_i, &b) in bitmap.iter().enumerate() {
                for bit in 0..8 {
                    if b & (1 << bit) != 0 {
                        let idx = byte_i * 8 + bit;
                        if idx >= n {
                            bail!("sparse bitmap sets bit {idx} beyond {n} elements");
                        }
                        indices.push(idx as u32);
                    }
                }
            }
            let values = read_f32s(r, indices.len())?;
            CompressedRepr::Sparse { indices, values }
        }
        LAYOUT_QSGD => {
            let bits = r.u8()?;
            if !(2..=8).contains(&bits) {
                bail!("qsgd bits must be in 2..=8, got {bits}");
            }
            let scale = f32::from_le_bytes(r.take(4)?.try_into().expect("4 bytes"));
            // The encoder only ever emits finite scales; an inf here would
            // dequantize to ±inf values and `0·inf = NaN` — reject it like
            // any other malformed untrusted input.
            if !scale.is_finite() || scale < 0.0 {
                bail!("qsgd scale must be finite and non-negative, got {scale}");
            }
            let packed = r.take((n * bits as usize).div_ceil(8))?;
            let max_code = 2 * qsgd_levels(bits);
            let mut codes = Vec::with_capacity(n);
            for i in 0..n {
                let bit = i * bits as usize;
                let mut word = packed[bit / 8] as u16 >> (bit % 8);
                if bit % 8 + bits as usize > 8 {
                    word |= (packed[bit / 8 + 1] as u16) << (8 - bit % 8);
                }
                let code = (word & ((1 << bits) - 1)) as u8;
                if code > max_code {
                    bail!("qsgd code {code} exceeds level range 0..={max_code}");
                }
                codes.push(code);
            }
            CompressedRepr::Qsgd { bits, scale, codes }
        }
        other => bail!("unknown compressed-tensor layout {other}"),
    };
    Ok(CompressedTensor { shape, repr })
}

/// Exact frame length `segs` would occupy sent densely at f32 — the "raw"
/// numerator of the compression accounting in `ByteMeter` (no frame is
/// built).
pub fn dense_segments_wire_len(segs: &[&SegmentParams]) -> usize {
    FRAME_OVERHEAD
        + 1
        + 2
        + segs
            .iter()
            .map(|sp| {
                2 + sp.segment.len()
                    + 2
                    + sp
                        .tensors
                        .iter()
                        .map(|t| tensor_payload_len(t, WireFormat::F32))
                        .sum::<usize>()
            })
            .sum::<usize>()
}

fn encode_payload(payload: &Payload, wire: WireFormat, out: &mut Vec<u8>) -> Result<()> {
    match payload {
        Payload::Segments(segs) => {
            if segs.len() > u16::MAX as usize {
                bail!("too many segments ({})", segs.len());
            }
            out.push(PAYLOAD_SEGMENTS);
            out.extend_from_slice(&(segs.len() as u16).to_le_bytes());
            for sp in segs {
                let name = sp.segment.as_bytes();
                if name.len() > u16::MAX as usize {
                    bail!("segment name too long ({} bytes)", name.len());
                }
                if sp.tensors.len() > u16::MAX as usize {
                    bail!("segment {} has too many tensors", sp.segment);
                }
                out.extend_from_slice(&(name.len() as u16).to_le_bytes());
                out.extend_from_slice(name);
                out.extend_from_slice(&(sp.tensors.len() as u16).to_le_bytes());
                for t in &sp.tensors {
                    encode_tensor(t, wire, out)?;
                }
            }
        }
        Payload::Tensor(t) => {
            out.push(PAYLOAD_TENSOR);
            encode_tensor(t, wire, out)?;
        }
        Payload::Empty => out.push(PAYLOAD_EMPTY),
        Payload::Compressed(segs) => {
            if segs.len() > u16::MAX as usize {
                bail!("too many segments ({})", segs.len());
            }
            out.push(PAYLOAD_COMPRESSED);
            out.extend_from_slice(&(segs.len() as u16).to_le_bytes());
            for sp in segs {
                let name = sp.segment.as_bytes();
                if name.len() > u16::MAX as usize {
                    bail!("segment name too long ({} bytes)", name.len());
                }
                if sp.tensors.len() > u16::MAX as usize {
                    bail!("segment {} has too many tensors", sp.segment);
                }
                out.extend_from_slice(&(name.len() as u16).to_le_bytes());
                out.extend_from_slice(name);
                out.extend_from_slice(&(sp.tensors.len() as u16).to_le_bytes());
                for t in &sp.tensors {
                    encode_compressed_tensor(t, out)?;
                }
            }
        }
    }
    Ok(())
}

/// Exact encoded length of a frame without building it (accounting,
/// tests). For malformed compressed payloads — which [`encode_frame`]
/// would reject — the compressed tensors contribute zero.
pub fn encoded_frame_len(frame: &Frame, wire: WireFormat) -> usize {
    let payload = match &frame.payload {
        Payload::Segments(segs) => {
            1 + 2
                + segs
                    .iter()
                    .map(|sp| {
                        2 + sp.segment.len()
                            + 2
                            + sp.tensors.iter().map(|t| tensor_payload_len(t, wire)).sum::<usize>()
                    })
                    .sum::<usize>()
        }
        Payload::Tensor(t) => 1 + tensor_payload_len(t, wire),
        Payload::Empty => 1,
        Payload::Compressed(segs) => {
            1 + 2
                + segs
                    .iter()
                    .map(|sp| {
                        2 + sp.segment.len()
                            + 2
                            + sp
                                .tensors
                                .iter()
                                .map(|t| compressed_tensor_len(t).unwrap_or(0))
                                .sum::<usize>()
                    })
                    .sum::<usize>()
        }
    };
    FRAME_OVERHEAD + payload
}

/// Serialise a frame. f32 tensor data is encoded under `wire`; i32 tensors
/// and all structure are unaffected by the wire format.
pub fn encode_frame(frame: &Frame, wire: WireFormat) -> Result<Vec<u8>> {
    let telemetry = crate::telemetry::active();
    let t0 = telemetry.as_ref().map(|_| std::time::Instant::now());
    let mut buf = Vec::with_capacity(encoded_frame_len(frame, wire));
    buf.extend_from_slice(&[0u8; 4]); // frame_len backpatched below
    buf.extend_from_slice(&MAGIC);
    buf.push(WIRE_VERSION);
    buf.push(frame.kind.code());
    buf.push(wire.code());
    buf.extend_from_slice(&frame.round.to_le_bytes());
    buf.extend_from_slice(&frame.client.to_le_bytes());
    buf.extend_from_slice(&[0u8; 4]); // payload_len backpatched below

    let payload_start = buf.len();
    encode_payload(&frame.payload, wire, &mut buf)?;
    let payload_len = buf.len() - payload_start;
    if payload_len > u32::MAX as usize {
        bail!("payload too large ({payload_len} bytes)");
    }
    buf[17..21].copy_from_slice(&(payload_len as u32).to_le_bytes());

    let crc = crc32(&buf[4..]);
    buf.extend_from_slice(&crc.to_le_bytes());
    let frame_len = buf.len() - 4;
    buf[0..4].copy_from_slice(&(frame_len as u32).to_le_bytes());
    if let (Some(t), Some(t0)) = (&telemetry, t0) {
        t.metrics.observe("codec_encode_s", t0.elapsed().as_secs_f64());
        let kind = frame.kind.label();
        t.metrics.counter_add(&format!("wire_bytes/{kind}"), buf.len() as u64);
        t.metrics.counter_add(&format!("frames/{kind}"), 1);
    }
    Ok(buf)
}

// ----------------------------------------------------------------- decode

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("frame truncated at byte {} (need {n} more)", self.pos);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// LEB128 u32 (at most 5 bytes; the fifth may carry 4 bits).
    fn varint(&mut self) -> Result<u32> {
        let mut v = 0u64;
        for shift in (0..35).step_by(7) {
            let b = self.u8()?;
            v |= u64::from(b & 0x7F) << shift;
            if b & 0x80 == 0 {
                return u32::try_from(v).map_err(|_| anyhow!("varint exceeds u32"));
            }
        }
        bail!("varint longer than 5 bytes")
    }
}

/// Read `rank, dims` with the same overflow/size guards as dense tensors.
fn read_shape(r: &mut Reader) -> Result<(Vec<usize>, usize)> {
    let rank = r.u8()? as usize;
    if rank > MAX_RANK {
        bail!("tensor rank {rank} exceeds wire maximum {MAX_RANK}");
    }
    let mut shape = Vec::with_capacity(rank);
    let mut elements = 1usize;
    for _ in 0..rank {
        let d = r.u32()? as usize;
        elements = elements
            .checked_mul(d)
            .ok_or_else(|| anyhow!("tensor shape overflows"))?;
        shape.push(d);
    }
    if elements > MAX_ELEMENTS {
        bail!("tensor claims {elements} elements (cap {MAX_ELEMENTS})");
    }
    Ok((shape, elements))
}

fn read_f32s(r: &mut Reader, n: usize) -> Result<Vec<f32>> {
    let bytes = r.take(n * 4)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

fn decode_tensor(r: &mut Reader) -> Result<HostTensor> {
    let enc = r.u8()?;
    let (shape, elements) = read_shape(r)?;
    match enc {
        ENC_I32 => {
            let bytes = r.take(elements * 4)?;
            let v = bytes
                .chunks_exact(4)
                .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            Ok(HostTensor::i32(shape, v))
        }
        ENC_F32 | ENC_F16 | ENC_INT8 => {
            let wire = match enc {
                ENC_F32 => WireFormat::F32,
                ENC_F16 => WireFormat::F16,
                _ => WireFormat::Int8,
            };
            let rest = &r.buf[r.pos..];
            let (v, used) = decode_f32s(wire, elements, rest)?;
            r.pos += used;
            Ok(HostTensor::f32(shape, v))
        }
        other => bail!("unknown tensor encoding tag {other}"),
    }
}

fn decode_payload(r: &mut Reader) -> Result<Payload> {
    match r.u8()? {
        PAYLOAD_SEGMENTS => {
            let count = r.u16()? as usize;
            let mut segs = Vec::with_capacity(count.min(64));
            for _ in 0..count {
                let name_len = r.u16()? as usize;
                let name = std::str::from_utf8(r.take(name_len)?)
                    .map_err(|_| anyhow!("segment name is not utf-8"))?
                    .to_string();
                let n_tensors = r.u16()? as usize;
                let mut tensors = Vec::with_capacity(n_tensors.min(1024));
                for _ in 0..n_tensors {
                    tensors.push(decode_tensor(r)?);
                }
                segs.push(SegmentParams { segment: name, tensors });
            }
            Ok(Payload::Segments(segs))
        }
        PAYLOAD_TENSOR => Ok(Payload::Tensor(decode_tensor(r)?)),
        PAYLOAD_EMPTY => Ok(Payload::Empty),
        PAYLOAD_COMPRESSED => {
            let count = r.u16()? as usize;
            let mut segs = Vec::with_capacity(count.min(64));
            for _ in 0..count {
                let name_len = r.u16()? as usize;
                let name = std::str::from_utf8(r.take(name_len)?)
                    .map_err(|_| anyhow!("segment name is not utf-8"))?
                    .to_string();
                let n_tensors = r.u16()? as usize;
                let mut tensors = Vec::with_capacity(n_tensors.min(1024));
                for _ in 0..n_tensors {
                    tensors.push(decode_compressed_tensor(r)?);
                }
                segs.push(CompressedSegment { segment: name, tensors });
            }
            Ok(Payload::Compressed(segs))
        }
        other => bail!("unknown payload tag {other}"),
    }
}

/// Parse and verify one encoded frame (as produced by [`encode_frame`]).
/// Rejects bad magic, unknown versions, length mismatches, and CRC errors
/// before touching the payload. Quantized payloads decode back to f32.
pub fn decode_frame(buf: &[u8]) -> Result<Frame> {
    let telemetry = crate::telemetry::active();
    let t0 = telemetry.as_ref().map(|_| std::time::Instant::now());
    if buf.len() < FRAME_OVERHEAD {
        bail!("frame too short ({} bytes, minimum {FRAME_OVERHEAD})", buf.len());
    }
    let frame_len = u32::from_le_bytes(buf[0..4].try_into().unwrap()) as usize;
    if frame_len != buf.len() - 4 {
        bail!("frame length prefix {frame_len} != {} actual", buf.len() - 4);
    }
    if buf[4..6] != MAGIC {
        bail!("bad frame magic {:02x}{:02x}", buf[4], buf[5]);
    }
    if buf[6] != WIRE_VERSION {
        bail!("unsupported wire version {} (this build speaks {WIRE_VERSION})", buf[6]);
    }
    let kind = MsgKind::from_code(buf[7])?;
    // The header wire tag is informational (each tensor carries its own
    // encoding tag); validate it all the same so garbage is caught early.
    let _wire = WireFormat::from_code(buf[8])?;
    let round = u32::from_le_bytes(buf[9..13].try_into().unwrap());
    let client = u32::from_le_bytes(buf[13..17].try_into().unwrap());
    let payload_len = u32::from_le_bytes(buf[17..21].try_into().unwrap()) as usize;
    if 4 + HEADER_LEN + payload_len + 4 != buf.len() {
        bail!("payload length {payload_len} inconsistent with frame size {}", buf.len());
    }
    let crc_stored =
        u32::from_le_bytes(buf[buf.len() - 4..].try_into().unwrap());
    let crc_actual = crc32(&buf[4..buf.len() - 4]);
    if crc_stored != crc_actual {
        bail!("frame CRC mismatch (stored {crc_stored:08x}, computed {crc_actual:08x})");
    }

    let mut r = Reader { buf: &buf[21..buf.len() - 4], pos: 0 };
    let payload = decode_payload(&mut r)?;
    if r.pos != r.buf.len() {
        bail!("{} trailing payload bytes", r.buf.len() - r.pos);
    }
    if let (Some(t), Some(t0)) = (&telemetry, t0) {
        t.metrics.observe("codec_decode_s", t0.elapsed().as_secs_f64());
    }
    Ok(Frame { kind, round, client, payload })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seg(name: &str, vals: &[f32]) -> SegmentParams {
        SegmentParams {
            segment: name.into(),
            tensors: vec![HostTensor::f32(vec![vals.len()], vals.to_vec())],
        }
    }

    fn sample_frame() -> Frame {
        Frame::new(
            MsgKind::Upload,
            3,
            12,
            Payload::Segments(vec![
                seg("tail", &[1.0, -2.5, 0.125, 9.0]),
                seg("prompt", &[0.5, 0.25]),
            ]),
        )
    }

    #[test]
    fn f32_roundtrip_is_identity() {
        let frame = sample_frame();
        let bytes = encode_frame(&frame, WireFormat::F32).unwrap();
        assert_eq!(bytes.len(), encoded_frame_len(&frame, WireFormat::F32));
        let back = decode_frame(&bytes).unwrap();
        assert_eq!(back, frame);
    }

    #[test]
    fn tensor_payload_roundtrip_with_i32() {
        let frame = Frame::new(
            MsgKind::SmashedData,
            0,
            1,
            Payload::Tensor(HostTensor::i32(vec![2, 2], vec![1, -2, 3, -4])),
        );
        let bytes = encode_frame(&frame, WireFormat::Int8).unwrap();
        // i32 tensors ignore the wire format.
        let back = decode_frame(&bytes).unwrap();
        assert_eq!(back, frame);
    }

    #[test]
    fn quantized_payloads_shrink_and_stay_close() {
        let vals: Vec<f32> = (0..512).map(|i| ((i as f32) * 0.017).sin()).collect();
        let frame =
            Frame::new(MsgKind::SmashedData, 1, 2, Payload::Tensor(HostTensor::f32(vec![512], vals.clone())));
        let f32_bytes = encode_frame(&frame, WireFormat::F32).unwrap();
        let f16_bytes = encode_frame(&frame, WireFormat::F16).unwrap();
        let int8_bytes = encode_frame(&frame, WireFormat::Int8).unwrap();
        assert!(f16_bytes.len() < f32_bytes.len());
        assert!(int8_bytes.len() < f16_bytes.len());
        let back = decode_frame(&int8_bytes).unwrap().payload.into_tensor().unwrap();
        let max_err = vals
            .iter()
            .zip(back.as_f32())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err <= 2.0 / 255.0, "max_err {max_err}");
    }

    #[test]
    fn rejects_corruption_truncation_and_version_skew() {
        let frame = sample_frame();
        let good = encode_frame(&frame, WireFormat::F32).unwrap();

        // Bit flip in the payload -> CRC error.
        let mut bad = good.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x40;
        assert!(decode_frame(&bad).is_err());

        // Truncated buffer.
        assert!(decode_frame(&good[..good.len() - 3]).is_err());

        // Wrong version (re-CRC so only the version check can fire).
        let mut skew = good.clone();
        skew[6] = 99;
        let crc = crc32(&skew[4..skew.len() - 4]);
        let n = skew.len();
        skew[n - 4..].copy_from_slice(&crc.to_le_bytes());
        let err = decode_frame(&skew).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");

        // Bad magic.
        let mut magic = good;
        magic[4] = b'X';
        assert!(decode_frame(&magic).is_err());
    }

    #[test]
    fn empty_payload_frames() {
        let frame = Frame::new(MsgKind::Abort, 9, 4, Payload::Empty);
        let bytes = encode_frame(&frame, WireFormat::F32).unwrap();
        assert_eq!(bytes.len(), FRAME_OVERHEAD + 1);
        assert_eq!(decode_frame(&bytes).unwrap(), frame);
    }

    // Regression (transport::encode): a constant tensor has max == min, so
    // the affine int8 quantizer's scale denominator is zero. The guard must
    // emit scale = 0 with the constant as the base, and the full frame
    // round-trip must reproduce the constant BIT-exactly — not NaN, not a
    // divided-by-zero artifact.
    #[test]
    fn int8_constant_tensor_frame_roundtrips_exactly() {
        for c in [3.25f32, -7.5, 0.0, f32::MIN_POSITIVE, 1e30] {
            let t = HostTensor::f32(vec![2, 3], vec![c; 6]);
            let frame = Frame::new(MsgKind::SmashedData, 0, 1, Payload::Tensor(t));
            let bytes = encode_frame(&frame, WireFormat::Int8).unwrap();
            let back = decode_frame(&bytes).unwrap().payload.into_tensor().unwrap();
            for v in back.as_f32() {
                assert_eq!(v.to_bits(), c.to_bits(), "constant {c} did not survive int8");
            }
        }
        // Single-element tensors are constant by definition.
        let t = HostTensor::f32(vec![1], vec![-0.625]);
        let frame = Frame::new(MsgKind::GradBodyOut, 1, 2, Payload::Tensor(t));
        let back = decode_frame(&encode_frame(&frame, WireFormat::Int8).unwrap()).unwrap();
        assert_eq!(back.payload.into_tensor().unwrap().as_f32(), &[-0.625]);
    }

    fn sparse(shape: Vec<usize>, indices: Vec<u32>, values: Vec<f32>) -> CompressedTensor {
        CompressedTensor { shape, repr: CompressedRepr::Sparse { indices, values } }
    }

    fn compressed_frame(tensors: Vec<CompressedTensor>) -> Frame {
        Frame::new(
            MsgKind::Upload,
            2,
            5,
            Payload::Compressed(vec![CompressedSegment { segment: "tail".into(), tensors }]),
        )
    }

    #[test]
    fn compressed_sparse_roundtrip_is_identity() {
        // Low density -> the varint layout is chosen and decodes back to
        // the identical Sparse repr (indices sorted, values bit-exact,
        // including a NaN).
        let frame = compressed_frame(vec![sparse(
            vec![4, 8],
            vec![0, 3, 17, 31],
            vec![1.5, -2.25, f32::NAN, 1e-20],
        )]);
        let bytes = encode_frame(&frame, WireFormat::F32).unwrap();
        assert_eq!(bytes.len(), encoded_frame_len(&frame, WireFormat::F32));
        let back = decode_frame(&bytes).unwrap();
        let segs = back.payload.into_compressed().unwrap();
        match &segs[0].tensors[0].repr {
            CompressedRepr::Sparse { indices, values } => {
                assert_eq!(indices, &[0, 3, 17, 31]);
                assert_eq!(values[0].to_bits(), 1.5f32.to_bits());
                assert!(values[2].is_nan());
                assert_eq!(values[3].to_bits(), 1e-20f32.to_bits());
            }
            other => panic!("expected sparse back, got {other:?}"),
        }
        assert_eq!(back.kind, MsgKind::Upload);
        assert_eq!((back.round, back.client), (2, 5));
    }

    #[test]
    fn compressed_layouts_pick_the_smallest_encoding() {
        // Very sparse -> varint; half-dense wide-spread -> bitmap beats
        // per-index varints; fully dense -> dense fallback, and in every
        // case the compressed tensor is no larger than its dense form.
        let dense_len = |n: usize| 2 + 4 + 4 * n; // enc+rank+dim+f32 data
        let cases = [
            (vec![1024usize], vec![5u32, 900], LAYOUT_SPARSE_VARINT),
            (
                vec![256],
                (0..128u32).map(|i| 2 * i).collect::<Vec<_>>(),
                LAYOUT_SPARSE_BITMAP,
            ),
            (vec![8], (0..8u32).collect(), LAYOUT_DENSE),
        ];
        for (shape, indices, expect_layout) in cases {
            let n: usize = shape.iter().product();
            let values: Vec<f32> = indices.iter().map(|&i| i as f32 * 0.5 - 3.0).collect();
            let t = sparse(shape, indices, values);
            let (layout, _) = compressed_layout(&t).unwrap();
            assert_eq!(layout, expect_layout, "n={n}");
            assert!(
                compressed_tensor_len(&t).unwrap() <= dense_len(n),
                "compressed exceeds dense for n={n}"
            );
            // Whatever the layout, reconstruction is preserved.
            let frame = compressed_frame(vec![t.clone()]);
            let bytes = encode_frame(&frame, WireFormat::F32).unwrap();
            assert_eq!(bytes.len(), encoded_frame_len(&frame, WireFormat::F32));
            let back = decode_frame(&bytes).unwrap().payload.into_compressed().unwrap();
            assert_eq!(back[0].tensors[0].decompress().unwrap(), t.decompress().unwrap());
        }
    }

    #[test]
    fn compressed_qsgd_roundtrip_and_packing() {
        for bits in [2u8, 3, 4, 7, 8] {
            let levels = crate::compress::qsgd_levels(bits);
            let n = 13;
            let codes: Vec<u8> = (0..n).map(|i| (i % (2 * levels as usize + 1)) as u8).collect();
            let t = CompressedTensor {
                shape: vec![n],
                repr: CompressedRepr::Qsgd { bits, scale: 1.75, codes: codes.clone() },
            };
            let frame = compressed_frame(vec![t.clone()]);
            let bytes = encode_frame(&frame, WireFormat::F32).unwrap();
            assert_eq!(bytes.len(), encoded_frame_len(&frame, WireFormat::F32));
            let back = decode_frame(&bytes).unwrap().payload.into_compressed().unwrap();
            match &back[0].tensors[0].repr {
                CompressedRepr::Qsgd { bits: b, scale, codes: c } => {
                    assert_eq!((*b, *scale), (bits, 1.75));
                    assert_eq!(c, &codes, "bits {bits}: packing mangled codes");
                }
                other => panic!("bits {bits}: {other:?}"),
            }
        }
        // A 1-element qsgd tensor falls back to dense (5 B header > 4 B).
        let t = CompressedTensor {
            shape: vec![1],
            repr: CompressedRepr::Qsgd { bits: 8, scale: 2.0, codes: vec![255] },
        };
        assert_eq!(compressed_layout(&t).unwrap().0, LAYOUT_DENSE);
        let frame = compressed_frame(vec![t.clone()]);
        let back = decode_frame(&encode_frame(&frame, WireFormat::F32).unwrap())
            .unwrap()
            .payload
            .into_compressed()
            .unwrap();
        assert_eq!(back[0].tensors[0].decompress().unwrap(), t.decompress().unwrap());
    }

    #[test]
    fn compressed_encoder_rejects_malformed_reprs() {
        // Out-of-range index.
        let bad = compressed_frame(vec![sparse(vec![4], vec![4], vec![1.0])]);
        assert!(encode_frame(&bad, WireFormat::F32).is_err());
        // Unsorted / duplicate indices.
        let bad = compressed_frame(vec![sparse(vec![4], vec![2, 1], vec![1.0, 2.0])]);
        assert!(encode_frame(&bad, WireFormat::F32).is_err());
        let bad = compressed_frame(vec![sparse(vec![4], vec![1, 1], vec![1.0, 2.0])]);
        assert!(encode_frame(&bad, WireFormat::F32).is_err());
        // Arity mismatch between indices and values.
        let bad = compressed_frame(vec![sparse(vec![4], vec![1], vec![1.0, 2.0])]);
        assert!(encode_frame(&bad, WireFormat::F32).is_err());
        // Bad qsgd bits.
        let bad = compressed_frame(vec![CompressedTensor {
            shape: vec![4],
            repr: CompressedRepr::Qsgd { bits: 9, scale: 1.0, codes: vec![0; 4] },
        }]);
        assert!(encode_frame(&bad, WireFormat::F32).is_err());
    }

    #[test]
    fn compressed_frames_reject_corruption_like_any_other() {
        let frame =
            compressed_frame(vec![sparse(vec![64], vec![3, 9, 60], vec![1.0, -2.0, 0.5])]);
        let good = encode_frame(&frame, WireFormat::F32).unwrap();
        let mut bad = good.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x10;
        assert!(decode_frame(&bad).is_err());
        assert!(decode_frame(&good[..good.len() - 2]).is_err());
    }
}
