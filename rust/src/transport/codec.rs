//! Versioned binary frame codec for the federated message protocol.
//!
//! Frame layout (all integers little-endian; see docs/WIRE.md):
//!
//! ```text
//! [ u32 frame_len ]                     length prefix: bytes that follow
//! [ "SF" u8 version u8 kind u8 wire ]   magic + protocol version + tags
//! [ u32 round ] [ u32 client ]          routing / bookkeeping
//! [ u32 payload_len ]
//! [ payload … ]
//! [ u32 crc32 ]                         over header + payload
//! ```
//!
//! Payload encoding: a tag byte (`0` segment list, `1` tensor, `2` empty),
//! then length-prefixed names and tensors. Each tensor carries its own
//! element-encoding tag (f32 raw / i32 raw / f16 / int8-affine), so a
//! decoder never needs out-of-band context. No serde: the offline registry
//! carries none, so this follows the `util/json.rs` hand-rolled precedent.

use anyhow::{anyhow, bail, Result};

use crate::comm::MsgKind;
use crate::model::SegmentParams;
use crate::runtime::{HostTensor, TensorData};

use super::crc32::crc32;
use super::encode::{decode_f32s, encode_f32s, encoded_f32_len, WireFormat};

/// Protocol version stamped into every frame header.
pub const WIRE_VERSION: u8 = 1;

const MAGIC: [u8; 2] = *b"SF";

/// Header bytes after the length prefix: magic(2) + version(1) + kind(1) +
/// wire(1) + round(4) + client(4) + payload_len(4).
pub const HEADER_LEN: usize = 17;

/// Fixed per-frame overhead: length prefix + header + CRC32 trailer.
pub const FRAME_OVERHEAD: usize = 4 + HEADER_LEN + 4;

/// Per-tensor element encodings (tagged in the payload, one per tensor).
const ENC_F32: u8 = 0;
const ENC_I32: u8 = 1;
const ENC_F16: u8 = 2;
const ENC_INT8: u8 = 3;

const PAYLOAD_SEGMENTS: u8 = 0;
const PAYLOAD_TENSOR: u8 = 1;
const PAYLOAD_EMPTY: u8 = 2;

/// Decode-side sanity cap: refuse frames claiming more elements than this
/// in a single tensor (256 Mi elements = 1 GiB of f32), so a corrupted
/// header cannot trigger a huge allocation before the CRC is even checked.
const MAX_ELEMENTS: usize = 1 << 28;
const MAX_RANK: usize = 8;

/// What a frame carries.
#[derive(Debug, Clone, PartialEq)]
pub enum Payload {
    /// Parameter segments, in protocol order (e.g. `[tail, prompt]`).
    Segments(Vec<SegmentParams>),
    /// A single activation/gradient tensor.
    Tensor(HostTensor),
    /// Control frames (e.g. `Abort`) carry no data.
    Empty,
}

impl Payload {
    pub fn into_tensor(self) -> Result<HostTensor> {
        match self {
            Payload::Tensor(t) => Ok(t),
            other => bail!("expected tensor payload, got {}", other.label()),
        }
    }

    pub fn into_segments(self) -> Result<Vec<SegmentParams>> {
        match self {
            Payload::Segments(s) => Ok(s),
            other => bail!("expected segments payload, got {}", other.label()),
        }
    }

    fn label(&self) -> &'static str {
        match self {
            Payload::Segments(_) => "segments",
            Payload::Tensor(_) => "tensor",
            Payload::Empty => "empty",
        }
    }
}

/// One protocol message: header fields + payload.
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    pub kind: MsgKind,
    pub round: u32,
    pub client: u32,
    pub payload: Payload,
}

impl Frame {
    pub fn new(kind: MsgKind, round: u32, client: u32, payload: Payload) -> Frame {
        Frame { kind, round, client, payload }
    }
}

// ----------------------------------------------------------------- encode

fn tensor_payload_len(t: &HostTensor, wire: WireFormat) -> usize {
    let data = match &t.data {
        TensorData::F32(v) => encoded_f32_len(wire, v.len()),
        TensorData::I32(v) => 4 * v.len(),
    };
    // enc tag + rank + dims + data
    2 + 4 * t.shape.len() + data
}

fn encode_tensor(t: &HostTensor, wire: WireFormat, out: &mut Vec<u8>) -> Result<()> {
    if t.shape.len() > MAX_RANK {
        bail!("tensor rank {} exceeds wire maximum {MAX_RANK}", t.shape.len());
    }
    for &d in &t.shape {
        if d > u32::MAX as usize {
            bail!("tensor dim {d} exceeds u32");
        }
    }
    match &t.data {
        TensorData::F32(v) => {
            out.push(match wire {
                WireFormat::F32 => ENC_F32,
                WireFormat::F16 => ENC_F16,
                WireFormat::Int8 => ENC_INT8,
            });
            out.push(t.shape.len() as u8);
            for &d in &t.shape {
                out.extend_from_slice(&(d as u32).to_le_bytes());
            }
            encode_f32s(wire, v, out);
        }
        TensorData::I32(v) => {
            out.push(ENC_I32);
            out.push(t.shape.len() as u8);
            for &d in &t.shape {
                out.extend_from_slice(&(d as u32).to_le_bytes());
            }
            out.reserve(v.len() * 4);
            for &x in v {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
    }
    Ok(())
}

fn encode_payload(payload: &Payload, wire: WireFormat, out: &mut Vec<u8>) -> Result<()> {
    match payload {
        Payload::Segments(segs) => {
            if segs.len() > u16::MAX as usize {
                bail!("too many segments ({})", segs.len());
            }
            out.push(PAYLOAD_SEGMENTS);
            out.extend_from_slice(&(segs.len() as u16).to_le_bytes());
            for sp in segs {
                let name = sp.segment.as_bytes();
                if name.len() > u16::MAX as usize {
                    bail!("segment name too long ({} bytes)", name.len());
                }
                if sp.tensors.len() > u16::MAX as usize {
                    bail!("segment {} has too many tensors", sp.segment);
                }
                out.extend_from_slice(&(name.len() as u16).to_le_bytes());
                out.extend_from_slice(name);
                out.extend_from_slice(&(sp.tensors.len() as u16).to_le_bytes());
                for t in &sp.tensors {
                    encode_tensor(t, wire, out)?;
                }
            }
        }
        Payload::Tensor(t) => {
            out.push(PAYLOAD_TENSOR);
            encode_tensor(t, wire, out)?;
        }
        Payload::Empty => out.push(PAYLOAD_EMPTY),
    }
    Ok(())
}

/// Exact encoded length of a frame without building it (accounting, tests).
pub fn encoded_frame_len(frame: &Frame, wire: WireFormat) -> usize {
    let payload = match &frame.payload {
        Payload::Segments(segs) => {
            1 + 2
                + segs
                    .iter()
                    .map(|sp| {
                        2 + sp.segment.len()
                            + 2
                            + sp.tensors.iter().map(|t| tensor_payload_len(t, wire)).sum::<usize>()
                    })
                    .sum::<usize>()
        }
        Payload::Tensor(t) => 1 + tensor_payload_len(t, wire),
        Payload::Empty => 1,
    };
    FRAME_OVERHEAD + payload
}

/// Serialise a frame. f32 tensor data is encoded under `wire`; i32 tensors
/// and all structure are unaffected by the wire format.
pub fn encode_frame(frame: &Frame, wire: WireFormat) -> Result<Vec<u8>> {
    let mut buf = Vec::with_capacity(encoded_frame_len(frame, wire));
    buf.extend_from_slice(&[0u8; 4]); // frame_len backpatched below
    buf.extend_from_slice(&MAGIC);
    buf.push(WIRE_VERSION);
    buf.push(frame.kind.code());
    buf.push(wire.code());
    buf.extend_from_slice(&frame.round.to_le_bytes());
    buf.extend_from_slice(&frame.client.to_le_bytes());
    buf.extend_from_slice(&[0u8; 4]); // payload_len backpatched below

    let payload_start = buf.len();
    encode_payload(&frame.payload, wire, &mut buf)?;
    let payload_len = buf.len() - payload_start;
    if payload_len > u32::MAX as usize {
        bail!("payload too large ({payload_len} bytes)");
    }
    buf[17..21].copy_from_slice(&(payload_len as u32).to_le_bytes());

    let crc = crc32(&buf[4..]);
    buf.extend_from_slice(&crc.to_le_bytes());
    let frame_len = buf.len() - 4;
    buf[0..4].copy_from_slice(&(frame_len as u32).to_le_bytes());
    Ok(buf)
}

// ----------------------------------------------------------------- decode

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("frame truncated at byte {} (need {n} more)", self.pos);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
}

fn decode_tensor(r: &mut Reader) -> Result<HostTensor> {
    let enc = r.u8()?;
    let rank = r.u8()? as usize;
    if rank > MAX_RANK {
        bail!("tensor rank {rank} exceeds wire maximum {MAX_RANK}");
    }
    let mut shape = Vec::with_capacity(rank);
    let mut elements = 1usize;
    for _ in 0..rank {
        let d = r.u32()? as usize;
        elements = elements
            .checked_mul(d)
            .ok_or_else(|| anyhow!("tensor shape overflows"))?;
        shape.push(d);
    }
    if elements > MAX_ELEMENTS {
        bail!("tensor claims {elements} elements (cap {MAX_ELEMENTS})");
    }
    match enc {
        ENC_I32 => {
            let bytes = r.take(elements * 4)?;
            let v = bytes
                .chunks_exact(4)
                .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            Ok(HostTensor::i32(shape, v))
        }
        ENC_F32 | ENC_F16 | ENC_INT8 => {
            let wire = match enc {
                ENC_F32 => WireFormat::F32,
                ENC_F16 => WireFormat::F16,
                _ => WireFormat::Int8,
            };
            let rest = &r.buf[r.pos..];
            let (v, used) = decode_f32s(wire, elements, rest)?;
            r.pos += used;
            Ok(HostTensor::f32(shape, v))
        }
        other => bail!("unknown tensor encoding tag {other}"),
    }
}

fn decode_payload(r: &mut Reader) -> Result<Payload> {
    match r.u8()? {
        PAYLOAD_SEGMENTS => {
            let count = r.u16()? as usize;
            let mut segs = Vec::with_capacity(count.min(64));
            for _ in 0..count {
                let name_len = r.u16()? as usize;
                let name = std::str::from_utf8(r.take(name_len)?)
                    .map_err(|_| anyhow!("segment name is not utf-8"))?
                    .to_string();
                let n_tensors = r.u16()? as usize;
                let mut tensors = Vec::with_capacity(n_tensors.min(1024));
                for _ in 0..n_tensors {
                    tensors.push(decode_tensor(r)?);
                }
                segs.push(SegmentParams { segment: name, tensors });
            }
            Ok(Payload::Segments(segs))
        }
        PAYLOAD_TENSOR => Ok(Payload::Tensor(decode_tensor(r)?)),
        PAYLOAD_EMPTY => Ok(Payload::Empty),
        other => bail!("unknown payload tag {other}"),
    }
}

/// Parse and verify one encoded frame (as produced by [`encode_frame`]).
/// Rejects bad magic, unknown versions, length mismatches, and CRC errors
/// before touching the payload. Quantized payloads decode back to f32.
pub fn decode_frame(buf: &[u8]) -> Result<Frame> {
    if buf.len() < FRAME_OVERHEAD {
        bail!("frame too short ({} bytes, minimum {FRAME_OVERHEAD})", buf.len());
    }
    let frame_len = u32::from_le_bytes(buf[0..4].try_into().unwrap()) as usize;
    if frame_len != buf.len() - 4 {
        bail!("frame length prefix {frame_len} != {} actual", buf.len() - 4);
    }
    if buf[4..6] != MAGIC {
        bail!("bad frame magic {:02x}{:02x}", buf[4], buf[5]);
    }
    if buf[6] != WIRE_VERSION {
        bail!("unsupported wire version {} (this build speaks {WIRE_VERSION})", buf[6]);
    }
    let kind = MsgKind::from_code(buf[7])?;
    // The header wire tag is informational (each tensor carries its own
    // encoding tag); validate it all the same so garbage is caught early.
    let _wire = WireFormat::from_code(buf[8])?;
    let round = u32::from_le_bytes(buf[9..13].try_into().unwrap());
    let client = u32::from_le_bytes(buf[13..17].try_into().unwrap());
    let payload_len = u32::from_le_bytes(buf[17..21].try_into().unwrap()) as usize;
    if 4 + HEADER_LEN + payload_len + 4 != buf.len() {
        bail!("payload length {payload_len} inconsistent with frame size {}", buf.len());
    }
    let crc_stored =
        u32::from_le_bytes(buf[buf.len() - 4..].try_into().unwrap());
    let crc_actual = crc32(&buf[4..buf.len() - 4]);
    if crc_stored != crc_actual {
        bail!("frame CRC mismatch (stored {crc_stored:08x}, computed {crc_actual:08x})");
    }

    let mut r = Reader { buf: &buf[21..buf.len() - 4], pos: 0 };
    let payload = decode_payload(&mut r)?;
    if r.pos != r.buf.len() {
        bail!("{} trailing payload bytes", r.buf.len() - r.pos);
    }
    Ok(Frame { kind, round, client, payload })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seg(name: &str, vals: &[f32]) -> SegmentParams {
        SegmentParams {
            segment: name.into(),
            tensors: vec![HostTensor::f32(vec![vals.len()], vals.to_vec())],
        }
    }

    fn sample_frame() -> Frame {
        Frame::new(
            MsgKind::Upload,
            3,
            12,
            Payload::Segments(vec![
                seg("tail", &[1.0, -2.5, 0.125, 9.0]),
                seg("prompt", &[0.5, 0.25]),
            ]),
        )
    }

    #[test]
    fn f32_roundtrip_is_identity() {
        let frame = sample_frame();
        let bytes = encode_frame(&frame, WireFormat::F32).unwrap();
        assert_eq!(bytes.len(), encoded_frame_len(&frame, WireFormat::F32));
        let back = decode_frame(&bytes).unwrap();
        assert_eq!(back, frame);
    }

    #[test]
    fn tensor_payload_roundtrip_with_i32() {
        let frame = Frame::new(
            MsgKind::SmashedData,
            0,
            1,
            Payload::Tensor(HostTensor::i32(vec![2, 2], vec![1, -2, 3, -4])),
        );
        let bytes = encode_frame(&frame, WireFormat::Int8).unwrap();
        // i32 tensors ignore the wire format.
        let back = decode_frame(&bytes).unwrap();
        assert_eq!(back, frame);
    }

    #[test]
    fn quantized_payloads_shrink_and_stay_close() {
        let vals: Vec<f32> = (0..512).map(|i| ((i as f32) * 0.017).sin()).collect();
        let frame =
            Frame::new(MsgKind::SmashedData, 1, 2, Payload::Tensor(HostTensor::f32(vec![512], vals.clone())));
        let f32_bytes = encode_frame(&frame, WireFormat::F32).unwrap();
        let f16_bytes = encode_frame(&frame, WireFormat::F16).unwrap();
        let int8_bytes = encode_frame(&frame, WireFormat::Int8).unwrap();
        assert!(f16_bytes.len() < f32_bytes.len());
        assert!(int8_bytes.len() < f16_bytes.len());
        let back = decode_frame(&int8_bytes).unwrap().payload.into_tensor().unwrap();
        let max_err = vals
            .iter()
            .zip(back.as_f32())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err <= 2.0 / 255.0, "max_err {max_err}");
    }

    #[test]
    fn rejects_corruption_truncation_and_version_skew() {
        let frame = sample_frame();
        let good = encode_frame(&frame, WireFormat::F32).unwrap();

        // Bit flip in the payload -> CRC error.
        let mut bad = good.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x40;
        assert!(decode_frame(&bad).is_err());

        // Truncated buffer.
        assert!(decode_frame(&good[..good.len() - 3]).is_err());

        // Wrong version (re-CRC so only the version check can fire).
        let mut skew = good.clone();
        skew[6] = 99;
        let crc = crc32(&skew[4..skew.len() - 4]);
        let n = skew.len();
        skew[n - 4..].copy_from_slice(&crc.to_le_bytes());
        let err = decode_frame(&skew).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");

        // Bad magic.
        let mut magic = good;
        magic[4] = b'X';
        assert!(decode_frame(&magic).is_err());
    }

    #[test]
    fn empty_payload_frames() {
        let frame = Frame::new(MsgKind::Abort, 9, 4, Payload::Empty);
        let bytes = encode_frame(&frame, WireFormat::F32).unwrap();
        assert_eq!(bytes.len(), FRAME_OVERHEAD + 1);
        assert_eq!(decode_frame(&bytes).unwrap(), frame);
    }
}
