//! CRC32 (IEEE 802.3, polynomial 0xEDB88320) — frame integrity checksum.
//!
//! Substrate: the offline registry has no `crc32fast`; this is the classic
//! byte-at-a-time table implementation, table built once on first use.

use std::sync::OnceLock;

static TABLE: OnceLock<[u32; 256]> = OnceLock::new();

fn table() -> &'static [u32; 256] {
    TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, entry) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *entry = c;
        }
        t
    })
}

/// CRC32 of `bytes` (init 0xFFFFFFFF, final xor 0xFFFFFFFF — the standard
/// zlib/PNG variant).
pub fn crc32(bytes: &[u8]) -> u32 {
    let t = table();
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = t[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Standard test vector: CRC32("123456789") = 0xCBF43926.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        // CRC32("a") = 0xE8B7BE43.
        assert_eq!(crc32(b"a"), 0xE8B7_BE43);
    }

    #[test]
    fn sensitive_to_single_bit_flips() {
        let base = crc32(b"sfprompt wire frame");
        let mut corrupted = b"sfprompt wire frame".to_vec();
        corrupted[5] ^= 0x01;
        assert_ne!(crc32(&corrupted), base);
    }
}
