//! Closed-form cost model — paper §3.5 / Table 1.
//!
//! Reproduces the per-global-round computational burden, communication
//! cost, and latency expressions for FL, SFL, and SFPrompt, in the paper's
//! own notation:
//!
//! * `|W|`  — model size (bytes), split as `|W_h| = α|W|`, `|W_b| = τ|W|`,
//!   `|W_t| = (1−α−τ)|W|`
//! * `q`    — cut-layer (smashed data) size per sample, bytes
//! * `|D|`  — local dataset size (samples), `γ` — retained fraction after
//!   EL2N pruning
//! * `U`    — local epochs per global round, `K` — selected clients,
//!   `R`    — shared link rate (bytes/s; effective R/K per client)
//! * `P_C`, `P_S` — client/server compute power, expressed in
//!   "param-bytes processed per second": updating model `W` on `D` takes
//!   `|D||W|/P` seconds, of which forward is the fraction `β`.
//!
//! One refinement relative to the printed table: the SFL smashed-data
//! traffic is multiplied by `U` (each local epoch crosses the cut layer),
//! which is exactly the effect the paper's own Figure 2 plots; the printed
//! table folds U into its Figure-2 discussion. SFPrompt's split-training
//! traffic is NOT multiplied by `U` because its local epochs are
//! local-loss updates that never touch the network — that asymmetry *is*
//! the contribution.

/// Inputs to the closed-form model.
#[derive(Debug, Clone, Copy)]
pub struct CostParams {
    pub w_bytes: f64,
    pub alpha: f64,
    pub tau: f64,
    /// retained fraction after pruning (γ in the paper)
    pub gamma: f64,
    /// prompt parameter bytes
    pub p_bytes: f64,
    /// cut-layer bytes per sample (q)
    pub q_bytes: f64,
    /// local dataset size (samples)
    pub d_samples: f64,
    pub clients: f64,       // K
    pub local_epochs: f64,  // U
    pub rate: f64,          // R, bytes/s
    pub p_client: f64,      // P_C, param-bytes/s
    pub p_server: f64,      // P_S
    pub beta: f64,          // forward fraction of a step
}

impl Default for CostParams {
    fn default() -> Self {
        CostParams {
            w_bytes: 391e6, // ViT-Base, paper Table 2
            alpha: 0.15,
            tau: 0.75,
            gamma: 0.4,
            p_bytes: 16.0 * 768.0 * 4.0,
            q_bytes: 197.0 * 768.0 * 4.0,
            // Back-solved from the paper's own Table 2: FL = 2|W|K = 3910 MB
            // and SFL ≈ 4q|D|UK ≈ 30.4 GB jointly pin |D| ≈ 250 samples.
            d_samples: 250.0,
            clients: 5.0,
            local_epochs: 10.0,
            rate: 12.5e6,
            p_client: 2e9,
            p_server: 200e9,
            beta: 1.0 / 3.0,
        }
    }
}

/// Per-round costs of one method.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RoundCost {
    /// per-client computational burden, param-bytes processed
    pub compute_client: f64,
    /// total bytes on the wire (all K clients)
    pub comm_bytes: f64,
    /// end-to-end latency, seconds
    pub latency_s: f64,
}

/// FL (FedSGD/FedAvg full fine-tune): exchange the whole model, train all
/// of it locally for U epochs.
pub fn fl(p: &CostParams) -> RoundCost {
    let comm = 2.0 * p.w_bytes * p.clients;
    let compute = p.d_samples * p.w_bytes * p.local_epochs;
    RoundCost {
        compute_client: compute,
        comm_bytes: comm,
        latency_s: comm / p.rate + compute / p.p_client,
    }
}

/// SFL (SplitFed): smashed data + gradients cross the network every local
/// epoch; the tail-sized client update is exchanged once per round.
pub fn sfl(p: &CostParams) -> RoundCost {
    let tail = (1.0 - p.alpha - p.tau) * p.w_bytes;
    let per_epoch_wire = 4.0 * p.q_bytes * p.d_samples;
    let comm = (per_epoch_wire * p.local_epochs + 2.0 * tail) * p.clients;
    let compute = (1.0 - p.tau) * p.d_samples * p.w_bytes * p.local_epochs;
    let server = p.tau * p.d_samples * p.w_bytes * p.clients * p.local_epochs / p.p_server;
    RoundCost {
        compute_client: compute,
        comm_bytes: comm,
        latency_s: comm / p.rate + compute / p.p_client + server,
    }
}

/// SFPrompt: local-loss epochs are network-free; only one pruned pass
/// crosses the cut layer per round; only tail+prompt aggregate.
pub fn sfprompt(p: &CostParams) -> RoundCost {
    let tail = (1.0 - p.alpha - p.tau) * p.w_bytes;
    // Distribution of the client model + aggregation of tail & prompt.
    let model_exchange = 2.0 * (tail + p.p_bytes);
    // One split-training pass over the γ-pruned dataset: 4 cut-layer
    // crossings per sample (smashed up, body-out down, grad up, grad down).
    let split_wire = 4.0 * p.q_bytes * p.gamma * p.d_samples;
    let comm = (split_wire + model_exchange) * p.clients;

    // Client compute: U local-loss epochs over the full local set on the
    // (head+tail) shortcut + one split pass over the pruned set + EL2N.
    let local = (1.0 - p.tau) * p.d_samples * p.w_bytes * p.local_epochs;
    let split_pass = (1.0 - p.tau) * p.gamma * p.d_samples * p.w_bytes;
    let el2n = p.beta * (1.0 - p.tau) * p.d_samples * p.w_bytes;
    let compute = local + split_pass + el2n;

    let server = p.tau * p.gamma * p.d_samples * p.w_bytes * p.clients / p.p_server;
    RoundCost {
        compute_client: compute,
        comm_bytes: comm,
        latency_s: comm / p.rate + compute / p.p_client + server,
    }
}

/// The paper's FL-advantage condition (§3.5): SFPrompt beats FL on
/// communication when `|W| > 2qγ|D| / (α + τ)`.
pub fn fl_crossover_w_bytes(p: &CostParams) -> f64 {
    2.0 * p.q_bytes * p.gamma * p.d_samples / (p.alpha + p.tau)
}

/// One point of the closed-form sweep (the `analyze` subcommand).
#[derive(Debug, Clone, Copy)]
pub struct SweepRow {
    pub w_mb: f64,
    pub local_epochs: f64,
    pub fl: RoundCost,
    pub sfl: RoundCost,
    pub sfprompt: RoundCost,
}

/// Sweep the closed-form cost model over model scale and local epochs:
/// |W| log-spaced from 10 MB to 10 GB (quarter-decade steps), at
/// U ∈ {1, 5, 10, 20}. All other parameters come from `base`.
pub fn sweep(base: &CostParams) -> Vec<SweepRow> {
    let mut rows = Vec::new();
    for &u in &[1.0, 5.0, 10.0, 20.0] {
        for i in 0..=12 {
            let w_bytes = 10e6 * 10f64.powf(i as f64 / 4.0);
            let p = CostParams { w_bytes, local_epochs: u, ..*base };
            rows.push(SweepRow {
                w_mb: w_bytes / 1e6,
                local_epochs: u,
                fl: fl(&p),
                sfl: sfl(&p),
                sfprompt: sfprompt(&p),
            });
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sfprompt_cheaper_than_sfl_on_comm() {
        let p = CostParams::default();
        assert!(sfprompt(&p).comm_bytes < sfl(&p).comm_bytes / 2.0);
    }

    #[test]
    fn sfprompt_cheaper_than_fl_for_large_models() {
        let p = CostParams::default(); // ViT-Base scale
        assert!(sfprompt(&p).comm_bytes < fl(&p).comm_bytes);
    }

    #[test]
    fn fl_wins_for_tiny_models() {
        let p = CostParams { w_bytes: 1e5, ..Default::default() };
        assert!(fl(&p).comm_bytes < sfprompt(&p).comm_bytes);
    }

    #[test]
    fn crossover_condition_matches_direct_comparison() {
        let mut p = CostParams::default();
        let w_star = fl_crossover_w_bytes(&p);
        // Just above the threshold SFPrompt should win on the split-wire
        // vs model-exchange tradeoff (ignoring the small prompt/tail terms
        // the closed form drops, hence the 1.5x margin).
        p.w_bytes = w_star * 1.5;
        assert!(sfprompt(&p).comm_bytes < fl(&p).comm_bytes);
        p.w_bytes = w_star * 0.2;
        assert!(sfprompt(&p).comm_bytes > fl(&p).comm_bytes);
    }

    #[test]
    fn sfl_comm_grows_with_local_epochs_but_fl_does_not() {
        let p1 = CostParams { local_epochs: 1.0, ..Default::default() };
        let p10 = CostParams { local_epochs: 10.0, ..Default::default() };
        assert!(sfl(&p10).comm_bytes > 5.0 * sfl(&p1).comm_bytes);
        assert_eq!(fl(&p10).comm_bytes, fl(&p1).comm_bytes);
        assert!((sfprompt(&p10).comm_bytes - sfprompt(&p1).comm_bytes).abs() < 1e-6);
    }

    #[test]
    fn split_methods_cut_client_compute() {
        let p = CostParams::default();
        assert!(sfl(&p).compute_client < fl(&p).compute_client / 2.0);
        assert!(sfprompt(&p).compute_client < fl(&p).compute_client / 2.0);
    }

    #[test]
    fn sweep_covers_the_grid_and_respects_the_crossover() {
        let base = CostParams::default();
        let rows = sweep(&base);
        assert_eq!(rows.len(), 4 * 13);
        assert!((rows[0].w_mb - 10.0).abs() < 1e-9);
        assert!((rows[12].w_mb - 10_000.0).abs() < 1e-6);
        // Deep into the large-model regime SFPrompt must beat FL on comm.
        let big = rows.iter().find(|r| r.w_mb > 5000.0 && r.local_epochs == 10.0).unwrap();
        assert!(big.sfprompt.comm_bytes < big.fl.comm_bytes);
        // All costs stay finite and non-negative across the grid.
        for r in &rows {
            for c in [r.fl, r.sfl, r.sfprompt] {
                assert!(c.comm_bytes.is_finite() && c.comm_bytes >= 0.0);
                assert!(c.latency_s.is_finite() && c.latency_s >= 0.0);
            }
        }
    }
}
