//! Baseline engines on the same substrate: FL (full fine-tune), SFL+FF,
//! SFL+Linear (paper §4.1).
//!
//! * **FL** — FedAvg full fine-tuning: the whole model crosses the network
//!   twice per round per client; all segments train locally for U epochs.
//! * **SFL+FF** — SplitFed with full fine-tuning: smashed data and
//!   gradients cross the cut layer every batch of every local epoch; the
//!   client model (head+tail) is exchanged for aggregation; the body
//!   trains on the server.
//! * **SFL+Linear** — SplitFed tuning only the classifier: activations
//!   still cross the cut layer every epoch (no gradient return needed
//!   since head and body are frozen).
//!
//! Like the SFPrompt engine, every message is serialised through the
//! `transport` codec over a channel pair (here driven synchronously — the
//! engine plays both endpoints), so `ByteMeter` records encoded frame
//! lengths, SFL's uplink payloads honour `FedConfig::wire`, and simulated
//! time is charged through the same fleet [`SimClock`] the SFPrompt engine
//! uses: per-client transfer bytes plus analytic client-compute FLOPs,
//! with availability and deadline/quorum round semantics (offline clients
//! are skipped outright; deadline-dropped clients' updates are discarded
//! and the loss means count survivors only). One modelling note for
//! SFL+FF: the server-side body updates as each client's gradients
//! arrive, so a later-dropped client's body contribution is not rolled
//! back — matching a real SplitFed server, which trains online. All
//! compute runs through the substrate-agnostic [`Backend`].
//!
//! Constructed only via [`super::RunBuilder`]; driven only through the
//! [`FederatedRun`] trait.

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::backend::{run_stage_hosts, Backend, TensorInputs};
use crate::comm::{ByteMeter, Direction, MsgKind};
use crate::compress::{decompress_update, UpdateCompressor};
use crate::data::{batch_indices, make_batch, SynthDataset};
use crate::metrics::{evaluate, RoundRecord, RunHistory};
use crate::model::{fedavg_multi, init_params, ParamSet, SegmentParams};
use crate::partition::partition;
use crate::runtime::HostTensor;
use crate::sim::{Fleet, RoundOutcome, SimClock};
use crate::telemetry::Ledger;
use crate::transport::{
    channel_pair, dense_segments_wire_len, encoded_frame_len, Frame, Payload, Transport,
    WireFormat,
};
use crate::util::rng::{seeds, Rng};

use super::client::Client;
use super::run::FederatedRun;
use super::{FedConfig, Method};

pub(crate) struct BaselineEngine<'a> {
    backend: &'a dyn Backend,
    fed: FedConfig,
    fleet: Fleet,
    method: Method,
    global: ParamSet,
    clients: Vec<Client>,
    rng: Rng,
    train: &'a SynthDataset,
    eval: Option<&'a SynthDataset>,
    history: RunHistory,
    /// Per-(round, client, kind) re-attribution of every `comm` record —
    /// kept in lock-step with the `ByteMeter` calls below so
    /// [`Ledger::reconcile`] holds bit-exactly.
    ledger: Ledger,
}

/// Deadline epilogue shared by both baseline rounds: resolve the round's
/// clock, FedAvg the surviving slots' updates into `global` (a
/// zero-survivor round leaves it untouched), and return the
/// survivor-filtered losses with the [`RoundOutcome`].
fn resolve_and_aggregate(
    global: &mut ParamSet,
    clock: &SimClock,
    updates: Vec<(usize, Vec<SegmentParams>, usize)>,
    slot_losses: Vec<(usize, Vec<f64>)>,
) -> Result<(Vec<f64>, RoundOutcome)> {
    let outcome = clock.finish();
    let per_client: Vec<(Vec<&SegmentParams>, usize)> = updates
        .iter()
        .filter(|(slot, _, _)| outcome.is_survivor(*slot))
        .map(|(_, segs, n)| (segs.iter().collect(), *n))
        .collect();
    if !per_client.is_empty() {
        let telemetry = crate::telemetry::active();
        let agg_span = telemetry.as_ref().map(|t| t.span("phase", "aggregate"));
        let agg_t0 = Instant::now();
        for seg in fedavg_multi(&per_client)? {
            global.set(seg);
        }
        drop(agg_span);
        if let Some(t) = &telemetry {
            t.metrics.observe("aggregate_s", agg_t0.elapsed().as_secs_f64());
        }
    }
    let losses = slot_losses
        .into_iter()
        .filter(|(slot, _)| outcome.is_survivor(*slot))
        .flat_map(|(_, l)| l)
        .collect();
    Ok((losses, outcome))
}

/// Pop a segments payload of exactly `names.len()` entries, validating the
/// protocol order; returns the segments in `names` order.
fn take_segments(payload: Payload, names: &[&str]) -> Result<Vec<SegmentParams>> {
    let segs = payload.into_segments()?;
    if segs.len() != names.len() {
        bail!("expected {} segments, got {}", names.len(), segs.len());
    }
    for (s, want) in segs.iter().zip(names) {
        if s.segment != *want {
            bail!("expected segment {want:?}, got {:?}", s.segment);
        }
    }
    Ok(segs)
}

impl<'a> BaselineEngine<'a> {
    pub(crate) fn new(
        backend: &'a dyn Backend,
        fed: FedConfig,
        method: Method,
        fleet: Fleet,
        train: &'a SynthDataset,
        eval: Option<&'a SynthDataset>,
    ) -> Self {
        assert_ne!(method, Method::SfPrompt, "use the SFPrompt engine for Method::SfPrompt");
        let mut rng = Rng::new(fed.seed);
        let labels = train.labels();
        let parts =
            partition(&labels, fed.num_clients, fed.partition, &mut rng.fork(seeds::PARTITION_FORK));
        let mut clients: Vec<Client> = parts
            .into_iter()
            .enumerate()
            .map(|(id, indices)| Client::new(id, indices, rng.fork(seeds::client_fork(id))))
            .collect();
        if !fed.compress.is_none() {
            for c in &mut clients {
                c.compress = Some(UpdateCompressor::new(
                    fed.compress,
                    seeds::compress_stream(fed.seed, c.id),
                ));
            }
        }
        let global = init_params(backend.manifest(), seeds::param_init(fed.seed));
        BaselineEngine {
            backend,
            fleet,
            fed,
            method,
            global,
            clients,
            rng,
            train,
            eval,
            history: RunHistory::default(),
            ledger: Ledger::new(),
        }
    }

    fn eval_maybe(&self, round: usize) -> Result<f64> {
        match self.eval {
            Some(ds) if self.fed.should_eval(round) => {
                evaluate(self.backend, "eval_forward_noprompt", &self.global, ds,
                         self.fed.eval_limit)
            }
            _ => Ok(f64::NAN),
        }
    }

    /// FL: full-model exchange + local full fine-tuning. FL has no split
    /// uplink payloads, so both directions stay at f32.
    fn round_fl(&mut self, round: usize) -> Result<RoundRecord> {
        let wall0 = Instant::now();
        let cfg = self.backend.manifest().config.clone();
        let train = self.train;
        let lr_t = HostTensor::scalar_f32(self.fed.lr);
        let r32 = round as u32;

        let counts: Vec<usize> = self.clients.iter().map(|c| c.num_samples()).collect();
        let selected = super::selection::select(
            self.fed.selection, self.fed.num_clients, self.fed.clients_per_round,
            &counts, round, &mut self.rng,
        );
        let mut comm = ByteMeter::default();
        let mut clock = self.fleet.begin_round(&selected);
        let mut slot_losses: Vec<(usize, Vec<f64>)> = Vec::new();
        let mut updates: Vec<(usize, Vec<SegmentParams>, usize)> = Vec::new();

        // The model every client receives this round — also the update
        // compression reference (FL's global only changes at aggregation,
        // after this loop).
        let dist_segs = vec![
            self.global.get("head")?.clone(),
            self.global.get("body")?.clone(),
            self.global.get("tail")?.clone(),
        ];

        for (slot, &cid) in selected.iter().enumerate() {
            if !clock.online(slot) {
                continue; // offline at round start: no traffic, no compute
            }
            // Baseline clients run inline on the driver thread, so the
            // observer's round span is on this thread's stack and implicit
            // parenting nests client spans correctly.
            let _client_span =
                crate::telemetry::active().map(|t| t.span("client", &format!("client:{cid}")));
            let mut losses = Vec::new();
            let (mut s_end, mut c_end) = channel_pair();

            // --- Downlink: the full model, over the wire. ---
            let payload = Payload::Segments(dist_segs.clone());
            let n = s_end
                .send(&Frame::new(MsgKind::FullModel, r32, cid as u32, payload), WireFormat::F32)?;
            comm.record(MsgKind::FullModel, Direction::Downlink, n);
            let dt = clock.charge_transfer(slot, n);
            self.ledger.tap(r32, cid as u32, MsgKind::FullModel, Direction::Downlink, n, n, dt);
            let (frame, _) = c_end.recv()?;
            let mut segs = take_segments(frame.payload, &["head", "body", "tail"])?;
            let mut tail = segs.pop().expect("tail");
            let mut body = segs.pop().expect("body");
            let mut head = segs.pop().expect("head");

            let client = &mut self.clients[cid];
            let n_k = client.num_samples();

            for _ in 0..self.fed.local_epochs {
                let mut order = client.indices.clone();
                client.rng.shuffle(&mut order);
                for chunk in batch_indices(&order, cfg.batch) {
                    let batch = make_batch(
                        &train.examples, &chunk, cfg.batch, cfg.image_size, cfg.channels,
                    );
                    let mut segs: BTreeMap<&str, &SegmentParams> = BTreeMap::new();
                    segs.insert("head", &head);
                    segs.insert("body", &body);
                    segs.insert("tail", &tail);
                    let mut tensors: TensorInputs = BTreeMap::new();
                    tensors.insert("images", &batch.images);
                    tensors.insert("labels", &batch.labels);
                    tensors.insert("lr", &lr_t);
                    let mut out = run_stage_hosts(self.backend, "full_step", &segs, &tensors)?;
                    losses.push(out.loss()? as f64);
                    head = out.take_segment("head")?;
                    body = out.take_segment("body")?;
                    tail = out.take_segment("tail")?;
                }
            }

            // --- Uplink: the updated full model (delta-compressed against
            // the distributed reference when configured). ---
            let payload = match self.clients[cid].compress.as_mut() {
                Some(comp) => Payload::Compressed(comp.compress_update(
                    &dist_segs.iter().collect::<Vec<_>>(),
                    &[&head, &body, &tail],
                )?),
                None => Payload::Segments(vec![head, body, tail]),
            };
            c_end.send(&Frame::new(MsgKind::FullModel, r32, cid as u32, payload), WireFormat::F32)?;
            let (frame, n) = s_end.recv()?;
            let segs = match frame.payload {
                Payload::Compressed(csegs) => {
                    let refs: Vec<&SegmentParams> = dist_segs.iter().collect();
                    decompress_update(&refs, &csegs)?
                }
                payload => take_segments(payload, &["head", "body", "tail"])?,
            };
            let raw = dense_segments_wire_len(&segs.iter().collect::<Vec<_>>());
            comm.record_with_raw(MsgKind::FullModel, Direction::Uplink, n, raw);
            let dt = clock.charge_transfer(slot, n);
            self.ledger.tap(r32, cid as u32, MsgKind::FullModel, Direction::Uplink, n, raw, dt);
            let compute_s = clock.charge_compute(
                slot,
                crate::flops::fl_client_round_flops(&cfg, n_k, self.fed.local_epochs),
            );
            self.ledger.tap_compute(r32, cid as u32, compute_s);
            clock.mark_done(slot);

            updates.push((slot, segs, n_k));
            slot_losses.push((slot, losses));
        }

        // --- Deadline resolution + FedAvg over survivors. ---
        let (losses, outcome) =
            resolve_and_aggregate(&mut self.global, &clock, updates, slot_losses)?;
        self.fleet.advance(outcome.latency_s);

        Ok(RoundRecord {
            round,
            mean_local_loss: f64::NAN,
            mean_split_loss: crate::util::stats::mean(&losses),
            eval_accuracy: self.eval_maybe(round)?,
            comm,
            wall_s: wall0.elapsed().as_secs_f64(),
            sim_latency_s: outcome.latency_s,
            clients: outcome.events,
        })
    }

    /// SFL (+FF or +Linear): split training every batch of every epoch.
    /// Uplink payloads (smashed, cut-layer gradients, the client-model
    /// upload) honour `FedConfig::wire`; downlink stays f32.
    fn round_sfl(&mut self, round: usize) -> Result<RoundRecord> {
        let wall0 = Instant::now();
        let cfg = self.backend.manifest().config.clone();
        let train = self.train;
        let lr_t = HostTensor::scalar_f32(self.fed.lr);
        let full_ft = self.method == Method::SflFullFinetune;
        let tail_stage = if full_ft { "tail_step_noprompt" } else { "tail_step_linear" };
        let wire = self.fed.wire;
        let r32 = round as u32;

        let counts: Vec<usize> = self.clients.iter().map(|c| c.num_samples()).collect();
        let selected = super::selection::select(
            self.fed.selection, self.fed.num_clients, self.fed.clients_per_round,
            &counts, round, &mut self.rng,
        );
        let mut comm = ByteMeter::default();
        let mut clock = self.fleet.begin_round(&selected);
        let mut slot_losses: Vec<(usize, Vec<f64>)> = Vec::new();
        let mut updates: Vec<(usize, Vec<SegmentParams>, usize)> = Vec::new();

        // The client model distributed this round — also the update
        // compression reference (head/tail only change at aggregation;
        // the server-side body trains online but never travels here).
        let dist_segs =
            vec![self.global.get("head")?.clone(), self.global.get("tail")?.clone()];

        for (slot, &cid) in selected.iter().enumerate() {
            if !clock.online(slot) {
                continue; // offline at round start: no traffic, no compute
            }
            let _client_span =
                crate::telemetry::active().map(|t| t.span("client", &format!("client:{cid}")));
            let mut losses = Vec::new();
            let (mut s_end, mut c_end) = channel_pair();

            // SFL distributes the client model (head+tail) each round.
            let payload = Payload::Segments(dist_segs.clone());
            let n = s_end.send(
                &Frame::new(MsgKind::ModelDistribution, r32, cid as u32, payload),
                WireFormat::F32,
            )?;
            comm.record(MsgKind::ModelDistribution, Direction::Downlink, n);
            let dt = clock.charge_transfer(slot, n);
            self.ledger.tap(
                r32, cid as u32, MsgKind::ModelDistribution, Direction::Downlink, n, n, dt,
            );
            let (frame, _) = c_end.recv()?;
            let mut segs = take_segments(frame.payload, &["head", "tail"])?;
            let mut tail = segs.pop().expect("tail");
            let mut head = segs.pop().expect("head");

            let client = &mut self.clients[cid];
            let n_k = client.num_samples();

            for _ in 0..self.fed.local_epochs {
                let mut order = client.indices.clone();
                client.rng.shuffle(&mut order);
                for chunk in batch_indices(&order, cfg.batch) {
                    let batch = make_batch(
                        &train.examples, &chunk, cfg.batch, cfg.image_size, cfg.channels,
                    );
                    // client: head forward; ship smashed data uplink.
                    let mut segs: BTreeMap<&str, &SegmentParams> = BTreeMap::new();
                    segs.insert("head", &head);
                    let mut tensors: TensorInputs = BTreeMap::new();
                    tensors.insert("images", &batch.images);
                    let mut out =
                        run_stage_hosts(self.backend, "head_forward_noprompt", &segs, &tensors)?;
                    let smashed = out.tensors.remove("smashed").expect("smashed");
                    c_end.send(
                        &Frame::new(MsgKind::SmashedData, r32, cid as u32, Payload::Tensor(smashed)),
                        wire,
                    )?;
                    let (frame, n) = s_end.recv()?;
                    let raw = encoded_frame_len(&frame, WireFormat::F32);
                    comm.record_with_raw(MsgKind::SmashedData, Direction::Uplink, n, raw);
                    let dt = clock.charge_transfer(slot, n);
                    self.ledger.tap(
                        r32, cid as u32, MsgKind::SmashedData, Direction::Uplink, n, raw, dt,
                    );
                    let server_smashed = frame.payload.into_tensor()?;

                    // server: body forward; ship activations downlink.
                    let body = self.global.get("body")?;
                    let mut segs: BTreeMap<&str, &SegmentParams> = BTreeMap::new();
                    segs.insert("body", body);
                    let mut tensors: TensorInputs = BTreeMap::new();
                    tensors.insert("smashed", &server_smashed);
                    let mut out =
                        run_stage_hosts(self.backend, "body_forward_noprompt", &segs, &tensors)?;
                    let body_out = out.tensors.remove("body_out").expect("body_out");
                    let n = s_end.send(
                        &Frame::new(MsgKind::BodyOutput, r32, cid as u32, Payload::Tensor(body_out)),
                        WireFormat::F32,
                    )?;
                    comm.record(MsgKind::BodyOutput, Direction::Downlink, n);
                    let dt = clock.charge_transfer(slot, n);
                    self.ledger.tap(
                        r32, cid as u32, MsgKind::BodyOutput, Direction::Downlink, n, n, dt,
                    );
                    let (frame, _) = c_end.recv()?;
                    let body_out = frame.payload.into_tensor()?;

                    // client: tail step.
                    let mut segs: BTreeMap<&str, &SegmentParams> = BTreeMap::new();
                    segs.insert("tail", &tail);
                    let mut tensors: TensorInputs = BTreeMap::new();
                    tensors.insert("body_out", &body_out);
                    tensors.insert("labels", &batch.labels);
                    tensors.insert("lr", &lr_t);
                    let mut out = run_stage_hosts(self.backend, tail_stage, &segs, &tensors)?;
                    losses.push(out.loss()? as f64);
                    tail = out.take_segment("tail")?;

                    if full_ft {
                        let g_body_out =
                            out.tensors.remove("g_body_out").expect("g_body_out");
                        c_end.send(
                            &Frame::new(
                                MsgKind::GradBodyOut, r32, cid as u32, Payload::Tensor(g_body_out),
                            ),
                            wire,
                        )?;
                        let (frame, n) = s_end.recv()?;
                        let raw = encoded_frame_len(&frame, WireFormat::F32);
                        comm.record_with_raw(MsgKind::GradBodyOut, Direction::Uplink, n, raw);
                        let dt = clock.charge_transfer(slot, n);
                        self.ledger.tap(
                            r32, cid as u32, MsgKind::GradBodyOut, Direction::Uplink, n, raw, dt,
                        );
                        let g_body_out = frame.payload.into_tensor()?;

                        // server: body backward + body update.
                        let body = self.global.get("body")?;
                        let mut segs: BTreeMap<&str, &SegmentParams> = BTreeMap::new();
                        segs.insert("body", body);
                        let mut tensors: TensorInputs = BTreeMap::new();
                        tensors.insert("smashed", &server_smashed);
                        tensors.insert("g_body_out", &g_body_out);
                        tensors.insert("lr", &lr_t);
                        let mut out =
                            run_stage_hosts(self.backend, "body_backward_train", &segs, &tensors)?;
                        let new_body = out.take_segment("body")?;
                        let g_smashed = out.tensors.remove("g_smashed").expect("g_smashed");
                        self.global.set(new_body);
                        let n = s_end.send(
                            &Frame::new(
                                MsgKind::GradSmashed, r32, cid as u32, Payload::Tensor(g_smashed),
                            ),
                            WireFormat::F32,
                        )?;
                        comm.record(MsgKind::GradSmashed, Direction::Downlink, n);
                        let dt = clock.charge_transfer(slot, n);
                        self.ledger.tap(
                            r32, cid as u32, MsgKind::GradSmashed, Direction::Downlink, n, n, dt,
                        );
                        let (frame, _) = c_end.recv()?;
                        let g_smashed = frame.payload.into_tensor()?;

                        // client: head update.
                        let mut segs: BTreeMap<&str, &SegmentParams> = BTreeMap::new();
                        segs.insert("head", &head);
                        let mut tensors: TensorInputs = BTreeMap::new();
                        tensors.insert("images", &batch.images);
                        tensors.insert("g_smashed", &g_smashed);
                        tensors.insert("lr", &lr_t);
                        let mut out = run_stage_hosts(self.backend, "head_step", &segs, &tensors)?;
                        head = out.take_segment("head")?;
                    }
                }
            }

            // --- Uplink: the client model, for aggregation
            // (delta-compressed against the distributed reference when
            // configured). ---
            let payload = match self.clients[cid].compress.as_mut() {
                Some(comp) => Payload::Compressed(comp.compress_update(
                    &dist_segs.iter().collect::<Vec<_>>(),
                    &[&head, &tail],
                )?),
                None => Payload::Segments(vec![head, tail]),
            };
            c_end.send(&Frame::new(MsgKind::Upload, r32, cid as u32, payload), wire)?;
            let (frame, n) = s_end.recv()?;
            let segs = match frame.payload {
                Payload::Compressed(csegs) => {
                    let refs: Vec<&SegmentParams> = dist_segs.iter().collect();
                    decompress_update(&refs, &csegs)?
                }
                payload => take_segments(payload, &["head", "tail"])?,
            };
            let raw = dense_segments_wire_len(&segs.iter().collect::<Vec<_>>());
            comm.record_with_raw(MsgKind::Upload, Direction::Uplink, n, raw);
            let dt = clock.charge_transfer(slot, n);
            self.ledger.tap(r32, cid as u32, MsgKind::Upload, Direction::Uplink, n, raw, dt);
            let compute_s = clock.charge_compute(
                slot,
                crate::flops::sfl_client_round_flops(&cfg, n_k, self.fed.local_epochs, full_ft),
            );
            self.ledger.tap_compute(r32, cid as u32, compute_s);
            clock.mark_done(slot);

            updates.push((slot, segs, n_k));
            slot_losses.push((slot, losses));
        }

        // --- Deadline resolution + FedAvg over survivors. ---
        let (losses, outcome) =
            resolve_and_aggregate(&mut self.global, &clock, updates, slot_losses)?;
        self.fleet.advance(outcome.latency_s);

        Ok(RoundRecord {
            round,
            mean_local_loss: f64::NAN,
            mean_split_loss: crate::util::stats::mean(&losses),
            eval_accuracy: self.eval_maybe(round)?,
            comm,
            wall_s: wall0.elapsed().as_secs_f64(),
            sim_latency_s: outcome.latency_s,
            clients: outcome.events,
        })
    }
}

impl FederatedRun for BaselineEngine<'_> {
    fn method(&self) -> Method {
        self.method
    }

    fn fed(&self) -> &FedConfig {
        &self.fed
    }

    fn round(&mut self, r: usize) -> Result<RoundRecord> {
        if r != self.history.rounds.len() {
            bail!(
                "rounds must run in order: expected round {}, got {r}",
                self.history.rounds.len()
            );
        }
        let rec = match self.method {
            Method::Fl => self.round_fl(r)?,
            Method::SflFullFinetune | Method::SflLinear => self.round_sfl(r)?,
            Method::SfPrompt => unreachable!("constructor rejects Method::SfPrompt"),
        };
        self.history.push(rec.clone());
        Ok(rec)
    }

    fn history(&self) -> &RunHistory {
        &self.history
    }

    fn comm_totals(&self) -> &ByteMeter {
        &self.history.total_comm
    }

    fn final_eval(&mut self) -> Result<f64> {
        match self.eval {
            Some(ds) => evaluate(
                self.backend, "eval_forward_noprompt", &self.global, ds, self.fed.eval_limit,
            ),
            None => Ok(f64::NAN),
        }
    }

    fn ledger(&self) -> Option<&Ledger> {
        Some(&self.ledger)
    }
}
