//! Server-side split-training operations: body forward/backward (Phase 2)
//! and parameter aggregation (Phase 3).

use std::collections::BTreeMap;

use anyhow::Result;

use crate::model::{fedavg_multi, SegmentParams};
use crate::runtime::{ArtifactStore, Executor, HostTensor, TensorInputs};

pub struct Server;

impl Server {
    /// Phase 2 server step A — forward the smashed data through the frozen
    /// body (held as pre-converted literals; it never changes in SFPrompt).
    pub fn body_forward(
        store: &ArtifactStore,
        body_lits: &[xla::Literal],
        smashed: &HostTensor,
    ) -> Result<HostTensor> {
        let mut segs: crate::runtime::SegmentInputs = BTreeMap::new();
        segs.insert("body", crate::runtime::SegInput::Literals(body_lits));
        let mut tensors: TensorInputs = BTreeMap::new();
        tensors.insert("smashed", smashed);
        let mut out = Executor::run_mixed(store, "body_forward", &segs, &tensors)?;
        Ok(out.tensors.remove("body_out").expect("body_out"))
    }

    /// Phase 2 server step B — backprop the client's cut-layer gradient
    /// through the frozen body; returns the gradient w.r.t. smashed data.
    pub fn body_backward(
        store: &ArtifactStore,
        body_lits: &[xla::Literal],
        smashed: &HostTensor,
        g_body_out: &HostTensor,
    ) -> Result<HostTensor> {
        let mut segs: crate::runtime::SegmentInputs = BTreeMap::new();
        segs.insert("body", crate::runtime::SegInput::Literals(body_lits));
        let mut tensors: TensorInputs = BTreeMap::new();
        tensors.insert("smashed", smashed);
        tensors.insert("g_body_out", g_body_out);
        let mut out = Executor::run_mixed(store, "body_backward", &segs, &tensors)?;
        Ok(out.tensors.remove("g_smashed").expect("g_smashed"))
    }

    /// Phase 3 — sample-count-weighted FedAvg of (tail, prompt) pairs
    /// (paper Eq. 3 with the n_k/N weights of Algorithm 2).
    pub fn aggregate(
        updates: &[(SegmentParams, SegmentParams, usize)],
    ) -> Result<(SegmentParams, SegmentParams)> {
        let per_client: Vec<(Vec<&SegmentParams>, usize)> =
            updates.iter().map(|(t, p, n)| (vec![t, p], *n)).collect();
        let mut out = fedavg_multi(&per_client)?;
        let prompt = out.pop().expect("prompt");
        let tail = out.pop().expect("tail");
        Ok((tail, prompt))
    }
}
