//! Server-side split-training operations: body forward/backward (Phase 2)
//! and parameter aggregation (Phase 3). The frozen body travels as an
//! opaque [`PreparedSegment`] handle; no substrate type leaks in.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::backend::{Backend, PreparedSegment, SegInput, SegmentInputs, TensorInputs};
use crate::model::{fedavg_multi, SegmentParams};
use crate::runtime::HostTensor;

pub struct Server;

impl Server {
    /// Phase 2 server step A — forward the smashed data through the frozen
    /// body (held as a prepared handle; it never changes in SFPrompt).
    pub fn body_forward(
        backend: &dyn Backend,
        body: &PreparedSegment,
        smashed: &HostTensor,
    ) -> Result<HostTensor> {
        let mut segs: SegmentInputs = BTreeMap::new();
        segs.insert("body", SegInput::Prepared(body));
        let mut tensors: TensorInputs = BTreeMap::new();
        tensors.insert("smashed", smashed);
        let mut out = backend.run_stage("body_forward", &segs, &tensors)?;
        Ok(out.tensors.remove("body_out").expect("body_out"))
    }

    /// [`Server::body_forward`] for several clients at once: one
    /// [`Backend::run_stage_batch`] call, which the native backend fuses
    /// into a single kernel invocation over the concatenated batch.
    /// Outputs are index-aligned and bit-identical to solo calls.
    pub fn body_forward_batch(
        backend: &dyn Backend,
        body: &PreparedSegment,
        smashed: &[&HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let mut segs: SegmentInputs = BTreeMap::new();
        segs.insert("body", SegInput::Prepared(body));
        let sets: Vec<TensorInputs> = smashed
            .iter()
            .map(|s| {
                let mut t: TensorInputs = BTreeMap::new();
                t.insert("smashed", *s);
                t
            })
            .collect();
        let outs = backend.run_stage_batch("body_forward", &segs, &sets)?;
        outs.into_iter()
            .map(|mut o| Ok(o.tensors.remove("body_out").expect("body_out")))
            .collect()
    }

    /// Phase 2 server step B — backprop the client's cut-layer gradient
    /// through the frozen body; returns the gradient w.r.t. smashed data.
    pub fn body_backward(
        backend: &dyn Backend,
        body: &PreparedSegment,
        smashed: &HostTensor,
        g_body_out: &HostTensor,
    ) -> Result<HostTensor> {
        let mut segs: SegmentInputs = BTreeMap::new();
        segs.insert("body", SegInput::Prepared(body));
        let mut tensors: TensorInputs = BTreeMap::new();
        tensors.insert("smashed", smashed);
        tensors.insert("g_body_out", g_body_out);
        let mut out = backend.run_stage("body_backward", &segs, &tensors)?;
        Ok(out.tensors.remove("g_smashed").expect("g_smashed"))
    }

    /// [`Server::body_backward`] for several clients at once (see
    /// [`Server::body_forward_batch`]).
    pub fn body_backward_batch(
        backend: &dyn Backend,
        body: &PreparedSegment,
        pairs: &[(&HostTensor, &HostTensor)],
    ) -> Result<Vec<HostTensor>> {
        let mut segs: SegmentInputs = BTreeMap::new();
        segs.insert("body", SegInput::Prepared(body));
        let sets: Vec<TensorInputs> = pairs
            .iter()
            .map(|(smashed, g_body_out)| {
                let mut t: TensorInputs = BTreeMap::new();
                t.insert("smashed", *smashed);
                t.insert("g_body_out", *g_body_out);
                t
            })
            .collect();
        let outs = backend.run_stage_batch("body_backward", &segs, &sets)?;
        outs.into_iter()
            .map(|mut o| Ok(o.tensors.remove("g_smashed").expect("g_smashed")))
            .collect()
    }

    /// Phase 3 — sample-count-weighted FedAvg of (tail, prompt) pairs
    /// (paper Eq. 3 with the n_k/N weights of Algorithm 2).
    pub fn aggregate(
        updates: &[(SegmentParams, SegmentParams, usize)],
    ) -> Result<(SegmentParams, SegmentParams)> {
        let per_client: Vec<(Vec<&SegmentParams>, usize)> =
            updates.iter().map(|(t, p, n)| (vec![t, p], *n)).collect();
        let mut out = fedavg_multi(&per_client)?;
        let prompt = out.pop().expect("prompt");
        let tail = out.pop().expect("tail");
        Ok((tail, prompt))
    }
}
