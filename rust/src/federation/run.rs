//! The unified run API: [`RunBuilder`] (the only way to construct an
//! engine) and the [`FederatedRun`] trait (the only way drivers talk to
//! one).
//!
//! The paper evaluates one orchestration loop against three baselines
//! under identical accounting; this module makes that symmetry a type.
//! A driver holds a `Box<dyn FederatedRun>` and neither knows nor cares
//! whether rounds run split training with prompts (SFPrompt), full
//! FedAvg (FL), or SplitFed (SFL+FF / SFL+Linear) — method variants are
//! a [`super::Method`] value plus a [`super::FedConfig`] delta, not a new
//! engine type.
//!
//! ```text
//! RunBuilder::new(method)         configure: FedConfig, wire, net model
//!     .rounds(10).clients(50, 5)  (validated: see `validate`)
//!     .build(&backend, &train, Some(&eval))?  -> Box<dyn FederatedRun>
//! driver::drive(run, observer)    round loop + event stream
//! ```
//!
//! `build` takes any [`Backend`] — the native kernel engine or the PJRT
//! artifact path — so engines are substrate-agnostic by construction.

use anyhow::{bail, Result};

use crate::backend::Backend;
use crate::comm::{ByteMeter, NetworkModel};
use crate::data::SynthDataset;
use crate::metrics::{RoundRecord, RunHistory};
use crate::partition::Partition;
use crate::sim::{Fleet, FleetSpec};
use crate::transport::WireFormat;

use super::baselines::BaselineEngine;
use super::engine::SfPromptEngine;
use super::{FedConfig, Method, Selection};

/// One federated training run, method-agnostic. Implemented by the
/// SFPrompt engine and the baseline engine; constructed by [`RunBuilder`].
///
/// Rounds must be executed in order (`round(0)`, `round(1)`, …); the
/// [`super::drive`] loop does this and streams events to an observer.
pub trait FederatedRun {
    /// Which method this run executes (for reporting).
    fn method(&self) -> Method;

    /// The validated federated configuration.
    fn fed(&self) -> &FedConfig;

    /// Execute global round `r` (select clients, run the method's phases
    /// over the simulated network) and return its metrics record. The
    /// record is also appended to [`FederatedRun::history`].
    fn round(&mut self, r: usize) -> Result<RoundRecord>;

    /// All rounds executed so far, with accumulated communication totals.
    fn history(&self) -> &RunHistory;

    /// Accumulated per-`MsgKind` measured bytes across all rounds so far.
    fn comm_totals(&self) -> &ByteMeter;

    /// One-time setup traffic outside the round loop (e.g. SFPrompt's
    /// initial frozen-head distribution). Zero for methods without any.
    fn setup_bytes(&self) -> u64 {
        0
    }

    /// Evaluate the current global model on the eval split (NaN when the
    /// run was built without one).
    fn final_eval(&mut self) -> Result<f64>;

    /// The per-(round, client, msg-kind) communication-cost ledger
    /// accumulated so far — a re-attribution of [`Self::comm_totals`]
    /// onto the paper's phase structure (docs/TRACING.md). `None` for
    /// engines that do not keep one.
    fn ledger(&self) -> Option<&crate::telemetry::Ledger> {
        None
    }
}

/// Validated, consuming builder — the only constructor for engines.
///
/// Defaults come from [`FedConfig::default`] (the paper's §4.1 setting)
/// and the shared-rate [`NetworkModel`] of §3.5 with `K` =
/// `clients_per_round` clients sharing the link. A [`FleetSpec`] replaces
/// that homogeneous model with heterogeneous devices/links, availability
/// traces, and deadline-based rounds (docs/FLEET.md); without one, time
/// accounting is bit-for-bit the legacy shared-rate clock.
#[derive(Debug, Clone)]
pub struct RunBuilder {
    method: Method,
    fed: FedConfig,
    net: Option<NetworkModel>,
    net_rate: Option<f64>,
    fleet: Option<FleetSpec>,
}

impl RunBuilder {
    pub fn new(method: Method) -> RunBuilder {
        RunBuilder { method, fed: FedConfig::default(), net: None, net_rate: None, fleet: None }
    }

    /// Replace the whole federated config at once.
    pub fn fed(mut self, fed: FedConfig) -> RunBuilder {
        self.fed = fed;
        self
    }

    pub fn rounds(mut self, rounds: usize) -> RunBuilder {
        self.fed.rounds = rounds;
        self
    }

    /// Fleet size and per-round cohort size (`K` of `N`).
    pub fn clients(mut self, total: usize, per_round: usize) -> RunBuilder {
        self.fed.num_clients = total;
        self.fed.clients_per_round = per_round;
        self
    }

    pub fn local_epochs(mut self, epochs: usize) -> RunBuilder {
        self.fed.local_epochs = epochs;
        self
    }

    pub fn lr(mut self, lr: f32) -> RunBuilder {
        self.fed.lr = lr;
        self
    }

    pub fn retain_fraction(mut self, retain: f64) -> RunBuilder {
        self.fed.retain_fraction = retain;
        self
    }

    pub fn local_loss_update(mut self, enabled: bool) -> RunBuilder {
        self.fed.local_loss_update = enabled;
        self
    }

    pub fn partition(mut self, partition: Partition) -> RunBuilder {
        self.fed.partition = partition;
        self
    }

    pub fn seed(mut self, seed: u64) -> RunBuilder {
        self.fed.seed = seed;
        self
    }

    pub fn selection(mut self, selection: Selection) -> RunBuilder {
        self.fed.selection = selection;
        self
    }

    pub fn wire(mut self, wire: WireFormat) -> RunBuilder {
        self.fed.wire = wire;
        self
    }

    /// Update-compression scheme for Phase-3 uploads (docs/COMPRESS.md):
    /// `Scheme::None` (default), top-k / rand-k sparsification with error
    /// feedback, or QSGD-style stochastic quantization.
    pub fn compress(mut self, scheme: crate::compress::Scheme) -> RunBuilder {
        self.fed.compress = scheme;
        self
    }

    pub fn eval_limit(mut self, limit: Option<usize>) -> RunBuilder {
        self.fed.eval_limit = limit;
        self
    }

    pub fn eval_every(mut self, every: usize) -> RunBuilder {
        self.fed.eval_every = every;
        self
    }

    /// Override the whole network model (rate and sharing factor).
    pub fn net(mut self, net: NetworkModel) -> RunBuilder {
        self.net = Some(net);
        self
    }

    /// Override only the shared link rate (bytes/s); the sharing factor
    /// stays `clients_per_round` per the paper's §3.5 model.
    pub fn net_rate(mut self, bytes_per_s: f64) -> RunBuilder {
        self.net_rate = Some(bytes_per_s);
        self
    }

    /// Simulate a heterogeneous fleet (devices, links, availability,
    /// deadlines) instead of the homogeneous shared-rate model. When set,
    /// `net`/`net_rate` are ignored — the fleet's link model wins.
    pub fn fleet(mut self, spec: FleetSpec) -> RunBuilder {
        self.fleet = Some(spec);
        self
    }

    /// Deadline-based rounds: aggregate whichever clients finish within
    /// `deadline_s` (doubling it until `min_quorum` make the cut). Applies
    /// to the configured fleet, or — when none is set — to the
    /// compute-free `ideal` fleet carrying this builder's resolved link
    /// rate as its shared pool, so `net`/`net_rate` overrides survive.
    pub fn deadline(mut self, deadline_s: f64, min_quorum: usize) -> RunBuilder {
        let spec = self.fleet.take().unwrap_or_else(|| FleetSpec {
            shared_pool_bytes_per_s: Some(self.resolved_net().rate_bytes_per_s),
            ..FleetSpec::named("ideal").expect("ideal preset")
        });
        self.fleet = Some(FleetSpec { deadline_s: Some(deadline_s), min_quorum, ..spec });
        self
    }

    /// The fleet spec this builder will simulate, if any.
    pub fn fleet_spec(&self) -> Option<&FleetSpec> {
        self.fleet.as_ref()
    }

    /// The config as currently accumulated (for inspection/reporting).
    pub fn fed_config(&self) -> &FedConfig {
        &self.fed
    }

    /// The network model [`RunBuilder::build`] will charge latency with.
    pub fn resolved_net(&self) -> NetworkModel {
        let mut net = self.net.unwrap_or(NetworkModel {
            sharing_clients: self.fed.clients_per_round,
            ..Default::default()
        });
        if let Some(rate) = self.net_rate {
            net.rate_bytes_per_s = rate;
        }
        net
    }

    /// Check every invariant the engines rely on. `build` calls this; it
    /// is public so specs can be checked without artifacts on disk.
    pub fn validate(&self) -> Result<()> {
        let f = &self.fed;
        if f.num_clients == 0 {
            bail!("num_clients must be at least 1");
        }
        if f.clients_per_round == 0 || f.clients_per_round > f.num_clients {
            bail!(
                "clients_per_round must be in 1..=num_clients, got {} of {}",
                f.clients_per_round,
                f.num_clients
            );
        }
        if f.rounds == 0 {
            bail!("rounds must be at least 1");
        }
        if f.local_epochs == 0 {
            bail!("local_epochs must be at least 1");
        }
        if f.retain_fraction.is_nan() || f.retain_fraction <= 0.0 || f.retain_fraction > 1.0 {
            bail!("retain_fraction must be in (0, 1], got {}", f.retain_fraction);
        }
        if !f.lr.is_finite() || f.lr <= 0.0 {
            bail!("lr must be positive and finite, got {}", f.lr);
        }
        if f.eval_every == 0 {
            bail!("eval_every must be at least 1");
        }
        f.compress.validate()?;
        if let Partition::Dirichlet { alpha } = f.partition {
            if !alpha.is_finite() || alpha <= 0.0 {
                bail!("dirichlet alpha must be positive and finite, got {alpha}");
            }
        }
        let net = self.resolved_net();
        if !net.rate_bytes_per_s.is_finite() || net.rate_bytes_per_s <= 0.0 {
            bail!("network rate must be positive and finite, got {}", net.rate_bytes_per_s);
        }
        if net.sharing_clients == 0 {
            bail!("network sharing_clients must be at least 1");
        }
        if let Some(fleet) = &self.fleet {
            fleet.validate()?;
            if fleet.min_quorum > f.clients_per_round {
                bail!(
                    "fleet min_quorum {} exceeds clients_per_round {} (the quorum can never \
                     be met)",
                    fleet.min_quorum,
                    f.clients_per_round
                );
            }
        }
        Ok(())
    }

    /// The fleet [`RunBuilder::build`] will charge simulated time through:
    /// the configured heterogeneous spec, or the legacy homogeneous
    /// shared-rate fleet.
    pub fn resolved_fleet(&self) -> Fleet {
        match &self.fleet {
            Some(spec) => Fleet::from_spec(spec.clone(), self.fed.num_clients, self.fed.seed),
            None => Fleet::homogeneous(self.resolved_net()),
        }
    }

    /// Stages a method's rounds execute — checked at `build` so a config
    /// lowered without the needed stage family (e.g. the sfprompt-only
    /// prompt-sweep configs) fails fast, not mid-round.
    fn required_stages(method: Method) -> &'static [&'static str] {
        match method {
            Method::SfPrompt => &[
                "local_step", "el2n_scores", "head_forward", "body_forward", "tail_step",
                "body_backward", "prompt_grad",
            ],
            Method::Fl => &["full_step"],
            Method::SflFullFinetune => &[
                "head_forward_noprompt", "body_forward_noprompt", "tail_step_noprompt",
                "body_backward_train", "head_step",
            ],
            Method::SflLinear => {
                &["head_forward_noprompt", "body_forward_noprompt", "tail_step_linear"]
            }
        }
    }

    /// Validate, partition `train` over the fleet, and construct the
    /// engine for `method` on `backend`. `eval` enables per-round accuracy
    /// points and [`FederatedRun::final_eval`].
    pub fn build<'a>(
        self,
        backend: &'a dyn Backend,
        train: &'a SynthDataset,
        eval: Option<&'a SynthDataset>,
    ) -> Result<Box<dyn FederatedRun + 'a>> {
        self.validate()?;
        if train.len() < self.fed.num_clients {
            bail!(
                "training set has {} samples for {} clients (every client needs at least one)",
                train.len(),
                self.fed.num_clients
            );
        }
        let manifest = backend.manifest();
        let missing: Vec<&str> = Self::required_stages(self.method)
            .iter()
            .copied()
            .filter(|s| !manifest.stages.contains_key(*s))
            .collect();
        if !missing.is_empty() {
            bail!(
                "config {:?} was lowered without the stages {} needs: missing {}",
                manifest.config.name,
                self.method.label(),
                missing.join(", ")
            );
        }
        let fleet = self.resolved_fleet();
        Ok(match self.method {
            Method::SfPrompt => {
                Box::new(SfPromptEngine::new(backend, self.fed, fleet, train, eval)?)
            }
            method => {
                Box::new(BaselineEngine::new(backend, self.fed, method, fleet, train, eval))
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> RunBuilder {
        RunBuilder::new(Method::SfPrompt)
    }

    #[test]
    fn default_builder_validates() {
        for method in
            [Method::SfPrompt, Method::Fl, Method::SflFullFinetune, Method::SflLinear]
        {
            RunBuilder::new(method).validate().unwrap();
        }
    }

    #[test]
    fn rejects_oversubscribed_cohort() {
        assert!(base().clients(4, 5).validate().is_err());
        assert!(base().clients(5, 0).validate().is_err());
        assert!(base().clients(0, 0).validate().is_err());
        assert!(base().clients(5, 5).validate().is_ok());
    }

    #[test]
    fn rejects_retain_fraction_outside_unit_interval() {
        for bad in [0.0, -0.5, 1.0001, f64::NAN, f64::INFINITY] {
            assert!(base().retain_fraction(bad).validate().is_err(), "{bad}");
        }
        assert!(base().retain_fraction(1.0).validate().is_ok());
        assert!(base().retain_fraction(1e-6).validate().is_ok());
    }

    #[test]
    fn rejects_zero_rounds_and_epochs() {
        assert!(base().rounds(0).validate().is_err());
        assert!(base().local_epochs(0).validate().is_err());
        assert!(base().eval_every(0).validate().is_err());
    }

    #[test]
    fn rejects_malformed_compress_schemes() {
        use crate::compress::Scheme;
        for bad in [
            Scheme::TopK { ratio: 0.0 },
            Scheme::TopK { ratio: 1.5 },
            Scheme::RandK { ratio: f64::NAN },
            Scheme::Quant { bits: 1 },
            Scheme::Quant { bits: 9 },
        ] {
            assert!(base().compress(bad).validate().is_err(), "{bad:?}");
        }
        assert!(base().compress(Scheme::TopK { ratio: 0.01 }).validate().is_ok());
        assert!(base().compress(Scheme::None).validate().is_ok());
    }

    #[test]
    fn rejects_bad_lr_alpha_and_net() {
        assert!(base().lr(0.0).validate().is_err());
        assert!(base().lr(-1.0).validate().is_err());
        assert!(base().lr(f32::NAN).validate().is_err());
        assert!(base()
            .partition(Partition::Dirichlet { alpha: 0.0 })
            .validate()
            .is_err());
        assert!(base().net_rate(0.0).validate().is_err());
        assert!(base().net_rate(-3.0).validate().is_err());
        assert!(base()
            .net(NetworkModel { rate_bytes_per_s: 1e6, sharing_clients: 0 })
            .validate()
            .is_err());
    }

    #[test]
    fn net_rate_override_keeps_sharing_factor() {
        let b = base().clients(40, 8).net_rate(2e6);
        let net = b.resolved_net();
        assert_eq!(net.sharing_clients, 8);
        assert!((net.rate_bytes_per_s - 2e6).abs() < 1e-9);
        b.validate().unwrap();
    }

    #[test]
    fn fleet_validation_runs_through_builder() {
        let mut bad = FleetSpec::named("uniform").unwrap();
        bad.dropout_p = 2.0;
        assert!(base().fleet(bad).validate().is_err());

        // Quorum can never exceed the per-round cohort.
        let mut spec = FleetSpec::named("two-tier").unwrap();
        spec.deadline_s = Some(10.0);
        spec.min_quorum = 6;
        assert!(base().clients(50, 5).fleet(spec.clone()).validate().is_err());
        spec.min_quorum = 5;
        assert!(base().clients(50, 5).fleet(spec).validate().is_ok());
    }

    #[test]
    fn deadline_defaults_to_ideal_fleet() {
        let b = base().deadline(12.5, 2);
        let spec = b.fleet_spec().expect("deadline implies a fleet");
        assert_eq!(spec.deadline_s, Some(12.5));
        assert_eq!(spec.min_quorum, 2);
        b.validate().unwrap();
        assert!(b.resolved_fleet().is_heterogeneous());
        // Without a fleet the resolved mode is the legacy homogeneous one.
        assert!(!base().resolved_fleet().is_heterogeneous());
    }

    #[test]
    fn full_net_override_wins() {
        let b = base().net(NetworkModel { rate_bytes_per_s: 5e5, sharing_clients: 3 });
        let net = b.resolved_net();
        assert_eq!(net.sharing_clients, 3);
        assert!((net.rate_bytes_per_s - 5e5).abs() < 1e-9);
    }
}
