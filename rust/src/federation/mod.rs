//! Federated split-training coordinator (L3, the paper's system).
//!
//! The public surface is the **unified run API**:
//!
//! * [`run`] — [`RunBuilder`] (validated, the only engine constructor)
//!   and the [`FederatedRun`] trait every engine implements, so drivers
//!   are method-agnostic.
//! * [`driver`] — the one round loop ([`drive`]) with its
//!   [`RoundObserver`] event stream (run/round/eval plus per-client
//!   `on_client_done` / `on_client_dropped` fleet events). Simulated time
//!   is charged through the fleet simulator ([`crate::sim`]): the
//!   homogeneous default reproduces the shared-rate [`LinkClock`] (§3.5)
//!   bit-for-bit, while a `FleetSpec` adds device heterogeneity,
//!   availability traces, and deadline-based rounds.
//! * [`spec`] — [`RunSpec`] (JSON in) / [`RunReport`] (JSON out) for
//!   headless `train --spec run.json --json` and data-driven experiments.
//!
//! Internals:
//!
//! * `client` — per-client state + Phase 1 (local-loss update, EL2N
//!   pruning) and the client half of Phase 2.
//! * `server` — the server half of Phase 2 (body forward/backward) and
//!   Phase 3 aggregation.
//! * `engine` — the SFPrompt global-round loop tying the phases together
//!   over the simulated network.
//! * `baselines` — FL (full fine-tune), SFL+FF, SFL+Linear on the same
//!   substrate, for Figures 4/6/7 and Tables 2/3.

mod baselines;
pub mod client;
pub mod driver;
pub(crate) mod engine;
pub mod run;
pub mod selection;
pub mod server;
pub mod spec;

pub use driver::{drive, LinkClock, NullObserver, ProgressPrinter, RoundObserver, Tee};
pub use run::{FederatedRun, RunBuilder};
pub use selection::Selection;
pub use spec::{RunReport, RunSpec};

use anyhow::{bail, Result};

use crate::partition::Partition;

/// Federated experiment configuration (paper §4.1 defaults).
#[derive(Debug, Clone, Copy)]
pub struct FedConfig {
    /// total clients in the fleet (paper: 50)
    pub num_clients: usize,
    /// clients sampled per round (paper: 5)
    pub clients_per_round: usize,
    /// local epochs per round (paper: 10)
    pub local_epochs: usize,
    /// global rounds
    pub rounds: usize,
    /// SGD learning rate for every step kind
    pub lr: f32,
    /// fraction of the local dataset RETAINED after EL2N pruning
    /// (the paper's pruning fraction γ prunes 1 − retain_fraction).
    pub retain_fraction: f64,
    /// run Phase-1 local-loss epochs (ablation switch, Fig 6)
    pub local_loss_update: bool,
    /// partitioning scheme
    pub partition: Partition,
    /// RNG seed for the whole run
    pub seed: u64,
    /// cap on eval samples per round (None = all)
    pub eval_limit: Option<usize>,
    /// evaluate every k rounds (always evaluates the last round)
    pub eval_every: usize,
    /// client-selection strategy (paper: uniform)
    pub selection: Selection,
    /// wire precision for uplink payloads (SmashedData, GradBodyOut,
    /// Upload); downlink and control traffic always travels as f32
    pub wire: crate::transport::WireFormat,
    /// update compression for Phase-3 uploads (`Upload`, and FL's uplink
    /// `FullModel`): none | topk | randk | quant, applied to the
    /// client-minus-reference delta with per-client error feedback for
    /// the sparsifiers (docs/COMPRESS.md)
    pub compress: crate::compress::Scheme,
}

impl FedConfig {
    /// Eval-scheduling policy, shared by every engine: evaluate every
    /// `eval_every` rounds, and always on the final round.
    pub fn should_eval(&self, round: usize) -> bool {
        round % self.eval_every == 0 || round + 1 == self.rounds
    }
}

impl Default for FedConfig {
    fn default() -> Self {
        FedConfig {
            num_clients: 50,
            clients_per_round: 5,
            local_epochs: 10,
            rounds: 10,
            lr: 0.05,
            retain_fraction: 0.4,
            local_loss_update: true,
            partition: Partition::Iid,
            seed: 17,
            eval_limit: Some(256),
            eval_every: 1,
            selection: Selection::Uniform,
            wire: crate::transport::WireFormat::F32,
            compress: crate::compress::Scheme::None,
        }
    }
}

/// Which method an engine run represents (for reporting).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    SfPrompt,
    Fl,
    SflFullFinetune,
    SflLinear,
}

impl Method {
    pub fn label(&self) -> &'static str {
        match self {
            Method::SfPrompt => "sfprompt",
            Method::Fl => "fl",
            Method::SflFullFinetune => "sfl_ff",
            Method::SflLinear => "sfl_linear",
        }
    }

    pub fn parse(s: &str) -> Result<Method> {
        Ok(match s {
            "sfprompt" => Method::SfPrompt,
            "fl" => Method::Fl,
            "sfl_ff" => Method::SflFullFinetune,
            "sfl_linear" => Method::SflLinear,
            other => bail!("unknown method {other:?} (known: sfprompt fl sfl_ff sfl_linear)"),
        })
    }
}
