//! Serializable run descriptions: [`RunSpec`] (JSON in) and [`RunReport`]
//! (JSON out), so `sfprompt train --spec run.json --json` works headlessly
//! and experiment cells are data, not code.
//!
//! A spec names everything a run needs — artifact config, synthetic
//! dataset profile, method, the full [`FedConfig`], dataset sizing, and an
//! optional link-rate override — and turns into a [`super::RunBuilder`]
//! plus generated datasets. A report carries the completed
//! [`RunHistory`] with per-`MsgKind` measured bytes. Non-finite floats
//! (`NaN` accuracy on eval-free rounds) serialize as `null`, so the
//! output is always strict JSON.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Result};

use crate::backend::{open_backend, Backend, BackendChoice};
use crate::compress::Scheme;
use crate::data::{synth, SynthDataset};
use crate::metrics::RunHistory;
use crate::partition::Partition;
use crate::runtime::ModelConfig;
use crate::sim::FleetSpec;
use crate::transport::WireFormat;
use crate::util::json::Json;
use crate::util::rng::seeds;

use super::run::RunBuilder;
use super::{FedConfig, Method, Selection};

/// A fully specified training run (the unit the experiment harness and
/// `train --spec` operate on).
#[derive(Debug, Clone)]
pub struct RunSpec {
    /// Model config name ("tiny", "small", …): a synthesized manifest on
    /// the native backend, a directory under `artifacts/` on PJRT.
    pub config: String,
    /// Synthetic dataset profile name (cifar10 | cifar100 | svhn | flower102).
    pub dataset: String,
    pub method: Method,
    /// Compute substrate ("native" default; "pjrt" needs artifacts).
    pub backend: BackendChoice,
    pub fed: FedConfig,
    pub samples_per_client: usize,
    pub eval_samples: usize,
    /// Optional §3.5 shared-link rate override, bytes/second.
    pub net_rate_bytes_per_s: Option<f64>,
    /// Optional heterogeneous fleet (devices, links, availability,
    /// deadline rounds — docs/FLEET.md). Absent ⇒ the homogeneous
    /// shared-rate fleet with pre-fleet time accounting, bit-for-bit.
    pub fleet: Option<FleetSpec>,
    /// Optional native-kernel worker count (CLI `--threads`). Absent ⇒
    /// `std::thread::available_parallelism()`. Any value yields
    /// byte-identical reports (docs/PERF.md determinism contract), so
    /// this knob is pure throughput and stays out of the JSON when unset.
    pub threads: Option<usize>,
}

impl RunSpec {
    /// A spec with the experiment-harness defaults (paper §4.1 federation,
    /// lr 0.08, 32 samples/client, 160 eval samples).
    pub fn new(config: &str, dataset: &str, method: Method) -> RunSpec {
        RunSpec {
            config: config.to_string(),
            dataset: dataset.to_string(),
            method,
            backend: BackendChoice::default(),
            // §4.1 defaults, with the harness's lr / eval-budget overrides.
            fed: FedConfig { lr: 0.08, eval_limit: Some(160), ..FedConfig::default() },
            samples_per_client: 32,
            eval_samples: 160,
            net_rate_bytes_per_s: None,
            fleet: None,
            threads: None,
        }
    }

    /// Construct the spec's compute substrate for its config, applying
    /// the spec's kernel thread count (process-global; `None` ⇒ auto).
    /// `artifacts_root` is only consulted by the PJRT backend.
    pub fn open_backend(&self, artifacts_root: &Path) -> Result<Box<dyn Backend>> {
        crate::backend::native::pool::set_threads(self.threads.unwrap_or(0));
        open_backend(self.backend, artifacts_root, &self.config)
    }

    /// The builder this spec resolves to (validation happens at `build`).
    pub fn builder(&self) -> RunBuilder {
        let mut b = RunBuilder::new(self.method).fed(self.fed);
        if let Some(rate) = self.net_rate_bytes_per_s {
            b = b.net_rate(rate);
        }
        if let Some(fleet) = &self.fleet {
            b = b.fleet(fleet.clone());
        }
        b
    }

    /// Generate the (train, eval) synthetic datasets for this spec under
    /// the model config's geometry. Train and eval share class prototypes
    /// (same proto seed) but draw disjoint samples.
    pub fn datasets(&self, cfg: &ModelConfig) -> Result<(SynthDataset, SynthDataset)> {
        if self.samples_per_client == 0 {
            bail!("samples_per_client must be at least 1");
        }
        if self.eval_samples == 0 {
            bail!("eval_samples must be at least 1 (accuracy over an empty split is meaningless)");
        }
        let mut profile = synth::profile(&self.dataset).ok_or_else(|| {
            anyhow!(
                "unknown dataset {:?} (known: {})",
                self.dataset,
                synth::PROFILES.iter().map(|p| p.name).collect::<Vec<_>>().join(" ")
            )
        })?;
        // The model config's class count wins (e.g. small=10, small_c100=100).
        profile.num_classes = cfg.num_classes;
        let n_train = self.fed.num_clients * self.samples_per_client;
        // Seed domains per the documented map in `util::rng::seeds`.
        let train = SynthDataset::generate(
            profile, cfg.image_size, cfg.channels, n_train,
            seeds::data_protos(self.fed.seed), seeds::data_train(self.fed.seed),
        );
        let eval = SynthDataset::generate(
            profile, cfg.image_size, cfg.channels, self.eval_samples,
            seeds::data_protos(self.fed.seed), seeds::data_eval(self.fed.seed),
        );
        Ok((train, eval))
    }

    /// Parse a spec from JSON text. Every key is optional (defaults are
    /// [`RunSpec::new`] with config "small" / dataset "cifar10" / method
    /// sfprompt); unknown keys are rejected so typos fail loudly.
    pub fn parse(text: &str) -> Result<RunSpec> {
        let v = Json::parse(text).map_err(|e| anyhow!("{e}"))?;
        RunSpec::from_json(&v)
    }

    pub fn from_json(v: &Json) -> Result<RunSpec> {
        const KNOWN: [&str; 23] = [
            "config", "dataset", "method", "backend", "rounds", "num_clients",
            "clients_per_round", "local_epochs", "lr", "retain_fraction", "local_loss_update",
            "partition", "seed", "eval_limit", "eval_every", "selection", "wire", "compress",
            "samples_per_client", "eval_samples", "net_rate_bytes_per_s", "fleet", "threads",
        ];
        let obj = v.as_obj().ok_or_else(|| anyhow!("run spec must be a JSON object"))?;
        for key in obj.keys() {
            if !KNOWN.contains(&key.as_str()) {
                bail!("unknown run-spec key {key:?} (known: {})", KNOWN.join(" "));
            }
        }
        let str_field = |key: &str, default: &str| -> Result<String> {
            match obj.get(key) {
                None => Ok(default.to_string()),
                Some(j) => j
                    .as_str()
                    .map(str::to_string)
                    .ok_or_else(|| anyhow!("spec key {key:?} must be a string")),
            }
        };
        let usize_field = |key: &str, default: usize| -> Result<usize> {
            match obj.get(key) {
                None => Ok(default),
                Some(j) => j
                    .as_usize()
                    .ok_or_else(|| anyhow!("spec key {key:?} must be a non-negative integer")),
            }
        };
        let f64_field = |key: &str, default: f64| -> Result<f64> {
            match obj.get(key) {
                None => Ok(default),
                Some(j) => {
                    j.as_f64().ok_or_else(|| anyhow!("spec key {key:?} must be a number"))
                }
            }
        };
        let bool_field = |key: &str, default: bool| -> Result<bool> {
            match obj.get(key) {
                None => Ok(default),
                Some(j) => {
                    j.as_bool().ok_or_else(|| anyhow!("spec key {key:?} must be a boolean"))
                }
            }
        };

        let config = str_field("config", "small")?;
        let dataset = str_field("dataset", "cifar10")?;
        let method = Method::parse(&str_field("method", "sfprompt")?)?;
        let mut spec = RunSpec::new(&config, &dataset, method);
        spec.backend = BackendChoice::parse(&str_field("backend", "native")?)?;
        let d = spec.fed; // defaults

        spec.fed.rounds = usize_field("rounds", d.rounds)?;
        spec.fed.num_clients = usize_field("num_clients", d.num_clients)?;
        spec.fed.clients_per_round = usize_field("clients_per_round", d.clients_per_round)?;
        spec.fed.local_epochs = usize_field("local_epochs", d.local_epochs)?;
        spec.fed.lr = f64_field("lr", d.lr as f64)? as f32;
        spec.fed.retain_fraction = f64_field("retain_fraction", d.retain_fraction)?;
        spec.fed.local_loss_update = bool_field("local_loss_update", d.local_loss_update)?;
        spec.fed.partition = match obj.get("partition") {
            None => d.partition,
            Some(j) => partition_from_json(j)?,
        };
        spec.fed.seed = match obj.get("seed") {
            None => d.seed,
            // Seeds above 2^53 don't survive f64; they travel as strings.
            Some(Json::Str(s)) => s
                .parse()
                .map_err(|_| anyhow!("spec key \"seed\" must be a non-negative integer"))?,
            Some(j) => j
                .as_i64()
                .and_then(|n| u64::try_from(n).ok())
                .ok_or_else(|| anyhow!("spec key \"seed\" must be a non-negative integer"))?,
        };
        spec.fed.eval_limit = match obj.get("eval_limit") {
            None => d.eval_limit,
            Some(Json::Null) => None,
            Some(j) => Some(
                j.as_usize()
                    .ok_or_else(|| anyhow!("spec key \"eval_limit\" must be an integer or null"))?,
            ),
        };
        spec.fed.eval_every = usize_field("eval_every", d.eval_every)?;
        spec.fed.selection = match obj.get("selection") {
            None => d.selection,
            Some(j) => Selection::parse(
                j.as_str()
                    .ok_or_else(|| anyhow!("spec key \"selection\" must be a string"))?,
            )?,
        };
        spec.fed.wire = match obj.get("wire") {
            None => d.wire,
            Some(j) => WireFormat::parse(
                j.as_str().ok_or_else(|| anyhow!("spec key \"wire\" must be a string"))?,
            )?,
        };
        spec.fed.compress = match obj.get("compress") {
            None => d.compress,
            Some(j) => Scheme::parse(
                j.as_str().ok_or_else(|| anyhow!("spec key \"compress\" must be a string"))?,
            )?,
        };
        spec.samples_per_client = usize_field("samples_per_client", spec.samples_per_client)?;
        spec.eval_samples = usize_field("eval_samples", spec.eval_samples)?;
        spec.net_rate_bytes_per_s = match obj.get("net_rate_bytes_per_s") {
            None | Some(Json::Null) => None,
            Some(j) => Some(j.as_f64().ok_or_else(|| {
                anyhow!("spec key \"net_rate_bytes_per_s\" must be a number or null")
            })?),
        };
        spec.fleet = match obj.get("fleet") {
            None | Some(Json::Null) => None,
            Some(j) => Some(FleetSpec::from_json(j)?),
        };
        spec.threads = match obj.get("threads") {
            None | Some(Json::Null) => None,
            Some(j) => match j.as_usize() {
                Some(n) if n >= 1 => Some(n),
                _ => bail!("spec key \"threads\" must be a positive integer or null"),
            },
        };
        Ok(spec)
    }

    pub fn to_json(&self) -> Json {
        let f = &self.fed;
        let mut o = BTreeMap::new();
        o.insert("config".to_string(), Json::Str(self.config.clone()));
        o.insert("dataset".to_string(), Json::Str(self.dataset.clone()));
        o.insert("method".to_string(), Json::Str(self.method.label().to_string()));
        o.insert("backend".to_string(), Json::Str(self.backend.label().to_string()));
        o.insert("rounds".to_string(), Json::Num(f.rounds as f64));
        o.insert("num_clients".to_string(), Json::Num(f.num_clients as f64));
        o.insert("clients_per_round".to_string(), Json::Num(f.clients_per_round as f64));
        o.insert("local_epochs".to_string(), Json::Num(f.local_epochs as f64));
        o.insert("lr".to_string(), Json::Num(f.lr as f64));
        o.insert("retain_fraction".to_string(), Json::Num(f.retain_fraction));
        o.insert("local_loss_update".to_string(), Json::Bool(f.local_loss_update));
        o.insert("partition".to_string(), partition_to_json(f.partition));
        o.insert(
            "seed".to_string(),
            // Seeds above 2^53 are not exact in f64; emit them as strings
            // so the report always reproduces the run it documents.
            if f.seed <= (1u64 << 53) {
                Json::Num(f.seed as f64)
            } else {
                Json::Str(f.seed.to_string())
            },
        );
        o.insert(
            "eval_limit".to_string(),
            f.eval_limit.map_or(Json::Null, |n| Json::Num(n as f64)),
        );
        o.insert("eval_every".to_string(), Json::Num(f.eval_every as f64));
        o.insert("selection".to_string(), Json::Str(f.selection.label().to_string()));
        o.insert("wire".to_string(), Json::Str(f.wire.label().to_string()));
        if !f.compress.is_none() {
            o.insert("compress".to_string(), Json::Str(f.compress.label()));
        }
        o.insert("samples_per_client".to_string(), Json::Num(self.samples_per_client as f64));
        o.insert("eval_samples".to_string(), Json::Num(self.eval_samples as f64));
        if let Some(rate) = self.net_rate_bytes_per_s {
            o.insert("net_rate_bytes_per_s".to_string(), Json::Num(rate));
        }
        if let Some(fleet) = &self.fleet {
            o.insert("fleet".to_string(), fleet.to_json());
        }
        if let Some(threads) = self.threads {
            o.insert("threads".to_string(), Json::Num(threads as f64));
        }
        Json::Obj(o)
    }
}

fn partition_from_json(v: &Json) -> Result<Partition> {
    if let Some(s) = v.as_str() {
        if s == "iid" {
            return Ok(Partition::Iid);
        }
        bail!("unknown partition {s:?} (use \"iid\" or {{\"dirichlet\": alpha}})");
    }
    if let Some(obj) = v.as_obj() {
        // Exactly {"dirichlet": alpha} — extra keys are typos, not knobs.
        if let (1, Some(alpha)) = (obj.len(), obj.get("dirichlet").and_then(Json::as_f64)) {
            return Ok(Partition::Dirichlet { alpha });
        }
    }
    bail!("partition must be \"iid\" or {{\"dirichlet\": alpha}}")
}

fn partition_to_json(p: Partition) -> Json {
    match p {
        Partition::Iid => Json::Str("iid".to_string()),
        Partition::Dirichlet { alpha } => {
            let mut o = BTreeMap::new();
            o.insert("dirichlet".to_string(), Json::Num(alpha));
            Json::Obj(o)
        }
    }
}

/// NaN/inf are not JSON; map them to null.
fn num_or_null(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Null
    }
}

/// The structured result of a completed run: the spec it ran under, the
/// per-round records, and the accumulated measured-byte totals broken
/// down per message kind.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub spec: RunSpec,
    pub setup_bytes: u64,
    pub history: RunHistory,
    /// Optional metrics summary from an installed [`crate::telemetry`]
    /// sink (counters, latency histograms, hottest stages), emitted under
    /// a `"telemetry"` key.
    pub telemetry: Option<Json>,
    /// Optional per-client health + anomaly rollup from a serving
    /// coordinator's [`crate::telemetry::HealthRegistry`], emitted under a
    /// `"health"` key. Like `wall_s`, this block is not part of the
    /// deterministic report contract: comparisons (`sfprompt diff`, the CI
    /// equality check) canonicalize it away.
    pub health: Option<Json>,
    /// Optional per-(round, client, msg-kind) communication-cost ledger
    /// (normally [`crate::telemetry::Ledger::to_json`]), emitted under a
    /// `"ledger"` key. A re-attribution of the measured `ByteMeter` data —
    /// its per-kind sums equal `comm.by_kind` exactly — but, carrying
    /// sim-clock transfer/compute seconds, it is canonicalized away by
    /// comparisons like `wall_s`/`health`/`telemetry`.
    pub ledger: Option<Json>,
}

impl RunReport {
    pub fn new(spec: &RunSpec, setup_bytes: u64, history: RunHistory) -> RunReport {
        RunReport {
            spec: spec.clone(),
            setup_bytes,
            history,
            telemetry: None,
            health: None,
            ledger: None,
        }
    }

    /// Attach a telemetry metrics block (normally
    /// [`crate::telemetry::MetricsRegistry::to_json`]) to the report.
    pub fn with_telemetry(mut self, telemetry: Json) -> RunReport {
        self.telemetry = Some(telemetry);
        self
    }

    /// Attach a health block (normally
    /// [`crate::telemetry::HealthRegistry::to_json`]) to the report.
    pub fn with_health(mut self, health: Json) -> RunReport {
        self.health = Some(health);
        self
    }

    /// Attach a communication-cost ledger block (normally
    /// [`crate::telemetry::Ledger::to_json`]) to the report.
    pub fn with_ledger(mut self, ledger: Json) -> RunReport {
        self.ledger = Some(ledger);
        self
    }

    pub fn to_json(&self) -> Json {
        let h = &self.history;
        let rounds: Vec<Json> = h
            .rounds
            .iter()
            .map(|r| {
                let mut o = BTreeMap::new();
                o.insert("round".to_string(), Json::Num(r.round as f64));
                o.insert("local_loss".to_string(), num_or_null(r.mean_local_loss));
                o.insert("split_loss".to_string(), num_or_null(r.mean_split_loss));
                o.insert("accuracy".to_string(), num_or_null(r.eval_accuracy));
                o.insert("bytes".to_string(), Json::Num(r.comm.total() as f64));
                o.insert("raw_bytes".to_string(), Json::Num(r.comm.raw_total() as f64));
                o.insert(
                    "compression_ratio".to_string(),
                    num_or_null(r.comm.compression_ratio()),
                );
                o.insert("messages".to_string(), Json::Num(r.comm.messages as f64));
                o.insert("sim_latency_s".to_string(), num_or_null(r.sim_latency_s));
                o.insert("wall_s".to_string(), num_or_null(r.wall_s));
                o.insert("survivors".to_string(), Json::Num(r.survivors() as f64));
                o.insert("dropped".to_string(), Json::Num(r.dropped() as f64));
                Json::Obj(o)
            })
            .collect();

        let by_kind: BTreeMap<String, Json> = h
            .total_comm
            .by_kind
            .iter()
            .map(|(kind, &bytes)| (kind.to_string(), Json::Num(bytes as f64)))
            .collect();
        let by_kind_raw: BTreeMap<String, Json> = h
            .total_comm
            .raw_by_kind
            .iter()
            .map(|(kind, &bytes)| (kind.to_string(), Json::Num(bytes as f64)))
            .collect();
        let mut comm = BTreeMap::new();
        comm.insert("total_bytes".to_string(), Json::Num(h.total_comm.total() as f64));
        comm.insert("raw_bytes".to_string(), Json::Num(h.total_comm.raw_total() as f64));
        comm.insert(
            "compression_ratio".to_string(),
            num_or_null(h.total_comm.compression_ratio()),
        );
        comm.insert("uplink_bytes".to_string(), Json::Num(h.total_comm.uplink as f64));
        comm.insert("downlink_bytes".to_string(), Json::Num(h.total_comm.downlink as f64));
        comm.insert("messages".to_string(), Json::Num(h.total_comm.messages as f64));
        comm.insert("setup_bytes".to_string(), Json::Num(self.setup_bytes as f64));
        comm.insert("by_kind".to_string(), Json::Obj(by_kind));
        comm.insert("by_kind_raw".to_string(), Json::Obj(by_kind_raw));

        let mut o = BTreeMap::new();
        o.insert("spec".to_string(), self.spec.to_json());
        o.insert("rounds".to_string(), Json::Arr(rounds));
        o.insert("comm".to_string(), Json::Obj(comm));
        o.insert("final_accuracy".to_string(), num_or_null(h.final_accuracy()));
        o.insert("best_accuracy".to_string(), num_or_null(h.best_accuracy()));
        o.insert(
            "sim_latency_s".to_string(),
            num_or_null(h.rounds.iter().map(|r| r.sim_latency_s).sum()),
        );
        o.insert("sim_wall_s".to_string(), num_or_null(h.sim_wall_s()));
        // Real measured wall-clock: the drive()-stamped whole-run figure
        // when available, otherwise the sum of per-round timings (histories
        // assembled without the driver, e.g. in tests).
        let wall_s = if h.run_wall_s > 0.0 {
            h.run_wall_s
        } else {
            h.rounds.iter().map(|r| r.wall_s).sum()
        };
        o.insert("wall_s".to_string(), num_or_null(wall_s));
        o.insert("dropped_clients".to_string(), Json::Num(h.dropped_clients() as f64));
        if let Some(t) = &self.telemetry {
            o.insert("telemetry".to_string(), t.clone());
        }
        if let Some(hh) = &self.health {
            o.insert("health".to_string(), hh.clone());
        }
        if let Some(l) = &self.ledger {
            o.insert("ledger".to_string(), l.clone());
        }
        Json::Obj(o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{ByteMeter, Direction, MsgKind};
    use crate::metrics::RoundRecord;

    #[test]
    fn run_spec_json_roundtrip() {
        let mut spec = RunSpec::new("small_c100", "cifar100", Method::SflLinear);
        spec.backend = BackendChoice::Pjrt;
        spec.fed.partition = Partition::Dirichlet { alpha: 0.25 };
        spec.fed.wire = WireFormat::Int8;
        spec.fed.compress = Scheme::TopK { ratio: 0.01 };
        spec.fed.selection = Selection::WeightedBySamples;
        spec.fed.eval_limit = None;
        spec.fed.rounds = 7;
        spec.fed.lr = 0.125;
        spec.fed.local_loss_update = false;
        spec.samples_per_client = 48;
        spec.net_rate_bytes_per_s = Some(2.5e6);

        let text = spec.to_json().to_string();
        let back = RunSpec::parse(&text).unwrap();
        assert_eq!(back.to_json(), spec.to_json());
        assert_eq!(back.method, Method::SflLinear);
        assert_eq!(back.backend, BackendChoice::Pjrt);
        assert_eq!(back.config, "small_c100");
        assert_eq!(back.fed.rounds, 7);
        assert_eq!(back.fed.wire, WireFormat::Int8);
        assert_eq!(back.fed.compress, Scheme::TopK { ratio: 0.01 });
        assert_eq!(back.fed.selection, Selection::WeightedBySamples);
        assert!(back.fed.eval_limit.is_none());
        assert!(!back.fed.local_loss_update);
        assert_eq!(back.fed.partition, Partition::Dirichlet { alpha: 0.25 });
        assert_eq!(back.net_rate_bytes_per_s, Some(2.5e6));
    }

    #[test]
    fn run_spec_defaults_apply_for_missing_keys() {
        let spec = RunSpec::parse(r#"{"method": "fl", "rounds": 3}"#).unwrap();
        assert_eq!(spec.method, Method::Fl);
        assert_eq!(spec.fed.rounds, 3);
        assert_eq!(spec.config, "small");
        assert_eq!(spec.dataset, "cifar10");
        assert_eq!(spec.fed.num_clients, 50);
        assert_eq!(spec.fed.eval_limit, Some(160));
        assert_eq!(spec.backend, BackendChoice::Native, "native is the default substrate");
        assert!(spec.net_rate_bytes_per_s.is_none());
        assert_eq!(spec.fed.compress, Scheme::None, "compression defaults off");
        assert!(
            !spec.to_json().to_string().contains("compress"),
            "scheme none stays out of the JSON"
        );
        spec.builder().validate().unwrap();

        let compressed = RunSpec::parse(r#"{"compress": "randk:0.05"}"#).unwrap();
        assert_eq!(compressed.fed.compress, Scheme::RandK { ratio: 0.05 });
        assert!(compressed.to_json().to_string().contains("\"compress\":\"randk:0.05\""));
    }

    #[test]
    fn run_spec_rejects_malformed_input() {
        assert!(RunSpec::parse("[1, 2]").is_err());
        assert!(RunSpec::parse(r#"{"rond": 3}"#).is_err(), "unknown key must fail");
        assert!(RunSpec::parse(r#"{"method": "sgd"}"#).is_err());
        assert!(RunSpec::parse(r#"{"backend": "cuda"}"#).is_err());
        assert!(RunSpec::parse(r#"{"partition": "zipf"}"#).is_err());
        assert!(RunSpec::parse(r#"{"wire": "bf16"}"#).is_err());
        assert!(RunSpec::parse(r#"{"compress": "topk"}"#).is_err());
        assert!(RunSpec::parse(r#"{"compress": "topk:0"}"#).is_err());
        assert!(RunSpec::parse(r#"{"compress": "quant:9"}"#).is_err());
        assert!(RunSpec::parse(r#"{"compress": 4}"#).is_err());
        assert!(RunSpec::parse(r#"{"rounds": "ten"}"#).is_err());
        assert!(RunSpec::parse(r#"{"rounds": -2}"#).is_err());
        assert!(RunSpec::parse("{").is_err());
    }

    #[test]
    fn run_spec_giant_seeds_roundtrip_exactly() {
        let mut spec = RunSpec::new("small", "cifar10", Method::SfPrompt);
        spec.fed.seed = u64::MAX;
        let back = RunSpec::parse(&spec.to_json().to_string()).unwrap();
        assert_eq!(back.fed.seed, u64::MAX);
        // Small seeds stay plain JSON numbers.
        spec.fed.seed = 17;
        assert!(spec.to_json().to_string().contains("\"seed\":17"));
        assert!(RunSpec::parse(r#"{"seed": -1}"#).is_err());
        assert!(RunSpec::parse(r#"{"seed": "not-a-number"}"#).is_err());
    }

    #[test]
    fn run_spec_fleet_roundtrips_and_rejects_garbage() {
        // Name form parses to the preset; serialization is the full object.
        let spec = RunSpec::parse(r#"{"fleet": "two-tier"}"#).unwrap();
        assert_eq!(spec.fleet, Some(FleetSpec::named("two-tier").unwrap()));
        let text = spec.to_json().to_string();
        assert!(text.contains("two_tier"), "{text}");
        let back = RunSpec::parse(&text).unwrap();
        assert_eq!(back.fleet, spec.fleet);
        assert_eq!(back.to_json(), spec.to_json());

        // Object form with deadline knobs.
        let spec = RunSpec::parse(
            r#"{"fleet": {"devices": {"pareto": {"scale": 1e10, "shape": 1.5}},
                          "dropout_p": 0.1, "deadline_s": 30.0, "min_quorum": 2}}"#,
        )
        .unwrap();
        let fleet = spec.fleet.as_ref().unwrap();
        assert_eq!(fleet.deadline_s, Some(30.0));
        assert_eq!(fleet.min_quorum, 2);
        spec.builder().validate().unwrap();
        assert_eq!(RunSpec::parse(&spec.to_json().to_string()).unwrap().fleet, spec.fleet);

        assert!(RunSpec::parse(r#"{"fleet": "quantum"}"#).is_err());
        assert!(RunSpec::parse(r#"{"fleet": {"dropout": 0.5}}"#).is_err());
        assert!(RunSpec::parse(r#"{"fleet": 7}"#).is_err());
        // No fleet key: no fleet in the spec or its JSON.
        let plain = RunSpec::parse("{}").unwrap();
        assert!(plain.fleet.is_none());
        assert!(!plain.to_json().to_string().contains("fleet"));
    }

    #[test]
    fn run_spec_threads_roundtrips_and_stays_out_when_unset() {
        let plain = RunSpec::parse("{}").unwrap();
        assert!(plain.threads.is_none());
        assert!(!plain.to_json().to_string().contains("threads"));

        let spec = RunSpec::parse(r#"{"threads": 4}"#).unwrap();
        assert_eq!(spec.threads, Some(4));
        let back = RunSpec::parse(&spec.to_json().to_string()).unwrap();
        assert_eq!(back.threads, Some(4));
        assert_eq!(back.to_json(), spec.to_json());

        assert_eq!(RunSpec::parse(r#"{"threads": null}"#).unwrap().threads, None);
        assert!(RunSpec::parse(r#"{"threads": 0}"#).is_err());
        assert!(RunSpec::parse(r#"{"threads": "many"}"#).is_err());
        assert!(RunSpec::parse(r#"{"threads": -3}"#).is_err());
    }

    #[test]
    fn run_spec_partition_forms() {
        let iid = RunSpec::parse(r#"{"partition": "iid"}"#).unwrap();
        assert_eq!(iid.fed.partition, Partition::Iid);
        let dir = RunSpec::parse(r#"{"partition": {"dirichlet": 0.1}}"#).unwrap();
        assert_eq!(dir.fed.partition, Partition::Dirichlet { alpha: 0.1 });
        // Extra keys inside the partition object are typos, not knobs.
        assert!(RunSpec::parse(r#"{"partition": {"dirichlet": 0.1, "alpha": 0.5}}"#).is_err());
        assert!(RunSpec::parse(r#"{"partition": {}}"#).is_err());
    }

    #[test]
    fn run_report_json_is_strict_and_nan_free() {
        let mut history = RunHistory::default();
        for (round, acc) in [(0usize, 0.5f64), (1, f64::NAN)] {
            let mut comm = ByteMeter::default();
            comm.record(MsgKind::SmashedData, Direction::Uplink, 100);
            comm.record(MsgKind::BodyOutput, Direction::Downlink, 60);
            history.push(RoundRecord {
                round,
                mean_local_loss: 1.5,
                mean_split_loss: 2.0,
                eval_accuracy: acc,
                comm,
                wall_s: 0.25,
                sim_latency_s: 0.5,
                clients: Vec::new(),
            });
        }
        let spec = RunSpec::new("tiny", "cifar10", Method::SfPrompt);
        let report = RunReport::new(&spec, 123, history);
        let text = report.to_json().to_string();
        assert!(!text.contains("NaN"), "{text}");

        let v = Json::parse(&text).unwrap();
        let rounds = v.get("rounds").unwrap().as_arr().unwrap();
        assert_eq!(rounds.len(), 2);
        assert_eq!(rounds[1].get("accuracy"), Some(&Json::Null));
        assert_eq!(rounds[0].get("accuracy").unwrap().as_f64(), Some(0.5));
        let comm = v.get("comm").unwrap();
        assert_eq!(comm.get("setup_bytes").unwrap().as_usize(), Some(123));
        assert_eq!(comm.get("total_bytes").unwrap().as_usize(), Some(320));
        assert_eq!(
            comm.get("raw_bytes").unwrap().as_usize(),
            Some(320),
            "plain records carry raw == wire"
        );
        assert_eq!(comm.get("compression_ratio").unwrap().as_f64(), Some(1.0));
        assert_eq!(
            comm.get("by_kind_raw").unwrap().get("smashed_data").unwrap().as_usize(),
            Some(200)
        );
        assert_eq!(rounds[0].get("raw_bytes").unwrap().as_usize(), Some(160));
        assert_eq!(rounds[0].get("compression_ratio").unwrap().as_f64(), Some(1.0));
        assert_eq!(
            comm.get("by_kind").unwrap().get("smashed_data").unwrap().as_usize(),
            Some(200)
        );
        assert_eq!(v.get("spec").unwrap().get("method").unwrap().as_str(), Some("sfprompt"));
        assert_eq!(v.get("final_accuracy"), Some(&Json::Null));
        assert_eq!(v.get("best_accuracy").unwrap().as_f64(), Some(0.5));
    }
}
