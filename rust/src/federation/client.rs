//! Client-side state machine: Phase 1 (self-update) + Phase 2 client half.
//!
//! [`client_split_round`] is the wire-level driver: it speaks the full
//! per-round protocol (model distribution → local phase → split batches →
//! upload → broadcast) over a [`Transport`], so it can run on its own
//! thread against the server hub — or against a loopback link in tests.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::comm::MsgKind;
use crate::data::{batch_indices, make_batch, Example};
use crate::model::SegmentParams;
use crate::runtime::{
    ArtifactStore, Executor, HostTensor, ModelConfig, SegInput, SegmentInputs, TensorInputs,
};
use crate::transport::{Frame, Payload, Transport};
use crate::util::rng::Rng;

use super::FedConfig;

/// A client: its local data partition and RNG stream. Model state (tail,
/// prompt) is delivered fresh each round by the server, per Algorithm 2.
/// The frozen head is held as pre-converted PJRT literals (perf fast path —
/// it never changes after the one-time distribution).
pub struct Client {
    pub id: usize,
    pub indices: Vec<usize>,
    pub rng: Rng,
    /// scratch for per-epoch shuffles (avoids an allocation per epoch)
    order: Vec<usize>,
}

/// Result of the Phase-1 local-loss update.
pub struct LocalUpdate {
    pub tail: SegmentParams,
    pub prompt: SegmentParams,
    pub mean_loss: f64,
    pub steps: usize,
    /// stage executions (for FLOPs accounting)
    pub batches: usize,
}

impl Client {
    pub fn new(id: usize, indices: Vec<usize>, rng: Rng) -> Client {
        let order = indices.clone();
        Client { id, indices, rng, order }
    }

    pub fn num_samples(&self) -> usize {
        self.indices.len()
    }

    /// Phase 1a — **local-loss update** (paper Eq. 1, Algorithm 1):
    /// connect W_h directly to W_t, run `epochs` SGD epochs over the FULL
    /// local dataset updating only (W_t, p). Zero network traffic.
    pub fn local_loss_update(
        &mut self,
        store: &ArtifactStore,
        examples: &[Example],
        head_lits: &[xla::Literal],
        mut tail: SegmentParams,
        mut prompt: SegmentParams,
        epochs: usize,
        lr: f32,
    ) -> Result<LocalUpdate> {
        let cfg = store.manifest.config.clone();
        let lr_t = HostTensor::scalar_f32(lr);
        let mut losses = Vec::new();
        let mut batches = 0usize;
        for _ in 0..epochs {
            self.rng.shuffle(&mut self.order);
            for chunk in batch_indices(&self.order, cfg.batch) {
                let batch =
                    make_batch(examples, &chunk, cfg.batch, cfg.image_size, cfg.channels);
                let mut segs: SegmentInputs = BTreeMap::new();
                segs.insert("head", SegInput::Literals(head_lits));
                segs.insert("tail", SegInput::Host(&tail));
                segs.insert("prompt", SegInput::Host(&prompt));
                let mut tensors: TensorInputs = BTreeMap::new();
                tensors.insert("images", &batch.images);
                tensors.insert("labels", &batch.labels);
                tensors.insert("lr", &lr_t);
                let mut out = Executor::run_mixed(store, "local_step", &segs, &tensors)?;
                losses.push(out.loss()? as f64);
                tail = out.take_segment("tail")?;
                prompt = out.take_segment("prompt")?;
                batches += 1;
            }
        }
        Ok(LocalUpdate {
            tail,
            prompt,
            mean_loss: crate::util::stats::mean(&losses),
            steps: losses.len(),
            batches,
        })
    }

    /// Phase 1b — **EL2N dataset pruning** (paper Eq. 2): score every local
    /// sample with `||softmax(f(x)) − onehot(y)||₂` through the W_h→W_t
    /// shortcut, keep the top `retain_fraction` by score (hard examples),
    /// per Paul et al. 2021. Returns retained indices (into the dataset).
    pub fn prune_dataset(
        &mut self,
        store: &ArtifactStore,
        examples: &[Example],
        head_lits: &[xla::Literal],
        tail: &SegmentParams,
        prompt: &SegmentParams,
        retain_fraction: f64,
    ) -> Result<Vec<usize>> {
        assert!((0.0..=1.0).contains(&retain_fraction));
        let cfg = store.manifest.config.clone();
        let mut scored: Vec<(usize, f32)> = Vec::with_capacity(self.indices.len());
        let mut seen = std::collections::BTreeSet::new();
        for chunk in batch_indices(&self.indices, cfg.batch) {
            let batch = make_batch(examples, &chunk, cfg.batch, cfg.image_size, cfg.channels);
            let mut segs: SegmentInputs = BTreeMap::new();
            segs.insert("head", SegInput::Literals(head_lits));
            segs.insert("tail", SegInput::Host(tail));
            segs.insert("prompt", SegInput::Host(prompt));
            let mut tensors: TensorInputs = BTreeMap::new();
            tensors.insert("images", &batch.images);
            tensors.insert("labels", &batch.labels);
            let out = Executor::run_mixed(store, "el2n_scores", &segs, &tensors)?;
            let scores = out.tensor("scores")?.as_f32().to_vec();
            // The tail of the final chunk is padding — dedupe by index.
            for (i, &idx) in chunk.iter().enumerate() {
                if seen.insert(idx) {
                    scored.push((idx, scores[i]));
                }
            }
        }
        // Keep the HIGHEST EL2N scores (most informative / hardest).
        scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let keep = ((self.indices.len() as f64 * retain_fraction).round() as usize)
            .clamp(1, self.indices.len());
        Ok(scored.into_iter().take(keep).map(|(i, _)| i).collect())
    }

    /// Phase 2 client step A — head forward on a pruned batch: produce the
    /// smashed data to ship to the server.
    pub fn head_forward(
        &self,
        store: &ArtifactStore,
        batch_images: &HostTensor,
        head_lits: &[xla::Literal],
        prompt: &SegmentParams,
    ) -> Result<HostTensor> {
        let mut segs: SegmentInputs = BTreeMap::new();
        segs.insert("head", SegInput::Literals(head_lits));
        segs.insert("prompt", SegInput::Host(prompt));
        let mut tensors: TensorInputs = BTreeMap::new();
        tensors.insert("images", batch_images);
        let mut out = Executor::run_mixed(store, "head_forward", &segs, &tensors)?;
        Ok(out.tensors.remove("smashed").expect("smashed"))
    }

    /// Phase 2 client step B — tail forward/backward + SGD on W_t; returns
    /// (loss, new tail, gradient w.r.t. body output to ship back).
    pub fn tail_step(
        &self,
        store: &ArtifactStore,
        body_out: &HostTensor,
        labels: &HostTensor,
        tail: &SegmentParams,
        lr: f32,
    ) -> Result<(f32, SegmentParams, HostTensor)> {
        let lr_t = HostTensor::scalar_f32(lr);
        let mut segs: BTreeMap<&str, &SegmentParams> = BTreeMap::new();
        segs.insert("tail", tail);
        let mut tensors: TensorInputs = BTreeMap::new();
        tensors.insert("body_out", body_out);
        tensors.insert("labels", labels);
        tensors.insert("lr", &lr_t);
        let mut out = Executor::run(store, "tail_step", &segs, &tensors)?;
        let loss = out.loss()?;
        let new_tail = out.take_segment("tail")?;
        let g = out.tensors.remove("g_body_out").expect("g_body_out");
        Ok((loss, new_tail, g))
    }

    /// Phase 2 client step C — backprop the returned cut-layer gradient
    /// through the frozen head into the prompt; returns the updated prompt.
    pub fn prompt_update(
        &self,
        store: &ArtifactStore,
        batch_images: &HostTensor,
        g_smashed: &HostTensor,
        head_lits: &[xla::Literal],
        prompt: &SegmentParams,
        lr: f32,
    ) -> Result<SegmentParams> {
        let lr_t = HostTensor::scalar_f32(lr);
        let mut segs: SegmentInputs = BTreeMap::new();
        segs.insert("head", SegInput::Literals(head_lits));
        segs.insert("prompt", SegInput::Host(prompt));
        let mut tensors: TensorInputs = BTreeMap::new();
        tensors.insert("images", batch_images);
        tensors.insert("g_smashed", g_smashed);
        tensors.insert("lr", &lr_t);
        let mut out = Executor::run_mixed(store, "prompt_grad", &segs, &tensors)?;
        out.take_segment("prompt")
    }
}

/// Losses a client reports back from one wire-driven round.
pub struct ClientRoundOutcome {
    pub local_losses: Vec<f64>,
    pub split_losses: Vec<f64>,
}

fn expect_kind(frame: &Frame, want: MsgKind, cid: u32) -> Result<()> {
    if frame.kind != want {
        bail!("client {cid}: expected {:?}, got {:?}", want, frame.kind);
    }
    Ok(())
}

/// Run one full SFPrompt round on the client side of a [`Transport`].
///
/// Protocol (client view): recv `ModelDistribution{tail, prompt}` → Phase 1
/// (local-loss epochs + EL2N pruning, network-free) → per pruned batch:
/// send `SmashedData`, recv `BodyOutput`, send `GradBodyOut`, recv
/// `GradSmashed` → send `Upload{tail, prompt}` → recv
/// `AggregateBroadcast`. Uplink payloads are encoded under `fed.wire`, so
/// quantization loss feeds back into training exactly as it would on a
/// real link.
pub fn client_split_round(
    client: &mut Client,
    store: &ArtifactStore,
    examples: &[Example],
    head_lits: &[xla::Literal],
    fed: &FedConfig,
    cfg: &ModelConfig,
    round: u32,
    link: &mut impl Transport,
) -> Result<ClientRoundOutcome> {
    let cid = client.id as u32;
    let wire = fed.wire;

    // --- Round start: receive the aggregated (W_t, p). ---
    let (frame, _) = link.recv()?;
    expect_kind(&frame, MsgKind::ModelDistribution, cid)?;
    let mut segs = frame.payload.into_segments()?;
    if segs.len() != 2 || segs[0].segment != "tail" || segs[1].segment != "prompt" {
        bail!(
            "client {cid}: malformed model distribution ({:?})",
            segs.iter().map(|s| s.segment.as_str()).collect::<Vec<_>>()
        );
    }
    let mut prompt = segs.pop().expect("prompt");
    let mut tail = segs.pop().expect("tail");

    let mut local_losses = Vec::new();
    let mut split_losses = Vec::new();

    // --- Phase 1a: local-loss update (network-free). ---
    if fed.local_loss_update {
        let upd = client.local_loss_update(
            store, examples, head_lits, tail, prompt, fed.local_epochs, fed.lr,
        )?;
        local_losses.push(upd.mean_loss);
        tail = upd.tail;
        prompt = upd.prompt;
    }

    // --- Phase 1b: EL2N pruning. ---
    let pruned =
        client.prune_dataset(store, examples, head_lits, &tail, &prompt, fed.retain_fraction)?;

    // --- Phase 2: split training over the pruned set. ---
    for chunk in batch_indices(&pruned, cfg.batch) {
        let batch = make_batch(examples, &chunk, cfg.batch, cfg.image_size, cfg.channels);
        let smashed = client.head_forward(store, &batch.images, head_lits, &prompt)?;
        link.send(
            &Frame::new(MsgKind::SmashedData, round, cid, Payload::Tensor(smashed)),
            wire,
        )?;

        let (frame, _) = link.recv()?;
        expect_kind(&frame, MsgKind::BodyOutput, cid)?;
        let body_out = frame.payload.into_tensor()?;

        let (loss, new_tail, g_body_out) =
            client.tail_step(store, &body_out, &batch.labels, &tail, fed.lr)?;
        split_losses.push(loss as f64);
        tail = new_tail;
        link.send(
            &Frame::new(MsgKind::GradBodyOut, round, cid, Payload::Tensor(g_body_out)),
            wire,
        )?;

        let (frame, _) = link.recv()?;
        expect_kind(&frame, MsgKind::GradSmashed, cid)?;
        let g_smashed = frame.payload.into_tensor()?;
        prompt =
            client.prompt_update(store, &batch.images, &g_smashed, head_lits, &prompt, fed.lr)?;
    }

    // --- Phase 3: upload for aggregation, wait for the broadcast. ---
    link.send(
        &Frame::new(MsgKind::Upload, round, cid, Payload::Segments(vec![tail, prompt])),
        wire,
    )?;
    let (frame, _) = link.recv()?;
    expect_kind(&frame, MsgKind::AggregateBroadcast, cid)?;

    Ok(ClientRoundOutcome { local_losses, split_losses })
}
