//! Client-side state machine: Phase 1 (self-update) + Phase 2 client half.
//!
//! [`client_split_round`] is the wire-level driver: it speaks the full
//! per-round protocol (model distribution → local phase → split batches →
//! upload → broadcast) over a [`Transport`], so it can run on its own
//! thread against the server hub — or against a loopback link in tests.
//!
//! All compute goes through the substrate-agnostic [`Backend`]: the
//! frozen head travels as an opaque [`PreparedSegment`] handle, so this
//! module neither knows nor cares whether stages run on the native kernel
//! engine or PJRT executables.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::backend::{Backend, PreparedSegment, SegInput, SegmentInputs, TensorInputs};
use crate::comm::MsgKind;
use crate::compress::UpdateCompressor;
use crate::data::{batch_indices, make_batch, Example};
use crate::model::SegmentParams;
use crate::partition::partition;
use crate::runtime::{HostTensor, ModelConfig};
use crate::transport::{Frame, Payload, Transport};
use crate::util::rng::{seeds, Rng};

use super::FedConfig;

/// Build the full client fleet for a run: partition `labels` and fork
/// each client's RNG stream, in the **one canonical order** every replica
/// of the run must follow (`Rng::fork` mutates the parent, so fork order
/// is part of the run's identity). Returns the fleet and the post-fork
/// parent RNG (whose next draws are the selection stream).
///
/// Both the in-process engine and a remote `net::client` process call
/// this, which is what makes a networked run bit-identical to the same
/// spec run locally: process boundaries change *where* a client computes,
/// never *what* it draws.
pub(crate) fn build_clients(fed: &FedConfig, labels: &[i32]) -> (Vec<Client>, Rng) {
    let mut rng = Rng::new(fed.seed);
    let parts =
        partition(labels, fed.num_clients, fed.partition, &mut rng.fork(seeds::PARTITION_FORK));
    let mut clients: Vec<Client> = parts
        .into_iter()
        .enumerate()
        .map(|(id, indices)| Client::new(id, indices, rng.fork(seeds::client_fork(id))))
        .collect();
    if !fed.compress.is_none() {
        for c in &mut clients {
            c.compress = Some(UpdateCompressor::new(
                fed.compress,
                seeds::compress_stream(fed.seed, c.id),
            ));
        }
    }
    (clients, rng)
}

/// A client: its local data partition and RNG stream. Model state (tail,
/// prompt) is delivered fresh each round by the server, per Algorithm 2.
/// The frozen head is held as a backend-prepared handle (perf fast path —
/// it never changes after the one-time distribution).
pub struct Client {
    pub id: usize,
    pub indices: Vec<usize>,
    pub rng: Rng,
    /// Update compressor + error-feedback residuals for Phase-3 uploads;
    /// `None` under `Scheme::None`. Engine-installed at construction, so
    /// residuals persist across every round this client is selected in.
    pub compress: Option<UpdateCompressor>,
    /// scratch for per-epoch shuffles (avoids an allocation per epoch)
    order: Vec<usize>,
}

/// Result of the Phase-1 local-loss update.
pub struct LocalUpdate {
    pub tail: SegmentParams,
    pub prompt: SegmentParams,
    pub mean_loss: f64,
    pub steps: usize,
    /// stage executions (for FLOPs accounting)
    pub batches: usize,
}

/// Keep the `keep` highest-scoring indices. NaN scores (a diverged model)
/// sort below every finite score instead of panicking, so pruning
/// degrades gracefully: finite-scored examples win the retained slots.
pub fn top_k_by_score(mut scored: Vec<(usize, f32)>, keep: usize) -> Vec<usize> {
    scored.sort_by(|a, b| match (a.1.is_nan(), b.1.is_nan()) {
        (false, false) => b.1.total_cmp(&a.1),
        (true, false) => std::cmp::Ordering::Greater,
        (false, true) => std::cmp::Ordering::Less,
        (true, true) => std::cmp::Ordering::Equal,
    });
    scored.into_iter().take(keep).map(|(i, _)| i).collect()
}

impl Client {
    pub fn new(id: usize, indices: Vec<usize>, rng: Rng) -> Client {
        let order = indices.clone();
        Client { id, indices, rng, compress: None, order }
    }

    pub fn num_samples(&self) -> usize {
        self.indices.len()
    }

    /// Phase 1a — **local-loss update** (paper Eq. 1, Algorithm 1):
    /// connect W_h directly to W_t, run `epochs` SGD epochs over the FULL
    /// local dataset updating only (W_t, p). Zero network traffic.
    pub fn local_loss_update(
        &mut self,
        backend: &dyn Backend,
        examples: &[Example],
        head: &PreparedSegment,
        mut tail: SegmentParams,
        mut prompt: SegmentParams,
        epochs: usize,
        lr: f32,
    ) -> Result<LocalUpdate> {
        let cfg = backend.manifest().config.clone();
        let lr_t = HostTensor::scalar_f32(lr);
        let mut losses = Vec::new();
        let mut batches = 0usize;
        for _ in 0..epochs {
            self.rng.shuffle(&mut self.order);
            for chunk in batch_indices(&self.order, cfg.batch) {
                let batch =
                    make_batch(examples, &chunk, cfg.batch, cfg.image_size, cfg.channels);
                let mut segs: SegmentInputs = BTreeMap::new();
                segs.insert("head", SegInput::Prepared(head));
                segs.insert("tail", SegInput::Host(&tail));
                segs.insert("prompt", SegInput::Host(&prompt));
                let mut tensors: TensorInputs = BTreeMap::new();
                tensors.insert("images", &batch.images);
                tensors.insert("labels", &batch.labels);
                tensors.insert("lr", &lr_t);
                let mut out = backend.run_stage("local_step", &segs, &tensors)?;
                losses.push(out.loss()? as f64);
                tail = out.take_segment("tail")?;
                prompt = out.take_segment("prompt")?;
                batches += 1;
            }
        }
        Ok(LocalUpdate {
            tail,
            prompt,
            mean_loss: crate::util::stats::mean(&losses),
            steps: losses.len(),
            batches,
        })
    }

    /// Phase 1b — **EL2N dataset pruning** (paper Eq. 2): score every local
    /// sample with `||softmax(f(x)) − onehot(y)||₂` through the W_h→W_t
    /// shortcut, keep the top `retain_fraction` by score (hard examples),
    /// per Paul et al. 2021. Returns retained indices (into the dataset).
    pub fn prune_dataset(
        &mut self,
        backend: &dyn Backend,
        examples: &[Example],
        head: &PreparedSegment,
        tail: &SegmentParams,
        prompt: &SegmentParams,
        retain_fraction: f64,
    ) -> Result<Vec<usize>> {
        assert!((0.0..=1.0).contains(&retain_fraction));
        let cfg = backend.manifest().config.clone();
        let mut scored: Vec<(usize, f32)> = Vec::with_capacity(self.indices.len());
        let mut seen = std::collections::BTreeSet::new();
        for chunk in batch_indices(&self.indices, cfg.batch) {
            let batch = make_batch(examples, &chunk, cfg.batch, cfg.image_size, cfg.channels);
            let mut segs: SegmentInputs = BTreeMap::new();
            segs.insert("head", SegInput::Prepared(head));
            segs.insert("tail", SegInput::Host(tail));
            segs.insert("prompt", SegInput::Host(prompt));
            let mut tensors: TensorInputs = BTreeMap::new();
            tensors.insert("images", &batch.images);
            tensors.insert("labels", &batch.labels);
            let out = backend.run_stage("el2n_scores", &segs, &tensors)?;
            let scores = out.tensor("scores")?.as_f32().to_vec();
            // The tail of the final chunk is padding — dedupe by index.
            for (i, &idx) in chunk.iter().enumerate() {
                if seen.insert(idx) {
                    scored.push((idx, scores[i]));
                }
            }
        }
        // Keep the HIGHEST EL2N scores (most informative / hardest).
        let keep = ((self.indices.len() as f64 * retain_fraction).round() as usize)
            .clamp(1, self.indices.len());
        Ok(top_k_by_score(scored, keep))
    }

    /// Phase 2 client step A — head forward on a pruned batch: produce the
    /// smashed data to ship to the server.
    pub fn head_forward(
        &self,
        backend: &dyn Backend,
        batch_images: &HostTensor,
        head: &PreparedSegment,
        prompt: &SegmentParams,
    ) -> Result<HostTensor> {
        let mut segs: SegmentInputs = BTreeMap::new();
        segs.insert("head", SegInput::Prepared(head));
        segs.insert("prompt", SegInput::Host(prompt));
        let mut tensors: TensorInputs = BTreeMap::new();
        tensors.insert("images", batch_images);
        let mut out = backend.run_stage("head_forward", &segs, &tensors)?;
        Ok(out.tensors.remove("smashed").expect("smashed"))
    }

    /// Phase 2 client step B — tail forward/backward + SGD on W_t; returns
    /// (loss, new tail, gradient w.r.t. body output to ship back).
    pub fn tail_step(
        &self,
        backend: &dyn Backend,
        body_out: &HostTensor,
        labels: &HostTensor,
        tail: &SegmentParams,
        lr: f32,
    ) -> Result<(f32, SegmentParams, HostTensor)> {
        let lr_t = HostTensor::scalar_f32(lr);
        let mut segs: SegmentInputs = BTreeMap::new();
        segs.insert("tail", SegInput::Host(tail));
        let mut tensors: TensorInputs = BTreeMap::new();
        tensors.insert("body_out", body_out);
        tensors.insert("labels", labels);
        tensors.insert("lr", &lr_t);
        let mut out = backend.run_stage("tail_step", &segs, &tensors)?;
        let loss = out.loss()?;
        let new_tail = out.take_segment("tail")?;
        let g = out.tensors.remove("g_body_out").expect("g_body_out");
        Ok((loss, new_tail, g))
    }

    /// Phase 2 client step C — backprop the returned cut-layer gradient
    /// through the frozen head into the prompt; returns the updated prompt.
    pub fn prompt_update(
        &self,
        backend: &dyn Backend,
        batch_images: &HostTensor,
        g_smashed: &HostTensor,
        head: &PreparedSegment,
        prompt: &SegmentParams,
        lr: f32,
    ) -> Result<SegmentParams> {
        let lr_t = HostTensor::scalar_f32(lr);
        let mut segs: SegmentInputs = BTreeMap::new();
        segs.insert("head", SegInput::Prepared(head));
        segs.insert("prompt", SegInput::Host(prompt));
        let mut tensors: TensorInputs = BTreeMap::new();
        tensors.insert("images", batch_images);
        tensors.insert("g_smashed", g_smashed);
        tensors.insert("lr", &lr_t);
        let mut out = backend.run_stage("prompt_grad", &segs, &tensors)?;
        out.take_segment("prompt")
    }
}

/// Losses a client reports back from one wire-driven round.
pub struct ClientRoundOutcome {
    pub local_losses: Vec<f64>,
    pub split_losses: Vec<f64>,
}

fn expect_kind(frame: &Frame, want: MsgKind, cid: u32) -> Result<()> {
    if frame.kind != want {
        bail!("client {cid}: expected {:?}, got {:?}", want, frame.kind);
    }
    Ok(())
}

/// Run one full SFPrompt round on the client side of a [`Transport`].
///
/// Protocol (client view): recv `ModelDistribution{tail, prompt}` → Phase 1
/// (local-loss epochs + EL2N pruning, network-free) → per pruned batch:
/// send `SmashedData`, recv `BodyOutput`, send `GradBodyOut`, recv
/// `GradSmashed` → send `Upload{tail, prompt}` → recv
/// `AggregateBroadcast`. Uplink payloads are encoded under `fed.wire`, so
/// quantization loss feeds back into training exactly as it would on a
/// real link.
#[allow(clippy::too_many_arguments)]
pub fn client_split_round(
    client: &mut Client,
    backend: &dyn Backend,
    examples: &[Example],
    head: &PreparedSegment,
    fed: &FedConfig,
    cfg: &ModelConfig,
    round: u32,
    link: &mut impl Transport,
) -> Result<ClientRoundOutcome> {
    let cid = client.id as u32;
    let wire = fed.wire;

    // --- Round start: receive the aggregated (W_t, p). ---
    let (frame, _) = link.recv()?;
    expect_kind(&frame, MsgKind::ModelDistribution, cid)?;
    let mut segs = frame.payload.into_segments()?;
    if segs.len() != 2 || segs[0].segment != "tail" || segs[1].segment != "prompt" {
        bail!(
            "client {cid}: malformed model distribution ({:?})",
            segs.iter().map(|s| s.segment.as_str()).collect::<Vec<_>>()
        );
    }
    let mut prompt = segs.pop().expect("prompt");
    let mut tail = segs.pop().expect("tail");
    // Update compression works on the delta against this round's
    // distributed reference; only clone it when a compressor is installed.
    let reference = client.compress.is_some().then(|| (tail.clone(), prompt.clone()));

    let mut local_losses = Vec::new();
    let mut split_losses = Vec::new();

    let telemetry = crate::telemetry::active();

    // --- Phase 1a: local-loss update (network-free). ---
    if fed.local_loss_update {
        let span = telemetry.as_ref().map(|t| t.span("phase", "phase1_local"));
        let upd = client.local_loss_update(
            backend, examples, head, tail, prompt, fed.local_epochs, fed.lr,
        )?;
        if let Some(mut s) = span {
            s.attr("batches", upd.batches as f64);
        }
        local_losses.push(upd.mean_loss);
        tail = upd.tail;
        prompt = upd.prompt;
    }

    // --- Phase 1b: EL2N pruning. ---
    let prune_span = telemetry.as_ref().map(|t| t.span("phase", "phase1_prune"));
    let prune_t0 = std::time::Instant::now();
    let pruned =
        client.prune_dataset(backend, examples, head, &tail, &prompt, fed.retain_fraction)?;
    if let Some(t) = &telemetry {
        t.metrics.observe("el2n_prune_s", prune_t0.elapsed().as_secs_f64());
    }
    if let Some(mut s) = prune_span {
        s.attr("retained", pruned.len() as f64);
        s.attr("local_n", client.num_samples() as f64);
    }

    // --- Phase 2: split training over the pruned set. ---
    let split_span = telemetry.as_ref().map(|t| t.span("phase", "phase2_split"));
    for chunk in batch_indices(&pruned, cfg.batch) {
        let batch = make_batch(examples, &chunk, cfg.batch, cfg.image_size, cfg.channels);
        let smashed = client.head_forward(backend, &batch.images, head, &prompt)?;
        link.send(
            &Frame::new(MsgKind::SmashedData, round, cid, Payload::Tensor(smashed)),
            wire,
        )?;

        let (frame, _) = link.recv()?;
        expect_kind(&frame, MsgKind::BodyOutput, cid)?;
        let body_out = frame.payload.into_tensor()?;

        let (loss, new_tail, g_body_out) =
            client.tail_step(backend, &body_out, &batch.labels, &tail, fed.lr)?;
        split_losses.push(loss as f64);
        tail = new_tail;
        link.send(
            &Frame::new(MsgKind::GradBodyOut, round, cid, Payload::Tensor(g_body_out)),
            wire,
        )?;

        let (frame, _) = link.recv()?;
        expect_kind(&frame, MsgKind::GradSmashed, cid)?;
        let g_smashed = frame.payload.into_tensor()?;
        prompt =
            client.prompt_update(backend, &batch.images, &g_smashed, head, &prompt, fed.lr)?;
    }
    drop(split_span);

    // --- Phase 3: upload for aggregation, wait for the broadcast.
    // With compression configured, what crosses the wire is the
    // error-compensated (tail, prompt) delta against the round's
    // reference; the server reconstructs before FedAvg. ---
    // The span covers compression, the upload, and the blocking wait for
    // the broadcast — the client's view of server-side round resolution.
    let _upload_span = telemetry.as_ref().map(|t| t.span("phase", "phase3_upload"));
    let upload = match (client.compress.as_mut(), &reference) {
        (Some(comp), Some((ref_tail, ref_prompt))) => Payload::Compressed(
            comp.compress_update(&[ref_tail, ref_prompt], &[&tail, &prompt])?,
        ),
        _ => Payload::Segments(vec![tail, prompt]),
    };
    link.send(&Frame::new(MsgKind::Upload, round, cid, upload), wire)?;
    let (frame, _) = link.recv()?;
    expect_kind(&frame, MsgKind::AggregateBroadcast, cid)?;

    Ok(ClientRoundOutcome { local_losses, split_losses })
}

#[cfg(test)]
mod tests {
    use super::top_k_by_score;

    #[test]
    fn top_k_keeps_highest_scores() {
        let scored = vec![(0, 0.1), (1, 0.9), (2, 0.5), (3, 0.7)];
        assert_eq!(top_k_by_score(scored, 2), vec![1, 3]);
    }

    #[test]
    fn top_k_survives_nan_scores() {
        // Regression: the old `partial_cmp().unwrap()` sort panicked on a
        // NaN EL2N score (diverged local model). NaN must rank last and
        // never abort the round.
        let scored = vec![(0, f32::NAN), (1, 0.9), (2, f32::NAN), (3, 0.7), (4, 0.8)];
        assert_eq!(top_k_by_score(scored, 3), vec![1, 4, 3]);
        // All-NaN still returns the requested count instead of panicking.
        let all_nan = vec![(0, f32::NAN), (1, f32::NAN)];
        assert_eq!(top_k_by_score(all_nan, 1).len(), 1);
    }
}
