//! The round-loop driver: owns the loop any method runs under and streams
//! progress to a [`RoundObserver`]. Simulated time is charged through the
//! fleet simulator's [`crate::sim::SimClock`] (paper §3.5 plus device
//! compute, availability, and deadlines — see docs/FLEET.md); the
//! [`LinkClock`] here is the legacy shared-rate reference the homogeneous
//! fleet is property-tested against bit-for-bit.
//!
//! Drivers used to be duplicated — `main.rs`, every `experiments/*.rs`
//! harness, and the examples each hand-wired the loop and its printing.
//! Now there is exactly one loop ([`drive`]) over the method-agnostic
//! [`FederatedRun`] trait, and presentation is an observer:
//!
//! * [`NullObserver`] — silent (tests, byte-accounting runs);
//! * [`ProgressPrinter`] — the standard per-round console line;
//! * anything else — implement [`RoundObserver`] (e.g. a CSV logger; see
//!   `examples/e2e_train.rs`).

use anyhow::Result;

use crate::comm::NetworkModel;
use crate::metrics::{RoundRecord, RunHistory};
use crate::sim::{ClientOutcome, DropReason};

use super::run::FederatedRun;
use super::{FedConfig, Method};

/// Per-round simulated link clocks under the paper's shared-rate model
/// (§3.5): K selected clients share one rate R, so each effective link
/// runs at R/K and the round's latency is the **max** over per-client
/// clocks (clients proceed in parallel, the server waits for the last).
///
/// Legacy reference: the engines now charge time through the fleet
/// simulator's [`crate::sim::SimClock`], whose homogeneous mode must
/// reproduce this arithmetic bit-for-bit (`tests/proptests.rs` pins the
/// equivalence).
pub struct LinkClock {
    net: NetworkModel,
    elapsed: Vec<f64>,
}

impl LinkClock {
    /// A clock per selected-client slot, all charged against `net`.
    pub fn new(net: NetworkModel, slots: usize) -> LinkClock {
        LinkClock { net, elapsed: vec![0.0; slots] }
    }

    /// Charge `bytes` of transfer time to `slot`'s link; returns the
    /// transfer time added.
    pub fn charge(&mut self, slot: usize, bytes: usize) -> f64 {
        let dt = self.net.transfer_time_s(bytes);
        self.elapsed[slot] += dt;
        dt
    }

    /// Accumulated link time for one slot.
    pub fn slot_s(&self, slot: usize) -> f64 {
        self.elapsed[slot]
    }

    /// Round latency: the slowest client's accumulated link time.
    pub fn round_latency_s(&self) -> f64 {
        self.elapsed.iter().copied().fold(0.0, f64::max)
    }

    pub fn net(&self) -> &NetworkModel {
        &self.net
    }
}

/// Event stream of one driven run. All methods have empty defaults, so an
/// observer implements only what it cares about.
///
/// Per-`MsgKind` measured bytes for the round are in
/// `rec.comm.by_kind` at `on_round_end`; `clock_s` is the cumulative
/// simulated clock (sum of per-round §3.5 latencies) after the round.
pub trait RoundObserver {
    fn on_run_start(&mut self, _method: Method, _fed: &FedConfig) {}
    fn on_round_start(&mut self, _round: usize) {}
    /// A selected client finished its round work at simulated time
    /// `finish_s` (within the round) and its update reached aggregation.
    fn on_client_done(&mut self, _round: usize, _client: usize, _finish_s: f64) {}
    /// A selected client's contribution was discarded: offline at round
    /// start, or past the (possibly quorum-extended) deadline. `at_s` is
    /// the simulated moment the fleet gave up on it.
    fn on_client_dropped(&mut self, _round: usize, _client: usize, _at_s: f64, _reason: DropReason) {
    }
    /// Fired after a round that produced an accuracy point (per
    /// `eval_every`, and always on the final round when an eval split is
    /// present).
    fn on_eval(&mut self, _round: usize, _accuracy: f64) {}
    fn on_round_end(&mut self, _rec: &RoundRecord, _clock_s: f64) {}
    fn on_run_end(&mut self, _history: &RunHistory) {}
}

/// Silent observer.
pub struct NullObserver;

impl RoundObserver for NullObserver {}

/// Fan one event stream out to two observers (left first, then right).
/// Lets `train` keep its console `ProgressPrinter` while a
/// [`crate::telemetry::TelemetryObserver`] records the same run.
pub struct Tee<'a>(pub &'a mut dyn RoundObserver, pub &'a mut dyn RoundObserver);

impl RoundObserver for Tee<'_> {
    fn on_run_start(&mut self, method: Method, fed: &FedConfig) {
        self.0.on_run_start(method, fed);
        self.1.on_run_start(method, fed);
    }
    fn on_round_start(&mut self, round: usize) {
        self.0.on_round_start(round);
        self.1.on_round_start(round);
    }
    fn on_client_done(&mut self, round: usize, client: usize, finish_s: f64) {
        self.0.on_client_done(round, client, finish_s);
        self.1.on_client_done(round, client, finish_s);
    }
    fn on_client_dropped(&mut self, round: usize, client: usize, at_s: f64, reason: DropReason) {
        self.0.on_client_dropped(round, client, at_s, reason);
        self.1.on_client_dropped(round, client, at_s, reason);
    }
    fn on_eval(&mut self, round: usize, accuracy: f64) {
        self.0.on_eval(round, accuracy);
        self.1.on_eval(round, accuracy);
    }
    fn on_round_end(&mut self, rec: &RoundRecord, clock_s: f64) {
        self.0.on_round_end(rec, clock_s);
        self.1.on_round_end(rec, clock_s);
    }
    fn on_run_end(&mut self, history: &RunHistory) {
        self.0.on_run_end(history);
        self.1.on_run_end(history);
    }
}

/// The standard per-round console line (what `train` and the experiment
/// harness print). With a label, rows are prefixed `[label]` in the
/// compact experiment style; without one, the fuller `train` style is
/// used (adds the simulated clock and wall time).
#[derive(Debug, Default)]
pub struct ProgressPrinter {
    label: Option<String>,
}

impl ProgressPrinter {
    pub fn new() -> ProgressPrinter {
        ProgressPrinter { label: None }
    }

    pub fn labeled(label: &str) -> ProgressPrinter {
        ProgressPrinter { label: Some(label.to_string()) }
    }
}

impl RoundObserver for ProgressPrinter {
    fn on_round_end(&mut self, rec: &RoundRecord, clock_s: f64) {
        match &self.label {
            Some(label) => println!(
                "  [{:<10}] round {:>2}: split_loss={:.4} local_loss={:.4} acc={:.4} comm={:.2}MB",
                label,
                rec.round,
                rec.mean_split_loss,
                rec.mean_local_loss,
                rec.eval_accuracy,
                rec.comm.mb()
            ),
            None => {
                let dropped = rec.dropped();
                let drop_note = if dropped > 0 {
                    format!(" dropped={}/{}", dropped, rec.clients.len())
                } else {
                    String::new()
                };
                // Only worth a column when compression actually shrank
                // something (ratio 1.0 means every payload went dense).
                let ratio = rec.comm.compression_ratio();
                let ratio_note =
                    if ratio < 1.0 { format!(" ratio={ratio:.3}") } else { String::new() };
                println!(
                    "round {:>3}: split_loss={:.4} local_loss={:.4} acc={:.4} comm={:.2}MB{} \
                     surv={}/{} sim_lat={:.1}s clock={:.1}s wall={:.1}s{}",
                    rec.round,
                    rec.mean_split_loss,
                    rec.mean_local_loss,
                    rec.eval_accuracy,
                    rec.comm.mb(),
                    ratio_note,
                    rec.survivors(),
                    rec.clients.len(),
                    rec.sim_latency_s,
                    clock_s,
                    rec.wall_s,
                    drop_note
                )
            }
        }
    }
}

/// Run every configured round of `run`, streaming events to `obs`;
/// returns the completed history (also available via `run.history()`).
pub fn drive(run: &mut dyn FederatedRun, obs: &mut dyn RoundObserver) -> Result<RunHistory> {
    let run_t0 = std::time::Instant::now();
    let rounds = run.fed().rounds;
    obs.on_run_start(run.method(), run.fed());
    let mut clock_s = 0.0;
    for r in 0..rounds {
        obs.on_round_start(r);
        let rec = run.round(r)?;
        clock_s += rec.sim_latency_s;
        for ev in &rec.clients {
            match ev.outcome {
                ClientOutcome::Done => obs.on_client_done(r, ev.client, ev.at_s),
                ClientOutcome::Dropped(reason) => {
                    obs.on_client_dropped(r, ev.client, ev.at_s, reason)
                }
            }
        }
        if rec.eval_accuracy.is_finite() {
            obs.on_eval(r, rec.eval_accuracy);
        }
        obs.on_round_end(&rec, clock_s);
    }
    let mut history = run.history().clone();
    history.run_wall_s = run_t0.elapsed().as_secs_f64();
    obs.on_run_end(&history);
    Ok(history)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_charges_per_slot_and_reports_max() {
        // 1000 B/s shared by 4 clients -> 250 B/s effective per link.
        let net = NetworkModel { rate_bytes_per_s: 1000.0, sharing_clients: 4 };
        let mut clock = LinkClock::new(net, 3);
        let dt = clock.charge(0, 500); // 2 s
        assert!((dt - 2.0).abs() < 1e-9);
        clock.charge(0, 250); // +1 s -> slot 0 at 3 s
        clock.charge(2, 1000); // 4 s
        assert!((clock.slot_s(0) - 3.0).abs() < 1e-9);
        assert!((clock.slot_s(1) - 0.0).abs() < 1e-12);
        assert!((clock.round_latency_s() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn empty_clock_reports_zero_latency() {
        let clock = LinkClock::new(NetworkModel::default(), 0);
        assert_eq!(clock.round_latency_s(), 0.0);
    }
}
