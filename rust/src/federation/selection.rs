//! Client-selection strategies.
//!
//! The paper samples K of N clients uniformly each round (§4.1); real
//! deployments also use sample-size weighting (more data → more likely
//! selected, cf. FedAvg) or deterministic round-robin (full coverage, used
//! by several cross-silo systems). All three are provided and
//! property-tested; the engines default to `Uniform`.
//!
//! Randomized strategies draw from the engine's root RNG (seeded with
//! `FedConfig::seed` — see the seed-domain map in `util::rng::seeds`), so
//! selection is independent of fleet availability draws: a client can be
//! selected and then found offline, which is exactly the dropped-round
//! accounting the fleet simulator observes.

use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Selection {
    /// K distinct clients uniformly at random (the paper's setting).
    Uniform,
    /// K distinct clients, probability proportional to local sample count.
    WeightedBySamples,
    /// Deterministic rotation: round r picks clients (rK ... rK+K-1) mod N.
    RoundRobin,
}

impl Selection {
    pub fn label(&self) -> &'static str {
        match self {
            Selection::Uniform => "uniform",
            Selection::WeightedBySamples => "weighted",
            Selection::RoundRobin => "round_robin",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Selection> {
        Ok(match s {
            "uniform" => Selection::Uniform,
            "weighted" => Selection::WeightedBySamples,
            "round_robin" => Selection::RoundRobin,
            other => anyhow::bail!(
                "unknown selection strategy {other:?} (known: uniform weighted round_robin)"
            ),
        })
    }
}

/// Select `k` distinct client ids from `n` clients.
///
/// `sample_counts` is indexed by client id (used by WeightedBySamples);
/// `round` drives RoundRobin.
pub fn select(
    strategy: Selection,
    n: usize,
    k: usize,
    sample_counts: &[usize],
    round: usize,
    rng: &mut Rng,
) -> Vec<usize> {
    assert!(k <= n && n > 0);
    match strategy {
        Selection::Uniform => rng.choose(n, k),
        Selection::RoundRobin => (0..k).map(|i| (round * k + i) % n).collect(),
        Selection::WeightedBySamples => {
            assert_eq!(sample_counts.len(), n);
            // Weighted sampling without replacement (Efraimidis-Spirakis):
            // key = u^(1/w), take the k largest keys.
            let mut keyed: Vec<(f64, usize)> = (0..n)
                .map(|i| {
                    let w = sample_counts[i].max(1) as f64;
                    let u = rng.uniform().max(f64::MIN_POSITIVE);
                    (u.powf(1.0 / w), i)
                })
                .collect();
            keyed.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
            keyed.into_iter().take(k).map(|(_, i)| i).collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn distinct(v: &[usize]) -> bool {
        let mut s = v.to_vec();
        s.sort_unstable();
        s.dedup();
        s.len() == v.len()
    }

    #[test]
    fn all_strategies_return_k_distinct_valid_ids() {
        let counts: Vec<usize> = (0..20).map(|i| i + 1).collect();
        let mut rng = Rng::new(1);
        for strategy in
            [Selection::Uniform, Selection::WeightedBySamples, Selection::RoundRobin]
        {
            for round in 0..50 {
                let sel = select(strategy, 20, 5, &counts, round, &mut rng);
                assert_eq!(sel.len(), 5);
                assert!(distinct(&sel), "{strategy:?}");
                assert!(sel.iter().all(|&i| i < 20));
            }
        }
    }

    #[test]
    fn round_robin_covers_everyone() {
        let counts = vec![1; 10];
        let mut rng = Rng::new(2);
        let mut seen = std::collections::BTreeSet::new();
        for round in 0..5 {
            for id in select(Selection::RoundRobin, 10, 2, &counts, round, &mut rng) {
                seen.insert(id);
            }
        }
        assert_eq!(seen.len(), 10);
    }

    #[test]
    fn weighted_prefers_data_rich_clients() {
        // Client 9 has 100x the data of clients 0..9; over many rounds it
        // must be selected far more often than client 0.
        let mut counts = vec![2usize; 10];
        counts[9] = 200;
        let mut rng = Rng::new(3);
        let (mut hits9, mut hits0) = (0, 0);
        for round in 0..400 {
            let sel = select(Selection::WeightedBySamples, 10, 3, &counts, round, &mut rng);
            hits9 += sel.contains(&9) as usize;
            hits0 += sel.contains(&0) as usize;
        }
        assert!(hits9 > 3 * hits0, "rich {hits9} vs poor {hits0}");
    }

    #[test]
    fn labels_roundtrip_through_parse() {
        for s in
            [Selection::Uniform, Selection::WeightedBySamples, Selection::RoundRobin]
        {
            assert_eq!(Selection::parse(s.label()).unwrap(), s);
        }
        assert!(Selection::parse("lottery").is_err());
    }

    #[test]
    fn uniform_is_roughly_fair() {
        let counts = vec![1; 10];
        let mut rng = Rng::new(4);
        let mut hits = vec![0usize; 10];
        for round in 0..1000 {
            for id in select(Selection::Uniform, 10, 2, &counts, round, &mut rng) {
                hits[id] += 1;
            }
        }
        // Each client expects 200 selections; allow generous slack.
        assert!(hits.iter().all(|&h| (120..=280).contains(&h)), "{hits:?}");
    }
}
