//! The SFPrompt global-round engine (Algorithms 1 + 2).
//!
//! Each round:
//!   0. select K clients; distribute the aggregated (W_t, p)         [net]
//!   1. Phase 1: each client runs U local-loss epochs over its full
//!      local data (no network), then EL2N-prunes it
//!   2. Phase 2: one split-training pass over the pruned data —
//!      smashed ↑, body-out ↓, cut-grad ↑, smashed-grad ↓ per batch  [net]
//!   3. Phase 3: upload (W_t, p); FedAvg; broadcast                  [net]
//!
//! All traffic flows through `comm::SimLink`s with exact byte accounting;
//! latency uses the shared-rate model of §3.5. Client compute is
//! sequential on this process (one CPU), but the simulated clock charges
//! parallel client time as the max over clients, matching the paper's
//! analysis.

use std::time::Instant;

use anyhow::Result;

use crate::comm::{ByteMeter, Direction, MsgKind, NetworkModel, SimLink};
use crate::data::{batch_indices, make_batch, SynthDataset};
use crate::metrics::{evaluate, RoundRecord, RunHistory};
use crate::model::{init_params, ParamSet, SegmentParams};
use crate::partition::partition;
use crate::runtime::ArtifactStore;
use crate::util::rng::Rng;

use super::client::Client;
use super::server::Server;
use super::FedConfig;

pub struct SfPromptEngine<'a> {
    pub store: &'a ArtifactStore,
    pub fed: FedConfig,
    pub net: NetworkModel,
    pub global: ParamSet,
    pub clients: Vec<Client>,
    rng: Rng,
    /// bytes of the one-time head distribution (setup, not per-round)
    pub setup_bytes: u64,
    /// Frozen segments as pre-converted PJRT literals (perf fast path —
    /// head/body never change during an SFPrompt run; see §Perf).
    head_lits: Vec<xla::Literal>,
    body_lits: Vec<xla::Literal>,
}

impl<'a> SfPromptEngine<'a> {
    pub fn new(store: &'a ArtifactStore, fed: FedConfig, dataset: &SynthDataset) -> Self {
        let mut rng = Rng::new(fed.seed);
        let labels = dataset.labels();
        let parts = partition(&labels, fed.num_clients, fed.partition, &mut rng.fork(1));
        let clients = parts
            .into_iter()
            .enumerate()
            .map(|(id, indices)| Client::new(id, indices, rng.fork(100 + id as u64)))
            .collect();
        let global = init_params(&store.manifest, fed.seed ^ 0xA5A5);
        let head_bytes = store.manifest.cost.message_bytes["head_params"] as u64;
        let head_lits = crate::runtime::segment_literals(global.get("head").unwrap())
            .expect("head literals");
        let body_lits = crate::runtime::segment_literals(global.get("body").unwrap())
            .expect("body literals");
        SfPromptEngine {
            store,
            net: NetworkModel { sharing_clients: fed.clients_per_round, ..Default::default() },
            fed,
            global,
            clients,
            rng,
            // One-time: every client receives the frozen head once.
            setup_bytes: head_bytes * fed.num_clients as u64,
            head_lits,
            body_lits,
        }
    }

    fn msg_sizes(&self) -> (usize, usize, usize) {
        let mb = &self.store.manifest.cost.message_bytes;
        (mb["tail_params"], mb["prompt_params"], mb["smashed_per_batch"])
    }

    /// Run one global round; returns its metrics record.
    pub fn run_round(
        &mut self,
        round: usize,
        dataset: &SynthDataset,
        eval: Option<&SynthDataset>,
    ) -> Result<RoundRecord> {
        let wall0 = Instant::now();
        let (tail_b, prompt_b, smashed_b) = self.msg_sizes();
        let cfg = self.store.manifest.config.clone();

        let counts: Vec<usize> = self.clients.iter().map(|c| c.num_samples()).collect();
        let selected = super::selection::select(
            self.fed.selection, self.fed.num_clients, self.fed.clients_per_round,
            &counts, round, &mut self.rng,
        );
        let mut comm = ByteMeter::default();
        let mut local_losses = Vec::new();
        let mut split_losses = Vec::new();
        let mut updates: Vec<(SegmentParams, SegmentParams, usize)> = Vec::new();
        let mut client_latency: Vec<f64> = Vec::new();

        for &cid in &selected {
            let mut link = SimLink::default();
            // --- Round start: distribute the aggregated (W_t, p). ---
            link.send(&self.net, MsgKind::ModelDistribution, Direction::Downlink,
                      tail_b + prompt_b);
            let mut tail = self.global.get("tail")?.clone();
            let mut prompt = self.global.get("prompt")?.clone();

            let client = &mut self.clients[cid];
            let n_k = client.num_samples();

            // --- Phase 1a: local-loss update (network-free). ---
            if self.fed.local_loss_update {
                let upd = client.local_loss_update(
                    self.store, &dataset.examples, &self.head_lits, tail, prompt,
                    self.fed.local_epochs, self.fed.lr,
                )?;
                local_losses.push(upd.mean_loss);
                tail = upd.tail;
                prompt = upd.prompt;
            }

            // --- Phase 1b: EL2N pruning. ---
            let pruned = client.prune_dataset(
                self.store, &dataset.examples, &self.head_lits, &tail, &prompt,
                self.fed.retain_fraction,
            )?;

            // --- Phase 2: split training over the pruned set. ---
            for chunk in batch_indices(&pruned, cfg.batch) {
                let batch = make_batch(
                    &dataset.examples, &chunk, cfg.batch, cfg.image_size, cfg.channels,
                );
                let smashed =
                    client.head_forward(self.store, &batch.images, &self.head_lits, &prompt)?;
                link.send(&self.net, MsgKind::SmashedData, Direction::Uplink, smashed_b);

                let body_out = Server::body_forward(self.store, &self.body_lits, &smashed)?;
                link.send(&self.net, MsgKind::BodyOutput, Direction::Downlink, smashed_b);

                let (loss, new_tail, g_body_out) =
                    client.tail_step(self.store, &body_out, &batch.labels, &tail, self.fed.lr)?;
                split_losses.push(loss as f64);
                tail = new_tail;
                link.send(&self.net, MsgKind::GradBodyOut, Direction::Uplink, smashed_b);

                let g_smashed =
                    Server::body_backward(self.store, &self.body_lits, &smashed, &g_body_out)?;
                link.send(&self.net, MsgKind::GradSmashed, Direction::Downlink, smashed_b);

                prompt = client.prompt_update(
                    self.store, &batch.images, &g_smashed, &self.head_lits, &prompt, self.fed.lr,
                )?;
            }

            // --- Phase 3 upload. ---
            link.send(&self.net, MsgKind::Upload, Direction::Uplink, tail_b + prompt_b);
            comm.merge(&link.meter);
            client_latency.push(link.elapsed_s);
            updates.push((tail, prompt, n_k));
        }

        // --- Phase 3: FedAvg + broadcast. ---
        let (tail, prompt) = Server::aggregate(&updates)?;
        self.global.set(tail);
        self.global.set(prompt);
        for _ in &selected {
            comm.record(MsgKind::AggregateBroadcast, Direction::Downlink, tail_b + prompt_b);
        }

        // Simulated round latency: parallel clients → max link clock.
        let sim_latency_s = client_latency.iter().copied().fold(0.0, f64::max);

        let eval_accuracy = match eval {
            Some(ds)
                if round % self.fed.eval_every == 0 || round + 1 == self.fed.rounds =>
            {
                evaluate(self.store, "eval_forward", &self.global, ds, self.fed.eval_limit)?
            }
            _ => f64::NAN,
        };

        Ok(RoundRecord {
            round,
            mean_local_loss: crate::util::stats::mean(&local_losses),
            mean_split_loss: crate::util::stats::mean(&split_losses),
            eval_accuracy,
            comm,
            wall_s: wall0.elapsed().as_secs_f64(),
            sim_latency_s,
        })
    }

    /// Run the configured number of rounds.
    pub fn run(
        &mut self,
        dataset: &SynthDataset,
        eval: Option<&SynthDataset>,
        mut on_round: impl FnMut(&RoundRecord),
    ) -> Result<RunHistory> {
        let mut history = RunHistory::default();
        for r in 0..self.fed.rounds {
            let rec = self.run_round(r, dataset, eval)?;
            on_round(&rec);
            history.push(rec);
        }
        Ok(history)
    }
}
