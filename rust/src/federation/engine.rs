//! The SFPrompt global-round engine (Algorithms 1 + 2).
//!
//! Each round:
//!   0. select K clients; distribute the aggregated (W_t, p)         [net]
//!   1. Phase 1: each client runs U local-loss epochs over its full
//!      local data (no network), then EL2N-prunes it
//!   2. Phase 2: one split-training pass over the pruned data —
//!      smashed ↑, body-out ↓, cut-grad ↑, smashed-grad ↓ per batch  [net]
//!   3. Phase 3: upload (W_t, p); FedAvg; broadcast                  [net]
//!
//! Every message is serialised through the `transport` codec and moved
//! over a channel link: `ByteMeter` records **encoded frame lengths**, not
//! manifest estimates, and uplink payloads honour `FedConfig::wire`
//! (f32/f16/int8). Each selected client runs on its own thread against the
//! server [`Hub`], so Phase-2 split training is genuinely concurrent (the
//! [`Backend`] is `Sync`), and the serve loop drains the hub
//! opportunistically so same-kind body-stage frames from concurrent
//! clients fuse into one batched kernel invocation
//! ([`Backend::run_stage_batch`] — bit-identical to solo calls, so
//! reports don't depend on arrival timing).
//!
//! Simulated time is the fleet simulator's: [`Fleet::begin_round`] samples
//! the cohort's [`SimClock`] (per-client link and device rates,
//! availability), every frame charges transfer time and every upload
//! charges the client's analytic compute FLOPs, and the round resolves
//! with deadline/quorum semantics — the server aggregates only the
//! survivors and the round's latency comes from the event queue
//! ([`crate::sim::RoundOutcome`]). Offline clients are dropped before any
//! traffic; deadline-dropped clients finish their protocol (and their
//! bytes count) but their update is discarded. With no fleet configured
//! this reduces to the §3.5 shared-rate model bit-for-bit.
//!
//! Constructed only via [`super::RunBuilder`]; driven only through the
//! [`FederatedRun`] trait.

use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::backend::{Backend, PreparedSegment};
use crate::comm::{ByteMeter, Direction, MsgKind};
use crate::compress::decompress_update;
use crate::data::SynthDataset;
use crate::metrics::{evaluate, RoundRecord, RunHistory};
use crate::model::{init_params, ParamSet, SegmentParams};
use crate::runtime::HostTensor;
use crate::sim::{Fleet, RoundOutcome, SimClock};
use crate::telemetry::Ledger;
use crate::transport::{
    dense_segments_wire_len, encoded_frame_len, Frame, FrameHub, Hub, Payload, WireFormat,
};
use crate::util::rng::{seeds, Rng};

use super::client::{build_clients, client_split_round, Client, ClientRoundOutcome};
use super::run::FederatedRun;
use super::server::Server;
use super::{FedConfig, Method};

pub(crate) struct SfPromptEngine<'a> {
    backend: &'a dyn Backend,
    fed: FedConfig,
    fleet: Fleet,
    global: ParamSet,
    clients: Vec<Client>,
    rng: Rng,
    /// bytes of the one-time head distribution (setup, not per-round)
    setup_bytes: u64,
    /// Frozen segments in backend-prepared form (perf fast path —
    /// head/body never change during an SFPrompt run; see §Perf).
    head_prep: PreparedSegment,
    body_prep: PreparedSegment,
    train: &'a SynthDataset,
    eval: Option<&'a SynthDataset>,
    history: RunHistory,
    /// Per-(round, client, kind) re-attribution of the byte meter plus
    /// sim-clock transfer/compute seconds (docs/TRACING.md).
    ledger: Ledger,
}

impl<'a> SfPromptEngine<'a> {
    pub(crate) fn new(
        backend: &'a dyn Backend,
        fed: FedConfig,
        fleet: Fleet,
        train: &'a SynthDataset,
        eval: Option<&'a SynthDataset>,
    ) -> Result<Self> {
        let labels = train.labels();
        let (clients, rng) = build_clients(&fed, &labels);
        let manifest = backend.manifest();
        let global = init_params(manifest, seeds::param_init(fed.seed));
        let head_bytes = manifest.cost.message_bytes["head_params"] as u64;
        let head_prep = backend.prepare_segment(global.get("head")?)?;
        let body_prep = backend.prepare_segment(global.get("body")?)?;
        Ok(SfPromptEngine {
            backend,
            fleet,
            fed,
            global,
            clients,
            rng,
            // One-time: every client receives the frozen head once.
            setup_bytes: head_bytes * fed.num_clients as u64,
            head_prep,
            body_prep,
            train,
            eval,
            history: RunHistory::default(),
            ledger: Ledger::new(),
        })
    }

    /// Run one global round; returns its metrics record.
    fn run_round(&mut self, round: usize) -> Result<RoundRecord> {
        let wall0 = Instant::now();
        // The TelemetryObserver's round span is open on this (driver)
        // thread; capture its id so client-thread spans can nest under it.
        let telemetry = crate::telemetry::active();
        let round_parent = telemetry.as_ref().and_then(|t| t.current_span_id());
        let cfg = self.backend.manifest().config.clone();
        let train = self.train;

        let counts: Vec<usize> = self.clients.iter().map(|c| c.num_samples()).collect();
        let selected = super::selection::select(
            self.fed.selection, self.fed.num_clients, self.fed.clients_per_round,
            &counts, round, &mut self.rng,
        );
        let k = selected.len();
        let n_ks: Vec<usize> = selected.iter().map(|&cid| self.clients[cid].num_samples()).collect();

        let mut comm = ByteMeter::default();
        let mut clock = self.fleet.begin_round(&selected);
        let (hub, endpoints) = Hub::new(k);

        // --- Round start: distribute the aggregated (W_t, p) to every
        // reachable client (offline slots get nothing, not even bytes).
        // The same pair doubles as the compression reference: compressed
        // uploads are deltas against exactly what was distributed. ---
        let dist_ref =
            [self.global.get("tail")?.clone(), self.global.get("prompt")?.clone()];
        let ledger = &mut self.ledger;
        distribute_model(&hub, &selected, round as u32, &dist_ref, &mut comm, &mut clock, ledger)?;

        // Threads own the online selected clients; park stand-ins.
        let mut endpoints: Vec<Option<_>> = endpoints.into_iter().map(Some).collect();
        let taken: Vec<(usize, Client, _)> = selected
            .iter()
            .enumerate()
            .filter(|&(slot, _)| clock.online(slot))
            .map(|(slot, &cid)| {
                let client = std::mem::replace(
                    &mut self.clients[cid],
                    Client::new(cid, Vec::new(), Rng::new(0)),
                );
                (slot, client, endpoints[slot].take().expect("endpoint taken once"))
            })
            .collect();

        let fed = self.fed;
        let backend = self.backend;
        let head_prep = &self.head_prep;
        let body_prep = &self.body_prep;
        let examples = &train.examples;
        let cfg_ref = &cfg;
        let selected_ref = &selected;

        let (agg_result, joined) = std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(taken.len());
            for (slot, client, mut link) in taken {
                let telem = telemetry.clone();
                handles.push(scope.spawn(move || {
                    let mut client = client;
                    let cid = client.id as u32;
                    // Explicit parent: this thread's spans (phases, backend
                    // stages) nest under the driver thread's round span.
                    let _client_span = telem
                        .as_ref()
                        .map(|t| t.span_under("client", &format!("client:{cid}"), round_parent));
                    // A thread that dies without telling the server would
                    // leave serve_round blocked forever (the other clients
                    // keep the hub's inbound channel alive) — so both the
                    // Err path and the panic path send an Abort frame.
                    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        client_split_round(
                            &mut client, backend, examples, head_prep, &fed, cfg_ref,
                            round as u32, &mut link,
                        )
                    }));
                    let out = match caught {
                        Ok(out) => out,
                        Err(payload) => {
                            let abort =
                                Frame::new(MsgKind::Abort, round as u32, cid, Payload::Empty);
                            let _ = link.send(&abort, WireFormat::F32);
                            std::panic::resume_unwind(payload);
                        }
                    };
                    if out.is_err() {
                        let abort =
                            Frame::new(MsgKind::Abort, round as u32, cid, Payload::Empty);
                        let _ = link.send(&abort, WireFormat::F32);
                    }
                    (slot, client, out)
                }));
            }

            // --- Server: route Phase-2 traffic, resolve the deadline,
            // FedAvg the survivors, broadcast. ---
            let serve_span = telemetry.as_ref().map(|t| t.span("phase", "serve"));
            let agg_result = serve_round(
                backend, body_prep, &hub, selected_ref, round as u32,
                &n_ks, &fed, &dist_ref, &mut comm, &mut clock, ledger,
            );
            drop(serve_span);
            // Dropping the hub unblocks any client still waiting on a recv
            // after a server-side error.
            drop(hub);
            let joined: Vec<(usize, Client, Result<ClientRoundOutcome>)> = handles
                .into_iter()
                .map(|h| h.join().expect("client thread panicked"))
                .collect();
            (agg_result, joined)
        });

        // Restore clients to the fleet and collect per-slot outcomes.
        let mut results: Vec<(usize, ClientRoundOutcome)> = Vec::new();
        let mut client_err: Option<anyhow::Error> = None;
        for (slot, client, out) in joined {
            self.clients[selected[slot]] = client;
            match out {
                Ok(o) => results.push((slot, o)),
                Err(e) if client_err.is_none() => {
                    client_err =
                        Some(e.context(format!("client {} in round {round}", selected[slot])));
                }
                Err(_) => {}
            }
        }
        let (agg, outcome) = match (agg_result, client_err) {
            (Ok(v), None) => v,
            (Ok(_), Some(e)) => return Err(e),
            (Err(server_err), Some(client_e)) => {
                // A deliberate client Abort makes the client error the root
                // cause; any other server-side failure (decode error, body
                // stage failure, …) is the root cause itself — the clients
                // only saw the hub close underneath them.
                if server_err.to_string().contains("aborted round") {
                    return Err(client_e);
                }
                return Err(server_err);
            }
            (Err(server_err), None) => return Err(server_err),
        };
        // Only survivors report into the round's loss means — the server
        // never saw a dropped client's numbers.
        let mut local_losses = Vec::new();
        let mut split_losses = Vec::new();
        for (slot, o) in results {
            if outcome.is_survivor(slot) {
                local_losses.extend(o.local_losses);
                split_losses.extend(o.split_losses);
            }
        }
        if let Some((tail, prompt)) = agg {
            self.global.set(tail);
            self.global.set(prompt);
        }
        self.fleet.advance(outcome.latency_s);

        let eval_accuracy = match self.eval {
            Some(ds) if self.fed.should_eval(round) => {
                let _eval_span = telemetry.as_ref().map(|t| t.span("phase", "eval"));
                evaluate(self.backend, "eval_forward", &self.global, ds, self.fed.eval_limit)?
            }
            _ => f64::NAN,
        };

        Ok(RoundRecord {
            round,
            mean_local_loss: crate::util::stats::mean(&local_losses),
            mean_split_loss: crate::util::stats::mean(&split_losses),
            eval_accuracy,
            comm,
            wall_s: wall0.elapsed().as_secs_f64(),
            // Simulated round latency from the event queue: max finisher,
            // or the effective deadline when stragglers were cut off.
            sim_latency_s: outcome.latency_s,
            clients: outcome.events,
        })
    }
}

impl FederatedRun for SfPromptEngine<'_> {
    fn method(&self) -> Method {
        Method::SfPrompt
    }

    fn fed(&self) -> &FedConfig {
        &self.fed
    }

    fn round(&mut self, r: usize) -> Result<RoundRecord> {
        if r != self.history.rounds.len() {
            return Err(anyhow!(
                "rounds must run in order: expected round {}, got {r}",
                self.history.rounds.len()
            ));
        }
        let rec = self.run_round(r)?;
        self.history.push(rec.clone());
        Ok(rec)
    }

    fn history(&self) -> &RunHistory {
        &self.history
    }

    fn comm_totals(&self) -> &ByteMeter {
        &self.history.total_comm
    }

    fn setup_bytes(&self) -> u64 {
        self.setup_bytes
    }

    fn final_eval(&mut self) -> Result<f64> {
        match self.eval {
            Some(ds) => {
                evaluate(self.backend, "eval_forward", &self.global, ds, self.fed.eval_limit)
            }
            None => Ok(f64::NAN),
        }
    }

    fn ledger(&self) -> Option<&Ledger> {
        Some(&self.ledger)
    }
}

/// Round start: send the aggregated `[tail, prompt]` pair to every
/// reachable selected client (offline slots get nothing, not even bytes),
/// metering each encoded frame and charging its transfer time. Shared by
/// the in-process engine and the networked serve loop — the `FrameHub`
/// decides whether "send" means an mpsc push or a socket write.
#[allow(clippy::too_many_arguments)]
pub(crate) fn distribute_model(
    hub: &dyn FrameHub,
    selected: &[usize],
    round: u32,
    dist_ref: &[SegmentParams; 2],
    comm: &mut ByteMeter,
    clock: &mut SimClock,
    ledger: &mut Ledger,
) -> Result<()> {
    let telemetry = crate::telemetry::active();
    let _dist_span = telemetry.as_ref().map(|t| t.span("phase", "distribute"));
    let dist = Payload::Segments(dist_ref.to_vec());
    for (slot, &cid) in selected.iter().enumerate() {
        if !clock.online(slot) {
            continue;
        }
        let frame = Frame::new(MsgKind::ModelDistribution, round, cid as u32, dist.clone());
        let n = hub.send_to(slot, &frame, WireFormat::F32)?;
        comm.record(MsgKind::ModelDistribution, Direction::Downlink, n);
        let dt = clock.charge_transfer(slot, n);
        ledger.tap(round, cid as u32, MsgKind::ModelDistribution, Direction::Downlink, n, n, dt);
    }
    Ok(())
}

/// Server half of one round: route split-training frames from the hub
/// until every online client has uploaded, resolve the deadline policy,
/// FedAvg the survivors, and broadcast. Records every encoded frame
/// length into `comm` — uplink frames alongside their dense-f32
/// equivalent, so the meter's raw-vs-wire split reflects `--wire` and
/// `--compress` savings — and charges each client's transfer bytes and,
/// at upload time, its analytic compute FLOPs into the round's
/// [`SimClock`]. Compressed uploads are reconstructed against `dist_ref`
/// (the `[tail, prompt]` pair distributed at round start) before FedAvg.
///
/// Returns the aggregate (None when every selected client was offline)
/// and the resolved [`RoundOutcome`].
#[allow(clippy::too_many_arguments)]
pub(crate) fn serve_round(
    backend: &dyn Backend,
    body_prep: &PreparedSegment,
    hub: &dyn FrameHub,
    selected: &[usize],
    round: u32,
    n_ks: &[usize],
    fed: &FedConfig,
    dist_ref: &[SegmentParams; 2],
    comm: &mut ByteMeter,
    clock: &mut SimClock,
    ledger: &mut Ledger,
) -> Result<(Option<(SegmentParams, SegmentParams)>, RoundOutcome)> {
    let slot_of = |cid: u32| {
        selected
            .iter()
            .position(|&c| c as u32 == cid)
            .ok_or_else(|| anyhow!("frame from unknown client {cid}"))
    };
    let cfg = &backend.manifest().config;
    let k = selected.len();
    let mut smashed_cache: Vec<Option<HostTensor>> = vec![None; k];
    let mut uploads: Vec<Option<(SegmentParams, SegmentParams)>> = vec![None; k];
    let mut smashed_batches = vec![0usize; k];
    let mut pending = (0..k).filter(|&slot| clock.online(slot)).count();

    // Service turns: block for one frame, then opportunistically drain
    // whatever else is already queued. Same-kind body-stage frames in a
    // turn fuse into ONE batched kernel invocation
    // ([`Backend::run_stage_batch`]) — concurrent clients tend to arrive
    // together, so Phase-2 body work coalesces while bookkeeping (bytes,
    // transfer time, replies) stays strictly per client. Hubs that can't
    // peek (`try_recv_any` default) degrade to one frame per turn, which
    // is the old behavior exactly.
    let mut queue: std::collections::VecDeque<(Frame, usize)> = Default::default();
    while pending > 0 {
        if queue.is_empty() {
            queue.push_back(hub.recv_any()?);
            while let Some(fr) = hub.try_recv_any()? {
                queue.push_back(fr);
            }
        }
        let (frame, n) = queue.pop_front().expect("queue refilled above");
        let slot = slot_of(frame.client)?;
        // Compressed uploads record their raw equivalent only after
        // reconstruction (below); every other uplink frame is dense
        // already, so its f32 re-measure is the raw side directly. The
        // transfer time is charged here either way — `dt` stays live so
        // the compressed-upload arm can attribute it with the bytes.
        let raw = (!matches!(frame.payload, Payload::Compressed(_)))
            .then(|| encoded_frame_len(&frame, WireFormat::F32));
        let dt = clock.charge_transfer(slot, n);
        if let Some(raw) = raw {
            comm.record_with_raw(frame.kind, Direction::Uplink, n, raw);
            ledger.tap(round, frame.client, frame.kind, Direction::Uplink, n, raw, dt);
        }
        match frame.kind {
            MsgKind::SmashedData => {
                // Pull every other SmashedData frame from this turn's
                // drain into the same fused forward.
                let mut cids = vec![frame.client];
                let mut slots = vec![slot];
                let mut inputs = vec![frame.payload.into_tensor()?];
                let mut i = 0;
                while i < queue.len() {
                    if queue[i].0.kind != MsgKind::SmashedData {
                        i += 1;
                        continue;
                    }
                    let (f2, n2) = queue.remove(i).expect("index checked");
                    let s2 = slot_of(f2.client)?;
                    let raw2 = encoded_frame_len(&f2, WireFormat::F32);
                    comm.record_with_raw(f2.kind, Direction::Uplink, n2, raw2);
                    let dt2 = clock.charge_transfer(s2, n2);
                    ledger.tap(round, f2.client, f2.kind, Direction::Uplink, n2, raw2, dt2);
                    cids.push(f2.client);
                    slots.push(s2);
                    inputs.push(f2.payload.into_tensor()?);
                }
                let refs: Vec<&HostTensor> = inputs.iter().collect();
                let body_outs = Server::body_forward_batch(backend, body_prep, &refs)?;
                for ((&s, &cid), (smashed, body_out)) in
                    slots.iter().zip(&cids).zip(inputs.into_iter().zip(body_outs))
                {
                    smashed_batches[s] += 1;
                    smashed_cache[s] = Some(smashed);
                    let reply =
                        Frame::new(MsgKind::BodyOutput, round, cid, Payload::Tensor(body_out));
                    let nb = hub.send_to(s, &reply, WireFormat::F32)?;
                    comm.record(MsgKind::BodyOutput, Direction::Downlink, nb);
                    let dtb = clock.charge_transfer(s, nb);
                    ledger.tap(round, cid, MsgKind::BodyOutput, Direction::Downlink, nb, nb, dtb);
                }
            }
            MsgKind::GradBodyOut => {
                let mut cids = vec![frame.client];
                let mut slots = vec![slot];
                let mut grads = vec![frame.payload.into_tensor()?];
                let mut i = 0;
                while i < queue.len() {
                    if queue[i].0.kind != MsgKind::GradBodyOut {
                        i += 1;
                        continue;
                    }
                    let (f2, n2) = queue.remove(i).expect("index checked");
                    let s2 = slot_of(f2.client)?;
                    let raw2 = encoded_frame_len(&f2, WireFormat::F32);
                    comm.record_with_raw(f2.kind, Direction::Uplink, n2, raw2);
                    let dt2 = clock.charge_transfer(s2, n2);
                    ledger.tap(round, f2.client, f2.kind, Direction::Uplink, n2, raw2, dt2);
                    cids.push(f2.client);
                    slots.push(s2);
                    grads.push(f2.payload.into_tensor()?);
                }
                let pairs: Vec<(&HostTensor, &HostTensor)> = slots
                    .iter()
                    .zip(&cids)
                    .zip(&grads)
                    .map(|((&s, &cid), g)| {
                        let smashed = smashed_cache[s].as_ref().ok_or_else(|| {
                            anyhow!("client {cid} sent a gradient before smashed data")
                        })?;
                        Ok((smashed, g))
                    })
                    .collect::<Result<_>>()?;
                let g_smasheds = Server::body_backward_batch(backend, body_prep, &pairs)?;
                for ((&s, &cid), g_smashed) in slots.iter().zip(&cids).zip(g_smasheds) {
                    let reply =
                        Frame::new(MsgKind::GradSmashed, round, cid, Payload::Tensor(g_smashed));
                    let nb = hub.send_to(s, &reply, WireFormat::F32)?;
                    comm.record(MsgKind::GradSmashed, Direction::Downlink, nb);
                    let dtb = clock.charge_transfer(s, nb);
                    ledger.tap(round, cid, MsgKind::GradSmashed, Direction::Downlink, nb, nb, dtb);
                }
            }
            MsgKind::Upload => {
                let mut segs = match frame.payload {
                    Payload::Compressed(csegs) => {
                        let refs: Vec<&SegmentParams> = dist_ref.iter().collect();
                        let segs = decompress_update(&refs, &csegs).map_err(|e| {
                            e.context(format!("client {}: compressed upload", frame.client))
                        })?;
                        let raw = dense_segments_wire_len(&segs.iter().collect::<Vec<_>>());
                        comm.record_with_raw(MsgKind::Upload, Direction::Uplink, n, raw);
                        // `dt` was charged at the top of the loop before the
                        // payload kind was known; attribute it here with the
                        // reconstructed raw bytes.
                        ledger.tap(
                            round,
                            frame.client,
                            MsgKind::Upload,
                            Direction::Uplink,
                            n,
                            raw,
                            dt,
                        );
                        segs
                    }
                    payload => payload.into_segments()?,
                };
                if segs.len() != 2 {
                    return Err(anyhow!(
                        "client {}: malformed upload ({} segments)",
                        frame.client,
                        segs.len()
                    ));
                }
                let prompt = segs.pop().expect("prompt");
                let tail = segs.pop().expect("tail");
                uploads[slot] = Some((tail, prompt));
                // The client's whole round of device work, charged now
                // that its Phase-2 batch count is known.
                let compute_s = clock.charge_compute(
                    slot,
                    crate::flops::sfprompt_client_round_flops(
                        cfg,
                        n_ks[slot],
                        smashed_batches[slot],
                        fed.local_epochs,
                        fed.local_loss_update,
                    ),
                );
                ledger.tap_compute(round, frame.client, compute_s);
                clock.mark_done(slot);
                pending -= 1;
            }
            MsgKind::Abort => {
                return Err(anyhow!("client {} aborted round {round}", frame.client));
            }
            other => return Err(anyhow!("unexpected {:?} frame on the server", other)),
        }
    }

    // Deadline resolution happens on upload marks, before the broadcast:
    // survivors are the clients whose upload beat the (possibly
    // quorum-extended) deadline.
    let survivors = clock.finish().survivors;

    // --- Phase 3: FedAvg over survivors + broadcast over the wire.
    // Dropped-but-online clients still receive the broadcast (their
    // threads are waiting on it, exactly like a real device that missed
    // the cut); only their upload is discarded.
    let agg = if survivors.is_empty() {
        None
    } else {
        let updates: Vec<(SegmentParams, SegmentParams, usize)> = survivors
            .iter()
            .map(|&slot| {
                let (tail, prompt) = uploads[slot].take().expect("survivor uploaded");
                (tail, prompt, n_ks[slot])
            })
            .collect();
        let agg_telemetry = crate::telemetry::active();
        let agg_span = agg_telemetry.as_ref().map(|t| t.span("phase", "aggregate"));
        let agg_t0 = Instant::now();
        let (tail, prompt) = Server::aggregate(&updates)?;
        drop(agg_span);
        if let Some(t) = &agg_telemetry {
            t.metrics.observe("aggregate_s", agg_t0.elapsed().as_secs_f64());
        }
        let bc = Payload::Segments(vec![tail.clone(), prompt.clone()]);
        for (slot, &cid) in selected.iter().enumerate() {
            if !clock.online(slot) {
                continue;
            }
            let frame = Frame::new(MsgKind::AggregateBroadcast, round, cid as u32, bc.clone());
            let n = hub.send_to(slot, &frame, WireFormat::F32)?;
            comm.record(MsgKind::AggregateBroadcast, Direction::Downlink, n);
            let dt = clock.charge_transfer(slot, n);
            ledger.tap(round, cid as u32, MsgKind::AggregateBroadcast, Direction::Downlink, n, n, dt);
        }
        Some((tail, prompt))
    };
    // The final resolve includes broadcast transfer time in the latency.
    let outcome = clock.finish();
    Ok((agg, outcome))
}
