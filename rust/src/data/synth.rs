//! Synthetic class-conditional image generator.
//!
//! Each class `c` gets a random but fixed "prototype field": a smooth
//! low-frequency pattern (sum of a few random 2-D cosines) plus a class-
//! specific colour bias. A sample is `prototype(c) + noise`, with a
//! per-dataset noise scale that controls task difficulty and a
//! `class_overlap` knob that mixes in a second class's prototype to create
//! genuinely hard (high-EL2N) examples — the structure dataset pruning
//! feeds on.

use crate::util::rng::Rng;

use super::Example;

/// Profile mirroring a real benchmark's geometry and class count.
#[derive(Debug, Clone, Copy)]
pub struct DatasetProfile {
    pub name: &'static str,
    pub num_classes: usize,
    pub noise: f32,
    /// Fraction of samples drawn near a class boundary (hard examples).
    pub class_overlap: f32,
}

/// The four evaluation datasets of the paper (§4.1), as synthetic profiles.
pub const PROFILES: &[DatasetProfile] = &[
    DatasetProfile { name: "cifar10", num_classes: 10, noise: 0.55, class_overlap: 0.15 },
    DatasetProfile { name: "cifar100", num_classes: 100, noise: 0.45, class_overlap: 0.20 },
    DatasetProfile { name: "svhn", num_classes: 10, noise: 0.80, class_overlap: 0.30 },
    DatasetProfile { name: "flower102", num_classes: 102, noise: 0.35, class_overlap: 0.10 },
];

pub fn profile(name: &str) -> Option<DatasetProfile> {
    PROFILES.iter().copied().find(|p| p.name == name)
}

/// A fully materialised synthetic dataset.
pub struct SynthDataset {
    pub profile: DatasetProfile,
    pub image_size: usize,
    pub channels: usize,
    pub examples: Vec<Example>,
}

struct ClassProto {
    /// (freq_y, freq_x, phase, amplitude) per component per channel
    waves: Vec<[f32; 4]>,
    color: Vec<f32>,
}

fn class_proto(rng: &mut Rng, channels: usize) -> ClassProto {
    let waves = (0..3 * channels)
        .map(|_| {
            [
                rng.uniform_f32() * 3.0 + 0.5,
                rng.uniform_f32() * 3.0 + 0.5,
                rng.uniform_f32() * std::f32::consts::TAU,
                rng.uniform_f32() * 0.8 + 0.4,
            ]
        })
        .collect();
    let color = (0..channels).map(|_| rng.normal_f32(0.0, 0.6)).collect();
    ClassProto { waves, color }
}

fn render(proto: &ClassProto, size: usize, channels: usize, out: &mut [f32]) {
    for y in 0..size {
        for x in 0..size {
            for ch in 0..channels {
                let mut v = proto.color[ch];
                for w in 0..3 {
                    let [fy, fx, phase, amp] = proto.waves[ch * 3 + w];
                    let arg = fy * y as f32 / size as f32 * std::f32::consts::TAU
                        + fx * x as f32 / size as f32 * std::f32::consts::TAU
                        + phase;
                    v += amp * arg.cos();
                }
                out[(y * size + x) * channels + ch] += v;
            }
        }
    }
}

impl SynthDataset {
    /// Generate `n` examples. Prototypes depend only on (`seed_protos`,
    /// class), so train and eval sets built with the same proto seed share
    /// class structure while having disjoint noise.
    pub fn generate(
        profile: DatasetProfile,
        image_size: usize,
        channels: usize,
        n: usize,
        seed_protos: u64,
        seed_samples: u64,
    ) -> SynthDataset {
        let mut proto_rng = Rng::new(seed_protos);
        let protos: Vec<ClassProto> =
            (0..profile.num_classes).map(|_| class_proto(&mut proto_rng, channels)).collect();

        let mut rng = Rng::new(seed_samples);
        let pixels = image_size * image_size * channels;
        let examples = (0..n)
            .map(|_| {
                let label = rng.below(profile.num_classes);
                let mut image = vec![0.0f32; pixels];
                render(&protos[label], image_size, channels, &mut image);
                if rng.uniform_f32() < profile.class_overlap {
                    // Hard example: blend with a random other class.
                    let other = rng.below(profile.num_classes);
                    let mut mix = vec![0.0f32; pixels];
                    render(&protos[other], image_size, channels, &mut mix);
                    let lam = 0.3 + 0.2 * rng.uniform_f32();
                    for (a, b) in image.iter_mut().zip(&mix) {
                        *a = (1.0 - lam) * *a + lam * *b;
                    }
                }
                for v in image.iter_mut() {
                    *v += rng.normal_f32(0.0, profile.noise);
                }
                Example { image, label: label as i32 }
            })
            .collect();

        SynthDataset { profile, image_size, channels, examples }
    }

    pub fn len(&self) -> usize {
        self.examples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.examples.is_empty()
    }

    pub fn labels(&self) -> Vec<i32> {
        self.examples.iter().map(|e| e.label).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_ds(seed: u64) -> SynthDataset {
        SynthDataset::generate(
            DatasetProfile { name: "t", num_classes: 4, noise: 0.3, class_overlap: 0.2 },
            8,
            3,
            64,
            1,
            seed,
        )
    }

    #[test]
    fn generates_requested_count_and_shapes() {
        let ds = tiny_ds(2);
        assert_eq!(ds.len(), 64);
        assert!(ds.examples.iter().all(|e| e.image.len() == 8 * 8 * 3));
        assert!(ds.examples.iter().all(|e| (0..4).contains(&e.label)));
    }

    #[test]
    fn deterministic_given_seeds() {
        let a = tiny_ds(3);
        let b = tiny_ds(3);
        assert_eq!(a.examples[0].image, b.examples[0].image);
        assert_eq!(a.labels(), b.labels());
    }

    #[test]
    fn noise_seeds_differ_but_protos_shared() {
        let a = tiny_ds(3);
        let b = tiny_ds(4);
        assert_ne!(a.examples[0].image, b.examples[0].image);
    }

    #[test]
    fn same_class_closer_than_cross_class() {
        // Class structure must be learnable: mean intra-class distance
        // should be well below mean inter-class distance.
        let ds = SynthDataset::generate(
            DatasetProfile { name: "t", num_classes: 3, noise: 0.2, class_overlap: 0.0 },
            8, 3, 120, 7, 8,
        );
        let dist = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f32>().sqrt()
        };
        let (mut intra, mut inter) = (Vec::new(), Vec::new());
        for i in 0..ds.len() {
            for j in (i + 1)..ds.len().min(i + 20) {
                let d = dist(&ds.examples[i].image, &ds.examples[j].image);
                if ds.examples[i].label == ds.examples[j].label {
                    intra.push(d as f64);
                } else {
                    inter.push(d as f64);
                }
            }
        }
        let m_intra = intra.iter().sum::<f64>() / intra.len() as f64;
        let m_inter = inter.iter().sum::<f64>() / inter.len() as f64;
        assert!(m_intra < 0.8 * m_inter, "intra {m_intra} inter {m_inter}");
    }

    #[test]
    fn all_profiles_have_distinct_names() {
        let mut names: Vec<&str> = PROFILES.iter().map(|p| p.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), PROFILES.len());
        assert!(profile("cifar100").is_some());
        assert!(profile("nope").is_none());
    }
}
