//! Datasets: synthetic class-conditional image corpora + batching.
//!
//! The paper fine-tunes on CIFAR-10/100, SVHN and Flower-102. Those are
//! substituted (DESIGN.md §Substitutions) by synthetic generators with the
//! same image geometry and class counts and a *learnable* class structure,
//! so accuracy trends (prompt vs linear vs FF, IID vs non-IID, pruning
//! fractions) are exercised end to end.

pub mod synth;

pub use synth::{DatasetProfile, SynthDataset, PROFILES};

use crate::runtime::tensor::HostTensor;

/// One training example (owned, host side).
#[derive(Debug, Clone)]
pub struct Example {
    pub image: Vec<f32>, // image_size * image_size * channels, HWC
    pub label: i32,
}

/// A batch assembled for a stage call.
#[derive(Debug, Clone)]
pub struct Batch {
    pub images: HostTensor, // [B, S, S, C] f32
    pub labels: HostTensor, // [B] i32
}

/// Assemble a batch from examples (pads by repeating the last example when
/// `idx` is shorter than `batch` — stage shapes are static).
pub fn make_batch(
    examples: &[Example],
    idx: &[usize],
    batch: usize,
    image_size: usize,
    channels: usize,
) -> Batch {
    assert!(!idx.is_empty(), "empty batch");
    let pixels = image_size * image_size * channels;
    let mut images = Vec::with_capacity(batch * pixels);
    let mut labels = Vec::with_capacity(batch);
    for i in 0..batch {
        let ex = &examples[idx[i.min(idx.len() - 1)]];
        images.extend_from_slice(&ex.image);
        labels.push(ex.label);
    }
    Batch {
        images: HostTensor::f32(vec![batch, image_size, image_size, channels], images),
        labels: HostTensor::i32(vec![batch], labels),
    }
}

/// Iterate `indices` in fixed-size chunks, padding the final chunk.
pub fn batch_indices(indices: &[usize], batch: usize) -> Vec<Vec<usize>> {
    let mut out = Vec::new();
    let mut cur: Vec<usize> = Vec::with_capacity(batch);
    for &i in indices {
        cur.push(i);
        if cur.len() == batch {
            out.push(std::mem::take(&mut cur));
        }
    }
    if !cur.is_empty() {
        while cur.len() < batch {
            cur.push(*cur.last().unwrap());
        }
        out.push(cur);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_indices_pads_tail() {
        let idx: Vec<usize> = (0..10).collect();
        let batches = batch_indices(&idx, 4);
        assert_eq!(batches.len(), 3);
        assert_eq!(batches[0], vec![0, 1, 2, 3]);
        assert_eq!(batches[2], vec![8, 9, 9, 9]);
    }

    #[test]
    fn batch_indices_exact_fit() {
        let idx: Vec<usize> = (0..8).collect();
        let batches = batch_indices(&idx, 4);
        assert_eq!(batches.len(), 2);
        assert!(batches.iter().all(|b| b.len() == 4));
    }

    #[test]
    fn make_batch_shapes() {
        let ex: Vec<Example> = (0..5)
            .map(|i| Example { image: vec![i as f32; 4 * 4 * 3], label: i })
            .collect();
        let b = make_batch(&ex, &[0, 2, 4], 4, 4, 3);
        assert_eq!(b.images.shape, vec![4, 4, 4, 3]);
        assert_eq!(b.labels.shape, vec![4]);
        let labels = b.labels.as_i32();
        assert_eq!(&labels[..3], &[0, 2, 4]);
        assert_eq!(labels[3], 4); // padded with the last example
    }
}
