//! Self-contained substrates: JSON, RNG, CLI parsing, CSV, timing.

pub mod cli;
pub mod csv;
pub mod json;
pub mod rng;
pub mod stats;
