//! Minimal CSV writer for experiment outputs (results/*.csv).

use std::fs;
use std::io::Write;
use std::path::Path;

pub struct CsvWriter {
    file: fs::File,
    columns: usize,
}

impl CsvWriter {
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> std::io::Result<CsvWriter> {
        if let Some(parent) = path.as_ref().parent() {
            fs::create_dir_all(parent)?;
        }
        let mut file = fs::File::create(path)?;
        writeln!(file, "{}", header.join(","))?;
        Ok(CsvWriter { file, columns: header.len() })
    }

    pub fn row(&mut self, values: &[String]) -> std::io::Result<()> {
        assert_eq!(values.len(), self.columns, "csv row arity mismatch");
        writeln!(self.file, "{}", values.join(","))
    }
}

/// Format helper: `csv_row![round, acc; "{:.4}"]`-style is overkill; a simple
/// trait keeps call sites terse.
pub fn fmt_f64(v: f64) -> String {
    format!("{:.6}", v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let dir = std::env::temp_dir().join(format!("sfp_csv_{}", std::process::id()));
        let path = dir.join("t.csv");
        {
            let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
            w.row(&["1".into(), "2".into()]).unwrap();
            w.row(&["x".into(), fmt_f64(0.5)]).unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 3);
        assert!(text.starts_with("a,b\n1,2\n"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let dir = std::env::temp_dir().join(format!("sfp_csv2_{}", std::process::id()));
        let mut w = CsvWriter::create(dir.join("t.csv"), &["a", "b"]).unwrap();
        let _ = w.row(&["only-one".into()]);
    }
}
