//! Minimal JSON parser / serialiser (substrate).
//!
//! The offline crate registry carries no `serde`/`serde_json`, so the
//! manifest interchange is handled by this self-contained recursive-descent
//! parser. It supports the full JSON grammar the AOT manifests use
//! (objects, arrays, strings with escapes, numbers, bools, null) and keeps
//! numbers as f64 (the manifests only contain integers small enough to be
//! exact in f64, which `as_i64`/`as_usize` assert).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        let n = self.as_f64()?;
        let i = n as i64;
        (i as f64 == n).then_some(i)
    }

    pub fn as_usize(&self) -> Option<usize> {
        let i = self.as_i64()?;
        usize::try_from(i).ok()
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj()?.get(key)
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Compact serialisation; `Json::to_string()` (via `ToString`) round-trips
/// everything this module parses.
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{}'", word)))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (multi-byte safe).
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(
            Json::parse("\"a\\nb\\u0041\"").unwrap(),
            Json::Str("a\nbA".into())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b"),
            Some(&Json::Bool(false))
        );
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"open").is_err());
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"k":[1,2.5,null,true,"s\n"],"o":{"n":-3}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn integers_are_exact() {
        let v = Json::parse("85798656").unwrap();
        assert_eq!(v.as_i64(), Some(85_798_656));
        assert_eq!(v.as_usize(), Some(85_798_656));
        assert_eq!(Json::parse("1.5").unwrap().as_i64(), None);
    }

    #[test]
    fn parses_unicode_passthrough() {
        let v = Json::parse("\"héllo → 世界\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo → 世界"));
    }
}
