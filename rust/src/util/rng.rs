//! Deterministic RNG substrate (SplitMix64 core + distributions).
//!
//! The offline registry has no `rand` crate; everything stochastic in the
//! coordinator (parameter init, synthetic data, Dirichlet partitioning,
//! client selection) runs on this generator so runs are reproducible from a
//! single seed.

/// The run's seed-domain map: every stochastic subsystem derives its
/// stream from the one `RunSpec`/`FedConfig` seed through a documented,
/// fixed derivation, so identical specs reproduce identical runs (see the
/// determinism regression test in `tests/fleet.rs`).
///
/// | domain                | derivation            | consumer                      |
/// |-----------------------|-----------------------|-------------------------------|
/// | engine root           | `seed`                | per-round client selection    |
/// | partition             | `root.fork(1)`        | IID / Dirichlet splits        |
/// | client `i` stream     | `root.fork(100 + i)`  | epoch shuffles                |
/// | parameter init        | `seed ^ 0xA5A5`       | `model::init_params`          |
/// | dataset prototypes    | `seed + 1000`         | synth class prototypes        |
/// | train samples         | `seed + 2000`         | synth train draws             |
/// | eval samples          | `seed + 9000`         | synth eval draws              |
/// | fleet                 | `seed ^ 0xF1EE7`      | device/link sampling + traces |
/// | compress, client `i`  | `(seed ^ 0xC0B5) + i·φ64` | rand-k draws, QSGD rounding |
pub mod seeds {
    /// Engine-root fork tag for the data partitioner.
    pub const PARTITION_FORK: u64 = 1;

    /// Engine-root fork tag for client `id`'s private stream.
    pub fn client_fork(id: usize) -> u64 {
        100 + id as u64
    }

    /// Seed for global parameter initialisation.
    pub fn param_init(seed: u64) -> u64 {
        seed ^ 0xA5A5
    }

    /// Seed for synthetic-dataset class prototypes (shared by train and
    /// eval so both splits draw from the same classes).
    pub fn data_protos(seed: u64) -> u64 {
        seed.wrapping_add(1000)
    }

    /// Seed for synthetic train-split sample draws.
    pub fn data_train(seed: u64) -> u64 {
        seed.wrapping_add(2000)
    }

    /// Seed for synthetic eval-split sample draws (disjoint from train).
    pub fn data_eval(seed: u64) -> u64 {
        seed.wrapping_add(9000)
    }

    /// Seed for the fleet simulator: device/link rate sampling and the
    /// per-round availability/straggler trace stream.
    pub fn fleet(seed: u64) -> u64 {
        seed ^ 0xF1EE7
    }

    /// Seed for client `client`'s update-compressor stream (rand-k
    /// coordinate draws, QSGD stochastic rounding). A pure derivation —
    /// not an engine-root fork — so enabling compression leaves every
    /// other documented stream untouched.
    pub fn compress_stream(seed: u64, client: usize) -> u64 {
        (seed ^ 0xC0B5)
            .wrapping_add((client as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }
}

/// SplitMix64 — tiny, fast, passes BigCrush for our purposes; the canonical
/// seeding sequence from Vigna (2015).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15) }
    }

    /// Derive an independent stream (for per-client / per-dataset RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xD6E8_FEB8_6659_FD93))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn uniform_f32(&mut self) -> f32 {
        self.uniform() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        // Rejection-free for our n << 2^64 use; modulo bias is negligible
        // (n is at most ~1e6 here) but we debias anyway.
        let zone = u64::MAX - (u64::MAX % n as u64);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n as u64) as usize;
            }
        }
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        }
    }

    pub fn normal_f32(&mut self, mean: f32, sigma: f32) -> f32 {
        mean + sigma * self.normal() as f32
    }

    /// Gamma(shape, 1) via Marsaglia-Tsang; shape > 0.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // Boost: Gamma(a) = Gamma(a+1) * U^(1/a)
            let g = self.gamma(shape + 1.0);
            let u = self.uniform().max(f64::MIN_POSITIVE);
            return g * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v = v * v * v;
            let u = self.uniform();
            if u < 1.0 - 0.0331 * x * x * x * x {
                return d * v;
            }
            if u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Dirichlet(alpha * 1_k): the non-IID split distribution (Hsu et al. 2019).
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let gs: Vec<f64> = (0..k).map(|_| self.gamma(alpha).max(1e-300)).collect();
        let sum: f64 = gs.iter().sum();
        gs.into_iter().map(|g| g / sum).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Sample k distinct indices from [0, n) (k <= n), order random.
    pub fn choose(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let a: Vec<u64> = (0..8).map({
            let mut r = Rng::new(7);
            move |_| r.next_u64()
        }).collect();
        let b: Vec<u64> = (0..8).map({
            let mut r = Rng::new(7);
            move |_| r.next_u64()
        }).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn uniform_in_range_and_mean() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn dirichlet_sums_to_one_and_alpha_controls_skew() {
        let mut r = Rng::new(3);
        for &alpha in &[0.1, 1.0, 10.0] {
            let d = r.dirichlet(alpha, 10);
            assert!((d.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            assert!(d.iter().all(|&p| p >= 0.0));
        }
        // Small alpha should concentrate mass: max component larger on average.
        let mut max_small = 0.0;
        let mut max_large = 0.0;
        for _ in 0..200 {
            max_small += r.dirichlet(0.1, 10).into_iter().fold(0.0, f64::max);
            max_large += r.dirichlet(10.0, 10).into_iter().fold(0.0, f64::max);
        }
        assert!(max_small > max_large * 1.5);
    }

    #[test]
    fn choose_is_distinct_subset() {
        let mut r = Rng::new(4);
        for _ in 0..50 {
            let picks = r.choose(50, 5);
            assert_eq!(picks.len(), 5);
            let mut s = picks.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), 5);
            assert!(picks.iter().all(|&i| i < 50));
        }
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Rng::new(5);
        for &shape in &[0.1f64, 0.5, 2.0] {
            let n = 20_000;
            let mean = (0..n).map(|_| r.gamma(shape)).sum::<f64>() / n as f64;
            assert!((mean - shape).abs() < 0.08 * shape.max(0.5), "shape {shape} mean {mean}");
        }
    }
}
