//! Tiny CLI argument parser (substrate — no `clap` offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    out.options.insert(rest.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|s| s.to_string()))
    }

    #[test]
    fn parses_mixed() {
        let a = parse("experiment --id fig4 --rounds=20 --verbose --out results");
        assert_eq!(a.positional, vec!["experiment"]);
        assert_eq!(a.get("id"), Some("fig4"));
        assert_eq!(a.get("rounds"), Some("20"));
        assert_eq!(a.get("out"), Some("results"));
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn get_parse_defaults() {
        let a = parse("train --lr 0.05");
        assert_eq!(a.get_parse("lr", 0.1f64), 0.05);
        assert_eq!(a.get_parse("rounds", 7usize), 7);
    }

    #[test]
    fn trailing_flag() {
        let a = parse("run --check");
        assert!(a.has_flag("check"));
        assert_eq!(a.get("check"), None);
    }
}
