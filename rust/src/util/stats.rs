//! Small statistics helpers shared by metrics and the bench harness.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// p-quantile via linear interpolation on a sorted copy.
pub fn quantile(xs: &[f64], p: f64) -> f64 {
    assert!((0.0..=1.0).contains(&p));
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = p * (s.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (pos - lo as f64) * (s[hi] - s[lo])
    }
}

/// Exponential moving average over a series (smoothing for loss curves).
pub fn ema(xs: &[f64], beta: f64) -> Vec<f64> {
    let mut out = Vec::with_capacity(xs.len());
    let mut acc = None;
    for &x in xs {
        let v = match acc {
            None => x,
            Some(prev) => beta * prev + (1.0 - beta) * x,
        };
        acc = Some(v);
        out.push(v);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((stddev(&xs) - 1.2909944).abs() < 1e-6);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[1.0]), 0.0);
    }

    #[test]
    fn quantiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert_eq!(quantile(&xs, 0.5), 2.5);
    }

    #[test]
    fn ema_converges() {
        let xs = vec![1.0; 100];
        let e = ema(&xs, 0.9);
        assert!((e[99] - 1.0).abs() < 1e-9);
        assert_eq!(e[0], 1.0);
    }
}
