//! Round-level metrics: accuracy evaluation, per-round records, reporting.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::backend::{run_stage_hosts, Backend, TensorInputs};
use crate::comm::ByteMeter;
use crate::data::{batch_indices, make_batch, SynthDataset};
use crate::model::ParamSet;
use crate::runtime::HostTensor;
use crate::sim::ClientEvent;

/// Metrics for one global round of any method.
#[derive(Debug, Clone)]
pub struct RoundRecord {
    pub round: usize,
    pub mean_local_loss: f64,
    pub mean_split_loss: f64,
    pub eval_accuracy: f64,
    pub comm: ByteMeter,
    pub wall_s: f64,
    pub sim_latency_s: f64,
    /// Per-selected-client fleet events (done / dropped with simulated
    /// times), chronological. The driver replays these to the observer.
    pub clients: Vec<ClientEvent>,
}

impl RoundRecord {
    /// Selected clients whose update the server aggregated this round.
    pub fn survivors(&self) -> usize {
        self.clients.iter().filter(|e| !e.is_dropped()).count()
    }

    /// Selected clients dropped this round (offline or past deadline).
    pub fn dropped(&self) -> usize {
        self.clients.iter().filter(|e| e.is_dropped()).count()
    }
}

/// Accumulated experiment output.
#[derive(Debug, Default, Clone)]
pub struct RunHistory {
    pub rounds: Vec<RoundRecord>,
    pub total_comm: ByteMeter,
    /// Real (measured) wall-clock of the whole driven run, stamped by
    /// [`crate::federation::drive`]. Zero for histories built elsewhere
    /// (e.g. hand-assembled in tests); distinct from [`Self::sim_wall_s`],
    /// which is simulated time.
    pub run_wall_s: f64,
}

impl RunHistory {
    pub fn push(&mut self, rec: RoundRecord) {
        self.total_comm.merge(&rec.comm);
        self.rounds.push(rec);
    }

    pub fn final_accuracy(&self) -> f64 {
        self.rounds.last().map_or(0.0, |r| r.eval_accuracy)
    }

    pub fn best_accuracy(&self) -> f64 {
        self.rounds.iter().map(|r| r.eval_accuracy).fold(0.0, f64::max)
    }

    pub fn comm_mb_per_round(&self) -> f64 {
        if self.rounds.is_empty() {
            0.0
        } else {
            self.total_comm.mb() / self.rounds.len() as f64
        }
    }

    /// Total simulated wall-clock: the sum of per-round §3.5 latencies.
    pub fn sim_wall_s(&self) -> f64 {
        self.rounds.iter().map(|r| r.sim_latency_s).sum()
    }

    /// Selected-client drops (offline or past deadline) across all rounds.
    pub fn dropped_clients(&self) -> usize {
        self.rounds.iter().map(|r| r.dropped()).sum()
    }
}

/// Argmax accuracy of `logits` [B, C] against labels [B], counting only the
/// first `valid` rows (tail batches are padded).
pub fn batch_accuracy(logits: &HostTensor, labels: &HostTensor, valid: usize) -> (usize, usize) {
    let c = logits.shape[1];
    let l = logits.as_f32();
    let y = labels.as_i32();
    let mut correct = 0;
    for (i, &label) in y.iter().enumerate().take(valid) {
        let row = &l[i * c..(i + 1) * c];
        // total_cmp: a NaN logit (diverged run) must not panic the eval.
        let pred = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(j, _)| j as i32)
            .unwrap();
        if pred == label {
            correct += 1;
        }
    }
    (correct, valid)
}

/// Evaluate model accuracy over an eval dataset with the given eval stage
/// (`eval_forward` with prompt, `eval_forward_noprompt` without).
pub fn evaluate(
    backend: &dyn Backend,
    stage: &str,
    params: &ParamSet,
    eval: &SynthDataset,
    limit: Option<usize>,
) -> Result<f64> {
    let cfg = &backend.manifest().config;
    let n = limit.unwrap_or(eval.len()).min(eval.len());
    let idx: Vec<usize> = (0..n).collect();
    let needs_prompt = backend.manifest().stage(stage)?.inputs.iter().any(|io| {
        matches!(io, crate::runtime::IoSpec::Segment(s) if s == "prompt")
    });

    let mut correct = 0usize;
    let mut total = 0usize;
    for chunk in batch_indices(&idx, cfg.batch) {
        let valid = chunk.iter().collect::<std::collections::BTreeSet<_>>().len();
        let batch = make_batch(&eval.examples, &chunk, cfg.batch, cfg.image_size, cfg.channels);
        let mut segs: BTreeMap<&str, &crate::model::SegmentParams> = BTreeMap::new();
        for seg in ["head", "body", "tail"] {
            segs.insert(seg, params.get(seg)?);
        }
        if needs_prompt {
            segs.insert("prompt", params.get("prompt")?);
        }
        let mut tensors: TensorInputs = BTreeMap::new();
        tensors.insert("images", &batch.images);
        let out = run_stage_hosts(backend, stage, &segs, &tensors)?;
        let logits = out.tensor("logits")?;
        let (c, t) = batch_accuracy(logits, &batch.labels, valid);
        correct += c;
        total += t;
    }
    Ok(correct as f64 / total.max(1) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_accuracy_counts_correctly() {
        let logits = HostTensor::f32(vec![3, 2], vec![1.0, 0.0, 0.0, 1.0, 2.0, -1.0]);
        let labels = HostTensor::i32(vec![3], vec![0, 1, 1]);
        let (c, t) = batch_accuracy(&logits, &labels, 3);
        assert_eq!((c, t), (2, 3));
        // padded row excluded
        let (c, t) = batch_accuracy(&logits, &labels, 2);
        assert_eq!((c, t), (2, 2));
    }

    #[test]
    fn history_aggregates() {
        let mut h = RunHistory::default();
        for r in 0..3 {
            let mut comm = ByteMeter::default();
            comm.record(
                crate::comm::MsgKind::Upload,
                crate::comm::Direction::Uplink,
                100,
            );
            h.push(RoundRecord {
                round: r,
                mean_local_loss: 1.0,
                mean_split_loss: 1.0,
                eval_accuracy: 0.1 * r as f64,
                comm,
                wall_s: 0.0,
                sim_latency_s: 0.0,
                clients: Vec::new(),
            });
        }
        assert_eq!(h.total_comm.total(), 300);
        assert!((h.final_accuracy() - 0.2).abs() < 1e-12);
        assert!((h.comm_mb_per_round() - 1e-4).abs() < 1e-9);
    }
}
