//! Cross-process trace stitching (`sfprompt trace merge`).
//!
//! A networked run writes one JSONL trace per process (coordinator plus
//! each client process), each stamped against its own monotonic epoch and
//! carrying the distributed-trace identity from the v2 header: a shared
//! 128-bit `trace_id`, a disjoint span-id block (`span_base`), and an
//! NTP-style clock estimate against the coordinator
//! (`coordinator_time = local_time + offset_s`, error bounded by `rtt_s`).
//! This module joins those files into one causally-consistent tree:
//!
//! * **Re-basing** — every span's `t0_s`/`t1_s` shift by its process's
//!   offset onto the coordinator timeline. Durations are untouched (both
//!   endpoints shift together), so per-process monotonicity survives.
//! * **Remote-parent resolution** — spans recorded with `rp` (a parent id
//!   living in another process) get a real parent edge once the owning
//!   trace is present; an `rp` that resolves to no span is an error, not
//!   a silent root.
//! * **Skew flagging** — after re-basing, a child that escapes its remote
//!   parent's interval by more than the clock estimate's RTT bound is
//!   flagged `skew: true`. Timestamps are never clamped or fabricated —
//!   the flag tells the reader the overlap is a clock artefact.
//!
//! The merged document serialises as JSONL (a `merged: true` v2 header
//! listing every process, then spans tagged with their process index) or
//! as Chrome trace-event JSON with one `pid` per process. See
//! docs/TRACING.md for the full schema and worked examples.

use std::collections::BTreeMap;

use crate::util::json::Json;

/// Tolerance added to every skew comparison so exact-boundary floating
/// point never flags a legitimate edge.
const SKEW_EPS: f64 = 1e-9;

#[derive(Debug, Clone)]
struct ParsedSpan {
    id: u64,
    parent: Option<u64>,
    remote_parent: Option<u64>,
    cat: String,
    name: String,
    tid: u64,
    t0_s: f64,
    t1_s: f64,
    sim_s: Option<f64>,
    attrs: Vec<(String, f64)>,
    open: bool,
}

/// One per-process trace file, parsed from the JSONL the [`super::Tracer`]
/// writes (v1 single-process or v2 distributed headers).
#[derive(Debug, Clone)]
pub struct ProcessTrace {
    /// Process label from the v2 header ("coordinator", "client-0", ...);
    /// empty for v1 traces.
    pub process: String,
    /// Run-wide trace id (0 for v1 traces).
    pub trace_id: u128,
    /// Start of this process's span-id block.
    pub span_base: u64,
    /// `(offset_s, rtt_s)` against the coordinator; `None` means this
    /// process *is* the coordinator timeline (offset treated as 0).
    pub clock: Option<(f64, f64)>,
    spans: Vec<ParsedSpan>,
}

impl ProcessTrace {
    /// Parse one trace file. Strict about structure (header first, every
    /// span line carries the required keys) but tolerant of unknown keys,
    /// mirroring the Python validator.
    pub fn parse(text: &str) -> Result<ProcessTrace, String> {
        let mut lines = text.lines().filter(|l| !l.trim().is_empty());
        let head = lines.next().ok_or("empty trace file")?;
        let meta = Json::parse(head).map_err(|e| format!("bad meta line: {e}"))?;
        if meta.get("ev").and_then(Json::as_str) != Some("meta")
            || meta.get("format").and_then(Json::as_str) != Some("sfprompt-trace")
        {
            return Err("first line is not an sfprompt-trace meta header".into());
        }
        let version = meta
            .get("version")
            .and_then(Json::as_i64)
            .ok_or("meta missing version")?;
        if !(1..=2).contains(&version) {
            return Err(format!("unsupported trace version {version}"));
        }
        let trace_id = match meta.get("trace_id").and_then(Json::as_str) {
            Some(h) => u128::from_str_radix(h, 16).map_err(|_| "bad trace_id hex")?,
            None => 0,
        };
        let process = meta
            .get("process")
            .and_then(Json::as_str)
            .unwrap_or("")
            .to_string();
        let span_base = meta
            .get("span_base")
            .and_then(Json::as_i64)
            .unwrap_or(0) as u64;
        let clock = meta.get("clock").map(|c| {
            let off = c.get("offset_s").and_then(Json::as_f64).unwrap_or(0.0);
            let rtt = c.get("rtt_s").and_then(Json::as_f64).unwrap_or(0.0);
            (off, rtt)
        });
        let mut spans = Vec::new();
        for (i, line) in lines.enumerate() {
            let j = Json::parse(line).map_err(|e| format!("bad span line {}: {e}", i + 2))?;
            if j.get("ev").and_then(Json::as_str) != Some("span") {
                return Err(format!("line {} is not a span", i + 2));
            }
            let id = j
                .get("id")
                .and_then(Json::as_i64)
                .ok_or_else(|| format!("span line {} missing id", i + 2))? as u64;
            let need_f64 = |key: &str| {
                j.get(key)
                    .and_then(Json::as_f64)
                    .ok_or_else(|| format!("span {id} missing {key}"))
            };
            let parent = match j.get("parent") {
                Some(Json::Null) | None => None,
                Some(p) => Some(p.as_i64().ok_or_else(|| format!("span {id} bad parent"))? as u64),
            };
            let remote_parent = j.get("rp").and_then(Json::as_i64).map(|v| v as u64);
            let attrs = j
                .get("attrs")
                .and_then(Json::as_obj)
                .map(|o| {
                    o.iter()
                        .filter_map(|(k, v)| v.as_f64().map(|n| (k.clone(), n)))
                        .collect()
                })
                .unwrap_or_default();
            spans.push(ParsedSpan {
                id,
                parent,
                remote_parent,
                cat: j.get("cat").and_then(Json::as_str).unwrap_or("").to_string(),
                name: j.get("name").and_then(Json::as_str).unwrap_or("").to_string(),
                tid: j.get("tid").and_then(Json::as_i64).unwrap_or(0) as u64,
                t0_s: need_f64("t0_s")?,
                t1_s: need_f64("t1_s")?,
                sim_s: j.get("sim_s").and_then(Json::as_f64),
                attrs,
                open: j.get("open").and_then(Json::as_bool).unwrap_or(false),
            });
        }
        Ok(ProcessTrace { process, trace_id, span_base, clock, spans })
    }
}

/// One span in the merged tree, re-based onto the coordinator timeline.
#[derive(Debug, Clone)]
pub struct MergedSpan {
    /// Index into [`MergedTrace::processes`].
    pub proc: usize,
    pub id: u64,
    /// Resolved parent — local edges kept, `rp` edges resolved.
    pub parent: Option<u64>,
    /// True when the parent edge crossed a process boundary.
    pub remote: bool,
    pub cat: String,
    pub name: String,
    pub tid: u64,
    /// Re-based wall clock (coordinator timeline).
    pub t0_s: f64,
    pub t1_s: f64,
    pub sim_s: Option<f64>,
    pub attrs: Vec<(String, f64)>,
    pub open: bool,
    /// True when this span escapes its remote parent's interval by more
    /// than the clock estimate's RTT bound — a clock artefact the merge
    /// surfaces instead of hiding.
    pub skew: bool,
}

/// Per-process header info carried into the merged document.
#[derive(Debug, Clone)]
pub struct MergedProcess {
    pub process: String,
    pub span_base: u64,
    /// Offset applied during re-basing (0 for the coordinator).
    pub offset_s: f64,
    /// RTT bound of the clock estimate (0 for the coordinator).
    pub rtt_s: f64,
}

/// The stitched, causally-consistent union of several process traces.
#[derive(Debug, Clone)]
pub struct MergedTrace {
    pub trace_id: u128,
    pub processes: Vec<MergedProcess>,
    /// All spans, sorted by re-based start time.
    pub spans: Vec<MergedSpan>,
}

/// Join per-process traces into one tree. Errors (rather than guessing)
/// on: mismatched trace ids, colliding span ids, or an `rp` that resolves
/// to no span in any input.
pub fn merge_traces(traces: &[ProcessTrace]) -> Result<MergedTrace, String> {
    if traces.is_empty() {
        return Err("no traces to merge".into());
    }
    // All non-zero trace ids must agree; with >1 process they must be set.
    let mut trace_id = 0u128;
    for t in traces {
        if t.trace_id != 0 {
            if trace_id != 0 && t.trace_id != trace_id {
                return Err(format!(
                    "trace id mismatch: {:032x} vs {:032x}",
                    trace_id, t.trace_id
                ));
            }
            trace_id = t.trace_id;
        } else if traces.len() > 1 {
            return Err(format!(
                "trace '{}' has no trace_id — not part of a distributed run",
                t.process
            ));
        }
    }
    // Canonical process order — ascending span base puts the coordinator
    // (base 0) first however the files were listed on the command line.
    let mut ordered: Vec<&ProcessTrace> = traces.iter().collect();
    ordered.sort_by_key(|t| t.span_base);

    let processes: Vec<MergedProcess> = ordered
        .iter()
        .map(|t| {
            let (offset_s, rtt_s) = t.clock.unwrap_or((0.0, 0.0));
            MergedProcess {
                process: t.process.clone(),
                span_base: t.span_base,
                offset_s,
                rtt_s,
            }
        })
        .collect();

    // Re-base and check span-id uniqueness across the union.
    let mut spans: Vec<MergedSpan> = Vec::new();
    let mut owner: BTreeMap<u64, usize> = BTreeMap::new();
    for (pi, t) in ordered.iter().enumerate() {
        let off = processes[pi].offset_s;
        for s in &t.spans {
            if owner.insert(s.id, spans.len()).is_some() {
                return Err(format!("span id {} appears in two traces", s.id));
            }
            let (parent, remote) = match s.remote_parent {
                Some(rp) => (Some(rp), true),
                None => (s.parent, false),
            };
            spans.push(MergedSpan {
                proc: pi,
                id: s.id,
                parent,
                remote,
                cat: s.cat.clone(),
                name: s.name.clone(),
                tid: s.tid,
                t0_s: s.t0_s + off,
                t1_s: s.t1_s + off,
                sim_s: s.sim_s,
                attrs: s.attrs.clone(),
                open: s.open,
                skew: false,
            });
        }
    }

    // Resolve every parent edge and flag skew on cross-process ones.
    for i in 0..spans.len() {
        let Some(pid) = spans[i].parent else { continue };
        let Some(&pj) = owner.get(&pid) else {
            return Err(format!(
                "span {} ({}) has unresolvable parent {}",
                spans[i].id, spans[i].name, pid
            ));
        };
        if spans[i].remote {
            let bound = processes[spans[i].proc].rtt_s + SKEW_EPS;
            let (c0, c1) = (spans[i].t0_s, spans[i].t1_s);
            let (p0, p1) = (spans[pj].t0_s, spans[pj].t1_s);
            if c0 < p0 - bound || c1 > p1 + bound {
                spans[i].skew = true;
            }
        } else if spans[pj].proc != spans[i].proc {
            return Err(format!(
                "span {} has a local parent edge into another process",
                spans[i].id
            ));
        }
    }
    spans.sort_by(|a, b| a.t0_s.total_cmp(&b.t0_s).then(a.id.cmp(&b.id)));
    Ok(MergedTrace { trace_id, processes, spans })
}

impl MergedTrace {
    /// JSONL serialisation: a `merged: true` v2 header naming every
    /// process, then one span per line in re-based start order. Same span
    /// schema as a single-process trace plus `proc` (process index) and
    /// `skew` where flagged; `rp` is kept for provenance on remote edges.
    pub fn to_jsonl(&self) -> String {
        let mut meta = BTreeMap::new();
        meta.insert("ev".into(), Json::Str("meta".into()));
        meta.insert("format".into(), Json::Str("sfprompt-trace".into()));
        meta.insert("version".into(), Json::Num(2.0));
        meta.insert("merged".into(), Json::Bool(true));
        meta.insert(
            "trace_id".into(),
            Json::Str(format!("{:032x}", self.trace_id)),
        );
        let procs: Vec<Json> = self
            .processes
            .iter()
            .map(|p| {
                let mut o = BTreeMap::new();
                o.insert("process".into(), Json::Str(p.process.clone()));
                o.insert("span_base".into(), Json::Num(p.span_base as f64));
                o.insert("offset_s".into(), Json::Num(p.offset_s));
                o.insert("rtt_s".into(), Json::Num(p.rtt_s));
                Json::Obj(o)
            })
            .collect();
        meta.insert("processes".into(), Json::Arr(procs));
        let mut out = Json::Obj(meta).to_string();
        out.push('\n');
        for s in &self.spans {
            let mut o = BTreeMap::new();
            o.insert("ev".into(), Json::Str("span".into()));
            o.insert("id".into(), Json::Num(s.id as f64));
            o.insert(
                "parent".into(),
                s.parent.map_or(Json::Null, |p| Json::Num(p as f64)),
            );
            if s.remote {
                o.insert("rp".into(), Json::Num(s.parent.unwrap_or(0) as f64));
            }
            o.insert("proc".into(), Json::Num(s.proc as f64));
            o.insert("cat".into(), Json::Str(s.cat.clone()));
            o.insert("name".into(), Json::Str(s.name.clone()));
            o.insert("tid".into(), Json::Num(s.tid as f64));
            o.insert("t0_s".into(), Json::Num(s.t0_s));
            o.insert("t1_s".into(), Json::Num(s.t1_s));
            if let Some(sim) = s.sim_s {
                o.insert("sim_s".into(), Json::Num(sim));
            }
            if s.open {
                o.insert("open".into(), Json::Bool(true));
            }
            if s.skew {
                o.insert("skew".into(), Json::Bool(true));
            }
            if !s.attrs.is_empty() {
                let attrs: BTreeMap<String, Json> = s
                    .attrs
                    .iter()
                    .map(|(k, v)| (k.clone(), Json::Num(*v)))
                    .collect();
                o.insert("attrs".into(), Json::Obj(attrs));
            }
            out.push_str(&Json::Obj(o).to_string());
            out.push('\n');
        }
        out
    }

    /// Chrome trace-event JSON with one `pid` per process (pid = process
    /// index + 1, named via metadata events) — Perfetto shows the
    /// coordinator and each client as separate process tracks on the
    /// shared, re-based timeline.
    pub fn to_chrome_trace(&self) -> Json {
        let mut events: Vec<Json> = Vec::new();
        for (pi, p) in self.processes.iter().enumerate() {
            let mut e = BTreeMap::new();
            e.insert("name".into(), Json::Str("process_name".into()));
            e.insert("ph".into(), Json::Str("M".into()));
            e.insert("pid".into(), Json::Num((pi + 1) as f64));
            e.insert("tid".into(), Json::Num(0.0));
            let mut args = BTreeMap::new();
            args.insert("name".into(), Json::Str(p.process.clone()));
            e.insert("args".into(), Json::Obj(args));
            events.push(Json::Obj(e));
        }
        for s in &self.spans {
            let mut e = BTreeMap::new();
            e.insert("name".into(), Json::Str(s.name.clone()));
            e.insert("cat".into(), Json::Str(s.cat.clone()));
            e.insert("ph".into(), Json::Str("X".into()));
            e.insert("ts".into(), Json::Num(s.t0_s * 1e6));
            e.insert("dur".into(), Json::Num((s.t1_s - s.t0_s) * 1e6));
            e.insert("pid".into(), Json::Num((s.proc + 1) as f64));
            e.insert("tid".into(), Json::Num(s.tid as f64));
            let mut args = BTreeMap::new();
            if let Some(sim) = s.sim_s {
                args.insert("sim_s".into(), Json::Num(sim));
            }
            if s.skew {
                args.insert("skew".into(), Json::Num(1.0));
            }
            for (k, v) in &s.attrs {
                args.insert(k.clone(), Json::Num(*v));
            }
            if !args.is_empty() {
                e.insert("args".into(), Json::Obj(args));
            }
            events.push(Json::Obj(e));
        }
        let mut doc = BTreeMap::new();
        doc.insert("traceEvents".into(), Json::Arr(events));
        doc.insert("displayTimeUnit".into(), Json::Str("ms".into()));
        Json::Obj(doc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::Tracer;

    /// Build a coordinator + one client trace pair the way the networked
    /// run does: shared trace id, disjoint span bases, client clock offset.
    fn traced_pair(offset: f64, rtt: f64) -> (String, String) {
        let coord = Tracer::new();
        coord.set_trace_context(0xabc, "coordinator", 0);
        let run = coord.open("run", "run:sfprompt", None);
        let round = coord.open("round", "round:0", None);
        coord.close(round, None, Vec::new());
        coord.close(run, None, Vec::new());
        coord.finish();

        let client = Tracer::new();
        client.set_trace_context(0xabc, "client-0", 1u64 << 40);
        client.set_clock(offset, rtt);
        let c = client.open_remote("client", "client:0", round);
        let phase = client.open("phase", "phase1_local", None);
        client.close(phase, None, Vec::new());
        client.close(c, None, Vec::new());
        client.finish();
        (coord.to_jsonl(), client.to_jsonl())
    }

    #[test]
    fn merge_resolves_remote_parents_and_rebases() {
        let (a, b) = traced_pair(5.0, 0.001);
        let ta = ProcessTrace::parse(&a).unwrap();
        let tb = ProcessTrace::parse(&b).unwrap();
        let merged = merge_traces(&[ta, tb]).unwrap();
        assert_eq!(merged.trace_id, 0xabc);
        assert_eq!(merged.processes.len(), 2);
        let client = merged
            .spans
            .iter()
            .find(|s| s.name.starts_with("client:"))
            .unwrap();
        assert!(client.remote);
        let round = merged.spans.iter().find(|s| s.cat == "round").unwrap();
        assert_eq!(client.parent, Some(round.id));
        // Client timestamps moved onto the coordinator timeline.
        assert!(client.t0_s >= 5.0);
        // Local nesting inside the client process survived the merge.
        let phase = merged.spans.iter().find(|s| s.cat == "phase").unwrap();
        assert_eq!(phase.parent, Some(client.id));
        assert!(!phase.remote);
        // Per-process order is preserved: phase sits inside client.
        assert!(phase.t0_s >= client.t0_s - 1e-9 && phase.t1_s <= client.t1_s + 1e-9);
    }

    #[test]
    fn large_offset_flags_skew_instead_of_clamping() {
        let (a, b) = traced_pair(5.0, 0.001);
        let ta = ProcessTrace::parse(&a).unwrap();
        let tb = ProcessTrace::parse(&b).unwrap();
        let merged = merge_traces(&[ta, tb]).unwrap();
        let client = merged
            .spans
            .iter()
            .find(|s| s.name.starts_with("client:"))
            .unwrap();
        // A +5s offset pushes the client span far outside its parent
        // round span: flagged, and the timestamps left alone.
        assert!(client.skew);
        assert!(client.t1_s > 5.0);
    }

    #[test]
    fn unresolvable_remote_parent_is_an_error() {
        let (_, b) = traced_pair(0.0, 0.0);
        let tb = ProcessTrace::parse(&b).unwrap();
        let err = merge_traces(&[tb]).unwrap_err();
        assert!(err.contains("unresolvable"), "got: {err}");
    }

    #[test]
    fn mismatched_trace_ids_are_an_error() {
        let t1 = Tracer::new();
        t1.set_trace_context(1, "coordinator", 0);
        let s = t1.open("run", "run:x", None);
        t1.close(s, None, Vec::new());
        t1.finish();
        let t2 = Tracer::new();
        t2.set_trace_context(2, "client-0", 1 << 40);
        let s = t2.open("run", "run:y", None);
        t2.close(s, None, Vec::new());
        t2.finish();
        let a = ProcessTrace::parse(&t1.to_jsonl()).unwrap();
        let b = ProcessTrace::parse(&t2.to_jsonl()).unwrap();
        assert!(merge_traces(&[a, b]).unwrap_err().contains("mismatch"));
    }

    #[test]
    fn merged_jsonl_round_trips_and_marks_processes() {
        let (a, b) = traced_pair(0.0, 0.01);
        let ta = ProcessTrace::parse(&a).unwrap();
        let tb = ProcessTrace::parse(&b).unwrap();
        let merged = merge_traces(&[ta, tb]).unwrap();
        let text = merged.to_jsonl();
        let mut lines = text.lines();
        let meta = Json::parse(lines.next().unwrap()).unwrap();
        assert_eq!(meta.get("merged"), Some(&Json::Bool(true)));
        assert_eq!(
            meta.get("processes").and_then(Json::as_arr).unwrap().len(),
            2
        );
        for line in lines {
            let j = Json::parse(line).unwrap();
            assert!(j.get("proc").and_then(Json::as_i64).is_some());
        }
        let chrome = merged.to_chrome_trace();
        let evs = chrome.get("traceEvents").and_then(Json::as_arr).unwrap();
        // 2 process_name metadata events + 4 spans.
        assert_eq!(evs.len(), 6);
    }
}
