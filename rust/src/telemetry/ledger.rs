//! Per-(round, client, phase, message-kind) communication-cost ledger.
//!
//! SFPrompt's headline numbers are *attribution* claims — how much of the
//! traffic and compute belongs to Phase 1 (network-free local update +
//! pruning), Phase 2 (split execution), and Phase 3 (upload/aggregate).
//! [`ByteMeter`] measures totals per kind; this ledger re-attributes the
//! **same measurements** onto the paper's structure: every engine tap
//! site that records into the meter also taps the ledger with the same
//! `(wire, raw)` byte counts plus the sim-clock transfer time that
//! [`crate::sim::SimClock::charge_transfer`] returned for the message,
//! and every `charge_compute` call taps its analytic compute seconds.
//!
//! The invariant — checked by [`Ledger::reconcile`] and property-tested
//! in `tests/proptests.rs` — is that per-kind row sums equal the meter's
//! `by_kind` / `raw_by_kind` totals **bit-exactly**: the ledger is a
//! re-attribution, never a re-measurement.
//!
//! A sealed run carries the ledger in its `RunReport` under `"ledger"`
//! (see docs/TRACING.md for the schema); `sfprompt report --waterfall`
//! renders it as a per-round transfer-vs-compute waterfall.

use std::collections::BTreeMap;

use crate::comm::{ByteMeter, Direction, MsgKind};
use crate::util::json::Json;

/// The paper phase a message kind belongs to (Algorithm 2's structure).
pub fn phase_of(kind: MsgKind) -> &'static str {
    match kind {
        MsgKind::ModelDistribution => "distribute",
        MsgKind::SmashedData
        | MsgKind::BodyOutput
        | MsgKind::GradBodyOut
        | MsgKind::GradSmashed => "phase2_split",
        MsgKind::Upload | MsgKind::AggregateBroadcast => "phase3_upload",
        MsgKind::FullModel => "full_exchange",
        MsgKind::Abort => "control",
    }
}

/// One (round, client, kind) cell: bytes by direction, the dense-f32
/// equivalent, message count, and accumulated sim-clock transfer time.
#[derive(Debug, Default, Clone, PartialEq)]
pub struct LedgerRow {
    pub up_bytes: u64,
    pub down_bytes: u64,
    pub raw_bytes: u64,
    pub messages: u64,
    pub transfer_s: f64,
}

/// The cost ledger: a sparse table over (round, client, msg-kind) plus a
/// per-(round, client) compute-seconds table.
#[derive(Debug, Default, Clone)]
pub struct Ledger {
    rows: BTreeMap<(u32, u32, &'static str), LedgerRow>,
    compute: BTreeMap<(u32, u32), f64>,
}

impl Ledger {
    pub fn new() -> Ledger {
        Ledger::default()
    }

    /// Record one transmission — called at the **same site**, with the
    /// **same byte counts**, as the paired `ByteMeter` record, plus the
    /// `dt` seconds `SimClock::charge_transfer` returned for it.
    pub fn tap(
        &mut self,
        round: u32,
        client: u32,
        kind: MsgKind,
        dir: Direction,
        wire_bytes: usize,
        raw_bytes: usize,
        transfer_s: f64,
    ) {
        let row = self.rows.entry((round, client, kind.label())).or_default();
        match dir {
            Direction::Uplink => row.up_bytes += wire_bytes as u64,
            Direction::Downlink => row.down_bytes += wire_bytes as u64,
        }
        row.raw_bytes += raw_bytes as u64;
        row.messages += 1;
        row.transfer_s += transfer_s;
    }

    /// Record the seconds `SimClock::charge_compute` charged a client for
    /// its round's local compute.
    pub fn tap_compute(&mut self, round: u32, client: u32, secs: f64) {
        *self.compute.entry((round, client)).or_insert(0.0) += secs;
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty() && self.compute.is_empty()
    }

    /// Per-kind (wire, raw) byte sums across all rows — the quantities
    /// that must equal the meter's `by_kind` / `raw_by_kind` exactly.
    pub fn by_kind_totals(&self) -> (BTreeMap<&'static str, u64>, BTreeMap<&'static str, u64>) {
        let mut wire: BTreeMap<&'static str, u64> = BTreeMap::new();
        let mut raw: BTreeMap<&'static str, u64> = BTreeMap::new();
        for ((_, _, kind), row) in &self.rows {
            *wire.entry(kind).or_insert(0) += row.up_bytes + row.down_bytes;
            *raw.entry(kind).or_insert(0) += row.raw_bytes;
        }
        (wire, raw)
    }

    /// Total messages across all rows (must equal `ByteMeter::messages`).
    pub fn total_messages(&self) -> u64 {
        self.rows.values().map(|r| r.messages).sum()
    }

    /// Check the re-attribution invariant against the meter that was fed
    /// at the same tap sites. `Err` carries a human-readable diagnosis.
    pub fn reconcile(&self, meter: &ByteMeter) -> Result<(), String> {
        let (wire, raw) = self.by_kind_totals();
        if wire != meter.by_kind {
            return Err(format!(
                "ledger wire bytes diverge from ByteMeter: ledger {wire:?} vs meter {:?}",
                meter.by_kind
            ));
        }
        if raw != meter.raw_by_kind {
            return Err(format!(
                "ledger raw bytes diverge from ByteMeter: ledger {raw:?} vs meter {:?}",
                meter.raw_by_kind
            ));
        }
        if self.total_messages() != meter.messages {
            return Err(format!(
                "ledger counts {} messages, meter {}",
                self.total_messages(),
                meter.messages
            ));
        }
        let up: u64 = self.rows.values().map(|r| r.up_bytes).sum();
        let down: u64 = self.rows.values().map(|r| r.down_bytes).sum();
        if up != meter.uplink || down != meter.downlink {
            return Err(format!(
                "ledger directions ({up} up / {down} down) diverge from meter ({} / {})",
                meter.uplink, meter.downlink
            ));
        }
        Ok(())
    }

    /// Rounds present in the ledger, ascending.
    pub fn rounds(&self) -> Vec<u32> {
        let mut out: Vec<u32> = self.rows.keys().map(|(r, _, _)| *r).collect();
        out.extend(self.compute.keys().map(|(r, _)| *r));
        out.sort_unstable();
        out.dedup();
        out
    }

    /// All rows of one round as (client, kind, row), plus that round's
    /// per-client compute seconds — the waterfall renderer's view.
    pub fn round_view(&self, round: u32) -> (Vec<(u32, &'static str, &LedgerRow)>, BTreeMap<u32, f64>) {
        let rows = self
            .rows
            .iter()
            .filter(|((r, _, _), _)| *r == round)
            .map(|((_, c, k), row)| (*c, *k, row))
            .collect();
        let compute = self
            .compute
            .iter()
            .filter(|((r, _), _)| *r == round)
            .map(|((_, c), s)| (*c, *s))
            .collect();
        (rows, compute)
    }

    /// The `"ledger"` block sealed into a `RunReport`.
    pub fn to_json(&self) -> Json {
        let mut rows = Vec::with_capacity(self.rows.len());
        for ((round, client, kind), row) in &self.rows {
            let mut o = BTreeMap::new();
            o.insert("round".to_string(), Json::Num(*round as f64));
            o.insert("client".to_string(), Json::Num(*client as f64));
            o.insert("kind".to_string(), Json::Str((*kind).to_string()));
            o.insert(
                "phase".to_string(),
                Json::Str(phase_label_of(kind).to_string()),
            );
            o.insert("up_bytes".to_string(), Json::Num(row.up_bytes as f64));
            o.insert("down_bytes".to_string(), Json::Num(row.down_bytes as f64));
            o.insert("raw_bytes".to_string(), Json::Num(row.raw_bytes as f64));
            o.insert("messages".to_string(), Json::Num(row.messages as f64));
            o.insert("transfer_s".to_string(), Json::Num(row.transfer_s));
            rows.push(Json::Obj(o));
        }
        let mut compute = Vec::with_capacity(self.compute.len());
        for ((round, client), secs) in &self.compute {
            let mut o = BTreeMap::new();
            o.insert("round".to_string(), Json::Num(*round as f64));
            o.insert("client".to_string(), Json::Num(*client as f64));
            o.insert("compute_s".to_string(), Json::Num(*secs));
            compute.push(Json::Obj(o));
        }
        let (wire, raw) = self.by_kind_totals();
        let mut totals = BTreeMap::new();
        totals.insert(
            "by_kind".to_string(),
            Json::Obj(wire.iter().map(|(k, v)| ((*k).to_string(), Json::Num(*v as f64))).collect()),
        );
        totals.insert(
            "raw_by_kind".to_string(),
            Json::Obj(raw.iter().map(|(k, v)| ((*k).to_string(), Json::Num(*v as f64))).collect()),
        );
        totals.insert(
            "up_bytes".to_string(),
            Json::Num(self.rows.values().map(|r| r.up_bytes).sum::<u64>() as f64),
        );
        totals.insert(
            "down_bytes".to_string(),
            Json::Num(self.rows.values().map(|r| r.down_bytes).sum::<u64>() as f64),
        );
        totals.insert("messages".to_string(), Json::Num(self.total_messages() as f64));
        totals.insert(
            "transfer_s".to_string(),
            Json::Num(self.rows.values().map(|r| r.transfer_s).sum()),
        );
        totals.insert(
            "compute_s".to_string(),
            Json::Num(self.compute.values().sum()),
        );
        let mut o = BTreeMap::new();
        o.insert("format".to_string(), Json::Str("sfprompt-ledger".to_string()));
        o.insert("version".to_string(), Json::Num(1.0));
        o.insert("rows".to_string(), Json::Arr(rows));
        o.insert("compute".to_string(), Json::Arr(compute));
        o.insert("totals".to_string(), Json::Obj(totals));
        Json::Obj(o)
    }
}

/// [`phase_of`] keyed by the *label* (the rows table stores labels so the
/// BTreeMap orders kinds alphabetically, matching `ByteMeter::by_kind`).
fn phase_label_of(label: &str) -> &'static str {
    for kind in [
        MsgKind::ModelDistribution,
        MsgKind::SmashedData,
        MsgKind::BodyOutput,
        MsgKind::GradBodyOut,
        MsgKind::GradSmashed,
        MsgKind::Upload,
        MsgKind::AggregateBroadcast,
        MsgKind::FullModel,
        MsgKind::Abort,
    ] {
        if kind.label() == label {
            return phase_of(kind);
        }
    }
    "unknown"
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_reconciles_with_a_meter_fed_at_the_same_sites() {
        let mut meter = ByteMeter::default();
        let mut ledger = Ledger::new();
        let sites = [
            (0u32, 3u32, MsgKind::ModelDistribution, Direction::Downlink, 1000usize, 1000usize),
            (0, 3, MsgKind::SmashedData, Direction::Uplink, 400, 400),
            (0, 3, MsgKind::Upload, Direction::Uplink, 120, 800),
            (1, 5, MsgKind::SmashedData, Direction::Uplink, 401, 401),
            (1, 5, MsgKind::AggregateBroadcast, Direction::Downlink, 900, 900),
        ];
        for (round, client, kind, dir, wire, raw) in sites {
            meter.record_with_raw(kind, dir, wire, raw);
            ledger.tap(round, client, kind, dir, wire, raw, 0.25);
        }
        ledger.tap_compute(0, 3, 1.5);
        ledger.reconcile(&meter).unwrap();

        // Dropping one tap breaks the invariant loudly.
        meter.record(MsgKind::Upload, Direction::Uplink, 64);
        let err = ledger.reconcile(&meter).unwrap_err();
        assert!(err.contains("diverge"), "{err}");
    }

    #[test]
    fn phases_follow_the_paper_structure() {
        assert_eq!(phase_of(MsgKind::ModelDistribution), "distribute");
        assert_eq!(phase_of(MsgKind::SmashedData), "phase2_split");
        assert_eq!(phase_of(MsgKind::GradSmashed), "phase2_split");
        assert_eq!(phase_of(MsgKind::Upload), "phase3_upload");
        assert_eq!(phase_of(MsgKind::FullModel), "full_exchange");
        assert_eq!(phase_label_of("upload"), "phase3_upload");
        assert_eq!(phase_label_of("nonsense"), "unknown");
    }

    #[test]
    fn json_block_carries_rows_compute_and_totals() {
        let mut ledger = Ledger::new();
        ledger.tap(2, 1, MsgKind::Upload, Direction::Uplink, 100, 400, 0.5);
        ledger.tap_compute(2, 1, 2.0);
        let j = ledger.to_json();
        assert_eq!(j.get("format").and_then(Json::as_str), Some("sfprompt-ledger"));
        let rows = j.get("rows").and_then(Json::as_arr).unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get("phase").and_then(Json::as_str), Some("phase3_upload"));
        assert_eq!(rows[0].get("raw_bytes").and_then(Json::as_f64), Some(400.0));
        let totals = j.get("totals").unwrap();
        assert_eq!(totals.get("compute_s").and_then(Json::as_f64), Some(2.0));
        assert_eq!(
            totals.get("by_kind").and_then(|b| b.get("upload")).and_then(Json::as_f64),
            Some(100.0)
        );
        assert!(!ledger.is_empty());
        assert_eq!(ledger.rounds(), vec![2]);
        let (rows, compute) = ledger.round_view(2);
        assert_eq!(rows.len(), 1);
        assert_eq!(compute.get(&1), Some(&2.0));
    }
}
