//! Telemetry: hierarchical tracing spans + a metrics registry for the
//! whole training pipeline.
//!
//! SFPrompt's claims are *resource* claims, so the repro measures where
//! wall-clock time and compute actually go instead of asserting it. One
//! [`Telemetry`] bundle per run holds:
//!
//! * [`Tracer`] — hierarchical spans (run → round → phase → client →
//!   backend stage), stamped with wall-clock **and** sim-clock time,
//!   serialised as JSON Lines or Chrome trace-event JSON (Perfetto);
//! * [`MetricsRegistry`] — counters/gauges/fixed-bucket histograms: stage
//!   latency and achieved GFLOP/s (vs the `flops/` analytic counts), frame
//!   encode/decode time, bytes per message kind, compress/decompress time,
//!   FedAvg aggregation time, EL2N pruning time, fleet events. Also
//!   renders as Prometheus text exposition
//!   ([`MetricsRegistry::to_prometheus_text`], served by
//!   `sfprompt serve --prom ADDR`).
//!
//! The **live-operations** layer (docs/OPS.md) builds on two more pieces
//! that work without the global sink: [`HealthRegistry`] — per-client
//! liveness/latency/straggler state plus run-level anomaly detection
//! ([`AnomalyDetector`]: non-finite/exploding loss, zero-survivor streaks,
//! stalled accuracy) — and [`FlightRecorder`] — a bounded, alloc-free ring
//! of recent events dumped as post-mortem JSONL when a served run dies.
//!
//! ## Enabling
//!
//! Telemetry is **off by default and free when off**: every hook starts
//! with [`active`], whose disabled path is a single relaxed atomic load —
//! no locks, no allocation (`benches/telemetry.rs` guards this). The CLI
//! enables it for `train --trace FILE --metrics FILE`; programmatic runs
//! call [`install`] / [`uninstall`] around [`crate::federation::drive`]
//! with a [`TelemetryObserver`] in the observer chain:
//!
//! ```ignore
//! let telemetry = Arc::new(Telemetry::new());
//! telemetry::install(telemetry.clone());
//! let mut obs = TelemetryObserver::new(telemetry.clone());
//! drive(run.as_mut(), &mut obs)?;
//! telemetry::uninstall();
//! telemetry.tracer.finish();
//! std::fs::write("trace.jsonl", telemetry.tracer.to_jsonl())?;
//! ```
//!
//! The sink is process-global because the hot hooks (a backend stage, a
//! codec frame, a compression pass) sit far below any function that could
//! reasonably thread an `Arc` parameter. Span *structure* still composes:
//! nesting is per-thread and spans carry the tracer instance's id, so two
//! concurrently live `Telemetry` values (e.g. parallel tests) never mix
//! stacks. See `docs/TELEMETRY.md` for the span taxonomy, metric names,
//! and file schemas.

mod flight;
mod health;
mod ledger;
mod merge;
mod metrics;
mod observer;
mod tracer;

pub use flight::{FlightEvent, FlightRecorder, DEFAULT_FLIGHT_CAPACITY};
pub use health::{
    Anomaly, AnomalyDetector, AnomalyKind, ClientHealth, HealthConfig, HealthRegistry,
    RoundHealth, StragglerFlag,
};
pub use ledger::{phase_of, Ledger, LedgerRow};
pub use merge::{merge_traces, MergedProcess, MergedSpan, MergedTrace, ProcessTrace};
pub use metrics::{Histogram, MetricsRegistry};
pub use observer::TelemetryObserver;
pub use tracer::{chrome_trace_from_records, SpanRecord, Tracer};

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

/// One run's worth of telemetry: a tracer and a metrics registry that
/// instrumentation sites reach through [`active`].
#[derive(Default)]
pub struct Telemetry {
    pub tracer: Tracer,
    pub metrics: MetricsRegistry,
}

impl Telemetry {
    pub fn new() -> Telemetry {
        Telemetry { tracer: Tracer::new(), metrics: MetricsRegistry::new() }
    }

    /// Open a span with implicit (thread-local) parenting. The returned
    /// guard closes the span on drop.
    pub fn span(self: &Arc<Self>, cat: &'static str, name: &str) -> SpanGuard {
        let id = self.tracer.open(cat, name, None);
        SpanGuard { telemetry: self.clone(), id, sim_s: None, attrs: Vec::new() }
    }

    /// Open a span under an explicit parent (or as a root when `None`) —
    /// the cross-thread nesting path: capture [`Self::current_span_id`] on
    /// the spawning thread, pass it into the spawned closure.
    pub fn span_under(
        self: &Arc<Self>,
        cat: &'static str,
        name: &str,
        parent: Option<u64>,
    ) -> SpanGuard {
        let id = self.tracer.open(cat, name, Some(parent));
        SpanGuard { telemetry: self.clone(), id, sim_s: None, attrs: Vec::new() }
    }

    /// Open a span whose parent span lives in **another process** (the
    /// coordinator's round span, carried over the control plane). Locally
    /// the span is a root; the cross-process edge is serialised as `rp`
    /// and resolved by `sfprompt trace merge` (docs/TRACING.md).
    pub fn span_remote(
        self: &Arc<Self>,
        cat: &'static str,
        name: &str,
        remote_parent: u64,
    ) -> SpanGuard {
        let id = self.tracer.open_remote(cat, name, remote_parent);
        SpanGuard { telemetry: self.clone(), id, sim_s: None, attrs: Vec::new() }
    }

    /// Innermost span open on the current thread (for explicit parenting).
    pub fn current_span_id(&self) -> Option<u64> {
        self.tracer.current_span_id()
    }

    /// Mirror every span closure into `flight`'s ring (kind = the span's
    /// category, name = the span name, payload = start/duration/thread).
    /// The live-operations layer attaches the serve run's flight recorder
    /// here so a post-mortem shows the last spans, not just round events.
    pub fn attach_flight(&self, flight: Arc<FlightRecorder>) {
        self.tracer.attach_flight(flight);
    }
}

/// RAII span handle: closes its span (recording attributes and the
/// optional sim-clock stamp) when dropped.
pub struct SpanGuard {
    telemetry: Arc<Telemetry>,
    id: u64,
    sim_s: Option<f64>,
    attrs: Vec<(String, f64)>,
}

impl SpanGuard {
    /// Span id — pass to [`Telemetry::span_under`] on another thread.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Attach a numeric attribute (recorded at close).
    pub fn attr(&mut self, key: &str, v: f64) {
        self.attrs.push((key.to_string(), v));
    }

    /// Stamp the simulated fleet clock onto this span.
    pub fn set_sim_s(&mut self, sim_s: f64) {
        self.sim_s = Some(sim_s);
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        self.telemetry
            .tracer
            .close(self.id, self.sim_s, std::mem::take(&mut self.attrs));
    }
}

/// Fast-path flag: instrumentation sites pay one relaxed load when
/// telemetry is off.
static ENABLED: AtomicBool = AtomicBool::new(false);
static GLOBAL: Mutex<Option<Arc<Telemetry>>> = Mutex::new(None);

/// Install `telemetry` as the process-global sink the pipeline hooks
/// report into. Replaces any previous sink.
pub fn install(telemetry: Arc<Telemetry>) {
    *GLOBAL.lock().unwrap() = Some(telemetry);
    ENABLED.store(true, Ordering::SeqCst);
}

/// Remove and return the global sink; hooks go back to the free disabled
/// path immediately.
pub fn uninstall() -> Option<Arc<Telemetry>> {
    ENABLED.store(false, Ordering::SeqCst);
    GLOBAL.lock().unwrap().take()
}

/// The global sink, if one is installed. Disabled path: one relaxed
/// atomic load, no lock, no allocation — safe to call in the tightest
/// loops.
#[inline]
pub fn active() -> Option<Arc<Telemetry>> {
    if !ENABLED.load(Ordering::Relaxed) {
        return None;
    }
    GLOBAL.lock().unwrap().clone()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn install_uninstall_roundtrip() {
        // Serialise against any other test touching the global sink.
        static GATE: Mutex<()> = Mutex::new(());
        let _gate = GATE.lock().unwrap();
        let prior = uninstall(); // isolate from concurrent installs
        assert!(active().is_none());
        let t = Arc::new(Telemetry::new());
        install(t.clone());
        let got = active().expect("installed sink visible");
        assert!(Arc::ptr_eq(&got, &t));
        let back = uninstall().expect("uninstall returns the sink");
        assert!(Arc::ptr_eq(&back, &t));
        assert!(active().is_none());
        if let Some(p) = prior {
            install(p);
        }
    }

    #[test]
    fn span_guard_records_attrs_on_drop() {
        let t = Arc::new(Telemetry::new());
        {
            let mut span = t.span("phase", "phase1_local");
            span.attr("batches", 4.0);
            span.set_sim_s(1.25);
        }
        assert_eq!(t.tracer.finish(), 0);
        let recs = t.tracer.records();
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].name, "phase1_local");
        assert_eq!(recs[0].sim_s, Some(1.25));
        assert_eq!(recs[0].attrs, vec![("batches".to_string(), 4.0)]);
    }

    #[test]
    fn two_telemetry_instances_do_not_mix_stacks() {
        let a = Arc::new(Telemetry::new());
        let b = Arc::new(Telemetry::new());
        let sa = a.span("run", "run:a");
        let _sb = b.span("run", "run:b");
        // b's open span must not become a's implicit parent.
        let child = a.span("round", "round:0");
        drop(child);
        drop(sa);
        a.tracer.finish();
        let recs = a.tracer.records();
        let round = recs.iter().find(|r| r.cat == "round").unwrap();
        let run = recs.iter().find(|r| r.cat == "run").unwrap();
        assert_eq!(round.parent, Some(run.id));
    }
}
